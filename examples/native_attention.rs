//! Native-backend quickstart: the smallest end-to-end use of the pure-Rust
//! MiTA attention path. Unlike the other examples this needs **no**
//! `make artifacts`, no Python, and no PJRT closure — it runs anywhere.
//!
//! 1. Calls the kernels directly (serial, zero-alloc via a [`Workspace`]):
//!    dense vs MiTA forward on one sequence, with a degenerate-parity
//!    check (m = k = n ⇒ identical outputs).
//! 2. Runs a batched problem through [`NativeBackend`] as a **typed
//!    service request** — a validated `QkvBatch` routed by `KernelId`,
//!    with padding expressed as the typed `valid_rows` field (no marker
//!    tensors, no raw op strings) — and execution fans out as
//!    (example × head) work items over pooled per-thread workspaces.
//! 3. Spawns the coordinator engine over `BackendSpec::Native` and drives
//!    the dynamic-batching serving loop against it (the report row shows
//!    queue-wait vs execute latency plus the run's routing stats: `ovf=`
//!    overflow fraction, `imb=` expert load imbalance).
//!
//! Run: `cargo run --release --example native_attention [-- n dim heads]`
//!
//! [`Workspace`]: mita::kernels::Workspace
//! [`NativeBackend`]: mita::runtime::NativeBackend

use std::time::Instant;

use anyhow::Result;
use mita::coordinator::batcher::BatchPolicy;
use mita::coordinator::server::{serve_native, NativeServeConfig, DEFAULT_MAX_INFLIGHT};
use mita::coordinator::Engine;
use mita::data::rng::Rng;
use mita::kernels::{
    dense_attention_mh, mita_attention_mh, MitaKernelConfig, MitaStats, Workspace,
};
use mita::runtime::{BackendSpec, NativeAttnConfig, NativeBackend, Tensor};
use mita::service::{KernelId, QkvBatch};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n = args.first().map(|s| s.parse::<usize>()).transpose()?.unwrap_or(512);
    let dim = args.get(1).map(|s| s.parse::<usize>()).transpose()?.unwrap_or(64);
    let heads = args.get(2).map(|s| s.parse::<usize>()).transpose()?.unwrap_or(4);

    let mut rng = Rng::new(7);
    let mut gen = |len: usize| (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect::<Vec<f32>>();
    let (q, k, v) = (gen(n * dim), gen(n * dim), gen(n * dim));

    // 1) Direct kernel calls: parity on the degenerate config, then timing
    //    of the real MiTA configuration against the dense baseline.
    let mut ws = Workspace::new();
    let pn = n.min(96);
    let sub = pn * dim;
    let pcfg = MitaKernelConfig { m: pn, k: pn, cap_factor: 2, block_q: 8 };
    let mut a = vec![0.0f32; sub];
    let mut b = vec![0.0f32; sub];
    let mut pstats = MitaStats::default();
    mita_attention_mh(
        &q[..sub],
        &k[..sub],
        &v[..sub],
        pn,
        heads,
        dim,
        &pcfg,
        &mut ws,
        &mut a,
        &mut pstats,
    );
    dense_attention_mh(&q[..sub], &k[..sub], &v[..sub], pn, heads, dim, &mut ws, &mut b);
    let max_diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    println!("degenerate parity (n={pn}): max|mita - dense| = {max_diff:.2e}");

    let cfg = MitaKernelConfig::for_seq(n);
    let mut out = vec![0.0f32; n * dim];
    let mut stats = MitaStats::default();
    let t0 = Instant::now();
    mita_attention_mh(&q, &k, &v, n, heads, dim, &cfg, &mut ws, &mut out, &mut stats);
    let mita_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    dense_attention_mh(&q, &k, &v, n, heads, dim, &mut ws, &mut out);
    let dense_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "n={n} dim={dim} heads={heads} (m={}, k={}): mita={mita_ms:.2}ms dense={dense_ms:.2}ms \
         (x{:.2}), overflow {}/{}",
        cfg.m,
        cfg.k,
        dense_ms / mita_ms,
        stats.overflow,
        stats.queries,
    );

    // 2) The same math as a typed service request through the backend's
    //    batched (example × head) dispatch: a validated QkvBatch, a
    //    KernelId, and typed valid_rows padding — the last batch row is
    //    marked padding, never computed, and comes back as zeros.
    let mut attn = NativeAttnConfig::for_shape(n, dim, heads);
    attn.mita = cfg;
    let backend = NativeBackend::new(attn.clone());
    let bsz = 4usize;
    let valid = bsz - 1;
    let fused_data: Vec<f32> = (0..bsz * 3 * n * dim).map(|_| rng.range_f32(-2.0, 2.0)).collect();
    let qkv = QkvBatch::fused(Tensor::f32(&[bsz, 3, n, dim], fused_data)?)?;
    let t0 = Instant::now();
    let out = backend.run_attention(&KernelId::Mita, &qkv, Some(valid))?;
    let batched_ms = t0.elapsed().as_secs_f64() * 1e3;
    let bstats = backend.mita_stats();
    let pad_zeroed = out.as_f32()?[valid * n * dim..].iter().all(|&x| x == 0.0);
    println!(
        "batched b={bsz} valid={valid}: out {:?} in {batched_ms:.2}ms ({} work items, {} pooled \
         workspaces, ovf {:.1}%, pad row zeroed: {pad_zeroed})",
        out.shape(),
        valid * heads,
        backend.workspace_pool().created(),
        bstats.overflow_fraction() * 100.0,
    );

    // 3) The same kernels behind the engine + dynamic batcher.
    let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![])?;
    for op in ["attn.mita", "attn.dense"] {
        let scfg = NativeServeConfig {
            n,
            dim,
            op: op.to_string(),
            requests: 32,
            rate: 0.0,
            queue_cap: 64,
            max_inflight: DEFAULT_MAX_INFLIGHT,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(2),
            },
        };
        let report = serve_native(&engine.handle(), &scfg)?;
        println!("{}", report.row());
    }
    engine.shutdown();
    Ok(())
}
