//! Native-backend quickstart: the smallest end-to-end use of the pure-Rust
//! MiTA attention path. Unlike the other examples this needs **no**
//! `make artifacts`, no Python, and no PJRT closure — it runs anywhere.
//!
//! 1. Calls the kernels directly: dense vs MiTA forward on one sequence,
//!    with a degenerate-parity check (m = k = n ⇒ identical outputs).
//! 2. Spawns the coordinator engine over `BackendSpec::Native` and drives
//!    the dynamic-batching serving loop against it.
//!
//! Run: `cargo run --release --example native_attention [-- n dim heads]`

use std::time::Instant;

use anyhow::Result;
use mita::coordinator::batcher::BatchPolicy;
use mita::coordinator::server::{serve_native, NativeServeConfig};
use mita::coordinator::Engine;
use mita::data::rng::Rng;
use mita::kernels::{dense_attention_mh, mita_attention_mh, MitaKernelConfig};
use mita::runtime::{BackendSpec, NativeAttnConfig};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n = args.first().map(|s| s.parse::<usize>()).transpose()?.unwrap_or(512);
    let dim = args.get(1).map(|s| s.parse::<usize>()).transpose()?.unwrap_or(64);
    let heads = args.get(2).map(|s| s.parse::<usize>()).transpose()?.unwrap_or(4);

    let mut rng = Rng::new(7);
    let mut gen = |len: usize| (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect::<Vec<f32>>();
    let (q, k, v) = (gen(n * dim), gen(n * dim), gen(n * dim));

    // 1) Direct kernel calls: parity on the degenerate config, then timing
    //    of the real MiTA configuration against the dense baseline.
    let pn = n.min(96);
    let sub = pn * dim;
    let pcfg = MitaKernelConfig { m: pn, k: pn, cap_factor: 2, block_q: 8 };
    let mut a = vec![0.0f32; sub];
    let mut b = vec![0.0f32; sub];
    mita_attention_mh(&q[..sub], &k[..sub], &v[..sub], pn, heads, dim, &pcfg, &mut a);
    dense_attention_mh(&q[..sub], &k[..sub], &v[..sub], pn, heads, dim, &mut b);
    let max_diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    println!("degenerate parity (n={pn}): max|mita - dense| = {max_diff:.2e}");

    let cfg = MitaKernelConfig::for_seq(n);
    let mut out = vec![0.0f32; n * dim];
    let t0 = Instant::now();
    let overflow = mita_attention_mh(&q, &k, &v, n, heads, dim, &cfg, &mut out);
    let mita_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    dense_attention_mh(&q, &k, &v, n, heads, dim, &mut out);
    let dense_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "n={n} dim={dim} heads={heads} (m={}, k={}): mita={mita_ms:.2}ms dense={dense_ms:.2}ms \
         (x{:.2}), overflow {overflow}/{}",
        cfg.m,
        cfg.k,
        dense_ms / mita_ms,
        n * heads
    );

    // 2) The same kernels behind the engine + dynamic batcher.
    let attn = NativeAttnConfig { n, dim, heads, mita: cfg };
    let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![])?;
    for op in ["attn.mita", "attn.dense"] {
        let scfg = NativeServeConfig {
            n,
            dim,
            op: op.to_string(),
            requests: 32,
            rate: 0.0,
            queue_cap: 64,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(2),
            },
        };
        let report = serve_native(&engine.handle(), &scfg)?;
        println!("{}", report.row());
    }
    engine.shutdown();
    Ok(())
}
