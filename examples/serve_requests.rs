//! Serving example: run the dynamic-batching coordinator against a compiled
//! `predict` artifact under open-loop load, then print the latency/
//! throughput report — the Fig. 5 measurement path in miniature.
//!
//! Run: `make artifacts && cargo run --release --example serve_requests
//!       [-- <bundle> [requests] [rate]]`   (default: f5_mita_n1024)

use anyhow::Result;
use mita::coordinator::batcher::BatchPolicy;
use mita::coordinator::server::{serve, ServeConfig, DEFAULT_MAX_INFLIGHT};
use mita::coordinator::Engine;
use mita::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let bundle = args.first().map(|s| s.as_str()).unwrap_or("f5_mita_n1024").to_string();
    let requests = args.get(1).map(|s| s.parse::<usize>()).transpose()?.unwrap_or(64);
    let rate = args.get(2).map(|s| s.parse::<f64>()).transpose()?.unwrap_or(0.0);

    let rt = Runtime::load("artifacts")?;
    let spec = rt.manifest().bundle(&bundle)?.clone();
    let predict = rt.manifest().bundle_artifact(&bundle, "predict")?.to_string();
    let init = rt.manifest().bundle_artifact(&bundle, "init")?.to_string();
    drop(rt);

    println!(
        "serving {bundle}: N={} attention={} batch={} ({} requests, rate={})",
        spec.model.num_tokens(),
        spec.model.attention.kind,
        spec.train.batch_size,
        requests,
        if rate > 0.0 { format!("{rate}/s") } else { "closed-loop".into() }
    );

    let engine = Engine::spawn("artifacts".into(), vec![predict])?;
    engine.handle().bind_init(&bundle, &init, 0, spec.param_count())?;
    // Sweep two batching policies to show the latency/throughput trade-off.
    for max_wait_ms in [1u64, 10u64] {
        let cfg = ServeConfig {
            bundle: bundle.clone(),
            binding: bundle.clone(),
            requests,
            rate,
            queue_cap: requests.max(64),
            max_inflight: DEFAULT_MAX_INFLIGHT,
            policy: BatchPolicy {
                max_batch: spec.train.batch_size,
                max_wait: std::time::Duration::from_millis(max_wait_ms),
            },
        };
        let report = serve(&engine.handle(), &spec, &bundle, &cfg)?;
        println!("max_wait={max_wait_ms}ms  {}", report.row());
    }
    engine.shutdown();
    Ok(())
}
