//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the AOT runtime, trains the tiny `quickstart` bundle for a few
//! steps, evaluates it, and classifies one image through the `predict`
//! artifact — all from Rust, no Python on the path.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use mita::coordinator::Trainer;
use mita::data::{BatchSource, Split};
use mita::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::load("artifacts")?;
    let bundle = "quickstart";
    let spec = rt.manifest().bundle(bundle)?.clone();
    println!(
        "model: {} depth={} dim={} attention={} (m={}, k={})",
        spec.model.task,
        spec.model.depth,
        spec.model.dim,
        spec.model.attention.kind,
        spec.model.attention.m,
        spec.model.attention.k
    );

    // 1) Train for a handful of steps.
    let source = BatchSource::for_bundle(&spec)?;
    let mut trainer = Trainer::new(&rt, bundle, 0)?;
    trainer.train(&source, 30, 10)?;
    let ev = trainer.eval(&source, 4)?;
    println!("after 30 steps: eval_loss={:.3} eval_acc={:.3}", ev.loss, ev.accuracy);

    // 2) Single-batch prediction through the predict artifact.
    let (x, y) = source.batch(Split::Val, 0)?;
    let predict = rt.manifest().bundle_artifact(bundle, "predict")?;
    let mut inputs = trainer.params()?;
    inputs.push(x);
    let outs = rt.run(predict, &inputs)?;
    let preds = outs[0].argmax_last()?;
    let correct = preds
        .as_i32()?
        .iter()
        .zip(y.as_i32()?)
        .filter(|(p, t)| p == t)
        .count();
    println!("predict batch: {}/{} correct", correct, y.len());

    let stats = rt.stats();
    println!(
        "runtime: {} compiles ({:.2}s), {} executions ({:.3}s total)",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );
    Ok(())
}
