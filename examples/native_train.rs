//! Smallest end-to-end native training demo: train a tiny MiTA
//! transformer on the synthetic LRA text task, evaluate, checkpoint,
//! reload through the typed service surface, and confirm the served
//! logits match the trainer's model exactly.
//!
//! Run with: `cargo run --release --example native_train`

use mita::coordinator::checkpoint;
use mita::data::lra;
use mita::data::Split;
use mita::kernels::{MitaStats, WorkspacePool, OP_ATTN_MITA};
use mita::model::{MitaModel, ModelConfig, ModelScratch};
use mita::runtime::{Backend, NativeAttnConfig, NativeBackend, Tensor};
use mita::service::{BindingId, ServiceRequest};
use mita::train::{AdamWConfig, NativeTrainer, TrainConfig};

fn main() -> anyhow::Result<()> {
    // A tiny text-classification task and a model shaped for it.
    let (seq, vocab) = (64usize, 64usize);
    let task = lra::by_name("text", seq, vocab, 1);
    let cfg = ModelConfig::for_task(task.as_ref(), 32, 2, 2, OP_ATTN_MITA);
    println!(
        "model: n={seq} dim={} heads={} depth={} params={}",
        cfg.dim,
        cfg.heads,
        cfg.depth,
        cfg.param_count()
    );
    let model = MitaModel::init(cfg, 7)?;

    // Train: exact backward passes + AdamW, deterministic minibatches.
    let mut trainer = NativeTrainer::new(model, AdamWConfig::default(), 3)?;
    let run = TrainConfig {
        steps: 60,
        batch: 8,
        eval_every: 20,
        eval_batches: 4,
        log_every: 10,
        checkpoint: None,
    };
    let outcome = trainer.train(task.as_ref(), &run)?;
    println!(
        "trained {} steps: loss {:.4} -> {:.4} (tail {:.4}), val loss {:.4}, val acc {:.3}, \
         {:.1} ms/step",
        outcome.steps,
        outcome.first_loss,
        outcome.final_loss,
        outcome.tail_loss,
        outcome.final_eval.loss,
        outcome.final_eval.accuracy,
        outcome.mean_step_secs * 1e3
    );

    // Checkpoint through the shared container format...
    let dir = std::env::temp_dir().join(format!("mita_native_train_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("text.ckpt");
    trainer.model().save(&path)?;
    println!("checkpoint saved to {}", path.display());

    // ...and reload it exactly the way `serve --workload model --checkpoint` does:
    // BindCheckpoint on the native backend, then typed model-forward.
    let mut backend = NativeBackend::new(NativeAttnConfig::for_shape(seq, 32, 2));
    backend.execute(ServiceRequest::BindCheckpoint {
        binding: BindingId::from("text"),
        params: checkpoint::load(&path)?,
    })?;
    let batch = 4usize;
    let (tokens, labels) = lra::batch_host(task.as_ref(), Split::Val, 0, batch);
    let served = backend.run_model(
        &BindingId::from("text"),
        &Tensor::i32(&[batch, seq], tokens.clone())?,
        None,
    )?;

    // The trainer's own inference forward must agree bit-for-bit.
    let registry = trainer.model().registry();
    let pool = WorkspacePool::new();
    let mut scratch = ModelScratch::default();
    let mut stats = MitaStats::default();
    let want = trainer.model().forward(
        &tokens,
        batch,
        batch,
        &registry,
        &pool,
        &mut scratch,
        &mut stats,
    )?;
    anyhow::ensure!(
        served.as_f32()? == want.as_slice(),
        "served logits diverged from the trained model"
    );
    let classes = trainer.model().cfg.classes;
    let correct = want
        .chunks_exact(classes)
        .zip(&labels)
        .filter(|(row, &y)| {
            row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map(|(i, _)| i)
                == Some(y as usize)
        })
        .count();
    println!("round-trip OK: served logits match exactly; {correct}/{batch} val examples correct");

    std::fs::remove_file(&path).ok();
    std::fs::remove_dir(&dir).ok();
    Ok(())
}
