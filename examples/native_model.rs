//! Model-subsystem quickstart: the smallest end-to-end use of the native
//! MiTA transformer. Needs **no** `make artifacts`, no Python, and no
//! PJRT closure — it runs anywhere.
//!
//! 1. Builds an LRA ListOps task and a matching [`MitaModel`], then runs
//!    one batched forward with MiTA blocks and again with dense blocks
//!    (same parameters, different per-block kernel) and compares the
//!    predicted classes + routing stats.
//! 2. Round-trips the model through the native checkpoint format.
//! 3. Spawns the coordinator engine over `BackendSpec::Native`, binds the
//!    model, sends one **typed** model-forward request (padding is the
//!    typed `valid_rows` field — no marker tensors), and drives the
//!    dynamic-batching serving loop with token requests (the report row
//!    shows queue-wait vs execute latency plus routing stats).
//!
//! Run: `cargo run --release --example native_model [-- seq_len dim heads]`
//!
//! [`MitaModel`]: mita::model::MitaModel

use anyhow::Result;
use mita::coordinator::batcher::BatchPolicy;
use mita::coordinator::{serve_model, Engine, ModelServeConfig, DEFAULT_MAX_INFLIGHT};
use mita::data::lra;
use mita::data::Split;
use mita::flops;
use mita::kernels::{MitaStats, WorkspacePool, OP_ATTN_DENSE, OP_ATTN_MITA};
use mita::model::{MitaModel, ModelConfig, ModelScratch, OP_MODEL_INIT};
use mita::runtime::{BackendSpec, NativeAttnConfig, Tensor};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n = args.first().map(|s| s.parse::<usize>()).transpose()?.unwrap_or(256);
    let dim = args.get(1).map(|s| s.parse::<usize>()).transpose()?.unwrap_or(64);
    let heads = args.get(2).map(|s| s.parse::<usize>()).transpose()?.unwrap_or(4);

    // 1) Task + model; one forward per kernel choice, shared parameters.
    let task = lra::by_name("listops", n, 16, 0xC0FFEE);
    let cfg = ModelConfig::for_task(task.as_ref(), dim, heads, 2, OP_ATTN_MITA);
    println!(
        "listops n={n} dim={dim} heads={heads} depth={} (m={}, k={}): {} params, {} / fwd",
        cfg.depth,
        cfg.mita.m,
        cfg.mita.k,
        cfg.param_count(),
        flops::gflops(flops::native_model_flops(&cfg)),
    );
    let model = MitaModel::init(cfg, 7)?;
    let dense = model.with_kernel(OP_ATTN_DENSE)?;
    let registry = model.registry();
    let pool = WorkspacePool::new();
    let mut scratch = ModelScratch::default();
    let mut stats = MitaStats::default();

    let bsz = 4usize;
    let (tokens, labels) = lra::batch_host(task.as_ref(), Split::Val, 0, bsz);
    let lm = model.forward(&tokens, bsz, bsz, &registry, &pool, &mut scratch, &mut stats)?;
    let ld = dense.forward(&tokens, bsz, bsz, &registry, &pool, &mut scratch, &mut stats)?;
    let classes = model.cfg.classes;
    for i in 0..bsz {
        // First-maximum argmax, matching Tensor::argmax_last's tie-break.
        let pick = |l: &[f32]| {
            let row = &l[i * classes..(i + 1) * classes];
            let mut best = 0usize;
            for (c, &x) in row.iter().enumerate() {
                if x > row[best] {
                    best = c;
                }
            }
            best
        };
        println!(
            "  example {i}: label={} mita_pred={} dense_pred={}",
            labels[i],
            pick(&lm),
            pick(&ld)
        );
    }
    println!(
        "routing over {} MiTA-block calls: ovf={:.1}% imb={:.2}",
        stats.calls,
        stats.overflow_fraction() * 100.0,
        stats.load_imbalance()
    );

    // 2) Checkpoint round-trip through the shared native format.
    let path = std::env::temp_dir().join(format!("native_model_{}.ckpt", std::process::id()));
    model.save(&path)?;
    let reloaded = MitaModel::load(&path)?;
    let lr = reloaded.forward(&tokens, bsz, bsz, &registry, &pool, &mut scratch, &mut stats)?;
    println!("checkpoint round-trip: logits identical = {}", lr == lm);
    std::fs::remove_file(&path).ok();

    // 3) The same model behind the engine: first one typed model-forward
    //    request (tokens + valid_rows — the second batch row is padding
    //    the backend never computes), then the dynamic batcher.
    let attn = NativeAttnConfig::for_shape(n, dim, heads).with_model(model.cfg.clone());
    let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![])?;
    engine.handle().bind_init("model", OP_MODEL_INIT, 7, 0)?;
    let two = Tensor::i32(&[2, n], tokens[..2 * n].to_vec())?;
    let logits = engine.handle().model_forward("model", two, Some(1))?;
    let pad_zeroed = logits.as_f32()?[classes..].iter().all(|&x| x == 0.0);
    println!(
        "typed model.forward: logits {:?} (row 1 is padding, zeroed: {pad_zeroed})",
        logits.shape()
    );
    let scfg = ModelServeConfig {
        task: "listops".into(),
        seq_len: n,
        vocab: 16,
        binding: "model".into(),
        requests: 32,
        rate: 0.0,
        queue_cap: 64,
        max_inflight: DEFAULT_MAX_INFLIGHT,
        policy: BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(2) },
    };
    let report = serve_model(&engine.handle(), &scfg)?;
    println!("{}", report.row());
    engine.shutdown();
    Ok(())
}
