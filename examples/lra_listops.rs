//! Domain example: long-sequence reasoning on the ListOps task (the
//! hierarchical workload the paper's LRA evaluation leads with).
//!
//! Trains the same 2-layer transformer with standard attention and with
//! MiTA, then compares accuracy and wall-clock — the paper's core claim
//! (Tab. 5) in one runnable binary. Also demonstrates the data substrate:
//! prints a decoded sample expression with its ground-truth value.
//!
//! Run: `make artifacts && cargo run --release --example lra_listops [-- steps]`

use anyhow::Result;
use mita::data::lra;
use mita::data::Split;
use mita::harness::train_bundle;
use mita::runtime::Runtime;

fn decode_listops(tokens: &[i32]) -> String {
    let mut s = String::new();
    for &t in tokens {
        match t {
            0..=9 => s.push_str(&format!("{t} ")),
            10 => s.push_str("[MAX "),
            11 => s.push_str("[MIN "),
            12 => s.push_str("[MED "),
            13 => s.push_str("[SM "),
            14 => s.push_str("] "),
            _ => {}
        }
    }
    s
}

fn main() -> Result<()> {
    let steps = std::env::args().nth(1).map(|s| s.parse::<usize>()).transpose()?;

    // Show what the task looks like (skip degenerate single-leaf samples).
    let task = lra::by_name("listops", 256, 16, 1);
    let (tokens, label) = (0..)
        .map(|i| task.sample(Split::Train, i))
        .find(|(t, _)| t.iter().filter(|&&x| x != 15).count() > 20)
        .unwrap();
    let expr = decode_listops(&tokens);
    println!("sample expression (value = {label}):");
    println!("  {}…\n", &expr[..expr.len().min(120)]);

    let rt = Runtime::load("artifacts")?;
    let mut results = Vec::new();
    for method in ["standard", "mita"] {
        let bundle = format!("t5_listops_{method}");
        let (_t, oc) = train_bundle(&rt, &bundle, 0, steps, None)?;
        println!(
            "{method:8}  acc={:.3}  step={:.0}ms  total={:.1}s",
            oc.eval.accuracy,
            oc.mean_step_secs * 1e3,
            oc.train_secs
        );
        results.push((method, oc));
    }
    let (std_oc, mita_oc) = (&results[0].1, &results[1].1);
    println!(
        "\nMiTA speedup: ×{:.2} wall-clock, accuracy Δ {:+.1} pts",
        std_oc.mean_step_secs / mita_oc.mean_step_secs,
        (mita_oc.eval.accuracy - std_oc.eval.accuracy) * 100.0
    );
    Ok(())
}
