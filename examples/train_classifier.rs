//! End-to-end training driver (the DESIGN.md validation workload): train a
//! MiTA transformer classifier on the synthetic image corpus for its full
//! step budget, log the loss curve, evaluate, and save a checkpoint that
//! the figure/table harness reuses.
//!
//! Run: `make artifacts && cargo run --release --example train_classifier
//!       [-- <bundle> [steps]]`   (default bundle: t2_mita)

use anyhow::Result;
use mita::data::BatchSource;
use mita::harness::{checkpoint_path, train_bundle};
use mita::report::ascii_chart;
use mita::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let bundle = args.first().map(|s| s.as_str()).unwrap_or("t2_mita").to_string();
    let steps = args.get(1).map(|s| s.parse::<usize>()).transpose()?;

    let rt = Runtime::load("artifacts")?;
    let spec = rt.manifest().bundle(&bundle)?.clone();
    println!(
        "training {bundle}: {} tokens, attention={} m={} k={}, batch={} lr={}",
        spec.model.num_tokens(),
        spec.model.attention.kind,
        spec.model.attention.m,
        spec.model.attention.k,
        spec.train.batch_size,
        spec.train.lr
    );

    let (trainer, outcome) = train_bundle(&rt, &bundle, 0, steps, None)?;

    println!("\nloss curve:");
    println!("{}", ascii_chart(&[(&bundle, outcome.loss_curve.clone())], 64, 14));
    println!(
        "steps={} tail_loss={:.4} eval_loss={:.4} eval_acc={:.4} mean_step={:.1}ms total={:.1}s",
        outcome.steps,
        outcome.tail_loss,
        outcome.eval.loss,
        outcome.eval.accuracy,
        outcome.mean_step_secs * 1e3,
        outcome.train_secs
    );

    // Throughput accounting (examples/sec through the full train step).
    let examples = outcome.steps * spec.train.batch_size;
    println!(
        "throughput: {:.1} examples/s ({} examples in {:.1}s)",
        examples as f64 / outcome.train_secs,
        examples,
        outcome.train_secs
    );

    let ckpt = checkpoint_path(&bundle);
    trainer.save_checkpoint(&ckpt)?;
    println!("checkpoint: {}", ckpt.display());

    // Baseline comparison on a held-out batch: majority-class accuracy.
    let source = BatchSource::for_bundle(&spec)?;
    let (_x, y) = source.batch(mita::data::Split::Val, 99)?;
    let ys = y.as_i32()?;
    let mut counts = std::collections::HashMap::new();
    for &v in ys {
        *counts.entry(v).or_insert(0usize) += 1;
    }
    let majority = counts.values().max().copied().unwrap_or(0) as f64 / ys.len() as f64;
    println!(
        "sanity: model acc {:.3} vs majority-class baseline {:.3}",
        outcome.eval.accuracy, majority
    );
    Ok(())
}
