#!/usr/bin/env bash
# Record a perf-trajectory point: run the four quick native benches
# under the forced-scalar SIMD lane and then under the auto lane, and
# append all eight runs (bench × lane) to the committed trajectory files
# at the repo root:
#
#   BENCH_attn_native.json    <- rust/benches/attn_microbench.rs
#   BENCH_model_native.json   <- rust/benches/model_native.rs
#   BENCH_decode_native.json  <- rust/benches/decode_native.rs
#   BENCH_load_native.json    <- rust/benches/load_native.rs
#
# Each trajectory file is {"bench": ..., "entries": [...]} where every
# entry is exactly the JSON one bench run wrote (its "simd_lane" field
# tells scalar baseline and dispatched runs apart) plus "recorded_utc",
# the recording commit (short SHA **and** `git describe --dirty`, so a
# point recorded from an uncommitted tree is visibly tainted), and the
# lane the run was forced to. Run from anywhere inside the repo; commit
# the two root files afterwards to extend the trajectory. See
# docs/PERF.md for how the trajectory is read.
set -euo pipefail

cd "$(git rev-parse --show-toplevel)"
commit=$(git rev-parse --short HEAD)
# --always: repos without tags fall back to the abbreviated SHA (still
# carrying the -dirty suffix when the working tree has changes).
describe=$(git describe --always --dirty)

append() { # append <run-json> <trajectory-json> <forced-lane>
    python3 - "$1" "$2" "$3" "$commit" "$describe" <<'PY'
import json, sys, datetime

run_path, traj_path, lane, commit, describe = sys.argv[1:6]
with open(run_path) as f:
    entry = json.load(f)
entry["recorded_utc"] = datetime.datetime.now(datetime.timezone.utc).isoformat(
    timespec="seconds"
)
entry["commit"] = commit
entry["describe"] = describe

# The bench stamps the lane it actually dispatched; a mismatch with the
# forced MITA_SIMD means the point would be attributed to the wrong
# lane — refuse to record it.
ran = entry.get("simd_lane")
if ran is not None and lane != "auto" and ran != lane:
    sys.exit(f"{run_path}: bench ran lane {ran!r} but {lane!r} was forced")
entry.setdefault("simd_lane", lane)

try:
    with open(traj_path) as f:
        traj = json.load(f)
except FileNotFoundError:
    traj = {"bench": entry.get("bench", "?"), "entries": []}
traj.setdefault("entries", []).append(entry)
traj.pop("note", None)  # drop the unpopulated-skeleton marker once real

with open(traj_path, "w") as f:
    json.dump(traj, f, indent=2)
    f.write("\n")
print(
    f"appended {run_path} (simd_lane={entry.get('simd_lane')}, "
    f"describe={describe}) -> {traj_path}"
)
PY
}

for lane in scalar auto; do
    echo "== attn_microbench --quick (MITA_SIMD=$lane) =="
    (cd rust && MITA_SIMD=$lane cargo bench --bench attn_microbench -- --quick)
    append rust/BENCH_attn_native.json BENCH_attn_native.json "$lane"

    echo "== model_native --quick (MITA_SIMD=$lane) =="
    (cd rust && MITA_SIMD=$lane cargo bench --bench model_native -- --quick)
    append rust/BENCH_model_native.json BENCH_model_native.json "$lane"

    echo "== decode_native --quick (MITA_SIMD=$lane) =="
    (cd rust && MITA_SIMD=$lane cargo bench --bench decode_native -- --quick)
    append rust/BENCH_decode_native.json BENCH_decode_native.json "$lane"

    echo "== load_native --quick (MITA_SIMD=$lane) =="
    (cd rust && MITA_SIMD=$lane cargo bench --bench load_native -- --quick)
    append rust/BENCH_load_native.json BENCH_load_native.json "$lane"
done

echo
echo "Trajectory updated; review and commit BENCH_attn_native.json,"
echo "BENCH_model_native.json, BENCH_decode_native.json, and"
echo "BENCH_load_native.json at the repo root."
