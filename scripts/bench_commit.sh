#!/usr/bin/env bash
# Record a perf-trajectory point: run the two quick native benches under
# the forced-scalar SIMD lane and then under the auto lane, and append
# all four runs (bench × lane) to the committed trajectory files at the
# repo root:
#
#   BENCH_attn_native.json   <- rust/benches/attn_microbench.rs
#   BENCH_model_native.json  <- rust/benches/model_native.rs
#
# Each trajectory file is {"bench": ..., "entries": [...]} where every
# entry is exactly the JSON one bench run wrote (its "simd_lane" field
# tells scalar baseline and dispatched runs apart) plus "recorded_utc"
# and the recording commit. Run from anywhere inside the repo; commit
# the two root files afterwards to extend the trajectory. See
# docs/PERF.md for how the trajectory is read.
set -euo pipefail

cd "$(git rev-parse --show-toplevel)"
commit=$(git rev-parse --short HEAD)

append() { # append <run-json> into <trajectory-json> tagged with commit
    python3 - "$1" "$2" "$commit" <<'PY'
import json, sys, datetime

run_path, traj_path, commit = sys.argv[1:4]
with open(run_path) as f:
    entry = json.load(f)
entry["recorded_utc"] = datetime.datetime.now(datetime.timezone.utc).isoformat(
    timespec="seconds"
)
entry["commit"] = commit

try:
    with open(traj_path) as f:
        traj = json.load(f)
except FileNotFoundError:
    traj = {"bench": entry.get("bench", "?"), "entries": []}
traj.setdefault("entries", []).append(entry)
traj.pop("note", None)  # drop the unpopulated-skeleton marker once real

with open(traj_path, "w") as f:
    json.dump(traj, f, indent=2)
    f.write("\n")
print(f"appended {run_path} (simd_lane={entry.get('simd_lane')}) -> {traj_path}")
PY
}

for lane in scalar auto; do
    echo "== attn_microbench --quick (MITA_SIMD=$lane) =="
    (cd rust && MITA_SIMD=$lane cargo bench --bench attn_microbench -- --quick)
    append rust/BENCH_attn_native.json BENCH_attn_native.json

    echo "== model_native --quick (MITA_SIMD=$lane) =="
    (cd rust && MITA_SIMD=$lane cargo bench --bench model_native -- --quick)
    append rust/BENCH_model_native.json BENCH_model_native.json
done

echo
echo "Trajectory updated; review and commit BENCH_attn_native.json and"
echo "BENCH_model_native.json at the repo root."
