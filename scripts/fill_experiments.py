#!/usr/bin/env python3
"""Transcribe `mita all` output into EXPERIMENTS.md placeholders.

Usage: python scripts/fill_experiments.py /tmp/mita_results.log
Idempotent: placeholders are HTML comments that survive filling (each block
is written between its marker and the next section).
"""

import re
import sys
from pathlib import Path

MARKERS = {
    "T2": "## Table 2",
    "T3": "## Table 3",
    "T4": "## Table 4",
    "T5": "## Table 5",
    "T6": "## Table 6",
    "T7": "## Table 7",
    "F5": "## Figure 5",
    "F34": "## Figures 3/4",
    "F8": "## Figure 8",
    "F9": "## Figure 9",
    "F10": "## Figure 10",
    "CPLX": "## Complexity",
}


def extract_blocks(log: str):
    """Split the run log into sections keyed by their '## ...' headers."""
    blocks = {}
    current_key, current = None, []
    for line in log.splitlines():
        matched = None
        for key, header in MARKERS.items():
            if line.startswith(header):
                matched = key
                break
        if matched:
            if current_key:
                blocks[current_key] = "\n".join(current).strip()
            current_key, current = matched, []
        elif current_key is not None:
            # Drop harness chatter / PJRT log noise inside a section.
            if (
                line.startswith("[")
                or line.startswith("SCHEDULE_DONE")
                or line.startswith("EXIT")
                or "TfrtCpuClient" in line
            ):
                continue
            current.append(line)
    if current_key:
        blocks[current_key] = "\n".join(current).strip()
    return blocks


def main():
    log_path = sys.argv[1] if len(sys.argv) > 1 else "/tmp/mita_results.log"
    log = Path(log_path).read_text()
    blocks = extract_blocks(log)

    exp_path = Path(__file__).resolve().parent.parent / "EXPERIMENTS.md"
    text = exp_path.read_text()
    filled = 0
    for key, content in blocks.items():
        marker = f"<!-- {key} -->"
        if marker in text and content:
            text = text.replace(marker, f"{marker}\n\n```\n{content}\n```", 1)
            filled += 1
    exp_path.write_text(text)
    print(f"filled {filled} sections from {log_path}: {sorted(blocks)}")


if __name__ == "__main__":
    main()
