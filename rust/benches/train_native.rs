//! Bench: native training throughput + MiTA-vs-dense time-to-accuracy on
//! a tiny LRA shape — the training-side counterpart of `model_native`.
//!
//! One row per attention kernel: the same seeded model and the same
//! deterministic minibatch stream train under `attn.mita` and
//! `attn.dense` blocks (the kernel choice is the only difference),
//! measuring steps/sec, the loss trajectory, the wall-clock to reach a
//! 5%-below-initial trailing-mean loss (time-to-loss), and final val
//! loss/accuracy. Everything lands in `BENCH_train_native.json` so CI
//! archives the training perf trajectory next to the kernel and model
//! benches.
//!
//! Quick mode for CI smoke runs: pass `--quick` after `--`, or set
//! `MITA_BENCH_QUICK=1`.

use std::fmt::Write as _;

use mita::data::lra;
use mita::kernels::{OP_ATTN_DENSE, OP_ATTN_MITA};
use mita::model::{MitaModel, ModelConfig};
use mita::train::{json_num, AdamWConfig, NativeTrainer, TrainConfig};

const TASK: &str = "text";
const SEQ: usize = 64;
const VOCAB: usize = 64;
const DIM: usize = 32;
const HEADS: usize = 2;
const DEPTH: usize = 2;
const BATCH: usize = 8;
/// Trailing-mean window for the time-to-loss milestone.
const WINDOW: usize = 5;

struct Row {
    kernel: &'static str,
    steps: usize,
    total_secs: f64,
    steps_per_sec: f64,
    first_loss: f64,
    final_loss: f64,
    time_to_loss_secs: Option<f64>,
    eval_loss: f64,
    eval_acc: f64,
    overflow_fraction: f64,
    losses: Vec<f64>,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MITA_BENCH_QUICK").is_ok_and(|v| v == "1");
    let steps = if quick { 15 } else { 80 };
    println!(
        "# train_native — {TASK} n={SEQ} dim={DIM} heads={HEADS} depth={DEPTH} batch={BATCH} \
         steps={steps} quick={quick} threads={}",
        mita::kernels::par::num_threads()
    );

    let rows =
        vec![run_kernel(OP_ATTN_MITA, "mita", steps), run_kernel(OP_ATTN_DENSE, "dense", steps)];

    println!("\nkernel, steps/s, first_loss, final_loss, time_to_loss_s, eval_loss, eval_acc");
    for r in &rows {
        println!(
            "{}, {:.2}, {:.4}, {:.4}, {}, {:.4}, {:.3}",
            r.kernel,
            r.steps_per_sec,
            r.first_loss,
            r.final_loss,
            r.time_to_loss_secs.map(|s| format!("{s:.3}")).unwrap_or_else(|| "-".into()),
            r.eval_loss,
            r.eval_acc
        );
    }
    write_json(quick, steps, &rows);
}

fn run_kernel(kernel: &'static str, short: &'static str, steps: usize) -> Row {
    let task = lra::by_name(TASK, SEQ, VOCAB, 0xBEEF);
    let cfg = ModelConfig::for_task(task.as_ref(), DIM, HEADS, DEPTH, kernel);
    let model = MitaModel::init(cfg, 7).expect("model init");
    let mut trainer =
        NativeTrainer::new(model, AdamWConfig::default(), 11).expect("trainer init");
    let run = TrainConfig {
        steps,
        batch: BATCH,
        eval_every: 0,
        eval_batches: 4,
        log_every: 0,
        checkpoint: None,
    };
    let outcome = trainer.train(task.as_ref(), &run).expect("training run");
    println!(
        "{short:6} {} steps in {:.2}s ({:.2} steps/s): loss {:.4} -> {:.4}, eval acc {:.3}",
        outcome.steps,
        outcome.mean_step_secs * outcome.steps as f64,
        1.0 / outcome.mean_step_secs.max(1e-9),
        outcome.first_loss,
        outcome.final_loss,
        outcome.final_eval.accuracy
    );

    let losses: Vec<f64> = trainer.history.iter().map(|r| r.loss).collect();
    // Wall-clock until the trailing WINDOW-step mean first drops 5% below
    // the initial loss.
    let target = losses[0] * 0.95;
    let mut elapsed = 0.0f64;
    let mut time_to_loss = None;
    for (i, rec) in trainer.history.iter().enumerate() {
        elapsed += rec.secs;
        if i + 1 >= WINDOW && time_to_loss.is_none() {
            let mean: f64 = losses[i + 1 - WINDOW..=i].iter().sum::<f64>() / WINDOW as f64;
            if mean < target {
                time_to_loss = Some(elapsed);
            }
        }
    }
    let total_secs: f64 = trainer.history.iter().map(|r| r.secs).sum();
    Row {
        kernel: short,
        steps: outcome.steps,
        total_secs,
        steps_per_sec: outcome.steps as f64 / total_secs.max(1e-9),
        first_loss: outcome.first_loss,
        final_loss: outcome.final_loss,
        time_to_loss_secs: time_to_loss,
        eval_loss: outcome.final_eval.loss,
        eval_acc: outcome.final_eval.accuracy,
        overflow_fraction: trainer.mita_stats().overflow_fraction(),
        losses,
    }
}

/// JSON artifact for the CI perf trajectory: one row per kernel with the
/// full loss trajectory.
fn write_json(quick: bool, steps: usize, rows: &[Row]) {
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"train_native\",");
    let _ = writeln!(json, "  \"task\": \"{TASK}\",");
    let _ = writeln!(json, "  \"n\": {SEQ},");
    let _ = writeln!(json, "  \"dim\": {DIM},");
    let _ = writeln!(json, "  \"heads\": {HEADS},");
    let _ = writeln!(json, "  \"depth\": {DEPTH},");
    let _ = writeln!(json, "  \"batch\": {BATCH},");
    let _ = writeln!(json, "  \"steps\": {steps},");
    let _ = writeln!(json, "  \"quick\": {quick},");
    let _ = writeln!(json, "  \"threads\": {},", mita::kernels::par::num_threads());
    let _ = writeln!(json, "  \"rows\": [");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let ttl = r
            .time_to_loss_secs
            .map(|s| format!("{s:.4}"))
            .unwrap_or_else(|| "null".into());
        // Loss fields go through json_num: a diverged run's NaN becomes
        // null instead of corrupting the artifact.
        let curve: Vec<String> = r.losses.iter().map(|&l| json_num(l, 4)).collect();
        let _ = writeln!(
            json,
            "    {{\"kernel\": \"{}\", \"steps\": {}, \"total_secs\": {:.4}, \
             \"steps_per_sec\": {:.3}, \"first_loss\": {}, \"final_loss\": {}, \
             \"time_to_loss_secs\": {ttl}, \"eval_loss\": {}, \"eval_acc\": {:.3}, \
             \"overflow_fraction\": {:.4}, \"loss_curve\": [{}]}}{comma}",
            r.kernel,
            r.steps,
            r.total_secs,
            r.steps_per_sec,
            json_num(r.first_loss, 4),
            json_num(r.final_loss, 4),
            json_num(r.eval_loss, 4),
            r.eval_acc,
            r.overflow_fraction,
            curve.join(", ")
        );
    }
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    std::fs::write("BENCH_train_native.json", json).expect("write BENCH_train_native.json");
    println!("\nwrote BENCH_train_native.json");
}
