//! Bench: open-loop load harness over the replica pool (docs/SERVING.md).
//!
//! Closed-loop benches (like `serving.rs`) hide queueing: the generator
//! waits for each response, so offered load self-throttles to capacity.
//! This harness is **open loop** — Poisson arrivals fire on a wall-clock
//! schedule whether or not earlier requests finished, which is what real
//! traffic does to a server. The sweep crosses replica counts with
//! offered rates below and above measured capacity, reporting exact
//! p50/p95/p99 latency, achieved throughput, and the shed fraction per
//! cell. Everything lands in `BENCH_load_native.json` for CI.
//!
//! Method: a 1-replica closed loop first calibrates the per-replica
//! service rate μ; each sweep cell then offers `factor × μ × replicas`
//! requests/sec with exponential inter-arrival gaps, submits through
//! [`ReplicaPool::submit`] (never blocking on completions), and polls
//! outstanding tickets. Sheds are the pool's typed `overloaded` rejections.
//!
//! Quick mode for CI smoke runs: pass `--quick` after `--`, or set
//! `MITA_BENCH_QUICK=1`.

use std::fmt::Write as _;
use std::time::{Duration, Instant};

use mita::coordinator::{PoolTicket, ReplicaPool, ReplicaPoolConfig};
use mita::data::rng::Rng;
use mita::runtime::{BackendSpec, NativeAttnConfig, Tensor};
use mita::service::{KernelId, QkvBatch, ServiceRequest};

const N: usize = 64;
const DIM: usize = 32;
const HEADS: usize = 2;
/// Per-replica admission cap: small enough that over-capacity offered
/// rates actually shed instead of queueing the whole backlog.
const MAX_INFLIGHT: usize = 4;
/// Distinct pre-generated request payloads cycled by the generator (the
/// arrival loop clones instead of regenerating 3·N·DIM floats per shot).
const PAYLOADS: usize = 16;

struct Row {
    replicas: usize,
    factor: f64,
    offered_rate: f64,
    requests: usize,
    completed: usize,
    shed: u64,
    errors: u64,
    wall_secs: f64,
    throughput: f64,
    p50_us: f64,
    p95_us: f64,
    p99_us: f64,
    /// Stage breakdown from the per-ticket [`ExecProfile`] (the same
    /// engine-side numbers `/v1/trace` exports): backend execute time,
    /// and the queue component (completion wall time minus execute).
    exec_p50_us: f64,
    exec_p95_us: f64,
    queue_p50_us: f64,
    queue_p95_us: f64,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MITA_BENCH_QUICK").is_ok_and(|v| v == "1");
    let replica_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let factors: &[f64] = if quick { &[0.5, 2.0] } else { &[0.5, 1.0, 2.0] };
    let requests = if quick { 150 } else { 800 };

    let payloads = make_payloads();
    let mu = calibrate(quick, &payloads);
    println!(
        "# load_native — open loop, n={N} dim={DIM} heads={HEADS} cap={MAX_INFLIGHT}/replica \
         quick={quick} threads={}",
        mita::kernels::par::num_threads()
    );
    println!("calibrated per-replica service rate: {mu:.0} req/s");

    let mut rows = Vec::new();
    println!(
        "\nreplicas, offered_x, offered req/s, completed/total, shed%, achieved req/s, \
         p50 us, p95 us, p99 us, exec p50 us, queue p50 us"
    );
    for (ri, &replicas) in replica_counts.iter().enumerate() {
        for (fi, &factor) in factors.iter().enumerate() {
            let seed = 0x10AD + (ri * factors.len() + fi) as u64;
            let row = run_cell(replicas, factor, mu, requests, &payloads, seed);
            println!(
                "{:8}, {:9.2}, {:13.0}, {:9}, {:5.1}, {:14.0}, {:6.0}, {:6.0}, {:6.0}, \
                 {:11.0}, {:12.0}",
                row.replicas,
                row.factor,
                row.offered_rate,
                format!("{}/{}", row.completed, row.requests),
                100.0 * row.shed as f64 / row.requests as f64,
                row.throughput,
                row.p50_us,
                row.p95_us,
                row.p99_us,
                row.exec_p50_us,
                row.queue_p50_us,
            );
            rows.push(row);
        }
    }
    write_json(quick, mu, &rows);
}

fn make_payloads() -> Vec<ServiceRequest> {
    let mut rng = Rng::new(0xF00D);
    (0..PAYLOADS)
        .map(|_| {
            let data: Vec<f32> = (0..3 * N * DIM).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            ServiceRequest::Attention {
                op: KernelId::Mita,
                qkv: QkvBatch::fused(Tensor::f32(&[1, 3, N, DIM], data).expect("qkv tensor"))
                    .expect("qkv batch"),
                valid_rows: None,
            }
        })
        .collect()
}

fn spawn_pool(replicas: usize) -> ReplicaPool {
    let spec = BackendSpec::Native(NativeAttnConfig::for_shape(N, DIM, HEADS));
    let cfg = ReplicaPoolConfig {
        replicas,
        max_inflight: MAX_INFLIGHT,
        retry_after_ms: 1,
        ..Default::default()
    };
    ReplicaPool::spawn(spec, vec![], cfg).expect("replica pool")
}

/// Closed-loop service-rate estimate on one replica (warmup excluded).
fn calibrate(quick: bool, payloads: &[ServiceRequest]) -> f64 {
    let pool = spawn_pool(1);
    let iters = if quick { 24 } else { 80 };
    for req in payloads.iter().take(4) {
        pool.call(req.clone()).expect("calibration warmup");
    }
    let t0 = Instant::now();
    for i in 0..iters {
        pool.call(payloads[i % payloads.len()].clone()).expect("calibration request");
    }
    let mean = t0.elapsed().as_secs_f64() / iters as f64;
    pool.shutdown();
    1.0 / mean.max(1e-9)
}

/// One sweep cell: `requests` Poisson arrivals at `factor × μ × replicas`
/// req/s against a fresh pool.
fn run_cell(
    replicas: usize,
    factor: f64,
    mu: f64,
    requests: usize,
    payloads: &[ServiceRequest],
    seed: u64,
) -> Row {
    let pool = spawn_pool(replicas);
    let offered_rate = factor * mu * replicas as f64;
    let mut rng = Rng::new(seed);
    // Arrival schedule up front: cumulative exponential gaps (seconds).
    let mut arrivals = Vec::with_capacity(requests);
    let mut t = 0.0f64;
    for _ in 0..requests {
        t += -(1.0 - rng.uniform()).ln() / offered_rate;
        arrivals.push(t);
    }

    let start = Instant::now();
    let mut next = 0usize;
    let mut pending: Vec<(PoolTicket, Instant)> = Vec::new();
    let mut latencies_us: Vec<f64> = Vec::new();
    let mut exec_us: Vec<f64> = Vec::new();
    let mut queue_us: Vec<f64> = Vec::new();
    let (mut shed, mut errors) = (0u64, 0u64);
    loop {
        // Settle whatever finished since the last poll, keeping the
        // engine-side execute profile so the JSON rows carry the same
        // stage breakdown `/v1/trace` reports.
        let mut i = 0;
        while i < pending.len() {
            match pending[i].0.try_wait_profiled() {
                Some((result, profile)) => {
                    let (_ticket, issued) = pending.swap_remove(i);
                    match result {
                        Ok(_) => {
                            let wall = issued.elapsed().as_secs_f64() * 1e6;
                            let exec = profile.execute_ns as f64 / 1e3;
                            latencies_us.push(wall);
                            exec_us.push(exec);
                            queue_us.push((wall - exec).max(0.0));
                        }
                        Err(_) => errors += 1,
                    }
                }
                None => i += 1,
            }
        }
        // Fire every due arrival — open loop: the schedule, not the
        // completions, decides when the next request goes out.
        let now = start.elapsed().as_secs_f64();
        while next < requests && arrivals[next] <= now {
            match pool.submit(payloads[next % payloads.len()].clone()) {
                Ok(ticket) => pending.push((ticket, Instant::now())),
                Err(e) if e.code() == "overloaded" => shed += 1,
                Err(_) => errors += 1,
            }
            next += 1;
        }
        if next == requests && pending.is_empty() {
            break;
        }
        std::thread::sleep(Duration::from_micros(50));
    }
    let wall_secs = start.elapsed().as_secs_f64();

    // Cross-check the pool's own registry against the harness counts —
    // the /v1/metrics numbers must tell the same story the client saw.
    let snap = pool.snapshot();
    assert_eq!(snap.serve_requests_total, requests as u64, "pool counted every submit");
    assert_eq!(snap.serve_shed_total, shed, "pool sheds match harness sheds");
    pool.shutdown();

    latencies_us.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    exec_us.sort_by(|a, b| a.partial_cmp(b).expect("finite exec times"));
    queue_us.sort_by(|a, b| a.partial_cmp(b).expect("finite queue times"));
    Row {
        replicas,
        factor,
        offered_rate,
        requests,
        completed: latencies_us.len(),
        shed,
        errors,
        wall_secs,
        throughput: latencies_us.len() as f64 / wall_secs.max(1e-9),
        p50_us: percentile(&latencies_us, 50.0),
        p95_us: percentile(&latencies_us, 95.0),
        p99_us: percentile(&latencies_us, 99.0),
        exec_p50_us: percentile(&exec_us, 50.0),
        exec_p95_us: percentile(&exec_us, 95.0),
        queue_p50_us: percentile(&queue_us, 50.0),
        queue_p95_us: percentile(&queue_us, 95.0),
    }
}

/// Exact (nearest-rank on sorted samples) percentile; 0 when empty.
fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted_us.len() - 1) as f64).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

/// JSON artifact for CI: the calibration point plus one row per sweep cell.
fn write_json(quick: bool, mu: f64, rows: &[Row]) {
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"load_native\",");
    let _ = writeln!(json, "  \"quick\": {quick},");
    let _ = writeln!(json, "  \"simd_lane\": \"{}\",", mita::kernels::simd::active_lane());
    let _ = writeln!(json, "  \"n\": {N},");
    let _ = writeln!(json, "  \"dim\": {DIM},");
    let _ = writeln!(json, "  \"heads\": {HEADS},");
    let _ = writeln!(json, "  \"max_inflight_per_replica\": {MAX_INFLIGHT},");
    let _ = writeln!(json, "  \"threads\": {},", mita::kernels::par::num_threads());
    let _ = writeln!(json, "  \"service_rate_per_replica\": {mu:.2},");
    let _ = writeln!(json, "  \"rows\": [");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"replicas\": {}, \"offered_factor\": {:.2}, \"offered_rate\": {:.2}, \
             \"requests\": {}, \"completed\": {}, \"shed\": {}, \"errors\": {}, \
             \"shed_fraction\": {:.4}, \"wall_secs\": {:.4}, \"throughput\": {:.2}, \
             \"p50_us\": {:.1}, \"p95_us\": {:.1}, \"p99_us\": {:.1}, \
             \"exec_p50_us\": {:.1}, \"exec_p95_us\": {:.1}, \
             \"queue_p50_us\": {:.1}, \"queue_p95_us\": {:.1}}}{comma}",
            r.replicas,
            r.factor,
            r.offered_rate,
            r.requests,
            r.completed,
            r.shed,
            r.errors,
            r.shed as f64 / r.requests as f64,
            r.wall_secs,
            r.throughput,
            r.p50_us,
            r.p95_us,
            r.p99_us,
            r.exec_p50_us,
            r.exec_p95_us,
            r.queue_p50_us,
            r.queue_p95_us,
        );
    }
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    std::fs::write("BENCH_load_native.json", json).expect("write BENCH_load_native.json");
    println!("\nwrote BENCH_load_native.json");
}
