//! Bench: AOT train-step latency per bundle — the Tab. 5 "training
//! throughput" measurement isolated from data generation. Requires
//! `make artifacts`.

use mita::coordinator::Trainer;
use mita::data::{BatchSource, Split};
use mita::runtime::Runtime;
use mita::util::bench::bench_for;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load("artifacts").expect("runtime");
    println!("# train_step bench (one optimizer step, data prebuilt)");

    for bundle in [
        "quickstart",
        "t2_std",
        "t2_mita",
        "t5_text_standard",
        "t5_text_mita",
        "t5_text_agent",
        "t5_text_linear",
    ] {
        if rt.manifest().bundle(bundle).is_err() {
            continue;
        }
        let spec = rt.manifest().bundle(bundle).unwrap().clone();
        let source = BatchSource::for_bundle(&spec).expect("source");
        let mut trainer = Trainer::new(&rt, bundle, 0).expect("init");
        let (x, y) = source.batch(Split::Train, 0).expect("batch");
        let r = bench_for(bundle, 2, 3.0, || {
            trainer.step(x.clone(), y.clone()).expect("step");
        });
        println!(
            "{}  ({:.1} examples/s)",
            r.row(),
            r.throughput(spec.train.batch_size as f64)
        );
    }
}
