//! Bench: end-to-end native MiTA transformer forward across the LRA task
//! shapes — the model-level counterpart of `attn_microbench`.
//!
//! For each task shape the same parameters run twice, once with every
//! block dispatched to `attn.mita` and once to `attn.dense` (the per-block
//! kernel choice is the only difference), measuring:
//!
//! - **throughput**: batched forward latency / sequences-per-second;
//! - **accuracy parity**: argmax agreement between the two models at the
//!   real MiTA configuration (routing/compression effects at model level);
//! - **strict parity**: max logits |Δ| on the landmarks-cover-everything
//!   config (m = k = n, clamped to n ≤ 256 to keep the degenerate O(n²)
//!   MiTA affordable), which must stay ≤ 1e-4;
//! - **analytical FLOPs**: `flops::native_model_flops` per forward.
//!
//! Everything lands in `BENCH_model_native.json` so CI can archive the
//! model-level perf trajectory next to the attention-kernel one.
//!
//! Quick mode for CI smoke runs: pass `--quick` after `--`, or set
//! `MITA_BENCH_QUICK=1` (still covers three task shapes).

use std::fmt::Write as _;

use mita::data::lra;
use mita::data::Split;
use mita::flops;
use mita::kernels::{MitaKernelConfig, MitaStats, WorkspacePool, OP_ATTN_DENSE, OP_ATTN_MITA};
use mita::model::{MitaModel, ModelConfig, ModelScratch};
use mita::runtime::{Backend, NativeAttnConfig, NativeBackend, Tensor};
use mita::service::{BindingId, ServiceRequest};
use mita::util::bench::bench_for;

/// Model shape shared by every row (the JSON metadata must never drift
/// from what was actually measured).
const DIM: usize = 64;
const HEADS: usize = 4;
const DEPTH: usize = 2;
/// Examples per forward call.
const BATCH: usize = 4;

struct Row {
    task: &'static str,
    n: usize,
    vocab: usize,
    classes: usize,
    m: usize,
    k: usize,
    dense_ms: f64,
    mita_ms: f64,
    parity: f32,
    agreement: f64,
    mita_flops: f64,
    dense_flops: f64,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MITA_BENCH_QUICK").is_ok_and(|v| v == "1");
    let shapes: &[(&str, usize)] = if quick {
        &[("listops", 256), ("text", 256), ("image", 256)]
    } else {
        &[
            ("listops", 256),
            ("text", 512),
            ("retrieval", 512),
            ("image", 1024),
            ("pathfinder", 1024),
        ]
    };
    let budget = if quick { 0.3 } else { 1.0 };
    println!(
        "# model_native — MiTA vs dense blocks (dim={DIM}, heads={HEADS}, depth={DEPTH}, \
         batch={BATCH}, quick={quick}, threads={}, simd_lane={})",
        mita::kernels::par::num_threads(),
        mita::kernels::simd::active_lane()
    );

    let mut rows = Vec::new();
    for &(name, n) in shapes {
        let vocab = lra::default_vocab(name).expect("known task");
        rows.push(run_shape(name, n, vocab, budget));
    }

    println!("\ntask, n, dense_ms, mita_ms, speedup, argmax_agreement, parity_max_diff");
    for r in &rows {
        println!(
            "{}, {}, {:.3}, {:.3}, x{:.2}, {:.2}, {:.2e}",
            r.task,
            r.n,
            r.dense_ms,
            r.mita_ms,
            r.dense_ms / r.mita_ms,
            r.agreement,
            r.parity
        );
    }
    write_json(quick, &rows);
}

fn run_shape(name: &'static str, n: usize, vocab: usize, budget: f64) -> Row {
    let task = lra::by_name(name, n, vocab, 0xBE9C);
    let mcfg = ModelConfig::for_task(task.as_ref(), DIM, HEADS, DEPTH, OP_ATTN_MITA);
    let model = MitaModel::init(mcfg.clone(), 7).expect("model init");
    let dense = model.with_kernel(OP_ATTN_DENSE).expect("dense model");
    let (tokens, _) = lra::batch_host(task.as_ref(), Split::Val, 0, BATCH);

    // Measure through the typed service surface — exactly what serving
    // executes: both variants bound as checkpoints, batches dispatched as
    // typed model-forward requests.
    let mut be = NativeBackend::new(NativeAttnConfig::for_shape(n, DIM, HEADS));
    be.execute(ServiceRequest::BindCheckpoint {
        binding: BindingId::from("mita"),
        params: model.to_tensors().expect("flatten mita model"),
    })
    .expect("bind mita model");
    be.execute(ServiceRequest::BindCheckpoint {
        binding: BindingId::from("dense"),
        params: dense.to_tensors().expect("flatten dense model"),
    })
    .expect("bind dense model");
    let batch = Tensor::i32(&[BATCH, n], tokens.clone()).expect("token batch");
    let (b_mita, b_dense) = (BindingId::from("mita"), BindingId::from("dense"));

    let rm = bench_for(&format!("mita  {name} n={n}"), 1, budget, || {
        be.run_model(&b_mita, &batch, None).expect("mita forward");
    });
    println!("{}  ({:.1} seqs/s)", rm.row(), rm.throughput(BATCH as f64));
    let rd = bench_for(&format!("dense {name} n={n}"), 1, budget, || {
        be.run_model(&b_dense, &batch, None).expect("dense forward");
    });
    println!("{}  ({:.1} seqs/s)", rd.row(), rd.throughput(BATCH as f64));

    // Accuracy parity at the real config: do routed and dense blocks pick
    // the same class per example?
    let lm = be.run_model(&b_mita, &batch, None).expect("mita logits");
    let lm = lm.as_f32().expect("f32 logits").to_vec();
    let ld = be.run_model(&b_dense, &batch, None).expect("dense logits");
    let ld = ld.as_f32().expect("f32 logits").to_vec();
    let classes = mcfg.classes;
    let agree = (0..BATCH)
        .filter(|&i| {
            let row = i * classes..(i + 1) * classes;
            argmax(&lm[row.clone()]) == argmax(&ld[row])
        })
        .count() as f64
        / BATCH as f64;

    // Strict parity on the landmarks-cover-everything config (m = k = n),
    // at a clamped sequence length so the degenerate O(n²) stays cheap
    // (library-level forward: this shape is never bound for serving).
    let pool = WorkspacePool::new();
    let mut scratch = ModelScratch::default();
    let mut stats = MitaStats::default();
    let pn = n.min(256);
    let ptask = lra::by_name(name, pn, vocab, 0xBE9C);
    let pcfg = ModelConfig::for_task(ptask.as_ref(), DIM, HEADS, DEPTH, OP_ATTN_MITA)
        .with_mita(MitaKernelConfig { m: pn, k: pn, cap_factor: 2, block_q: 8 });
    let pmodel = MitaModel::init(pcfg, 7).expect("parity init");
    let pdense = pmodel.with_kernel(OP_ATTN_DENSE).expect("parity dense");
    let pregistry = pmodel.registry();
    let (ptokens, _) = lra::batch_host(ptask.as_ref(), Split::Val, 0, 2);
    let pa = pmodel
        .forward(&ptokens, 2, 2, &pregistry, &pool, &mut scratch, &mut stats)
        .expect("parity mita");
    let pb = pdense
        .forward(&ptokens, 2, 2, &pregistry, &pool, &mut scratch, &mut stats)
        .expect("parity dense fwd");
    let parity = pa.iter().zip(&pb).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(parity < 1e-4, "{name}: model-level parity broke (max|Δ| = {parity:.2e})");

    Row {
        task: name,
        n,
        vocab: task.vocab(),
        classes,
        m: mcfg.mita.m,
        k: mcfg.mita.k,
        dense_ms: rd.mean_secs * 1e3,
        mita_ms: rm.mean_secs * 1e3,
        parity,
        agreement: agree,
        mita_flops: flops::native_model_flops(&mcfg),
        dense_flops: flops::native_model_flops(&dense.cfg),
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// JSON artifact for the CI perf trajectory: one MiTA-vs-dense row per
/// LRA task shape, with throughput, parity, and model-level FLOPs.
fn write_json(quick: bool, rows: &[Row]) {
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"model_native\",");
    let _ = writeln!(json, "  \"dim\": {DIM},");
    let _ = writeln!(json, "  \"heads\": {HEADS},");
    let _ = writeln!(json, "  \"depth\": {DEPTH},");
    let _ = writeln!(json, "  \"batch\": {BATCH},");
    let _ = writeln!(json, "  \"quick\": {quick},");
    let _ = writeln!(json, "  \"threads\": {},", mita::kernels::par::num_threads());
    let _ = writeln!(json, "  \"simd_lane\": \"{}\",", mita::kernels::simd::active_lane());
    let _ = writeln!(json, "  \"rows\": [");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let (m_tp, d_tp) = (BATCH as f64 / r.mita_ms * 1e3, BATCH as f64 / r.dense_ms * 1e3);
        let _ = writeln!(
            json,
            "    {{\"task\": \"{}\", \"n\": {}, \"vocab\": {}, \"classes\": {}, \"m\": {}, \
             \"k\": {}, \"dense_ms\": {:.4}, \"mita_ms\": {:.4}, \"speedup\": {:.3}, \
             \"mita_seqs_per_s\": {m_tp:.2}, \"dense_seqs_per_s\": {d_tp:.2}, \
             \"argmax_agreement\": {:.3}, \"parity_max_diff\": {:.3e}, \
             \"mita_model_flops\": {:.0}, \"dense_model_flops\": {:.0}}}{comma}",
            r.task,
            r.n,
            r.vocab,
            r.classes,
            r.m,
            r.k,
            r.dense_ms,
            r.mita_ms,
            r.dense_ms / r.mita_ms,
            r.agreement,
            r.parity,
            r.mita_flops,
            r.dense_flops
        );
    }
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    std::fs::write("BENCH_model_native.json", json).expect("write BENCH_model_native.json");
    println!("\nwrote BENCH_model_native.json");
}
