//! Bench: L3 serving hot path — pure batching/packing overhead (no engine)
//! plus an end-to-end batching-policy sweep over the quickstart predict
//! artifact (throughput vs latency trade-off).

use std::time::{Duration, Instant};

use mita::coordinator::batcher::{BatchPolicy, Batcher, Flush};
use mita::coordinator::server::{serve, ServeConfig, DEFAULT_MAX_INFLIGHT};
use mita::coordinator::Engine;
use mita::runtime::Runtime;
use mita::util::bench::bench;

fn main() {
    // Pure-L3 cost: batcher decision + take loop on a synthetic queue.
    let policy = BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) };
    let r = bench("batcher push+poll+take (1024 reqs)", 2, 50, || {
        let mut b: Batcher<u32> = Batcher::new(policy);
        let now = Instant::now();
        for i in 0..1024u32 {
            b.push(i, now);
            if let Flush::Take(n) = b.poll(now) {
                let _ = b.take(n);
            }
        }
        while !b.is_empty() {
            let n = b.len().min(policy.max_batch);
            let _ = b.take(n);
        }
    });
    println!("{}  ({:.0} reqs/s through policy)", r.row(), r.throughput(1024.0));

    // End-to-end serving policy sweep (needs artifacts).
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP e2e: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load("artifacts").expect("runtime");
    let spec = rt.manifest().bundle("quickstart").unwrap().clone();
    let predict = rt.manifest().bundle_artifact("quickstart", "predict").unwrap().to_string();
    drop(rt);
    let engine = Engine::spawn("artifacts".into(), vec![predict]).expect("engine");
    let rt2 = Runtime::load("artifacts").expect("runtime");
    let init = rt2.manifest().bundle_artifact("quickstart", "init").unwrap().to_string();
    drop(rt2);
    engine.handle().bind_init("quickstart", &init, 0, spec.param_count()).expect("bind");

    println!("\n# serving policy sweep (quickstart, closed loop, 128 reqs)");
    for max_wait_ms in [0u64, 1, 5, 20] {
        let cfg = ServeConfig {
            bundle: "quickstart".into(),
            binding: "quickstart".into(),
            requests: 128,
            rate: 0.0,
            queue_cap: 256,
            max_inflight: DEFAULT_MAX_INFLIGHT,
            policy: BatchPolicy {
                max_batch: spec.train.batch_size,
                max_wait: Duration::from_millis(max_wait_ms),
            },
        };
        let report = serve(&engine.handle(), &spec, "quickstart", &cfg).expect("serve");
        println!("max_wait={max_wait_ms:2}ms  {}", report.row());
    }
    engine.shutdown();
}
