//! Bench: attention forward latency, two parts.
//!
//! **Native sweep** (always runs, no artifacts needed): the pure-Rust MiTA
//! kernels vs the naive dense baseline across sequence lengths at a fixed
//! model shape (dim=64, heads=4). Writes `BENCH_attn_native.json` so CI
//! can archive the perf trajectory.
//!
//! **PJRT sweep** (requires `make artifacts`): the original Fig. 5
//! predict-latency measurement over the compiled bundles.
//!
//! Quick mode for CI smoke runs: pass `--quick` after `--`, or set
//! `MITA_BENCH_QUICK=1`.

use std::fmt::Write as _;

use mita::data::rng::Rng;
use mita::data::{BatchSource, Split};
use mita::flops;
use mita::kernels::{dense_attention_mh, mita_attention_mh, MitaKernelConfig};
use mita::runtime::{Runtime, Tensor};
use mita::util::bench::bench_for;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MITA_BENCH_QUICK").is_ok_and(|v| v == "1");

    native_sweep(quick);

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\nSKIP PJRT sweep: run `make artifacts` first");
        return;
    }
    pjrt_sweep();
}

/// Native CPU kernels: MiTA vs naive dense, per sequence length.
fn native_sweep(quick: bool) {
    let (dim, heads) = (64usize, 4usize);
    let ns: &[usize] = if quick { &[256, 1024] } else { &[256, 512, 1024, 2048, 4096] };
    let budget = if quick { 0.25 } else { 1.5 };
    println!("# attn_microbench — native kernels (dim={dim}, heads={heads}, quick={quick})");

    let mut rows: Vec<(usize, MitaKernelConfig, f64, f64)> = Vec::new();
    for &n in ns {
        let mut rng = Rng::derive(0xBE7C, &[n as u64]);
        let mut gen =
            |len: usize| (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect::<Vec<f32>>();
        let (q, k, v) = (gen(n * dim), gen(n * dim), gen(n * dim));
        let cfg = MitaKernelConfig::for_seq(n);
        let mut out = vec![0.0f32; n * dim];

        let rd = bench_for(&format!("dense n={n}"), 1, budget, || {
            dense_attention_mh(&q, &k, &v, n, heads, dim, &mut out);
        });
        println!("{}", rd.row());
        let rm = bench_for(&format!("mita n={n} (m={}, k={})", cfg.m, cfg.k), 1, budget, || {
            mita_attention_mh(&q, &k, &v, n, heads, dim, &cfg, &mut out);
        });
        println!("{}", rm.row());
        rows.push((n, cfg, rd.mean_secs, rm.mean_secs));
    }

    println!("\nN, dense_ms, mita_ms, speedup");
    for (n, _, d, m) in &rows {
        println!("{n}, {:.3}, {:.3}, x{:.2}", d * 1e3, m * 1e3, d / m);
    }

    // JSON artifact for the CI perf trajectory.
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"attn_native\",");
    let _ = writeln!(json, "  \"dim\": {dim},");
    let _ = writeln!(json, "  \"heads\": {heads},");
    let _ = writeln!(json, "  \"quick\": {quick},");
    let _ = writeln!(json, "  \"threads\": {},", mita::kernels::par::num_threads());
    let _ = writeln!(json, "  \"rows\": [");
    for (i, (n, cfg, d, m)) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"n\": {n}, \"m\": {}, \"k\": {}, \"dense_ms\": {:.4}, \"mita_ms\": {:.4}, \
             \"speedup\": {:.3}}}{comma}",
            cfg.m,
            cfg.k,
            d * 1e3,
            m * 1e3,
            d / m
        );
    }
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    std::fs::write("BENCH_attn_native.json", json).expect("write BENCH_attn_native.json");
    println!("\nwrote BENCH_attn_native.json");
}

/// Fig. 5 — forward latency of the 3-layer d=128 model, standard vs MiTA
/// attention, through the compiled PJRT artifacts.
fn pjrt_sweep() {
    let rt = Runtime::load("artifacts").expect("runtime");
    println!("\n# attn_microbench (Fig. 5): predict latency, batch as compiled");

    let mut rows: Vec<(usize, f64, f64)> = Vec::new();
    for name in rt.manifest().bundles_with_prefix("f5_standard_n") {
        let n = rt.manifest().bundle(name).unwrap().model.num_tokens();
        let mut lat = [0.0f64; 2];
        for (slot, method) in ["standard", "mita"].iter().enumerate() {
            let bundle = format!("f5_{method}_n{n}");
            let Ok(spec) = rt.manifest().bundle(&bundle).map(Clone::clone) else { continue };
            let predict = rt.manifest().bundle_artifact(&bundle, "predict").unwrap().to_string();
            let source = BatchSource::for_bundle(&spec).expect("source");
            let (x, _) = source.batch(Split::Val, 0).expect("batch");

            // Build input list: init params + x.
            let init = rt.manifest().bundle_artifact(&bundle, "init").unwrap();
            let state = rt
                .run_literals(init, &[Tensor::scalar_i32(0).to_literal().unwrap()])
                .expect("init");
            let p = spec.param_layout.len();
            let params = &state[..p];
            let xl = x.to_literal().unwrap();
            let mut inputs: Vec<&xla::Literal> = params.iter().collect();
            inputs.push(&xl);

            rt.warmup(&predict).unwrap();
            let exe = rt.executable(&predict).unwrap();
            let r = bench_for(&format!("{bundle} (fwd)"), 1, 2.0, || {
                let out = exe.execute::<&xla::Literal>(&inputs).unwrap();
                let _ = out[0][0].to_literal_sync().unwrap();
            });
            println!(
                "{}  ({:.1} seqs/s, attn {}/ex)",
                r.row(),
                r.throughput(spec.train.batch_size as f64),
                flops::gflops(flops::attention_flops(&spec.model))
            );
            lat[slot] = r.mean_secs;
        }
        if lat[0] > 0.0 && lat[1] > 0.0 {
            rows.push((n, lat[0], lat[1]));
        }
    }

    println!("\nN, standard_ms, mita_ms, speedup");
    for (n, s, m) in rows {
        println!("{n}, {:.2}, {:.2}, x{:.2}", s * 1e3, m * 1e3, s / m);
    }
}
