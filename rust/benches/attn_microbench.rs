//! Bench: attention forward latency, three parts.
//!
//! **Native sweep** (always runs, no artifacts needed): the pure-Rust MiTA
//! kernel vs the naive dense baseline across sequence lengths at a fixed
//! model shape (dim=64, heads=4), one sequence at a time through a warm
//! `Workspace` (the serial per-sequence path).
//!
//! **Batch sweep** (always runs): the backend's batched (example × head)
//! parallel dispatch vs that serial per-sequence path across batch sizes —
//! the speedup column is the win from work-item parallelism + pooled
//! workspaces. Both sweeps land in `BENCH_attn_native.json` so CI can
//! archive the perf trajectory.
//!
//! **PJRT sweep** (requires `make artifacts`): the original Fig. 5
//! predict-latency measurement over the compiled bundles.
//!
//! Quick mode for CI smoke runs: pass `--quick` after `--`, or set
//! `MITA_BENCH_QUICK=1`.

use std::fmt::Write as _;

use mita::data::rng::Rng;
use mita::data::{BatchSource, Split};
use mita::flops;
use mita::kernels::{
    dense_attention_mh, mita_attention_mh, MitaKernelConfig, MitaStats, Workspace,
};
use mita::runtime::{NativeAttnConfig, NativeBackend, Runtime, Tensor};
use mita::service::{KernelId, QkvBatch};
use mita::util::bench::bench_for;

/// Model shape shared by the native sweeps and the JSON artifact (the
/// JSON metadata must never drift from what was actually measured).
const DIM: usize = 64;
const HEADS: usize = 4;
/// Sequence length of the batch-size sweep.
const BATCH_N: usize = 512;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MITA_BENCH_QUICK").is_ok_and(|v| v == "1");

    let seq_rows = native_sweep(quick);
    let batch_rows = batched_sweep(quick);
    write_json(quick, &seq_rows, &batch_rows);

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\nSKIP PJRT sweep: run `make artifacts` first");
        return;
    }
    pjrt_sweep();
}

/// Native CPU kernels: MiTA vs naive dense, per sequence length (serial
/// per-sequence path through one warm workspace).
fn native_sweep(quick: bool) -> Vec<(usize, MitaKernelConfig, f64, f64)> {
    let (dim, heads) = (DIM, HEADS);
    let ns: &[usize] = if quick { &[256, 1024] } else { &[256, 512, 1024, 2048, 4096] };
    let budget = if quick { 0.25 } else { 1.5 };
    println!(
        "# attn_microbench — native kernels (dim={dim}, heads={heads}, quick={quick}, \
         simd_lane={})",
        mita::kernels::simd::active_lane()
    );

    let mut ws = Workspace::new();
    let mut stats = MitaStats::default();
    let mut rows: Vec<(usize, MitaKernelConfig, f64, f64)> = Vec::new();
    for &n in ns {
        let mut rng = Rng::derive(0xBE7C, &[n as u64]);
        let mut gen =
            |len: usize| (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect::<Vec<f32>>();
        let (q, k, v) = (gen(n * dim), gen(n * dim), gen(n * dim));
        let cfg = MitaKernelConfig::for_seq(n);
        let mut out = vec![0.0f32; n * dim];

        let rd = bench_for(&format!("dense n={n}"), 1, budget, || {
            dense_attention_mh(&q, &k, &v, n, heads, dim, &mut ws, &mut out);
        });
        println!("{}", rd.row());
        let rm = bench_for(&format!("mita n={n} (m={}, k={})", cfg.m, cfg.k), 1, budget, || {
            mita_attention_mh(&q, &k, &v, n, heads, dim, &cfg, &mut ws, &mut out, &mut stats);
        });
        println!("{}", rm.row());
        rows.push((n, cfg, rd.mean_secs, rm.mean_secs));
    }

    println!("\nN, dense_ms, mita_ms, speedup");
    for (n, _, d, m) in &rows {
        println!("{n}, {:.3}, {:.3}, x{:.2}", d * 1e3, m * 1e3, d / m);
    }
    rows
}

/// Batched (example × head) parallel dispatch through `NativeBackend` —
/// driven as typed attention requests (validated `QkvBatch` + `KernelId`,
/// the serving path's exact request form) — vs the serial per-sequence
/// kernel path, per batch size.
fn batched_sweep(quick: bool) -> Vec<(usize, f64, f64)> {
    let (n, dim, heads) = (BATCH_N, DIM, HEADS);
    let batches: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8, 16] };
    let budget = if quick { 0.25 } else { 1.0 };
    let cfg = MitaKernelConfig::for_seq(n);
    println!(
        "\n# attn_microbench — batched dispatch (n={n}, dim={dim}, heads={heads}, threads={})",
        mita::kernels::par::num_threads()
    );

    let mut attn = NativeAttnConfig::for_shape(n, dim, heads);
    attn.mita = cfg;
    let backend = NativeBackend::new(attn);
    let per = n * dim;
    let mut ws = Workspace::new();
    let mut stats = MitaStats::default();
    let mut rows: Vec<(usize, f64, f64)> = Vec::new();
    for &b in batches {
        let mut rng = Rng::derive(0xBA7C, &[b as u64]);
        let data: Vec<f32> = (0..b * 3 * per).map(|_| rng.range_f32(-2.0, 2.0)).collect();
        let qkv = QkvBatch::fused(Tensor::f32(&[b, 3, n, dim], data.clone()).unwrap())
            .expect("valid fused batch");
        let mut out = vec![0.0f32; b * per];

        // Serial per-sequence path: one warm workspace, one example at a
        // time (what the backend did before batched dispatch).
        let rs = bench_for(&format!("serial  b={b}"), 1, budget, || {
            for i in 0..b {
                let ex = &data[i * 3 * per..(i + 1) * 3 * per];
                let (q, k, v) = (&ex[..per], &ex[per..2 * per], &ex[2 * per..]);
                let out_ex = &mut out[i * per..(i + 1) * per];
                mita_attention_mh(q, k, v, n, heads, dim, &cfg, &mut ws, out_ex, &mut stats);
            }
        });
        println!("{}  ({:.1} seqs/s)", rs.row(), rs.throughput(b as f64));

        let rb = bench_for(&format!("batched b={b}"), 1, budget, || {
            backend.run_attention(&KernelId::Mita, &qkv, None).unwrap();
        });
        println!("{}  ({:.1} seqs/s)", rb.row(), rb.throughput(b as f64));
        rows.push((b, rs.mean_secs, rb.mean_secs));
    }

    println!("\nbatch, serial_ms, batched_ms, batched_speedup");
    for (b, s, m) in &rows {
        println!("{b}, {:.3}, {:.3}, x{:.2}", s * 1e3, m * 1e3, s / m);
    }
    rows
}

/// JSON artifact for the CI perf trajectory: per-sequence rows + the
/// batched-throughput entries.
fn write_json(
    quick: bool,
    seq_rows: &[(usize, MitaKernelConfig, f64, f64)],
    batch_rows: &[(usize, f64, f64)],
) {
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"attn_native\",");
    let _ = writeln!(json, "  \"dim\": {DIM},");
    let _ = writeln!(json, "  \"heads\": {HEADS},");
    let _ = writeln!(json, "  \"quick\": {quick},");
    let _ = writeln!(json, "  \"threads\": {},", mita::kernels::par::num_threads());
    let _ = writeln!(json, "  \"simd_lane\": \"{}\",", mita::kernels::simd::active_lane());
    let _ = writeln!(json, "  \"rows\": [");
    for (i, (n, cfg, d, m)) in seq_rows.iter().enumerate() {
        let comma = if i + 1 < seq_rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"n\": {n}, \"m\": {}, \"k\": {}, \"dense_ms\": {:.4}, \"mita_ms\": {:.4}, \
             \"speedup\": {:.3}}}{comma}",
            cfg.m,
            cfg.k,
            d * 1e3,
            m * 1e3,
            d / m
        );
    }
    let _ = writeln!(json, "  ],");
    let _ = writeln!(json, "  \"batched\": [");
    for (i, (b, s, m)) in batch_rows.iter().enumerate() {
        let comma = if i + 1 < batch_rows.len() { "," } else { "" };
        let (s_tp, b_tp) = (*b as f64 / s, *b as f64 / m);
        let _ = writeln!(
            json,
            "    {{\"batch\": {b}, \"n\": {BATCH_N}, \"serial_ms\": {:.4}, \"batched_ms\": {:.4}, \
             \"serial_seqs_per_s\": {s_tp:.2}, \"batched_seqs_per_s\": {b_tp:.2}, \
             \"speedup\": {:.3}}}{comma}",
            s * 1e3,
            m * 1e3,
            s / m
        );
    }
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    std::fs::write("BENCH_attn_native.json", json).expect("write BENCH_attn_native.json");
    println!("\nwrote BENCH_attn_native.json");
}

/// Fig. 5 — forward latency of the 3-layer d=128 model, standard vs MiTA
/// attention, through the compiled PJRT artifacts.
fn pjrt_sweep() {
    let rt = Runtime::load("artifacts").expect("runtime");
    println!("\n# attn_microbench (Fig. 5): predict latency, batch as compiled");

    let mut rows: Vec<(usize, f64, f64)> = Vec::new();
    for name in rt.manifest().bundles_with_prefix("f5_standard_n") {
        let n = rt.manifest().bundle(name).unwrap().model.num_tokens();
        let mut lat = [0.0f64; 2];
        for (slot, method) in ["standard", "mita"].iter().enumerate() {
            let bundle = format!("f5_{method}_n{n}");
            let Ok(spec) = rt.manifest().bundle(&bundle).map(Clone::clone) else { continue };
            let predict = rt.manifest().bundle_artifact(&bundle, "predict").unwrap().to_string();
            let source = BatchSource::for_bundle(&spec).expect("source");
            let (x, _) = source.batch(Split::Val, 0).expect("batch");

            // Build input list: init params + x.
            let init = rt.manifest().bundle_artifact(&bundle, "init").unwrap();
            let state = rt
                .run_literals(init, &[Tensor::scalar_i32(0).to_literal().unwrap()])
                .expect("init");
            let p = spec.param_layout.len();
            let params = &state[..p];
            let xl = x.to_literal().unwrap();
            let mut inputs: Vec<&xla::Literal> = params.iter().collect();
            inputs.push(&xl);

            rt.warmup(&predict).unwrap();
            let exe = rt.executable(&predict).unwrap();
            let r = bench_for(&format!("{bundle} (fwd)"), 1, 2.0, || {
                let out = exe.execute::<&xla::Literal>(&inputs).unwrap();
                let _ = out[0][0].to_literal_sync().unwrap();
            });
            println!(
                "{}  ({:.1} seqs/s, attn {}/ex)",
                r.row(),
                r.throughput(spec.train.batch_size as f64),
                flops::gflops(flops::attention_flops(&spec.model))
            );
            lat[slot] = r.mean_secs;
        }
        if lat[0] > 0.0 && lat[1] > 0.0 {
            rows.push((n, lat[0], lat[1]));
        }
    }

    println!("\nN, standard_ms, mita_ms, speedup");
    for (n, s, m) in rows {
        println!("{n}, {:.2}, {:.2}, x{:.2}", s * 1e3, m * 1e3, s / m);
    }
}
