//! Bench: Fig. 5 — forward latency of the 3-layer d=128 model, standard vs
//! MiTA attention, across sequence lengths. Prints the per-N speedup series
//! the paper plots. Requires `make artifacts`.

use mita::data::{BatchSource, Split};
use mita::flops;
use mita::runtime::{Runtime, Tensor};
use mita::util::bench::bench_for;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load("artifacts").expect("runtime");
    println!("# attn_microbench (Fig. 5): predict latency, batch as compiled");

    let mut rows: Vec<(usize, f64, f64)> = Vec::new();
    for name in rt.manifest().bundles_with_prefix("f5_standard_n") {
        let n = rt.manifest().bundle(name).unwrap().model.num_tokens();
        let mut lat = [0.0f64; 2];
        for (slot, method) in ["standard", "mita"].iter().enumerate() {
            let bundle = format!("f5_{method}_n{n}");
            let Ok(spec) = rt.manifest().bundle(&bundle).map(Clone::clone) else { continue };
            let predict = rt.manifest().bundle_artifact(&bundle, "predict").unwrap().to_string();
            let source = BatchSource::for_bundle(&spec).expect("source");
            let (x, _) = source.batch(Split::Val, 0).expect("batch");

            // Build input list: init params + x.
            let init = rt.manifest().bundle_artifact(&bundle, "init").unwrap();
            let state = rt
                .run_literals(init, &[Tensor::scalar_i32(0).to_literal().unwrap()])
                .expect("init");
            let p = spec.param_layout.len();
            let params = &state[..p];
            let xl = x.to_literal().unwrap();
            let mut inputs: Vec<&xla::Literal> = params.iter().collect();
            inputs.push(&xl);

            rt.warmup(&predict).unwrap();
            let exe = rt.executable(&predict).unwrap();
            let r = bench_for(&format!("{bundle} (fwd)"), 1, 2.0, || {
                let out = exe.execute::<&xla::Literal>(&inputs).unwrap();
                let _ = out[0][0].to_literal_sync().unwrap();
            });
            println!(
                "{}  ({:.1} seqs/s, attn {}/ex)",
                r.row(),
                r.throughput(spec.train.batch_size as f64),
                flops::gflops(flops::attention_flops(&spec.model))
            );
            lat[slot] = r.mean_secs;
        }
        if lat[0] > 0.0 && lat[1] > 0.0 {
            rows.push((n, lat[0], lat[1]));
        }
    }

    println!("\nN, standard_ms, mita_ms, speedup");
    for (n, s, m) in rows {
        println!("{n}, {:.2}, {:.2}, x{:.2}", s * 1e3, m * 1e3, s / m);
    }
}
