//! Bench: synthetic data-generator throughput (the L3 substrate that must
//! never bottleneck the train loop — compare against train_step times in
//! benches/train_step.rs).

use mita::data::images::{ImageCorpus, Split};
use mita::data::lra;
use mita::util::bench::bench;

fn main() {
    println!("# data_gen bench (items = examples/iteration)");

    let corpus = ImageCorpus::new(32, 32, 3, 10, 8, 42);
    let mut i = 0u64;
    let r = bench("images 32x32x3 cls batch=32", 2, 20, || {
        corpus.batch_cls(Split::Train, i * 32, 32).unwrap();
        i += 1;
    });
    println!("{}  ({:.0} imgs/s)", r.row(), r.throughput(32.0));

    let corpus64 = ImageCorpus::new(64, 64, 3, 10, 8, 42);
    let mut i = 0u64;
    let r = bench("images 64x64x3 seg batch=16", 2, 10, || {
        corpus64.batch_seg(Split::Train, i * 16, 16, 4).unwrap();
        i += 1;
    });
    println!("{}  ({:.0} imgs/s)", r.row(), r.throughput(16.0));

    for (task, n, vocab) in [
        ("listops", 256usize, 16usize),
        ("text", 512, 64),
        ("retrieval", 512, 64),
        ("image", 256, 32),
        ("pathfinder", 256, 4),
    ] {
        let t = lra::by_name(task, n, vocab, 7);
        let mut i = 0u64;
        let r = bench(&format!("lra {task} N={n} batch=8"), 2, 20, || {
            lra::batch(t.as_ref(), Split::Train, i * 8, 8).unwrap();
            i += 1;
        });
        println!("{}  ({:.0} seqs/s)", r.row(), r.throughput(8.0));
    }
}
