//! Bench: autoregressive decoding over the causal kernels — prefill
//! throughput and per-token decode latency (see docs/DECODE.md).
//!
//! Two levels, matching how the subsystem is layered:
//!
//! - **model rows**: full greedy [`generate`] sessions (KV cache + the
//!   single-token transformer forward) through incremental causal MiTA
//!   vs causal dense, reporting prefill tokens/s and mean per-token
//!   decode latency;
//! - **state rows**: the attention core alone — the incremental
//!   [`CausalMitaState`] `append_key` + `attend` loop vs the
//!   full-recompute reference ([`recompute_attend`] per step), i.e. the
//!   O(1)-amortized fast-weight update vs the O(n) re-routing it
//!   replaces. The speedup column is the point of the subsystem.
//!
//! Everything lands in `BENCH_decode_native.json` so CI can archive the
//! decode perf trajectory next to the attention/model/train ones
//! (scripts/bench_commit.sh appends it to the repo-root trajectory).
//!
//! Quick mode for CI smoke runs: pass `--quick` after `--`, or set
//! `MITA_BENCH_QUICK=1`.

use std::fmt::Write as _;
use std::time::Instant;

use mita::data::rng::Rng;
use mita::decode::generate::generate;
use mita::decode::state::recompute_attend;
use mita::decode::{CausalMitaState, DecodeKernel};
use mita::kernels::MitaKernelConfig;
use mita::model::{MitaModel, ModelConfig};

/// Model shape shared by every model-level row.
const VOCAB: usize = 32;
const DIM: usize = 64;
const HEADS: usize = 4;
const DEPTH: usize = 2;
const CLASSES: usize = 4;

struct ModelRow {
    variant: &'static str,
    prompt: usize,
    gen: usize,
    prefill_ms: f64,
    prefill_tok_per_s: f64,
    decode_us_per_tok: f64,
    decode_tok_per_s: f64,
}

struct StateRow {
    n: usize,
    d: usize,
    m: usize,
    k: usize,
    inc_us_per_tok: f64,
    rec_us_per_tok: f64,
    speedup: f64,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("MITA_BENCH_QUICK").is_ok_and(|v| v == "1");
    let budget = if quick { 0.3 } else { 1.0 };
    // (prompt, generated) per session; seq_len = prompt + gen.
    let sessions: &[(usize, usize)] =
        if quick { &[(32, 32)] } else { &[(32, 32), (128, 128), (256, 256)] };
    // Key-stream lengths for the attention-core comparison.
    let streams: &[usize] = if quick { &[128] } else { &[256, 512, 1024] };

    println!(
        "# decode_native — prefill + per-token decode (dim={DIM}, heads={HEADS}, \
         depth={DEPTH}, quick={quick}, threads={}, simd_lane={})",
        mita::kernels::par::num_threads(),
        mita::kernels::simd::active_lane()
    );

    let mut model_rows = Vec::new();
    for &(prompt, gen) in sessions {
        for kernel in [DecodeKernel::Mita, DecodeKernel::Dense] {
            model_rows.push(run_session(prompt, gen, kernel, budget));
        }
    }
    println!("\nvariant, prompt, gen, prefill_ms, prefill_tok/s, decode_us/tok, decode_tok/s");
    for r in &model_rows {
        println!(
            "{}, {}, {}, {:.3}, {:.0}, {:.2}, {:.0}",
            r.variant,
            r.prompt,
            r.gen,
            r.prefill_ms,
            r.prefill_tok_per_s,
            r.decode_us_per_tok,
            r.decode_tok_per_s
        );
    }

    let mut state_rows = Vec::new();
    for &n in streams {
        state_rows.push(run_stream(n, budget));
    }
    println!("\nn, d, m, k, incremental_us/tok, recompute_us/tok, speedup");
    for r in &state_rows {
        println!(
            "{}, {}, {}, {}, {:.2}, {:.2}, x{:.2}",
            r.n, r.d, r.m, r.k, r.inc_us_per_tok, r.rec_us_per_tok, r.speedup
        );
    }

    write_json(quick, &model_rows, &state_rows);
}

/// Full greedy generation sessions under a wall-clock budget; prefill
/// and decode wall times come from the [`generate`] outcome itself, so
/// the split is exactly what the serving trace reports.
fn run_session(prompt_len: usize, gen: usize, kernel: DecodeKernel, budget: f64) -> ModelRow {
    let seq_len = prompt_len + gen;
    let cfg =
        ModelConfig::new(VOCAB, seq_len, DIM, HEADS, DEPTH, 2 * DIM, CLASSES, kernel.causal_op());
    let model = MitaModel::init(cfg, 7).expect("model init");
    let mut rng = Rng::new(0xDEC0);
    let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(VOCAB) as i32).collect();
    let mut nop = |_: usize, _: i32, _: u64| {};

    // Warm once (first call touches cold caches), then measure.
    generate(&model, Some(kernel), &prompt, gen, &mut nop).expect("warmup");
    let (mut prefill_ns, mut decode_ns, mut sessions) = (0u64, 0u64, 0u64);
    let t0 = Instant::now();
    loop {
        let out = generate(&model, Some(kernel), &prompt, gen, &mut nop).expect("generate");
        prefill_ns += out.prefill_ns;
        decode_ns += out.decode_ns;
        sessions += 1;
        if t0.elapsed().as_secs_f64() >= budget {
            break;
        }
    }
    // Step 0 rides the prefill pass; the decode loop covers gen-1 steps.
    let prefill_toks = (sessions * prompt_len as u64) as f64;
    let decode_toks = (sessions * (gen as u64 - 1)) as f64;
    let row = ModelRow {
        variant: kernel.causal_op(),
        prompt: prompt_len,
        gen,
        prefill_ms: prefill_ns as f64 / sessions as f64 / 1e6,
        prefill_tok_per_s: prefill_toks / (prefill_ns as f64 / 1e9),
        decode_us_per_tok: decode_ns as f64 / 1e3 / decode_toks,
        decode_tok_per_s: decode_toks / (decode_ns as f64 / 1e9),
    };
    println!(
        "  {} prompt={} gen={}: {} sessions in {:.2}s",
        row.variant,
        prompt_len,
        gen,
        sessions,
        t0.elapsed().as_secs_f64()
    );
    row
}

/// The attention core alone over one synthetic (block, head) stream:
/// incremental state maintenance vs per-step full recompute. Outputs are
/// asserted bit-identical before timing — this bench never races ahead
/// of the parity gate in tests/decode_native.rs.
fn run_stream(n: usize, budget: f64) -> StateRow {
    let d = DIM / HEADS;
    let cfg = MitaKernelConfig::for_seq(n);
    let mut rng = Rng::new(0xFA57);
    let q: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let k: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let v: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut out = vec![0.0f32; d];

    // Parity check once, outside the timed loops.
    let mut st = CausalMitaState::new(n, d, &cfg);
    for t in 0..n {
        st.append_key(&k);
        st.attend(&q[t * d..(t + 1) * d], &k, &v, &mut out);
        let (_, reference) = recompute_attend(&q[t * d..(t + 1) * d], &k, &v, t, d, n, &cfg);
        assert_eq!(out, reference, "incremental path diverged at step {t} (n={n})");
    }

    // Incremental: one full n-step stream per iteration.
    let (mut inc_ns, mut inc_toks) = (0u64, 0u64);
    let t0 = Instant::now();
    loop {
        let mut st = CausalMitaState::new(n, d, &cfg);
        let it0 = Instant::now();
        for t in 0..n {
            st.append_key(&k);
            st.attend(&q[t * d..(t + 1) * d], &k, &v, &mut out);
        }
        inc_ns += it0.elapsed().as_nanos() as u64;
        inc_toks += n as u64;
        if t0.elapsed().as_secs_f64() >= budget {
            break;
        }
    }

    // Full recompute: the same stream, re-deriving landmarks, experts,
    // and routing from the whole key cache at every step.
    let (mut rec_ns, mut rec_toks) = (0u64, 0u64);
    let t0 = Instant::now();
    loop {
        let it0 = Instant::now();
        for t in 0..n {
            let (_, o) = recompute_attend(&q[t * d..(t + 1) * d], &k, &v, t, d, n, &cfg);
            std::hint::black_box(&o);
        }
        rec_ns += it0.elapsed().as_nanos() as u64;
        rec_toks += n as u64;
        if t0.elapsed().as_secs_f64() >= budget {
            break;
        }
    }

    let inc = inc_ns as f64 / 1e3 / inc_toks as f64;
    let rec = rec_ns as f64 / 1e3 / rec_toks as f64;
    StateRow {
        n,
        d,
        m: cfg.m,
        k: cfg.k,
        inc_us_per_tok: inc,
        rec_us_per_tok: rec,
        speedup: rec / inc,
    }
}

/// JSON artifact for the CI perf trajectory (same envelope fields as
/// the other native benches; scripts/bench_commit.sh stamps the lane).
fn write_json(quick: bool, model_rows: &[ModelRow], state_rows: &[StateRow]) {
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"decode_native\",");
    let _ = writeln!(json, "  \"vocab\": {VOCAB},");
    let _ = writeln!(json, "  \"dim\": {DIM},");
    let _ = writeln!(json, "  \"heads\": {HEADS},");
    let _ = writeln!(json, "  \"depth\": {DEPTH},");
    let _ = writeln!(json, "  \"quick\": {quick},");
    let _ = writeln!(json, "  \"threads\": {},", mita::kernels::par::num_threads());
    let _ = writeln!(json, "  \"simd_lane\": \"{}\",", mita::kernels::simd::active_lane());
    let _ = writeln!(json, "  \"model_rows\": [");
    for (i, r) in model_rows.iter().enumerate() {
        let comma = if i + 1 < model_rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"variant\": \"{}\", \"prompt\": {}, \"gen\": {}, \"prefill_ms\": {:.4}, \
             \"prefill_tok_per_s\": {:.1}, \"decode_us_per_tok\": {:.3}, \
             \"decode_tok_per_s\": {:.1}}}{comma}",
            r.variant,
            r.prompt,
            r.gen,
            r.prefill_ms,
            r.prefill_tok_per_s,
            r.decode_us_per_tok,
            r.decode_tok_per_s
        );
    }
    let _ = writeln!(json, "  ],");
    let _ = writeln!(json, "  \"state_rows\": [");
    for (i, r) in state_rows.iter().enumerate() {
        let comma = if i + 1 < state_rows.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"n\": {}, \"d\": {}, \"m\": {}, \"k\": {}, \
             \"incremental_us_per_tok\": {:.3}, \"recompute_us_per_tok\": {:.3}, \
             \"speedup\": {:.3}}}{comma}",
            r.n, r.d, r.m, r.k, r.inc_us_per_tok, r.rec_us_per_tok, r.speedup
        );
    }
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    std::fs::write("BENCH_decode_native.json", json).expect("write BENCH_decode_native.json");
    println!("\nwrote BENCH_decode_native.json");
}
