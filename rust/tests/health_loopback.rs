//! Health-aware routing + observability over the full TCP path
//! (docs/OBSERVABILITY.md):
//!
//! - killing one replica of two drains routing to the survivor: every
//!   request still succeeds, the dead replica's fault window flips its
//!   `replica_health` gauge to `unhealthy`, and per-replica request
//!   counters show the drain;
//! - `GET /v1/readyz` stays 200 while any replica can route, reporting
//!   `degraded` rather than `ready`;
//! - the structured event journal records the `replica.error` /
//!   `replica.health` decision points behind `GET /v1/logs`;
//! - the continuous profiler shows nonzero `op_time_us_total` for every
//!   MiTA kernel phase once a model forward and an overflowing
//!   attention call have run, via `/v1/metrics` and `/v1/profile`;
//! - every new Prometheus series passes the in-repo exposition checker.
//!
//! State-machine edges (degraded thresholds, window recycling) are
//! pinned by the `health.rs` unit tests; this file proves the wiring.

use std::sync::Arc;

use mita::coordinator::health::HEALTH_MIN_SAMPLES;
use mita::coordinator::{
    check_prometheus_text, NetClient, NetServer, NetServerConfig, ReplicaPool, ReplicaPoolConfig,
};
use mita::data::lra;
use mita::data::rng::Rng;
use mita::kernels::profile::{self, MITA_PHASES};
use mita::kernels::{mita_attention, MitaKernelConfig, MitaStats, Workspace};
use mita::model::{ModelConfig, OP_MODEL_INIT};
use mita::runtime::{BackendSpec, NativeAttnConfig, Tensor};
use mita::service::{KernelId, QkvBatch, ServiceRequest};
use mita::util::json::Value;

const N: usize = 32;
const DIM: usize = 16;
const DEPTH: usize = 2;

fn attn_request(seed: u64) -> ServiceRequest {
    let mut rng = Rng::new(seed);
    let data: Vec<f32> = (0..3 * N * DIM).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    ServiceRequest::Attention {
        op: KernelId::Mita,
        qkv: QkvBatch::fused(Tensor::f32(&[1, 3, N, DIM], data).unwrap()).unwrap(),
        valid_rows: None,
    }
}

/// N model-capable replicas behind the network front, model bound on all.
fn spawn_loopback(
    replicas: usize,
) -> (Arc<ReplicaPool>, NetClient, std::thread::JoinHandle<anyhow::Result<()>>) {
    let task = lra::by_name("listops", N, 16, 7);
    let mcfg = ModelConfig::for_task(task.as_ref(), DIM, 2, DEPTH, "attn.mita");
    let attn = NativeAttnConfig::for_shape(N, DIM, 2).with_model(mcfg);
    let cfg = ReplicaPoolConfig {
        replicas,
        max_inflight: 8,
        retry_after_ms: 1,
        ..Default::default()
    };
    let pool = Arc::new(ReplicaPool::spawn(BackendSpec::Native(attn), vec![], cfg).unwrap());
    pool.call(ServiceRequest::BindInit {
        binding: "model".into(),
        init_op: OP_MODEL_INIT.to_string(),
        seed: 7,
        param_count: 0,
    })
    .unwrap();
    let cfg = NetServerConfig { addr: "127.0.0.1:0".into(), max_inflight: 16 };
    let server = NetServer::bind(pool.clone(), &cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let join = std::thread::spawn(move || server.run());
    (pool, NetClient::new(addr.to_string()), join)
}

fn shutdown(pool: Arc<ReplicaPool>) {
    if let Ok(pool) = Arc::try_unwrap(pool) {
        pool.shutdown();
    }
}

#[test]
fn dead_replica_drains_routing_and_readyz_reports_degraded() {
    let (pool, client, join) = spawn_loopback(2);

    // Fresh pool: ready, all replicas healthy.
    let (status, body) = client.readyz_raw().unwrap();
    assert_eq!(status, 200, "{body}");
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ready");
    assert_eq!(v.get("replicas_healthy").unwrap().as_f64().unwrap() as usize, 2);

    // Kill replica 0's engine out from under the pool, then drive enough
    // requests that its fault window must cross the unhealthy threshold.
    pool.kill_replica(0);
    let before = client.metrics().unwrap();
    let total = 8usize;
    for i in 0..total {
        client.call(&attn_request(i as u64)).unwrap();
    }
    let after = client.metrics().unwrap();

    // Every request succeeded despite the dead replica: retries are
    // internal, nothing shed, nothing surfaced as an error.
    assert_eq!(
        after.serve_requests_total - before.serve_requests_total,
        total as u64,
        "all requests served"
    );
    assert_eq!(after.serve_errors_total, before.serve_errors_total, "no client-visible errors");
    assert_eq!(after.serve_shed_total, before.serve_shed_total, "nothing shed");

    // The drain: replica 0 completed nothing new, replica 1 took it all.
    let delta = |r: usize| {
        after.replicas[r].replica_requests_total - before.replicas[r].replica_requests_total
    };
    assert_eq!(delta(0), 0, "dead replica completes nothing");
    assert_eq!(delta(1), total as u64, "survivor absorbs the full load");

    // Health accounting: the failed submits scored as faults until the
    // state machine flipped to unhealthy, after which routing skips it.
    let r0 = &after.replicas[0];
    assert_eq!(r0.health, "unhealthy", "fault window crossed the threshold");
    assert!(
        r0.health_faults >= HEALTH_MIN_SAMPLES as u64,
        "at least {HEALTH_MIN_SAMPLES} faults recorded, got {}",
        r0.health_faults
    );
    assert_eq!(after.replicas[1].health, "healthy");

    // Prometheus surface: the gauge flipped and the whole exposition —
    // including every series added alongside health — still checks out.
    let text = client.metrics_prometheus().unwrap();
    assert!(
        text.contains("replica_health{replica=\"0\",state=\"unhealthy\"} 1"),
        "{text}"
    );
    assert!(
        text.contains("replica_health{replica=\"1\",state=\"healthy\"} 1"),
        "{text}"
    );
    check_prometheus_text(&text).expect("exposition stays scrapeable");

    // Readyz: degraded but still ready — one replica can route.
    let (status, body) = client.readyz_raw().unwrap();
    assert_eq!(status, 200, "degraded pool is still ready: {body}");
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "degraded");
    assert!(v.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(v.get("replicas_healthy").unwrap().as_f64().unwrap() as usize, 1);
    assert_eq!(v.get("replicas_unhealthy").unwrap().as_f64().unwrap() as usize, 1);

    // The journal recorded the decision points: failed submits and the
    // health transition (the journal is process-global, so assert
    // presence, not counts).
    let logs = Value::parse(&client.logs_raw(None, Some("warn")).unwrap()).unwrap();
    let events = logs.get("events").unwrap().as_arr().unwrap();
    let has = |name: &str| {
        events.iter().any(|e| e.get("event").unwrap().as_str().unwrap() == name)
    };
    assert!(has("replica.error"), "failed submits are journaled: {logs}");
    assert!(has("replica.health"), "health transitions are journaled: {logs}");
    let transition = events
        .iter()
        .find(|e| e.get("event").unwrap().as_str().unwrap() == "replica.health")
        .unwrap();
    assert!(
        transition.get("message").unwrap().as_str().unwrap().contains("unhealthy"),
        "{logs}"
    );
    // `level=error` filters the warn-level transition back out.
    let errors_only = Value::parse(&client.logs_raw(None, Some("error")).unwrap()).unwrap();
    assert!(errors_only
        .get("events")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .all(|e| e.get("level").unwrap().as_str().unwrap() == "error"));

    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    shutdown(pool);
}

#[test]
fn profile_probe_covers_every_mita_phase() {
    let (pool, client, join) = spawn_loopback(1);

    // A model forward through the server exercises the routed MiTA
    // phases (landmarks / scores / topk / route / pack / attend)...
    let task = lra::by_name("listops", N, 16, 7);
    let (tokens, _) = task.sample(mita::data::Split::Val, 0);
    let tokens = Tensor::i32(&[1, N], tokens).unwrap();
    client
        .call(&ServiceRequest::ModelForward { binding: "model".into(), tokens, valid_rows: None })
        .unwrap();

    // ...and the overflow fallback phase is only recorded when overflow
    // actually happens, so force it: identical queries all route to one
    // expert with cap_factor 1 (the profiler is process-global, so this
    // in-process call lands in the same accumulators the server exports).
    let (n, d) = (24, 4);
    let q = vec![0.7f32; n * d];
    let mut rng = Rng::new(9);
    let k: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let v: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let cfg = MitaKernelConfig { m: 4, k: 8, cap_factor: 1, block_q: 1 };
    let mut ws = Workspace::new();
    let mut out = vec![0.0f32; n * d];
    let mut stats = MitaStats::default();
    mita_attention(&q, &k, &v, n, d, &cfg, &mut ws, &mut out, &mut stats);
    assert!(stats.overflow > 0, "probe must exercise the overflow path");

    // Every MiTA kernel phase is now nonzero — in the process snapshot,
    // in the /v1/metrics op series, and in the /v1/profile tree.
    let snap = profile::snapshot();
    for phase in MITA_PHASES {
        let s = snap.iter().find(|s| s.op == phase).expect("phase present in snapshot");
        assert!(s.calls > 0, "{phase} has calls");
        assert!(s.time_us > 0.0, "{phase} accumulated time");
    }
    let m = client.metrics().unwrap();
    for phase in MITA_PHASES {
        let s = m.ops.iter().find(|s| s.op == phase).expect("phase present in /v1/metrics");
        assert!(s.calls > 0 && s.time_us > 0.0, "{phase} nonzero over the wire");
    }
    let body = Value::parse(&client.profile_raw().unwrap()).unwrap();
    assert!(body.get("uptime_seconds").unwrap().as_f64().unwrap() >= 0.0);
    let mita_group = body.get("profile").unwrap().get("mita").unwrap();
    assert!(mita_group.get("total_us").unwrap().as_f64().unwrap() > 0.0);
    for phase in MITA_PHASES {
        let leaf = phase.strip_prefix("mita.").unwrap();
        let node = mita_group.get(leaf).unwrap();
        assert!(node.get("calls").unwrap().as_f64().unwrap() > 0.0, "{phase} in tree");
        assert!(node.get("time_us").unwrap().as_f64().unwrap() > 0.0, "{phase} in tree");
        assert!(node.get("mean_us").unwrap().as_f64().unwrap() > 0.0, "{phase} in tree");
    }

    // The decode phases exist in the exported series (zero until a
    // generate request runs; presence is the contract here).
    for op in ["decode.prefill", "decode.step"] {
        assert!(m.ops.iter().any(|s| s.op == op), "{op} series exported");
    }

    // And the Prometheus rendering of the same series stays scrapeable.
    let text = client.metrics_prometheus().unwrap();
    for phase in MITA_PHASES {
        assert!(text.contains(&format!("op_time_us_total{{op=\"{phase}\"}}")), "{text}");
        assert!(text.contains(&format!("op_calls_total{{op=\"{phase}\"}}")), "{text}");
    }
    check_prometheus_text(&text).expect("exposition stays scrapeable");

    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    shutdown(pool);
}
