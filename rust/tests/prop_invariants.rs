//! Property tests (seeded, via util::prop) on coordinator invariants:
//! routing/packing, batching policy, metrics, and the online-softmax
//! combine the MiTA kernel relies on.

use std::time::{Duration, Instant};

use mita::coordinator::batcher::{BatchPolicy, Batcher, Flush};
use mita::coordinator::metrics::LatencyHistogram;
use mita::mita::routing::{
    adaptive_pool_matrix, capacity, pack_by_expert, route_argmax, scores, topk_indices,
};
use mita::util::prop::run_prop;

// ---------------------------------------------------------------------------
// Routing invariants (must mirror kernels/ref.py semantics).
// ---------------------------------------------------------------------------

#[test]
fn prop_pool_matrix_is_partition_of_unity() {
    run_prop(200, |g| {
        let n = g.usize_in(1, 300);
        let m = g.usize_in(1, n);
        let p = adaptive_pool_matrix(n, m);
        for i in 0..m {
            let row: f32 = (0..n).map(|r| p[i * n + r]).sum();
            assert!((row - 1.0).abs() < 1e-4, "row {i} sums {row}");
        }
        for r in 0..n {
            let owners = (0..m).filter(|&i| p[i * n + r] != 0.0).count();
            assert_eq!(owners, 1, "col {r} in {owners} windows (n={n}, m={m})");
        }
    });
}

#[test]
fn prop_topk_indices_are_maximal_and_distinct() {
    run_prop(120, |g| {
        let n = g.usize_in(2, 64);
        let m = g.usize_in(1, 8);
        let kk = g.usize_in(1, n);
        let s = g.vec_f32(n * m, -10.0, 10.0);
        let idx = topk_indices(&s, n, m, kk);
        assert_eq!(idx.len(), m * kk);
        for e in 0..m {
            let picks = &idx[e * kk..(e + 1) * kk];
            // Distinct and in range.
            let mut sorted = picks.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), kk);
            assert!(picks.iter().all(|&p| p < n));
            // Every non-picked score <= the minimum picked score.
            let min_picked = picks
                .iter()
                .map(|&p| s[p * m + e])
                .fold(f32::INFINITY, f32::min);
            for r in 0..n {
                if !picks.contains(&r) {
                    assert!(
                        s[r * m + e] <= min_picked + 1e-6,
                        "expert {e}: unpicked {r} beats picked min"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_routing_is_argmax() {
    run_prop(100, |g| {
        let n = g.usize_in(1, 48);
        let m = g.usize_in(1, 8);
        let d = g.usize_in(1, 16);
        let q = g.vec_f32(n * d, -2.0, 2.0);
        let lands = g.vec_f32(m * d, -2.0, 2.0);
        let e = route_argmax(&q, &lands, n, d, m);
        assert_eq!(e.len(), n);
        // Verify against brute force via scores (scores() computes K·Q̃ᵀ with
        // 1/sqrt(d) scaling, which preserves argmax).
        let s = scores(&q, &lands, n, d, m);
        for r in 0..n {
            let best = (0..m)
                .max_by(|&a, &b| s[r * m + a].partial_cmp(&s[r * m + b]).unwrap())
                .unwrap();
            assert!(
                (s[r * m + e[r]] - s[r * m + best]).abs() < 1e-5,
                "row {r}: {} vs {}",
                e[r],
                best
            );
        }
    });
}

#[test]
fn prop_pack_by_expert_invariants() {
    run_prop(200, |g| {
        let n = g.usize_in(1, 200);
        let m = g.usize_in(1, 16);
        let cap_factor = g.usize_in(1, 3);
        let block_q = [8, 16, 64][g.usize_in(0, 2)];
        let cap = capacity(n, m, cap_factor, block_q);
        assert!(cap % block_q == 0 && cap >= 1);

        let assign = g.vec_usize_below(n, m);
        let r = pack_by_expert(&assign, m, cap);

        // Counts are exact.
        let mut counts = vec![0usize; m];
        for &e in &assign {
            counts[e] += 1;
        }
        assert_eq!(r.counts, counts);

        // Overflow = sum over experts of max(0, count - cap).
        let expect_overflow: usize = counts.iter().map(|&c| c.saturating_sub(cap)).sum();
        assert_eq!(r.overflow, expect_overflow);

        // Kept slots are unique and consistent with their expert's range.
        let mut seen = std::collections::HashSet::new();
        for (q, slot) in r.slot.iter().enumerate() {
            if let Some(s) = slot {
                assert!(seen.insert(*s), "duplicate slot {s}");
                let e = assign[q];
                assert!(*s >= e * cap && *s < (e + 1) * cap, "slot outside expert range");
            }
        }
        assert_eq!(seen.len(), n - r.overflow);
    });
}

#[test]
fn prop_capacity_bounds_mean_load() {
    run_prop(100, |g| {
        let n = g.usize_in(1, 4096);
        let m = g.usize_in(1, 64);
        let cap = capacity(n, m, 2, 64);
        // cap must hold at least 2x the mean per-expert load.
        assert!(cap * m >= 2 * n || cap >= n, "n={n} m={m} cap={cap}");
    });
}

// ---------------------------------------------------------------------------
// Online-softmax combine (f64 reference, mirrors kernel math).
// ---------------------------------------------------------------------------

fn softmax_attention_1q(scores: &[f64], values: &[f64], d: usize) -> Vec<f64> {
    let mx = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let ps: Vec<f64> = scores.iter().map(|s| (s - mx).exp()).collect();
    let den: f64 = ps.iter().sum();
    let mut out = vec![0.0; d];
    for (j, p) in ps.iter().enumerate() {
        for c in 0..d {
            out[c] += p * values[j * d + c];
        }
    }
    out.iter().map(|x| x / den).collect()
}

fn partial(scores: &[f64], values: &[f64], d: usize) -> (Vec<f64>, f64, f64) {
    let mx = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let ps: Vec<f64> = scores.iter().map(|s| (s - mx).exp()).collect();
    let l: f64 = ps.iter().sum();
    let mut o = vec![0.0; d];
    for (j, p) in ps.iter().enumerate() {
        for c in 0..d {
            o[c] += p * values[j * d + c];
        }
    }
    (o, mx, l)
}

#[test]
fn prop_online_softmax_combine_is_exact() {
    run_prop(200, |g| {
        let k1 = g.usize_in(1, 24);
        let k2 = g.usize_in(1, 24);
        let d = g.usize_in(1, 8);
        let scale = [1.0f32, 30.0][g.usize_in(0, 1)] as f64;
        let s1: Vec<f64> = (0..k1).map(|_| g.f32_in(-3.0, 3.0) as f64 * scale).collect();
        let s2: Vec<f64> = (0..k2).map(|_| g.f32_in(-3.0, 3.0) as f64 * scale).collect();
        let v1: Vec<f64> = (0..k1 * d).map(|_| g.f32_in(-2.0, 2.0) as f64).collect();
        let v2: Vec<f64> = (0..k2 * d).map(|_| g.f32_in(-2.0, 2.0) as f64).collect();

        let (o1, m1, l1) = partial(&s1, &v1, d);
        let (o2, m2, l2) = partial(&s2, &v2, d);
        // Combine (Alg. 1 line 16).
        let mx = m1.max(m2);
        let a1 = (m1 - mx).exp();
        let a2 = (m2 - mx).exp();
        let den = l1 * a1 + l2 * a2;
        let combined: Vec<f64> =
            (0..d).map(|c| (o1[c] * a1 + o2[c] * a2) / den).collect();

        let mut full_s = s1.clone();
        full_s.extend_from_slice(&s2);
        let mut full_v = v1.clone();
        full_v.extend_from_slice(&v2);
        let expect = softmax_attention_1q(&full_s, &full_v, d);
        for c in 0..d {
            assert!(
                (combined[c] - expect[c]).abs() < 1e-9 * (1.0 + expect[c].abs()),
                "dim {c}: {} vs {}",
                combined[c],
                expect[c]
            );
        }
    });
}

// ---------------------------------------------------------------------------
// Batcher policy invariants.
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_never_exceeds_max_batch_and_stays_fifo() {
    run_prop(150, |g| {
        let max_batch = g.usize_in(1, 16);
        let policy =
            BatchPolicy { max_batch, max_wait: Duration::from_millis(g.usize_in(1, 20) as u64) };
        let mut b: Batcher<usize> = Batcher::new(policy);
        let now = Instant::now();
        let n = g.usize_in(0, 64);
        for i in 0..n {
            b.push(i, now);
        }
        let mut emitted: Vec<usize> = Vec::new();
        loop {
            match b.poll(now + policy.max_wait + Duration::from_millis(1)) {
                Flush::Take(k) => {
                    assert!(k <= max_batch);
                    assert!(k > 0);
                    emitted.extend(b.take(k).into_iter().map(|p| p.payload));
                }
                Flush::Wait(_) => break,
            }
        }
        // All items emitted exactly once, in order.
        assert_eq!(emitted, (0..n).collect::<Vec<_>>());
        assert_eq!(b.items_emitted as usize, n);
        // Pad accounting: total slots = batches * max_batch.
        assert_eq!(
            b.items_emitted + b.padded_slots,
            b.batches_emitted * max_batch as u64
        );
    });
}

#[test]
fn prop_batcher_respects_deadline() {
    run_prop(100, |g| {
        let wait_ms = g.usize_in(1, 50) as u64;
        let policy = BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(wait_ms) };
        let mut b: Batcher<u8> = Batcher::new(policy);
        let t0 = Instant::now();
        b.push(0, t0);
        // Just before the deadline: must wait, with a hint <= remaining.
        let before = t0 + Duration::from_millis(wait_ms.saturating_sub(1));
        match b.poll(before) {
            Flush::Wait(Some(hint)) => assert!(hint <= Duration::from_millis(wait_ms)),
            Flush::Wait(None) => panic!("queue is non-empty: hint expected"),
            Flush::Take(_) => {} // deadline arithmetic can round; taking early is allowed only at the boundary
        }
        // At/after the deadline: must flush.
        match b.poll(t0 + Duration::from_millis(wait_ms + 1)) {
            Flush::Take(1) => {}
            other => panic!("expected flush after deadline, got {other:?}"),
        }
    });
}

// ---------------------------------------------------------------------------
// Metrics invariants.
// ---------------------------------------------------------------------------

#[test]
fn prop_histogram_percentiles_monotone_and_bounded() {
    run_prop(100, |g| {
        let mut h = LatencyHistogram::new();
        let n = g.usize_in(1, 500);
        let mut max_us = 0u64;
        for _ in 0..n {
            let us = g.usize_in(1, 10_000_000) as u64;
            max_us = max_us.max(us);
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), n as u64);
        let mut prev = 0.0;
        for p in [1.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let v = h.percentile(p);
            assert!(v >= prev, "p{p} {v} < {prev}");
            prev = v;
        }
        // p100 within a bucket width of the true max.
        let p100 = h.percentile(100.0);
        assert!(p100 <= (max_us as f64 * 1e-6) * 1.13 + 2e-6, "{p100} vs {max_us}us");
    });
}

// ---------------------------------------------------------------------------
// JSON parser fuzz (structure-preserving roundtrips).
// ---------------------------------------------------------------------------

#[test]
fn prop_json_parses_generated_documents() {
    use mita::util::json::Value;
    run_prop(150, |g| {
        // Build a random JSON document bottom-up (depth <= 3).
        fn gen(g: &mut mita::util::prop::Gen, depth: usize) -> String {
            match if depth == 0 { g.usize_in(0, 2) } else { g.usize_in(0, 4) } {
                0 => format!("{}", g.f32_in(-1e4, 1e4)),
                1 => format!("\"s{}\"", g.usize_in(0, 999)),
                2 => ["true", "false", "null"][g.usize_in(0, 2)].to_string(),
                3 => {
                    let n = g.usize_in(0, 4);
                    let items: Vec<String> = (0..n).map(|_| gen(g, depth - 1)).collect();
                    format!("[{}]", items.join(","))
                }
                _ => {
                    let n = g.usize_in(0, 4);
                    let items: Vec<String> =
                        (0..n).map(|i| format!("\"k{i}\":{}", gen(g, depth - 1))).collect();
                    format!("{{{}}}", items.join(","))
                }
            }
        }
        let doc = gen(g, 3);
        let parsed = Value::parse(&doc).unwrap_or_else(|e| panic!("doc {doc}: {e}"));
        // Objects keep all their keys.
        if let Value::Obj(map) = &parsed {
            for k in map.keys() {
                assert!(doc.contains(&format!("\"{k}\"")));
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Capacity ablation (DESIGN.md §6): overflow rate under realistic routing.
// ---------------------------------------------------------------------------

#[test]
fn overflow_rate_small_under_gaussianish_routing() {
    // Routing distributions from random continuous features are uneven but
    // not adversarial; cap_factor=2 must keep the shared-expert fallback
    // rate low (the kernel-vs-ref accuracy argument relies on this).
    use mita::data::rng::Rng;
    let mut total_q = 0usize;
    let mut total_overflow = 0usize;
    for trial in 0..20 {
        let (n, d, m) = (256, 16, 16);
        let mut rng = Rng::derive(0xAB1A7E, &[trial]);
        let mut normal = |len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32).collect()
        };
        let q = normal(n * d);
        let lands = mita::mita::routing::landmarks_pool1d(&q, n, d, m);
        let assign = mita::mita::routing::route_argmax(&q, &lands, n, d, m);
        let cap = mita::mita::routing::capacity(n, m, 2, 16);
        let pack = mita::mita::routing::pack_by_expert(&assign, m, cap);
        total_q += n;
        total_overflow += pack.overflow;
    }
    let rate = total_overflow as f64 / total_q as f64;
    assert!(rate < 0.05, "overflow rate {rate:.3} exceeds 5% at cap_factor=2");
}

#[test]
fn overflow_vanishes_as_cap_factor_grows() {
    use mita::data::rng::Rng;
    let (n, d, m) = (256, 8, 8);
    let mut rng = Rng::new(99);
    let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let lands = mita::mita::routing::landmarks_pool1d(&q, n, d, m);
    let assign = mita::mita::routing::route_argmax(&q, &lands, n, d, m);
    let mut prev = usize::MAX;
    for cf in [1usize, 2, 4, 8] {
        let cap = mita::mita::routing::capacity(n, m, cf, 16);
        let o = mita::mita::routing::pack_by_expert(&assign, m, cap).overflow;
        assert!(o <= prev, "overflow not monotone in cap_factor");
        prev = o;
    }
    assert_eq!(prev, 0, "cap_factor=8 must eliminate overflow");
}
