//! Integration tests of the native model subsystem: MiTA-vs-dense model
//! parity, checkpoint round-trips, the backend's `model.forward` op, and
//! end-to-end serving over the LRA tasks through the engine.

use mita::coordinator::batcher::BatchPolicy;
use mita::coordinator::{checkpoint, serve_model, Engine, ModelServeConfig, DEFAULT_MAX_INFLIGHT};
use mita::data::lra;
use mita::data::Split;
use mita::kernels::{MitaKernelConfig, MitaStats, WorkspacePool, OP_ATTN_DENSE, OP_ATTN_MITA};
use mita::model::{MitaModel, ModelConfig, ModelScratch, OP_MODEL_INIT};
use mita::runtime::{Backend, BackendSpec, NativeAttnConfig, NativeBackend, Tensor};
use mita::service::{BindingId, ServiceRequest};

/// Tiny (seq_len, vocab) valid for every task: 64 is a perfect square
/// (image/pathfinder), vocab from the canonical per-task table.
fn tiny_shape(name: &str) -> (usize, usize) {
    (64, lra::default_vocab(name).expect("known task"))
}

fn forward_all(model: &MitaModel, tokens: &[i32], bsz: usize) -> Vec<f32> {
    let registry = model.registry();
    let pool = WorkspacePool::new();
    let mut scratch = ModelScratch::default();
    let mut stats = MitaStats::default();
    model
        .forward(tokens, bsz, bsz, &registry, &pool, &mut scratch, &mut stats)
        .expect("forward")
}

/// Acceptance gate: with the landmarks-cover-everything config (m = k =
/// n) every MiTA expert attends the full KV set, so a MiTA-block model
/// and a dense-block model sharing parameters must produce the same
/// logits within 1e-4 — across all five LRA tasks.
#[test]
fn model_parity_when_landmarks_cover_everything() {
    for name in lra::TASK_NAMES {
        let (n, vocab) = tiny_shape(name);
        let task = lra::by_name(name, n, vocab, 3);
        let pcfg = MitaKernelConfig { m: n, k: n, cap_factor: 2, block_q: 8 };
        let cfg = ModelConfig::for_task(task.as_ref(), 32, 2, 2, OP_ATTN_MITA).with_mita(pcfg);
        let model = MitaModel::init(cfg, 17).unwrap();
        let dense = model.with_kernel(OP_ATTN_DENSE).unwrap();
        let bsz = 3usize;
        let (tokens, _) = lra::batch_host(task.as_ref(), Split::Val, 0, bsz);

        let lm = forward_all(&model, &tokens, bsz);
        let ld = forward_all(&dense, &tokens, bsz);
        let max_diff = lm.iter().zip(&ld).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "{name}: model parity broke (max|Δ| = {max_diff})");
        assert!(lm.iter().all(|x| x.is_finite()), "{name}: non-finite logits");
    }
}

#[test]
fn model_forward_is_deterministic_across_instances() {
    let task = lra::by_name("listops", 64, 16, 5);
    let cfg = ModelConfig::for_task(task.as_ref(), 32, 2, 2, OP_ATTN_MITA);
    let (tokens, _) = lra::batch_host(task.as_ref(), Split::Train, 0, 2);
    let a = forward_all(&MitaModel::init(cfg.clone(), 11).unwrap(), &tokens, 2);
    let b = forward_all(&MitaModel::init(cfg.clone(), 11).unwrap(), &tokens, 2);
    assert_eq!(a, b, "same (config, seed, tokens) must be bit-identical");
    let c = forward_all(&MitaModel::init(cfg, 12).unwrap(), &tokens, 2);
    assert_ne!(a, c, "a different seed must change the logits");
}

#[test]
fn checkpoint_roundtrip_preserves_model_exactly() {
    let dir = std::env::temp_dir().join(format!("mita_model_rt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.ckpt");

    let task = lra::by_name("text", 64, 64, 2);
    let mut cfg = ModelConfig::for_task(task.as_ref(), 32, 4, 3, OP_ATTN_MITA);
    cfg.block_kernels[1] = OP_ATTN_DENSE.to_string(); // mixed blocks survive
    let model = MitaModel::init(cfg, 23).unwrap();
    model.save(&path).unwrap();

    let loaded = MitaModel::load(&path).unwrap();
    assert_eq!(loaded.cfg, model.cfg, "config descriptor must round-trip");
    assert_eq!(loaded.params, model.params, "parameters must round-trip bit-exactly");

    let (tokens, _) = lra::batch_host(task.as_ref(), Split::Val, 7, 2);
    assert_eq!(forward_all(&model, &tokens, 2), forward_all(&loaded, &tokens, 2));

    // The same file feeds the generic checkpoint loader + backend binding.
    let tensors = checkpoint::load(&path).unwrap();
    let attn = NativeAttnConfig::for_shape(64, 32, 4);
    let mut be = NativeBackend::new(attn);
    be.execute(ServiceRequest::BindCheckpoint { binding: BindingId::from("m"), params: tensors })
        .unwrap();
    let x = Tensor::i32(&[2, 64], tokens).unwrap();
    let out = be.run_model(&BindingId::from("m"), &x, None).unwrap();
    assert_eq!(out.shape(), &[2, model.cfg.classes]);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn backend_model_request_matches_direct_forward_and_skips_padding() {
    let task = lra::by_name("image", 64, 32, 9);
    let mcfg = ModelConfig::for_task(task.as_ref(), 32, 2, 2, OP_ATTN_MITA);
    let attn = NativeAttnConfig::for_shape(64, 32, 2).with_model(mcfg.clone());
    let mut be = NativeBackend::new(attn);
    be.execute(ServiceRequest::BindInit {
        binding: BindingId::from("m"),
        init_op: OP_MODEL_INIT.into(),
        seed: 5,
        param_count: 0,
    })
    .unwrap();

    let (bsz, valid) = (4usize, 2usize);
    let (tokens, _) = lra::batch_host(task.as_ref(), Split::Val, 0, bsz);
    let x = Tensor::i32(&[bsz, 64], tokens.clone()).unwrap();
    // Typed valid_rows instead of the old one-element marker tensor.
    let out = be.run_model(&BindingId::from("m"), &x, Some(valid)).unwrap();
    let full = out.as_f32().unwrap();
    let classes = mcfg.classes;

    // Valid prefix matches the library-level forward on the same model.
    let model = MitaModel::init(mcfg, 5).unwrap();
    let want = forward_all(&model, &tokens[..valid * 64], valid);
    assert_eq!(&full[..valid * classes], want.as_slice());
    // Pad rows never reach the model (zero logits, no routed queries).
    assert!(full[valid * classes..].iter().all(|&x| x == 0.0));
    let stats = be.mita_stats();
    assert_eq!(stats.queries, model.cfg.depth * valid * model.cfg.heads * 64);
}

#[test]
fn engine_serves_model_requests_end_to_end() {
    let (n, vocab) = tiny_shape("listops");
    let task = lra::by_name("listops", n, vocab, 1);
    let mcfg = ModelConfig::for_task(task.as_ref(), 32, 2, 2, OP_ATTN_MITA);
    let attn = NativeAttnConfig::for_shape(n, 32, 2).with_model(mcfg);
    let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![]).unwrap();
    engine.handle().bind_init("model", OP_MODEL_INIT, 0, 0).unwrap();

    let cfg = ModelServeConfig {
        task: "listops".into(),
        seq_len: n,
        vocab,
        binding: "model".into(),
        requests: 12,
        rate: 0.0,
        queue_cap: 64,
        max_inflight: DEFAULT_MAX_INFLIGHT,
        policy: BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(2) },
    };
    let report = serve_model(&engine.handle(), &cfg).unwrap();
    assert_eq!(report.completed, 12);
    assert_eq!(report.rejected, 0);
    assert!(report.batches >= 3, "12 requests at max_batch 4 need >= 3 batches");
    // The run's MiTA stats cover the model's routed blocks.
    let mita = report.mita.expect("native backend reports MiTA stats");
    assert!(mita.queries > 0, "MiTA blocks must have routed queries");

    // Unknown tasks are rejected before any serving starts.
    let mut bad = cfg;
    bad.task = "nope".into();
    assert!(serve_model(&engine.handle(), &bad).is_err());
    engine.shutdown();
}
