//! Integration tests over the AOT runtime: require `make artifacts` to have
//! run (they skip with a loud note otherwise, so `cargo test` works in a
//! fresh checkout).

use mita::coordinator::{checkpoint, Trainer};
use mita::data::{BatchSource, Split};
use mita::runtime::{Runtime, Tensor};

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load("artifacts").expect("runtime load"))
}

#[test]
fn manifest_loads_and_has_all_experiments() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest();
    for bundle in [
        "quickstart",
        "t2_std",
        "t2_mita",
        "t4_std",
        "t4_mita_swap",
        "t5_listops_standard",
        "t5_pathfinder_mita",
        "t6_mk_16x16",
        "t7_mita",
        "f5_standard_n1024",
        "f9_eval_agent",
        "f10_eval_m8k8",
        "fig_analysis_mita",
    ] {
        assert!(m.bundle(bundle).is_ok(), "missing bundle {bundle}");
    }
    // Artifact files exist on disk.
    for (name, art) in &m.artifacts {
        assert!(
            std::path::Path::new("artifacts").join(&art.file).exists(),
            "missing file for {name}"
        );
    }
}

#[test]
fn init_layout_matches_manifest() {
    let Some(rt) = runtime() else { return };
    let bundle = rt.manifest().bundle("quickstart").unwrap().clone();
    let trainer = Trainer::new(&rt, "quickstart", 7).unwrap();
    let params = trainer.params().unwrap();
    assert_eq!(params.len(), bundle.param_count());
    for (t, spec) in params.iter().zip(&bundle.param_layout) {
        assert_eq!(t.shape(), spec.shape.as_slice(), "param {}", spec.path);
    }
}

#[test]
fn quickstart_trains_and_loss_decreases() {
    let Some(rt) = runtime() else { return };
    let bundle = rt.manifest().bundle("quickstart").unwrap().clone();
    let source = BatchSource::for_bundle(&bundle).unwrap();
    let mut trainer = Trainer::new(&rt, "quickstart", 0).unwrap();
    trainer.train(&source, 60, 0).unwrap();
    let first = trainer.history[0].loss;
    let tail = trainer.tail_loss();
    assert!(
        tail < first * 0.7,
        "loss did not decrease: first={first:.3} tail={tail:.3}"
    );
    let ev = trainer.eval(&source, 4).unwrap();
    assert!(ev.accuracy > 0.2, "eval acc {:.3} not above chance", ev.accuracy);
}

#[test]
fn deterministic_init_and_step() {
    let Some(rt) = runtime() else { return };
    let bundle = rt.manifest().bundle("quickstart").unwrap().clone();
    let source = BatchSource::for_bundle(&bundle).unwrap();
    let mut a = Trainer::new(&rt, "quickstart", 123).unwrap();
    let mut b = Trainer::new(&rt, "quickstart", 123).unwrap();
    let (xa, ya) = source.batch(Split::Train, 0).unwrap();
    let (xb, yb) = source.batch(Split::Train, 0).unwrap();
    assert_eq!(xa, xb);
    let (la, _) = a.step(xa, ya).unwrap();
    let (lb, _) = b.step(xb, yb).unwrap();
    assert_eq!(la, lb, "same seed + batch must give identical loss");
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some(rt) = runtime() else { return };
    let bundle = rt.manifest().bundle("quickstart").unwrap().clone();
    let source = BatchSource::for_bundle(&bundle).unwrap();
    let mut trainer = Trainer::new(&rt, "quickstart", 1).unwrap();
    trainer.train(&source, 10, 0).unwrap();
    let ev1 = trainer.eval(&source, 2).unwrap();

    let dir = std::env::temp_dir().join(format!("mita_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("q.ckpt");
    trainer.save_checkpoint(&path).unwrap();

    let ev2 = mita::coordinator::eval_checkpoint(&rt, &path, "quickstart", 2).unwrap();
    assert!((ev1.loss - ev2.loss).abs() < 1e-5, "{} vs {}", ev1.loss, ev2.loss);
    assert_eq!(ev1.accuracy, ev2.accuracy);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn warm_start_resumes_from_params() {
    let Some(rt) = runtime() else { return };
    let bundle = rt.manifest().bundle("quickstart").unwrap().clone();
    let source = BatchSource::for_bundle(&bundle).unwrap();
    let mut base = Trainer::new(&rt, "quickstart", 2).unwrap();
    base.train(&source, 15, 0).unwrap();
    let params = base.params().unwrap();
    let warm = Trainer::with_warm_start(&rt, "quickstart", 99, &params).unwrap();
    // Warm-started trainer evaluates identically to the source params.
    let ev_base = base.eval(&source, 2).unwrap();
    let ev_warm = warm.eval(&source, 2).unwrap();
    assert!((ev_base.loss - ev_warm.loss).abs() < 1e-5);
}

#[test]
fn predict_artifact_runs_and_shapes_match() {
    let Some(rt) = runtime() else { return };
    let bundle = rt.manifest().bundle("quickstart").unwrap().clone();
    let source = BatchSource::for_bundle(&bundle).unwrap();
    let trainer = Trainer::new(&rt, "quickstart", 3).unwrap();
    let (x, _) = source.batch(Split::Val, 0).unwrap();
    let mut inputs = trainer.params().unwrap();
    inputs.push(x);
    let art = rt.manifest().bundle_artifact("quickstart", "predict").unwrap();
    let outs = rt.run(art, &inputs).unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(
        outs[0].shape(),
        &[bundle.train.batch_size, bundle.model.num_classes]
    );
    let preds = outs[0].argmax_last().unwrap();
    assert!(preds.as_i32().unwrap().iter().all(|&p| p >= 0 && p < 10));
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let art = rt.manifest().bundle_artifact("quickstart", "init").unwrap();
    // Wrong input count.
    assert!(rt.run(art, &[]).is_err());
    // Wrong dtype/shape.
    let bad = Tensor::f32(&[2, 2], vec![0.0; 4]).unwrap();
    assert!(rt.run(art, &[bad]).is_err());
}

#[test]
fn attention_swap_eval_works() {
    // Fig. 9 mechanics: params trained under one bundle evaluated under
    // another with identical layout.
    let Some(rt) = runtime() else { return };
    let t2 = rt.manifest().bundle("t2_std").unwrap().clone();
    let f9 = rt.manifest().bundle("f9_eval_mita").unwrap().clone();
    assert_eq!(t2.param_count(), f9.param_count());
    let trainer = Trainer::new(&rt, "t2_std", 5).unwrap();
    let source = BatchSource::for_bundle(&f9).unwrap();
    let ev = trainer.eval_with(&source, 1, "f9_eval_mita").unwrap();
    assert!(ev.loss.is_finite());
}

#[test]
fn seg_bundle_eval_produces_confusion_miou() {
    let Some(rt) = runtime() else { return };
    let bundle = rt.manifest().bundle("t4_std").unwrap().clone();
    let source = BatchSource::for_bundle(&bundle).unwrap();
    let trainer = Trainer::new(&rt, "t4_std", 0).unwrap();
    let ev = trainer.eval(&source, 1).unwrap();
    let miou = ev.miou.expect("seg eval must report miou");
    assert!((0.0..=1.0).contains(&miou));
    assert!((0.0..=1.0).contains(&ev.accuracy));
}

#[test]
fn checkpoint_format_rejects_layout_mismatch() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join(format!("mita_it2_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.ckpt");
    checkpoint::save(&path, &[Tensor::scalar_f32(1.0)]).unwrap();
    // quickstart wants dozens of params; one tensor must be rejected.
    assert!(mita::coordinator::eval_checkpoint(&rt, &path, "quickstart", 1).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn loader_batches_match_manifest_specs_for_every_bundle() {
    // The data substrate and the AOT artifacts must agree on batch shapes
    // for every training bundle in the manifest — the contract that makes
    // `mita all` safe to run unattended.
    let Some(rt) = runtime() else { return };
    for name in rt.manifest().bundles_with_prefix("") {
        let bundle = rt.manifest().bundle(name).unwrap().clone();
        let Some(train_art) = bundle.artifacts.get("train_step") else { continue };
        let spec = rt.manifest().artifact(train_art).unwrap().clone();
        let source = BatchSource::for_bundle(&bundle).expect(name);
        let (x, y) = source.batch(Split::Train, 0).expect(name);
        let p = bundle.param_count();
        // train_step inputs: 3P params + step + x + y.
        x.check_spec(&spec.inputs[3 * p + 1]).unwrap_or_else(|e| panic!("{name} x: {e}"));
        y.check_spec(&spec.inputs[3 * p + 2]).unwrap_or_else(|e| panic!("{name} y: {e}"));
    }
}
