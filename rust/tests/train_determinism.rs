//! Thread-count determinism of the native trainer.
//!
//! This file holds exactly one test and therefore owns its whole test
//! binary: it mutates `MITA_NUM_THREADS` (process-global state), which
//! would race `getenv` calls from concurrently running tests if any
//! shared the process. Keep it single-test.
//!
//! The property under test is the deterministic gradient-reduction
//! order: per-example gradients land in per-example slabs and are summed
//! in example-index order regardless of which worker thread produced
//! them, so losses, gradients, and the resulting parameters are
//! bit-identical for any worker count.

use mita::data::lra;
use mita::model::{MitaModel, ModelConfig};
use mita::train::grads::flatten_params;
use mita::train::{AdamWConfig, NativeTrainer, TrainConfig};

fn run_training(threads: &str) -> (Vec<u64>, Vec<u32>) {
    std::env::set_var("MITA_NUM_THREADS", threads);
    let task = lra::by_name("text", 32, 32, 29);
    let cfg = ModelConfig::for_task(task.as_ref(), 16, 2, 2, mita::kernels::OP_ATTN_MITA);
    let model = MitaModel::init(cfg, 8).unwrap();
    let mut trainer = NativeTrainer::new(model, AdamWConfig::default(), 12).unwrap();
    let run = TrainConfig {
        steps: 10,
        batch: 6,
        eval_every: 4,
        eval_batches: 1,
        log_every: 0,
        checkpoint: None,
    };
    trainer.train(task.as_ref(), &run).unwrap();
    let losses = trainer.history.iter().map(|r| r.loss.to_bits()).collect();
    let params = flatten_params(&trainer.model().params).iter().map(|p| p.to_bits()).collect();
    (losses, params)
}

#[test]
fn loss_curves_and_params_are_bit_identical_across_thread_counts() {
    let (loss1, params1) = run_training("1");
    let (loss4, params4) = run_training("4");
    std::env::remove_var("MITA_NUM_THREADS");
    assert_eq!(loss1.len(), 10);
    assert_eq!(
        loss1, loss4,
        "10-step loss curve must be bit-identical for 1 vs 4 worker threads"
    );
    assert_eq!(params1, params4, "trained parameters must be bit-identical too");
}
