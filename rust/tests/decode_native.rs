//! Decode-subsystem gate (docs/DECODE.md):
//!
//! - the incremental [`CausalMitaState`] must be **bit-identical** to
//!   the from-scratch recompute reference at every single step —
//!   landmarks, expert memberships, routing, and attention outputs;
//! - the registry-visible causal kernels (`mita.causal` /
//!   `dense.causal`) must match the same reference row for row;
//! - greedy generation is deterministic and accepts per-request kernel
//!   overrides;
//! - the full TCP path streams step events over chunked
//!   `/v1/generate`, meters them, and splits a `decode` span out of
//!   `execute` in the trace export.
//!
//! The suite runs under the default lane, `MITA_SIMD=scalar`, and
//! `MITA_NUM_THREADS=1` in CI, so "bit-identical" here means across
//! lanes and thread counts too.

use std::sync::Arc;

use mita::coordinator::{NetClient, NetServer, NetServerConfig, ReplicaPool, ReplicaPoolConfig};
use mita::data::lra;
use mita::data::rng::Rng;
use mita::decode::state::{recompute_attend, recompute_landmarks, recompute_members};
use mita::decode::{chunk_width, CausalMitaState, DecodeKernel};
use mita::kernels::{KernelRegistry, MitaKernelConfig, MitaStats, Workspace, OP_ATTN_MITA};
use mita::model::{MitaModel, ModelConfig, OP_MODEL_INIT};
use mita::runtime::{BackendSpec, NativeAttnConfig, Tensor};
use mita::service::{GenerateParams, ServiceRequest, ServiceResponse, StepEvent};
use mita::util::json::Value;

fn random_rows(seed: u64, n: usize, d: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

/// The heart of the subsystem: after every `append_key` + `attend`,
/// every piece of incremental state equals the full recompute from the
/// key cache — bit for bit, at all chunk boundaries and in between.
#[test]
fn incremental_state_matches_recompute_at_every_step() {
    let (n, d) = (48usize, 8usize);
    let cfg = MitaKernelConfig { m: 6, k: 4, cap_factor: 2, block_q: 8 };
    let q = random_rows(11, n, d);
    let k = random_rows(12, n, d);
    let v = random_rows(13, n, d);

    let mut st = CausalMitaState::new(n, d, &cfg);
    assert_eq!(st.width(), chunk_width(n, cfg.m));
    let mut out = vec![0.0f32; d];
    for t in 0..n {
        st.append_key(&k);
        assert_eq!(st.num_keys(), t + 1);

        let ref_landmarks = recompute_landmarks(&k, t + 1, d, n, &cfg);
        assert_eq!(st.landmarks(), &ref_landmarks[..], "landmarks diverge at step {t}");
        assert_eq!(st.num_landmarks(), ref_landmarks.len() / d);

        let ref_members = recompute_members(&k, t + 1, d, n, &cfg);
        for (c, members) in ref_members.iter().enumerate() {
            assert_eq!(&st.expert_members(c), members, "expert {c} members diverge at step {t}");
        }

        let routed = st.attend(&q[t * d..(t + 1) * d], &k, &v, &mut out);
        let (ref_routed, ref_out) = recompute_attend(&q[t * d..(t + 1) * d], &k, &v, t, d, n, &cfg);
        assert_eq!(routed, ref_routed, "routing diverges at step {t}");
        assert_eq!(out, ref_out, "attention output diverges at step {t}");
        // Before the first landmark completes no query can be routed.
        assert_eq!(routed.is_none(), t + 1 < st.width(), "routing onset at step {t}");
    }
    // Every routed query landed on a completed expert.
    let routed_total: usize = st.route_counts().iter().sum();
    assert_eq!(routed_total, n - (st.width() - 1), "all post-onset queries were routed");

    let mut stats = MitaStats::default();
    st.record_stats(&mut stats);
    assert_eq!(stats.calls, 1);
    assert_eq!(stats.queries, routed_total);
    assert_eq!(stats.overflow, 0, "the causal kernel has no capacity packing");
}

/// The batch-shaped causal kernels are registry-visible and row-for-row
/// equal to the recompute reference (MiTA) / trivially causal (dense).
#[test]
fn registry_causal_kernels_match_reference_rows() {
    let (n, d) = (24usize, 8usize);
    let cfg = MitaKernelConfig { m: 4, k: 4, cap_factor: 2, block_q: 8 };
    let registry = KernelRegistry::with_defaults(cfg);
    let names = registry.names();
    assert!(names.contains(&"mita.causal") && names.contains(&"dense.causal"), "{names:?}");

    let q = random_rows(21, n, d);
    let k = random_rows(22, n, d);
    let v = random_rows(23, n, d);
    let mut ws = Workspace::new();
    let mut stats = MitaStats::default();
    let mut out = vec![0.0f32; n * d];
    registry.get("mita.causal").unwrap().run(&q, &k, &v, n, d, &mut ws, &mut out, &mut stats);
    for t in 0..n {
        let (_, ref_out) = recompute_attend(&q[t * d..(t + 1) * d], &k, &v, t, d, n, &cfg);
        assert_eq!(&out[t * d..(t + 1) * d], &ref_out[..], "mita.causal row {t} diverges");
    }
    assert!(stats.queries > 0, "causal kernel records routing stats");

    // Causal dense: row 0 sees only key 0, so its output is exactly
    // v[0]; later rows must differ from the acausal batch kernel run.
    let mut dense_out = vec![0.0f32; n * d];
    let mut dense_stats = MitaStats::default();
    let kernel = registry.get("dense.causal").unwrap();
    kernel.run(&q, &k, &v, n, d, &mut ws, &mut dense_out, &mut dense_stats);
    assert_eq!(&dense_out[..d], &v[..d], "causal row 0 attends only itself");
    let mut again = vec![0.0f32; n * d];
    kernel.run(&q, &k, &v, n, d, &mut ws, &mut again, &mut dense_stats);
    assert_eq!(dense_out, again, "causal dense is deterministic");
    let mut acausal = vec![0.0f32; n * d];
    registry.get("attn.dense").unwrap().run(
        &q,
        &k,
        &v,
        n,
        d,
        &mut ws,
        &mut acausal,
        &mut MitaStats::default(),
    );
    assert_ne!(dense_out, acausal, "masking the upper triangle must change early rows");
}

/// Token-by-token generation through the library API: deterministic,
/// kernel-overridable, and explicit about the prefill/decode split.
#[test]
fn generation_is_deterministic_and_kernel_override_holds() {
    use mita::decode::generate::generate;
    let model =
        MitaModel::init(ModelConfig::new(13, 24, 16, 2, 2, 32, 3, OP_ATTN_MITA), 7).unwrap();
    let prompt = [2i32, 7, 4, 1];
    let mut steps: Vec<(usize, i32, u64)> = Vec::new();
    let mut record = |i: usize, t: i32, ns: u64| steps.push((i, t, ns));
    let out = generate(&model, None, &prompt, 6, &mut record).unwrap();
    assert_eq!(out.tokens.len(), prompt.len() + 6);
    assert_eq!(&out.tokens[..4], &prompt);
    assert_eq!(out.prefill_tokens, 4);
    assert_eq!(steps.len(), 6);
    assert_eq!(steps[0].2, 0, "step 0 latency is folded into the prefill pass");

    // The explicit MiTA override is the same path the model config
    // derives, so the token stream is identical.
    let mut nop = |_: usize, _: i32, _: u64| {};
    let forced = generate(&model, Some(DecodeKernel::Mita), &prompt, 6, &mut nop).unwrap();
    assert_eq!(out.tokens, forced.tokens, "explicit attn.mita override equals the derived path");

    // Dense override runs the causal-dense path on the same weights and
    // stays in-vocab; deterministic across reruns.
    let dense = generate(&model, Some(DecodeKernel::Dense), &prompt, 6, &mut nop).unwrap();
    assert!(dense.tokens[4..].iter().all(|&t| (0..13).contains(&t)));
    let dense2 = generate(&model, Some(DecodeKernel::Dense), &prompt, 6, &mut nop).unwrap();
    assert_eq!(dense.tokens, dense2.tokens);
}

const N: usize = 32;
const DIM: usize = 16;
const DEPTH: usize = 2;

/// One model-capable replica behind the network front, model bound.
fn spawn_loopback() -> (Arc<ReplicaPool>, NetClient, std::thread::JoinHandle<anyhow::Result<()>>)
{
    let task = lra::by_name("listops", N, 16, 7);
    let mcfg = ModelConfig::for_task(task.as_ref(), DIM, 2, DEPTH, "attn.mita");
    let attn = NativeAttnConfig::for_shape(N, DIM, 2).with_model(mcfg);
    let cfg =
        ReplicaPoolConfig { replicas: 1, max_inflight: 8, retry_after_ms: 1, ..Default::default() };
    let pool = Arc::new(ReplicaPool::spawn(BackendSpec::Native(attn), vec![], cfg).unwrap());
    pool.call(ServiceRequest::BindInit {
        binding: "model".into(),
        init_op: OP_MODEL_INIT.to_string(),
        seed: 7,
        param_count: 0,
    })
    .unwrap();
    let cfg = NetServerConfig { addr: "127.0.0.1:0".into(), max_inflight: 8 };
    let server = NetServer::bind(pool.clone(), &cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let join = std::thread::spawn(move || server.run());
    (pool, NetClient::new(addr.to_string()), join)
}

fn shutdown(pool: Arc<ReplicaPool>) {
    if let Ok(pool) = Arc::try_unwrap(pool) {
        pool.shutdown();
    }
}

fn span(trace: &Value, key: &str) -> f64 {
    trace.get("spans").unwrap().get(key).unwrap().as_f64().unwrap()
}

/// Chunked `/v1/generate` over real TCP: ordered step events, terminal
/// response agreement, decode metrics, and the decode trace span.
#[test]
fn loopback_generate_streams_steps_meters_and_traces() {
    let (pool, client, join) = spawn_loopback();

    let req = ServiceRequest::Generate {
        binding: "model".into(),
        prompt: Tensor::i32(&[4], vec![1, 2, 3, 4]).unwrap(),
        max_tokens: 6,
        params: GenerateParams::default(),
    };
    let mut steps: Vec<StepEvent> = Vec::new();
    let (resp, trace_id) = client.generate(&req, &mut |ev| steps.push(ev)).unwrap();
    let (tokens, prefill_tokens) = match resp {
        ServiceResponse::Generate { tokens, prefill_tokens } => (tokens, prefill_tokens),
        other => panic!("generate must answer with a Generate response, got {other:?}"),
    };
    let tokens = tokens.as_i32().unwrap().to_vec();
    assert_eq!(prefill_tokens, 4);
    assert_eq!(tokens.len(), 6, "terminal tokens are the generated suffix only");
    assert_eq!(steps.len(), 6, "one step event per generated token");
    assert!(steps.iter().enumerate().all(|(i, s)| s.index == i), "steps arrive in order");
    assert_eq!(steps[0].latency_ns, 0, "step 0 compute is the prefill tail");
    assert!(steps[1..].iter().all(|s| s.latency_ns > 0), "decode steps carry wall time");
    let streamed: Vec<i32> = steps.iter().map(|s| s.token).collect();
    assert_eq!(&tokens[..], &streamed[..], "streamed tokens equal the terminal response");
    let trace_id = trace_id.expect("terminal chunk echoes a trace id");

    // Pool-wide decode metrics: 6 tokens from 4 prompt tokens; step 0
    // never enters the latency histogram.
    let m = client.metrics().unwrap();
    assert_eq!(m.tokens_generated_total, 6);
    assert_eq!(m.prefill_tokens_total, 4);
    assert_eq!(m.decode_step_latency_us.count, 5);

    // Trace export: the generate record splits a decode span out of
    // execute, and the disjoint-stage invariant still holds.
    let body = Value::parse(&client.trace_raw(None, None).unwrap()).unwrap();
    let traces = body.get("traces").unwrap().as_arr().unwrap();
    let t = traces
        .iter()
        .find(|t| t.get("trace_id").unwrap().as_f64().unwrap() as u64 == trace_id)
        .expect("generate request was traced");
    assert_eq!(t.get("kind").unwrap().as_str().unwrap(), "generate");
    assert!(t.get("ok").unwrap().as_bool().unwrap());
    assert!(span(t, "decode_us") > 0.0, "decode span was bracketed");
    let total = span(t, "total_us");
    let staged = span(t, "admission_us")
        + span(t, "route_us")
        + span(t, "queue_us")
        + span(t, "batch_us")
        + span(t, "execute_us")
        + span(t, "decode_us");
    assert!(staged <= total + 1e-3, "stage spans ({staged}us) exceed wall time ({total}us)");

    // Pre-stream failures keep their typed error (no chunked header was
    // written): an unbound binding reports `unbound_params`.
    let bad = ServiceRequest::Generate {
        binding: "nope".into(),
        prompt: Tensor::i32(&[2], vec![1, 2]).unwrap(),
        max_tokens: 2,
        params: GenerateParams::default(),
    };
    let mut none = 0usize;
    let err = client.generate(&bad, &mut |_| none += 1).unwrap_err();
    assert_eq!(err.code(), "unbound_params", "{err:?}");
    assert_eq!(none, 0, "failed requests stream no step events");

    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    shutdown(pool);
}
