//! Multi-replica serving integration tests (docs/SERVING.md):
//!
//! - requests distribute across ≥ 2 replicas over the full TCP path,
//!   proven by the per-replica counters on `/v1/metrics`;
//! - a saturated pool sheds with the typed `overloaded` error carrying a
//!   `retry_after_ms` hint, at both admission layers (pool caps and the
//!   transport in-flight cap);
//! - responses are bit-identical to the single-replica path — placement
//!   must never change results;
//! - binds broadcast, so every replica serves the same bound model;
//! - `/v1/metrics` lists every documented series and stays readable
//!   while admission is shedding;
//! - `NetClient` retries honor the hint and exhaust to the typed error.

use std::sync::Arc;

use mita::coordinator::{
    METRIC_NAMES, NetClient, NetServer, NetServerConfig, ReplicaPool, ReplicaPoolConfig,
};
use mita::data::rng::Rng;
use mita::data::{lra, Split};
use mita::model::{ModelConfig, OP_MODEL_INIT};
use mita::runtime::{BackendSpec, NativeAttnConfig, Tensor};
use mita::service::{KernelId, QkvBatch, ServiceRequest};

const N: usize = 32;
const DIM: usize = 16;

fn attn_request(seed: u64) -> ServiceRequest {
    let mut rng = Rng::new(seed);
    let data: Vec<f32> = (0..3 * N * DIM).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    ServiceRequest::Attention {
        op: KernelId::Mita,
        qkv: QkvBatch::fused(Tensor::f32(&[1, 3, N, DIM], data).unwrap()).unwrap(),
        valid_rows: None,
    }
}

fn pool_with(replicas: usize, max_inflight: usize, model: bool) -> Arc<ReplicaPool> {
    let attn = if model {
        let task = lra::by_name("listops", N, 16, 7);
        let mcfg = ModelConfig::for_task(task.as_ref(), DIM, 2, 1, "attn.mita");
        NativeAttnConfig::for_shape(N, DIM, 2).with_model(mcfg)
    } else {
        NativeAttnConfig::for_shape(N, DIM, 2)
    };
    let cfg = ReplicaPoolConfig { replicas, max_inflight, retry_after_ms: 1, ..Default::default() };
    Arc::new(ReplicaPool::spawn(BackendSpec::Native(attn), vec![], cfg).unwrap())
}

fn shutdown(pool: Arc<ReplicaPool>) {
    // Lingering handler threads may still hold clones; their engine Drop
    // impls clean up in that case.
    if let Ok(pool) = Arc::try_unwrap(pool) {
        pool.shutdown();
    }
}

/// Pool + network server on a loopback port. `pool_cap` is the
/// per-replica admission cap, `transport_cap` the network front's
/// in-flight cap.
fn spawn_loopback(
    replicas: usize,
    pool_cap: usize,
    transport_cap: usize,
) -> (
    Arc<ReplicaPool>,
    NetClient,
    std::net::SocketAddr,
    std::thread::JoinHandle<anyhow::Result<()>>,
) {
    let pool = pool_with(replicas, pool_cap, false);
    let cfg = NetServerConfig { addr: "127.0.0.1:0".into(), max_inflight: transport_cap };
    let server = NetServer::bind(pool.clone(), &cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let join = std::thread::spawn(move || server.run());
    (pool, NetClient::new(addr.to_string()), addr, join)
}

#[test]
fn requests_distribute_across_replicas_over_tcp() {
    let (pool, client, _addr, join) = spawn_loopback(2, 8, 8);
    for i in 0..8 {
        let out = client.call(&attn_request(i)).unwrap().into_tensor().unwrap();
        assert_eq!(out.shape(), &[1, N, DIM]);
    }
    let m = client.metrics().unwrap();
    assert_eq!(m.serve_requests_total, 8);
    assert_eq!(m.serve_shed_total, 0);
    assert_eq!(m.replicas.len(), 2);
    // Sequential wire callers settle each request before sending the
    // next, so the rotating tie-break splits the stream exactly in half —
    // the per-replica counters prove traffic crossed both engines.
    assert_eq!(m.replicas[0].replica_requests_total, 4);
    assert_eq!(m.replicas[1].replica_requests_total, 4);
    assert_eq!(m.request_latency_us.count, 8);
    assert!(m.request_latency_us.p50_us > 0.0);
    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    shutdown(pool);
}

#[test]
fn saturated_pool_sheds_typed_overloaded_over_tcp() {
    // Pool caps at 0: the transport admits the request, the pool sheds it.
    let (pool, client, _addr, join) = spawn_loopback(2, 0, 8);
    let err = client.call(&attn_request(0)).unwrap_err();
    assert_eq!(err.code(), "overloaded");
    let hint = err.retry_after_ms().expect("pool sheds carry a retry hint over the wire");
    assert!(hint >= 1);
    let m = client.metrics().unwrap();
    assert_eq!(m.serve_requests_total, 1);
    assert_eq!(m.serve_shed_total, 1);
    assert!(m.shed_fraction() > 0.99);
    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    shutdown(pool);
}

#[test]
fn multi_replica_responses_bit_identical_to_single_replica() {
    let single = pool_with(1, 8, false);
    let multi = pool_with(2, 8, false);
    for seed in 0..4 {
        let a = single.call(attn_request(seed)).unwrap().into_tensor().unwrap();
        let b = multi.call(attn_request(seed)).unwrap().into_tensor().unwrap();
        assert_eq!(a, b, "replica placement must not change results (seed {seed})");
    }
    shutdown(single);
    shutdown(multi);
}

#[test]
fn bind_broadcasts_so_every_replica_serves_the_model() {
    let pool = pool_with(2, 8, true);
    pool.call(ServiceRequest::BindInit {
        binding: "model".into(),
        init_op: OP_MODEL_INIT.to_string(),
        seed: 7,
        param_count: 0,
    })
    .unwrap();
    let task = lra::by_name("listops", N, 16, 7);
    let (tokens, _) = task.sample(Split::Val, 0);
    let tokens = Tensor::i32(&[1, N], tokens).unwrap();
    let forward = |t: Tensor| ServiceRequest::ModelForward {
        binding: "model".into(),
        tokens: t,
        valid_rows: None,
    };
    // Two sequential calls land on different replicas (rotating
    // tie-break); identical logits prove the bind reached both — an
    // unbound replica would answer unbound_params instead.
    let a = pool.call(forward(tokens.clone())).unwrap().into_tensor().unwrap();
    let b = pool.call(forward(tokens)).unwrap().into_tensor().unwrap();
    assert_eq!(a, b, "both replicas answer from the same bound parameters");
    let snap = pool.snapshot();
    assert_eq!(snap.replicas[0].replica_requests_total, 1);
    assert_eq!(snap.replicas[1].replica_requests_total, 1);
    shutdown(pool);
}

#[test]
fn metrics_list_documented_series_and_bypass_admission() {
    // Transport cap 0: every service POST sheds at the transport layer...
    let (pool, client, _addr, join) = spawn_loopback(2, 4, 0);
    let err = client.call(&attn_request(0)).unwrap_err();
    assert_eq!(err.code(), "overloaded");
    assert!(err.retry_after_ms().is_some(), "transport sheds carry a retry hint too");
    // ...while the telemetry surface stays readable and complete.
    let raw = client.metrics_raw().unwrap();
    for name in METRIC_NAMES {
        assert!(raw.contains(name), "metrics payload missing documented series {name:?}");
    }
    let m = client.metrics().unwrap();
    assert_eq!(m.replicas.len(), 2);
    // The transport-layer shed was folded into the pool-wide counters.
    assert_eq!(m.serve_requests_total, 1);
    assert_eq!(m.serve_shed_total, 1);
    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    shutdown(pool);
}

#[test]
fn transport_cap_sheds_independently_of_pool_counters() {
    use std::io::{Read as _, Write as _};
    // Transport cap 1, pool cap 4: saturate the *transport* layer while
    // the pool still has plenty of room, so the shed below can only have
    // come from `record_transport_shed` — the request never reaches a
    // replica, and the per-replica counters must not move.
    let (pool, client, addr, join) = spawn_loopback(1, 4, 1);

    // Hold the single transport slot: hand-roll a service POST whose
    // declared body arrives in two halves. After the head the server
    // acquires the in-flight slot, then blocks reading the rest.
    let (path, body) = mita::service::wire::encode_request(&attn_request(1));
    let body = body.render();
    let mut raw = std::net::TcpStream::connect(addr).unwrap();
    let head = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    raw.write_all(head.as_bytes()).unwrap();
    let split = body.len() / 2;
    raw.write_all(&body.as_bytes()[..split]).unwrap();
    raw.flush().unwrap();
    // Give the handler thread a beat to parse the head and take the slot
    // (it then parks in the body read until the second half arrives).
    std::thread::sleep(std::time::Duration::from_millis(200));

    // The next service request refuses at the transport layer...
    let err = client.call(&attn_request(2)).unwrap_err();
    assert_eq!(err.code(), "overloaded");
    assert!(err.retry_after_ms().is_some());
    // ...moving the pool-wide shed counters but not the replica counters:
    // the request was never routed.
    let m = client.metrics().unwrap();
    assert_eq!(m.serve_requests_total, 1);
    assert_eq!(m.serve_shed_total, 1);
    assert_eq!(m.replicas[0].replica_requests_total, 0, "transport sheds never reach a replica");

    // Completing the held body releases the slot and the stalled request
    // executes normally — both counters tell that story apart.
    raw.write_all(&body.as_bytes()[split..]).unwrap();
    raw.flush().unwrap();
    let mut response = String::new();
    raw.read_to_string(&mut response).unwrap();
    assert!(response.contains("\"ok\":true"), "held request completes once its body lands: {response}");
    let m = client.metrics().unwrap();
    assert_eq!(m.serve_requests_total, 2);
    assert_eq!(m.serve_shed_total, 1, "completion does not re-count the shed");
    assert_eq!(m.replicas[0].replica_requests_total, 1);

    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    shutdown(pool);
}

#[test]
fn client_retries_honor_hint_then_exhaust_to_typed_overloaded() {
    let (pool, client, _addr, join) = spawn_loopback(1, 0, 8);
    let client = client.with_retries(2);
    let t0 = std::time::Instant::now();
    let err = client.call(&attn_request(0)).unwrap_err();
    assert_eq!(err.code(), "overloaded", "budget exhaustion returns the last typed error");
    assert!(err.retry_after_ms().is_some());
    // All three attempts reached the pool and were shed.
    let m = client.metrics().unwrap();
    assert_eq!(m.serve_shed_total, 3);
    // The backoff actually slept between attempts (hint floor is 1ms,
    // scaled per attempt: ≥ 3ms total; allow scheduler slack downward).
    assert!(t0.elapsed().as_millis() >= 2, "retries back off before re-sending");
    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    shutdown(pool);
}
