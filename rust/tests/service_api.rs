//! Typed service API integration tests:
//!
//! - the error taxonomy end to end — malformed wire JSON, wrong-rank
//!   tensors, unknown ops, unbound bindings, and over-capacity admission
//!   each produce their documented **stable code** (never a stringly
//!   message match);
//! - the full TCP loopback path — `NetServer` over a single-replica
//!   `ReplicaPool` on 127.0.0.1:0, the `NetClient` wire client, attention
//!   + model-forward + stats requests, and a clean `/v1/admin/shutdown`,
//!   all deterministic. (Multi-replica behavior lives in
//!   `tests/replica_pool.rs`.)

use std::sync::Arc;

use mita::coordinator::{
    Engine, NetClient, NetServer, NetServerConfig, ReplicaPool, ReplicaPoolConfig,
};
use mita::data::lra;
use mita::data::rng::Rng;
use mita::data::Split;
use mita::model::{ModelConfig, OP_MODEL_INIT};
use mita::runtime::{BackendSpec, NativeAttnConfig, Tensor};
use mita::service::wire::{self, EP_ATTENTION};
use mita::service::{BindingId, KernelId, QkvBatch, ServiceError, ServiceRequest};
use mita::util::json::Value;

fn fused_request(batch: usize, n: usize, dim: usize, valid: Option<usize>) -> ServiceRequest {
    let mut rng = Rng::new(0xA11CE);
    let data: Vec<f32> = (0..batch * 3 * n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    ServiceRequest::Attention {
        op: KernelId::Mita,
        qkv: QkvBatch::fused(Tensor::f32(&[batch, 3, n, dim], data).unwrap()).unwrap(),
        valid_rows: valid,
    }
}

/// Spawn a single-replica pool (with a tiny listops model bound under
/// "model") plus the network server on a loopback port; returns the pool,
/// the client, and the server thread handle.
fn spawn_loopback(
    max_inflight: usize,
) -> (Arc<ReplicaPool>, NetClient, std::thread::JoinHandle<anyhow::Result<()>>) {
    let task = lra::by_name("listops", 32, 16, 7);
    let mcfg = ModelConfig::for_task(task.as_ref(), 16, 2, 1, "attn.mita");
    let attn = NativeAttnConfig::for_shape(32, 16, 2).with_model(mcfg);
    let pool_cfg =
        ReplicaPoolConfig { replicas: 1, max_inflight, retry_after_ms: 5, ..Default::default() };
    let pool = Arc::new(ReplicaPool::spawn(BackendSpec::Native(attn), vec![], pool_cfg).unwrap());
    pool.call(ServiceRequest::BindInit {
        binding: "model".into(),
        init_op: OP_MODEL_INIT.to_string(),
        seed: 7,
        param_count: 0,
    })
    .unwrap();

    let cfg = NetServerConfig { addr: "127.0.0.1:0".into(), max_inflight };
    let server = NetServer::bind(pool.clone(), &cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let join = std::thread::spawn(move || server.run());
    (pool, NetClient::new(addr.to_string()), join)
}

// ---------------------------------------------------------------------------
// Error taxonomy: each documented failure produces its stable code.
// ---------------------------------------------------------------------------

#[test]
fn taxonomy_malformed_wire_json_is_bad_request() {
    // Parse failures at the wire boundary, before any backend is touched.
    for text in ["{", "", "[1,", "{\"version\": }"] {
        assert!(Value::parse(text).is_err(), "{text:?} should not parse");
    }
    // The endpoint-level parser rejects structurally-valid JSON that is
    // not a valid request, with the same stable code.
    let body = Value::parse("[1, 2, 3]").unwrap();
    let err = wire::parse_request(EP_ATTENTION, &body).unwrap_err();
    assert_eq!(err.code(), "bad_request");
}

#[test]
fn taxonomy_wrong_rank_tensor_is_bad_shape() {
    // At batch construction...
    let flat = Tensor::f32(&[6], vec![0.0; 6]).unwrap();
    assert_eq!(QkvBatch::fused(flat).unwrap_err().code(), "bad_shape");
    // ...and through the engine for requests that pass construction but
    // carry an impossible valid_rows.
    let engine = Engine::spawn_backend(
        BackendSpec::Native(NativeAttnConfig::for_shape(8, 4, 2)),
        vec![],
    )
    .unwrap();
    let err = match fused_request(2, 8, 4, Some(3)) {
        ServiceRequest::Attention { op, qkv, valid_rows } => {
            engine.handle().attention(op, qkv, valid_rows).unwrap_err()
        }
        _ => unreachable!(),
    };
    assert_eq!(err.code(), "bad_shape");
    engine.shutdown();
}

#[test]
fn taxonomy_unknown_op_and_unbound_binding() {
    let engine = Engine::spawn_backend(
        BackendSpec::Native(NativeAttnConfig::for_shape(8, 4, 2)),
        vec![],
    )
    .unwrap();
    let handle = engine.handle();

    let qkv = match fused_request(1, 8, 4, None) {
        ServiceRequest::Attention { qkv, .. } => qkv,
        _ => unreachable!(),
    };
    let err = handle.attention(KernelId::Custom("attn.flash9".into()), qkv, None).unwrap_err();
    assert_eq!(err.code(), "unknown_op");

    let tokens = Tensor::i32(&[1, 8], vec![0; 8]).unwrap();
    let err = handle.model_forward("never-bound", tokens, None).unwrap_err();
    assert_eq!(err.code(), "unbound_params");
    engine.shutdown();
}

#[test]
fn taxonomy_over_capacity_admission_is_overloaded() {
    // max_inflight = 0 rejects every request at admission, determin-
    // istically, with the overloaded code and HTTP 503 semantics.
    let (pool, client, join) = spawn_loopback(0);
    let err = client.call(&fused_request(1, 32, 16, None)).unwrap_err();
    assert_eq!(err.code(), "overloaded");
    assert!(err.retry_after_ms().is_some(), "sheds carry a retry hint over the wire");
    assert_eq!(ServiceError::overloaded("").http_status(), 503);
    // Health and shutdown are server-local: they bypass admission.
    client.healthz().unwrap();
    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    if let Ok(pool) = Arc::try_unwrap(pool) {
        pool.shutdown();
    }
}

// ---------------------------------------------------------------------------
// TCP loopback end-to-end.
// ---------------------------------------------------------------------------

#[test]
fn loopback_serves_attention_model_and_stats_then_shuts_down() {
    let (pool, client, join) = spawn_loopback(8);
    client.healthz().unwrap();

    // Attention with typed padding: [3, 32, 16] out, pad row zeroed.
    let (batch, n, dim) = (3usize, 32usize, 16usize);
    let out = client
        .call(&fused_request(batch, n, dim, Some(2)))
        .unwrap()
        .into_tensor()
        .unwrap();
    assert_eq!(out.shape(), &[batch, n, dim]);
    let data = out.as_f32().unwrap();
    assert!(data[..2 * n * dim].iter().any(|&x| x != 0.0), "real rows computed");
    assert!(data[2 * n * dim..].iter().all(|&x| x == 0.0), "pad row stays zero");

    // Model forward against the bound listops model.
    let task = lra::by_name("listops", 32, 16, 7);
    let (tokens, _) = task.sample(Split::Val, 0);
    let tokens = Tensor::i32(&[1, 32], tokens).unwrap();
    let logits = client
        .call(&ServiceRequest::ModelForward {
            binding: BindingId::from("model"),
            tokens: tokens.clone(),
            valid_rows: None,
        })
        .unwrap()
        .into_tensor()
        .unwrap();
    assert_eq!(logits.shape(), &[1, task.classes()]);
    assert!(logits.as_f32().unwrap().iter().all(|x| x.is_finite()));

    // The wire answer matches a direct engine round-trip bit for bit
    // (f32 payloads survive the JSON f64 wire format exactly).
    let direct = pool.handle(0).model_forward("model", tokens, None).unwrap();
    assert_eq!(logits, direct);

    // Stats flowed through: at least the two executions above.
    let resp = client.call(&ServiceRequest::Stats { reset: false }).unwrap();
    let stats = resp.into_stats().unwrap();
    assert!(stats.runtime.executions >= 2);
    let mita = stats.mita.expect("native backend reports routing stats");
    assert!(mita.queries > 0);

    // Typed errors survive the wire: unknown kernel → unknown_op.
    let qkv = match fused_request(1, 32, 16, None) {
        ServiceRequest::Attention { qkv, .. } => qkv,
        _ => unreachable!(),
    };
    let err = client
        .call(&ServiceRequest::Attention {
            op: KernelId::Custom("attn.flash9".into()),
            qkv,
            valid_rows: None,
        })
        .unwrap_err();
    assert_eq!(err.code(), "unknown_op");

    // Clean shutdown: the accept loop exits and the server thread joins
    // (a hung accept loop would hang this join, failing the test on the
    // harness timeout).
    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    if let Ok(pool) = Arc::try_unwrap(pool) {
        pool.shutdown();
    }
}
