//! Bit-parity gate for the runtime-dispatched SIMD lanes.
//!
//! The determinism contract (`rust/src/kernels/simd/mod.rs`) says every
//! lane — scalar, portable, AVX2, NEON — returns **bit-identical**
//! results for every dispatched primitive. This suite is the gate:
//!
//! - every primitive in the [`SimdOps`] table, swept over lengths that
//!   straddle the 8-wide chunk boundary (1, W−1, W, W+1, 1000+7) plus
//!   empty, on every lane the host can actually run;
//! - the derived softmax / attention paths under each *forced* lane
//!   (`set_lane`, the same mechanism `MITA_SIMD` uses);
//! - whole-model logits, scalar lane vs the host's auto lane.
//!
//! Comparisons are `to_bits()` equality — no tolerances anywhere.
//! Lanes unavailable on the build/CPU (e.g. AVX2 on aarch64) are simply
//! absent from `available_lanes()` and skipped; scalar and portable
//! exist everywhere, so the suite never degenerates to nothing.

use std::sync::Mutex;

use mita::data::lra;
use mita::data::rng::Rng;
use mita::data::Split;
use mita::kernels::linalg::{softmax_in_place, softmax_rows_scaled};
use mita::kernels::simd::dispatch::auto_lane;
use mita::kernels::simd::{active_lane, available_lanes, lane_table, set_lane, Lane, SimdOps, W};
use mita::kernels::{dense_attention, MitaStats, Workspace, WorkspacePool, OP_ATTN_MITA};
use mita::model::{MitaModel, ModelConfig, ModelScratch};

/// Lengths straddling every chunking edge: empty, single element, one
/// short of a chunk, exactly one chunk, one past, and a long odd tail.
const LENGTHS: [usize; 6] = [0, 1, W - 1, W, W + 1, 1007];

/// Tests that flip the process-global lane (`set_lane`) serialize here so
/// the per-table tests never observe a half-switched world.
static LANE_LOCK: Mutex<()> = Mutex::new(());

fn lane_by_name(name: &str) -> Lane {
    *Lane::ALL
        .iter()
        .find(|l| l.name() == name)
        .unwrap_or_else(|| panic!("unknown lane name {name:?}"))
}

/// Deterministic input pair with signs, magnitudes, and no NaNs.
fn vec_pair(n: usize, salt: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::derive(0x51D0, &[salt, n as u64]);
    let x = (0..n).map(|_| rng.range_f32(-3.0, 3.0)).collect();
    let y = (0..n).map(|_| rng.range_f32(-2.0, 2.0)).collect();
    (x, y)
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: bit mismatch at [{i}]: {g} vs {w}"
        );
    }
}

fn scalar_table() -> &'static SimdOps {
    lane_table(Lane::Scalar).expect("scalar lane always exists")
}

#[test]
fn reductions_bit_identical_across_all_available_lanes() {
    let s = scalar_table();
    for lane in available_lanes() {
        let t = lane_table(lane).unwrap();
        for n in LENGTHS {
            let (x, y) = vec_pair(n, 1);
            let tag = format!("{} n={n}", lane.name());
            assert_eq!((t.dot)(&x, &y).to_bits(), (s.dot)(&x, &y).to_bits(), "dot {tag}");
            assert_eq!((t.sum)(&x).to_bits(), (s.sum)(&x).to_bits(), "sum {tag}");
            assert_eq!((t.max)(&x).to_bits(), (s.max)(&x).to_bits(), "max {tag}");
            assert_eq!(
                (t.sq_dev_sum)(&x, 0.125).to_bits(),
                (s.sq_dev_sum)(&x, 0.125).to_bits(),
                "sq_dev_sum {tag}"
            );
        }
    }
}

#[test]
fn elementwise_ops_bit_identical_across_all_available_lanes() {
    let s = scalar_table();
    for lane in available_lanes() {
        let t = lane_table(lane).unwrap();
        for n in LENGTHS {
            let (x, y) = vec_pair(n, 2);
            let tag = format!("{} n={n}", lane.name());

            for alpha in [1.0f32, -0.73] {
                let mut got = y.clone();
                let mut want = y.clone();
                (t.axpy)(alpha, &x, &mut got);
                (s.axpy)(alpha, &x, &mut want);
                assert_bits_eq(&got, &want, &format!("axpy a={alpha} {tag}"));
            }

            let mut got = x.clone();
            let mut want = x.clone();
            (t.scale)(&mut got, 0.311);
            (s.scale)(&mut want, 0.311);
            assert_bits_eq(&got, &want, &format!("scale {tag}"));

            let (g, b) = vec_pair(n, 3);
            let mut got = vec![0.0f32; n];
            let mut want = vec![0.0f32; n];
            (t.norm_affine)(&x, 0.21, 1.7, &g, &b, &mut got);
            (s.norm_affine)(&x, 0.21, 1.7, &g, &b, &mut want);
            assert_bits_eq(&got, &want, &format!("norm_affine {tag}"));

            let mut got = x.clone();
            let mut want = x.clone();
            (t.gelu)(&mut got);
            (s.gelu)(&mut want);
            assert_bits_eq(&got, &want, &format!("gelu {tag}"));
        }
    }
}

#[test]
fn gather_stride_bit_identical_across_all_available_lanes() {
    let s = scalar_table();
    // Column gathers shaped like the top-k scan: n rows × m experts,
    // gathering column `off` with stride m. Covers sub-chunk, exact, and
    // odd-tail row counts and a stride of 1 (contiguous degenerate case).
    for lane in available_lanes() {
        let t = lane_table(lane).unwrap();
        for (n, m) in [(1usize, 3usize), (7, 13), (8, 13), (9, 13), (257, 31), (64, 1)] {
            let (src, _) = vec_pair(n * m, 4);
            for off in [0, m - 1, m / 2] {
                let mut got = vec![0.0f32; n];
                let mut want = vec![0.0f32; n];
                (t.gather_stride)(&src, off, m, &mut got);
                (s.gather_stride)(&src, off, m, &mut want);
                assert_bits_eq(&got, &want, &format!("gather {} n={n} m={m} off={off}", lane.name()));
            }
        }
    }
}

#[test]
fn softmax_and_dense_attention_bit_identical_under_forced_lanes() {
    let _guard = LANE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let restore = lane_by_name(active_lane());

    let (n, d) = (33, 16);
    let (q, k) = vec_pair(n * d, 5);
    let (v, logits) = vec_pair(n * d, 6);

    // Reference pass under the scalar lane.
    set_lane(Lane::Scalar);
    let mut sm_ref = logits.clone();
    softmax_rows_scaled(&mut sm_ref, n, d, 0.25);
    let mut plain_ref = logits.clone();
    softmax_in_place(&mut plain_ref);
    let mut ws = Workspace::new();
    let mut attn_ref = vec![0.0f32; n * d];
    dense_attention(&q, &k, &v, n, d, &mut ws, &mut attn_ref);

    for lane in available_lanes() {
        set_lane(lane);
        let mut sm = logits.clone();
        softmax_rows_scaled(&mut sm, n, d, 0.25);
        assert_bits_eq(&sm, &sm_ref, &format!("softmax_rows_scaled via {}", lane.name()));
        let mut plain = logits.clone();
        softmax_in_place(&mut plain);
        assert_bits_eq(&plain, &plain_ref, &format!("softmax_in_place via {}", lane.name()));
        let mut attn = vec![0.0f32; n * d];
        dense_attention(&q, &k, &v, n, d, &mut ws, &mut attn);
        assert_bits_eq(&attn, &attn_ref, &format!("dense_attention via {}", lane.name()));
    }

    set_lane(restore);
}

#[test]
fn whole_model_logits_bit_identical_scalar_vs_auto() {
    let _guard = LANE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let restore = lane_by_name(active_lane());

    let (name, n, batch) = ("listops", 64usize, 3usize);
    let vocab = lra::default_vocab(name).expect("known task");
    let task = lra::by_name(name, n, vocab, 0x51D7);
    let cfg = ModelConfig::for_task(task.as_ref(), 32, 2, 2, OP_ATTN_MITA);
    let model = MitaModel::init(cfg, 11).expect("model init");
    let registry = model.registry();
    let pool = WorkspacePool::new();
    let mut scratch = ModelScratch::default();
    let mut stats = MitaStats::default();
    let (tokens, _) = lra::batch_host(task.as_ref(), Split::Val, 0, batch);

    let run = |lane: Lane, scratch: &mut ModelScratch, stats: &mut MitaStats| {
        set_lane(lane);
        model
            .forward(&tokens, batch, batch, &registry, &pool, scratch, stats)
            .expect("forward")
    };

    let want = run(Lane::Scalar, &mut scratch, &mut stats);
    let auto = auto_lane();
    let got = run(auto, &mut scratch, &mut stats);
    assert_bits_eq(
        &got,
        &want,
        &format!("model logits: scalar vs auto ({})", auto.name()),
    );
    // And every other lane the host can run, not just auto's pick.
    for lane in available_lanes() {
        let got = run(lane, &mut scratch, &mut stats);
        assert_bits_eq(&got, &want, &format!("model logits: scalar vs {}", lane.name()));
    }

    set_lane(restore);
}
