//! End-to-end trace correctness over the full TCP path
//! (docs/OBSERVABILITY.md):
//!
//! - every traced response echoes its `trace_id` — client-supplied ids
//!   verbatim, server-allocated ids unique;
//! - `GET /v1/trace` shows those ids with non-zero stage spans, the
//!   spans are non-negative and their sum never exceeds the recorded
//!   wall time (stages are disjoint);
//! - model-forward traces carry one per-block profile per transformer
//!   block, attention traces carry none;
//! - `limit` and `min_us` filter the export as documented.
//!
//! Ring *eviction* order is pinned by the `trace.rs` unit tests (a
//! loopback eviction test would need capacity+1 = 257 engine round
//! trips); here the `pushed`/`capacity` accounting is checked instead.

use std::sync::Arc;

use mita::coordinator::{NetClient, NetServer, NetServerConfig, ReplicaPool, ReplicaPoolConfig};
use mita::data::lra;
use mita::data::rng::Rng;
use mita::data::Split;
use mita::model::{ModelConfig, OP_MODEL_INIT};
use mita::runtime::{BackendSpec, NativeAttnConfig, Tensor};
use mita::service::{wire, KernelId, QkvBatch, ServiceRequest};
use mita::util::json::Value;

const N: usize = 32;
const DIM: usize = 16;
const DEPTH: usize = 2;

fn attn_request(seed: u64) -> ServiceRequest {
    let mut rng = Rng::new(seed);
    let data: Vec<f32> = (0..3 * N * DIM).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    ServiceRequest::Attention {
        op: KernelId::Mita,
        qkv: QkvBatch::fused(Tensor::f32(&[1, 3, N, DIM], data).unwrap()).unwrap(),
        valid_rows: None,
    }
}

/// One model-capable replica behind the network front, model bound.
fn spawn_loopback() -> (Arc<ReplicaPool>, NetClient, std::thread::JoinHandle<anyhow::Result<()>>)
{
    let task = lra::by_name("listops", N, 16, 7);
    let mcfg = ModelConfig::for_task(task.as_ref(), DIM, 2, DEPTH, "attn.mita");
    let attn = NativeAttnConfig::for_shape(N, DIM, 2).with_model(mcfg);
    let cfg =
        ReplicaPoolConfig { replicas: 1, max_inflight: 8, retry_after_ms: 1, ..Default::default() };
    let pool =
        Arc::new(ReplicaPool::spawn(BackendSpec::Native(attn), vec![], cfg).unwrap());
    pool.call(ServiceRequest::BindInit {
        binding: "model".into(),
        init_op: OP_MODEL_INIT.to_string(),
        seed: 7,
        param_count: 0,
    })
    .unwrap();
    let cfg = NetServerConfig { addr: "127.0.0.1:0".into(), max_inflight: 8 };
    let server = NetServer::bind(pool.clone(), &cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let join = std::thread::spawn(move || server.run());
    (pool, NetClient::new(addr.to_string()), join)
}

fn shutdown(pool: Arc<ReplicaPool>) {
    if let Ok(pool) = Arc::try_unwrap(pool) {
        pool.shutdown();
    }
}

fn span(trace: &Value, key: &str) -> f64 {
    trace.get("spans").unwrap().get(key).unwrap().as_f64().unwrap()
}

#[test]
fn traces_echo_ids_and_export_consistent_spans() {
    let (pool, client, join) = spawn_loopback();

    // Client-supplied trace ids (well above anything the allocator hands
    // out in this process) come back verbatim in each response body.
    let explicit: Vec<u64> = vec![900_001, 900_002, 900_003];
    for (i, &id) in explicit.iter().enumerate() {
        let (path, body) = wire::encode_request(&attn_request(i as u64));
        let body = wire::with_trace_id(body, id);
        let (status, text) = client.http_raw("POST", path, &body.render()).unwrap();
        assert_eq!(status, 200, "{text}");
        assert!(
            text.contains(&format!("\"trace_id\":{id}")),
            "response must echo the supplied trace id {id}: {text}"
        );
    }

    // Server-allocated ids: a model forward (per-block profiles) and an
    // untagged attention request.
    let task = lra::by_name("listops", N, 16, 7);
    let (tokens, _) = task.sample(Split::Val, 0);
    let tokens = Tensor::i32(&[1, N], tokens).unwrap();
    client
        .call(&ServiceRequest::ModelForward {
            binding: "model".into(),
            tokens,
            valid_rows: None,
        })
        .unwrap();
    client.call(&attn_request(9)).unwrap();

    let body = Value::parse(&client.trace_raw(None, None).unwrap()).unwrap();
    let traces = body.get("traces").unwrap().as_arr().unwrap();
    assert_eq!(traces.len(), 5, "all five compute requests were traced");
    assert_eq!(body.get("pushed").unwrap().as_f64().unwrap() as u64, 5);
    assert!(body.get("capacity").unwrap().as_f64().unwrap() as usize >= 5);

    // Ids are unique and include every client-supplied one.
    let mut ids: Vec<u64> =
        traces.iter().map(|t| t.get("trace_id").unwrap().as_f64().unwrap() as u64).collect();
    ids.sort_unstable();
    let mut deduped = ids.clone();
    deduped.dedup();
    assert_eq!(ids, deduped, "trace ids must be unique");
    for id in &explicit {
        assert!(ids.contains(id), "supplied id {id} missing from /v1/trace export");
    }

    // Stage spans: non-negative, execute non-zero, and (stages being
    // disjoint) their sum never exceeds the recorded wall time. The
    // small epsilon absorbs ns → us float rounding.
    for t in traces {
        assert!(t.get("ok").unwrap().as_bool().unwrap());
        let total = span(t, "total_us");
        let staged = span(t, "admission_us")
            + span(t, "route_us")
            + span(t, "queue_us")
            + span(t, "batch_us")
            + span(t, "execute_us");
        assert!(total > 0.0, "traced request has wall time");
        assert!(span(t, "execute_us") > 0.0, "backend execute was bracketed");
        assert!(
            staged <= total + 1e-3,
            "stage spans ({staged}us) exceed wall time ({total}us)"
        );
        let blocks = t.get("blocks").unwrap().as_arr().unwrap();
        match t.get("kind").unwrap().as_str().unwrap() {
            "model_forward" => {
                assert_eq!(blocks.len(), DEPTH, "one profile per transformer block");
                for b in blocks {
                    assert!(b.get("attn_us").unwrap().as_f64().unwrap() > 0.0);
                    assert!(b.get("queries").unwrap().as_f64().unwrap() > 0.0);
                }
            }
            _ => assert!(blocks.is_empty(), "non-model traces carry no block profiles"),
        }
    }

    // `limit` caps the export newest-first; `min_us` filters out
    // everything when set absurdly high.
    let body = Value::parse(&client.trace_raw(Some(2), None).unwrap()).unwrap();
    assert_eq!(body.get("traces").unwrap().as_arr().unwrap().len(), 2);
    let body = Value::parse(&client.trace_raw(None, Some(u64::MAX / 2)).unwrap()).unwrap();
    assert!(body.get("traces").unwrap().as_arr().unwrap().is_empty());

    client.shutdown().unwrap();
    join.join().unwrap().unwrap();
    shutdown(pool);
}
