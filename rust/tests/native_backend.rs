//! Native-backend correctness — all artifact-free, so CI exercises the
//! full stack on plain runners:
//!
//! - parity: native MiTA forward vs the dense baseline in the degenerate
//!   full-attention configuration (m = n, k = n);
//! - routing/packing invariants of the kernel vs `mita::routing` directly;
//! - an independent per-query reference (f64 softmax over the routed
//!   expert's gathered KV) that ignores capacity packing entirely, pinning
//!   the expert-grouped execution + overflow machinery;
//! - the batched (example × head) dispatch vs the serial per-sequence
//!   kernels, bit-for-bit, plus workspace-pool reuse across thread counts
//!   and padding-row short-circuiting;
//! - the engine + serving integration over `BackendSpec::Native`.

use std::time::Duration;

use mita::coordinator::batcher::BatchPolicy;
use mita::coordinator::server::{serve_native, NativeServeConfig, DEFAULT_MAX_INFLIGHT};
use mita::coordinator::Engine;
use mita::data::rng::Rng;
use mita::kernels::linalg::{matmul_nt, scale_in_place};
use mita::kernels::{
    dense_attention, dense_attention_mh, mita_attention, mita_attention_mh, MitaKernelConfig,
    MitaStats, Workspace,
};
use mita::mita::routing;
use mita::runtime::backend::{OP_ATTN_DENSE, OP_ATTN_MITA};
use mita::runtime::{BackendSpec, NativeAttnConfig, NativeBackend, Tensor};
use mita::service::{KernelId, QkvBatch};
use mita::util::prop::run_prop;

fn rand_vec(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| rng.range_f32(lo, hi)).collect()
}

fn fused_qkv(bsz: usize, n: usize, dim: usize, data: Vec<f32>) -> QkvBatch {
    QkvBatch::fused(Tensor::f32(&[bsz, 3, n, dim], data).unwrap()).unwrap()
}

// ---------------------------------------------------------------------------
// Parity with the dense baseline (degenerate full-attention case).
// ---------------------------------------------------------------------------

#[test]
fn prop_degenerate_mita_equals_dense() {
    run_prop(30, |g| {
        let n = g.usize_in(1, 80);
        let d = g.usize_in(1, 24);
        let q = g.vec_f32(n * d, -2.0, 2.0);
        let k = g.vec_f32(n * d, -2.0, 2.0);
        let v = g.vec_f32(n * d, -2.0, 2.0);
        let cfg = MitaKernelConfig {
            m: n,
            k: n,
            cap_factor: g.usize_in(1, 3),
            block_q: [1, 8, 16][g.usize_in(0, 2)],
        };
        let mut ws = Workspace::new();
        let mut got = vec![0.0f32; n * d];
        let mut stats = MitaStats::default();
        mita_attention(&q, &k, &v, n, d, &cfg, &mut ws, &mut got, &mut stats);
        let mut want = vec![0.0f32; n * d];
        dense_attention(&q, &k, &v, n, d, &mut ws, &mut want);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "n={n} d={d} elem {i}: {a} vs {b}");
        }
    });
}

// ---------------------------------------------------------------------------
// Kernel-internal landmark scores match routing::scores.
// ---------------------------------------------------------------------------

#[test]
fn prop_blocked_scores_match_routing_scores() {
    run_prop(60, |g| {
        let n = g.usize_in(1, 96);
        let m = g.usize_in(1, 16);
        let d = g.usize_in(1, 32);
        let k = g.vec_f32(n * d, -2.0, 2.0);
        let lands = g.vec_f32(m * d, -2.0, 2.0);
        let want = routing::scores(&k, &lands, n, d, m);
        let mut got = vec![0.0f32; n * m];
        matmul_nt(&k, &lands, n, m, d, &mut got);
        scale_in_place(&mut got, 1.0 / (d as f32).sqrt());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "elem {i}: {a} vs {b}");
        }
    });
}

// ---------------------------------------------------------------------------
// Routing/packing invariants: the kernel's stats must be exactly what
// mita::routing computes on the same inputs.
// ---------------------------------------------------------------------------

#[test]
fn prop_kernel_routing_matches_routing_module() {
    run_prop(40, |g| {
        let n = g.usize_in(1, 120);
        let d = g.usize_in(1, 16);
        let m = g.usize_in(1, n.min(8));
        let kk = g.usize_in(1, n);
        let cap_factor = g.usize_in(1, 2);
        let block_q = [1, 4, 16][g.usize_in(0, 2)];
        let q = g.vec_f32(n * d, -2.0, 2.0);
        let k = g.vec_f32(n * d, -2.0, 2.0);
        let v = g.vec_f32(n * d, -2.0, 2.0);
        let cfg = MitaKernelConfig { m, k: kk, cap_factor, block_q };
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; n * d];
        let mut stats = MitaStats::default();
        mita_attention(&q, &k, &v, n, d, &cfg, &mut ws, &mut out, &mut stats);

        let lands = routing::landmarks_pool1d(&q, n, d, m);
        let assign = routing::route_argmax(&q, &lands, n, d, m);
        let cap = routing::capacity(n, m, cap_factor, block_q);
        let pack = routing::pack_by_expert(&assign, m, cap);
        assert_eq!(stats.cap, cap);
        assert_eq!(stats.overflow, pack.overflow);
        assert_eq!(stats.expert_counts, pack.counts);
        assert_eq!(stats.queries, n);
        assert_eq!(stats.calls, 1);
    });
}

// ---------------------------------------------------------------------------
// Independent per-query reference: same discrete routing decisions, f64
// attention math, no packing — catches any grouping/overflow bug.
// ---------------------------------------------------------------------------

fn ref_query_output(qrow: &[f32], picks: &[usize], k: &[f32], v: &[f32], d: usize) -> Vec<f64> {
    let scale = 1.0 / (d as f64).sqrt();
    let logits: Vec<f64> = picks
        .iter()
        .map(|&ki| {
            let krow = &k[ki * d..(ki + 1) * d];
            let dot: f64 = qrow.iter().zip(krow).map(|(a, b)| *a as f64 * *b as f64).sum();
            dot * scale
        })
        .collect();
    let mx = logits.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let ps: Vec<f64> = logits.iter().map(|l| (l - mx).exp()).collect();
    let den: f64 = ps.iter().sum();
    let mut out = vec![0.0f64; d];
    for (p, &ki) in ps.iter().zip(picks) {
        let vrow = &v[ki * d..(ki + 1) * d];
        for (o, x) in out.iter_mut().zip(vrow) {
            *o += p / den * *x as f64;
        }
    }
    out
}

#[test]
fn prop_every_query_matches_unpacked_reference() {
    run_prop(30, |g| {
        let n = g.usize_in(2, 64);
        let d = g.usize_in(1, 12);
        let m = g.usize_in(1, n.min(6));
        let kk = g.usize_in(1, n);
        // Tiny capacities so the overflow fallback path is hit often.
        let cfg = MitaKernelConfig { m, k: kk, cap_factor: 1, block_q: 1 };
        let q = g.vec_f32(n * d, -2.0, 2.0);
        let k = g.vec_f32(n * d, -2.0, 2.0);
        let v = g.vec_f32(n * d, -2.0, 2.0);
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; n * d];
        let mut stats = MitaStats::default();
        mita_attention(&q, &k, &v, n, d, &cfg, &mut ws, &mut out, &mut stats);

        // Reconstruct the kernel's discrete decisions with the same shared
        // routing functions (scores via the same blocked matmul).
        let lands = routing::landmarks_pool1d(&q, n, d, m);
        let mut s = vec![0.0f32; n * m];
        matmul_nt(&k, &lands, n, m, d, &mut s);
        scale_in_place(&mut s, 1.0 / (d as f32).sqrt());
        let topk = routing::topk_indices(&s, n, m, kk);
        let assign = routing::route_argmax(&q, &lands, n, d, m);

        for qi in 0..n {
            let picks = &topk[assign[qi] * kk..(assign[qi] + 1) * kk];
            let want = ref_query_output(&q[qi * d..(qi + 1) * d], picks, &k, &v, d);
            for c in 0..d {
                let got = out[qi * d + c] as f64;
                assert!(
                    (got - want[c]).abs() < 1e-4,
                    "query {qi} col {c}: {got} vs {} (n={n} m={m} k={kk})",
                    want[c]
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Batched (example × head) dispatch vs the serial per-sequence kernels —
// the decomposition must be bit-for-bit identical.
// ---------------------------------------------------------------------------

#[test]
fn batched_dispatch_matches_per_sequence_kernels() {
    let mut rng = Rng::new(77);
    for (bsz, n, dim, heads) in [(5, 24, 16, 2), (3, 17, 12, 1), (2, 33, 24, 3)] {
        let per = n * dim;
        let data = rand_vec(&mut rng, bsz * 3 * per, -2.0, 2.0);
        let fused = fused_qkv(bsz, n, dim, data.clone());
        let attn = NativeAttnConfig::for_shape(n, dim, heads);
        let cfg = attn.mita;
        let backend = NativeBackend::new(attn);

        let got_mita = backend.run_attention(&KernelId::Mita, &fused, None).unwrap();
        let got_dense = backend.run_attention(&KernelId::Dense, &fused, None).unwrap();
        assert_eq!(got_mita.shape(), &[bsz, n, dim]);

        let mut ws = Workspace::new();
        let mut stats = MitaStats::default();
        let mut want_mita = vec![0.0f32; bsz * per];
        let mut want_dense = vec![0.0f32; bsz * per];
        for i in 0..bsz {
            let ex = &data[i * 3 * per..(i + 1) * 3 * per];
            let (q, k, v) = (&ex[..per], &ex[per..2 * per], &ex[2 * per..]);
            let out_ex = &mut want_mita[i * per..(i + 1) * per];
            mita_attention_mh(q, k, v, n, heads, dim, &cfg, &mut ws, out_ex, &mut stats);
            let out_ex = &mut want_dense[i * per..(i + 1) * per];
            dense_attention_mh(q, k, v, n, heads, dim, &mut ws, out_ex);
        }
        assert_eq!(
            got_mita.as_f32().unwrap(),
            &want_mita[..],
            "mita batched != serial (b={bsz} n={n} dim={dim} heads={heads})"
        );
        assert_eq!(
            got_dense.as_f32().unwrap(),
            &want_dense[..],
            "dense batched != serial (b={bsz} n={n} dim={dim} heads={heads})"
        );

        // The backend recorded exactly the serial path's routing totals.
        let bstats = backend.mita_stats();
        assert_eq!(bstats.queries, stats.queries);
        assert_eq!(bstats.overflow, stats.overflow);
        assert_eq!(bstats.calls, bsz * heads);
    }
}

// ---------------------------------------------------------------------------
// Workspace-pool reuse: steady state creates no new workspaces, under both
// single-threaded and multi-threaded scheduling. Worker counts are driven
// with explicit scoped threads (mutating MITA_NUM_THREADS from a test
// would race other tests' getenv calls); the CI job that exports
// MITA_NUM_THREADS=1 additionally pins the whole suite — including the
// backend test below — to single-threaded dispatch.
// ---------------------------------------------------------------------------

#[test]
fn workspace_pool_reuse_under_explicit_thread_counts() {
    let items_total = 12usize;
    for threads in [1usize, 4] {
        let pool = mita::kernels::WorkspacePool::new();
        // One "dispatch round": acquire per work item, exactly like
        // run_batched's workers, spread over `threads` workers.
        let round = |pool: &mita::kernels::WorkspacePool| {
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(|| {
                        for _ in 0..items_total / threads {
                            let mut pooled = pool.acquire();
                            let (ws, stats) = pooled.parts();
                            let buf = ws.take_f32("item.q", 64);
                            ws.give_f32("item.q", buf);
                            stats.record(4, 0, &[1]);
                        }
                    });
                }
            });
        };
        for _ in 0..4 {
            round(&pool);
        }
        // created() counts the peak concurrent demand ever seen, so across
        // 4 rounds × items_total acquires the workspace-per-worker bound is
        // exactly the reuse property (without reuse it would approach the
        // total acquire count).
        let created = pool.created();
        assert!(created >= 1, "pool must materialize workspaces");
        assert!(
            created <= threads,
            "at most one workspace per worker (created {created}, threads {threads})"
        );
        assert_eq!(pool.idle(), created, "all workspaces returned after joins");
        let mut stats = MitaStats::default();
        pool.collect_stats(&mut stats);
        assert_eq!(stats.queries, 4 * items_total, "every work item recorded once");
    }
}

#[test]
fn backend_reuses_pooled_workspaces_in_steady_state() {
    // Ambient thread count (the MITA_NUM_THREADS=1 CI pass pins this to
    // one worker; the default pass exercises the parallel scheduler).
    let (bsz, n, dim, heads) = (3usize, 32usize, 16usize, 4usize);
    let mut rng = Rng::new(12);
    let data = rand_vec(&mut rng, bsz * 3 * n * dim, -1.0, 1.0);
    let fused = fused_qkv(bsz, n, dim, data);
    let backend = NativeBackend::new(NativeAttnConfig::for_shape(n, dim, heads));

    for _ in 0..4 {
        backend.run_attention(&KernelId::Mita, &fused, None).unwrap();
        backend.run_attention(&KernelId::Dense, &fused, None).unwrap();
    }
    // created() is the peak concurrent-acquire count: staying within the
    // work-item bound across 8 runs × 12 items proves pooled reuse
    // (without reuse it would track the total acquire count, 96).
    let created = backend.workspace_pool().created();
    assert!(created >= 1, "pool must materialize workspaces");
    assert!(created <= bsz * heads, "never more workspaces than concurrent work items");
    assert_eq!(backend.workspace_pool().idle(), created, "all returned between runs");
}

// ---------------------------------------------------------------------------
// Engine + serving integration over the native backend.
// ---------------------------------------------------------------------------

#[test]
fn engine_native_backend_runs_attention_requests() {
    let (n, dim, heads) = (32, 16, 2);
    let attn = NativeAttnConfig::for_shape(n, dim, heads);
    let mut rng = Rng::new(40);
    let fused = fused_qkv(1, n, dim, rand_vec(&mut rng, 3 * n * dim, -1.0, 1.0));

    // Direct backend call is the reference for the engine round-trip.
    let backend = NativeBackend::new(attn.clone());
    let want = backend.run_attention(&KernelId::Mita, &fused, None).unwrap();

    let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![OP_ATTN_MITA.into()])
        .expect("native engine");
    let handle = engine.handle();
    let got = handle.attention(KernelId::Mita, fused.clone(), None).unwrap();
    assert_eq!(got, want);
    assert_eq!(got.shape(), &[1, n, dim]);

    let dense = handle.attention(KernelId::Dense, fused.clone(), None).unwrap();
    assert_eq!(dense.shape(), &[1, n, dim]);

    // Stats flow through the engine thread: one MiTA run of `heads` work
    // items routed n queries each (the dense run adds none).
    let stats = handle.backend_stats().unwrap();
    assert_eq!(stats.runtime.executions, 2);
    let mita = stats.mita.expect("native backend reports mita stats");
    assert_eq!(mita.calls, heads);
    assert_eq!(mita.queries, heads * n);

    // Failures keep their typed codes through the engine round-trip.
    let err = handle
        .attention(KernelId::Custom("attn.predict".into()), fused.clone(), None)
        .unwrap_err();
    assert_eq!(err.code(), "unknown_op");
    let err = handle.attention(KernelId::Mita, fused, Some(9)).unwrap_err();
    assert_eq!(err.code(), "bad_shape");
    let err = handle
        .run_artifact("predict", Some("weights"), vec![Tensor::scalar_i32(0)])
        .unwrap_err();
    assert_eq!(err.code(), "unavailable");
    let err = handle.bind_init("w", "init", 0, 4).unwrap_err();
    assert_eq!(err.code(), "unknown_op");
    engine.shutdown();
}

#[test]
fn native_serving_closed_loop_completes_all_requests() {
    let attn = NativeAttnConfig::for_shape(64, 16, 2);
    let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![]).unwrap();
    for op in [OP_ATTN_MITA, OP_ATTN_DENSE] {
        let cfg = NativeServeConfig {
            n: 64,
            dim: 16,
            op: op.to_string(),
            requests: 24,
            rate: 0.0,
            queue_cap: 64,
            max_inflight: DEFAULT_MAX_INFLIGHT,
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
        };
        let report = serve_native(&engine.handle(), &cfg).unwrap();
        assert_eq!(report.completed, 24, "op {op}");
        assert_eq!(report.rejected, 0);
        assert!(report.throughput_rps > 0.0);
        assert!(report.batches >= 6); // 24 requests / max_batch 4
        assert!(report.p50_ms <= report.p99_ms + 1e-9);
        // The split histograms are populated and consistent with the
        // end-to-end latency: queue-wait and execute each bound the total.
        assert!(report.queue_p50_ms >= 0.0 && report.exec_p50_ms > 0.0);
        assert!(report.queue_p50_ms <= report.p99_ms + 1e-9);
        assert!(report.exec_p50_ms <= report.p99_ms + 1e-9);
        assert!(report.row().contains("qwait=") && report.row().contains("exec="));

        // Per-run routing stats ride along in the report; padded batch
        // slots are marked and never computed, so a MiTA run routes
        // exactly completed · heads · n queries — no more, no less.
        let mita = report.mita.as_ref().expect("native serve reports mita stats");
        if op == OP_ATTN_MITA {
            assert_eq!(mita.queries, 24 * 2 * 64, "pad rows must never reach the kernels");
            assert!(mita.overflow <= mita.queries);
            assert!(report.row().contains("ovf="));
        } else {
            assert_eq!(mita.queries, 0, "dense runs record no routing work");
        }
    }
    engine.shutdown();
}

#[test]
fn native_serving_open_loop_backpressure() {
    let attn = NativeAttnConfig::for_shape(128, 32, 4);
    let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![]).unwrap();
    let cfg = NativeServeConfig {
        n: 128,
        dim: 32,
        op: OP_ATTN_MITA.to_string(),
        requests: 100,
        rate: 50_000.0,
        queue_cap: 4,
        max_inflight: 2,
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
    };
    let report = serve_native(&engine.handle(), &cfg).unwrap();
    assert_eq!(report.completed + report.rejected, 100);
    assert!(report.completed > 0);
    // Every completed request was computed: the stats cover exactly the
    // completed ones (4 heads × n queries each).
    let mita = report.mita.expect("native serve reports mita stats");
    assert_eq!(mita.queries, report.completed * 4 * 128);
    engine.shutdown();
}
