//! Native training subsystem integration tests.
//!
//! Three layers of guarantees:
//!
//! 1. **Gradient checks** — every analytic backward (LayerNorm, GELU,
//!    linear, softmax cross-entropy, dense attention, MiTA attention,
//!    and the whole model end to end) is compared against central finite
//!    differences (f64 quotient, relative tolerance 1e-3). The MiTA
//!    kernel is checked under its straight-through convention: the
//!    numeric side evaluates a *frozen-selection* forward (top-k picks
//!    and argmax routing captured at the unperturbed point), because the
//!    analytic backward deliberately treats those selections as
//!    constants. The frozen config forces capacity overflow so the
//!    fallback-served queries' gradients are exercised too.
//! 2. **Training end to end** — 100 AdamW steps on a tiny LRA text task
//!    reduce the loss on-average for both `attn.mita` and `attn.dense`
//!    blocks.
//! 3. **Checkpoint round-trip** — a trained model saved through the
//!    shared container reloads via `NativeBackend`/`BindCheckpoint` and
//!    serves logits that match the trainer's own eval forward exactly.

use mita::coordinator::checkpoint;
use mita::data::lra;
use mita::data::rng::Rng;
use mita::data::Split;
use mita::kernels::linalg::{dot, matmul_nt, softmax_in_place};
use mita::kernels::{
    dense_attention, mita_attention, MitaKernelConfig, MitaStats, Workspace, WorkspacePool,
    OP_ATTN_DENSE, OP_ATTN_MITA,
};
use mita::mita::routing;
use mita::model::{MitaModel, ModelConfig, ModelScratch};
use mita::runtime::{Backend, NativeAttnConfig, NativeBackend, Tensor};
use mita::service::{BindingId, ServiceRequest};
use mita::train::backward::{
    bias_grad_acc, dense_attention_backward, gelu_backward, gelu_forward, layer_norm_backward,
    layer_norm_forward, matmul_nn, matmul_tn_acc, mita_attention_backward, softmax_xent,
};
use mita::train::gradcheck::{check, CheckOpts};
use mita::train::grads::{flatten_params, load_flat};
use mita::train::{
    loss_and_gradients, AdamWConfig, Gradients, NativeTrainer, TrainConfig, TrainScratch,
};

fn rand_vec(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| rng.range_f32(lo, hi)).collect()
}

/// Scalar loss used by the layer-level checks: a fixed random projection
/// of the layer output, accumulated in f64.
fn project(out: &[f32], c: &[f32]) -> f64 {
    out.iter().zip(c).map(|(&o, &w)| o as f64 * w as f64).sum()
}

// ---------------------------------------------------------------------------
// Layer-level gradient checks
// ---------------------------------------------------------------------------

#[test]
fn gradcheck_layer_norm() {
    let (rows, d) = (3usize, 5usize);
    let mut rng = Rng::new(101);
    let x = rand_vec(&mut rng, rows * d, -1.5, 1.5);
    let g = rand_vec(&mut rng, d, 0.5, 1.5);
    let b = rand_vec(&mut rng, d, -0.5, 0.5);
    let c = rand_vec(&mut rng, rows * d, -1.0, 1.0);

    let mut dx = vec![0.0f32; rows * d];
    let mut dg = vec![0.0f32; d];
    let mut db = vec![0.0f32; d];
    layer_norm_backward(&x, d, &g, &c, &mut dx, &mut dg, &mut db);

    let mut out = vec![0.0f32; rows * d];
    let mut fx = |xs: &[f32]| {
        layer_norm_forward(xs, d, &g, &b, &mut out);
        project(&out, &c)
    };
    check("layer_norm/dx", &x, &dx, &CheckOpts::default(), &mut fx).unwrap();

    let mut fg = |gs: &[f32]| {
        layer_norm_forward(&x, d, gs, &b, &mut out);
        project(&out, &c)
    };
    check("layer_norm/dg", &g, &dg, &CheckOpts::default(), &mut fg).unwrap();

    let mut fb = |bs: &[f32]| {
        layer_norm_forward(&x, d, &g, bs, &mut out);
        project(&out, &c)
    };
    check("layer_norm/db", &b, &db, &CheckOpts::default(), &mut fb).unwrap();
}

#[test]
fn gradcheck_gelu() {
    let mut rng = Rng::new(102);
    let x = rand_vec(&mut rng, 24, -3.0, 3.0);
    let c = rand_vec(&mut rng, 24, -1.0, 1.0);
    let mut dx = vec![0.0f32; 24];
    gelu_backward(&x, &c, &mut dx);
    let mut f = |xs: &[f32]| {
        let mut out = xs.to_vec();
        gelu_forward(&mut out);
        project(&out, &c)
    };
    check("gelu/dx", &x, &dx, &CheckOpts::default(), &mut f).unwrap();
}

#[test]
fn gradcheck_linear() {
    // y = x·Wᵀ + b for x [n, din], W [dout, din] — the projection shape
    // every matmul in the model uses.
    let (n, din, dout) = (4usize, 3usize, 5usize);
    let mut rng = Rng::new(103);
    let x = rand_vec(&mut rng, n * din, -1.0, 1.0);
    let w = rand_vec(&mut rng, dout * din, -1.0, 1.0);
    let b = rand_vec(&mut rng, dout, -0.5, 0.5);
    let c = rand_vec(&mut rng, n * dout, -1.0, 1.0);

    // Analytic: dx = c·W, dW += cᵀ·x, db += Σ rows of c.
    let mut dx = vec![0.0f32; n * din];
    matmul_nn(&c, &w, n, dout, din, &mut dx);
    let mut dw = vec![0.0f32; dout * din];
    matmul_tn_acc(&c, &x, n, dout, din, &mut dw);
    let mut db = vec![0.0f32; dout];
    bias_grad_acc(&c, &mut db);

    let forward = |xs: &[f32], ws: &[f32], bs: &[f32]| -> f64 {
        let mut y = vec![0.0f32; n * dout];
        matmul_nt(xs, ws, n, dout, din, &mut y);
        for row in y.chunks_exact_mut(dout) {
            for (v, &bc) in row.iter_mut().zip(bs) {
                *v += bc;
            }
        }
        project(&y, &c)
    };
    let mut fx = |xs: &[f32]| forward(xs, &w, &b);
    check("linear/dx", &x, &dx, &CheckOpts::default(), &mut fx).unwrap();
    let mut fw = |ws: &[f32]| forward(&x, ws, &b);
    check("linear/dw", &w, &dw, &CheckOpts::default(), &mut fw).unwrap();
    let mut fb = |bs: &[f32]| forward(&x, &w, bs);
    check("linear/db", &b, &db, &CheckOpts::default(), &mut fb).unwrap();
}

#[test]
fn gradcheck_softmax_xent() {
    let mut rng = Rng::new(104);
    let logits = rand_vec(&mut rng, 6, -2.0, 2.0);
    let mut dlogits = vec![0.0f32; 6];
    let label = 3usize;
    softmax_xent(&logits, label, &mut dlogits);
    let mut f = |ls: &[f32]| mita::train::backward::softmax_xent_loss(ls, label);
    check("softmax_xent/dlogits", &logits, &dlogits, &CheckOpts::default(), &mut f).unwrap();
}

#[test]
fn gradcheck_dense_attention() {
    let (n, d) = (7usize, 4usize);
    let mut rng = Rng::new(105);
    let q = rand_vec(&mut rng, n * d, -1.0, 1.0);
    let k = rand_vec(&mut rng, n * d, -1.0, 1.0);
    let v = rand_vec(&mut rng, n * d, -1.0, 1.0);
    let c = rand_vec(&mut rng, n * d, -1.0, 1.0);

    let mut ws = Workspace::new();
    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dv = vec![0.0f32; n * d];
    dense_attention_backward(&q, &k, &v, n, d, &c, &mut ws, &mut dq, &mut dk, &mut dv);

    let mut ws2 = Workspace::new();
    let mut out = vec![0.0f32; n * d];
    let mut fq = |qs: &[f32]| {
        dense_attention(qs, &k, &v, n, d, &mut ws2, &mut out);
        project(&out, &c)
    };
    check("dense_attn/dq", &q, &dq, &CheckOpts::default(), &mut fq).unwrap();
    let mut fk = |ks: &[f32]| {
        dense_attention(&q, ks, &v, n, d, &mut ws2, &mut out);
        project(&out, &c)
    };
    check("dense_attn/dk", &k, &dk, &CheckOpts::default(), &mut fk).unwrap();
    let mut fv = |vs: &[f32]| {
        dense_attention(&q, &k, vs, n, d, &mut ws2, &mut out);
        project(&out, &c)
    };
    check("dense_attn/dv", &v, &dv, &CheckOpts::default(), &mut fv).unwrap();
}

// ---------------------------------------------------------------------------
// MiTA gradient check (straight-through, with overflow exercised)
// ---------------------------------------------------------------------------

/// The forward's selection structure at one input point, captured with
/// the same `mita::routing` functions the kernel calls.
struct FrozenSelection {
    kk: usize,
    topk: Vec<usize>,
    assign: Vec<usize>,
}

fn capture_selection(
    q: &[f32],
    k: &[f32],
    n: usize,
    d: usize,
    cfg: &MitaKernelConfig,
) -> FrozenSelection {
    let (m, kk) = (cfg.m, cfg.k);
    let landmarks = routing::landmarks_pool1d(q, n, d, m);
    let s = routing::scores(k, &landmarks, n, d, m);
    let topk = routing::topk_indices(&s, n, m, kk);
    let assign = routing::route_argmax(q, &landmarks, n, d, m);
    FrozenSelection { kk, topk, assign }
}

/// MiTA forward with the selection held constant: each query attends its
/// frozen expert's frozen picks. This is exactly the function the
/// straight-through backward differentiates.
fn mita_frozen_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    sel: &FrozenSelection,
) -> Vec<f32> {
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; n * d];
    let mut logits = vec![0.0f32; sel.kk];
    for qi in 0..n {
        let picks = &sel.topk[sel.assign[qi] * sel.kk..(sel.assign[qi] + 1) * sel.kk];
        let qrow = &q[qi * d..(qi + 1) * d];
        for (l, &ki) in logits.iter_mut().zip(picks) {
            *l = dot(qrow, &k[ki * d..(ki + 1) * d]) * scale;
        }
        softmax_in_place(&mut logits);
        let orow = &mut out[qi * d..(qi + 1) * d];
        for (&w, &ki) in logits.iter().zip(picks) {
            for (o, &vv) in orow.iter_mut().zip(&v[ki * d..(ki + 1) * d]) {
                *o += w * vv;
            }
        }
    }
    out
}

#[test]
fn gradcheck_mita_attention_frozen_selection_with_overflow() {
    // cap = ceil(18/3)·1 = 6 slots per expert; clustering 12 queries near
    // one point overloads their expert and forces the overflow fallback.
    let (n, d) = (18usize, 4usize);
    let cfg = MitaKernelConfig { m: 3, k: 5, cap_factor: 1, block_q: 1 };
    let mut rng = Rng::new(106);
    let mut q = rand_vec(&mut rng, n * d, -1.0, 1.0);
    let base = rand_vec(&mut rng, d, 0.5, 1.5);
    for qi in 0..12 {
        for c in 0..d {
            q[qi * d + c] = base[c] + rng.range_f32(-0.05, 0.05);
        }
    }
    let k = rand_vec(&mut rng, n * d, -1.0, 1.0);
    let v = rand_vec(&mut rng, n * d, -1.0, 1.0);
    let c = rand_vec(&mut rng, n * d, -1.0, 1.0);

    // The real forward must overflow, and must agree with the frozen
    // forward at the unperturbed point (packing only reorders work).
    let mut ws = Workspace::new();
    let mut out = vec![0.0f32; n * d];
    let mut stats = MitaStats::default();
    mita_attention(&q, &k, &v, n, d, &cfg, &mut ws, &mut out, &mut stats);
    assert!(stats.overflow > 0, "test must exercise the overflow fallback");
    let sel = capture_selection(&q, &k, n, d, &cfg);
    let frozen = mita_frozen_forward(&q, &k, &v, n, d, &sel);
    for (i, (a, b)) in out.iter().zip(&frozen).enumerate() {
        assert!((a - b).abs() < 1e-6, "frozen forward diverged at {i}: {a} vs {b}");
    }

    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dv = vec![0.0f32; n * d];
    mita_attention_backward(&q, &k, &v, n, d, &cfg, &c, &mut ws, &mut dq, &mut dk, &mut dv);

    // Numeric side: frozen-selection forward (the straight-through
    // convention — selection indices are constants of the unperturbed
    // point).
    let mut fq = |qs: &[f32]| project(&mita_frozen_forward(qs, &k, &v, n, d, &sel), &c);
    check("mita_attn/dq", &q, &dq, &CheckOpts::default(), &mut fq).unwrap();
    let mut fk = |ks: &[f32]| project(&mita_frozen_forward(&q, ks, &v, n, d, &sel), &c);
    check("mita_attn/dk", &k, &dk, &CheckOpts::default(), &mut fk).unwrap();
    let mut fv = |vs: &[f32]| project(&mita_frozen_forward(&q, &k, vs, n, d, &sel), &c);
    check("mita_attn/dv", &v, &dv, &CheckOpts::default(), &mut fv).unwrap();

    // Overflowed queries carry gradient: with everything clustered on one
    // expert, at least one fallback-served query must have nonzero dq.
    let overflowed: f32 = dq[..12 * d].iter().map(|g| g.abs()).sum();
    assert!(overflowed > 0.0, "overflow-fallback queries must receive gradients");
}

// ---------------------------------------------------------------------------
// Whole-model gradient checks
// ---------------------------------------------------------------------------

/// Re-draw every parameter at O(0.3–0.6) scale. The GPT-style 0.02-std
/// init leaves the first LayerNorm's input σ ≈ 0.02 — a central
/// difference with ε = 1e-2 would then probe LN far outside its locally
/// linear regime and truncation error would swamp the tolerance. Healthy
/// activation scales keep every layer smooth at the probe step.
fn randomize_params(p: &mut mita::model::ModelParams, rng: &mut Rng) {
    let mut fill = |v: &mut Vec<f32>, lo: f32, hi: f32| {
        for x in v.iter_mut() {
            *x = rng.range_f32(lo, hi);
        }
    };
    fill(&mut p.tok_emb, -0.6, 0.6);
    fill(&mut p.pos_emb, -0.3, 0.3);
    for b in &mut p.blocks {
        fill(&mut b.ln1_g, 0.8, 1.2);
        fill(&mut b.ln1_b, -0.2, 0.2);
        fill(&mut b.wq, -0.4, 0.4);
        fill(&mut b.bq, -0.1, 0.1);
        fill(&mut b.wk, -0.4, 0.4);
        fill(&mut b.bk, -0.1, 0.1);
        fill(&mut b.wv, -0.4, 0.4);
        fill(&mut b.bv, -0.1, 0.1);
        fill(&mut b.wo, -0.4, 0.4);
        fill(&mut b.bo, -0.1, 0.1);
        fill(&mut b.ln2_g, 0.8, 1.2);
        fill(&mut b.ln2_b, -0.2, 0.2);
        fill(&mut b.w1, -0.4, 0.4);
        fill(&mut b.b1, -0.1, 0.1);
        fill(&mut b.w2, -0.4, 0.4);
        fill(&mut b.b2, -0.1, 0.1);
    }
    fill(&mut p.lnf_g, 0.8, 1.2);
    fill(&mut p.lnf_b, -0.2, 0.2);
    fill(&mut p.head_w, -0.4, 0.4);
    fill(&mut p.head_b, -0.1, 0.1);
}

fn model_gradcheck(cfg: ModelConfig, label: &str) {
    let mut model = MitaModel::init(cfg.clone(), 21).unwrap();
    let batch = 2usize;
    let mut rng = Rng::new(77);
    randomize_params(&mut model.params, &mut rng);
    let tokens: Vec<i32> =
        (0..batch * cfg.seq_len).map(|_| rng.below(cfg.vocab) as i32).collect();
    let labels: Vec<i32> = (0..batch).map(|_| rng.below(cfg.classes) as i32).collect();

    let pool = WorkspacePool::new();
    let mut scratch = TrainScratch::default();
    let mut grads = Gradients::zeros(&cfg);
    let mut stats = MitaStats::default();
    loss_and_gradients(
        &model, &tokens, &labels, batch, &pool, &mut scratch, &mut grads, &mut stats,
    )
    .unwrap();

    let flat = flatten_params(&model.params);
    let mut probe = model.clone();
    let mut f = |xs: &[f32]| {
        load_flat(&mut probe.params, xs);
        let mut g = Gradients::zeros(&cfg);
        let mut st = MitaStats::default();
        loss_and_gradients(
            &probe, &tokens, &labels, batch, &pool, &mut scratch, &mut g, &mut st,
        )
        .unwrap()
        .loss
    };
    let worst =
        check(label, &flat, grads.as_slice(), &CheckOpts::strided(5), &mut f).unwrap();
    assert!(worst.is_finite());
}

#[test]
fn gradcheck_whole_model_dense() {
    model_gradcheck(ModelConfig::new(6, 6, 6, 2, 2, 10, 3, OP_ATTN_DENSE), "model/dense");
}

#[test]
fn gradcheck_whole_model_mita() {
    // m = 1, k = n: a single expert gathering every KV pair — routing and
    // top-k are selection-stable under perturbation (the picked *set*
    // cannot change), so the unfrozen numeric derivative is valid while
    // the MiTA backward code path (landmark recompute, pick gather,
    // per-expert softmax) is fully exercised. Kernel-level checks above
    // cover skewed configs incl. the overflow fallback.
    let cfg = ModelConfig::new(6, 6, 6, 2, 2, 10, 3, OP_ATTN_MITA)
        .with_mita(MitaKernelConfig { m: 1, k: 6, cap_factor: 8, block_q: 1 });
    model_gradcheck(cfg, "model/mita");
}

// ---------------------------------------------------------------------------
// End-to-end training + checkpoint round-trip
// ---------------------------------------------------------------------------

#[test]
fn training_reduces_loss_for_both_kernels() {
    for kernel in [OP_ATTN_MITA, OP_ATTN_DENSE] {
        let task = lra::by_name("text", 32, 32, 13);
        let cfg = ModelConfig::for_task(task.as_ref(), 16, 2, 1, kernel);
        let model = MitaModel::init(cfg, 2).unwrap();
        let mut trainer =
            NativeTrainer::new(model, AdamWConfig::default().with_lr(1e-2), 4).unwrap();
        let run = TrainConfig {
            steps: 100,
            batch: 8,
            eval_every: 0,
            eval_batches: 2,
            log_every: 0,
            checkpoint: None,
        };
        let outcome = trainer.train(task.as_ref(), &run).unwrap();
        assert!(trainer.history.iter().all(|r| r.loss.is_finite()), "{kernel}: loss blew up");
        let head: f64 = trainer.history[..25].iter().map(|r| r.loss).sum::<f64>() / 25.0;
        let tail: f64 = trainer.history[75..].iter().map(|r| r.loss).sum::<f64>() / 25.0;
        assert!(
            tail < head,
            "{kernel}: loss did not fall on average ({head:.4} -> {tail:.4})"
        );
        assert!(outcome.tail_loss < outcome.first_loss, "{kernel}: outcome summary disagrees");
        assert_eq!(outcome.steps, 100);
        assert!(outcome.final_eval.examples > 0);
    }
}

#[test]
fn trained_checkpoint_roundtrips_through_native_backend() {
    let task = lra::by_name("text", 32, 32, 5);
    let cfg = ModelConfig::for_task(task.as_ref(), 16, 2, 1, OP_ATTN_MITA);
    let model = MitaModel::init(cfg, 1).unwrap();
    let mut trainer = NativeTrainer::new(model, AdamWConfig::default(), 9).unwrap();
    for _ in 0..20 {
        trainer.step(task.as_ref(), 4).unwrap();
    }

    // The trainer's eval logits: the inference forward over val tokens —
    // exactly what `NativeTrainer::eval` aggregates.
    let batch = 3usize;
    let (tokens, _) = lra::batch_host(task.as_ref(), Split::Val, 0, batch);
    let registry = trainer.model().registry();
    let pool = WorkspacePool::new();
    let mut scratch = ModelScratch::default();
    let mut stats = MitaStats::default();
    let want = trainer
        .model()
        .forward(&tokens, batch, batch, &registry, &pool, &mut scratch, &mut stats)
        .unwrap();
    let eval = trainer.eval(task.as_ref(), 1, batch).unwrap();
    assert!(eval.loss.is_finite());

    // Save through the shared container, reload through the typed
    // service surface, serve the same tokens.
    let dir = std::env::temp_dir().join(format!("mita_train_rt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trained.ckpt");
    trainer.model().save(&path).unwrap();
    let tensors = checkpoint::load(&path).unwrap();
    let mut be = NativeBackend::new(NativeAttnConfig::for_shape(32, 16, 2));
    be.execute(ServiceRequest::BindCheckpoint {
        binding: BindingId::from("trained"),
        params: tensors,
    })
    .unwrap();
    let toks = Tensor::i32(&[batch, 32], tokens.clone()).unwrap();
    let served = be.run_model(&BindingId::from("trained"), &toks, None).unwrap();
    assert_eq!(served.shape(), &[batch, trainer.model().cfg.classes]);
    assert_eq!(
        served.as_f32().unwrap(),
        want.as_slice(),
        "served logits must equal the trainer's eval logits bit-for-bit"
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir(&dir).ok();
}

#[test]
fn best_checkpoint_is_saved_and_loadable() {
    let task = lra::by_name("text", 32, 32, 17);
    let cfg = ModelConfig::for_task(task.as_ref(), 16, 2, 1, OP_ATTN_DENSE);
    let model = MitaModel::init(cfg, 6).unwrap();
    let mut trainer = NativeTrainer::new(model, AdamWConfig::default(), 3).unwrap();
    let dir = std::env::temp_dir().join(format!("mita_train_best_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("best.ckpt");
    let run = TrainConfig {
        steps: 12,
        batch: 4,
        eval_every: 5,
        eval_batches: 1,
        log_every: 0,
        checkpoint: Some(path.clone()),
    };
    let outcome = trainer.train(task.as_ref(), &run).unwrap();
    assert!(outcome.best_eval.loss <= outcome.final_eval.loss + 1e-12);
    let loaded = MitaModel::load(&path).unwrap();
    assert_eq!(loaded.cfg, trainer.model().cfg);
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir(&dir).ok();
}
