//! Integration tests over the serving stack (engine thread + batcher +
//! server loop). Skip when artifacts are missing.

use std::time::Duration;

use mita::coordinator::batcher::BatchPolicy;
use mita::coordinator::server::{serve, ServeConfig};
use mita::coordinator::Engine;
use mita::runtime::Runtime;

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
    }
    ok
}

#[test]
fn engine_runs_jobs_and_shuts_down() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::load("artifacts").unwrap();
    let art = rt.manifest().bundle_artifact("quickstart", "init").unwrap().to_string();
    drop(rt);

    let engine = Engine::spawn("artifacts".into(), vec![art.clone()]).unwrap();
    let handle = engine.handle();
    let out = handle
        .run_artifact(&art, None, vec![mita::runtime::Tensor::scalar_i32(0)])
        .unwrap();
    assert!(!out.is_empty());
    // Concurrent submissions from two threads.
    let h2 = engine.handle();
    let art2 = art.clone();
    let t = std::thread::spawn(move || {
        h2.run_artifact(&art2, None, vec![mita::runtime::Tensor::scalar_i32(1)]).unwrap().len()
    });
    let n1 =
        handle.run_artifact(&art, None, vec![mita::runtime::Tensor::scalar_i32(2)]).unwrap().len();
    let n2 = t.join().unwrap();
    assert_eq!(n1, n2);
    engine.shutdown();
}

#[test]
fn engine_reports_unknown_artifact() {
    if !have_artifacts() {
        return;
    }
    let engine = Engine::spawn("artifacts".into(), vec![]).unwrap();
    let err = engine.handle().run_artifact("no_such_artifact", None, vec![]).unwrap_err();
    assert_eq!(err.code(), "unknown_op");
    engine.shutdown();
}

#[test]
fn closed_loop_serving_completes_all_requests() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::load("artifacts").unwrap();
    let spec = rt.manifest().bundle("quickstart").unwrap().clone();
    let predict = rt.manifest().bundle_artifact("quickstart", "predict").unwrap().to_string();
    drop(rt);

    let engine = Engine::spawn("artifacts".into(), vec![predict]).unwrap();
    let rt2 = Runtime::load("artifacts").unwrap();
    let init = rt2.manifest().bundle_artifact("quickstart", "init").unwrap().to_string();
    drop(rt2);
    engine.handle().bind_init("quickstart", &init, 0, spec.param_count()).unwrap();
    let cfg = ServeConfig {
        bundle: "quickstart".into(),
        binding: "quickstart".into(),
        requests: 40,
        rate: 0.0,
        queue_cap: 64,
        max_inflight: 2,
        policy: BatchPolicy {
            max_batch: spec.train.batch_size,
            max_wait: Duration::from_millis(2),
        },
    };
    let report = serve(&engine.handle(), &spec, "quickstart", &cfg).unwrap();
    assert_eq!(report.completed, 40);
    assert_eq!(report.rejected, 0);
    assert!(report.throughput_rps > 0.0);
    assert!(report.p50_ms <= report.p99_ms + 1e-9);
    assert!(report.batches >= (40 / spec.train.batch_size) as u64);
    engine.shutdown();
}

#[test]
fn open_loop_backpressure_rejects_under_overload() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::load("artifacts").unwrap();
    let spec = rt.manifest().bundle("quickstart").unwrap().clone();
    let predict = rt.manifest().bundle_artifact("quickstart", "predict").unwrap().to_string();
    drop(rt);

    let engine = Engine::spawn("artifacts".into(), vec![predict]).unwrap();
    let rt2 = Runtime::load("artifacts").unwrap();
    let init = rt2.manifest().bundle_artifact("quickstart", "init").unwrap().to_string();
    drop(rt2);
    engine.handle().bind_init("quickstart", &init, 0, spec.param_count()).unwrap();
    // Tiny queue + absurd arrival rate -> rejections must occur, yet the
    // server must still complete what it admitted.
    let cfg = ServeConfig {
        bundle: "quickstart".into(),
        binding: "quickstart".into(),
        requests: 200,
        rate: 100_000.0,
        queue_cap: 4,
        max_inflight: 2,
        policy: BatchPolicy {
            max_batch: spec.train.batch_size,
            max_wait: Duration::from_millis(1),
        },
    };
    let report = serve(&engine.handle(), &spec, "quickstart", &cfg).unwrap();
    assert_eq!(report.completed + report.rejected, 200);
    assert!(report.completed > 0);
    engine.shutdown();
}
