//! Std-only stand-in for the `anyhow` crate, covering exactly the surface
//! this workspace uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and the [`Context`] extension trait for `Result` and
//! `Option`.
//!
//! The build environment is fully offline, so instead of the real crate we
//! vendor this ~150-line subset. Semantics match anyhow where it matters:
//! context wraps outside-in (`"ctx: cause"`), any `std::error::Error` value
//! converts via `?`, and `Error` itself deliberately does *not* implement
//! `std::error::Error` (that is what keeps the blanket `From` impl coherent,
//! same trick as upstream).

use std::fmt;

/// Error: a message with any context frames already folded in.
pub struct Error(String);

/// `anyhow::Result<T>` — alias with the crate's error as the default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(message.to_string())
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error(format!("{context}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// Context-attachment extension, as in anyhow: available on `Result` with a
/// displayable error, and on `Option` (where `None` becomes the context).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_then_wrap(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?; // From<ParseIntError>
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_then_wrap("42").unwrap(), 42);
        assert!(parse_then_wrap("nope").is_err());
    }

    #[test]
    fn context_wraps_outside_in() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
    }

    #[test]
    fn option_context_and_with_context() {
        let v: Option<u8> = None;
        let err = v.context("missing").unwrap_err();
        assert_eq!(format!("{err}"), "missing");
        let v: Option<u8> = Some(3);
        assert_eq!(v.with_context(|| "unused").unwrap(), 3);
    }

    fn ensures(x: usize) -> Result<usize> {
        ensure!(x < 10, "x too big: {x}");
        Ok(x)
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(ensures(3).unwrap(), 3);
        assert_eq!(ensures(11).unwrap_err().to_string(), "x too big: 11");
        fn bails() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert_eq!(bails().unwrap_err().to_string(), "boom 1");
    }
}
