//! Stub of the vendored `xla` PJRT bindings.
//!
//! The original build environment vendors the full PJRT C-API closure; this
//! container does not ship it, so the workspace builds against this stub
//! instead. The split is deliberate:
//!
//! - [`Literal`] is **fully functional** (host tensors: f32/s32, reshape,
//!   readback). Everything that only moves data — checkpoints, parameter
//!   bindings, tensor conversion — keeps working.
//! - The **runtime surface** ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`HloModuleProto`]) type-checks but reports
//!   "PJRT unavailable" at the first call, so artifact-dependent paths fail
//!   with a clear message instead of at link time. The native CPU backend
//!   (`mita::kernels`) is the execution path in this build.
//!
//! Swapping the real crate back in is a one-line change in rust/Cargo.toml.

use std::borrow::Borrow;
use std::path::Path;

/// Stub error; rendered with `{:?}` by callers, matching the real crate.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (stub xla crate; use the native backend or \
         restore the vendored PJRT closure)"
    ))
}

/// Element types of the PJRT boundary. Only `F32`/`S32` are constructed by
/// this stub, but the full set is declared so caller `match` arms over
/// "unsupported" types stay reachable, as with the real crate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Typed host storage behind a [`Literal`]. Public only because the
/// [`NativeType`] trait must name it; not part of the intended API.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    S32(Vec<i32>),
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Sized + Copy {
    fn element_type() -> ElementType;
    #[doc(hidden)]
    fn store(data: &[Self]) -> Storage;
    #[doc(hidden)]
    fn read(storage: &Storage) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }

    fn store(data: &[Self]) -> Storage {
        Storage::F32(data.to_vec())
    }

    fn read(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::F32(v) => Some(v.clone()),
            Storage::S32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }

    fn store(data: &[Self]) -> Storage {
        Storage::S32(data.to_vec())
    }

    fn read(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::S32(v) => Some(v.clone()),
            Storage::F32(_) => None,
        }
    }
}

/// Dims + element type of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A dense host tensor (the only literal kind this workspace constructs).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    storage: Storage,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], storage: T::store(data) }
    }

    fn element_count(&self) -> i64 {
        match &self.storage {
            Storage::F32(v) => v.len() as i64,
            Storage::S32(v) => v.len() as i64,
        }
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.element_count() {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), storage: self.storage.clone() })
    }

    /// Shape of the array (always available: the stub has no tuple literals).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.storage {
            Storage::F32(_) => ElementType::F32,
            Storage::S32(_) => ElementType::S32,
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match T::read(&self.storage) {
            Some(v) => Ok(v),
            None => Err(Error(format!("to_vec: literal is not {:?}", T::element_type()))),
        }
    }

    /// Tuple decomposition — only execution results are tuples, and the stub
    /// never produces one.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error("decompose_tuple: stub literals are never tuples".to_string()))
    }
}

/// Parsed HLO module (stub: cannot be constructed).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parse {}", path.as_ref().display())))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer holding one execution output (stub: never produced).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Compiled executable (stub: never produced).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on per-device argument lists; generic over owned or borrowed
    /// literals, matching the real crate's call sites.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let l = l.reshape(&[2, 2]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_roundtrip_i32_and_bad_reshape() {
        let l = Literal::vec1(&[7i32, 8]);
        assert!(l.reshape(&[3]).is_err());
        let r = l.reshape(&[2, 1]).unwrap();
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![7, 8]);
        assert_eq!(Literal::vec1(&[0i32; 0]).array_shape().unwrap().dims(), &[0]);
    }

    #[test]
    fn runtime_surface_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        let mut l = Literal::vec1(&[1.0f32]);
        assert!(l.decompose_tuple().is_err());
    }
}
