//! # MiTA — Mixture-of-Top-k Attention
//!
//! Rust coordinator of the three-layer reproduction of *"Mixture-of-Top-k
//! Attention: Efficient Attention via Scalable Fast Weights"*:
//!
//! - **L1** (build time): Pallas kernels in `python/compile/kernels/`.
//! - **L2** (build time): JAX models in `python/compile/model.py`, lowered
//!   once to HLO text under `artifacts/`.
//! - **L3** (this crate): loads + executes the artifacts via PJRT, owns the
//!   serving loop, the training driver, data generation, metrics, and the
//!   benchmark harness that regenerates every table/figure of the paper.
//! - **L3-native** (`kernels` + `runtime::backend`): a pure-Rust MiTA /
//!   dense attention stack behind the same `Backend` interface — an
//!   `AttentionKernel` registry, zero-alloc `Workspace` arenas, and
//!   batched (example × head) parallel dispatch — so serving and
//!   benchmarking run on machines with no PJRT closure at all.
//! - **L3-model** (`model`): a native MiTA Transformer over that stack —
//!   pre-LN blocks whose attention resolves per block through the kernel
//!   registry — served end-to-end over the LRA tasks.
//! - **L3-service** (`service` + `coordinator::netserver`): the typed
//!   request surface — `ServiceRequest`/`ServiceResponse` with a stable
//!   error taxonomy, parsed once at the service boundary — and the
//!   network front that speaks it over HTTP/1.1 + JSON
//!   (`docs/PROTOCOL.md`).
//! - **L3-decode** (`decode`): the autoregressive workload — causal
//!   variants of both kernels, incremental MiTA landmark/expert state
//!   (per-step bit-parity against a full-recompute reference), KV-cached
//!   single-token forwards, and streaming generation over `/v1/generate`
//!   (`docs/DECODE.md`).
//! - **L3-train** (`train`): exact hand-derived backward passes for
//!   every model layer (dense softmax and straight-through MiTA
//!   attention included), flat gradients + AdamW, and the
//!   `NativeTrainer` loop over the LRA tasks — checkpoints land in the
//!   same container the serving path binds (`docs/TRAINING.md`).
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod coordinator;
pub mod data;
pub mod decode;
pub mod flops;
pub mod harness;
pub mod kernels;
pub mod mita;
pub mod model;
pub mod report;
pub mod runtime;
pub mod service;
pub mod train;
pub mod util;
