//! Model configuration of the native MiTA transformer.
//!
//! A [`ModelConfig`] fixes the token geometry (vocab, sequence length,
//! classes), the transformer shape (dim, heads, depth, MLP width), the
//! shared MiTA kernel parameters, and — the part that makes attention
//! kernels drop-in — a *per-block* attention kernel registry name
//! (`attn.mita` / `attn.dense`), so a model can mix routed and dense
//! blocks freely. The config round-trips through a single i32 tensor so a
//! checkpoint (see [`crate::coordinator::checkpoint`]) is self-describing:
//!
//! ```text
//! [version, vocab, seq_len, dim, heads, depth, mlp_hidden, classes,
//!  m, k, cap_factor, block_q, kernel_id × depth]
//! ```

use anyhow::{bail, Context, Result};

use crate::data::lra::SeqTask;
use crate::kernels::{MitaKernelConfig, OP_ATTN_DENSE, OP_ATTN_MITA};
use crate::runtime::Tensor;

/// Version tag of the checkpoint config tensor.
const CONFIG_VERSION: i32 = 1;

/// Registry-name ↔ checkpoint-id mapping for per-block attention kernels.
/// The causal decode variants are checkpointable too, so a model tagged
/// for autoregressive serving round-trips like any other.
const KERNEL_IDS: &[(&str, i32)] = &[
    (OP_ATTN_MITA, 0),
    (OP_ATTN_DENSE, 1),
    (crate::decode::OP_ATTN_MITA_CAUSAL, 2),
    (crate::decode::OP_ATTN_DENSE_CAUSAL, 3),
];

fn kernel_id(name: &str) -> Result<i32> {
    match KERNEL_IDS.iter().find(|(n, _)| *n == name) {
        Some(&(_, id)) => Ok(id),
        None => bail!(
            "attention kernel {name:?} is not checkpointable (known: {})",
            KERNEL_IDS.iter().map(|&(n, _)| n).collect::<Vec<_>>().join(", ")
        ),
    }
}

fn kernel_name(id: i32) -> Result<&'static str> {
    match KERNEL_IDS.iter().find(|(_, i)| *i == id) {
        Some(&(name, _)) => Ok(name),
        None => bail!("unknown attention kernel id {id} in model config"),
    }
}

fn as_dim(x: i32, what: &str) -> Result<usize> {
    anyhow::ensure!(x >= 0, "model config {what} is negative ({x})");
    Ok(x as usize)
}

/// Shape + kernel-selection description of one native MiTA transformer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    /// Token vocabulary size (embedding rows).
    pub vocab: usize,
    /// Sequence length (fixed; the positional table has this many rows).
    pub seq_len: usize,
    /// Model dimension (`heads · head_dim`).
    pub dim: usize,
    /// Attention heads per block.
    pub heads: usize,
    /// Transformer blocks.
    pub depth: usize,
    /// Hidden width of each block's GELU MLP.
    pub mlp_hidden: usize,
    /// Classifier output classes.
    pub classes: usize,
    /// MiTA kernel parameters shared by every `attn.mita` block.
    pub mita: MitaKernelConfig,
    /// Per-block attention kernel registry names (`len == depth`); blocks
    /// may mix `attn.mita` and `attn.dense`.
    pub block_kernels: Vec<String>,
}

impl ModelConfig {
    /// A config with every block dispatching through `kernel` and
    /// paper-flavored MiTA parameters for the sequence length.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        vocab: usize,
        seq_len: usize,
        dim: usize,
        heads: usize,
        depth: usize,
        mlp_hidden: usize,
        classes: usize,
        kernel: &str,
    ) -> Self {
        ModelConfig {
            vocab,
            seq_len,
            dim,
            heads,
            depth,
            mlp_hidden,
            classes,
            mita: MitaKernelConfig::for_seq(seq_len),
            block_kernels: vec![kernel.to_string(); depth],
        }
    }

    /// Shape a model for an LRA task: vocab / sequence length / classes
    /// come from the task, the MLP hidden width defaults to `2 · dim`.
    pub fn for_task(
        task: &dyn SeqTask,
        dim: usize,
        heads: usize,
        depth: usize,
        kernel: &str,
    ) -> Self {
        let (vocab, n, classes) = (task.vocab(), task.seq_len(), task.classes());
        ModelConfig::new(vocab, n, dim, heads, depth, 2 * dim, classes, kernel)
    }

    /// Same config with every block dispatched to `kernel` instead.
    pub fn with_kernel(mut self, kernel: &str) -> Self {
        for k in &mut self.block_kernels {
            *k = kernel.to_string();
        }
        self
    }

    /// Same config with different MiTA kernel parameters.
    pub fn with_mita(mut self, mita: MitaKernelConfig) -> Self {
        self.mita = mita;
        self
    }

    /// Per-head feature dimension.
    pub fn head_dim(&self) -> usize {
        self.dim / self.heads
    }

    /// Total trainable f32 parameter count (mirrors `ModelParams::init`).
    pub fn param_count(&self) -> usize {
        let (d, h) = (self.dim, self.mlp_hidden);
        let block = 2 * d                // ln1
            + 3 * (d * d + d)           // q/k/v projections
            + d * d + d                 // output projection
            + 2 * d                     // ln2
            + d * h + h                 // fc1
            + h * d + d;                // fc2
        self.vocab * d                  // token embedding
            + self.seq_len * d          // positional embedding
            + self.depth * block
            + 2 * d                     // final layernorm
            + self.classes * d + self.classes // head
    }

    /// Structural validity: non-degenerate shape, heads divide dim, one
    /// checkpointable kernel name per block.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.vocab >= 1 && self.seq_len >= 1 && self.classes >= 1,
            "degenerate token geometry (vocab {}, seq_len {}, classes {})",
            self.vocab,
            self.seq_len,
            self.classes
        );
        anyhow::ensure!(self.depth >= 1 && self.mlp_hidden >= 1, "degenerate depth/MLP width");
        anyhow::ensure!(
            self.heads >= 1 && self.dim >= 1 && self.dim % self.heads == 0,
            "model dim {} not divisible by {} heads",
            self.dim,
            self.heads
        );
        anyhow::ensure!(
            self.block_kernels.len() == self.depth,
            "{} block kernels for depth {}",
            self.block_kernels.len(),
            self.depth
        );
        for name in &self.block_kernels {
            kernel_id(name)?;
        }
        Ok(())
    }

    /// Encode as the checkpoint's leading i32 config tensor.
    pub fn to_tensor(&self) -> Result<Tensor> {
        self.validate()?;
        let mut data = vec![
            CONFIG_VERSION,
            self.vocab as i32,
            self.seq_len as i32,
            self.dim as i32,
            self.heads as i32,
            self.depth as i32,
            self.mlp_hidden as i32,
            self.classes as i32,
            self.mita.m as i32,
            self.mita.k as i32,
            self.mita.cap_factor as i32,
            self.mita.block_q as i32,
        ];
        for name in &self.block_kernels {
            data.push(kernel_id(name)?);
        }
        let len = data.len();
        Tensor::i32(&[len], data)
    }

    /// Decode from a checkpoint's leading config tensor.
    pub fn from_tensor(t: &Tensor) -> Result<Self> {
        let data = t.as_i32().context("model config tensor must be i32")?;
        anyhow::ensure!(
            data.len() >= 12,
            "model config tensor holds {} values, want >= 12",
            data.len()
        );
        anyhow::ensure!(data[0] == CONFIG_VERSION, "unsupported model config version {}", data[0]);
        let depth = as_dim(data[5], "depth")?;
        anyhow::ensure!(
            data.len() == 12 + depth,
            "model config tensor holds {} values, want {} for depth {depth}",
            data.len(),
            12 + depth
        );
        let block_kernels = data[12..]
            .iter()
            .map(|&id| kernel_name(id).map(str::to_string))
            .collect::<Result<Vec<_>>>()?;
        let cfg = ModelConfig {
            vocab: as_dim(data[1], "vocab")?,
            seq_len: as_dim(data[2], "seq_len")?,
            dim: as_dim(data[3], "dim")?,
            heads: as_dim(data[4], "heads")?,
            depth,
            mlp_hidden: as_dim(data[6], "mlp_hidden")?,
            classes: as_dim(data[7], "classes")?,
            mita: MitaKernelConfig {
                m: as_dim(data[8], "m")?,
                k: as_dim(data[9], "k")?,
                cap_factor: as_dim(data[10], "cap_factor")?,
                block_q: as_dim(data[11], "block_q")?,
            },
            block_kernels,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::lra;

    #[test]
    fn config_tensor_roundtrip() {
        let mut cfg = ModelConfig::new(16, 64, 32, 2, 3, 64, 10, OP_ATTN_MITA);
        cfg.block_kernels[1] = OP_ATTN_DENSE.to_string(); // mixed blocks survive
        let t = cfg.to_tensor().unwrap();
        assert_eq!(t.shape(), &[15]);
        let back = ModelConfig::from_tensor(&t).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn for_task_matches_task_geometry() {
        let task = lra::by_name("listops", 256, 16, 1);
        let cfg = ModelConfig::for_task(task.as_ref(), 64, 4, 2, OP_ATTN_MITA);
        assert_eq!((cfg.vocab, cfg.seq_len, cfg.classes), (16, 256, 10));
        assert_eq!(cfg.mlp_hidden, 128);
        assert_eq!(cfg.head_dim(), 16);
        assert!(cfg.validate().is_ok());
        let dense = cfg.clone().with_kernel(OP_ATTN_DENSE);
        assert!(dense.block_kernels.iter().all(|k| k == OP_ATTN_DENSE));
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let good = ModelConfig::new(8, 16, 32, 2, 2, 64, 4, OP_ATTN_MITA);
        assert!(good.validate().is_ok());
        let mut bad = good.clone();
        bad.heads = 3; // 32 % 3 != 0
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.block_kernels.pop(); // len != depth
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.block_kernels[0] = "attn.unknown".to_string();
        assert!(bad.validate().is_err());
        assert!(bad.to_tensor().is_err());
        let mut bad = good;
        bad.vocab = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn from_tensor_rejects_garbage() {
        assert!(ModelConfig::from_tensor(&Tensor::i32(&[3], vec![1, 2, 3]).unwrap()).is_err());
        let cfg = ModelConfig::new(8, 16, 32, 2, 2, 64, 4, OP_ATTN_MITA);
        let t = cfg.to_tensor().unwrap();
        let mut data = t.as_i32().unwrap().to_vec();
        data[0] = 99; // bad version
        let bad = Tensor::i32(&[data.len()], data.clone()).unwrap();
        assert!(ModelConfig::from_tensor(&bad).is_err());
        data[0] = 1;
        data[12] = 7; // bad kernel id
        assert!(ModelConfig::from_tensor(&Tensor::i32(&[data.len()], data).unwrap()).is_err());
    }

    #[test]
    fn param_count_counts_every_tensor() {
        // depth 1, dim 4, hidden 8, vocab 5, seq 6, classes 3:
        // block = 8 + 3·20 + 20 + 8 + 40 + 36 = 172
        // total = 20 + 24 + 172 + 8 + 15 = 239
        let cfg = ModelConfig::new(5, 6, 4, 2, 1, 8, 3, OP_ATTN_DENSE);
        assert_eq!(cfg.param_count(), 239);
    }
}
