//! Parameter containers + deterministic seeded initialization of the
//! native MiTA transformer.
//!
//! Weights are row-major `[out, in]` matrices (a linear layer is
//! `matmul_nt(x, w) + b`, the dot-product form every kernel in
//! [`crate::kernels::linalg`] autovectorizes). Every tensor draws from its
//! own `Rng::derive(seed, [tag, layer, slot])` stream, so initialization
//! is reproducible and order-independent — the same (config, seed) pair
//! yields bit-identical parameters on any thread count or call order.

use anyhow::{Context, Result};

use crate::data::rng::Rng;
use crate::model::config::ModelConfig;
use crate::runtime::Tensor;

const TAG_EMBED: u64 = 1;
const TAG_BLOCK: u64 = 2;
const TAG_HEAD: u64 = 3;

/// GPT-style init scale for projection / embedding weights.
const WEIGHT_STD: f64 = 0.02;

fn normal_vec(seed: u64, ids: [u64; 3], len: usize, std: f64) -> Vec<f32> {
    let mut rng = Rng::derive(seed, &ids);
    (0..len).map(|_| (rng.normal() * std) as f32).collect()
}

/// Parameters of one pre-LN transformer block.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockParams {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    /// Query projection `[dim, dim]`.
    pub wq: Vec<f32>,
    pub bq: Vec<f32>,
    /// Key projection `[dim, dim]`.
    pub wk: Vec<f32>,
    pub bk: Vec<f32>,
    /// Value projection `[dim, dim]`.
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
    /// Output projection `[dim, dim]`.
    pub wo: Vec<f32>,
    pub bo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    /// MLP expansion `[mlp_hidden, dim]`.
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    /// MLP contraction `[dim, mlp_hidden]`.
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

/// Number of checkpoint tensors each block flattens to.
pub const BLOCK_TENSORS: usize = 16;
/// Checkpoint tensors outside the blocks (tok/pos embeddings, final LN
/// pair, head weight + bias).
pub const EXTRA_TENSORS: usize = 6;

/// All parameters of a native MiTA transformer.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelParams {
    /// Token embedding `[vocab, dim]`.
    pub tok_emb: Vec<f32>,
    /// Learned positional embedding `[seq_len, dim]`.
    pub pos_emb: Vec<f32>,
    pub blocks: Vec<BlockParams>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    /// Classifier head `[classes, dim]`.
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

impl ModelParams {
    /// Deterministic seeded initialization: N(0, 0.02²) weights, zero
    /// biases, unit layernorm scales, N(0, 0.01²) positions.
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        let (d, h) = (cfg.dim, cfg.mlp_hidden);
        let blocks = (0..cfg.depth)
            .map(|l| {
                let li = l as u64;
                BlockParams {
                    ln1_g: vec![1.0; d],
                    ln1_b: vec![0.0; d],
                    wq: normal_vec(seed, [TAG_BLOCK, li, 0], d * d, WEIGHT_STD),
                    bq: vec![0.0; d],
                    wk: normal_vec(seed, [TAG_BLOCK, li, 1], d * d, WEIGHT_STD),
                    bk: vec![0.0; d],
                    wv: normal_vec(seed, [TAG_BLOCK, li, 2], d * d, WEIGHT_STD),
                    bv: vec![0.0; d],
                    wo: normal_vec(seed, [TAG_BLOCK, li, 3], d * d, WEIGHT_STD),
                    bo: vec![0.0; d],
                    ln2_g: vec![1.0; d],
                    ln2_b: vec![0.0; d],
                    w1: normal_vec(seed, [TAG_BLOCK, li, 4], h * d, WEIGHT_STD),
                    b1: vec![0.0; h],
                    w2: normal_vec(seed, [TAG_BLOCK, li, 5], d * h, WEIGHT_STD),
                    b2: vec![0.0; d],
                }
            })
            .collect();
        ModelParams {
            tok_emb: normal_vec(seed, [TAG_EMBED, 0, 0], cfg.vocab * d, WEIGHT_STD),
            pos_emb: normal_vec(seed, [TAG_EMBED, 0, 1], cfg.seq_len * d, 0.01),
            blocks,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
            head_w: normal_vec(seed, [TAG_HEAD, 0, 0], cfg.classes * d, WEIGHT_STD),
            head_b: vec![0.0; cfg.classes],
        }
    }

    /// Total f32 parameters held (equals `cfg.param_count()`).
    pub fn count(&self) -> usize {
        let block: usize = self
            .blocks
            .iter()
            .map(|b| {
                b.ln1_g.len()
                    + b.ln1_b.len()
                    + b.wq.len()
                    + b.bq.len()
                    + b.wk.len()
                    + b.bk.len()
                    + b.wv.len()
                    + b.bv.len()
                    + b.wo.len()
                    + b.bo.len()
                    + b.ln2_g.len()
                    + b.ln2_b.len()
                    + b.w1.len()
                    + b.b1.len()
                    + b.w2.len()
                    + b.b2.len()
            })
            .sum();
        self.tok_emb.len()
            + self.pos_emb.len()
            + block
            + self.lnf_g.len()
            + self.lnf_b.len()
            + self.head_w.len()
            + self.head_b.len()
    }

    /// Flatten to checkpoint tensors in the fixed documented order:
    /// tok_emb, pos_emb, per block (ln1 g/b, wq/bq, wk/bk, wv/bv, wo/bo,
    /// ln2 g/b, w1/b1, w2/b2), lnf g/b, head w/b.
    pub fn to_tensors(&self, cfg: &ModelConfig) -> Result<Vec<Tensor>> {
        let (d, h) = (cfg.dim, cfg.mlp_hidden);
        let mut out = Vec::with_capacity(EXTRA_TENSORS + BLOCK_TENSORS * self.blocks.len());
        out.push(Tensor::f32(&[cfg.vocab, d], self.tok_emb.clone())?);
        out.push(Tensor::f32(&[cfg.seq_len, d], self.pos_emb.clone())?);
        for b in &self.blocks {
            out.push(Tensor::f32(&[d], b.ln1_g.clone())?);
            out.push(Tensor::f32(&[d], b.ln1_b.clone())?);
            out.push(Tensor::f32(&[d, d], b.wq.clone())?);
            out.push(Tensor::f32(&[d], b.bq.clone())?);
            out.push(Tensor::f32(&[d, d], b.wk.clone())?);
            out.push(Tensor::f32(&[d], b.bk.clone())?);
            out.push(Tensor::f32(&[d, d], b.wv.clone())?);
            out.push(Tensor::f32(&[d], b.bv.clone())?);
            out.push(Tensor::f32(&[d, d], b.wo.clone())?);
            out.push(Tensor::f32(&[d], b.bo.clone())?);
            out.push(Tensor::f32(&[d], b.ln2_g.clone())?);
            out.push(Tensor::f32(&[d], b.ln2_b.clone())?);
            out.push(Tensor::f32(&[h, d], b.w1.clone())?);
            out.push(Tensor::f32(&[h], b.b1.clone())?);
            out.push(Tensor::f32(&[d, h], b.w2.clone())?);
            out.push(Tensor::f32(&[d], b.b2.clone())?);
        }
        out.push(Tensor::f32(&[d], self.lnf_g.clone())?);
        out.push(Tensor::f32(&[d], self.lnf_b.clone())?);
        out.push(Tensor::f32(&[cfg.classes, d], self.head_w.clone())?);
        out.push(Tensor::f32(&[cfg.classes], self.head_b.clone())?);
        Ok(out)
    }

    /// Rebuild from checkpoint tensors (inverse of
    /// [`ModelParams::to_tensors`], with shape checks against `cfg`).
    pub fn from_tensors(cfg: &ModelConfig, tensors: &[Tensor]) -> Result<Self> {
        let want = EXTRA_TENSORS + BLOCK_TENSORS * cfg.depth;
        anyhow::ensure!(
            tensors.len() == want,
            "model checkpoint holds {} parameter tensors, want {want} for depth {}",
            tensors.len(),
            cfg.depth
        );
        let (d, h) = (cfg.dim, cfg.mlp_hidden);
        let mut i = 0usize;
        let tok_emb = take(tensors, &mut i, &[cfg.vocab, d], "tok_emb")?;
        let pos_emb = take(tensors, &mut i, &[cfg.seq_len, d], "pos_emb")?;
        let mut blocks = Vec::with_capacity(cfg.depth);
        for _ in 0..cfg.depth {
            blocks.push(BlockParams {
                ln1_g: take(tensors, &mut i, &[d], "ln1_g")?,
                ln1_b: take(tensors, &mut i, &[d], "ln1_b")?,
                wq: take(tensors, &mut i, &[d, d], "wq")?,
                bq: take(tensors, &mut i, &[d], "bq")?,
                wk: take(tensors, &mut i, &[d, d], "wk")?,
                bk: take(tensors, &mut i, &[d], "bk")?,
                wv: take(tensors, &mut i, &[d, d], "wv")?,
                bv: take(tensors, &mut i, &[d], "bv")?,
                wo: take(tensors, &mut i, &[d, d], "wo")?,
                bo: take(tensors, &mut i, &[d], "bo")?,
                ln2_g: take(tensors, &mut i, &[d], "ln2_g")?,
                ln2_b: take(tensors, &mut i, &[d], "ln2_b")?,
                w1: take(tensors, &mut i, &[h, d], "w1")?,
                b1: take(tensors, &mut i, &[h], "b1")?,
                w2: take(tensors, &mut i, &[d, h], "w2")?,
                b2: take(tensors, &mut i, &[d], "b2")?,
            });
        }
        Ok(ModelParams {
            tok_emb,
            pos_emb,
            blocks,
            lnf_g: take(tensors, &mut i, &[d], "lnf_g")?,
            lnf_b: take(tensors, &mut i, &[d], "lnf_b")?,
            head_w: take(tensors, &mut i, &[cfg.classes, d], "head_w")?,
            head_b: take(tensors, &mut i, &[cfg.classes], "head_b")?,
        })
    }
}

fn take(tensors: &[Tensor], i: &mut usize, shape: &[usize], what: &str) -> Result<Vec<f32>> {
    let t = tensors
        .get(*i)
        .with_context(|| format!("model checkpoint truncated at tensor {} ({what})", *i))?;
    anyhow::ensure!(
        t.shape() == shape,
        "checkpoint tensor {} ({what}): shape {:?}, want {shape:?}",
        *i,
        t.shape()
    );
    *i += 1;
    Ok(t.as_f32().with_context(|| format!("{what} must be f32"))?.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::OP_ATTN_MITA;

    fn cfg() -> ModelConfig {
        ModelConfig::new(9, 12, 8, 2, 2, 16, 3, OP_ATTN_MITA)
    }

    #[test]
    fn init_is_deterministic_and_sized() {
        let c = cfg();
        let a = ModelParams::init(&c, 42);
        let b = ModelParams::init(&c, 42);
        assert_eq!(a, b, "same (config, seed) must be bit-identical");
        assert_eq!(a.count(), c.param_count());
        assert_ne!(a.tok_emb, ModelParams::init(&c, 43).tok_emb, "seeds must differ");
        // Structured defaults.
        assert!(a.blocks[0].ln1_g.iter().all(|&x| x == 1.0));
        assert!(a.blocks[0].bq.iter().all(|&x| x == 0.0));
        assert!(a.head_b.iter().all(|&x| x == 0.0));
        // Per-tensor streams: wq and wk must not repeat each other.
        assert_ne!(a.blocks[0].wq, a.blocks[0].wk);
        assert_ne!(a.blocks[0].wq, a.blocks[1].wq);
    }

    #[test]
    fn tensor_roundtrip() {
        let c = cfg();
        let p = ModelParams::init(&c, 7);
        let tensors = p.to_tensors(&c).unwrap();
        assert_eq!(tensors.len(), EXTRA_TENSORS + BLOCK_TENSORS * c.depth);
        let back = ModelParams::from_tensors(&c, &tensors).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn from_tensors_rejects_wrong_shapes() {
        let c = cfg();
        let p = ModelParams::init(&c, 7);
        let mut tensors = p.to_tensors(&c).unwrap();
        assert!(ModelParams::from_tensors(&c, &tensors[1..]).is_err(), "wrong count");
        tensors[2] = Tensor::f32(&[3], vec![0.0; 3]).unwrap(); // ln1_g wrong shape
        assert!(ModelParams::from_tensors(&c, &tensors).is_err());
    }
}
