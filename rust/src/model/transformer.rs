//! The native MiTA transformer: a full pre-LN block stack executed over
//! the kernel registry.
//!
//! ```text
//! tokens [b, n] i32
//!   │ token embedding + learned positions
//!   ▼
//! depth × ┌ LN → Q/K/V proj → KernelRegistry op (attn.mita | attn.dense,
//!         │      per block) via run_batched over the WorkspacePool → proj ⊕
//!         └ LN → GELU MLP ⊕
//!   │ final LN → mean-pool over n → classifier head
//!   ▼
//! logits [b, classes] f32
//! ```
//!
//! Attention is dispatched through [`crate::kernels::api::run_batched`] —
//! the same (example × head) work-item executor the raw attention ops use —
//! so each block picks `attn.mita` or `attn.dense` by registry name and
//! inherits batched parallelism + pooled workspaces for free. Every other
//! stage (embedding, projections, MLP, head) parallelizes per example via
//! [`par_chunks_mut`] with per-thread scratch drawn from the same
//! [`WorkspacePool`]; within a chunk the math is serial, so outputs are
//! bit-identical across thread counts.
//!
//! Model checkpoints reuse [`crate::coordinator::checkpoint`]'s container
//! format: tensor 0 is the i32 [`ModelConfig`] descriptor, the rest are
//! the parameters in [`crate::model::params::ModelParams::to_tensors`]
//! order — a checkpoint is self-describing and loads without a config.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::checkpoint;
use crate::kernels::api::{
    run_batched, AttentionKernel, AttnProblem, BlockProfile, KernelRegistry, MitaStats, QkvData,
    QkvLayout,
};
use crate::kernels::linalg::{axpy, dot, matmul_nt, scale_in_place};
use crate::kernels::par::par_chunks_mut;
use crate::kernels::simd;
use crate::kernels::workspace::WorkspacePool;
use crate::model::config::ModelConfig;
use crate::model::params::ModelParams;
use crate::runtime::Tensor;

/// LayerNorm epsilon (shared with the exact backward in
/// [`crate::train::backward`]).
pub(crate) const LN_EPS: f32 = 1e-5;

/// Normalize each `[d]` row of `x` with scale `g` and shift `b`.
/// `pub(crate)` so the training tape forward reuses the inference math
/// bit-for-bit instead of re-deriving it. Mean, variance, and the
/// normalize-affine pass all run through the dispatched SIMD ops
/// (canonical reduction order; [`crate::train::backward::layer_norm_backward`]
/// recomputes with the same ops, so x̂ stays bit-identical).
pub(crate) fn layer_norm_rows(x: &[f32], d: usize, g: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(x.len() % d, 0);
    let ops = simd::ops();
    for (xrow, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let mean = (ops.sum)(xrow) / d as f32;
        let var = (ops.sq_dev_sum)(xrow, mean) / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        (ops.norm_affine)(xrow, mean, inv, g, b, orow);
    }
}

/// `x[r, :] += bias` for row-major `[rows, len(bias)]`.
pub(crate) fn add_bias_rows(x: &mut [f32], bias: &[f32]) {
    for row in x.chunks_exact_mut(bias.len()) {
        axpy(1.0, bias, row);
    }
}

/// GELU (tanh approximation), in place. The implementation lives in
/// [`crate::kernels::simd::scalar::gelu`] — `tanh` is libm, so every
/// SIMD lane shares that one scalar body; constants are mirrored by
/// [`crate::train::backward::gelu_backward`].
pub(crate) fn gelu_in_place(x: &mut [f32]) {
    (simd::ops().gelu)(x)
}

/// Reusable activation buffers of one forward pass. Steady-state calls at
/// one (batch, shape) reuse every allocation; per-thread scratch inside
/// the parallel regions comes from the caller's [`WorkspacePool`].
#[derive(Debug, Default)]
pub struct ModelScratch {
    /// Residual-stream activations `[valid, n, dim]`.
    h: Vec<f32>,
    /// Pre-LN output `[valid, n, dim]`.
    y: Vec<f32>,
    /// Fused Q/K/V projections `[valid, 3, n, dim]`.
    qkv: Vec<f32>,
    /// Attention output `[valid, n, dim]`.
    attn: Vec<f32>,
    /// Head-major staging buffer for `run_batched`.
    headout: Vec<f32>,
    /// Per-block routing accumulator, reset before each block's kernel
    /// run so per-block stats separate without per-call allocation.
    block_stats: MitaStats,
}

/// A native MiTA transformer: config + parameters.
#[derive(Debug, Clone)]
pub struct MitaModel {
    pub cfg: ModelConfig,
    pub params: ModelParams,
}

impl MitaModel {
    /// Deterministic seeded initialization (validates the config).
    pub fn init(cfg: ModelConfig, seed: u64) -> Result<Self> {
        cfg.validate()?;
        let params = ModelParams::init(&cfg, seed);
        Ok(MitaModel { cfg, params })
    }

    /// Same parameters with every block dispatched to `kernel` instead —
    /// the MiTA-vs-dense parity lever.
    pub fn with_kernel(&self, kernel: &str) -> Result<MitaModel> {
        let cfg = self.cfg.clone().with_kernel(kernel);
        cfg.validate()?;
        Ok(MitaModel { cfg, params: self.params.clone() })
    }

    /// The standard kernel set keyed by this model's MiTA parameters.
    pub fn registry(&self) -> KernelRegistry {
        KernelRegistry::with_defaults(self.cfg.mita)
    }

    /// Flatten to checkpoint tensors (config descriptor first).
    pub fn to_tensors(&self) -> Result<Vec<Tensor>> {
        let mut out = vec![self.cfg.to_tensor()?];
        out.extend(self.params.to_tensors(&self.cfg)?);
        Ok(out)
    }

    /// Rebuild from checkpoint tensors (inverse of
    /// [`MitaModel::to_tensors`]).
    pub fn from_tensors(tensors: &[Tensor]) -> Result<Self> {
        anyhow::ensure!(!tensors.is_empty(), "model checkpoint is empty");
        let cfg = ModelConfig::from_tensor(&tensors[0])
            .context("tensor 0 must be the model config descriptor")?;
        let params = ModelParams::from_tensors(&cfg, &tensors[1..])?;
        Ok(MitaModel { cfg, params })
    }

    /// Save to the shared native checkpoint format (atomic rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        checkpoint::save(path, &self.to_tensors()?)
    }

    /// Load a self-describing model checkpoint.
    pub fn load(path: &Path) -> Result<Self> {
        Self::from_tensors(&checkpoint::load(path)?)
    }

    /// Classify a batch: `tokens` is row-major `[batch, seq_len]`, only
    /// the first `valid` rows are computed (trailing rows are padding —
    /// their logits stay zero). Returns logits `[batch, classes]`.
    ///
    /// Attention dispatches through `registry` by each block's kernel
    /// name; all scratch comes from `scratch` + the pool, so steady-state
    /// calls allocate only the returned logits. MiTA routing statistics
    /// accumulate into `stats`.
    #[allow(clippy::too_many_arguments)]
    pub fn forward(
        &self,
        tokens: &[i32],
        batch: usize,
        valid: usize,
        registry: &KernelRegistry,
        pool: &WorkspacePool,
        scratch: &mut ModelScratch,
        stats: &mut MitaStats,
    ) -> Result<Vec<f32>> {
        self.forward_impl(tokens, batch, valid, registry, pool, scratch, stats, None)
    }

    /// Like [`MitaModel::forward`], additionally overwriting `profile`
    /// with one [`BlockProfile`] per block for **this call**: attention
    /// vs MLP wall time and that block's own routing stats. `stats`
    /// still receives the merged totals, so the two entry points are
    /// interchangeable for existing callers and outputs are
    /// bit-identical (profiling only reads the clock).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_profiled(
        &self,
        tokens: &[i32],
        batch: usize,
        valid: usize,
        registry: &KernelRegistry,
        pool: &WorkspacePool,
        scratch: &mut ModelScratch,
        stats: &mut MitaStats,
        profile: &mut Vec<BlockProfile>,
    ) -> Result<Vec<f32>> {
        self.forward_impl(tokens, batch, valid, registry, pool, scratch, stats, Some(profile))
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_impl(
        &self,
        tokens: &[i32],
        batch: usize,
        valid: usize,
        registry: &KernelRegistry,
        pool: &WorkspacePool,
        scratch: &mut ModelScratch,
        stats: &mut MitaStats,
        mut profile: Option<&mut Vec<BlockProfile>>,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let p = &self.params;
        let (n, d, heads, hid) = (cfg.seq_len, cfg.dim, cfg.heads, cfg.mlp_hidden);
        let per = n * d;
        anyhow::ensure!(
            tokens.len() == batch * n,
            "tokens hold {} ids, want {} for [b={batch}, n={n}]",
            tokens.len(),
            batch * n
        );
        anyhow::ensure!(
            valid >= 1 && valid <= batch,
            "valid rows {valid} out of range 1..={batch}"
        );
        // Resolve every block's kernel up front (fail before any compute).
        let kernels: Vec<&dyn AttentionKernel> = cfg
            .block_kernels
            .iter()
            .map(|name| {
                registry.get(name).with_context(|| {
                    format!(
                        "block kernel {name:?} not in the registry (available: {})",
                        registry.names().join(", ")
                    )
                })
            })
            .collect::<Result<Vec<_>>>()?;
        for (i, &t) in tokens[..valid * n].iter().enumerate() {
            anyhow::ensure!(
                (0..cfg.vocab as i32).contains(&t),
                "token {t} at flat position {i} outside vocab 0..{}",
                cfg.vocab
            );
        }

        // Token embedding + learned positions.
        scratch.h.resize(valid * per, 0.0);
        {
            let (tok_emb, pos_emb) = (&p.tok_emb, &p.pos_emb);
            par_chunks_mut(&mut scratch.h, per, |i, hex| {
                let toks = &tokens[i * n..(i + 1) * n];
                for (t, (&tok, hrow)) in toks.iter().zip(hex.chunks_exact_mut(d)).enumerate() {
                    let erow = &tok_emb[tok as usize * d..(tok as usize + 1) * d];
                    let prow = &pos_emb[t * d..(t + 1) * d];
                    for ((h, &e), &pv) in hrow.iter_mut().zip(erow).zip(prow) {
                        *h = e + pv;
                    }
                }
            });
        }

        scratch.y.resize(valid * per, 0.0);
        scratch.qkv.resize(valid * 3 * per, 0.0);
        scratch.attn.resize(valid * per, 0.0);
        if let Some(prof) = profile.as_mut() {
            prof.clear();
            prof.resize(p.blocks.len(), BlockProfile::default());
        }
        for (bi, (block, kernel)) in p.blocks.iter().zip(&kernels).enumerate() {
            let t_block = Instant::now();
            // Pre-LN.
            {
                let h = &scratch.h;
                par_chunks_mut(&mut scratch.y, per, |i, yex| {
                    layer_norm_rows(&h[i * per..(i + 1) * per], d, &block.ln1_g, &block.ln1_b, yex);
                });
            }
            // Fused Q/K/V projections into `[valid, 3, n, dim]`.
            {
                let y = &scratch.y;
                par_chunks_mut(&mut scratch.qkv, 3 * per, |i, qex| {
                    let yex = &y[i * per..(i + 1) * per];
                    let (qb, rest) = qex.split_at_mut(per);
                    let (kb, vb) = rest.split_at_mut(per);
                    matmul_nt(yex, &block.wq, n, d, d, qb);
                    add_bias_rows(qb, &block.bq);
                    matmul_nt(yex, &block.wk, n, d, d, kb);
                    add_bias_rows(kb, &block.bk);
                    matmul_nt(yex, &block.wv, n, d, d, vb);
                    add_bias_rows(vb, &block.bv);
                });
            }
            // Attention through the block's registry kernel: batched
            // (example × head) work items over the shared pool.
            let prob = AttnProblem::new(valid, heads, n, d, QkvLayout::Fused);
            let data = QkvData::Fused(&scratch.qkv[..valid * 3 * per]);
            // Routing stats go through the per-block accumulator and are
            // merged into the caller's total, so profiled and plain
            // forwards report identical aggregates.
            scratch.block_stats.reset();
            run_batched(
                *kernel,
                &prob,
                &data,
                pool,
                &mut scratch.headout,
                &mut scratch.attn[..valid * per],
                &mut scratch.block_stats,
            );
            stats.merge(&scratch.block_stats);
            // Output projection + residual.
            {
                let attn = &scratch.attn;
                par_chunks_mut(&mut scratch.h, per, |i, hex| {
                    let mut pooled = pool.acquire();
                    let (ws, _) = pooled.parts();
                    let mut proj = ws.take_f32("model.proj", per);
                    matmul_nt(&attn[i * per..(i + 1) * per], &block.wo, n, d, d, &mut proj);
                    add_bias_rows(&mut proj, &block.bo);
                    axpy(1.0, &proj, hex);
                    ws.give_f32("model.proj", proj);
                });
            }
            let t_attn_done = Instant::now();
            // Pre-LN GELU MLP + residual.
            par_chunks_mut(&mut scratch.h, per, |_, hex| {
                let mut pooled = pool.acquire();
                let (ws, _) = pooled.parts();
                let mut ln = ws.take_f32("model.ln2", per);
                layer_norm_rows(hex, d, &block.ln2_g, &block.ln2_b, &mut ln);
                let mut hidden = ws.take_f32("model.hidden", n * hid);
                matmul_nt(&ln, &block.w1, n, hid, d, &mut hidden);
                add_bias_rows(&mut hidden, &block.b1);
                gelu_in_place(&mut hidden);
                let mut mlp = ws.take_f32("model.mlp", per);
                matmul_nt(&hidden, &block.w2, n, d, hid, &mut mlp);
                add_bias_rows(&mut mlp, &block.b2);
                axpy(1.0, &mlp, hex);
                ws.give_f32("model.ln2", ln);
                ws.give_f32("model.hidden", hidden);
                ws.give_f32("model.mlp", mlp);
            });
            if let Some(prof) = profile.as_mut() {
                let entry = &mut prof[bi];
                entry.attn_ns = t_attn_done.duration_since(t_block).as_nanos() as u64;
                entry.mlp_ns = t_attn_done.elapsed().as_nanos() as u64;
                entry.stats.merge(&scratch.block_stats);
            }
        }

        // Final LN → mean-pool over the sequence → classifier head.
        // Padding rows keep their zero logits and are never computed.
        let classes = cfg.classes;
        let mut logits = vec![0.0f32; batch * classes];
        {
            let h = &scratch.h;
            par_chunks_mut(&mut logits[..valid * classes], classes, |i, lex| {
                let mut pooled = pool.acquire();
                let (ws, _) = pooled.parts();
                let mut ln = ws.take_f32("model.lnf", per);
                layer_norm_rows(&h[i * per..(i + 1) * per], d, &p.lnf_g, &p.lnf_b, &mut ln);
                let mut mean = ws.take_f32("model.pool", d);
                mean.fill(0.0);
                for row in ln.chunks_exact(d) {
                    axpy(1.0, row, &mut mean);
                }
                scale_in_place(&mut mean, 1.0 / n as f32);
                let head = p.head_w.chunks_exact(d).zip(&p.head_b);
                for (lc, (wrow, &bc)) in lex.iter_mut().zip(head) {
                    *lc = dot(&mean, wrow) + bc;
                }
                ws.give_f32("model.lnf", ln);
                ws.give_f32("model.pool", mean);
            });
        }
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::kernels::{OP_ATTN_DENSE, OP_ATTN_MITA};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig::new(11, 12, 16, 2, 2, 32, 3, OP_ATTN_MITA)
    }

    fn tokens_for(cfg: &ModelConfig, batch: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..batch * cfg.seq_len).map(|_| rng.below(cfg.vocab) as i32).collect()
    }

    #[test]
    fn layer_norm_normalizes_rows() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0, -1.0, -1.0, -1.0, 7.0];
        let g = vec![1.0f32; 4];
        let b = vec![0.0f32; 4];
        let mut out = vec![0.0f32; 8];
        layer_norm_rows(&x, 4, &g, &b, &mut out);
        for row in out.chunks_exact(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
        // Scale and shift apply per channel.
        let g = vec![2.0f32, 1.0, 1.0, 1.0];
        let b = vec![0.0f32, 5.0, 0.0, 0.0];
        let mut scaled = vec![0.0f32; 8];
        layer_norm_rows(&x, 4, &g, &b, &mut scaled);
        assert!((scaled[0] - 2.0 * out[0]).abs() < 1e-5);
        assert!((scaled[1] - (out[1] + 5.0)).abs() < 1e-5);
    }

    #[test]
    fn gelu_shape() {
        let mut x = vec![0.0f32, 5.0, -5.0, 1.0];
        gelu_in_place(&mut x);
        assert_eq!(x[0], 0.0);
        assert!((x[1] - 5.0).abs() < 1e-3, "gelu(5) ≈ 5, got {}", x[1]);
        assert!(x[2].abs() < 1e-3, "gelu(-5) ≈ 0, got {}", x[2]);
        assert!((x[3] - 0.8412).abs() < 1e-3, "gelu(1) ≈ 0.8412, got {}", x[3]);
    }

    #[test]
    fn forward_shapes_determinism_and_padding() {
        let cfg = tiny_cfg();
        let model = MitaModel::init(cfg.clone(), 5).unwrap();
        let registry = model.registry();
        let pool = WorkspacePool::new();
        let mut scratch = ModelScratch::default();
        let mut stats = MitaStats::default();
        let (batch, valid) = (4usize, 3usize);
        let tokens = tokens_for(&cfg, batch, 1);

        let a = model
            .forward(&tokens, batch, valid, &registry, &pool, &mut scratch, &mut stats)
            .unwrap();
        assert_eq!(a.len(), batch * cfg.classes);
        assert!(a[..valid * cfg.classes].iter().all(|x| x.is_finite()));
        assert!(a[valid * cfg.classes..].iter().all(|&x| x == 0.0), "pad logits stay zero");
        // Every MiTA block routed each valid example's queries per head.
        assert_eq!(stats.calls, cfg.depth * valid * cfg.heads);
        assert_eq!(stats.queries, cfg.depth * valid * cfg.heads * cfg.seq_len);

        // Steady state through warm scratch is bit-identical.
        let b = model
            .forward(&tokens, batch, valid, &registry, &pool, &mut scratch, &mut stats)
            .unwrap();
        assert_eq!(a, b);
        // Fresh scratch too (no stale-state dependence).
        let mut fresh = ModelScratch::default();
        let c = model
            .forward(&tokens, batch, valid, &registry, &pool, &mut fresh, &mut stats)
            .unwrap();
        assert_eq!(a, c);
    }

    #[test]
    fn forward_profiled_matches_forward_and_separates_blocks() {
        let cfg = tiny_cfg();
        let model = MitaModel::init(cfg.clone(), 5).unwrap();
        let registry = model.registry();
        let pool = WorkspacePool::new();
        let mut scratch = ModelScratch::default();
        let (batch, valid) = (3usize, 2usize);
        let tokens = tokens_for(&cfg, batch, 7);

        let mut plain_stats = MitaStats::default();
        let plain = model
            .forward(&tokens, batch, valid, &registry, &pool, &mut scratch, &mut plain_stats)
            .unwrap();

        let mut stats = MitaStats::default();
        let mut profile = vec![BlockProfile { attn_ns: 99, ..Default::default() }];
        let profiled = model
            .forward_profiled(
                &tokens,
                batch,
                valid,
                &registry,
                &pool,
                &mut scratch,
                &mut stats,
                &mut profile,
            )
            .unwrap();

        assert_eq!(plain, profiled, "profiling is observation-only");
        assert_eq!(stats, plain_stats, "merged totals are unchanged");
        assert_eq!(profile.len(), cfg.depth, "stale entries are overwritten");
        let mut merged = MitaStats::default();
        for (bi, bp) in profile.iter().enumerate() {
            assert!(bp.attn_ns > 0, "block {bi} attention span must be non-zero");
            assert!(bp.mlp_ns > 0, "block {bi} MLP span must be non-zero");
            assert_eq!(bp.stats.calls, valid * cfg.heads, "block {bi} records its own calls");
            assert_eq!(bp.stats.queries, valid * cfg.heads * cfg.seq_len);
            merged.merge(&bp.stats);
        }
        assert_eq!(merged.queries, stats.queries, "per-block stats sum to the total");
        assert_eq!(merged.overflow, stats.overflow);
        assert_eq!(merged.expert_counts, stats.expert_counts);
    }

    #[test]
    fn forward_valid_prefix_matches_smaller_batch() {
        let cfg = tiny_cfg();
        let model = MitaModel::init(cfg.clone(), 9).unwrap();
        let registry = model.registry();
        let pool = WorkspacePool::new();
        let mut scratch = ModelScratch::default();
        let mut stats = MitaStats::default();
        let tokens = tokens_for(&cfg, 4, 2);
        let padded = model
            .forward(&tokens, 4, 2, &registry, &pool, &mut scratch, &mut stats)
            .unwrap();
        let exact = model
            .forward(&tokens[..2 * cfg.seq_len], 2, 2, &registry, &pool, &mut scratch, &mut stats)
            .unwrap();
        assert_eq!(&padded[..2 * cfg.classes], exact.as_slice());
    }

    #[test]
    fn forward_rejects_bad_inputs() {
        let cfg = tiny_cfg();
        let model = MitaModel::init(cfg.clone(), 3).unwrap();
        let registry = model.registry();
        let pool = WorkspacePool::new();
        let mut scratch = ModelScratch::default();
        let mut stats = MitaStats::default();
        let tokens = tokens_for(&cfg, 2, 3);
        let mut fails = |toks: &[i32], v: usize, reg: &KernelRegistry| {
            model.forward(toks, 2, v, reg, &pool, &mut scratch, &mut stats).is_err()
        };
        assert!(fails(&tokens[1..], 2, &registry), "wrong token count");
        assert!(fails(&tokens, 0, &registry), "valid = 0");
        assert!(fails(&tokens, 3, &registry), "valid > batch");
        let mut bad = tokens.clone();
        bad[0] = cfg.vocab as i32;
        assert!(fails(&bad, 2, &registry), "out-of-vocab token");
        assert!(fails(&tokens, 2, &KernelRegistry::new()), "kernel missing from registry");
    }

    #[test]
    fn with_kernel_swaps_every_block_and_keeps_params() {
        let model = MitaModel::init(tiny_cfg(), 11).unwrap();
        let dense = model.with_kernel(OP_ATTN_DENSE).unwrap();
        assert!(dense.cfg.block_kernels.iter().all(|k| k == OP_ATTN_DENSE));
        assert_eq!(model.params, dense.params);
        assert!(model.with_kernel("attn.unknown").is_err());
    }
}
