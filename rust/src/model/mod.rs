//! Native MiTA transformer model subsystem.
//!
//! The layer that turns the raw attention kernels into a system that runs
//! whole scenarios: a pure-Rust Transformer (token embedding + learned
//! positions, pre-LN residual blocks, GELU MLP, final LN + classifier
//! head) whose per-block attention dispatches through the
//! [`crate::kernels::api::KernelRegistry`] — `attn.mita` and `attn.dense`
//! are drop-in choices per block — and executes over the shared
//! [`crate::kernels::workspace::WorkspacePool`].
//!
//! - [`config`]: [`ModelConfig`] + the i32 descriptor tensor that makes
//!   checkpoints self-describing.
//! - [`params`]: [`ModelParams`] — deterministic seeded init and the
//!   flat tensor order shared with [`crate::coordinator::checkpoint`].
//! - [`transformer`]: [`MitaModel`] — the forward pass, checkpoint
//!   save/load, and [`ModelScratch`] activation reuse.
//!
//! Upward, [`crate::runtime::NativeBackend`] serves whole models through
//! typed [`ServiceRequest::ModelForward`] requests (bind a checkpoint
//! with [`ServiceRequest::BindCheckpoint`], or seed-init one with
//! [`ServiceRequest::BindInit`] + [`OP_MODEL_INIT`]); `serve_model`
//! drives classification traffic over the LRA tasks through the engine +
//! dynamic batcher, and the network front exposes the same path at
//! `/v1/model/forward` (docs/PROTOCOL.md).
//!
//! [`ServiceRequest::ModelForward`]: crate::service::ServiceRequest::ModelForward
//! [`ServiceRequest::BindCheckpoint`]: crate::service::ServiceRequest::BindCheckpoint
//! [`ServiceRequest::BindInit`]: crate::service::ServiceRequest::BindInit

pub mod config;
pub mod params;
pub mod transformer;

pub use config::ModelConfig;
pub use params::{BlockParams, ModelParams};
pub use transformer::{MitaModel, ModelScratch};

/// Backend op name: whole-model classification forward
/// (tokens `[b, n]` i32 → logits `[b, classes]` f32).
pub const OP_MODEL_FORWARD: &str = "model.forward";
/// Init-op name `bind_init` accepts on the native backend (seed-derived
/// parameters from the backend's model config).
pub const OP_MODEL_INIT: &str = "model.init";
