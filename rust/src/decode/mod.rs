//! L3-decode: the autoregressive decoding subsystem.
//!
//! Everything the repo served before this module is fixed-length
//! classification; decode opens the token-by-token workload class. It
//! has three floors:
//!
//! - [`state`] — [`CausalMitaState`]: incremental landmark pools,
//!   per-landmark top-k expert membership, and argmax routing that are
//!   *updated* as each key appends (the fast-weight-programmer
//!   recurrent view) instead of recomputed per step, plus the exact
//!   full-recompute reference that gates bit-parity at every step.
//! - this file — causal variants of both attention kernels
//!   ([`OP_ATTN_MITA_CAUSAL`] / [`OP_ATTN_DENSE_CAUSAL`]) behind the
//!   existing [`AttentionKernel`] registry. The batch causal-MiTA
//!   kernel drives the *same* incremental state row by row, so batch
//!   row `t` is bit-identical to decode step `t` by construction, and
//!   models configured with causal blocks train/serve/checkpoint
//!   through every existing path.
//! - [`generate`] — [`DecodeSession`]: a KV-cached single-token forward
//!   that mirrors the batched transformer arithmetic, greedy decoding
//!   through the tied token embedding, and per-step timing hooks for
//!   the streaming service surface (`/v1/generate`).
//!
//! Bit-reproducibility discipline is unchanged from the batch kernels:
//! all arithmetic goes through the dispatched SIMD ops, so every lane
//! and thread count produces identical bits (`tests/decode_native.rs`).

pub mod generate;
pub mod state;

pub use generate::{generate, DecodeKernel, DecodeOutcome, DecodeSession};
pub use state::{chunk_width, CausalMitaState};

use crate::kernels::api::{AttentionKernel, MitaStats};
use crate::kernels::linalg::{dot, softmax_in_place_scaled, weighted_row_sum};
use crate::kernels::mita::MitaKernelConfig;
use crate::kernels::workspace::Workspace;

/// Registry name of the causal incremental-MiTA kernel.
pub const OP_ATTN_MITA_CAUSAL: &str = "mita.causal";
/// Registry name of the causal dense (full lower-triangle) kernel.
pub const OP_ATTN_DENSE_CAUSAL: &str = "dense.causal";

/// One causal dense attention row: query `t` over key/value rows
/// `0..=t`. `logits` must be the `t + 1` scratch slots; the 1/√d scale
/// is folded into the softmax exp pass exactly like the batch dense
/// kernel, and the weighted value sum runs over the contiguous row
/// prefix. Shared by the batch kernel and the decode step so the two
/// paths are the same arithmetic by construction.
pub(crate) fn causal_dense_row(
    qrow: &[f32],
    k: &[f32],
    v: &[f32],
    t: usize,
    d: usize,
    scale: f32,
    logits: &mut [f32],
    orow: &mut [f32],
) {
    debug_assert_eq!(logits.len(), t + 1);
    for (j, l) in logits.iter_mut().enumerate() {
        *l = dot(qrow, &k[j * d..(j + 1) * d]);
    }
    softmax_in_place_scaled(logits, scale);
    weighted_row_sum(logits, &v[..(t + 1) * d], d, orow);
}

/// Causal incremental-MiTA attention for one (example × head) work
/// item: runs the decode-time [`CausalMitaState`] over the rows of a
/// batch call, so batched prefill and step-by-step decode share one
/// code path (and one set of bits). State buffers live in the
/// workspace — zero allocations once the pool is warm.
#[derive(Debug, Clone, Default)]
pub struct CausalMitaKernel {
    pub cfg: MitaKernelConfig,
}

impl AttentionKernel for CausalMitaKernel {
    fn name(&self) -> &'static str {
        OP_ATTN_MITA_CAUSAL
    }

    fn run(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        d: usize,
        ws: &mut Workspace,
        out: &mut [f32],
        stats: &mut MitaStats,
    ) {
        assert_eq!(q.len(), n * d, "q must be [n, d]");
        assert_eq!(k.len(), n * d, "k must be [n, d]");
        assert_eq!(v.len(), n * d, "v must be [n, d]");
        assert_eq!(out.len(), n * d, "out must be [n, d]");
        if n == 0 || d == 0 {
            return;
        }
        let mut st = CausalMitaState::from_workspace(ws, n, d, &self.cfg);
        for t in 0..n {
            st.append_key(k);
            st.attend(&q[t * d..(t + 1) * d], k, v, &mut out[t * d..(t + 1) * d]);
        }
        st.record_stats(stats);
        st.into_workspace(ws);
    }
}

/// Causal dense attention: softmax over the full lower triangle, the
/// exact baseline the causal-MiTA kernel approximates.
#[derive(Debug, Clone, Default)]
pub struct CausalDenseKernel;

impl AttentionKernel for CausalDenseKernel {
    fn name(&self) -> &'static str {
        OP_ATTN_DENSE_CAUSAL
    }

    fn run(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        d: usize,
        ws: &mut Workspace,
        out: &mut [f32],
        stats: &mut MitaStats,
    ) {
        assert_eq!(q.len(), n * d, "q must be [n, d]");
        assert_eq!(k.len(), n * d, "k must be [n, d]");
        assert_eq!(v.len(), n * d, "v must be [n, d]");
        assert_eq!(out.len(), n * d, "out must be [n, d]");
        if n == 0 || d == 0 {
            return;
        }
        let scale = 1.0 / (d as f32).sqrt();
        let mut logits = ws.take_f32("dense.causal.logits", n);
        for t in 0..n {
            causal_dense_row(
                &q[t * d..(t + 1) * d],
                k,
                v,
                t,
                d,
                scale,
                &mut logits[..t + 1],
                &mut out[t * d..(t + 1) * d],
            );
        }
        ws.give_f32("dense.causal.logits", logits);
        // No routing structure to report; the call still counts so
        // per-kernel telemetry sees causal dense traffic.
        stats.record(0, 0, &[]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::kernels::linalg::softmax_in_place;

    fn rows(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect()
    }

    #[test]
    fn causal_dense_row_is_masked_softmax_attention() {
        let (n, d) = (6usize, 4usize);
        let mut rng = Rng::new(3);
        let q = rows(&mut rng, n, d);
        let k = rows(&mut rng, n, d);
        let v = rows(&mut rng, n, d);
        let scale = 1.0 / (d as f32).sqrt();
        let mut logits = vec![0.0f32; n];
        let mut orow = vec![0.0f32; d];
        for t in 0..n {
            let qrow = &q[t * d..(t + 1) * d];
            causal_dense_row(qrow, &k, &v, t, d, scale, &mut logits[..t + 1], &mut orow);
            // Naive reference: scale-then-softmax over j ≤ t only.
            let mut want = vec![0.0f32; t + 1];
            for (j, w) in want.iter_mut().enumerate() {
                *w = dot(&q[t * d..(t + 1) * d], &k[j * d..(j + 1) * d]) * scale;
            }
            softmax_in_place(&mut want);
            let mut oref = vec![0.0f32; d];
            for (j, &w) in want.iter().enumerate() {
                for x in 0..d {
                    oref[x] += w * v[j * d + x];
                }
            }
            for x in 0..d {
                assert!((orow[x] - oref[x]).abs() < 1e-5, "row {t} dim {x}");
            }
        }
    }

    #[test]
    fn causal_dense_first_row_attends_only_itself() {
        let (n, d) = (4usize, 4usize);
        let mut rng = Rng::new(9);
        let q = rows(&mut rng, n, d);
        let k = rows(&mut rng, n, d);
        let v = rows(&mut rng, n, d);
        let kern = CausalDenseKernel;
        let mut ws = Workspace::new();
        let mut stats = MitaStats::default();
        let mut out = vec![0.0f32; n * d];
        kern.run(&q, &k, &v, n, d, &mut ws, &mut out, &mut stats);
        // Row 0 can only see key 0 → softmax of one logit → exactly v[0].
        assert_eq!(&out[..d], &v[..d]);
        assert_eq!(stats.calls, 1);
    }

    #[test]
    fn causal_mita_first_row_attends_only_itself() {
        let (n, d) = (9usize, 4usize);
        let mut rng = Rng::new(11);
        let q = rows(&mut rng, n, d);
        let k = rows(&mut rng, n, d);
        let v = rows(&mut rng, n, d);
        let cfg = MitaKernelConfig { m: 3, k: 2, cap_factor: 2, block_q: 4 };
        let kern = CausalMitaKernel { cfg };
        let mut ws = Workspace::new();
        let mut stats = MitaStats::default();
        let mut out = vec![0.0f32; n * d];
        kern.run(&q, &k, &v, n, d, &mut ws, &mut out, &mut stats);
        assert_eq!(&out[..d], &v[..d]);
        assert_eq!(stats.calls, 1);
        assert_eq!(stats.overflow, 0);
    }

    #[test]
    fn causal_kernels_reuse_workspace_when_warm() {
        let (n, d) = (16usize, 8usize);
        let mut rng = Rng::new(17);
        let q = rows(&mut rng, n, d);
        let k = rows(&mut rng, n, d);
        let v = rows(&mut rng, n, d);
        let mut out = vec![0.0f32; n * d];
        for kern in [
            Box::new(CausalMitaKernel::default()) as Box<dyn AttentionKernel>,
            Box::new(CausalDenseKernel) as Box<dyn AttentionKernel>,
        ] {
            let mut ws = Workspace::new();
            let mut stats = MitaStats::default();
            kern.run(&q, &k, &v, n, d, &mut ws, &mut out, &mut stats);
            let warm = (ws.f32_capacity(), ws.usize_capacity(), ws.buffer_count());
            kern.run(&q, &k, &v, n, d, &mut ws, &mut out, &mut stats);
            assert_eq!(
                warm,
                (ws.f32_capacity(), ws.usize_capacity(), ws.buffer_count()),
                "{} grew its workspace on a warm call",
                kern.name()
            );
        }
    }
}
