//! Incremental causal-MiTA decode state: the fast-weight view of routing.
//!
//! The batch MiTA kernel recomputes its whole selection structure
//! (landmarks → scores → top-k experts → routing) per call. Under
//! autoregressive decoding that would be O(n) re-routing per generated
//! token; [`CausalMitaState`] instead *maintains* the structure as keys
//! append — exactly the recurrent fast-weight-programmer reading of
//! attention (Schlag et al., PAPERS.md):
//!
//! - **Landmarks** are fixed-width chunks over the key sequence: with
//!   `w = `[`chunk_width`]`(n_max, m)`, landmark `c` is the mean of key
//!   rows `c·w .. (c+1)·w`. Each arriving key is `axpy`-accumulated into
//!   a running chunk sum; when the chunk fills, the landmark freezes as
//!   `sum · (1/w)`. Chunking (instead of the batch kernel's
//!   window-relative pooling) is what makes landmarks *append-only*: a
//!   new token never shifts an existing landmark, so all downstream
//!   state stays valid.
//! - **Expert membership** per completed landmark is the top-`k` keys by
//!   score `dot(key, landmark) / √d` under the deterministic total order
//!   (score desc, index asc). Maintained by streaming admission: a new
//!   key enters iff its score strictly beats the current worst member
//!   (minimum score, ties resolved to the *larger* index — so an
//!   arriving key that ties never displaces an earlier one, matching the
//!   sort order). When a landmark completes, its membership is built by
//!   replaying all existing keys through the same admission rule.
//! - **Routing**: query `t` goes to the completed landmark with the
//!   largest `dot(q_t, landmark)`, first-max-wins — the same tie-break
//!   as `mita::routing::route_argmax`. Its attended set is the expert's
//!   members ∪ the tail keys not yet covered by a completed landmark
//!   ∪ the query's own position (causal self-attention always sees the
//!   recent past and itself). With no completed landmark yet, the query
//!   attends over the full prefix.
//!
//! Every update is spelled so the incremental path is **bit-identical**
//! to the full-recompute reference ([`recompute_landmarks`],
//! [`recompute_members`], [`recompute_attend`]) at every step: same
//! `axpy` accumulation order, same `dot · scale` expression, same pick
//! order (ascending indices). `tests/decode_native.rs` gates this
//! per step, per kernel, across thread counts and SIMD lanes.

use crate::kernels::linalg::{axpy, dot, scale_in_place};
use crate::kernels::mita::{attend_one, MitaKernelConfig};
use crate::kernels::workspace::Workspace;
use crate::kernels::MitaStats;

/// Fixed landmark chunk width for a session of at most `n_max` keys and
/// (at most) `m` landmarks: `max(1, ceil(n_max / m))`. The number of
/// landmarks that ever complete is `n_max / w ≤ m`.
pub fn chunk_width(n_max: usize, m: usize) -> usize {
    n_max.div_ceil(m.max(1)).max(1)
}

/// Incremental landmark / expert-membership / routing state of one
/// (block, head) causal-MiTA decode stream. See the module docs for the
/// update rules; buffers are either owned (decode sessions) or checked
/// out of a [`Workspace`] (the batch kernel), so steady-state appends
/// never allocate.
#[derive(Debug)]
pub struct CausalMitaState {
    /// Head dimension.
    d: usize,
    /// Landmark chunk width (fixed per session).
    w: usize,
    /// Expert membership size (top-k keys per landmark).
    kk: usize,
    /// Landmarks that can ever complete (`n_max / w`).
    m_max: usize,
    /// Maximum keys this session can hold.
    n_max: usize,
    /// Keys appended so far.
    n_keys: usize,
    /// Completed landmarks (`n_keys / w`).
    m_cur: usize,
    /// Frozen landmark rows `[m_max, d]` (rows `m_cur..` are garbage).
    landmarks: Vec<f32>,
    /// Running sum of the current (incomplete) chunk `[d]`.
    chunk_sum: Vec<f32>,
    /// Flat member key indices `[m_max, kk]` (per landmark, first
    /// `member_len[c]` entries are live, in admission order).
    members: Vec<usize>,
    /// Scores of the corresponding members `[m_max, kk]`.
    member_scores: Vec<f32>,
    /// Live member count per landmark `[m_max]`.
    member_len: Vec<usize>,
    /// Attended-index scratch `[n_max]`.
    picks: Vec<usize>,
    /// Attention-logit scratch `[n_max]`.
    logits: Vec<f32>,
    /// Queries routed to each expert `[m_max]`.
    route_counts: Vec<usize>,
}

/// Workspace buffer names of a pooled [`CausalMitaState`] (the batch
/// kernel checks these out per call and returns them after).
const WS_LANDMARKS: &str = "mita.causal.landmarks";
const WS_CHUNK: &str = "mita.causal.chunk";
const WS_MSCORES: &str = "mita.causal.mscores";
const WS_LOGITS: &str = "mita.causal.logits";
const WS_MEMBERS: &str = "mita.causal.members";
const WS_MLEN: &str = "mita.causal.mlen";
const WS_PICKS: &str = "mita.causal.picks";
const WS_COUNTS: &str = "mita.causal.counts";

impl CausalMitaState {
    /// A fresh owned state for a session of at most `n_max` keys of
    /// dimension `d`. `cfg.m` / `cfg.k` are clamped to `n_max` exactly
    /// like the batch kernels clamp to their sequence length.
    pub fn new(n_max: usize, d: usize, cfg: &MitaKernelConfig) -> Self {
        let (_, kk, w, m_max) = Self::dims(n_max, cfg);
        CausalMitaState {
            d,
            w,
            kk,
            m_max,
            n_max,
            n_keys: 0,
            m_cur: 0,
            landmarks: vec![0.0; m_max * d],
            chunk_sum: vec![0.0; d],
            members: vec![0; m_max * kk],
            member_scores: vec![0.0; m_max * kk],
            member_len: vec![0; m_max],
            picks: vec![0; n_max],
            logits: vec![0.0; n_max],
            route_counts: vec![0; m_max],
        }
    }

    /// Clamped (m, k), chunk width, and completable-landmark count.
    fn dims(n_max: usize, cfg: &MitaKernelConfig) -> (usize, usize, usize, usize) {
        let n = n_max.max(1);
        let m = cfg.m.clamp(1, n);
        let kk = cfg.k.clamp(1, n);
        let w = chunk_width(n_max, m);
        (m, kk, w, n_max / w)
    }

    /// Like [`CausalMitaState::new`], but every buffer comes out of `ws`
    /// (zero-alloc once the workspace is warm). Balance with
    /// [`CausalMitaState::into_workspace`].
    pub fn from_workspace(
        ws: &mut Workspace,
        n_max: usize,
        d: usize,
        cfg: &MitaKernelConfig,
    ) -> Self {
        let (_, kk, w, m_max) = Self::dims(n_max, cfg);
        let mut st = CausalMitaState {
            d,
            w,
            kk,
            m_max,
            n_max,
            n_keys: 0,
            m_cur: 0,
            landmarks: ws.take_f32(WS_LANDMARKS, m_max * d),
            chunk_sum: ws.take_f32(WS_CHUNK, d),
            members: ws.take_usize(WS_MEMBERS, m_max * kk),
            member_scores: ws.take_f32(WS_MSCORES, m_max * kk),
            member_len: ws.take_usize(WS_MLEN, m_max),
            picks: ws.take_usize(WS_PICKS, n_max),
            logits: ws.take_f32(WS_LOGITS, n_max),
            route_counts: ws.take_usize(WS_COUNTS, m_max),
        };
        // Workspace contents are unspecified on take; zero exactly the
        // buffers whose stale values the update rules would read.
        st.chunk_sum.fill(0.0);
        st.member_len.fill(0);
        st.route_counts.fill(0);
        st
    }

    /// Return every buffer of a [`CausalMitaState::from_workspace`]
    /// state, parking capacities for the next call.
    pub fn into_workspace(self, ws: &mut Workspace) {
        ws.give_f32(WS_LANDMARKS, self.landmarks);
        ws.give_f32(WS_CHUNK, self.chunk_sum);
        ws.give_usize(WS_MEMBERS, self.members);
        ws.give_f32(WS_MSCORES, self.member_scores);
        ws.give_usize(WS_MLEN, self.member_len);
        ws.give_usize(WS_PICKS, self.picks);
        ws.give_f32(WS_LOGITS, self.logits);
        ws.give_usize(WS_COUNTS, self.route_counts);
    }

    /// Keys appended so far.
    pub fn num_keys(&self) -> usize {
        self.n_keys
    }

    /// Completed landmarks so far.
    pub fn num_landmarks(&self) -> usize {
        self.m_cur
    }

    /// Landmark chunk width of this session.
    pub fn width(&self) -> usize {
        self.w
    }

    /// Frozen landmark rows `[num_landmarks, d]`.
    pub fn landmarks(&self) -> &[f32] {
        &self.landmarks[..self.m_cur * self.d]
    }

    /// Sorted member key indices of completed landmark `c`.
    pub fn expert_members(&self, c: usize) -> Vec<usize> {
        assert!(c < self.m_cur, "landmark {c} not completed ({} are)", self.m_cur);
        let mut out = self.members[c * self.kk..c * self.kk + self.member_len[c]].to_vec();
        out.sort_unstable();
        out
    }

    /// Queries routed to each (completed) expert so far.
    pub fn route_counts(&self) -> &[usize] {
        &self.route_counts
    }

    /// Record this stream's routing outcome into `stats` (`cap` reports
    /// the per-expert membership size; the causal kernel has no capacity
    /// packing, so overflow is structurally zero).
    pub fn record_stats(&self, stats: &mut MitaStats) {
        stats.record(self.kk, 0, &self.route_counts);
    }

    /// Append key row `n_keys` of `kcache` (row-major `[≥ n_keys+1, d]`):
    /// fold it into the running chunk sum, admit it into every completed
    /// expert, and — if it completes a chunk — freeze the new landmark
    /// and build its membership by replaying keys `0..=n_keys`.
    pub fn append_key(&mut self, kcache: &[f32]) {
        let (d, t) = (self.d, self.n_keys);
        assert!(t < self.n_max, "decode state is full ({} keys)", self.n_max);
        assert!(kcache.len() >= (t + 1) * d, "key cache misses row {t}");
        let krow = &kcache[t * d..(t + 1) * d];
        let scale = 1.0 / (d as f32).sqrt();

        // Stream the new key through every completed expert's admission.
        for c in 0..self.m_cur {
            let score = dot(krow, &self.landmarks[c * d..(c + 1) * d]) * scale;
            self.admit(c, t, score);
        }

        axpy(1.0, krow, &mut self.chunk_sum);
        self.n_keys = t + 1;
        if self.n_keys % self.w == 0 && self.m_cur < self.m_max {
            // Freeze landmark m_cur = chunk mean. The recompute reference
            // accumulates the same rows with the same axpy order into a
            // zeroed buffer, so the frozen bits are identical.
            let c = self.m_cur;
            let lm = &mut self.landmarks[c * d..(c + 1) * d];
            lm.copy_from_slice(&self.chunk_sum);
            scale_in_place(lm, 1.0 / self.w as f32);
            self.chunk_sum.fill(0.0);
            self.m_cur = c + 1;
            // Replay every existing key (index order) through admission:
            // streamed admission equals sort-based top-k under
            // (score desc, index asc), so membership matches the
            // reference as a set.
            let lm = &self.landmarks[c * d..(c + 1) * d];
            // Admission is inlined here (not `self.admit`) because `lm`
            // holds a field borrow of `self.landmarks` across the loop.
            for i in 0..self.n_keys {
                let score = dot(&kcache[i * d..(i + 1) * d], lm) * scale;
                let base = c * self.kk;
                let len = self.member_len[c];
                if len < self.kk {
                    self.members[base + len] = i;
                    self.member_scores[base + len] = score;
                    self.member_len[c] = len + 1;
                } else {
                    let mut worst = 0usize;
                    for j in 1..len {
                        let (sj, sw) =
                            (self.member_scores[base + j], self.member_scores[base + worst]);
                        let later = self.members[base + j] > self.members[base + worst];
                        if sj < sw || (sj == sw && later) {
                            worst = j;
                        }
                    }
                    if score > self.member_scores[base + worst] {
                        self.members[base + worst] = i;
                        self.member_scores[base + worst] = score;
                    }
                }
            }
        }
    }

    /// Admission of key `i` (score `score`) into completed expert `c`:
    /// push below capacity, else replace the worst member (minimum
    /// score, ties to the larger index) iff strictly better.
    fn admit(&mut self, c: usize, i: usize, score: f32) {
        let base = c * self.kk;
        let len = self.member_len[c];
        if len < self.kk {
            self.members[base + len] = i;
            self.member_scores[base + len] = score;
            self.member_len[c] = len + 1;
            return;
        }
        let mut worst = 0usize;
        for j in 1..len {
            let (sj, sw) = (self.member_scores[base + j], self.member_scores[base + worst]);
            if sj < sw || (sj == sw && self.members[base + j] > self.members[base + worst]) {
                worst = j;
            }
        }
        if score > self.member_scores[base + worst] {
            self.members[base + worst] = i;
            self.member_scores[base + worst] = score;
        }
    }

    /// Attend query row `t = num_keys() - 1` over the causal prefix:
    /// route to the best completed landmark (first-max-wins on raw
    /// `dot(q, landmark)` logits), gather its members plus the
    /// uncovered tail plus `t` itself (ascending, deduplicated), and run
    /// the shared expert-attention row. Returns the routed expert id
    /// (`None` while no landmark has completed — the query attended the
    /// full prefix). `out` receives the `[d]` attention output.
    pub fn attend(
        &mut self,
        qrow: &[f32],
        kcache: &[f32],
        vcache: &[f32],
        out: &mut [f32],
    ) -> Option<usize> {
        let (d, n) = (self.d, self.n_keys);
        assert!(n > 0, "attend before any key was appended");
        let t = n - 1;
        assert_eq!(qrow.len(), d, "q row must be [d]");
        assert!(kcache.len() >= n * d && vcache.len() >= n * d, "k/v cache misses rows");
        assert_eq!(out.len(), d, "out row must be [d]");
        let scale = 1.0 / (d as f32).sqrt();

        // Route on raw landmark logits, first-max-wins (the scalar loop
        // order of `routing::route_argmax`).
        let routed = if self.m_cur == 0 {
            None
        } else {
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for c in 0..self.m_cur {
                let v = dot(qrow, &self.landmarks[c * d..(c + 1) * d]);
                if v > best_v {
                    best_v = v;
                    best = c;
                }
            }
            self.route_counts[best] += 1;
            Some(best)
        };

        // Attended set: expert members ∪ uncovered tail ∪ {t}, ascending.
        let mut cnt = 0usize;
        if let Some(e) = routed {
            let base = e * self.kk;
            for j in 0..self.member_len[e] {
                self.picks[cnt] = self.members[base + j];
                cnt += 1;
            }
        }
        for i in self.m_cur * self.w..n {
            self.picks[cnt] = i;
            cnt += 1;
        }
        self.picks[cnt] = t;
        cnt += 1;
        let picks = &mut self.picks[..cnt];
        picks.sort_unstable();
        let mut uniq = 1usize;
        for j in 1..cnt {
            if picks[j] != picks[uniq - 1] {
                picks[uniq] = picks[j];
                uniq += 1;
            }
        }
        attend_one(
            qrow,
            &self.picks[..uniq],
            kcache,
            vcache,
            d,
            scale,
            &mut self.logits[..uniq],
            out,
        );
        routed
    }
}

// ---------------------------------------------------------------------------
// Full-recompute reference (the bit-parity gate)
// ---------------------------------------------------------------------------

/// Recompute every completed landmark from scratch for an `n`-key prefix:
/// returns `[n / w, d]` landmark rows, accumulated with the same zeroed
/// buffer + `axpy`-in-index-order + `1/w` scale the incremental path
/// froze them with, so the bits must match exactly.
pub fn recompute_landmarks(
    kcache: &[f32],
    n: usize,
    d: usize,
    n_max: usize,
    cfg: &MitaKernelConfig,
) -> Vec<f32> {
    let (_, _, w, m_max) = CausalMitaState::dims(n_max, cfg);
    let m_cur = (n / w).min(m_max);
    let mut out = vec![0.0f32; m_cur * d];
    for c in 0..m_cur {
        let lm = &mut out[c * d..(c + 1) * d];
        for i in c * w..(c + 1) * w {
            axpy(1.0, &kcache[i * d..(i + 1) * d], lm);
        }
        scale_in_place(lm, 1.0 / w as f32);
    }
    out
}

/// Recompute each completed landmark's top-k membership from scratch:
/// rank all `n` keys by `dot(key, landmark) · 1/√d` under
/// (score desc, index asc) and keep the best `k`, returned ascending.
pub fn recompute_members(
    kcache: &[f32],
    n: usize,
    d: usize,
    n_max: usize,
    cfg: &MitaKernelConfig,
) -> Vec<Vec<usize>> {
    let (_, kk, _, _) = CausalMitaState::dims(n_max, cfg);
    let landmarks = recompute_landmarks(kcache, n, d, n_max, cfg);
    let scale = 1.0 / (d as f32).sqrt();
    landmarks
        .chunks_exact(d)
        .map(|lm| {
            let mut ranked: Vec<(f32, usize)> = (0..n)
                .map(|i| (dot(&kcache[i * d..(i + 1) * d], lm) * scale, i))
                .collect();
            ranked.sort_by(|a, b| {
                b.0.partial_cmp(&a.0).expect("finite scores").then(a.1.cmp(&b.1))
            });
            ranked.truncate(kk);
            let mut idx: Vec<usize> = ranked.into_iter().map(|(_, i)| i).collect();
            idx.sort_unstable();
            idx
        })
        .collect()
}

/// Recompute query `t`'s routing + attention output from scratch (the
/// step-`t` reference the incremental [`CausalMitaState::attend`] must
/// match bit for bit). Returns `(routed expert, [d] output)`.
#[allow(clippy::too_many_arguments)]
pub fn recompute_attend(
    qrow: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    t: usize,
    d: usize,
    n_max: usize,
    cfg: &MitaKernelConfig,
) -> (Option<usize>, Vec<f32>) {
    let n = t + 1;
    let (_, _, w, _) = CausalMitaState::dims(n_max, cfg);
    let landmarks = recompute_landmarks(kcache, n, d, n_max, cfg);
    let members = recompute_members(kcache, n, d, n_max, cfg);
    let m_cur = members.len();
    let scale = 1.0 / (d as f32).sqrt();

    let routed = if m_cur == 0 {
        None
    } else {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for c in 0..m_cur {
            let v = dot(qrow, &landmarks[c * d..(c + 1) * d]);
            if v > best_v {
                best_v = v;
                best = c;
            }
        }
        Some(best)
    };

    let mut picks: Vec<usize> = match routed {
        Some(e) => members[e].clone(),
        None => Vec::new(),
    };
    picks.extend(m_cur * w..n);
    picks.push(t);
    picks.sort_unstable();
    picks.dedup();

    let mut logits = vec![0.0f32; picks.len()];
    let mut out = vec![0.0f32; d];
    attend_one(qrow, &picks, kcache, vcache, d, scale, &mut logits, &mut out);
    (routed, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn rows(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| rng.range_f32(-1.5, 1.5)).collect()
    }

    #[test]
    fn chunk_width_covers_the_session() {
        assert_eq!(chunk_width(16, 4), 4);
        assert_eq!(chunk_width(17, 4), 5);
        assert_eq!(chunk_width(3, 8), 1);
        assert_eq!(chunk_width(0, 4), 1);
        // m_max · w ≤ n_max < (m_max + 1) · w never over-counts landmarks.
        for n in 1..40usize {
            for m in 1..10usize {
                let w = chunk_width(n, m);
                assert!(n / w <= m, "n={n} m={m} w={w}");
            }
        }
    }

    #[test]
    fn incremental_matches_recompute_at_every_step() {
        let (n, d) = (37usize, 8usize);
        let cfg = MitaKernelConfig { m: 5, k: 6, cap_factor: 2, block_q: 4 };
        let mut rng = Rng::new(71);
        let q = rows(&mut rng, n, d);
        let k = rows(&mut rng, n, d);
        let v = rows(&mut rng, n, d);

        let mut st = CausalMitaState::new(n, d, &cfg);
        let mut out = vec![0.0f32; d];
        for t in 0..n {
            st.append_key(&k[..(t + 1) * d]);
            let routed = st.attend(&q[t * d..(t + 1) * d], &k, &v, &mut out);

            let lms = recompute_landmarks(&k, t + 1, d, n, &cfg);
            assert_eq!(st.landmarks(), &lms[..], "step {t}: landmark bits diverge");
            let members = recompute_members(&k, t + 1, d, n, &cfg);
            assert_eq!(st.num_landmarks(), members.len(), "step {t}");
            for (c, want) in members.iter().enumerate() {
                assert_eq!(&st.expert_members(c), want, "step {t} expert {c} membership");
            }
            let qrow = &q[t * d..(t + 1) * d];
            let (ref_route, ref_out) = recompute_attend(qrow, &k, &v, t, d, n, &cfg);
            assert_eq!(routed, ref_route, "step {t}: routing diverges");
            assert_eq!(out, ref_out[..], "step {t}: attention output bits diverge");
        }
        // Route counts cover every query that saw a completed landmark.
        let routed_total: usize = st.route_counts().iter().sum();
        let first_landmark_at = st.width(); // queries 0..w see none
        assert_eq!(routed_total, n - first_landmark_at);
    }

    #[test]
    fn workspace_state_matches_owned_state() {
        let (n, d) = (24usize, 4usize);
        let cfg = MitaKernelConfig { m: 4, k: 5, cap_factor: 1, block_q: 2 };
        let mut rng = Rng::new(5);
        let q = rows(&mut rng, n, d);
        let k = rows(&mut rng, n, d);
        let v = rows(&mut rng, n, d);

        let mut owned = CausalMitaState::new(n, d, &cfg);
        let mut ws = Workspace::new();
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        // Two passes through the same workspace: the second starts from
        // stale buffer contents and must still match the owned state.
        for pass in 0..2 {
            let mut pooled = CausalMitaState::from_workspace(&mut ws, n, d, &cfg);
            for t in 0..n {
                pooled.append_key(&k);
                let rp = pooled.attend(&q[t * d..(t + 1) * d], &k, &v, &mut b);
                if pass == 0 {
                    owned.append_key(&k);
                    let ro = owned.attend(&q[t * d..(t + 1) * d], &k, &v, &mut a);
                    assert_eq!(ro, rp, "pass {pass} step {t}");
                    assert_eq!(a, b, "pass {pass} step {t}");
                }
            }
            pooled.into_workspace(&mut ws);
        }
        let warm = (ws.f32_capacity(), ws.usize_capacity(), ws.buffer_count());
        let st = CausalMitaState::from_workspace(&mut ws, n, d, &cfg);
        st.into_workspace(&mut ws);
        assert_eq!(
            warm,
            (ws.f32_capacity(), ws.usize_capacity(), ws.buffer_count()),
            "steady-state workspace reuse must not grow"
        );
    }

    #[test]
    fn stats_record_membership_capacity_and_routes() {
        let (n, d) = (12usize, 4usize);
        let cfg = MitaKernelConfig { m: 3, k: 4, cap_factor: 2, block_q: 2 };
        let mut rng = Rng::new(13);
        let q = rows(&mut rng, n, d);
        let k = rows(&mut rng, n, d);
        let v = rows(&mut rng, n, d);
        let mut st = CausalMitaState::new(n, d, &cfg);
        let mut out = vec![0.0f32; d];
        for t in 0..n {
            st.append_key(&k);
            st.attend(&q[t * d..(t + 1) * d], &k, &v, &mut out);
        }
        let mut stats = MitaStats::default();
        st.record_stats(&mut stats);
        assert_eq!(stats.calls, 1);
        assert_eq!(stats.cap, 4);
        assert_eq!(stats.overflow, 0, "causal streaming admission never overflows");
        assert_eq!(stats.queries, st.route_counts().iter().sum::<usize>());
    }
}
