//! KV-cached token-by-token generation over the native transformer.
//!
//! A [`DecodeSession`] advances one token at a time: the single-token
//! forward mirrors the batched [`MitaModel`] block arithmetic operation
//! for operation (`matmul_nt` with `p = 1` computes each output element
//! with the same hoisted dispatched `dot` as a batch row, LayerNorm /
//! bias / GELU reuse the exact `model::transformer` helpers), while
//! attention reads the per-(block, head) K/V caches — incremental
//! [`CausalMitaState`] for MiTA blocks, the shared
//! [`causal_dense_row`](super::causal_dense_row) for dense blocks. All
//! caches and scratch are preallocated at session start, so the
//! steady-state decode loop never allocates.
//!
//! Generation is greedy through the **tied token embedding**: the
//! classifier head is `[classes, d]` (too narrow to emit tokens), so
//! next-token logits are `dot(lnf(h_t), tok_emb[v])` over the
//! vocabulary, argmax with first-max-wins (the registry's deterministic
//! tie-break: lowest index). Everything runs through the dispatched
//! SIMD ops, so generated token streams are bit-identical across
//! lanes and thread counts.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::kernels::api::BlockProfile;
use crate::kernels::linalg::{axpy, dot, gather_head, matmul_nt, scatter_head};
use crate::kernels::profile::{self, Op};
use crate::kernels::{OP_ATTN_DENSE, OP_ATTN_MITA};
use crate::model::transformer::{add_bias_rows, gelu_in_place, layer_norm_rows};
use crate::model::MitaModel;

use super::state::CausalMitaState;
use super::{causal_dense_row, OP_ATTN_DENSE_CAUSAL, OP_ATTN_MITA_CAUSAL};

/// Which causal attention path a block decodes through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeKernel {
    /// Incremental causal MiTA (landmark/expert state updated per key).
    Mita,
    /// Full lower-triangle softmax attention.
    Dense,
}

impl DecodeKernel {
    /// Map a registry kernel name to its causal decode path. Both the
    /// batch names (`attn.mita` / `attn.dense`) and the causal names
    /// (`mita.causal` / `dense.causal`) are accepted, so existing
    /// classification checkpoints decode without re-tagging blocks.
    pub fn from_name(name: &str) -> Result<DecodeKernel> {
        match name {
            OP_ATTN_MITA | OP_ATTN_MITA_CAUSAL => Ok(DecodeKernel::Mita),
            OP_ATTN_DENSE | OP_ATTN_DENSE_CAUSAL => Ok(DecodeKernel::Dense),
            other => bail!("no causal decode path for attention kernel {other:?}"),
        }
    }

    /// The causal registry name of this path.
    pub fn causal_op(&self) -> &'static str {
        match self {
            DecodeKernel::Mita => OP_ATTN_MITA_CAUSAL,
            DecodeKernel::Dense => OP_ATTN_DENSE_CAUSAL,
        }
    }
}

/// Result of one [`generate`] call.
#[derive(Debug, Clone)]
pub struct DecodeOutcome {
    /// Prompt followed by the generated tokens (`prompt.len() + max_tokens`).
    pub tokens: Vec<i32>,
    /// Prompt length (the prefill-vs-decode split point).
    pub prefill_tokens: usize,
    /// Wall time of the prefill pass (prompt forwards + first argmax).
    pub prefill_ns: u64,
    /// Wall time of the decode loop (everything after the first token).
    pub decode_ns: u64,
    /// Per-block attention/MLP time + MiTA routing stats, accumulated
    /// over every step of the session.
    pub blocks: Vec<BlockProfile>,
}

/// One autoregressive decoding stream over a borrowed model: per-head
/// K/V caches, per-head incremental MiTA states, and all single-token
/// scratch, preallocated for `n_max` positions.
pub struct DecodeSession<'m> {
    model: &'m MitaModel,
    /// Per-block causal attention path.
    kernels: Vec<DecodeKernel>,
    /// Positions this session can hold.
    n_max: usize,
    /// Tokens consumed so far (= the next position).
    pos: usize,
    /// Per-(block × head) key cache rows `[n_max, dh]`, filled to `pos`.
    k_cache: Vec<Vec<f32>>,
    /// Per-(block × head) value cache rows, same layout.
    v_cache: Vec<Vec<f32>>,
    /// Incremental MiTA state per (block × head); `None` on dense blocks.
    states: Vec<Option<CausalMitaState>>,
    /// Residual stream `[d]`.
    h: Vec<f32>,
    /// Pre-LN output `[d]`.
    y: Vec<f32>,
    /// Q/K/V projection rows `[d]` each.
    qb: Vec<f32>,
    kb: Vec<f32>,
    vb: Vec<f32>,
    /// Per-head query row and attention output row `[dh]`.
    qh: Vec<f32>,
    oh: Vec<f32>,
    /// Merged attention row `[d]`, then projection/MLP scratch.
    attn: Vec<f32>,
    proj: Vec<f32>,
    ln: Vec<f32>,
    hidden: Vec<f32>,
    mlp: Vec<f32>,
    lnf: Vec<f32>,
    /// Dense-row logit scratch `[n_max]`.
    row_logits: Vec<f32>,
    /// Per-block timing + routing accumulators.
    profiles: Vec<BlockProfile>,
}

impl<'m> DecodeSession<'m> {
    /// A fresh session holding at most `n_max` positions. `kernel`
    /// overrides every block's decode path; `None` derives it per block
    /// from the model config.
    pub fn new(model: &'m MitaModel, kernel: Option<DecodeKernel>, n_max: usize) -> Result<Self> {
        let cfg = &model.cfg;
        cfg.validate()?;
        anyhow::ensure!(n_max >= 1, "decode session needs at least one position");
        anyhow::ensure!(
            n_max <= cfg.seq_len,
            "decode session wants {n_max} positions, model holds {} learned positions",
            cfg.seq_len
        );
        let kernels: Vec<DecodeKernel> = match kernel {
            Some(k) => vec![k; cfg.depth],
            None => cfg
                .block_kernels
                .iter()
                .map(|name| DecodeKernel::from_name(name))
                .collect::<Result<Vec<_>>>()?,
        };
        let (d, dh, heads, hid) = (cfg.dim, cfg.head_dim(), cfg.heads, cfg.mlp_hidden);
        let slots = cfg.depth * heads;
        let states = kernels
            .iter()
            .flat_map(|&k| std::iter::repeat(k).take(heads))
            .map(|k| match k {
                DecodeKernel::Mita => Some(CausalMitaState::new(n_max, dh, &cfg.mita)),
                DecodeKernel::Dense => None,
            })
            .collect();
        Ok(DecodeSession {
            model,
            kernels,
            n_max,
            pos: 0,
            k_cache: vec![Vec::with_capacity(n_max * dh); slots],
            v_cache: vec![Vec::with_capacity(n_max * dh); slots],
            states,
            h: vec![0.0; d],
            y: vec![0.0; d],
            qb: vec![0.0; d],
            kb: vec![0.0; d],
            vb: vec![0.0; d],
            qh: vec![0.0; dh],
            oh: vec![0.0; dh],
            attn: vec![0.0; d],
            proj: vec![0.0; d],
            ln: vec![0.0; d],
            hidden: vec![0.0; hid],
            mlp: vec![0.0; d],
            lnf: vec![0.0; d],
            row_logits: vec![0.0; n_max],
            profiles: vec![BlockProfile::default(); cfg.depth],
        })
    }

    /// Positions consumed so far.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Advance one token: embed at the next position, run every block
    /// with cached keys/values, and leave the residual stream in place
    /// for [`DecodeSession::greedy_token`].
    pub fn step(&mut self, tok: i32) -> Result<()> {
        // Copy the `&'m` out so the config/params borrows don't pin
        // `self` while the scratch fields are mutated below.
        let model = self.model;
        let cfg = &model.cfg;
        let p = &model.params;
        let (d, dh, heads, hid) = (cfg.dim, cfg.head_dim(), cfg.heads, cfg.mlp_hidden);
        let t = self.pos;
        anyhow::ensure!(t < self.n_max, "decode session is full ({} positions)", self.n_max);
        anyhow::ensure!(
            (0..cfg.vocab as i32).contains(&tok),
            "token {tok} at position {t} outside vocab 0..{}",
            cfg.vocab
        );

        // Token embedding + learned position (same elementwise add as the
        // batched embedding pass).
        let erow = &p.tok_emb[tok as usize * d..(tok as usize + 1) * d];
        let prow = &p.pos_emb[t * d..(t + 1) * d];
        for ((h, &e), &pv) in self.h.iter_mut().zip(erow).zip(prow) {
            *h = e + pv;
        }

        let scale = 1.0 / (dh as f32).sqrt();
        for (bi, block) in p.blocks.iter().enumerate() {
            let t_block = Instant::now();
            // Pre-LN + Q/K/V projections (p = 1 rows of the batch GEMMs).
            layer_norm_rows(&self.h, d, &block.ln1_g, &block.ln1_b, &mut self.y);
            matmul_nt(&self.y, &block.wq, 1, d, d, &mut self.qb);
            add_bias_rows(&mut self.qb, &block.bq);
            matmul_nt(&self.y, &block.wk, 1, d, d, &mut self.kb);
            add_bias_rows(&mut self.kb, &block.bk);
            matmul_nt(&self.y, &block.wv, 1, d, d, &mut self.vb);
            add_bias_rows(&mut self.vb, &block.bv);

            for hh in 0..heads {
                let slot = bi * heads + hh;
                gather_head(&self.qb, 1, d, dh, hh, &mut self.qh);
                gather_head(&self.kb, 1, d, dh, hh, &mut self.oh);
                self.k_cache[slot].extend_from_slice(&self.oh);
                gather_head(&self.vb, 1, d, dh, hh, &mut self.oh);
                self.v_cache[slot].extend_from_slice(&self.oh);
                match self.kernels[bi] {
                    DecodeKernel::Mita => {
                        let st = self.states[slot].as_mut().expect("MiTA block owns a state");
                        st.append_key(&self.k_cache[slot]);
                        st.attend(
                            &self.qh,
                            &self.k_cache[slot],
                            &self.v_cache[slot],
                            &mut self.oh,
                        );
                    }
                    DecodeKernel::Dense => causal_dense_row(
                        &self.qh,
                        &self.k_cache[slot],
                        &self.v_cache[slot],
                        t,
                        dh,
                        scale,
                        &mut self.row_logits[..t + 1],
                        &mut self.oh,
                    ),
                }
                scatter_head(&self.oh, 1, d, dh, hh, &mut self.attn);
            }

            // Output projection + residual.
            matmul_nt(&self.attn, &block.wo, 1, d, d, &mut self.proj);
            add_bias_rows(&mut self.proj, &block.bo);
            axpy(1.0, &self.proj, &mut self.h);
            let t_attn_done = Instant::now();

            // Pre-LN GELU MLP + residual.
            layer_norm_rows(&self.h, d, &block.ln2_g, &block.ln2_b, &mut self.ln);
            matmul_nt(&self.ln, &block.w1, 1, hid, d, &mut self.hidden);
            add_bias_rows(&mut self.hidden, &block.b1);
            gelu_in_place(&mut self.hidden);
            matmul_nt(&self.hidden, &block.w2, 1, d, hid, &mut self.mlp);
            add_bias_rows(&mut self.mlp, &block.b2);
            axpy(1.0, &self.mlp, &mut self.h);

            let prof = &mut self.profiles[bi];
            prof.attn_ns += t_attn_done.duration_since(t_block).as_nanos() as u64;
            prof.mlp_ns += t_attn_done.elapsed().as_nanos() as u64;
        }
        self.pos = t + 1;
        Ok(())
    }

    /// Greedy next token from the current residual stream: final LN,
    /// then logits through the tied token embedding, argmax with
    /// first-max-wins (lowest index on exact ties).
    pub fn greedy_token(&mut self) -> i32 {
        let model = self.model;
        let (cfg, p) = (&model.cfg, &model.params);
        let d = cfg.dim;
        layer_norm_rows(&self.h, d, &p.lnf_g, &p.lnf_b, &mut self.lnf);
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (v, erow) in p.tok_emb.chunks_exact(d).enumerate() {
            let s = dot(&self.lnf, erow);
            if s > best_v {
                best_v = s;
                best = v;
            }
        }
        best as i32
    }

    /// Close the session: fold each MiTA head's routing counts (and one
    /// call per dense head, mirroring the batch kernel's accounting)
    /// into the per-block profiles and return them.
    pub fn finish(mut self) -> Vec<BlockProfile> {
        let heads = self.model.cfg.heads;
        for (bi, prof) in self.profiles.iter_mut().enumerate() {
            for hh in 0..heads {
                match &self.states[bi * heads + hh] {
                    Some(st) => st.record_stats(&mut prof.stats),
                    None => prof.stats.record(0, 0, &[]),
                }
            }
        }
        self.profiles
    }
}

/// Generate `max_tokens` tokens greedily from `prompt`. `on_step(index,
/// token, latency_ns)` fires once per generated token, in order; step 0
/// reports zero latency because its compute is the tail of the prefill
/// pass (counted in [`DecodeOutcome::prefill_ns`]), every later step
/// reports the wall time of the forward that produced it. Requires
/// `prompt.len() + max_tokens <= cfg.seq_len` (learned positions bound
/// the horizon).
pub fn generate(
    model: &MitaModel,
    kernel: Option<DecodeKernel>,
    prompt: &[i32],
    max_tokens: usize,
    on_step: &mut dyn FnMut(usize, i32, u64),
) -> Result<DecodeOutcome> {
    let cfg = &model.cfg;
    let p = prompt.len();
    anyhow::ensure!(p >= 1, "generation needs a non-empty prompt");
    anyhow::ensure!(max_tokens >= 1, "max_tokens must be at least 1");
    anyhow::ensure!(
        p + max_tokens <= cfg.seq_len,
        "prompt ({p}) + max_tokens ({max_tokens}) exceeds the model's {} learned positions",
        cfg.seq_len
    );

    // Positions actually consumed: p prompt tokens + max_tokens - 1
    // generated feedbacks (the last token is emitted, never re-read).
    let mut sess = DecodeSession::new(model, kernel, p + max_tokens - 1)?;
    let t0 = Instant::now();
    for &tok in prompt {
        sess.step(tok)?;
    }
    let mut next = sess.greedy_token();
    let prefill_ns = t0.elapsed().as_nanos() as u64;
    profile::record(Op::DecodePrefill, prefill_ns);

    let mut tokens = prompt.to_vec();
    tokens.push(next);
    on_step(0, next, 0);
    let decode_t0 = Instant::now();
    let mut t_prev = decode_t0;
    for s in 1..max_tokens {
        sess.step(next)?;
        next = sess.greedy_token();
        tokens.push(next);
        let now = Instant::now();
        let step_ns = now.duration_since(t_prev).as_nanos() as u64;
        profile::record(Op::DecodeStep, step_ns);
        on_step(s, next, step_ns);
        t_prev = now;
    }
    let decode_ns = decode_t0.elapsed().as_nanos() as u64;
    Ok(DecodeOutcome {
        tokens,
        prefill_tokens: p,
        prefill_ns,
        decode_ns,
        blocks: sess.finish(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny_model(kernel: &str) -> MitaModel {
        MitaModel::init(ModelConfig::new(13, 24, 16, 2, 2, 32, 3, kernel), 7).unwrap()
    }

    #[test]
    fn generate_is_deterministic_and_respects_bounds() {
        let model = tiny_model(OP_ATTN_MITA);
        let prompt = [1i32, 5, 2, 9];
        let mut steps = Vec::new();
        let out = generate(&model, None, &prompt, 6, &mut |i, t, _| steps.push((i, t))).unwrap();
        assert_eq!(out.tokens.len(), prompt.len() + 6);
        assert_eq!(&out.tokens[..4], &prompt);
        assert_eq!(out.prefill_tokens, 4);
        assert_eq!(steps.len(), 6);
        assert!(steps.iter().enumerate().all(|(i, &(si, _))| i == si), "steps arrive in order");
        assert!(out.tokens[4..].iter().all(|&t| (0..13).contains(&t)), "tokens stay in vocab");
        assert_eq!(out.blocks.len(), 2);
        // MiTA blocks report per-head routing calls; byte-for-byte rerun.
        assert_eq!(out.blocks[0].stats.calls, model.cfg.heads);
        let again = generate(&model, None, &prompt, 6, &mut |_, _, _| {}).unwrap();
        assert_eq!(out.tokens, again.tokens, "greedy decode is deterministic");
    }

    #[test]
    fn kernel_override_and_dense_path_work() {
        let model = tiny_model(OP_ATTN_MITA);
        let prompt = [3i32, 3, 7];
        let dense = generate(&model, Some(DecodeKernel::Dense), &prompt, 4, &mut |_, _, _| {})
            .unwrap();
        assert_eq!(dense.tokens.len(), 7);
        // Dense profiles carry call counts but no routed queries.
        assert_eq!(dense.blocks[0].stats.calls, model.cfg.heads);
        assert_eq!(dense.blocks[0].stats.queries, 0);
        // The dense-tagged model derives the same path without override.
        let dense_model = tiny_model(OP_ATTN_DENSE);
        let derived = generate(&dense_model, None, &prompt, 4, &mut |_, _, _| {}).unwrap();
        assert_eq!(derived.tokens.len(), 7);
    }

    #[test]
    fn generate_rejects_bad_calls() {
        let model = tiny_model(OP_ATTN_MITA);
        let mut sink = |_: usize, _: i32, _: u64| {};
        assert!(generate(&model, None, &[], 4, &mut sink).is_err(), "empty prompt");
        assert!(generate(&model, None, &[1], 0, &mut sink).is_err(), "zero tokens");
        let long: Vec<i32> = vec![1; 24];
        assert!(generate(&model, None, &long, 1, &mut sink).is_err(), "horizon overflow");
        assert!(generate(&model, None, &[99], 2, &mut sink).is_err(), "out-of-vocab prompt");
    }

    #[test]
    fn decode_kernel_name_mapping() {
        assert_eq!(DecodeKernel::from_name(OP_ATTN_MITA).unwrap(), DecodeKernel::Mita);
        assert_eq!(DecodeKernel::from_name(OP_ATTN_MITA_CAUSAL).unwrap(), DecodeKernel::Mita);
        assert_eq!(DecodeKernel::from_name(OP_ATTN_DENSE).unwrap(), DecodeKernel::Dense);
        assert_eq!(DecodeKernel::from_name(OP_ATTN_DENSE_CAUSAL).unwrap(), DecodeKernel::Dense);
        assert!(DecodeKernel::from_name("attn.other").is_err());
        assert_eq!(DecodeKernel::Mita.causal_op(), OP_ATTN_MITA_CAUSAL);
        assert_eq!(DecodeKernel::Dense.causal_op(), OP_ATTN_DENSE_CAUSAL);
    }
}
