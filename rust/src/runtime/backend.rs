//! Execution-backend abstraction: one interface over the PJRT artifact
//! path and the native CPU kernel path.
//!
//! Since the typed-service redesign, a backend executes exactly one
//! thing: a validated [`ServiceRequest`]. The stringly-typed `run(op,
//! binding, inputs)` surface — with its magic one-element i32
//! "valid-rows marker" tensor — is gone; shapes, kernel ids, and padding
//! are parsed once at the service boundary ([`crate::service`]) and
//! backends consume typed requests, answering with typed
//! [`ServiceResponse`]s or [`ServiceError`]s carrying stable codes.
//!
//! - [`PjrtBackend`]: manifest-driven AOT artifacts. Serves
//!   [`ServiceRequest::Artifact`] (and the two bind classes); typed
//!   attention / model requests answer `unavailable` — compiled bundles
//!   only exist as artifacts.
//! - [`NativeBackend`]: the pure-Rust attention stack in
//!   [`crate::kernels`] — runs anywhere. [`ServiceRequest::Attention`]
//!   resolves through a [`KernelRegistry`] and fans out as
//!   (example × head) work items over a [`WorkspacePool`] (see
//!   [`run_batched`]); [`ServiceRequest::ModelForward`] runs a bound
//!   [`MitaModel`](crate::model::MitaModel) end to end. Rows past the
//!   request's typed `valid_rows` are zero-filled and never computed.
//!
//! Backends are built *inside* the engine thread from a [`BackendSpec`]
//! (PJRT handles are not `Send`, so the spec crosses the thread boundary,
//! not the backend).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::kernels::api::{
    merge_block_profiles, run_batched, AttnProblem, BlockProfile, KernelRegistry, MitaStats,
};
use crate::kernels::workspace::WorkspacePool;
use crate::kernels::MitaKernelConfig;
use crate::model::{MitaModel, ModelConfig, ModelScratch};
use crate::runtime::client::{Runtime, RuntimeStats};
use crate::runtime::tensor::Tensor;
use crate::service::{
    resolve_valid_rows, BindingId, GenerateParams, KernelId, QkvBatch, ServiceError,
    ServiceRequest, ServiceResponse, ServiceResult, ServiceStats, StepEvent,
};

pub use crate::kernels::api::{OP_ATTN_DENSE, OP_ATTN_MITA};
pub use crate::model::{OP_MODEL_FORWARD, OP_MODEL_INIT};

/// Cap on distinct parameter bindings per backend. Binding creation is
/// wire-reachable through the network front, so the maps must not grow
/// without bound; rebinding an existing key is always allowed.
pub const MAX_BINDINGS: usize = 64;

fn check_binding_capacity<V>(
    map: &HashMap<String, V>,
    key: &BindingId,
) -> ServiceResult<()> {
    if !map.contains_key(key.as_str()) && map.len() >= MAX_BINDINGS {
        return Err(ServiceError::overloaded(format!(
            "binding capacity reached ({MAX_BINDINGS} keys); rebind an existing key"
        )));
    }
    Ok(())
}

/// A place computations run: typed service requests over host tensors,
/// with named parameter bindings kept backend-side between calls.
pub trait Backend {
    /// Short identifier ("pjrt" / "native") for logs and reports.
    fn name(&self) -> &'static str;

    /// Prepare an op off the hot path (compile an artifact, warm caches).
    fn warmup(&self, op: &str) -> Result<()>;

    /// Execute one typed request. Every failure is a [`ServiceError`]
    /// with a stable code — callers (the engine, the network front) can
    /// surface it without string matching.
    fn execute(&mut self, req: ServiceRequest) -> ServiceResult<ServiceResponse>;

    /// Execute one typed request, reporting incremental progress. Only
    /// [`ServiceRequest::Generate`] produces step events (one per decoded
    /// token, emitted *before* the final response); every other request
    /// class — and any backend without streaming support — behaves
    /// exactly like [`Backend::execute`].
    fn execute_streaming(
        &mut self,
        req: ServiceRequest,
        _on_step: &mut dyn FnMut(StepEvent),
    ) -> ServiceResult<ServiceResponse> {
        self.execute(req)
    }

    /// Drain the per-block profile of the most recent model-forward
    /// execute, if the backend records one. Backends without per-block
    /// instrumentation return an empty vec; the engine attaches the
    /// result to the request's trace.
    fn take_block_profiles(&mut self) -> Vec<BlockProfile> {
        Vec::new()
    }

    /// Drain the decode-loop wall time of the most recent execute (0 for
    /// anything but a [`ServiceRequest::Generate`], and for backends
    /// without a decode path). The engine folds it into the request's
    /// profile so traces can split prefill from decode.
    fn take_decode_ns(&mut self) -> u64 {
        0
    }
}

/// Serializable description of a backend, safe to send to the engine
/// thread that will actually construct it.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    /// AOT artifact execution from `artifacts_dir` (PJRT).
    Pjrt { artifacts_dir: PathBuf },
    /// Native CPU attention kernels.
    Native(NativeAttnConfig),
}

impl BackendSpec {
    /// Construct the backend. Called on the thread that will own it.
    pub fn create(&self) -> Result<Box<dyn Backend>> {
        match self {
            BackendSpec::Pjrt { artifacts_dir } => {
                Ok(Box::new(PjrtBackend::load(artifacts_dir.clone())?))
            }
            BackendSpec::Native(cfg) => Ok(Box::new(NativeBackend::new(cfg.clone()))),
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// The artifact-execution backend: wraps [`Runtime`] and keeps parameter
/// bindings as device-format literals so the hot path never re-converts
/// weights.
pub struct PjrtBackend {
    runtime: Runtime,
    bindings: HashMap<String, Vec<xla::Literal>>,
}

impl PjrtBackend {
    pub fn load(artifacts_dir: PathBuf) -> Result<Self> {
        Ok(PjrtBackend { runtime: Runtime::load(artifacts_dir)?, bindings: HashMap::new() })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    fn run_artifact(
        &self,
        artifact: &str,
        binding: Option<&BindingId>,
        inputs: &[Tensor],
    ) -> ServiceResult<Vec<Tensor>> {
        // Resolve the artifact name up front so "no such artifact" gets
        // its own code instead of a generic execution failure.
        if self.runtime.manifest().artifact(artifact).is_err() {
            return Err(ServiceError::UnknownOp(format!(
                "no artifact {artifact:?} in the manifest"
            )));
        }
        match binding {
            None => self.runtime.run(artifact, inputs).map_err(ServiceError::internal),
            Some(key) => {
                let params = self.bindings.get(key.as_str()).ok_or_else(|| {
                    ServiceError::UnboundParams(format!("no binding {key:?}"))
                })?;
                let outs = self
                    .runtime
                    .run_hybrid(artifact, params, inputs)
                    .map_err(ServiceError::internal)?;
                outs.iter()
                    .map(Tensor::from_literal)
                    .collect::<Result<_>>()
                    .map_err(ServiceError::internal)
            }
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn warmup(&self, op: &str) -> Result<()> {
        self.runtime.warmup(op)
    }

    fn execute(&mut self, req: ServiceRequest) -> ServiceResult<ServiceResponse> {
        match req {
            ServiceRequest::Artifact { artifact, binding, inputs } => {
                let outputs = self.run_artifact(&artifact, binding.as_ref(), &inputs)?;
                Ok(ServiceResponse::Artifact { outputs })
            }
            ServiceRequest::BindCheckpoint { binding, params } => {
                check_binding_capacity(&self.bindings, &binding)?;
                let lits: Vec<xla::Literal> = params
                    .iter()
                    .map(Tensor::to_literal)
                    .collect::<Result<_>>()
                    .map_err(ServiceError::internal)?;
                self.bindings.insert(binding.as_str().to_string(), lits);
                Ok(ServiceResponse::Bound { binding })
            }
            ServiceRequest::BindInit { binding, init_op, seed, param_count } => {
                check_binding_capacity(&self.bindings, &binding)?;
                if self.runtime.manifest().artifact(&init_op).is_err() {
                    return Err(ServiceError::UnknownOp(format!(
                        "no init artifact {init_op:?} in the manifest"
                    )));
                }
                let seed_lit =
                    Tensor::scalar_i32(seed).to_literal().map_err(ServiceError::internal)?;
                let mut state = self
                    .runtime
                    .run_literals(&init_op, &[seed_lit])
                    .map_err(ServiceError::internal)?;
                // param_count == 0 (the wire default) keeps every init
                // output — truncating to an empty parameter set would
                // "succeed" into a useless binding.
                if param_count > 0 {
                    if state.len() < param_count {
                        return Err(ServiceError::BadShape(format!(
                            "init returned {} < {param_count} outputs",
                            state.len()
                        )));
                    }
                    state.truncate(param_count);
                }
                self.bindings.insert(binding.as_str().to_string(), state);
                Ok(ServiceResponse::Bound { binding })
            }
            ServiceRequest::Stats { .. } => Ok(ServiceResponse::Stats(ServiceStats {
                runtime: self.runtime.stats(),
                mita: None,
                blocks: Vec::new(),
            })),
            ServiceRequest::Metrics => Err(ServiceError::Unavailable(
                "serving metrics are assembled by the replica pool, not a backend".into(),
            )),
            other @ (ServiceRequest::Attention { .. }
            | ServiceRequest::ModelForward { .. }
            | ServiceRequest::Generate { .. }) => Err(ServiceError::Unavailable(format!(
                "pjrt backend serves compiled artifacts; {:?} requests need the native \
                 backend",
                other.kind()
            ))),
        }
    }
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Shape + kernel configuration of the native attention workload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NativeAttnConfig {
    /// Sequence length of the serving workload (used to build request
    /// pools; ops themselves take their shape from the request tensors).
    pub n: usize,
    /// Model dimension (`heads · head_dim`).
    pub dim: usize,
    pub heads: usize,
    pub mita: MitaKernelConfig,
    /// Whole-model configuration, when the backend should be able to
    /// seed-init a [`MitaModel`] via [`ServiceRequest::BindInit`] +
    /// [`OP_MODEL_INIT`] (checkpoints bound with
    /// [`ServiceRequest::BindCheckpoint`] are self-describing and need no
    /// config here).
    pub model: Option<ModelConfig>,
}

impl NativeAttnConfig {
    /// Paper-flavored defaults for a (n, dim, heads) shape.
    pub fn for_shape(n: usize, dim: usize, heads: usize) -> Self {
        NativeAttnConfig { n, dim, heads, mita: MitaKernelConfig::for_seq(n), model: None }
    }

    /// Attach a whole-model config (enables `BindInit`-seeded models).
    pub fn with_model(mut self, model: ModelConfig) -> Self {
        self.model = Some(model);
        self
    }
}

/// The native CPU backend: [`ServiceRequest::Attention`] resolves through
/// a [`KernelRegistry`] and executes as batched (example × head) work
/// items with pooled per-thread workspaces; [`ServiceRequest::ModelForward`]
/// runs a bound [`MitaModel`]'s classification forward. Output shapes:
/// `[b, n, dim]` for attention, `[b, classes]` for model logits — rows
/// past the request's `valid_rows` are zero-filled and never computed.
pub struct NativeBackend {
    cfg: NativeAttnConfig,
    registry: KernelRegistry,
    pool: WorkspacePool,
    /// Head-major staging buffer reused across calls.
    headout: RefCell<Vec<f32>>,
    stats: RefCell<RuntimeStats>,
    mita: RefCell<MitaStats>,
    /// Cumulative per-block profile across model forwards (index =
    /// block; reset together with `mita`). Feeds the per-layer metrics
    /// series.
    blocks: RefCell<Vec<BlockProfile>>,
    /// Per-block profile of the most recent model forward, drained by
    /// [`Backend::take_block_profiles`] into the request's trace.
    last_blocks: RefCell<Vec<BlockProfile>>,
    /// Decode-loop wall time of the most recent generate, drained by
    /// [`Backend::take_decode_ns`] into the request's profile.
    last_decode_ns: RefCell<u64>,
    /// Models bound by key. Each carries its own registry keyed by the
    /// checkpoint's MiTA params (the backend registry serves the raw
    /// attention ops, whose kernel config may differ).
    models: HashMap<String, BoundModel>,
    /// Activation buffers shared by every bound model's forward calls.
    model_scratch: RefCell<ModelScratch>,
}

struct BoundModel {
    model: MitaModel,
    registry: KernelRegistry,
}

impl NativeBackend {
    pub fn new(cfg: NativeAttnConfig) -> Self {
        let registry = KernelRegistry::with_defaults(cfg.mita);
        Self::with_registry(registry, cfg)
    }

    /// Build over a custom kernel registry (alternative or experimental
    /// kernels slot in without touching the backend).
    pub fn with_registry(registry: KernelRegistry, cfg: NativeAttnConfig) -> Self {
        NativeBackend {
            cfg,
            registry,
            pool: WorkspacePool::new(),
            headout: RefCell::new(Vec::new()),
            stats: RefCell::new(RuntimeStats::default()),
            mita: RefCell::new(MitaStats::default()),
            blocks: RefCell::new(Vec::new()),
            last_blocks: RefCell::new(Vec::new()),
            last_decode_ns: RefCell::new(0),
            models: HashMap::new(),
            model_scratch: RefCell::new(ModelScratch::default()),
        }
    }

    pub fn config(&self) -> &NativeAttnConfig {
        &self.cfg
    }

    /// The worker workspace pool (exposed for reuse tests / diagnostics).
    pub fn workspace_pool(&self) -> &WorkspacePool {
        &self.pool
    }

    /// Registered op names.
    pub fn ops(&self) -> Vec<&'static str> {
        self.registry.names()
    }

    /// Accumulated MiTA routing statistics (test/diagnostic accessor; the
    /// service path reads them through [`ServiceRequest::Stats`]).
    pub fn mita_stats(&self) -> MitaStats {
        self.mita.borrow().clone()
    }

    /// Execute a typed attention request (also reachable without the
    /// trait's `&mut self`, since attention never mutates bindings).
    pub fn run_attention(
        &self,
        op: &KernelId,
        qkv: &QkvBatch,
        valid_rows: Option<usize>,
    ) -> ServiceResult<Tensor> {
        let kernel = self.registry.resolve(op.as_str()).map_err(ServiceError::UnknownOp)?;
        let heads = self.cfg.heads.max(1);
        let valid = resolve_valid_rows(valid_rows, qkv.batch())?;
        let prob = AttnProblem::new(qkv.batch(), heads, qkv.seq_len(), qkv.dim(), qkv.layout())
            .with_valid(valid);
        if let Err(e) = prob.validate() {
            return Err(ServiceError::BadShape(format!("invalid attention problem: {e}")));
        }
        let t0 = Instant::now();
        let mut out = vec![0.0f32; prob.batch * prob.example_len()];
        {
            let data = qkv.view();
            let mut headout = self.headout.borrow_mut();
            let mut mita = self.mita.borrow_mut();
            run_batched(kernel, &prob, &data, &self.pool, &mut headout, &mut out, &mut mita);
        }
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        Tensor::f32(&[prob.batch, prob.n, prob.dim], out).map_err(ServiceError::internal)
    }

    /// Execute a typed model-forward request against a bound model.
    pub fn run_model(
        &self,
        binding: &BindingId,
        tokens: &Tensor,
        valid_rows: Option<usize>,
    ) -> ServiceResult<Tensor> {
        let bound = self.models.get(binding.as_str()).ok_or_else(|| {
            let mut keys: Vec<&str> = self.models.keys().map(String::as_str).collect();
            keys.sort_unstable();
            ServiceError::UnboundParams(format!(
                "no model bound under {binding:?} (bound models: [{}])",
                keys.join(", ")
            ))
        })?;
        let cfg = &bound.model.cfg;
        let toks = tokens
            .as_i32()
            .map_err(|_| ServiceError::BadShape("model tokens must be i32".into()))?;
        let (b, n) = match *tokens.shape() {
            [n] => (1, n),
            [b, n] => (b, n),
            ref s => {
                return Err(ServiceError::BadShape(format!(
                    "model tokens must be [b, n] or [n], got {s:?}"
                )))
            }
        };
        if n != cfg.seq_len {
            return Err(ServiceError::BadShape(format!(
                "token length {n} != model sequence length {}",
                cfg.seq_len
            )));
        }
        let valid = resolve_valid_rows(valid_rows, b)?;

        let t0 = Instant::now();
        let logits = {
            let mut scratch = self.model_scratch.borrow_mut();
            let mut mita = self.mita.borrow_mut();
            let mut last = self.last_blocks.borrow_mut();
            let logits = bound
                .model
                .forward_profiled(
                    toks,
                    b,
                    valid,
                    &bound.registry,
                    &self.pool,
                    &mut scratch,
                    &mut mita,
                    &mut last,
                )
                .map_err(ServiceError::internal)?;
            merge_block_profiles(&mut self.blocks.borrow_mut(), &last);
            logits
        };
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        Tensor::f32(&[b, cfg.classes], logits).map_err(ServiceError::internal)
    }

    /// Execute a typed generate request against a bound model: greedy
    /// autoregressive decoding through [`crate::decode::generate`], one
    /// [`StepEvent`] per emitted token. Returns the emitted tokens as a
    /// `[max_tokens]` i32 tensor plus the prompt length that was
    /// prefilled.
    pub fn run_generate(
        &self,
        binding: &BindingId,
        prompt: &Tensor,
        max_tokens: usize,
        params: &GenerateParams,
        on_step: &mut dyn FnMut(StepEvent),
    ) -> ServiceResult<(Tensor, usize)> {
        let bound = self.models.get(binding.as_str()).ok_or_else(|| {
            let mut keys: Vec<&str> = self.models.keys().map(String::as_str).collect();
            keys.sort_unstable();
            ServiceError::UnboundParams(format!(
                "no model bound under {binding:?} (bound models: [{}])",
                keys.join(", ")
            ))
        })?;
        let toks = prompt
            .as_i32()
            .map_err(|_| ServiceError::BadShape("generate prompt must be i32".into()))?;
        match *prompt.shape() {
            [_] | [1, _] => {}
            ref s => {
                return Err(ServiceError::BadShape(format!(
                    "generate prompt must be [p] or [1, p], got {s:?}"
                )))
            }
        }
        // An explicit kernel override must name a decodable kernel
        // (batch names map onto their causal variants).
        let kernel = params
            .kernel
            .as_ref()
            .map(|id| {
                crate::decode::DecodeKernel::from_name(id.as_str())
                    .map_err(|e| ServiceError::UnknownOp(format!("generate kernel: {e}")))
            })
            .transpose()?;

        let t0 = Instant::now();
        let mut step = |i: usize, tok: i32, ns: u64| {
            on_step(StepEvent { index: i, token: tok, latency_ns: ns });
        };
        let outcome = crate::decode::generate(&bound.model, kernel, toks, max_tokens, &mut step)
            .map_err(|e| ServiceError::BadShape(format!("generate: {e}")))?;
        {
            let mut mita = self.mita.borrow_mut();
            for b in &outcome.blocks {
                mita.merge(&b.stats);
            }
            merge_block_profiles(&mut self.blocks.borrow_mut(), &outcome.blocks);
            *self.last_blocks.borrow_mut() = outcome.blocks;
            *self.last_decode_ns.borrow_mut() = outcome.decode_ns;
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        // The response carries the generated suffix only; the caller already
        // holds the prompt, and the step stream mirrors exactly these tokens.
        let gen: Vec<i32> = outcome.tokens[outcome.prefill_tokens..].to_vec();
        let tokens = Tensor::i32(&[gen.len()], gen).map_err(ServiceError::internal)?;
        Ok((tokens, outcome.prefill_tokens))
    }

    fn take_stats(&self, reset: bool) -> ServiceStats {
        let (mita, blocks) = if reset {
            let mut mita = self.mita.borrow_mut();
            let snapshot = mita.clone();
            mita.reset();
            (snapshot, std::mem::take(&mut *self.blocks.borrow_mut()))
        } else {
            (self.mita.borrow().clone(), self.blocks.borrow().clone())
        };
        ServiceStats { runtime: self.stats.borrow().clone(), mita: Some(mita), blocks }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn warmup(&self, _op: &str) -> Result<()> {
        Ok(()) // nothing to compile
    }

    fn execute(&mut self, req: ServiceRequest) -> ServiceResult<ServiceResponse> {
        match req {
            ServiceRequest::Attention { op, qkv, valid_rows } => {
                let out = self.run_attention(&op, &qkv, valid_rows)?;
                Ok(ServiceResponse::Attention { out })
            }
            ServiceRequest::ModelForward { binding, tokens, valid_rows } => {
                let logits = self.run_model(&binding, &tokens, valid_rows)?;
                Ok(ServiceResponse::ModelForward { logits })
            }
            ServiceRequest::Generate { binding, prompt, max_tokens, params } => {
                let (tokens, prefill_tokens) =
                    self.run_generate(&binding, &prompt, max_tokens, &params, &mut |_| {})?;
                Ok(ServiceResponse::Generate { tokens, prefill_tokens })
            }
            // Bind a model checkpoint: the tensor list must be a
            // self-describing MitaModel flat form (config descriptor
            // first — exactly what `MitaModel::to_tensors` writes).
            ServiceRequest::BindCheckpoint { binding, params } => {
                check_binding_capacity(&self.models, &binding)?;
                let model = MitaModel::from_tensors(&params).map_err(|e| {
                    ServiceError::BadRequest(format!(
                        "binding {binding:?}: native bindings are model checkpoints: {e}"
                    ))
                })?;
                let registry = model.registry();
                self.models.insert(binding.as_str().to_string(), BoundModel { model, registry });
                Ok(ServiceResponse::Bound { binding })
            }
            // Seed-initialize a model from the backend's model config.
            // The init op must be OP_MODEL_INIT; `param_count` is
            // advisory (a seeded model always materializes its full
            // parameter set).
            ServiceRequest::BindInit { binding, init_op, seed, .. } => {
                check_binding_capacity(&self.models, &binding)?;
                if init_op != OP_MODEL_INIT {
                    return Err(ServiceError::UnknownOp(format!(
                        "native backend init op must be {OP_MODEL_INIT:?} (requested {init_op:?})"
                    )));
                }
                let mcfg = self.cfg.model.clone().ok_or_else(|| {
                    ServiceError::BadRequest(
                        "backend spec carries no model config (NativeAttnConfig::with_model)"
                            .into(),
                    )
                })?;
                let model =
                    MitaModel::init(mcfg, seed as u64).map_err(ServiceError::internal)?;
                let registry = model.registry();
                self.models.insert(binding.as_str().to_string(), BoundModel { model, registry });
                Ok(ServiceResponse::Bound { binding })
            }
            ServiceRequest::Artifact { artifact, .. } => Err(ServiceError::Unavailable(format!(
                "native backend serves typed attention/model requests, not compiled artifacts \
                 (requested {artifact:?})"
            ))),
            ServiceRequest::Stats { reset } => Ok(ServiceResponse::Stats(self.take_stats(reset))),
            ServiceRequest::Metrics => Err(ServiceError::Unavailable(
                "serving metrics are assembled by the replica pool, not a backend".into(),
            )),
        }
    }

    fn execute_streaming(
        &mut self,
        req: ServiceRequest,
        on_step: &mut dyn FnMut(StepEvent),
    ) -> ServiceResult<ServiceResponse> {
        match req {
            ServiceRequest::Generate { binding, prompt, max_tokens, params } => {
                let (tokens, prefill_tokens) =
                    self.run_generate(&binding, &prompt, max_tokens, &params, on_step)?;
                Ok(ServiceResponse::Generate { tokens, prefill_tokens })
            }
            other => self.execute(other),
        }
    }

    fn take_block_profiles(&mut self) -> Vec<BlockProfile> {
        std::mem::take(&mut *self.last_blocks.borrow_mut())
    }

    fn take_decode_ns(&mut self) -> u64 {
        std::mem::take(&mut *self.last_decode_ns.borrow_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn qkv_tensors(n: usize, dim: usize, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        (0..3)
            .map(|_| {
                let data = (0..n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                Tensor::f32(&[n, dim], data).unwrap()
            })
            .collect()
    }

    fn attention(be: &NativeBackend, op: KernelId, qkv: QkvBatch) -> Tensor {
        be.run_attention(&op, &qkv, None).unwrap()
    }

    #[test]
    fn fused_and_separate_inputs_agree() {
        let (n, dim) = (12, 8);
        let sep = qkv_tensors(n, dim, 4);
        let mut fused = Vec::new();
        for t in &sep {
            fused.extend_from_slice(t.as_f32().unwrap());
        }
        let fused = QkvBatch::fused(Tensor::f32(&[3, n, dim], fused).unwrap()).unwrap();
        let sep = QkvBatch::separate(sep[0].clone(), sep[1].clone(), sep[2].clone()).unwrap();

        let be = NativeBackend::new(NativeAttnConfig::for_shape(n, dim, 2));
        let a = attention(&be, KernelId::Mita, sep);
        let b = attention(&be, KernelId::Mita, fused);
        assert_eq!(a, b);
        assert_eq!(a.shape(), &[1, n, dim]);
        // Both runs routed n queries per head.
        let mstats = be.mita_stats();
        assert_eq!(mstats.queries, 2 * 2 * n);
        assert_eq!(mstats.calls, 2 * 2);
        assert_eq!(be.take_stats(false).runtime.executions, 2);
    }

    #[test]
    fn batched_run_matches_per_example() {
        let (n, dim, bsz) = (10, 4, 3);
        let mut rng = Rng::new(7);
        let mut data = Vec::new();
        for _ in 0..bsz * 3 * n * dim {
            data.push(rng.range_f32(-1.0, 1.0));
        }
        let batch =
            QkvBatch::fused(Tensor::f32(&[bsz, 3, n, dim], data.clone()).unwrap()).unwrap();
        let be = NativeBackend::new(NativeAttnConfig::for_shape(n, dim, 1));
        let out = attention(&be, KernelId::Dense, batch);
        assert_eq!(out.shape(), &[bsz, n, dim]);
        let full = out.as_f32().unwrap();
        for i in 0..bsz {
            let one = QkvBatch::fused(
                Tensor::f32(&[3, n, dim], data[i * 3 * n * dim..(i + 1) * 3 * n * dim].to_vec())
                    .unwrap(),
            )
            .unwrap();
            let o = attention(&be, KernelId::Dense, one);
            assert_eq!(&full[i * n * dim..(i + 1) * n * dim], o.as_f32().unwrap());
        }
    }

    #[test]
    fn typed_valid_rows_skips_padding() {
        let (n, dim, bsz, valid) = (8, 4, 4, 2);
        let mut rng = Rng::new(19);
        let data: Vec<f32> = (0..bsz * 3 * n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let fused = QkvBatch::fused(Tensor::f32(&[bsz, 3, n, dim], data.clone()).unwrap()).unwrap();

        let be = NativeBackend::new(NativeAttnConfig::for_shape(n, dim, 2));
        let out = be.run_attention(&KernelId::Mita, &fused, Some(valid)).unwrap();
        let full = out.as_f32().unwrap();
        let per = n * dim;

        // Real rows match an unpadded run over the prefix.
        let prefix = QkvBatch::fused(
            Tensor::f32(&[valid, 3, n, dim], data[..valid * 3 * per].to_vec()).unwrap(),
        )
        .unwrap();
        let be2 = NativeBackend::new(NativeAttnConfig::for_shape(n, dim, 2));
        let want = be2.run_attention(&KernelId::Mita, &prefix, None).unwrap();
        assert_eq!(&full[..valid * per], want.as_f32().unwrap());

        // Pad rows never reach the output (stay exactly zero) and never
        // reach the kernels (stats only count valid work).
        assert!(full[valid * per..].iter().all(|&x| x == 0.0));
        let mstats = be.mita_stats();
        assert_eq!(mstats.calls, valid * 2);
        assert_eq!(mstats.queries, valid * 2 * n);

        // Out-of-range valid_rows are rejected with the bad_shape code.
        for bad in [Some(0usize), Some(5)] {
            let err = be.run_attention(&KernelId::Mita, &fused, bad).unwrap_err();
            assert_eq!(err.code(), "bad_shape");
        }
    }

    #[test]
    fn error_codes_for_bad_requests() {
        let mut be = NativeBackend::new(NativeAttnConfig::for_shape(8, 4, 2));
        let qkv =
            QkvBatch::fused(Tensor::f32(&[3, 8, 4], vec![0.0; 3 * 8 * 4]).unwrap()).unwrap();

        // Unknown (but well-formed) kernel name.
        let err = be.run_attention(&KernelId::Custom("attn.nope".into()), &qkv, None).unwrap_err();
        assert_eq!(err.code(), "unknown_op");

        // Unbound model binding.
        let tokens = Tensor::i32(&[1, 8], vec![0; 8]).unwrap();
        let err = be.run_model(&BindingId::from("w"), &tokens, None).unwrap_err();
        assert_eq!(err.code(), "unbound_params");

        // Artifact execution is a different backend's job.
        let err = be
            .execute(ServiceRequest::Artifact {
                artifact: "predict".into(),
                binding: None,
                inputs: vec![],
            })
            .unwrap_err();
        assert_eq!(err.code(), "unavailable");

        // Non-checkpoint bind payloads and non-model init ops.
        let err = be
            .execute(ServiceRequest::BindCheckpoint {
                binding: BindingId::from("w"),
                params: vec![],
            })
            .unwrap_err();
        assert_eq!(err.code(), "bad_request");
        let err = be
            .execute(ServiceRequest::BindInit {
                binding: BindingId::from("w"),
                init_op: "init".into(),
                seed: 0,
                param_count: 1,
            })
            .unwrap_err();
        assert_eq!(err.code(), "unknown_op");

        assert!(be.warmup(OP_ATTN_MITA).is_ok());
        assert_eq!(
            be.ops(),
            vec![
                OP_ATTN_MITA,
                OP_ATTN_DENSE,
                crate::decode::OP_ATTN_MITA_CAUSAL,
                crate::decode::OP_ATTN_DENSE_CAUSAL,
            ]
        );
    }

    #[test]
    fn binding_capacity_is_bounded() {
        let mcfg = ModelConfig::new(5, 8, 4, 1, 1, 8, 2, OP_ATTN_MITA);
        let attn = NativeAttnConfig::for_shape(8, 4, 1).with_model(mcfg);
        let mut be = NativeBackend::new(attn);
        let bind = |i: usize| ServiceRequest::BindInit {
            binding: BindingId::new(format!("m{i}")),
            init_op: OP_MODEL_INIT.into(),
            seed: 0,
            param_count: 0,
        };
        for i in 0..MAX_BINDINGS {
            be.execute(bind(i)).unwrap();
        }
        // One past the cap: rejected with the overloaded code.
        let err = be.execute(bind(MAX_BINDINGS)).unwrap_err();
        assert_eq!(err.code(), "overloaded");
        // Rebinding an existing key is always allowed.
        be.execute(bind(0)).unwrap();
    }

    #[test]
    fn backend_spec_creates_native() {
        let spec = BackendSpec::Native(NativeAttnConfig::for_shape(16, 8, 2));
        let mut be = spec.create().unwrap();
        assert_eq!(be.name(), "native");
        let stats =
            be.execute(ServiceRequest::Stats { reset: false }).unwrap().into_stats().unwrap();
        assert!(stats.mita.is_some());
    }

    #[test]
    fn model_forward_binds_runs_and_skips_padding() {
        let mcfg = ModelConfig::new(7, 10, 8, 2, 1, 16, 3, OP_ATTN_MITA);
        let attn = NativeAttnConfig::for_shape(10, 8, 2).with_model(mcfg.clone());
        let mut be = NativeBackend::new(attn);
        let mut rng = Rng::new(31);
        let toks: Vec<i32> = (0..2 * 10).map(|_| rng.below(7) as i32).collect();
        let tokens = Tensor::i32(&[2, 10], toks).unwrap();
        let m = BindingId::from("m");

        // model forward needs a binding that exists.
        assert_eq!(be.run_model(&m, &tokens, None).unwrap_err().code(), "unbound_params");

        be.execute(ServiceRequest::BindInit {
            binding: m.clone(),
            init_op: OP_MODEL_INIT.into(),
            seed: 3,
            param_count: 0,
        })
        .unwrap();
        let out = be.run_model(&m, &tokens, None).unwrap();
        assert_eq!(out.shape(), &[2, 3]);
        assert!(out.as_f32().unwrap().iter().all(|x| x.is_finite()));

        // Typed valid_rows computes only the prefix; pad logits stay 0.
        let padded = be.run_model(&m, &tokens, Some(1)).unwrap();
        let full = padded.as_f32().unwrap();
        assert_eq!(&full[..3], &out.as_f32().unwrap()[..3]);
        assert!(full[3..].iter().all(|&x| x == 0.0));

        // A checkpoint bound via BindCheckpoint matches the seeded model.
        let model = MitaModel::init(mcfg, 3).unwrap();
        be.execute(ServiceRequest::BindCheckpoint {
            binding: BindingId::from("ckpt"),
            params: model.to_tensors().unwrap(),
        })
        .unwrap();
        let out2 = be.run_model(&BindingId::from("ckpt"), &tokens, None).unwrap();
        assert_eq!(out, out2);
        assert!(be.mita_stats().queries > 0, "model attention records routing stats");

        // Wrong sequence length / wrong dtype are bad_shape.
        let short = Tensor::i32(&[2, 6], vec![0; 12]).unwrap();
        assert_eq!(be.run_model(&m, &short, None).unwrap_err().code(), "bad_shape");
        let wrong = Tensor::f32(&[2, 10], vec![0.0; 20]).unwrap();
        assert_eq!(be.run_model(&m, &wrong, None).unwrap_err().code(), "bad_shape");
    }

    #[test]
    fn generate_streams_steps_and_reports_decode_time() {
        let mcfg = ModelConfig::new(7, 24, 8, 2, 1, 16, 3, OP_ATTN_MITA);
        let attn = NativeAttnConfig::for_shape(24, 8, 2).with_model(mcfg);
        let mut be = NativeBackend::new(attn);
        be.execute(ServiceRequest::BindInit {
            binding: BindingId::from("m"),
            init_op: OP_MODEL_INIT.into(),
            seed: 5,
            param_count: 0,
        })
        .unwrap();

        let prompt = Tensor::i32(&[4], vec![1, 2, 3, 0]).unwrap();
        let mut steps: Vec<StepEvent> = Vec::new();
        let resp = be
            .execute_streaming(
                ServiceRequest::Generate {
                    binding: BindingId::from("m"),
                    prompt: prompt.clone(),
                    max_tokens: 6,
                    params: GenerateParams::default(),
                },
                &mut |ev| steps.push(ev),
            )
            .unwrap();
        let (tokens, prefill) = match resp {
            ServiceResponse::Generate { tokens, prefill_tokens } => (tokens, prefill_tokens),
            other => panic!("wrong class {:?}", other.kind()),
        };
        assert_eq!(prefill, 4);
        assert_eq!(tokens.shape(), &[6]);
        assert_eq!(steps.len(), 6, "one step event per emitted token");
        assert_eq!(steps[0].latency_ns, 0, "step 0 is the prefill tail");
        let streamed: Vec<i32> = steps.iter().map(|s| s.token).collect();
        assert_eq!(streamed, tokens.as_i32().unwrap());
        assert!(be.take_decode_ns() > 0, "decode loop wall time recorded");
        assert_eq!(be.take_decode_ns(), 0, "drain empties the decode time");
        assert_eq!(be.take_block_profiles().len(), 1, "generate records block profiles");

        // The plain execute path emits no steps but decodes identically
        // (an explicit kernel override naming the bound kernel included).
        let resp = be
            .execute(ServiceRequest::Generate {
                binding: BindingId::from("m"),
                prompt,
                max_tokens: 6,
                params: GenerateParams { kernel: Some(KernelId::Mita) },
            })
            .unwrap();
        match resp {
            ServiceResponse::Generate { tokens: t2, .. } => assert_eq!(t2, tokens),
            other => panic!("wrong class {:?}", other.kind()),
        }

        // Taxonomy: undecodable kernel override / unbound binding.
        let one = Tensor::i32(&[1], vec![0]).unwrap();
        let err = be
            .execute(ServiceRequest::Generate {
                binding: BindingId::from("m"),
                prompt: one.clone(),
                max_tokens: 1,
                params: GenerateParams { kernel: Some(KernelId::Custom("attn.nope".into())) },
            })
            .unwrap_err();
        assert_eq!(err.code(), "unknown_op");
        let err = be
            .execute(ServiceRequest::Generate {
                binding: BindingId::from("nope"),
                prompt: one,
                max_tokens: 1,
                params: GenerateParams::default(),
            })
            .unwrap_err();
        assert_eq!(err.code(), "unbound_params");
    }

    #[test]
    fn model_forward_records_per_block_profiles() {
        let mcfg = ModelConfig::new(7, 10, 8, 2, 2, 16, 3, OP_ATTN_MITA);
        let attn = NativeAttnConfig::for_shape(10, 8, 2).with_model(mcfg.clone());
        let mut be = NativeBackend::new(attn);
        be.execute(ServiceRequest::BindInit {
            binding: BindingId::from("m"),
            init_op: OP_MODEL_INIT.into(),
            seed: 3,
            param_count: 0,
        })
        .unwrap();
        assert!(be.take_block_profiles().is_empty(), "no model forward ran yet");

        let mut rng = Rng::new(33);
        let toks: Vec<i32> = (0..2 * 10).map(|_| rng.below(7) as i32).collect();
        let tokens = Tensor::i32(&[2, 10], toks).unwrap();
        be.run_model(&BindingId::from("m"), &tokens, None).unwrap();

        // The last-request profile drains once; cumulative stats keep it.
        let last = be.take_block_profiles();
        assert_eq!(last.len(), mcfg.depth);
        assert!(last.iter().all(|b| b.attn_ns > 0 && b.mlp_ns > 0));
        assert!(be.take_block_profiles().is_empty(), "drain empties the last profile");
        let stats = be.take_stats(false);
        assert_eq!(stats.blocks, last, "cumulative profile covers the one run");
        let per_block: usize = stats.blocks.iter().map(|b| b.stats.queries).sum();
        assert_eq!(per_block, stats.mita.unwrap().queries, "blocks partition the total");

        // A second run accumulates; reset clears the cumulative profile.
        be.run_model(&BindingId::from("m"), &tokens, None).unwrap();
        let stats = be.take_stats(true);
        assert_eq!(stats.blocks[0].stats.queries, 2 * last[0].stats.queries);
        assert!(be.take_stats(false).blocks.is_empty(), "reset drains block profiles");
    }
}
