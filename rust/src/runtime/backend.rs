//! Execution-backend abstraction: one interface over the PJRT artifact
//! path and the native CPU kernel path.
//!
//! The coordinator's engine thread used to be welded to the PJRT
//! [`Runtime`]; with [`Backend`] it owns a `Box<dyn Backend>` instead, so
//! the same serving loop, batcher, and benches drive either:
//!
//! - [`PjrtBackend`]: manifest-driven AOT artifacts (ops are artifact
//!   names, parameter bindings are device literals) — requires the real
//!   vendored `xla` closure.
//! - [`NativeBackend`]: the pure-Rust attention stack in
//!   [`crate::kernels`] — runs anywhere. Ops resolve through a
//!   [`KernelRegistry`], inputs parse into an [`AttnProblem`], and
//!   execution fans out as (example × head) work items over a
//!   [`WorkspacePool`] (see [`run_batched`]), so steady-state calls
//!   allocate nothing beyond the output tensor. Per-call MiTA routing
//!   statistics accumulate and surface through [`Backend::mita_stats`].
//!   Beyond the raw attention ops it also serves whole
//!   [`MitaModel`](crate::model::MitaModel)s: bind a checkpoint with
//!   [`Backend::bind_tensors`] (or seed-init one via
//!   [`Backend::bind_init`] + [`OP_MODEL_INIT`]) and run
//!   [`OP_MODEL_FORWARD`] on token batches to get class logits.
//!
//! Backends are built *inside* the engine thread from a [`BackendSpec`]
//! (PJRT handles are not `Send`, so the spec crosses the thread boundary,
//! not the backend).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::kernels::api::{run_batched, AttnProblem, KernelRegistry, MitaStats, QkvData, QkvLayout};
use crate::kernels::workspace::WorkspacePool;
use crate::kernels::MitaKernelConfig;
use crate::model::{MitaModel, ModelConfig, ModelScratch};
use crate::runtime::client::{Runtime, RuntimeStats};
use crate::runtime::tensor::Tensor;

pub use crate::kernels::api::{OP_ATTN_DENSE, OP_ATTN_MITA};
pub use crate::model::{OP_MODEL_FORWARD, OP_MODEL_INIT};

/// A place computations run: named ops over host tensors, with optional
/// named parameter bindings kept backend-side between calls.
pub trait Backend {
    /// Short identifier ("pjrt" / "native") for logs and reports.
    fn name(&self) -> &'static str;

    /// Prepare an op off the hot path (compile an artifact, warm caches).
    fn warmup(&self, op: &str) -> Result<()>;

    /// Bind named parameters from host tensors (e.g. a loaded checkpoint).
    fn bind_tensors(&mut self, key: &str, params: Vec<Tensor>) -> Result<()>;

    /// Bind named parameters by running an init op with a seed and keeping
    /// its first `param_count` outputs.
    fn bind_init(&mut self, key: &str, init_op: &str, seed: i32, param_count: usize) -> Result<()>;

    /// Execute `op` on `inputs`, optionally prefixed by a binding's
    /// parameters.
    fn run(&self, op: &str, binding: Option<&str>, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Compile/execute counters for reports.
    fn stats(&self) -> RuntimeStats;

    /// Accumulated MiTA routing statistics, when this backend executes the
    /// native kernels (None for artifact backends).
    fn mita_stats(&self) -> Option<MitaStats> {
        None
    }

    /// Snapshot **and reset** the MiTA routing accumulator, so the caller
    /// gets stats covering exactly the interval since the previous take
    /// (peaks like `load_imbalance` are monotone maxima and cannot be
    /// recovered per-interval from cumulative snapshots).
    fn take_mita_stats(&self) -> Option<MitaStats> {
        None
    }
}

/// Serializable description of a backend, safe to send to the engine
/// thread that will actually construct it.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    /// AOT artifact execution from `artifacts_dir` (PJRT).
    Pjrt { artifacts_dir: PathBuf },
    /// Native CPU attention kernels.
    Native(NativeAttnConfig),
}

impl BackendSpec {
    /// Construct the backend. Called on the thread that will own it.
    pub fn create(&self) -> Result<Box<dyn Backend>> {
        match self {
            BackendSpec::Pjrt { artifacts_dir } => {
                Ok(Box::new(PjrtBackend::load(artifacts_dir.clone())?))
            }
            BackendSpec::Native(cfg) => Ok(Box::new(NativeBackend::new(cfg.clone()))),
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// The artifact-execution backend: wraps [`Runtime`] and keeps parameter
/// bindings as device-format literals so the hot path never re-converts
/// weights (previously this logic lived inside the engine thread).
pub struct PjrtBackend {
    runtime: Runtime,
    bindings: HashMap<String, Vec<xla::Literal>>,
}

impl PjrtBackend {
    pub fn load(artifacts_dir: PathBuf) -> Result<Self> {
        Ok(PjrtBackend { runtime: Runtime::load(artifacts_dir)?, bindings: HashMap::new() })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn warmup(&self, op: &str) -> Result<()> {
        self.runtime.warmup(op)
    }

    fn bind_tensors(&mut self, key: &str, params: Vec<Tensor>) -> Result<()> {
        let lits: Vec<xla::Literal> =
            params.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
        self.bindings.insert(key.to_string(), lits);
        Ok(())
    }

    fn bind_init(
        &mut self,
        key: &str,
        init_op: &str,
        seed: i32,
        param_count: usize,
    ) -> Result<()> {
        let seed_lit = Tensor::scalar_i32(seed).to_literal()?;
        let mut state = self.runtime.run_literals(init_op, &[seed_lit])?;
        anyhow::ensure!(
            state.len() >= param_count,
            "init returned {} < {param_count} outputs",
            state.len()
        );
        state.truncate(param_count);
        self.bindings.insert(key.to_string(), state);
        Ok(())
    }

    fn run(&self, op: &str, binding: Option<&str>, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match binding {
            None => self.runtime.run(op, inputs),
            Some(key) => {
                let params =
                    self.bindings.get(key).with_context(|| format!("no binding {key:?}"))?;
                let outs = self.runtime.run_hybrid(op, params, inputs)?;
                outs.iter().map(Tensor::from_literal).collect()
            }
        }
    }

    fn stats(&self) -> RuntimeStats {
        self.runtime.stats()
    }
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Shape + kernel configuration of the native attention workload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NativeAttnConfig {
    /// Sequence length of the serving workload (used to build request
    /// pools; ops themselves take their shape from the input tensors).
    pub n: usize,
    /// Model dimension (`heads · head_dim`).
    pub dim: usize,
    pub heads: usize,
    pub mita: MitaKernelConfig,
    /// Whole-model configuration, when the backend should be able to
    /// seed-init a [`MitaModel`] via `bind_init` + [`OP_MODEL_INIT`]
    /// (checkpoints bound with `bind_tensors` are self-describing and
    /// need no config here).
    pub model: Option<ModelConfig>,
}

impl NativeAttnConfig {
    /// Paper-flavored defaults for a (n, dim, heads) shape.
    pub fn for_shape(n: usize, dim: usize, heads: usize) -> Self {
        NativeAttnConfig { n, dim, heads, mita: MitaKernelConfig::for_seq(n), model: None }
    }

    /// Attach a whole-model config (enables `bind_init`-seeded models).
    pub fn with_model(mut self, model: ModelConfig) -> Self {
        self.model = Some(model);
        self
    }
}

/// The native CPU backend: resolves ops through a [`KernelRegistry`] and
/// executes them as batched (example × head) work items with pooled
/// per-thread workspaces. Accepts per-op inputs in three forms:
///
/// - one fused tensor `[b, 3, n, dim]` (or `[3, n, dim]` for b = 1) with
///   Q/K/V stacked on axis 1 — the serving path packs requests this way;
/// - the fused tensor plus a one-element i32 *valid-rows marker*: only the
///   first `valid` batch rows are computed, trailing padding rows are
///   zero-filled and never executed (the batcher pads short batches);
/// - three tensors Q, K, V of `[b, n, dim]` (or `[n, dim]` for b = 1).
///
/// Output is always `[b, n, dim]`.
///
/// Whole models run through [`OP_MODEL_FORWARD`] instead: inputs are a
/// `[b, n]` (or `[n]`) i32 token tensor plus the same optional valid-rows
/// marker, the binding key names a model bound earlier (`bind_tensors`
/// with a checkpoint, or `bind_init` with [`OP_MODEL_INIT`]), and the
/// output is `[b, classes]` logits with padding rows zeroed.
pub struct NativeBackend {
    cfg: NativeAttnConfig,
    registry: KernelRegistry,
    pool: WorkspacePool,
    /// Head-major staging buffer reused across calls.
    headout: RefCell<Vec<f32>>,
    stats: RefCell<RuntimeStats>,
    mita: RefCell<MitaStats>,
    /// Models bound by key. Each carries its own registry keyed by the
    /// checkpoint's MiTA parameters (the backend registry serves the raw
    /// attention ops, whose kernel config may differ).
    models: HashMap<String, BoundModel>,
    /// Activation buffers shared by every bound model's forward calls.
    model_scratch: RefCell<ModelScratch>,
}

struct BoundModel {
    model: MitaModel,
    registry: KernelRegistry,
}

impl NativeBackend {
    pub fn new(cfg: NativeAttnConfig) -> Self {
        let registry = KernelRegistry::with_defaults(cfg.mita);
        Self::with_registry(registry, cfg)
    }

    /// Build over a custom kernel registry (alternative or experimental
    /// kernels slot in without touching the backend).
    pub fn with_registry(registry: KernelRegistry, cfg: NativeAttnConfig) -> Self {
        NativeBackend {
            cfg,
            registry,
            pool: WorkspacePool::new(),
            headout: RefCell::new(Vec::new()),
            stats: RefCell::new(RuntimeStats::default()),
            mita: RefCell::new(MitaStats::default()),
            models: HashMap::new(),
            model_scratch: RefCell::new(ModelScratch::default()),
        }
    }

    pub fn config(&self) -> &NativeAttnConfig {
        &self.cfg
    }

    /// The worker workspace pool (exposed for reuse tests / diagnostics).
    pub fn workspace_pool(&self) -> &WorkspacePool {
        &self.pool
    }

    /// Registered op names.
    pub fn ops(&self) -> Vec<&'static str> {
        self.registry.names()
    }

    /// Parse input tensors into a problem descriptor plus a borrowed data
    /// view (see the type-level docs for the accepted forms).
    fn problem<'a>(&self, inputs: &'a [Tensor]) -> Result<(AttnProblem, QkvData<'a>)> {
        let heads = self.cfg.heads.max(1);
        match inputs.len() {
            1 | 2 => {
                let shape = inputs[0].shape();
                let (b, n, dim) = match *shape {
                    [three, n, dim] if three == 3 => (1, n, dim),
                    [b, three, n, dim] if three == 3 => (b, n, dim),
                    _ => bail!("fused input must be [b, 3, n, dim] or [3, n, dim], got {shape:?}"),
                };
                let mut prob = AttnProblem::new(b, heads, n, dim, QkvLayout::Fused);
                if inputs.len() == 2 {
                    prob = prob.with_valid(parse_valid_marker(&inputs[1], b)?);
                }
                Ok((prob, QkvData::Fused(inputs[0].as_f32()?)))
            }
            3 => {
                let shape = inputs[0].shape();
                for t in &inputs[1..] {
                    anyhow::ensure!(
                        t.shape() == shape,
                        "q/k/v shapes differ: {shape:?} vs {:?}",
                        t.shape()
                    );
                }
                let (b, n, dim) = match *shape {
                    [n, dim] => (1, n, dim),
                    [b, n, dim] => (b, n, dim),
                    _ => bail!("q/k/v must be [b, n, dim] or [n, dim], got {shape:?}"),
                };
                let data = QkvData::Separate {
                    q: inputs[0].as_f32()?,
                    k: inputs[1].as_f32()?,
                    v: inputs[2].as_f32()?,
                };
                Ok((AttnProblem::new(b, heads, n, dim, QkvLayout::Separate), data))
            }
            other => bail!(
                "native attention wants 1 fused tensor (+ optional valid-rows marker) \
                 or 3 q/k/v tensors, got {other}"
            ),
        }
    }

    /// Execute [`OP_MODEL_FORWARD`]: a bound model's classification
    /// forward over a `[b, n]` token batch (+ optional valid-rows marker).
    fn run_model(&self, binding: Option<&str>, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let key = binding
            .context("model.forward needs a parameter binding (bind_tensors/bind_init first)")?;
        let bound = self.models.get(key).with_context(|| {
            let mut keys: Vec<&str> = self.models.keys().map(String::as_str).collect();
            keys.sort_unstable();
            format!("no model bound under {key:?} (bound models: [{}])", keys.join(", "))
        })?;
        let cfg = &bound.model.cfg;
        anyhow::ensure!(
            !inputs.is_empty() && inputs.len() <= 2,
            "model.forward wants a token tensor (+ optional valid-rows marker), got {} inputs",
            inputs.len()
        );
        let shape = inputs[0].shape();
        let (b, n) = match *shape {
            [n] => (1, n),
            [b, n] => (b, n),
            _ => bail!("model tokens must be [b, n] or [n], got {shape:?}"),
        };
        anyhow::ensure!(
            n == cfg.seq_len,
            "token length {n} != model sequence length {}",
            cfg.seq_len
        );
        let valid = if inputs.len() == 2 { parse_valid_marker(&inputs[1], b)? } else { b };
        let tokens = inputs[0].as_i32().context("model tokens must be i32")?;

        let t0 = Instant::now();
        let logits = {
            let mut scratch = self.model_scratch.borrow_mut();
            let mut mita = self.mita.borrow_mut();
            bound.model.forward(
                tokens,
                b,
                valid,
                &bound.registry,
                &self.pool,
                &mut scratch,
                &mut mita,
            )?
        };
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        Ok(vec![Tensor::f32(&[b, cfg.classes], logits)?])
    }
}

/// Parse the one-element i32 valid-rows marker against batch size `b`.
fn parse_valid_marker(t: &Tensor, b: usize) -> Result<usize> {
    let marker = t.as_i32().context("valid-rows marker")?;
    anyhow::ensure!(
        marker.len() == 1,
        "valid-rows marker must hold one i32, got {} values",
        marker.len()
    );
    let valid = marker[0];
    anyhow::ensure!(valid >= 1 && valid as usize <= b, "valid rows {valid} out of range 1..={b}");
    Ok(valid as usize)
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn warmup(&self, _op: &str) -> Result<()> {
        Ok(()) // nothing to compile
    }

    /// Bind a model checkpoint: the tensor list must be a self-describing
    /// [`MitaModel`] flat form (config descriptor first — exactly what
    /// `MitaModel::to_tensors` / `model-check --checkpoint` writes).
    fn bind_tensors(&mut self, key: &str, params: Vec<Tensor>) -> Result<()> {
        let model = MitaModel::from_tensors(&params)
            .with_context(|| format!("binding {key:?}: native bindings are model checkpoints"))?;
        let registry = model.registry();
        self.models.insert(key.to_string(), BoundModel { model, registry });
        Ok(())
    }

    /// Seed-initialize a model from the backend's model config and bind
    /// it under `key`. The init op must be [`OP_MODEL_INIT`]; the PJRT
    /// `param_count` argument is advisory here (a seeded model always
    /// materializes its full parameter set).
    fn bind_init(
        &mut self,
        key: &str,
        init_op: &str,
        seed: i32,
        _param_count: usize,
    ) -> Result<()> {
        anyhow::ensure!(
            init_op == OP_MODEL_INIT,
            "native backend init op must be {OP_MODEL_INIT:?} (requested {init_op:?})"
        );
        let mcfg = self
            .cfg
            .model
            .clone()
            .context("backend spec carries no model config (NativeAttnConfig::with_model)")?;
        let model = MitaModel::init(mcfg, seed as u64)?;
        let registry = model.registry();
        self.models.insert(key.to_string(), BoundModel { model, registry });
        Ok(())
    }

    fn run(&self, op: &str, binding: Option<&str>, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if op == OP_MODEL_FORWARD {
            return self.run_model(binding, inputs);
        }
        anyhow::ensure!(binding.is_none(), "native attention ops take no parameter binding");
        let kernel = self.registry.get(op).with_context(|| {
            format!(
                "native backend has no op {op:?} (available: {})",
                self.registry.names().join(", ")
            )
        })?;
        let (prob, data) = self.problem(inputs)?;
        if let Err(e) = prob.validate() {
            bail!("invalid attention problem: {e}");
        }
        let t0 = Instant::now();
        let mut out = vec![0.0f32; prob.batch * prob.example_len()];
        {
            let mut headout = self.headout.borrow_mut();
            let mut mita = self.mita.borrow_mut();
            run_batched(kernel, &prob, &data, &self.pool, &mut headout, &mut out, &mut mita);
        }
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        Ok(vec![Tensor::f32(&[prob.batch, prob.n, prob.dim], out)?])
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    fn mita_stats(&self) -> Option<MitaStats> {
        Some(self.mita.borrow().clone())
    }

    fn take_mita_stats(&self) -> Option<MitaStats> {
        let mut mita = self.mita.borrow_mut();
        let snapshot = mita.clone();
        mita.reset();
        Some(snapshot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn qkv_tensors(n: usize, dim: usize, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        (0..3)
            .map(|_| {
                let data = (0..n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                Tensor::f32(&[n, dim], data).unwrap()
            })
            .collect()
    }

    #[test]
    fn fused_and_separate_inputs_agree() {
        let (n, dim) = (12, 8);
        let sep = qkv_tensors(n, dim, 4);
        let mut fused = Vec::new();
        for t in &sep {
            fused.extend_from_slice(t.as_f32().unwrap());
        }
        let fused = Tensor::f32(&[3, n, dim], fused).unwrap();

        let be = NativeBackend::new(NativeAttnConfig::for_shape(n, dim, 2));
        let a = be.run(OP_ATTN_MITA, None, &sep).unwrap();
        let b = be.run(OP_ATTN_MITA, None, &[fused]).unwrap();
        assert_eq!(a[0], b[0]);
        assert_eq!(a[0].shape(), &[1, n, dim]);
        assert_eq!(be.stats().executions, 2);
        // Both runs routed n queries per head.
        let mstats = be.mita_stats().unwrap();
        assert_eq!(mstats.queries, 2 * 2 * n);
        assert_eq!(mstats.calls, 2 * 2);
    }

    #[test]
    fn batched_run_matches_per_example() {
        let (n, dim, bsz) = (10, 4, 3);
        let mut rng = Rng::new(7);
        let mut data = Vec::new();
        for _ in 0..bsz * 3 * n * dim {
            data.push(rng.range_f32(-1.0, 1.0));
        }
        let batch = Tensor::f32(&[bsz, 3, n, dim], data.clone()).unwrap();
        let be = NativeBackend::new(NativeAttnConfig::for_shape(n, dim, 1));
        let out = be.run(OP_ATTN_DENSE, None, &[batch]).unwrap();
        assert_eq!(out[0].shape(), &[bsz, n, dim]);
        let full = out[0].as_f32().unwrap();
        for i in 0..bsz {
            let one =
                Tensor::f32(&[3, n, dim], data[i * 3 * n * dim..(i + 1) * 3 * n * dim].to_vec())
                    .unwrap();
            let o = be.run(OP_ATTN_DENSE, None, &[one]).unwrap();
            assert_eq!(&full[i * n * dim..(i + 1) * n * dim], o[0].as_f32().unwrap());
        }
    }

    #[test]
    fn valid_rows_marker_skips_padding() {
        let (n, dim, bsz, valid) = (8, 4, 4, 2);
        let mut rng = Rng::new(19);
        let data: Vec<f32> =
            (0..bsz * 3 * n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let fused = Tensor::f32(&[bsz, 3, n, dim], data.clone()).unwrap();
        let marker = Tensor::i32(&[1], vec![valid as i32]).unwrap();

        let be = NativeBackend::new(NativeAttnConfig::for_shape(n, dim, 2));
        let out = be.run(OP_ATTN_MITA, None, &[fused.clone(), marker]).unwrap();
        let full = out[0].as_f32().unwrap();
        let per = n * dim;

        // Real rows match an unpadded run over the prefix.
        let prefix =
            Tensor::f32(&[valid, 3, n, dim], data[..valid * 3 * per].to_vec()).unwrap();
        let be2 = NativeBackend::new(NativeAttnConfig::for_shape(n, dim, 2));
        let want = be2.run(OP_ATTN_MITA, None, &[prefix]).unwrap();
        assert_eq!(&full[..valid * per], want[0].as_f32().unwrap());

        // Pad rows never reach the output (stay exactly zero) and never
        // reach the kernels (stats only count valid work).
        assert!(full[valid * per..].iter().all(|&x| x == 0.0));
        let mstats = be.mita_stats().unwrap();
        assert_eq!(mstats.calls, valid * 2);
        assert_eq!(mstats.queries, valid * 2 * n);

        // Out-of-range markers are rejected.
        for bad in [0i32, 5] {
            let marker = Tensor::i32(&[1], vec![bad]).unwrap();
            assert!(be.run(OP_ATTN_MITA, None, &[fused.clone(), marker]).is_err());
        }
        let wide = Tensor::i32(&[2], vec![1, 1]).unwrap();
        assert!(be.run(OP_ATTN_MITA, None, &[fused, wide]).is_err());
    }

    #[test]
    fn rejects_bad_ops_and_shapes() {
        let be = NativeBackend::new(NativeAttnConfig::for_shape(8, 4, 2));
        let t = Tensor::f32(&[2, 2], vec![0.0; 4]).unwrap();
        assert!(be.run("predict", None, &[t.clone()]).is_err());
        assert!(be.run(OP_ATTN_MITA, None, &[t.clone()]).is_err()); // not [3, n, dim]
        assert!(be.run(OP_ATTN_MITA, Some("w"), &[t]).is_err());
        let mut be = be;
        assert!(be.bind_tensors("w", vec![]).is_err());
        assert!(be.bind_init("w", "init", 0, 1).is_err());
        assert!(be.warmup(OP_ATTN_MITA).is_ok());
        assert_eq!(be.ops(), vec![OP_ATTN_MITA, OP_ATTN_DENSE]);
    }

    #[test]
    fn backend_spec_creates_native() {
        let spec = BackendSpec::Native(NativeAttnConfig::for_shape(16, 8, 2));
        let be = spec.create().unwrap();
        assert_eq!(be.name(), "native");
        assert!(be.mita_stats().is_some());
    }

    #[test]
    fn model_forward_binds_runs_and_skips_padding() {
        let mcfg = ModelConfig::new(7, 10, 8, 2, 1, 16, 3, OP_ATTN_MITA);
        let attn = NativeAttnConfig::for_shape(10, 8, 2).with_model(mcfg.clone());
        let mut be = NativeBackend::new(attn);
        let mut rng = Rng::new(31);
        let toks: Vec<i32> = (0..2 * 10).map(|_| rng.below(7) as i32).collect();
        let tokens = Tensor::i32(&[2, 10], toks).unwrap();

        // model.forward needs a binding that exists.
        assert!(be.run(OP_MODEL_FORWARD, None, &[tokens.clone()]).is_err());
        assert!(be.run(OP_MODEL_FORWARD, Some("m"), &[tokens.clone()]).is_err());

        be.bind_init("m", OP_MODEL_INIT, 3, 0).unwrap();
        assert!(be.bind_init("m", "init", 3, 0).is_err(), "only {OP_MODEL_INIT:?} seeds models");
        let out = be.run(OP_MODEL_FORWARD, Some("m"), &[tokens.clone()]).unwrap();
        assert_eq!(out[0].shape(), &[2, 3]);
        assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));

        // The valid-rows marker computes only the prefix; pad logits stay 0.
        let marker = Tensor::i32(&[1], vec![1]).unwrap();
        let padded = be.run(OP_MODEL_FORWARD, Some("m"), &[tokens.clone(), marker]).unwrap();
        let full = padded[0].as_f32().unwrap();
        assert_eq!(&full[..3], &out[0].as_f32().unwrap()[..3]);
        assert!(full[3..].iter().all(|&x| x == 0.0));

        // A checkpoint bound via bind_tensors matches the seeded model.
        let model = MitaModel::init(mcfg, 3).unwrap();
        be.bind_tensors("ckpt", model.to_tensors().unwrap()).unwrap();
        let out2 = be.run(OP_MODEL_FORWARD, Some("ckpt"), &[tokens]).unwrap();
        assert_eq!(out[0], out2[0]);
        assert!(be.mita_stats().unwrap().queries > 0, "model attention records routing stats");

        // Wrong sequence length / non-checkpoint bindings are rejected.
        let short = Tensor::i32(&[2, 6], vec![0; 12]).unwrap();
        assert!(be.run(OP_MODEL_FORWARD, Some("m"), &[short]).is_err());
        assert!(be.bind_tensors("bad", vec![Tensor::scalar_i32(1)]).is_err());
    }
}
