//! Host-side tensor type bridging Rust data and XLA `Literal`s.
//!
//! The coordinator's data generators produce `Tensor`s; the runtime converts
//! them to `xla::Literal` on the way into an executable and back on the way
//! out. Only the dtypes that cross the AOT boundary are supported (f32/i32).

use anyhow::{bail, Context, Result};

use super::manifest::{DType, TensorSpec};

/// A dense host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(data.len() == n, "shape {shape:?} wants {n} elems, got {}", data.len());
        Ok(Tensor::F32 { shape: shape.to_vec(), data })
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(data.len() == n, "shape {shape:?} wants {n} elems, got {}", data.len());
        Ok(Tensor::I32 { shape: shape.to_vec(), data })
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros(spec: &TensorSpec) -> Result<Self> {
        let n = spec.elements();
        Ok(match spec.dtype {
            DType::F32 => Tensor::F32 { shape: spec.shape.clone(), data: vec![0.0; n] },
            DType::I32 => Tensor::I32 { shape: spec.shape.clone(), data: vec![0; n] },
            other => bail!("unsupported dtype {other:?}"),
        })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Extract the single element of a scalar tensor as f64.
    pub fn scalar(&self) -> Result<f64> {
        anyhow::ensure!(self.len() == 1, "scalar() on tensor of {} elems", self.len());
        Ok(match self {
            Tensor::F32 { data, .. } => data[0] as f64,
            Tensor::I32 { data, .. } => data[0] as f64,
        })
    }

    /// Check this tensor against a manifest spec.
    pub fn check_spec(&self, spec: &TensorSpec) -> Result<()> {
        anyhow::ensure!(
            self.shape() == spec.shape.as_slice() && self.dtype() == spec.dtype,
            "tensor {:?}{:?} does not match spec {:?}{:?}",
            self.dtype(),
            self.shape(),
            spec.dtype,
            spec.shape
        );
        Ok(())
    }

    /// Convert to an XLA literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
    }

    /// Convert from an XLA literal (f32/s32 only).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                Tensor::f32(&dims, data)
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                Tensor::i32(&dims, data)
            }
            other => bail!("unsupported literal type {other:?}"),
        }
    }

    /// Mean of an f32 tensor (convenience for metrics).
    pub fn mean(&self) -> Result<f64> {
        let d = self.as_f32()?;
        anyhow::ensure!(!d.is_empty(), "mean of empty tensor");
        Ok(d.iter().map(|&x| x as f64).sum::<f64>() / d.len() as f64)
    }

    /// Argmax over the last axis; returns i32 indices of shape[:-1].
    pub fn argmax_last(&self) -> Result<Tensor> {
        let d = self.as_f32()?;
        let shape = self.shape();
        anyhow::ensure!(!shape.is_empty(), "argmax on scalar");
        let last = *shape.last().context("empty shape")?;
        let rows = d.len() / last;
        let mut out = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &d[r * last..(r + 1) * last];
            let mut best = 0usize;
            for (i, &x) in row.iter().enumerate() {
                if x > row[best] {
                    best = i;
                }
            }
            out.push(best as i32);
        }
        Tensor::i32(&shape[..shape.len() - 1], out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let t = Tensor::f32(&[2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert!((t.mean().unwrap() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(&[4], vec![1, 2, 3]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar_i32(7);
        assert_eq!(t.scalar().unwrap(), 7.0);
        assert_eq!(t.shape(), &[] as &[usize]);
    }

    #[test]
    fn argmax_last_works() {
        let t = Tensor::f32(&[2, 3], vec![0.1, 0.9, 0.3, 5.0, -1.0, 2.0]).unwrap();
        let am = t.argmax_last().unwrap();
        assert_eq!(am.as_i32().unwrap(), &[1, 0]);
    }

    #[test]
    fn check_spec_matches() {
        let t = Tensor::f32(&[2, 2], vec![0.0; 4]).unwrap();
        let ok = TensorSpec { shape: vec![2, 2], dtype: DType::F32 };
        let bad = TensorSpec { shape: vec![4], dtype: DType::F32 };
        assert!(t.check_spec(&ok).is_ok());
        assert!(t.check_spec(&bad).is_err());
    }

    #[test]
    fn zeros_from_spec() {
        let spec = TensorSpec { shape: vec![3, 2], dtype: DType::I32 };
        let t = Tensor::zeros(&spec).unwrap();
        assert_eq!(t.as_i32().unwrap(), &[0; 6]);
    }
}
