//! Schema of `artifacts/manifest.json` — the contract between the Python
//! AOT pipeline (python/compile/aot.py) and the Rust coordinator.
//!
//! The manifest records, per *bundle* (one experiment configuration), the
//! model/train configs, the flattened parameter layout, and the artifact
//! names of each lowered computation; and per *artifact*, the HLO text file
//! plus exact input/output tensor specs. Parsed with util::json (the build
//! environment has no serde).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// Tensor dtype names used throughout the manifest (`_DTYPE_NAMES` in aot.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
    Bf16,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            "bf16" => DType::Bf16,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::Bf16 => 2,
        }
    }
}

/// Shape + dtype of one tensor crossing the Rust⇄XLA boundary.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        let shape = v
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(v.get("dtype")?.as_str()?)?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One lowered computation (an `.hlo.txt` file).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub spec_hash: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Attention mechanism configuration (mirrors configs.AttentionConfig).
#[derive(Debug, Clone)]
pub struct AttentionCfg {
    pub kind: String,
    pub m: usize,
    pub k: usize,
    pub s: usize,
    pub landmark: String,
    pub cap_factor: usize,
    pub use_pallas: bool,
}

/// Model configuration (mirrors configs.ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub task: String,
    pub depth: usize,
    pub dim: usize,
    pub heads: usize,
    pub mlp_ratio: f64,
    pub num_classes: usize,
    pub attention: AttentionCfg,
    pub image_hw: (usize, usize),
    pub patch: usize,
    pub channels: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub pool: String,
    pub dwc: bool,
    pub gate: bool,
}

impl ModelCfg {
    /// Token count seen by the transformer (N in the paper).
    pub fn num_tokens(&self) -> usize {
        if self.task == "lra" {
            self.seq_len
        } else {
            (self.image_hw.0 / self.patch) * (self.image_hw.1 / self.patch)
        }
    }

    pub fn grid_hw(&self) -> (usize, usize) {
        (self.image_hw.0 / self.patch, self.image_hw.1 / self.patch)
    }

    fn from_json(v: &Value) -> Result<Self> {
        let a = v.get("attention")?;
        let hw = v.get("image_hw")?.as_arr()?;
        anyhow::ensure!(hw.len() == 2, "image_hw must have 2 entries");
        Ok(ModelCfg {
            task: v.get("task")?.as_str()?.to_string(),
            depth: v.get("depth")?.as_usize()?,
            dim: v.get("dim")?.as_usize()?,
            heads: v.get("heads")?.as_usize()?,
            mlp_ratio: v.get("mlp_ratio")?.as_f64()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            attention: AttentionCfg {
                kind: a.get("kind")?.as_str()?.to_string(),
                m: a.get("m")?.as_usize()?,
                k: a.get("k")?.as_usize()?,
                s: a.get("s")?.as_usize()?,
                landmark: a.get("landmark")?.as_str()?.to_string(),
                cap_factor: a.get("cap_factor")?.as_usize()?,
                use_pallas: a.get("use_pallas")?.as_bool()?,
            },
            image_hw: (hw[0].as_usize()?, hw[1].as_usize()?),
            patch: v.get("patch")?.as_usize()?,
            channels: v.get("channels")?.as_usize()?,
            seq_len: v.get("seq_len")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            pool: v.get("pool")?.as_str()?.to_string(),
            dwc: v.get("dwc")?.as_bool()?,
            gate: v.get("gate")?.as_bool()?,
        })
    }
}

/// Training hyperparameters (mirrors configs.TrainConfig).
#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub lr: f64,
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub label_smoothing: f64,
    pub grad_clip: f64,
    pub batch_size: usize,
}

impl TrainCfg {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(TrainCfg {
            lr: v.get("lr")?.as_f64()?,
            weight_decay: v.get("weight_decay")?.as_f64()?,
            beta1: v.get("beta1")?.as_f64()?,
            beta2: v.get("beta2")?.as_f64()?,
            eps: v.get("eps")?.as_f64()?,
            warmup_steps: v.get("warmup_steps")?.as_usize()?,
            total_steps: v.get("total_steps")?.as_usize()?,
            label_smoothing: v.get("label_smoothing")?.as_f64()?,
            grad_clip: v.get("grad_clip")?.as_f64()?,
            batch_size: v.get("batch_size")?.as_usize()?,
        })
    }
}

/// One flattened parameter leaf (jax tree order).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub path: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One experiment bundle.
#[derive(Debug, Clone)]
pub struct BundleSpec {
    pub model: ModelCfg,
    pub train: TrainCfg,
    pub meta: HashMap<String, Value>,
    pub param_layout: Vec<ParamSpec>,
    /// computation name ("init", "train_step", ...) -> artifact name.
    pub artifacts: HashMap<String, String>,
}

impl BundleSpec {
    /// Number of parameter leaves (P in aot.py's flat signatures).
    pub fn param_count(&self) -> usize {
        self.param_layout.len()
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str().ok())
    }

    pub fn meta_u64(&self, key: &str) -> Option<u64> {
        self.meta.get(key).and_then(|v| v.as_f64().ok()).map(|f| f as u64)
    }

    fn from_json(v: &Value) -> Result<Self> {
        let param_layout = v
            .get("param_layout")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    path: p.get("path")?.as_str()?.to_string(),
                    shape: p
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    dtype: DType::parse(p.get("dtype")?.as_str()?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = HashMap::new();
        for (k, val) in v.get("artifacts")?.as_obj()? {
            artifacts.insert(k.clone(), val.as_str()?.to_string());
        }
        let meta = match v.opt("meta") {
            Some(m) => m.as_obj()?.clone(),
            None => HashMap::new(),
        };
        Ok(BundleSpec {
            model: ModelCfg::from_json(v.get("model")?)?,
            train: TrainCfg::from_json(v.get("train")?)?,
            meta,
            param_layout,
            artifacts,
        })
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub artifacts: HashMap<String, ArtifactSpec>,
    pub bundles: HashMap<String, BundleSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = Value::parse(text).context("parsing manifest.json")?;
        let version = v.get("version")?.as_usize()?;
        anyhow::ensure!(version == 2, "unsupported manifest version {version}");

        let mut artifacts = HashMap::new();
        for (name, av) in v.get("artifacts")?.as_obj()? {
            let spec = ArtifactSpec {
                file: av.get("file")?.as_str()?.to_string(),
                spec_hash: av.get("spec_hash")?.as_str()?.to_string(),
                inputs: av
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: av
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
            };
            artifacts.insert(name.clone(), spec);
        }

        let mut bundles = HashMap::new();
        for (name, bv) in v.get("bundles")?.as_obj()? {
            bundles.insert(
                name.clone(),
                BundleSpec::from_json(bv).with_context(|| format!("bundle {name:?}"))?,
            );
        }
        Ok(Manifest { version, artifacts, bundles })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn bundle(&self, name: &str) -> Result<&BundleSpec> {
        self.bundles
            .get(name)
            .with_context(|| format!("bundle {name:?} not in manifest (run `make artifacts`)"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    /// Artifact name of a bundle's computation, e.g. ("t2_std", "train_step").
    pub fn bundle_artifact(&self, bundle: &str, which: &str) -> Result<&str> {
        let b = self.bundle(bundle)?;
        b.artifacts
            .get(which)
            .map(|s| s.as_str())
            .with_context(|| format!("bundle {bundle:?} has no {which:?} artifact"))
    }

    /// All bundle names with a given prefix, sorted (experiment iteration).
    pub fn bundles_with_prefix(&self, prefix: &str) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .bundles
            .keys()
            .filter(|k| k.starts_with(prefix))
            .map(|s| s.as_str())
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = r#"{
        "version": 2,
        "artifacts": {
            "q.init": {
                "file": "q.init.hlo.txt", "spec_hash": "ab",
                "inputs": [{"shape": [], "dtype": "i32"}],
                "outputs": [{"shape": [4, 4], "dtype": "f32"}]
            }
        },
        "bundles": {
            "q": {
                "model": {
                    "task": "cls_image", "depth": 2, "dim": 64, "heads": 4,
                    "mlp_ratio": 4.0, "num_classes": 10,
                    "attention": {"kind": "mita", "m": 4, "k": 4, "s": 1,
                                  "landmark": "pool2d", "cap_factor": 2,
                                  "use_pallas": false},
                    "image_hw": [16, 16], "patch": 4, "channels": 3,
                    "seq_len": 1024, "vocab": 32, "pool": "mean",
                    "dwc": false, "gate": false
                },
                "train": {
                    "lr": 0.001, "weight_decay": 0.05, "beta1": 0.9,
                    "beta2": 0.999, "eps": 1e-8, "warmup_steps": 5,
                    "total_steps": 60, "label_smoothing": 0.1,
                    "grad_clip": 1.0, "batch_size": 16
                },
                "meta": {"steps": 60, "row": "std"},
                "param_layout": [{"path": "pos", "shape": [16, 64], "dtype": "f32"}],
                "artifacts": {"init": "q.init"}
            }
        }
    }"#;

    #[test]
    fn parse_minimal_manifest() {
        let m = Manifest::parse(MINIMAL).unwrap();
        assert_eq!(m.version, 2);
        let b = m.bundle("q").unwrap();
        assert_eq!(b.model.num_tokens(), 16);
        assert_eq!(b.param_count(), 1);
        assert_eq!(b.meta_u64("steps"), Some(60));
        assert_eq!(b.meta_str("row"), Some("std"));
        assert_eq!(m.bundle_artifact("q", "init").unwrap(), "q.init");
        let art = m.artifact("q.init").unwrap();
        assert_eq!(art.inputs[0].dtype, DType::I32);
        assert_eq!(art.outputs[0].elements(), 16);
        assert!(m.bundle("nope").is_err());
        assert_eq!(m.bundles_with_prefix("q"), vec!["q"]);
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = MINIMAL.replacen("\"version\": 2", "\"version\": 1", 1);
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn dtype_parse_rejects_unknown() {
        assert!(DType::parse("f64").is_err());
        assert_eq!(DType::parse("f32").unwrap().size_bytes(), 4);
        assert_eq!(DType::parse("bf16").unwrap().size_bytes(), 2);
    }
}
