//! PJRT runtime: loads AOT HLO-text artifacts, compiles them on the CPU
//! client, caches executables, and runs them with `Tensor` I/O.
//!
//! Python never runs here — this is the self-contained request path.
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

/// Compile/run statistics (surfaced by `mita info` and the benches).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

/// The PJRT-backed runtime. Single-threaded by design (PJRT handles are not
/// `Send`); the serving coordinator owns one `Runtime` inside a dedicated
/// engine thread (see coordinator::engine).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Load manifest + create the CPU PJRT client. `dir` is `artifacts/`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn artifact_spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.artifact(name)
    }

    /// Get (compiling + caching on first use) an executable by artifact name.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (warm the cache off the hot path).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Run an artifact on literal inputs, returning the flattened outputs.
    ///
    /// AOT computations are lowered with `return_tuple=True`, so PJRT yields
    /// a single tuple buffer which we decompose into element literals.
    pub fn run_literals(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let mut lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result {name}: {e:?}"))?;
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        let parts = lit
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("decompose {name}: {e:?}"))?;
        Ok(parts)
    }

    /// Run an artifact with `Tensor` I/O (validated against the manifest).
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?.clone();
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            t.check_spec(s).with_context(|| format!("{name} input {i}"))?;
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let outs = self.run_literals(name, &lits)?;
        outs.iter().map(Tensor::from_literal).collect()
    }

    /// Run with mixed literal state + tensor batch inputs (train loop hot
    /// path: parameters stay as literals between steps, only the batch is
    /// freshly converted).
    pub fn run_hybrid(
        &self,
        name: &str,
        state: &[xla::Literal],
        batch: &[Tensor],
    ) -> Result<Vec<xla::Literal>> {
        let mut lits: Vec<xla::Literal> = Vec::with_capacity(state.len() + batch.len());
        // Literals are opaque handles; cloning copies host data. To avoid
        // that we pass borrowed literals — execute takes Borrow<Literal>.
        // Build a reference vector instead.
        let mut refs: Vec<&xla::Literal> = state.iter().collect();
        for t in batch {
            lits.push(t.to_literal()?);
        }
        refs.extend(lits.iter());
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<&xla::Literal>(&refs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let mut lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result {name}: {e:?}"))?;
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        lit.decompose_tuple().map_err(|e| anyhow::anyhow!("decompose {name}: {e:?}"))
    }
}
