//! Runtime layer: execution backends plus the manifest-driven loading of
//! AOT-compiled XLA artifacts through the PJRT C API (the `xla` crate).
//!
//! - [`backend`]: the [`Backend`] trait — PJRT artifacts or native CPU
//!   kernels executing typed [`crate::service::ServiceRequest`]s behind
//!   one interface — and [`BackendSpec`] for picking one.
//! - [`manifest`]: schema of `artifacts/manifest.json` (the Python⇄Rust
//!   contract).
//! - [`tensor`]: host tensors ⇄ `xla::Literal`.
//! - [`client`]: the [`Runtime`] — compile cache + execution.

pub mod backend;
pub mod client;
pub mod manifest;
pub mod tensor;

pub use backend::{Backend, BackendSpec, NativeAttnConfig, NativeBackend, PjrtBackend};
pub use client::{Runtime, RuntimeStats};
pub use manifest::{ArtifactSpec, BundleSpec, DType, Manifest, ModelCfg, TensorSpec, TrainCfg};
pub use tensor::Tensor;
