//! Runtime layer: manifest-driven loading and execution of AOT-compiled
//! XLA artifacts through the PJRT C API (the `xla` crate).
//!
//! - [`manifest`]: schema of `artifacts/manifest.json` (the Python⇄Rust
//!   contract).
//! - [`tensor`]: host tensors ⇄ `xla::Literal`.
//! - [`client`]: the [`Runtime`] — compile cache + execution.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{Runtime, RuntimeStats};
pub use manifest::{ArtifactSpec, BundleSpec, DType, Manifest, ModelCfg, TensorSpec, TrainCfg};
pub use tensor::Tensor;
