//! Synthetic class-conditional image corpus — the ImageNet-1K / ADE20K
//! stand-in (DESIGN.md §3 substitutions).
//!
//! Each class is a deterministic *prototype*: a set of Gaussian blobs with
//! class-specific positions/scales/colors. A sample is its prototype under a
//! random global translation (wrapping), per-blob jitter, brightness scaling
//! and pixel noise — so classification requires recognizing the *global
//! arrangement* of blobs (attention-relevant structure), not a single pixel.
//!
//! The same geometry yields dense labels: every pixel is labeled by the blob
//! region that dominates it (background = class 0), giving the ADE20K-style
//! per-patch segmentation targets of Tab. 4.

use crate::data::rng::Rng;
use crate::runtime::Tensor;
use anyhow::Result;

/// Dataset split (affects the derived RNG stream only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
}

impl Split {
    /// Stable stream id used to derive split-specific RNG streams.
    pub fn stream_id(self) -> u64 {
        match self {
            Split::Train => 0,
            Split::Val => 1,
        }
    }
}

/// One Gaussian blob of a class prototype.
#[derive(Debug, Clone)]
struct Blob {
    cy: f32,
    cx: f32,
    sigma: f32,
    color: [f32; 3],
    /// Segmentation class this blob paints (1..seg_classes; 0 = background).
    seg_class: i32,
}

/// Corpus configuration + deterministic prototypes.
#[derive(Debug, Clone)]
pub struct ImageCorpus {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub seg_classes: usize,
    pub seed: u64,
    /// Per-pixel additive Gaussian noise (difficulty knob; bundles may
    /// override via meta "noise_sigma").
    pub noise_sigma: f32,
    blobs: Vec<Vec<Blob>>, // per class
}

pub const BLOBS_PER_CLASS: usize = 4;

impl ImageCorpus {
    pub fn new(
        height: usize,
        width: usize,
        channels: usize,
        num_classes: usize,
        seg_classes: usize,
        seed: u64,
    ) -> Self {
        assert!(seg_classes >= 2, "need background + at least one class");
        // A shared palette of blob appearances (color/scale) is drawn once;
        // classes differ only in the *arrangement* of which palette entries
        // sit where. This blocks single-pixel color shortcuts: telling
        // classes apart requires relating multiple regions — the attention-
        // relevant structure — and keeps accuracies off the ceiling.
        let mut prng = Rng::derive(seed, &[0xA1E77E]);
        let mut blobs = Vec::with_capacity(num_classes);
        let palette: Vec<([f32; 3], f32)> = (0..BLOBS_PER_CLASS)
            .map(|_| {
                (
                    [
                        prng.range_f32(-1.0, 1.0),
                        prng.range_f32(-1.0, 1.0),
                        prng.range_f32(-1.0, 1.0),
                    ],
                    prng.range_f32(0.07, 0.13),
                )
            })
            .collect();
        for c in 0..num_classes {
            let mut rng = Rng::derive(seed, &[0xB10B, c as u64]);
            let mut cls = Vec::with_capacity(BLOBS_PER_CLASS);
            for b in 0..BLOBS_PER_CLASS {
                let (color, sigma) = palette[b];
                cls.push(Blob {
                    cy: rng.range_f32(0.15, 0.85),
                    cx: rng.range_f32(0.15, 0.85),
                    sigma,
                    color,
                    seg_class: 1 + ((c * BLOBS_PER_CLASS + b) % (seg_classes - 1)) as i32,
                });
            }
            blobs.push(cls);
        }
        ImageCorpus {
            height,
            width,
            channels,
            num_classes,
            seg_classes,
            seed,
            noise_sigma: 0.45,
            blobs,
        }
    }

    /// Override the noise level (returns self for builder-style use).
    pub fn with_noise(mut self, sigma: f32) -> Self {
        self.noise_sigma = sigma;
        self
    }

    /// Deterministic label of sample `idx` (balanced round-robin + hash mix).
    pub fn label(&self, split: Split, idx: u64) -> i32 {
        let mut rng = Rng::derive(self.seed, &[0x1ABE1, split.stream_id(), idx]);
        rng.below(self.num_classes) as i32
    }

    /// Render one sample: (pixels [H*W*C] row-major HWC, per-pixel seg labels).
    pub fn render(&self, split: Split, idx: u64) -> (Vec<f32>, Vec<i32>, i32) {
        let label = self.label(split, idx);
        let mut rng = Rng::derive(self.seed, &[0x5A3B1E, split.stream_id(), idx]);
        let (h, w, ch) = (self.height, self.width, self.channels);

        // Global wrap-around translation + brightness; per-blob jitter.
        let dy = rng.range_f32(-0.2, 0.2);
        let dx = rng.range_f32(-0.2, 0.2);
        let brightness = rng.range_f32(0.7, 1.3);
        let noise_sigma = self.noise_sigma;

        let proto = &self.blobs[label as usize];
        let jitter: Vec<(f32, f32)> = proto
            .iter()
            .map(|_| (rng.range_f32(-0.04, 0.04), rng.range_f32(-0.04, 0.04)))
            .collect();

        let mut pixels = vec![0.0f32; h * w * ch];
        let mut seg = vec![0i32; h * w];
        for y in 0..h {
            for x in 0..w {
                let fy = (y as f32 + 0.5) / h as f32;
                let fx = (x as f32 + 0.5) / w as f32;
                let mut best_infl = 0.0f32;
                let mut best_seg = 0i32;
                let mut acc = [0.0f32; 3];
                for (b, blob) in proto.iter().enumerate() {
                    // Wrapping distance on the unit torus keeps translated
                    // blobs whole.
                    let mut ddy = (fy - (blob.cy + dy + jitter[b].0)).abs() % 1.0;
                    let mut ddx = (fx - (blob.cx + dx + jitter[b].1)).abs() % 1.0;
                    if ddy > 0.5 {
                        ddy = 1.0 - ddy;
                    }
                    if ddx > 0.5 {
                        ddx = 1.0 - ddx;
                    }
                    let d2 = ddy * ddy + ddx * ddx;
                    let infl = (-d2 / (2.0 * blob.sigma * blob.sigma)).exp();
                    for (a, &col) in acc.iter_mut().zip(blob.color.iter()) {
                        *a += infl * col;
                    }
                    if infl > best_infl {
                        best_infl = infl;
                        best_seg = blob.seg_class;
                    }
                }
                seg[y * w + x] = if best_infl > 0.3 { best_seg } else { 0 };
                for c in 0..ch {
                    let noise = rng.normal() as f32 * noise_sigma;
                    pixels[(y * w + x) * ch + c] = acc[c.min(2)] * brightness + noise;
                }
            }
        }
        (pixels, seg, label)
    }

    /// Per-patch segmentation labels: majority pixel label within each patch.
    pub fn patch_labels(&self, seg: &[i32], patch: usize) -> Vec<i32> {
        let (h, w) = (self.height, self.width);
        let (gh, gw) = (h / patch, w / patch);
        let mut out = Vec::with_capacity(gh * gw);
        for py in 0..gh {
            for px in 0..gw {
                let mut counts = vec![0usize; self.seg_classes];
                for y in 0..patch {
                    for x in 0..patch {
                        let lbl = seg[(py * patch + y) * w + (px * patch + x)];
                        counts[lbl as usize] += 1;
                    }
                }
                let best = counts
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, &c)| c)
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0);
                out.push(best);
            }
        }
        out
    }

    /// Classification batch: (x [B,H,W,C] f32, y [B] i32).
    pub fn batch_cls(&self, split: Split, start: u64, batch: usize) -> Result<(Tensor, Tensor)> {
        let (h, w, ch) = (self.height, self.width, self.channels);
        let mut xs = Vec::with_capacity(batch * h * w * ch);
        let mut ys = Vec::with_capacity(batch);
        for i in 0..batch {
            let (px, _, label) = self.render(split, start + i as u64);
            xs.extend_from_slice(&px);
            ys.push(label);
        }
        Ok((Tensor::f32(&[batch, h, w, ch], xs)?, Tensor::i32(&[batch], ys)?))
    }

    /// Segmentation batch: (x [B,H,W,C] f32, y [B, N] i32) with N = patches.
    pub fn batch_seg(
        &self,
        split: Split,
        start: u64,
        batch: usize,
        patch: usize,
    ) -> Result<(Tensor, Tensor)> {
        let (h, w, ch) = (self.height, self.width, self.channels);
        let n = (h / patch) * (w / patch);
        let mut xs = Vec::with_capacity(batch * h * w * ch);
        let mut ys = Vec::with_capacity(batch * n);
        for i in 0..batch {
            let (px, seg, _) = self.render(split, start + i as u64);
            xs.extend_from_slice(&px);
            ys.extend_from_slice(&self.patch_labels(&seg, patch));
        }
        Ok((Tensor::f32(&[batch, h, w, ch], xs)?, Tensor::i32(&[batch, n], ys)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> ImageCorpus {
        ImageCorpus::new(32, 32, 3, 10, 8, 42)
    }

    #[test]
    fn deterministic_rendering() {
        let c = corpus();
        let (a, sa, la) = c.render(Split::Train, 7);
        let (b, sb, lb) = c.render(Split::Train, 7);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        assert_eq!(la, lb);
    }

    #[test]
    fn splits_differ() {
        let c = corpus();
        let (a, _, _) = c.render(Split::Train, 7);
        let (b, _, _) = c.render(Split::Val, 7);
        assert_ne!(a, b);
    }

    #[test]
    fn labels_in_range_and_balanced() {
        let c = corpus();
        let mut counts = vec![0usize; 10];
        for i in 0..1000 {
            let l = c.label(Split::Train, i);
            assert!((0..10).contains(&l));
            counts[l as usize] += 1;
        }
        // Roughly balanced: each class within 3x of uniform.
        for &cnt in &counts {
            assert!(cnt > 30 && cnt < 300, "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn seg_labels_in_range() {
        let c = corpus();
        let (_, seg, _) = c.render(Split::Train, 3);
        assert!(seg.iter().all(|&s| (0..8).contains(&s)));
        // Some foreground must exist.
        assert!(seg.iter().any(|&s| s > 0));
        let patches = c.patch_labels(&seg, 4);
        assert_eq!(patches.len(), 64);
        assert!(patches.iter().all(|&s| (0..8).contains(&s)));
    }

    #[test]
    fn batch_shapes() {
        let c = corpus();
        let (x, y) = c.batch_cls(Split::Train, 0, 4).unwrap();
        assert_eq!(x.shape(), &[4, 32, 32, 3]);
        assert_eq!(y.shape(), &[4]);
        let (x, y) = c.batch_seg(Split::Val, 0, 2, 4).unwrap();
        assert_eq!(x.shape(), &[2, 32, 32, 3]);
        assert_eq!(y.shape(), &[2, 64]);
    }

    #[test]
    fn pixel_stats_reasonable() {
        let c = corpus();
        let (px, _, _) = c.render(Split::Train, 0);
        let mean = px.iter().sum::<f32>() / px.len() as f32;
        let var = px.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / px.len() as f32;
        assert!(mean.abs() < 1.0, "mean {mean}");
        assert!(var > 0.01 && var < 4.0, "var {var}");
    }
}
