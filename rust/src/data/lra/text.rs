//! Byte-level text classification (LRA "Text" stand-in).
//!
//! Documents are long streams of mostly-neutral word tokens with *signal*
//! tokens from two disjoint sets sprinkled throughout. The label is which
//! signal set dominates — solving it requires aggregating evidence spread
//! over the whole sequence (no local shortcut), mirroring the byte-level
//! IMDb task's long-range nature.

use crate::data::images::Split;
use crate::data::lra::SeqTask;
use crate::data::rng::Rng;

pub const TOK_PAD: i32 = 0;

pub struct TextTask {
    seq_len: usize,
    vocab: usize,
    seed: u64,
    set_a: std::ops::Range<i32>,
    set_b: std::ops::Range<i32>,
}

impl TextTask {
    pub fn new(seq_len: usize, vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 24, "text task needs vocab >= 24");
        TextTask { seq_len, vocab, seed, set_a: 1..9, set_b: 9..17 }
    }
}

impl SeqTask for TextTask {
    fn name(&self) -> &'static str {
        "text"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn classes(&self) -> usize {
        2
    }

    fn sample(&self, split: Split, idx: u64) -> (Vec<i32>, i32) {
        let mut rng = Rng::derive(self.seed, &[0x7E87, split.stream_id(), idx]);
        let label = rng.coin(0.5) as i32;
        let len = self.seq_len - rng.below(self.seq_len / 5); // variable length

        // Signal budget: the dominant set gets `base + margin` tokens, the
        // other `base`; both scattered uniformly.
        let base = 4 + rng.below(6);
        let margin = 3 + rng.below(6);
        let (n_dom, n_sub) = (base + margin, base);
        let (dom, sub) = if label == 1 {
            (self.set_a.clone(), self.set_b.clone())
        } else {
            (self.set_b.clone(), self.set_a.clone())
        };

        // Neutral filler with mild bigram structure (word pairs), so the
        // model has non-signal statistics to latch onto — like real text.
        let neutral_lo = 17;
        let mut tokens = vec![TOK_PAD; self.seq_len];
        let mut pos = 0usize;
        while pos < len {
            let w = neutral_lo + rng.below(self.vocab - neutral_lo as usize) as i32;
            tokens[pos] = w;
            pos += 1;
            if pos < len && rng.coin(0.3) {
                // Deterministic "collocation": follow w with its pair token.
                let pair = neutral_lo
                    + ((w as usize * 7 + 3) % (self.vocab - neutral_lo as usize)) as i32;
                tokens[pos] = pair;
                pos += 1;
            }
        }

        // Scatter signal tokens at distinct random positions.
        let slots = rng.sample_distinct(len, (n_dom + n_sub).min(len));
        for (i, &p) in slots.iter().enumerate() {
            let range = if i < n_dom { dom.clone() } else { sub.clone() };
            let span = (range.end - range.start) as usize;
            tokens[p] = range.start + rng.below(span) as i32;
        }
        (tokens, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signal_counts_match_label() {
        let t = TextTask::new(512, 64, 21);
        for i in 0..100 {
            let (tokens, label) = t.sample(Split::Train, i);
            let a = tokens.iter().filter(|&&x| (1..9).contains(&x)).count();
            let b = tokens.iter().filter(|&&x| (9..17).contains(&x)).count();
            if label == 1 {
                assert!(a > b, "sample {i}: a={a} b={b} label=1");
            } else {
                assert!(b > a, "sample {i}: a={a} b={b} label=0");
            }
        }
    }

    #[test]
    fn mostly_neutral() {
        let t = TextTask::new(512, 64, 22);
        let (tokens, _) = t.sample(Split::Train, 0);
        let signal = tokens.iter().filter(|&&x| (1..17).contains(&x)).count();
        assert!(signal < 40, "too much signal: {signal}");
    }
}
