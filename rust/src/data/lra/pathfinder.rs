//! Path connectivity (LRA "Pathfinder" stand-in).
//!
//! A small grid contains two self-avoiding random-walk curves. Two endpoint
//! markers are placed either on the same curve (label 1) or on different
//! curves (label 0). Read as a flat token sequence, deciding connectivity
//! requires tracing a path through 2-D neighborhood structure — the
//! long-range spatial reasoning of the original task.
//!
//! Vocabulary: 0 = empty, 1 = curve pixel, 2 = endpoint marker, 3 = unused
//! (reserved; keeps vocab=4 as in specs.py).

use crate::data::images::Split;
use crate::data::lra::SeqTask;
use crate::data::rng::Rng;

pub const TOK_EMPTY: i32 = 0;
pub const TOK_CURVE: i32 = 1;
pub const TOK_END: i32 = 2;

pub struct Pathfinder {
    side: usize,
    seed: u64,
}

impl Pathfinder {
    pub fn new(seq_len: usize, seed: u64) -> Self {
        let side = (seq_len as f64).sqrt() as usize;
        assert_eq!(side * side, seq_len, "seq_len must be a perfect square");
        Pathfinder { side, seed }
    }

    /// Self-avoiding-ish random walk of `len` cells; returns visited cells.
    fn walk(&self, rng: &mut Rng, occupied: &[bool], len: usize) -> Vec<usize> {
        let s = self.side;
        // Try several starts to find room.
        'outer: for _ in 0..20 {
            let mut cells = Vec::with_capacity(len);
            let start = rng.below(s * s);
            if occupied[start] {
                continue;
            }
            let (mut y, mut x) = (start / s, start % s);
            cells.push(start);
            let mut visited = vec![false; s * s];
            visited[start] = true;
            while cells.len() < len {
                // Candidate moves (4-neighborhood), avoiding revisits and
                // other curves.
                let mut cands: Vec<(usize, usize)> = Vec::with_capacity(4);
                if y > 0 {
                    cands.push((y - 1, x));
                }
                if y + 1 < s {
                    cands.push((y + 1, x));
                }
                if x > 0 {
                    cands.push((y, x - 1));
                }
                if x + 1 < s {
                    cands.push((y, x + 1));
                }
                let valid: Vec<(usize, usize)> = cands
                    .into_iter()
                    .filter(|&(yy, xx)| !visited[yy * s + xx] && !occupied[yy * s + xx])
                    .collect();
                if valid.is_empty() {
                    if cells.len() >= len / 2 {
                        break; // good enough
                    }
                    continue 'outer; // stuck too early; retry
                }
                let (ny, nx) = valid[rng.below(valid.len())];
                y = ny;
                x = nx;
                visited[y * s + x] = true;
                cells.push(y * s + x);
            }
            return cells;
        }
        // Fallback: first unoccupied cell (degenerate but valid and disjoint).
        let free = occupied.iter().position(|&o| !o).unwrap_or(0);
        vec![free]
    }
}

impl SeqTask for Pathfinder {
    fn name(&self) -> &'static str {
        "pathfinder"
    }

    fn seq_len(&self) -> usize {
        self.side * self.side
    }

    fn vocab(&self) -> usize {
        4
    }

    fn classes(&self) -> usize {
        2
    }

    fn sample(&self, split: Split, idx: u64) -> (Vec<i32>, i32) {
        let s = self.side;
        let mut rng = Rng::derive(self.seed, &[0xFA7F1D, split.stream_id(), idx]);
        let label = rng.coin(0.5) as i32;
        let curve_len = s * 2 + rng.below(s);

        let mut grid = vec![TOK_EMPTY; s * s];
        let mut occupied = vec![false; s * s];

        let c1 = self.walk(&mut rng, &occupied, curve_len);
        // Dilate curve 1 into the occupancy mask so curve 2 can never touch
        // it — otherwise adjacent-but-distinct curves would be connected in
        // the 4-neighborhood sense and negatives would be mislabeled.
        for &c in &c1 {
            let (y, x) = (c / s, c % s);
            for (dy, dx) in [(0i64, 0i64), (-1, 0), (1, 0), (0, -1), (0, 1)] {
                let yy = y as i64 + dy;
                let xx = x as i64 + dx;
                if yy >= 0 && yy < s as i64 && xx >= 0 && xx < s as i64 {
                    occupied[yy as usize * s + xx as usize] = true;
                }
            }
        }
        let c2 = self.walk(&mut rng, &occupied, curve_len);
        for &c in &c2 {
            occupied[c] = true;
        }
        for &c in c1.iter().chain(c2.iter()) {
            grid[c] = TOK_CURVE;
        }

        // Endpoint markers.
        let (e1, e2) = if label == 1 {
            (c1[0], *c1.last().unwrap())
        } else {
            (c1[0], *c2.last().unwrap())
        };
        grid[e1] = TOK_END;
        grid[e2] = TOK_END;
        (grid, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// BFS over curve+endpoint cells must agree with the generated label
    /// (endpoints connected iff label == 1) — unless the two walks touch,
    /// which the generator prevents via `occupied`.
    #[test]
    fn connectivity_matches_label() {
        let t = Pathfinder::new(256, 51);
        let s = 16;
        for i in 0..60 {
            let (grid, label) = t.sample(Split::Train, i);
            let ends: Vec<usize> =
                grid.iter().enumerate().filter(|(_, &v)| v == TOK_END).map(|(i, _)| i).collect();
            if ends.len() != 2 {
                continue; // endpoints collided (rare degenerate walk); skip
            }
            // BFS from ends[0] over non-empty cells.
            let mut seen = vec![false; s * s];
            let mut queue = vec![ends[0]];
            seen[ends[0]] = true;
            while let Some(c) = queue.pop() {
                let (y, x) = (c / s, c % s);
                let mut push = |yy: usize, xx: usize, queue: &mut Vec<usize>| {
                    let cc = yy * s + xx;
                    if !seen[cc] && grid[cc] != TOK_EMPTY {
                        seen[cc] = true;
                        queue.push(cc);
                    }
                };
                if y > 0 {
                    push(y - 1, x, &mut queue);
                }
                if y + 1 < s {
                    push(y + 1, x, &mut queue);
                }
                if x > 0 {
                    push(y, x - 1, &mut queue);
                }
                if x + 1 < s {
                    push(y, x + 1, &mut queue);
                }
            }
            let connected = seen[ends[1]];
            assert_eq!(connected, label == 1, "sample {i}");
        }
    }

    #[test]
    fn has_two_endpoints_and_curves() {
        let t = Pathfinder::new(256, 52);
        let mut ok = 0;
        for i in 0..20 {
            let (grid, _) = t.sample(Split::Train, i);
            let ends = grid.iter().filter(|&&v| v == TOK_END).count();
            let curve = grid.iter().filter(|&&v| v == TOK_CURVE).count();
            if ends == 2 && curve > 16 {
                ok += 1;
            }
        }
        assert!(ok >= 18, "only {ok}/20 well-formed samples");
    }
}
