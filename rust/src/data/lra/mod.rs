//! Synthetic Long-Range-Arena-style tasks (Tab. 5 substrate).
//!
//! Each module generates one task with the same structure as its LRA
//! counterpart (ListOps expression trees, byte-level text classification,
//! document-pair retrieval, sequence-image classification, path
//! connectivity), scaled to the CPU testbed (DESIGN.md §3).
//!
//! All tasks implement [`SeqTask`]: deterministic `(split, idx) -> sample`
//! so any batch can be generated independently.

pub mod image;
pub mod listops;
pub mod pathfinder;
pub mod retrieval;
pub mod text;

use crate::data::images::Split;
use crate::runtime::Tensor;
use anyhow::Result;

/// A sequence-classification task: token ids in [0, vocab), one label.
pub trait SeqTask {
    fn name(&self) -> &'static str;
    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;
    fn classes(&self) -> usize;
    /// Deterministic sample; `tokens.len() == seq_len` (padded).
    fn sample(&self, split: Split, idx: u64) -> (Vec<i32>, i32);
}

/// Build a batch (x [B, N] i32, y [B] i32) from any task.
pub fn batch(task: &dyn SeqTask, split: Split, start: u64, bsz: usize) -> Result<(Tensor, Tensor)> {
    let n = task.seq_len();
    let mut xs = Vec::with_capacity(bsz * n);
    let mut ys = Vec::with_capacity(bsz);
    for i in 0..bsz {
        let (tokens, label) = task.sample(split, start + i as u64);
        debug_assert_eq!(tokens.len(), n);
        xs.extend_from_slice(&tokens);
        ys.push(label);
    }
    Ok((Tensor::i32(&[bsz, n], xs)?, Tensor::i32(&[bsz], ys)?))
}

/// Instantiate the task matching a t5 bundle's (task name, seq_len, vocab).
pub fn by_name(name: &str, seq_len: usize, vocab: usize, seed: u64) -> Box<dyn SeqTask> {
    match name {
        "listops" => Box::new(listops::ListOps::new(seq_len, seed)),
        "text" => Box::new(text::TextTask::new(seq_len, vocab, seed)),
        "retrieval" => Box::new(retrieval::Retrieval::new(seq_len, vocab, seed)),
        "image" => Box::new(image::SeqImage::new(seq_len, vocab, seed)),
        "pathfinder" => Box::new(pathfinder::Pathfinder::new(seq_len, seed)),
        other => panic!("unknown LRA task {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_samples() {
        let tasks: Vec<Box<dyn SeqTask>> = vec![
            by_name("listops", 256, 16, 1),
            by_name("text", 512, 64, 1),
            by_name("retrieval", 512, 64, 1),
            by_name("image", 256, 32, 1),
            by_name("pathfinder", 256, 4, 1),
        ];
        for t in &tasks {
            for idx in 0..20 {
                let (tokens, label) = t.sample(Split::Train, idx);
                assert_eq!(tokens.len(), t.seq_len(), "{}", t.name());
                assert!(
                    tokens.iter().all(|&x| (0..t.vocab() as i32).contains(&x)),
                    "{} token out of vocab",
                    t.name()
                );
                assert!((0..t.classes() as i32).contains(&label), "{}", t.name());
            }
        }
    }

    #[test]
    fn tasks_are_deterministic_and_split_sensitive() {
        let t = by_name("listops", 256, 16, 3);
        assert_eq!(t.sample(Split::Train, 5), t.sample(Split::Train, 5));
        assert_ne!(t.sample(Split::Train, 5).0, t.sample(Split::Val, 5).0);
    }

    #[test]
    fn labels_are_roughly_balanced() {
        for name in ["text", "retrieval", "pathfinder"] {
            let t = by_name(name, 256, 64, 7);
            let n = 400;
            let pos: usize = (0..n)
                .filter(|&i| t.sample(Split::Train, i).1 == 1)
                .count();
            assert!(
                pos > n as usize / 4 && pos < 3 * n as usize / 4,
                "{name}: {pos}/{n} positive"
            );
        }
    }

    #[test]
    fn batch_shapes() {
        let t = by_name("text", 512, 64, 1);
        let (x, y) = batch(t.as_ref(), Split::Train, 0, 8).unwrap();
        assert_eq!(x.shape(), &[8, 512]);
        assert_eq!(y.shape(), &[8]);
    }
}
