//! Synthetic Long-Range-Arena-style tasks (Tab. 5 substrate).
//!
//! Each module generates one task with the same structure as its LRA
//! counterpart (ListOps expression trees, byte-level text classification,
//! document-pair retrieval, sequence-image classification, path
//! connectivity), scaled to the CPU testbed (DESIGN.md §3).
//!
//! All tasks implement [`SeqTask`]: deterministic `(split, idx) -> sample`
//! so any batch can be generated independently.

pub mod image;
pub mod listops;
pub mod pathfinder;
pub mod retrieval;
pub mod text;

use crate::data::images::Split;
use crate::runtime::Tensor;
use anyhow::Result;

/// The five LRA-style task names [`by_name`] accepts.
pub const TASK_NAMES: [&str; 5] = ["listops", "text", "retrieval", "image", "pathfinder"];

/// A sequence-classification task: token ids in [0, vocab), one label.
pub trait SeqTask {
    fn name(&self) -> &'static str;
    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;
    fn classes(&self) -> usize;
    /// Deterministic sample; `tokens.len() == seq_len` (padded).
    fn sample(&self, split: Split, idx: u64) -> (Vec<i32>, i32);
}

/// Build a batch as plain host buffers — row-major tokens `[bsz · N]` plus
/// `[bsz]` labels. This is the generation primitive: it needs no runtime,
/// no tensor type, and no artifacts, so the native model path and the
/// benches consume it directly.
pub fn batch_host(
    task: &dyn SeqTask,
    split: Split,
    start: u64,
    bsz: usize,
) -> (Vec<i32>, Vec<i32>) {
    let n = task.seq_len();
    let mut xs = Vec::with_capacity(bsz * n);
    let mut ys = Vec::with_capacity(bsz);
    for i in 0..bsz {
        let (tokens, label) = task.sample(split, start + i as u64);
        debug_assert_eq!(tokens.len(), n);
        xs.extend_from_slice(&tokens);
        ys.push(label);
    }
    (xs, ys)
}

/// Thin [`Tensor`] adapter over [`batch_host`] for the PJRT bundle path:
/// (x [B, N] i32, y [B] i32).
pub fn batch(task: &dyn SeqTask, split: Split, start: u64, bsz: usize) -> Result<(Tensor, Tensor)> {
    let n = task.seq_len();
    let (xs, ys) = batch_host(task, split, start, bsz);
    Ok((Tensor::i32(&[bsz, n], xs)?, Tensor::i32(&[bsz], ys)?))
}

/// Instantiate the task matching a t5 bundle's (task name, seq_len, vocab).
pub fn by_name(name: &str, seq_len: usize, vocab: usize, seed: u64) -> Box<dyn SeqTask> {
    match name {
        "listops" => Box::new(listops::ListOps::new(seq_len, seed)),
        "text" => Box::new(text::TextTask::new(seq_len, vocab, seed)),
        "retrieval" => Box::new(retrieval::Retrieval::new(seq_len, vocab, seed)),
        "image" => Box::new(image::SeqImage::new(seq_len, vocab, seed)),
        "pathfinder" => Box::new(pathfinder::Pathfinder::new(seq_len, seed)),
        other => panic!("unknown LRA task {other:?}"),
    }
}

/// The canonical vocabulary argument for a task name (`None` for unknown
/// names) — the single source of truth the CLI defaults and tests share.
/// Matches what the task constructors expect / fix internally: listops and
/// pathfinder have hard-wired vocabularies, text/retrieval need room for
/// their signal sets, image uses it as the quantization bin count.
pub fn default_vocab(name: &str) -> Option<usize> {
    match name {
        "listops" => Some(listops::VOCAB),
        "text" | "retrieval" => Some(64),
        "image" => Some(32),
        "pathfinder" => Some(4),
        _ => None,
    }
}

/// Non-panicking [`by_name`]: validates the task name and the shape
/// constraints the constructors would otherwise `assert!` on (a CLI typo
/// should be an error, not a process abort).
pub fn try_by_name(
    name: &str,
    seq_len: usize,
    vocab: usize,
    seed: u64,
) -> Result<Box<dyn SeqTask>> {
    anyhow::ensure!(
        TASK_NAMES.contains(&name),
        "unknown LRA task {name:?} (expected one of {TASK_NAMES:?})"
    );
    if matches!(name, "image" | "pathfinder") {
        let side = (seq_len as f64).sqrt() as usize;
        anyhow::ensure!(
            side * side == seq_len,
            "{name} needs a perfect-square seq_len, got {seq_len}"
        );
    }
    match name {
        "text" => anyhow::ensure!(vocab >= 24, "text needs vocab >= 24, got {vocab}"),
        "retrieval" => anyhow::ensure!(
            vocab >= 32 && seq_len >= 32,
            "retrieval needs vocab >= 32 and seq_len >= 32, got ({vocab}, {seq_len})"
        ),
        _ => {}
    }
    Ok(by_name(name, seq_len, vocab, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_samples() {
        let tasks: Vec<Box<dyn SeqTask>> = vec![
            by_name("listops", 256, 16, 1),
            by_name("text", 512, 64, 1),
            by_name("retrieval", 512, 64, 1),
            by_name("image", 256, 32, 1),
            by_name("pathfinder", 256, 4, 1),
        ];
        for t in &tasks {
            for idx in 0..20 {
                let (tokens, label) = t.sample(Split::Train, idx);
                assert_eq!(tokens.len(), t.seq_len(), "{}", t.name());
                assert!(
                    tokens.iter().all(|&x| (0..t.vocab() as i32).contains(&x)),
                    "{} token out of vocab",
                    t.name()
                );
                assert!((0..t.classes() as i32).contains(&label), "{}", t.name());
            }
        }
    }

    #[test]
    fn tasks_are_deterministic_and_split_sensitive() {
        let t = by_name("listops", 256, 16, 3);
        assert_eq!(t.sample(Split::Train, 5), t.sample(Split::Train, 5));
        assert_ne!(t.sample(Split::Train, 5).0, t.sample(Split::Val, 5).0);
    }

    #[test]
    fn labels_are_roughly_balanced() {
        for name in ["text", "retrieval", "pathfinder"] {
            let t = by_name(name, 256, 64, 7);
            let n = 400;
            let pos: usize = (0..n)
                .filter(|&i| t.sample(Split::Train, i).1 == 1)
                .count();
            assert!(
                pos > n as usize / 4 && pos < 3 * n as usize / 4,
                "{name}: {pos}/{n} positive"
            );
        }
    }

    #[test]
    fn batch_shapes() {
        let t = by_name("text", 512, 64, 1);
        let (x, y) = batch(t.as_ref(), Split::Train, 0, 8).unwrap();
        assert_eq!(x.shape(), &[8, 512]);
        assert_eq!(y.shape(), &[8]);
    }

    /// The SeqTask contract, pinned for all five tasks: `sample(split,
    /// idx)` is reproducible across calls *and* across task instances,
    /// tokens stay inside the vocabulary, labels inside the class set,
    /// and every sequence is exactly `seq_len` long. n = 64 is a perfect
    /// square, so it is valid for every task.
    #[test]
    fn all_tasks_deterministic_and_bounded() {
        for name in TASK_NAMES {
            let (n, vocab) = (64, default_vocab(name).unwrap());
            let t = try_by_name(name, n, vocab, 9).unwrap();
            let fresh = try_by_name(name, n, vocab, 9).unwrap(); // same seed, new instance
            for split in [Split::Train, Split::Val] {
                for idx in 0..12u64 {
                    let (tokens, label) = t.sample(split, idx);
                    assert_eq!(
                        t.sample(split, idx),
                        (tokens.clone(), label),
                        "{name}: resample must be identical"
                    );
                    assert_eq!(
                        fresh.sample(split, idx),
                        (tokens.clone(), label),
                        "{name}: fresh instance must agree"
                    );
                    assert_eq!(tokens.len(), t.seq_len(), "{name}: length != seq_len");
                    assert!(
                        tokens.iter().all(|&x| (0..t.vocab() as i32).contains(&x)),
                        "{name}: token outside [0, vocab)"
                    );
                    assert!(
                        (0..t.classes() as i32).contains(&label),
                        "{name}: label {label} outside [0, classes)"
                    );
                }
            }
        }
    }

    #[test]
    fn batch_host_matches_tensor_batch() {
        let t = by_name("text", 128, 64, 1);
        let (xs, ys) = batch_host(t.as_ref(), Split::Train, 3, 4);
        assert_eq!(xs.len(), 4 * 128);
        assert_eq!(ys.len(), 4);
        let (x, y) = batch(t.as_ref(), Split::Train, 3, 4).unwrap();
        assert_eq!(x.as_i32().unwrap(), xs.as_slice());
        assert_eq!(y.as_i32().unwrap(), ys.as_slice());
        // Random access: batch 3 regenerated standalone matches.
        let (one, _) = batch_host(t.as_ref(), Split::Train, 5, 1);
        assert_eq!(&xs[2 * 128..3 * 128], one.as_slice());
    }

    #[test]
    fn try_by_name_rejects_bad_shapes_without_panicking() {
        assert!(try_by_name("nope", 64, 16, 1).is_err());
        assert!(try_by_name("image", 200, 32, 1).is_err(), "200 is not a perfect square");
        assert!(try_by_name("pathfinder", 65, 4, 1).is_err());
        assert!(try_by_name("text", 64, 8, 1).is_err(), "text vocab floor");
        assert!(try_by_name("retrieval", 16, 64, 1).is_err(), "retrieval seq floor");
        for name in TASK_NAMES {
            assert!(try_by_name(name, 256, default_vocab(name).unwrap(), 1).is_ok(), "{name}");
        }
        assert!(default_vocab("nope").is_none());
    }
}
