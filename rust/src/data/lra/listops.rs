//! ListOps: hierarchical prefix-notation expressions over digits 0-9.
//!
//! Token vocabulary (16 = the LRA convention of fused open-brackets):
//!   0..=9   digits
//!   10..=13 `[MAX` `[MIN` `[MED` `[SM`
//!   14      `]`
//!   15      PAD
//!
//! The label is the expression's value (10-way classification). Ground
//! truth is computed by the generator itself — solving the task requires
//! modeling the full tree, the paper's long-range hierarchical benchmark.

use crate::data::images::Split;
use crate::data::lra::SeqTask;
use crate::data::rng::Rng;

pub const TOK_CLOSE: i32 = 14;
pub const TOK_PAD: i32 = 15;
pub const VOCAB: usize = 16;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Max,
    Min,
    Med,
    Sm,
}

impl Op {
    fn token(self) -> i32 {
        match self {
            Op::Max => 10,
            Op::Min => 11,
            Op::Med => 12,
            Op::Sm => 13,
        }
    }

    fn apply(self, args: &[i32]) -> i32 {
        debug_assert!(!args.is_empty());
        match self {
            Op::Max => *args.iter().max().unwrap(),
            Op::Min => *args.iter().min().unwrap(),
            Op::Med => {
                let mut v = args.to_vec();
                v.sort_unstable();
                v[(v.len() - 1) / 2]
            }
            Op::Sm => args.iter().sum::<i32>() % 10,
        }
    }
}

pub struct ListOps {
    seq_len: usize,
    seed: u64,
    max_depth: usize,
    max_args: usize,
}

impl ListOps {
    pub fn new(seq_len: usize, seed: u64) -> Self {
        ListOps { seq_len, seed, max_depth: 4, max_args: 5 }
    }

    /// Recursively emit a subexpression; returns its value.
    /// `budget` is the remaining token budget (mutated).
    fn gen_expr(&self, rng: &mut Rng, depth: usize, budget: &mut usize, out: &mut Vec<i32>) -> i32 {
        // A node costs at least 2 (open+close) + 2 children.
        if depth >= self.max_depth || *budget < 6 || rng.coin(0.25) {
            *budget -= 1;
            let d = rng.below(10) as i32;
            out.push(d);
            return d;
        }
        let op = match rng.below(4) {
            0 => Op::Max,
            1 => Op::Min,
            2 => Op::Med,
            _ => Op::Sm,
        };
        out.push(op.token());
        *budget -= 2; // open + close
        let nargs = 2 + rng.below(self.max_args - 1);
        let mut vals = Vec::with_capacity(nargs);
        for _ in 0..nargs {
            if *budget < 2 {
                break;
            }
            vals.push(self.gen_expr(rng, depth + 1, budget, out));
        }
        if vals.is_empty() {
            // Degenerate: ensure at least one argument.
            *budget = budget.saturating_sub(1);
            let d = rng.below(10) as i32;
            out.push(d);
            vals.push(d);
        }
        out.push(TOK_CLOSE);
        op.apply(&vals)
    }
}

impl SeqTask for ListOps {
    fn name(&self) -> &'static str {
        "listops"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn vocab(&self) -> usize {
        VOCAB
    }

    fn classes(&self) -> usize {
        10
    }

    fn sample(&self, split: Split, idx: u64) -> (Vec<i32>, i32) {
        let mut rng = Rng::derive(self.seed, &[0x115705, split.stream_id(), idx]);
        let mut tokens = Vec::with_capacity(self.seq_len);
        // Use most of the budget so sequences are genuinely long.
        let mut budget = self.seq_len * 3 / 4;
        let value = self.gen_expr(&mut rng, 0, &mut budget, &mut tokens);
        tokens.truncate(self.seq_len);
        while tokens.len() < self.seq_len {
            tokens.push(TOK_PAD);
        }
        (tokens, value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_semantics() {
        assert_eq!(Op::Max.apply(&[3, 9, 1]), 9);
        assert_eq!(Op::Min.apply(&[3, 9, 1]), 1);
        assert_eq!(Op::Med.apply(&[3, 9, 1]), 3);
        assert_eq!(Op::Med.apply(&[1, 2, 3, 4]), 2); // floor median
        assert_eq!(Op::Sm.apply(&[7, 8]), 5);
    }

    #[test]
    fn expressions_are_balanced() {
        let t = ListOps::new(256, 11);
        for i in 0..50 {
            let (tokens, label) = t.sample(Split::Train, i);
            let mut depth = 0i32;
            for &tok in &tokens {
                match tok {
                    10..=13 => depth += 1,
                    TOK_CLOSE => {
                        depth -= 1;
                        assert!(depth >= 0, "unbalanced at sample {i}");
                    }
                    _ => {}
                }
            }
            assert_eq!(depth, 0, "unclosed brackets in sample {i}");
            assert!((0..10).contains(&label));
        }
    }

    /// Independent stack evaluator must agree with the generator's label.
    #[test]
    fn independent_evaluator_agrees() {
        fn eval(tokens: &[i32]) -> i32 {
            let mut stack: Vec<(i32, Vec<i32>)> = vec![];
            let mut top_args: Vec<i32> = vec![];
            for &t in tokens {
                match t {
                    0..=9 => top_args.push(t),
                    10..=13 => {
                        stack.push((t, std::mem::take(&mut top_args)));
                    }
                    TOK_CLOSE => {
                        let (op, saved) = stack.pop().unwrap();
                        let val = match op {
                            10 => *top_args.iter().max().unwrap(),
                            11 => *top_args.iter().min().unwrap(),
                            12 => {
                                let mut v = top_args.clone();
                                v.sort_unstable();
                                v[(v.len() - 1) / 2]
                            }
                            _ => top_args.iter().sum::<i32>() % 10,
                        };
                        top_args = saved;
                        top_args.push(val);
                    }
                    _ => {} // PAD
                }
            }
            assert_eq!(top_args.len(), 1);
            top_args[0]
        }

        let t = ListOps::new(256, 5);
        for i in 0..100 {
            let (tokens, label) = t.sample(Split::Val, i);
            assert_eq!(eval(&tokens), label, "sample {i}");
        }
    }

    #[test]
    fn sequences_use_budget() {
        let t = ListOps::new(256, 3);
        let mut total_non_pad = 0usize;
        for i in 0..20 {
            let (tokens, _) = t.sample(Split::Train, i);
            total_non_pad += tokens.iter().filter(|&&x| x != TOK_PAD).count();
        }
        // Average expression length should be a sizable fraction of seq_len.
        assert!(total_non_pad / 20 > 40, "expressions too short: {}", total_non_pad / 20);
    }
}
