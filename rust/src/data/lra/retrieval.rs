//! Document-pair retrieval (LRA "Retrieval" stand-in).
//!
//! Two documents are concatenated with a SEP token; the label is whether
//! they share a *topic* (a small set of characteristic tokens each document
//! repeats among noise). Matching requires comparing token statistics
//! across the two halves — the cross-document long-range dependency of the
//! original AAN task.

use crate::data::images::Split;
use crate::data::lra::SeqTask;
use crate::data::rng::Rng;

pub const TOK_PAD: i32 = 0;
pub const TOK_SEP: i32 = 1;
const TOPIC_SIZE: usize = 6;

pub struct Retrieval {
    seq_len: usize,
    vocab: usize,
    seed: u64,
}

impl Retrieval {
    pub fn new(seq_len: usize, vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 32);
        assert!(seq_len >= 32);
        Retrieval { seq_len, vocab, seed }
    }

    /// Sample a topic: TOPIC_SIZE distinct word tokens (>= 2).
    fn topic(&self, rng: &mut Rng) -> Vec<i32> {
        rng.sample_distinct(self.vocab - 2, TOPIC_SIZE)
            .into_iter()
            .map(|x| (x + 2) as i32)
            .collect()
    }

    /// Fill `out` with a document about `topic`: topic tokens at ~25%
    /// density among uniform noise words.
    fn write_doc(&self, rng: &mut Rng, topic: &[i32], out: &mut [i32]) {
        for slot in out.iter_mut() {
            *slot = if rng.coin(0.25) {
                topic[rng.below(topic.len())]
            } else {
                (2 + rng.below(self.vocab - 2)) as i32
            };
        }
    }
}

impl SeqTask for Retrieval {
    fn name(&self) -> &'static str {
        "retrieval"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn classes(&self) -> usize {
        2
    }

    fn sample(&self, split: Split, idx: u64) -> (Vec<i32>, i32) {
        let mut rng = Rng::derive(self.seed, &[0x8E78, split.stream_id(), idx]);
        let label = rng.coin(0.5) as i32;
        let half = (self.seq_len - 1) / 2;

        let topic1 = self.topic(&mut rng);
        let topic2 = if label == 1 {
            topic1.clone()
        } else {
            // Disjoint topic: resample until no overlap (expected ~1 iter).
            loop {
                let t = self.topic(&mut rng);
                if t.iter().all(|x| !topic1.contains(x)) {
                    break t;
                }
            }
        };

        let mut tokens = vec![TOK_PAD; self.seq_len];
        let (doc1, rest) = tokens.split_at_mut(half);
        self.write_doc(&mut rng, &topic1, doc1);
        rest[0] = TOK_SEP;
        let doc2_len = half.min(rest.len() - 1);
        self.write_doc(&mut rng, &topic2, &mut rest[1..1 + doc2_len]);
        (tokens, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sep_present_and_halves_filled() {
        let t = Retrieval::new(512, 64, 31);
        let (tokens, _) = t.sample(Split::Train, 0);
        let half = (512 - 1) / 2;
        assert_eq!(tokens[half], TOK_SEP);
        assert!(tokens[..half].iter().all(|&x| x >= 2));
    }

    #[test]
    fn topic_overlap_tracks_label() {
        let t = Retrieval::new(512, 64, 32);
        let half = (512 - 1) / 2;
        for i in 0..60 {
            let (tokens, label) = t.sample(Split::Val, i);
            // Estimate topics by token frequency in each half.
            let freq = |xs: &[i32]| {
                let mut f = std::collections::HashMap::new();
                for &x in xs {
                    if x >= 2 {
                        *f.entry(x).or_insert(0usize) += 1;
                    }
                }
                let mut v: Vec<(i32, usize)> = f.into_iter().collect();
                v.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
                v.truncate(TOPIC_SIZE);
                v.into_iter().map(|(t, _)| t).collect::<Vec<_>>()
            };
            let t1 = freq(&tokens[..half]);
            let t2 = freq(&tokens[half + 1..]);
            let overlap = t1.iter().filter(|x| t2.contains(x)).count();
            if label == 1 {
                assert!(overlap >= 3, "sample {i}: overlap {overlap} for positive");
            } else {
                assert!(overlap <= 2, "sample {i}: overlap {overlap} for negative");
            }
        }
    }
}
