//! Sequence-image classification (LRA "Image" stand-in).
//!
//! A grayscale rendering of the synthetic blob corpus, flattened to a pixel
//! sequence and quantized to token ids — the sCIFAR-style "classify an image
//! you can only read as a 1-D stream" task.

use crate::data::images::{ImageCorpus, Split};
use crate::data::lra::SeqTask;
use crate::data::rng::Rng;

pub struct SeqImage {
    side: usize,
    vocab: usize,
    corpus: ImageCorpus,
}

impl SeqImage {
    pub fn new(seq_len: usize, vocab: usize, seed: u64) -> Self {
        let side = (seq_len as f64).sqrt() as usize;
        assert_eq!(side * side, seq_len, "seq_len must be a perfect square");
        // Grayscale (1 channel), 10 classes like sCIFAR.
        let corpus = ImageCorpus::new(side, side, 1, 10, 2, seed ^ 0x1A6E);
        SeqImage { side, vocab, corpus }
    }
}

impl SeqTask for SeqImage {
    fn name(&self) -> &'static str {
        "image"
    }

    fn seq_len(&self) -> usize {
        self.side * self.side
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn classes(&self) -> usize {
        10
    }

    fn sample(&self, split: Split, idx: u64) -> (Vec<i32>, i32) {
        let (pixels, _, label) = self.corpus.render(split, idx);
        // Quantize pixel intensities (~[-2, 2]) into vocab bins; dithering
        // noise is already in the render.
        let v = self.vocab as f32;
        let tokens = pixels
            .iter()
            .map(|&p| {
                let unit = ((p + 2.0) / 4.0).clamp(0.0, 0.999);
                (unit * v) as i32
            })
            .collect();
        let _ = Rng::new(0); // (rng unused; kept for interface symmetry)
        (tokens, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantization_in_vocab() {
        let t = SeqImage::new(256, 32, 41);
        let (tokens, label) = t.sample(Split::Train, 0);
        assert_eq!(tokens.len(), 256);
        assert!(tokens.iter().all(|&x| (0..32).contains(&x)));
        assert!((0..10).contains(&label));
    }

    #[test]
    fn uses_multiple_bins() {
        let t = SeqImage::new(256, 32, 42);
        let (tokens, _) = t.sample(Split::Train, 1);
        let distinct: std::collections::HashSet<i32> = tokens.iter().copied().collect();
        assert!(distinct.len() > 4, "only {} distinct bins", distinct.len());
    }
}
