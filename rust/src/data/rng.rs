//! Deterministic, dependency-free PRNG for the synthetic data generators.
//!
//! splitmix64 seeds an xoshiro256++ core; every generator in `data/` derives
//! its stream from (corpus seed, split, sample index) so datasets are
//! reproducible and order-independent — a worker can generate batch 17
//! without generating batches 0..16 first.

/// splitmix64 step (also used standalone for hashing seeds).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 via splitmix64 (as recommended by the authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream from (seed, stream ids) — hash-combined.
    pub fn derive(seed: u64, ids: &[u64]) -> Self {
        let mut sm = seed;
        let mut acc = splitmix64(&mut sm);
        for &id in ids {
            let mut x = acc ^ id.wrapping_mul(0x9E3779B97F4A7C15);
            acc = splitmix64(&mut x);
        }
        Rng::new(acc)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.uniform() as f32) * (hi - lo)
    }

    /// Uniform integer in [0, n). Rejection-free (bias negligible for n << 2^64).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `count` distinct indices from [0, n) (count <= n).
    pub fn sample_distinct(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n);
        // Partial Fisher–Yates over an index vector.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..count {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(count);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::derive(1, &[0, 0]);
        let mut b = Rng::derive(1, &[0, 1]);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_distinct(20, 10);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
        assert!(s.iter().all(|&x| x < 20));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
