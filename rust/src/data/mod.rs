//! Synthetic data substrates (DESIGN.md §3 substitutions).
//!
//! - [`rng`]: deterministic splittable PRNG.
//! - [`images`]: class-conditional blob corpus (ImageNet/ADE20K stand-in).
//! - [`lra`]: five Long-Range-Arena-style sequence tasks.
//! - [`loader`]: bundle-driven batch source used by the trainer.

pub mod images;
pub mod loader;
pub mod lra;
pub mod rng;

pub use images::{ImageCorpus, Split};
pub use loader::BatchSource;
