//! Bundle-driven batch source: reads a bundle's model config from the
//! manifest and produces matching (x, y) batches from the right synthetic
//! generator. This is the only glue between the manifest and `data/`.

use anyhow::{bail, Result};

use crate::data::images::{ImageCorpus, Split};
use crate::data::lra::{self, SeqTask};
use crate::runtime::{BundleSpec, Tensor};

/// Default corpus seed; experiments may override via `with_seed`.
pub const DEFAULT_SEED: u64 = 0xC0FFEE;

/// A deterministic stream of batches for one bundle's task.
pub struct BatchSource {
    kind: SourceKind,
    batch_size: usize,
}

enum SourceKind {
    Cls { corpus: ImageCorpus },
    Seg { corpus: ImageCorpus, patch: usize },
    Lra { task: Box<dyn SeqTask> },
}

impl BatchSource {
    /// Build the batch source matching a bundle's model config.
    pub fn for_bundle(bundle: &BundleSpec) -> Result<Self> {
        Self::for_bundle_seeded(bundle, DEFAULT_SEED)
    }

    pub fn for_bundle_seeded(bundle: &BundleSpec, seed: u64) -> Result<Self> {
        let m = &bundle.model;
        let batch_size = bundle.train.batch_size;
        let noise = bundle
            .meta
            .get("noise_sigma")
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.45) as f32;
        let kind = match m.task.as_str() {
            "cls_image" => SourceKind::Cls {
                corpus: ImageCorpus::new(
                    m.image_hw.0,
                    m.image_hw.1,
                    m.channels,
                    m.num_classes,
                    8,
                    seed,
                )
                .with_noise(noise),
            },
            "seg_image" => SourceKind::Seg {
                corpus: ImageCorpus::new(
                    m.image_hw.0,
                    m.image_hw.1,
                    m.channels,
                    // Classification classes unused for seg targets; the seg
                    // label space must match num_classes.
                    10,
                    m.num_classes,
                    seed,
                ),
                patch: m.patch,
            },
            "lra" => {
                let task_name = bundle
                    .meta_str("task")
                    .unwrap_or("text");
                SourceKind::Lra { task: lra::by_name(task_name, m.seq_len, m.vocab, seed) }
            }
            other => bail!("unknown task {other:?}"),
        };
        Ok(BatchSource { kind, batch_size })
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// The `i`-th batch of a split (deterministic, random-access).
    pub fn batch(&self, split: Split, i: u64) -> Result<(Tensor, Tensor)> {
        let start = i * self.batch_size as u64;
        match &self.kind {
            SourceKind::Cls { corpus } => corpus.batch_cls(split, start, self.batch_size),
            SourceKind::Seg { corpus, patch } => {
                corpus.batch_seg(split, start, self.batch_size, *patch)
            }
            SourceKind::Lra { task } => lra::batch(task.as_ref(), split, start, self.batch_size),
        }
    }
}
