//! Structured event log: a process-wide, leveled, JSON-lines journal.
//!
//! The serving path already has a handful of *decision points* — the
//! admission gate sheds a request, a bind broadcasts to every replica,
//! an engine catches a backend panic, the pool marks a replica
//! unhealthy, the server shuts down. Each of those now emits one
//! [`LogRecord`] into a fixed-capacity ring modeled on
//! [`TraceRing`](crate::coordinator::trace::TraceRing): slot allocation
//! is a single `fetch_add` on a cursor, so concurrent emitters contend
//! only on the distinct slot they were assigned, and once the ring
//! wraps the oldest events are overwritten.
//!
//! Records carry a monotone sequence number, a wall-clock timestamp
//! (unix milliseconds), a [`Level`], a stable dotted event name, an
//! optional `trace_id` correlating the event to `GET /v1/trace`
//! records, and a human-readable message. They are exported newest
//! first via `GET /v1/logs?limit=N&level=L` and the `client logs`
//! subcommand, one JSON object per event (JSON-lines when printed).
//!
//! Verbosity is a process-wide threshold: `MITA_LOG` (env) seeds it,
//! `--log-level` on `serve` overrides it, and events below the
//! threshold are dropped at the emission site before any formatting
//! cost is paid by [`enabled`]-guarded callers.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Value;

/// Default number of events retained by the process journal.
pub const DEFAULT_LOG_CAPACITY: usize = 512;

/// Event severity. Ordered: `Debug < Info < Warn < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(usize)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    /// Lowercase name, as rendered in JSON and accepted by [`Level::parse`].
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }

    /// Parse a level name (case-insensitive). `None` for unknown names.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }

    fn from_usize(v: usize) -> Level {
        match v {
            0 => Level::Debug,
            1 => Level::Info,
            2 => Level::Warn,
            _ => Level::Error,
        }
    }
}

/// One structured event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogRecord {
    /// Process-wide monotone sequence number (journal order).
    pub seq: u64,
    /// Wall-clock emission time, milliseconds since the unix epoch.
    pub unix_ms: u64,
    pub level: Level,
    /// Stable dotted event name (`admission.shed`, `engine.panic`, ...).
    pub event: &'static str,
    /// Correlates the event with a `/v1/trace` record, when the event
    /// happened inside a traced request.
    pub trace_id: Option<u64>,
    /// Human-readable detail (free-form; the event name is the stable key).
    pub message: String,
}

impl LogRecord {
    /// Render as one JSON object (one line of the JSON-lines export).
    pub fn to_json(&self) -> Value {
        let trace = match self.trace_id {
            Some(id) => Value::Num(id as f64),
            None => Value::Null,
        };
        Value::obj(vec![
            ("seq", Value::Num(self.seq as f64)),
            ("unix_ms", Value::Num(self.unix_ms as f64)),
            ("level", Value::str(self.level.as_str())),
            ("event", Value::str(self.event)),
            ("trace_id", trace),
            ("message", Value::str(self.message.as_str())),
        ])
    }
}

/// Fixed-capacity event ring + level threshold. The process owns one
/// (see [`global`]); tests construct their own.
#[derive(Debug)]
pub struct EventLog {
    slots: Vec<Mutex<Option<LogRecord>>>,
    cursor: AtomicU64,
    level: AtomicUsize,
}

impl EventLog {
    pub fn new(capacity: usize, level: Level) -> Self {
        let capacity = capacity.max(1);
        EventLog {
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
            cursor: AtomicU64::new(0),
            level: AtomicUsize::new(level as usize),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever admitted (not the retained count; filtered
    /// events are never admitted).
    pub fn pushed(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Current threshold: events below it are dropped at emission.
    pub fn level(&self) -> Level {
        Level::from_usize(self.level.load(Ordering::Relaxed))
    }

    pub fn set_level(&self, level: Level) {
        self.level.store(level as usize, Ordering::Relaxed);
    }

    /// Emit one event (if it clears the threshold). Timestamping and
    /// sequencing happen here so call sites stay one-liners.
    pub fn emit(&self, level: Level, event: &'static str, trace_id: Option<u64>, message: String) {
        if (level as usize) < self.level.load(Ordering::Relaxed) {
            return;
        }
        let unix_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let seq = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = (seq % self.slots.len() as u64) as usize;
        *self.slots[slot].lock().unwrap() =
            Some(LogRecord { seq, unix_ms, level, event, trace_id, message });
    }

    /// Snapshot retained events, newest first. `min_level` drops events
    /// below the given severity; `limit` caps the result length after
    /// filtering.
    pub fn export(&self, limit: usize, min_level: Level) -> Vec<LogRecord> {
        let mut records: Vec<LogRecord> = self
            .slots
            .iter()
            .filter_map(|slot| slot.lock().unwrap().clone())
            .filter(|r| r.level >= min_level)
            .collect();
        records.sort_by(|a, b| b.seq.cmp(&a.seq));
        records.truncate(limit);
        records
    }

    /// Render an export as the `GET /v1/logs` response body.
    pub fn export_json(&self, limit: usize, min_level: Level) -> Value {
        let events: Vec<Value> =
            self.export(limit, min_level).iter().map(LogRecord::to_json).collect();
        Value::obj(vec![
            ("events", Value::Arr(events)),
            ("capacity", Value::Num(self.capacity() as f64)),
            ("pushed", Value::Num(self.pushed() as f64)),
            ("level", Value::str(self.level().as_str())),
        ])
    }
}

/// The process journal. Threshold seeds from `MITA_LOG` (default
/// `info`); `serve --log-level` overrides it via [`set_level`].
pub fn global() -> &'static EventLog {
    static EVENTS: OnceLock<EventLog> = OnceLock::new();
    EVENTS.get_or_init(|| {
        let level = std::env::var("MITA_LOG")
            .ok()
            .and_then(|s| Level::parse(&s))
            .unwrap_or(Level::Info);
        EventLog::new(DEFAULT_LOG_CAPACITY, level)
    })
}

/// Emit into the process journal.
pub fn emit(level: Level, event: &'static str, trace_id: Option<u64>, message: String) {
    global().emit(level, event, trace_id, message);
}

/// Whether `level` clears the process threshold — guard for call sites
/// whose message formatting is worth skipping.
pub fn enabled(level: Level) -> bool {
    level >= global().level()
}

/// Set the process threshold (the `--log-level` hook).
pub fn set_level(level: Level) {
    global().set_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log(capacity: usize, level: Level) -> EventLog {
        EventLog::new(capacity, level)
    }

    #[test]
    fn levels_parse_and_order() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Debug < Level::Info && Level::Warn < Level::Error);
        assert_eq!(Level::Error.as_str(), "error");
    }

    #[test]
    fn threshold_filters_at_emission() {
        let log = log(8, Level::Warn);
        log.emit(Level::Info, "quiet.event", None, "dropped".into());
        log.emit(Level::Warn, "loud.event", None, "kept".into());
        log.emit(Level::Error, "bad.event", Some(7), "kept too".into());
        assert_eq!(log.pushed(), 2, "filtered events are never admitted");
        let events = log.export(10, Level::Debug);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].event, "bad.event");
        assert_eq!(events[0].trace_id, Some(7));
        assert_eq!(events[1].event, "loud.event");

        log.set_level(Level::Debug);
        log.emit(Level::Debug, "chatty.event", None, "now kept".into());
        assert_eq!(log.pushed(), 3);
    }

    #[test]
    fn ring_evicts_oldest_and_exports_newest_first() {
        let log = log(3, Level::Debug);
        for i in 0..5u64 {
            log.emit(Level::Info, "tick", Some(i), format!("tick {i}"));
        }
        let ids: Vec<Option<u64>> = log.export(10, Level::Debug).iter().map(|r| r.trace_id).collect();
        assert_eq!(ids, vec![Some(4), Some(3), Some(2)]);
        // Export-side min_level filters retained records too.
        log.emit(Level::Error, "boom", None, "x".into());
        let errors = log.export(10, Level::Error);
        assert_eq!(errors.len(), 1);
        assert_eq!(errors[0].event, "boom");
        // limit caps after ordering.
        assert_eq!(log.export(1, Level::Debug)[0].event, "boom");
    }

    #[test]
    fn records_render_as_json_lines() {
        let log = log(4, Level::Debug);
        log.emit(Level::Warn, "admission.shed", Some(42), "inflight full".into());
        let rec = &log.export(1, Level::Debug)[0];
        let text = rec.to_json().render();
        assert!(text.contains("\"event\":\"admission.shed\""), "{text}");
        assert!(text.contains("\"level\":\"warn\""), "{text}");
        assert!(text.contains("\"trace_id\":42"), "{text}");
        assert!(text.contains("\"message\":\"inflight full\""), "{text}");
        assert!(text.contains("\"seq\":0"), "{text}");
        // Untraced events render an explicit null trace_id.
        log.emit(Level::Info, "server.bind", None, "0.0.0.0:0".into());
        let text = log.export(1, Level::Debug)[0].to_json().render();
        assert!(text.contains("\"trace_id\":null"), "{text}");
    }

    #[test]
    fn export_json_carries_journal_accounting() {
        let log = log(2, Level::Info);
        log.emit(Level::Info, "a", None, "1".into());
        log.emit(Level::Info, "b", None, "2".into());
        log.emit(Level::Info, "c", None, "3".into());
        let text = log.export_json(10, Level::Debug).render();
        assert!(text.contains("\"capacity\":2"), "{text}");
        assert!(text.contains("\"pushed\":3"), "{text}");
        assert!(text.contains("\"level\":\"info\""), "{text}");
        assert!(!text.contains("\"event\":\"a\""), "evicted event must not render: {text}");
    }
}
