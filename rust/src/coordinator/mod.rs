//! L3 coordinator: everything that runs on the request path.
//!
//! - [`engine`]: dedicated thread owning an execution backend — PJRT
//!   artifacts or the native CPU kernels — behind one frontend/engine
//!   split as in vLLM's router architecture.
//! - [`batcher`]: pure dynamic-batching policy (max-batch / max-wait).
//! - [`server`]: async serving loop + load generator + latency accounting,
//!   with a bundle-driven front ([`serve`]), an artifact-free native
//!   attention front ([`serve_native`]), and a whole-model front over the
//!   LRA tasks ([`serve_model`]).
//! - [`trainer`]: AOT train-step driver with loss-curve tracking.
//! - [`checkpoint`]: flat-parameter save/load.
//! - [`metrics`]: histograms, streaming stats, mIoU.

pub mod batcher;
pub mod checkpoint;
pub mod engine;
pub mod metrics;
pub mod server;
pub mod trainer;

pub use batcher::{BatchPolicy, Batcher, Flush};
pub use engine::{Engine, EngineHandle, EngineStats};
pub use server::{
    serve, serve_model, serve_native, ModelServeConfig, NativeServeConfig, ServeConfig,
    ServeReport,
};
pub use trainer::{eval_checkpoint, EvalResult, Trainer};
