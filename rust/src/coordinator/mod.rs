//! L3 coordinator: everything that runs on the request path.
//!
//! - [`engine`]: dedicated thread owning an execution backend — PJRT
//!   artifacts or the native CPU kernels — driven by typed
//!   [`ServiceRequest`](crate::service::ServiceRequest)s over
//!   submit/poll tickets (one frontend/engine split as in vLLM's router
//!   architecture, now pipelined).
//! - [`batcher`]: pure dynamic-batching policy (max-batch / max-wait).
//! - [`server`]: the serving loop + load generator + latency accounting;
//!   one [`Workload`]-parameterized front with convenience builders for
//!   PJRT bundles ([`serve`]), native attention ([`serve_native`]), and
//!   whole-model classification ([`serve_model`]).
//! - [`replica`]: the multi-replica serving layer — N engines from one
//!   spec behind least-outstanding routing, per-replica admission caps,
//!   and typed shedding with `retry_after_ms` hints.
//! - [`netserver`]: the network edge — a TCP HTTP/1.1 + JSON loop
//!   mapping wire requests onto the typed service API over a
//!   [`ReplicaPool`], plus the matching loopback [`NetClient`].
//! - [`trace`]: end-to-end request tracing — per-request stage spans
//!   (admission → route → queue → execute) plus per-block model
//!   profiles, retained in a fixed-capacity ring behind `GET /v1/trace`.
//! - [`log`]: the structured event journal — leveled JSON-lines records
//!   from the serving decision points (shed, bind, panic, health
//!   transitions, shutdown), retained in a ring behind `GET /v1/logs`.
//! - [`health`]: per-replica health state machine (healthy / degraded /
//!   unhealthy from rolling fault rates) + rolling-window SLO burn-rate
//!   accounting, surfaced via `GET /v1/readyz` and `/v1/metrics`.
//! - [`trainer`]: the **PJRT-artifact** train-step driver with
//!   loss-curve tracking (native training lives in [`crate::train`]).
//! - [`checkpoint`]: flat-parameter save/load.
//! - [`metrics`]: histograms, streaming stats, mIoU — and the serving
//!   telemetry registry behind `GET /v1/metrics`.

pub mod batcher;
pub mod checkpoint;
pub mod engine;
pub mod health;
pub mod log;
pub mod metrics;
pub mod netserver;
pub mod replica;
pub mod server;
pub mod trace;
pub mod trainer;

pub use batcher::{BatchPolicy, Batcher, Flush};
pub use engine::{Engine, EngineHandle, EngineStats, Ticket};
pub use health::{
    HealthState, ReplicaHealth, SloSnapshot, SloWindowSnapshot, SloWindows,
    DEFAULT_SLO_TARGET_MS,
};
pub use log::{EventLog, Level, LogRecord, DEFAULT_LOG_CAPACITY};
pub use metrics::{
    check_prometheus_text, render_prometheus, BlockSeries, HistogramSnapshot, MetricsSnapshot,
    ReplicaSnapshot, ServeMetrics, BUILD_GIT, BUILD_VERSION, METRIC_BLOCK_OVERFLOW,
    METRIC_EXPERT_QUERIES, METRIC_NAMES,
};
pub use netserver::{NetClient, NetServer, NetServerConfig};
pub use replica::{PoolTicket, ReplicaPool, ReplicaPoolConfig};
pub use server::{
    serve, serve_model, serve_native, serve_workload, ModelServeConfig, NativeServeConfig,
    ServeConfig, ServeReport, Workload, WorkloadSpec, DEFAULT_MAX_INFLIGHT,
};
pub use trace::{
    next_trace_id, TraceRecord, TraceRing, TraceSpans, TraceStart, DEFAULT_TRACE_CAPACITY,
};
pub use trainer::{eval_checkpoint, EvalResult, StepRecord, Trainer};
