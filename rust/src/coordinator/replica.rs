//! Multi-replica serving: N engine replicas behind least-outstanding
//! routing with per-replica admission caps and graceful backpressure.
//!
//! A [`ReplicaPool`] spawns N engines from **one** [`BackendSpec`] (each
//! replica owns its own `NativeBackend`, bound to the same checkpoint via
//! broadcast binds), then routes each compute request to the replica with
//! the fewest outstanding tickets. Ties rotate round-robin so a stream of
//! sequential callers still spreads across the fleet instead of camping
//! on replica 0. This mirrors MiTA's own compress-and-route strategy one
//! level up the stack: experts become replicas, capacity factors become
//! admission caps, and overflow becomes typed shedding.
//!
//! Backpressure contract: when every replica is at its admission cap the
//! pool **sheds** — [`ReplicaPool::submit`] returns a typed `overloaded`
//! error carrying a `retry_after_ms` hint (the observed mean latency,
//! floored by config) — it never queues unboundedly or stalls the caller.
//!
//! Observability: the pool owns the [`ServeMetrics`] registry and
//! assembles the [`MetricsSnapshot`] served by `GET /v1/metrics` —
//! pool-wide counters, the request-latency histogram, and per-replica
//! gauges including the MiTA routing stats (`overflow_fraction`,
//! `load_imbalance`) read from each replica's kernels.
//!
//! Health-aware routing: each replica carries a [`ReplicaHealth`]
//! machine fed by ticket settlement — replica-class faults (`internal`,
//! `unavailable`) count against it, client-class errors do not. The
//! routing scan skips `unhealthy` replicas while any non-unhealthy
//! candidate remains, and a failed engine submission records a fault
//! and moves on to the next candidate instead of failing the request,
//! so a dead engine drains instead of poisoning the stream.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::engine::{Engine, EngineHandle, ExecProfile, Ticket};
use crate::coordinator::health::{HealthState, ReplicaHealth};
use crate::coordinator::log::{self, Level};
use crate::coordinator::metrics::{
    BlockSeries, MetricsSnapshot, ReplicaSnapshot, ServeMetrics, BUILD_GIT, BUILD_VERSION,
};
use crate::coordinator::trace::{
    TraceRecord, TraceRing, TraceSpans, TraceStart, DEFAULT_TRACE_CAPACITY,
};
use crate::kernels::api::merge_block_profiles;
use crate::kernels::MitaStats;
use crate::runtime::BackendSpec;
use crate::service::{
    ServiceError, ServiceRequest, ServiceResponse, ServiceResult, ServiceStats, StepEvent,
};

/// Pool sizing and backpressure knobs.
#[derive(Debug, Clone)]
pub struct ReplicaPoolConfig {
    /// Number of engine replicas (≥ 1).
    pub replicas: usize,
    /// Per-replica admission cap: tickets outstanding on one replica
    /// before the router stops considering it. 0 sheds everything
    /// (useful for testing the backpressure path).
    pub max_inflight: usize,
    /// Floor for the `retry_after_ms` hint on shed requests; the pool
    /// raises it to the observed mean request latency once it has one.
    pub retry_after_ms: u64,
    /// Completed traces retained by the pool's [`TraceRing`] (the
    /// `serve --trace-ring` knob). Values below 16 are floored to 16 so
    /// a misconfigured ring still holds enough records to debug with.
    pub trace_capacity: usize,
}

/// Smallest trace ring the pool will build, whatever the config says.
pub const MIN_TRACE_CAPACITY: usize = 16;

impl Default for ReplicaPoolConfig {
    fn default() -> Self {
        ReplicaPoolConfig {
            replicas: 1,
            max_inflight: 64,
            retry_after_ms: 10,
            trace_capacity: DEFAULT_TRACE_CAPACITY,
        }
    }
}

struct Replica {
    engine: Engine,
    handle: EngineHandle,
    /// Tickets issued to this replica and not yet settled (the pool's
    /// own count — the engine has no notion of it).
    outstanding: Arc<AtomicUsize>,
    /// Compute requests ever routed to this replica.
    requests_total: AtomicU64,
    /// Rolling fault-rate health machine, fed by ticket settlement and
    /// consulted by the routing scan.
    health: Arc<ReplicaHealth>,
}

/// N engine replicas behind least-outstanding-tickets routing. Shared as
/// `Arc<ReplicaPool>` between the network front's connection handlers.
pub struct ReplicaPool {
    replicas: Vec<Replica>,
    /// Rotates the routing scan's starting replica so equal-depth ties
    /// round-robin instead of always resolving to the lowest index.
    rr: AtomicUsize,
    cfg: ReplicaPoolConfig,
    metrics: Arc<ServeMetrics>,
    /// Completed request traces, newest-overwrites-oldest; exported via
    /// `GET /v1/trace`.
    traces: TraceRing,
}

impl ReplicaPool {
    /// Spawn `cfg.replicas` engines from one spec. Each replica gets its
    /// own backend (and warmup); binds arriving through
    /// [`ReplicaPool::call`] broadcast to all of them, so every replica
    /// answers from the same parameters.
    pub fn spawn(spec: BackendSpec, warmup: Vec<String>, cfg: ReplicaPoolConfig) -> Result<Self> {
        if cfg.replicas == 0 {
            anyhow::bail!("replica pool wants at least 1 replica");
        }
        let replicas = (0..cfg.replicas)
            .map(|i| -> Result<Replica> {
                let engine = Engine::spawn_backend(spec.clone(), warmup.clone())?;
                let handle = engine.handle();
                log::emit(Level::Info, "replica.spawn", None, format!("replica {i} up"));
                Ok(Replica {
                    engine,
                    handle,
                    outstanding: Arc::new(AtomicUsize::new(0)),
                    requests_total: AtomicU64::new(0),
                    health: Arc::new(ReplicaHealth::new()),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let traces = TraceRing::new(cfg.trace_capacity.max(MIN_TRACE_CAPACITY));
        Ok(ReplicaPool {
            replicas,
            rr: AtomicUsize::new(0),
            cfg,
            metrics: Arc::new(ServeMetrics::new()),
            traces,
        })
    }

    /// Number of replicas in the pool.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Direct handle to one replica's engine (tests and binds that must
    /// target a specific replica; routed traffic goes through
    /// [`ReplicaPool::submit`]).
    pub fn handle(&self, replica: usize) -> EngineHandle {
        self.replicas[replica].handle.clone()
    }

    /// The `retry_after_ms` hint the pool attaches when shedding: the
    /// observed mean request latency, floored by the configured minimum.
    pub fn retry_hint_ms(&self) -> u64 {
        (self.metrics.mean_latency_ms().ceil() as u64).max(self.cfg.retry_after_ms).max(1)
    }

    /// Record a compute request shed *before* it reached the pool (the
    /// network front's transport in-flight cap), so `serve_shed_total`
    /// and the shed fraction cover both admission layers.
    pub fn record_transport_shed(&self) {
        self.metrics.record_request();
        self.metrics.record_shed();
    }

    /// Route one compute request: pick the admitting replica with the
    /// fewest outstanding tickets (ties rotate round-robin), reserve a
    /// slot, and submit. When every replica is at its cap, shed with a
    /// typed `overloaded` error carrying the retry hint — never block.
    pub fn submit(&self, req: ServiceRequest) -> ServiceResult<PoolTicket> {
        self.submit_inner(req, None)
    }

    fn submit_inner(
        &self,
        req: ServiceRequest,
        mut steps: Option<std::sync::mpsc::Sender<StepEvent>>,
    ) -> ServiceResult<PoolTicket> {
        self.metrics.record_request();
        let n = self.replicas.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        // Candidate order: rotated indices, stable-sorted by queue depth —
        // least-outstanding first, round-robin among equals.
        let mut order: Vec<usize> = (0..n).map(|i| (start + i) % n).collect();
        order.sort_by_key(|&i| self.replicas[i].outstanding.load(Ordering::Relaxed));
        // Unhealthy replicas are skipped while any non-unhealthy
        // candidate exists; a fully-unhealthy pool still routes, so
        // recovery samples keep flowing.
        let any_routable = order
            .iter()
            .any(|&i| self.replicas[i].health.state() != HealthState::Unhealthy);
        let mut req = Some(req);
        let mut last_err = None;
        for &i in &order {
            let r = &self.replicas[i];
            if any_routable && r.health.state() == HealthState::Unhealthy {
                continue;
            }
            // Reserve atomically against the cap (depths move under us).
            let depth = match r.outstanding.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |o| {
                (o < self.cfg.max_inflight).then_some(o + 1)
            }) {
                Ok(prev) => prev + 1,
                Err(_) => continue,
            };
            // The first replica whose engine accepts the submission
            // consumes the request (and the step channel, when
            // streaming); a failed submission hands both back so the
            // scan can retry the next candidate.
            let this_req = req.take().expect("request consumed only by a successful submit");
            let inner = match r.handle.submit_recoverable(this_req, steps.take()) {
                Ok(t) => t,
                Err((e, back_req, back_steps)) => {
                    // The engine thread is gone: release the slot, score
                    // the fault against this replica's health, and move
                    // on — the request only fails when every candidate
                    // does.
                    r.outstanding.fetch_sub(1, Ordering::SeqCst);
                    Self::record_health(&r.health, i, true);
                    log::emit(
                        Level::Error,
                        "replica.error",
                        None,
                        format!("replica {i} rejected submit: {e}"),
                    );
                    req = Some(back_req);
                    steps = back_steps;
                    last_err = Some(e);
                    continue;
                }
            };
            r.requests_total.fetch_add(1, Ordering::Relaxed);
            return Ok(PoolTicket {
                inner: Some(inner),
                replica: i,
                depth_at_route: depth,
                issued: Instant::now(),
                outstanding: Arc::clone(&r.outstanding),
                metrics: Arc::clone(&self.metrics),
                health: Arc::clone(&r.health),
                settled: false,
            });
        }
        if let Some(e) = last_err {
            self.metrics.record_error();
            return Err(e);
        }
        self.metrics.record_shed();
        log::emit(
            Level::Warn,
            "pool.shed",
            None,
            format!("all {n} replicas at cap {}", self.cfg.max_inflight),
        );
        Err(ServiceError::overloaded(format!(
            "all {n} replicas at their admission cap ({} tickets each)",
            self.cfg.max_inflight
        ))
        .with_retry_after(self.retry_hint_ms()))
    }

    /// Score one settled outcome against a replica's health machine and
    /// journal the state transition, if any.
    fn record_health(health: &ReplicaHealth, replica: usize, fault: bool) {
        if let Some((old, new)) = health.record(fault) {
            log::emit(
                Level::Warn,
                "replica.health",
                None,
                format!("replica {replica} {} -> {}", old.as_str(), new.as_str()),
            );
        }
    }

    /// Blocking request entry point — the pool-level twin of
    /// `EngineHandle::call`, with control-plane classes handled
    /// pool-wide:
    ///
    /// - `Metrics` answers from the pool's registry (no engine hop);
    /// - binds **broadcast** to every replica, so routed traffic always
    ///   sees the same parameters regardless of placement;
    /// - `Stats` aggregates across replicas (runtime counters summed,
    ///   MiTA routing stats merged);
    /// - compute classes route through [`ReplicaPool::submit`].
    pub fn call(&self, req: ServiceRequest) -> ServiceResult<ServiceResponse> {
        self.call_traced(req, None)
    }

    /// [`ReplicaPool::call`] with tracing: when `start` carries a
    /// [`TraceStart`] from the network edge, a compute request's stage
    /// spans (route / queue / execute, plus the admission span already
    /// measured by the caller) and per-block profile are recorded into
    /// the trace ring on settlement. Control-plane requests (binds,
    /// stats, metrics) are never traced; tracing is observation-only and
    /// does not alter routing, results, or metrics.
    pub fn call_traced(
        &self,
        req: ServiceRequest,
        start: Option<TraceStart>,
    ) -> ServiceResult<ServiceResponse> {
        match req {
            ServiceRequest::Metrics => Ok(ServiceResponse::Metrics(self.snapshot())),
            ServiceRequest::BindCheckpoint { .. } | ServiceRequest::BindInit { .. } => {
                log::emit(
                    Level::Info,
                    "bind.broadcast",
                    start.as_ref().map(|s| s.trace_id),
                    format!("bind to {} replicas", self.replicas.len()),
                );
                let mut last = None;
                for r in &self.replicas {
                    last = Some(r.handle.call(req.clone())?);
                }
                Ok(last.expect("pool has at least one replica"))
            }
            ServiceRequest::Stats { reset } => {
                let mut agg = ServiceStats::default();
                let mut mita: Option<MitaStats> = None;
                for r in &self.replicas {
                    let s = r.handle.call(ServiceRequest::Stats { reset })?.into_stats()?;
                    agg.runtime.compiles += s.runtime.compiles;
                    agg.runtime.compile_secs += s.runtime.compile_secs;
                    agg.runtime.executions += s.runtime.executions;
                    agg.runtime.execute_secs += s.runtime.execute_secs;
                    if let Some(m) = s.mita {
                        match &mut mita {
                            None => mita = Some(m),
                            Some(acc) => acc.merge(&m),
                        }
                    }
                    merge_block_profiles(&mut agg.blocks, &s.blocks);
                }
                agg.mita = mita;
                Ok(ServiceResponse::Stats(agg))
            }
            other => {
                let kind = other.kind();
                let route_t = Instant::now();
                let ticket = self.submit(other)?;
                let route_ns = route_t.elapsed().as_nanos() as u64;
                let (replica, depth) = (ticket.replica(), ticket.depth_at_route());
                let wait_t = Instant::now();
                let (result, prof) = ticket.wait_profiled();
                self.record_generate_outcome(&result);
                if let Some(s) = start {
                    // Queue time is what the engine-side wait cost beyond
                    // the execute itself (reply-channel hop included).
                    let wait_ns = wait_t.elapsed().as_nanos() as u64;
                    self.traces.push(TraceRecord {
                        trace_id: s.trace_id,
                        kind,
                        replica,
                        queue_depth: depth,
                        ok: result.is_ok(),
                        spans: Self::compute_spans(&s, route_ns, wait_ns, &prof),
                        blocks: prof.blocks,
                    });
                }
                result
            }
        }
    }

    /// Streaming variant of [`ReplicaPool::call_traced`] for generate
    /// requests: per-token [`StepEvent`]s are forwarded to `on_step` as
    /// the replica produces them, and each post-prefill step's latency
    /// feeds the `decode_step_latency_us` histogram. The engine closes
    /// the step channel before completing the ticket, so the drain loop
    /// always terminates ahead of settlement. Routing, shedding, and
    /// tracing behave exactly as in the non-streaming path.
    pub fn generate_streaming(
        &self,
        req: ServiceRequest,
        start: Option<TraceStart>,
        on_step: &mut dyn FnMut(StepEvent),
    ) -> ServiceResult<ServiceResponse> {
        let kind = req.kind();
        let route_t = Instant::now();
        let (step_tx, step_rx) = std::sync::mpsc::channel();
        let ticket = self.submit_inner(req, Some(step_tx))?;
        let route_ns = route_t.elapsed().as_nanos() as u64;
        let (replica, depth) = (ticket.replica(), ticket.depth_at_route());
        let wait_t = Instant::now();
        for ev in step_rx.iter() {
            if ev.index > 0 {
                // Step 0 is the prefill tail and carries latency 0 by
                // contract; only true decode steps enter the histogram.
                self.metrics
                    .record_decode_step(std::time::Duration::from_nanos(ev.latency_ns));
            }
            on_step(ev);
        }
        let (result, prof) = ticket.wait_profiled();
        self.record_generate_outcome(&result);
        if let Some(s) = start {
            let wait_ns = wait_t.elapsed().as_nanos() as u64;
            self.traces.push(TraceRecord {
                trace_id: s.trace_id,
                kind,
                replica,
                queue_depth: depth,
                ok: result.is_ok(),
                spans: Self::compute_spans(&s, route_ns, wait_ns, &prof),
                blocks: prof.blocks,
            });
        }
        result
    }

    /// Stage spans for a compute-path trace. Decode time is split out of
    /// the engine's execute span so the stages stay disjoint: for
    /// generate requests `execute_ns` is the prefill-plus-glue remainder
    /// and `decode_ns` the token loop; for everything else `decode_ns`
    /// is zero and `execute_ns` is unchanged.
    fn compute_spans(
        s: &TraceStart,
        route_ns: u64,
        wait_ns: u64,
        prof: &ExecProfile,
    ) -> TraceSpans {
        TraceSpans {
            admission_ns: s.admission_ns,
            route_ns,
            queue_ns: wait_ns.saturating_sub(prof.execute_ns),
            batch_ns: 0,
            execute_ns: prof.execute_ns.saturating_sub(prof.decode_ns),
            decode_ns: prof.decode_ns,
            total_ns: s.t0.elapsed().as_nanos() as u64,
        }
    }

    /// Bump the generation counters when a settled result is a
    /// successful generate response (streaming or not).
    fn record_generate_outcome(&self, result: &ServiceResult<ServiceResponse>) {
        if let Ok(ServiceResponse::Generate { tokens, prefill_tokens }) = result {
            let emitted = tokens.as_i32().map(|t| t.len()).unwrap_or(0) as u64;
            self.metrics.record_generate(emitted, *prefill_tokens as u64);
        }
    }

    /// The pool's trace ring (`GET /v1/trace` reads it through here).
    pub fn traces(&self) -> &TraceRing {
        &self.traces
    }

    /// Seconds since the pool's metrics registry was created (the
    /// `uptime_seconds` gauge, without assembling a full snapshot).
    pub fn uptime_seconds(&self) -> f64 {
        self.metrics.uptime_seconds()
    }

    /// One replica's current health state.
    pub fn replica_health(&self, replica: usize) -> HealthState {
        self.replicas[replica].health.state()
    }

    /// Readiness counts for `GET /v1/readyz`: replicas currently
    /// `(healthy, degraded, unhealthy)`. The pool is *ready* while any
    /// replica is non-unhealthy — degraded capacity still serves.
    pub fn readiness(&self) -> (usize, usize, usize) {
        let mut counts = (0, 0, 0);
        for r in &self.replicas {
            match r.health.state() {
                HealthState::Healthy => counts.0 += 1,
                HealthState::Degraded => counts.1 += 1,
                HealthState::Unhealthy => counts.2 += 1,
            }
        }
        counts
    }

    /// Terminate one replica's engine loop **without** removing the
    /// replica from the pool — the fault-injection hook behind the
    /// health-aware routing tests. Subsequent submissions to it fail
    /// with `unavailable`, which the health machine scores as faults
    /// until routing drains away from it.
    pub fn kill_replica(&self, replica: usize) {
        self.replicas[replica].handle.terminate();
    }

    /// Assemble the `/v1/metrics` payload: pool counters, the latency
    /// histogram, and per-replica gauges (queue depth sampled now, MiTA
    /// routing stats read from each replica's kernels).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let replicas = self
            .replicas
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let stats = r.handle.backend_stats().ok();
                let (overflow_fraction, load_imbalance) = stats
                    .as_ref()
                    .and_then(|s| s.mita.as_ref())
                    .map(|m| (m.overflow_fraction(), m.load_imbalance()))
                    .unwrap_or((0.0, 0.0));
                let blocks = stats
                    .map(|s| {
                        s.blocks
                            .iter()
                            .enumerate()
                            .map(|(bi, b)| BlockSeries {
                                block: bi as u64,
                                overflow_fraction: b.stats.overflow_fraction(),
                                queries: b.stats.queries as u64,
                                expert_queries: b
                                    .stats
                                    .expert_counts
                                    .iter()
                                    .map(|&c| c as u64)
                                    .collect(),
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                ReplicaSnapshot {
                    replica: i as u64,
                    replica_requests_total: r.requests_total.load(Ordering::Relaxed),
                    replica_queue_depth: r.outstanding.load(Ordering::Relaxed) as u64,
                    max_inflight: self.cfg.max_inflight as u64,
                    overflow_fraction,
                    load_imbalance,
                    health: r.health.state().as_str().to_string(),
                    health_faults: r.health.faults_total(),
                    health_results: r.health.results_total(),
                    blocks,
                }
            })
            .collect();
        MetricsSnapshot {
            serve_requests_total: self.metrics.requests_total(),
            serve_shed_total: self.metrics.shed_total(),
            serve_errors_total: self.metrics.errors_total(),
            request_latency_us: self.metrics.latency_snapshot(),
            tokens_generated_total: self.metrics.tokens_generated_total(),
            prefill_tokens_total: self.metrics.prefill_tokens_total(),
            decode_step_latency_us: self.metrics.decode_latency_snapshot(),
            replicas,
            ops: crate::kernels::profile::snapshot(),
            slo: self.metrics.slo_snapshot(),
            uptime_seconds: self.metrics.uptime_seconds(),
            build_version: BUILD_VERSION.to_string(),
            build_git: BUILD_GIT.to_string(),
            simd_lane: crate::kernels::simd::active_lane().to_string(),
        }
    }

    /// Shut every replica down and join its engine thread.
    pub fn shutdown(mut self) {
        for r in self.replicas.drain(..) {
            r.engine.shutdown();
        }
    }
}

/// An in-flight pool request: wraps the engine [`Ticket`] and, on
/// settlement (wait / try-wait / drop), releases the replica's admission
/// slot and records latency or error in the pool metrics.
pub struct PoolTicket {
    inner: Option<Ticket>,
    replica: usize,
    /// Replica queue depth right after this request reserved its slot
    /// (so ≥ 1; includes the request itself).
    depth_at_route: usize,
    issued: Instant,
    outstanding: Arc<AtomicUsize>,
    metrics: Arc<ServeMetrics>,
    health: Arc<ReplicaHealth>,
    settled: bool,
}

impl PoolTicket {
    /// Which replica this request was routed to.
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// The routed replica's outstanding depth at reservation time.
    pub fn depth_at_route(&self) -> usize {
        self.depth_at_route
    }

    /// Block until the request completes.
    pub fn wait(mut self) -> ServiceResult<ServiceResponse> {
        let ticket = self.inner.take().expect("pool ticket already redeemed");
        let result = ticket.wait();
        self.settle(&result);
        result
    }

    /// [`PoolTicket::wait`] plus the engine-side [`ExecProfile`]
    /// (execute wall time and, for model forwards, per-block timings).
    pub fn wait_profiled(mut self) -> (ServiceResult<ServiceResponse>, ExecProfile) {
        let ticket = self.inner.take().expect("pool ticket already redeemed");
        let (result, profile) = ticket.wait_profiled();
        self.settle(&result);
        (result, profile)
    }

    /// Non-blocking completion check; `None` while still executing. Once
    /// it returns `Some`, the ticket is settled.
    pub fn try_wait(&mut self) -> Option<ServiceResult<ServiceResponse>> {
        let result = self.inner.as_mut()?.try_wait()?;
        self.inner = None;
        self.settle(&result);
        Some(result)
    }

    /// [`PoolTicket::try_wait`] plus the engine-side [`ExecProfile`] —
    /// the polling-loop variant open-loop harnesses use to derive stage
    /// breakdowns without blocking the arrival schedule.
    pub fn try_wait_profiled(&mut self) -> Option<(ServiceResult<ServiceResponse>, ExecProfile)> {
        let (result, profile) = self.inner.as_mut()?.try_wait_profiled()?;
        self.inner = None;
        self.settle(&result);
        Some((result, profile))
    }

    fn settle(&mut self, result: &ServiceResult<ServiceResponse>) {
        if self.settled {
            return;
        }
        self.settled = true;
        self.outstanding.fetch_sub(1, Ordering::SeqCst);
        match result {
            Ok(_) => self.metrics.record_latency(self.issued.elapsed()),
            Err(_) => self.metrics.record_error(),
        }
        // Health: only replica-class faults count against the machine.
        // A client-class error (bad shape, unbound binding) is evidence
        // of a live replica answering, so it scores as ok.
        let fault = match result {
            Ok(_) => false,
            Err(e) => matches!(e.code(), "internal" | "unavailable"),
        };
        ReplicaPool::record_health(&self.health, self.replica, fault);
    }
}

impl Drop for PoolTicket {
    fn drop(&mut self) {
        // An abandoned ticket still releases its admission slot (no
        // latency sample — the request was never observed completing).
        if !self.settled {
            self.settled = true;
            self.outstanding.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::runtime::{NativeAttnConfig, Tensor};
    use crate::service::{KernelId, QkvBatch};

    fn attn_request(seed: u64) -> ServiceRequest {
        let (n, dim) = (16usize, 8usize);
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..3 * n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        ServiceRequest::Attention {
            op: KernelId::Mita,
            qkv: QkvBatch::fused(Tensor::f32(&[1, 3, n, dim], data).unwrap()).unwrap(),
            valid_rows: None,
        }
    }

    fn pool(replicas: usize, max_inflight: usize) -> ReplicaPool {
        let spec = BackendSpec::Native(NativeAttnConfig::for_shape(16, 8, 2));
        let cfg =
            ReplicaPoolConfig { replicas, max_inflight, retry_after_ms: 5, ..Default::default() };
        ReplicaPool::spawn(spec, vec![], cfg).unwrap()
    }

    #[test]
    fn sequential_calls_round_robin_across_replicas() {
        let p = pool(2, 8);
        for i in 0..6 {
            p.call(attn_request(i)).unwrap().into_tensor().unwrap();
        }
        let snap = p.snapshot();
        assert_eq!(snap.serve_requests_total, 6);
        assert_eq!(snap.serve_shed_total, 0);
        assert_eq!(snap.replicas.len(), 2);
        // Sequential callers leave every depth at 0, so the rotating
        // tie-break alternates replicas exactly.
        assert_eq!(snap.replicas[0].replica_requests_total, 3);
        assert_eq!(snap.replicas[1].replica_requests_total, 3);
        assert_eq!(snap.request_latency_us.count, 6);
        assert!(snap.request_latency_us.p50_us > 0.0);
        p.shutdown();
    }

    #[test]
    fn saturated_pool_sheds_with_retry_hint() {
        let p = pool(2, 0);
        let err = p.submit(attn_request(0)).map(|_| ()).unwrap_err();
        assert_eq!(err.code(), "overloaded");
        let hint = err.retry_after_ms().expect("shed carries a retry hint");
        assert!(hint >= 5, "hint {hint} respects the configured floor");
        let snap = p.snapshot();
        assert_eq!(snap.serve_requests_total, 1);
        assert_eq!(snap.serve_shed_total, 1);
        assert!((snap.shed_fraction() - 1.0).abs() < 1e-12);
        p.shutdown();
    }

    #[test]
    fn admission_slots_release_on_settle_and_drop() {
        let p = pool(1, 1);
        // One slot: hold it via an unredeemed ticket, watch the second
        // submit shed, then drop the ticket and watch the slot free up.
        let t = p.submit(attn_request(1)).unwrap();
        assert_eq!(p.submit(attn_request(2)).map(|_| ()).unwrap_err().code(), "overloaded");
        drop(t);
        let t = p.submit(attn_request(3)).unwrap();
        t.wait().unwrap();
        let snap = p.snapshot();
        assert_eq!(snap.replicas[0].replica_queue_depth, 0);
        assert_eq!(snap.serve_requests_total, 3);
        assert_eq!(snap.serve_shed_total, 1);
        p.shutdown();
    }

    #[test]
    fn traced_calls_record_spans_and_per_block_series() {
        use crate::kernels::OP_ATTN_MITA;
        use crate::model::{ModelConfig, OP_MODEL_INIT};
        use crate::service::BindingId;

        let mcfg = ModelConfig::new(7, 16, 8, 2, 2, 16, 3, OP_ATTN_MITA);
        let spec =
            BackendSpec::Native(NativeAttnConfig::for_shape(16, 8, 2).with_model(mcfg.clone()));
        let cfg = ReplicaPoolConfig {
            replicas: 1,
            max_inflight: 4,
            retry_after_ms: 5,
            ..Default::default()
        };
        let p = ReplicaPool::spawn(spec, vec![], cfg).unwrap();
        p.call(ServiceRequest::BindInit {
            binding: BindingId::from("m"),
            init_op: OP_MODEL_INIT.to_string(),
            seed: 1,
            param_count: 0,
        })
        .unwrap();

        let mut rng = Rng::new(3);
        let toks: Vec<i32> = (0..16).map(|_| rng.below(7) as i32).collect();
        let forward = ServiceRequest::ModelForward {
            binding: BindingId::from("m"),
            tokens: Tensor::i32(&[1, 16], toks).unwrap(),
            valid_rows: None,
        };
        let start = TraceStart::begin().admitted();
        let forward_id = start.trace_id;
        p.call_traced(forward, Some(start)).unwrap();
        let start = TraceStart::begin().admitted();
        let attn_id = start.trace_id;
        p.call_traced(attn_request(1), Some(start)).unwrap();

        let recs = p.traces().export(usize::MAX, 0);
        assert_eq!(recs.len(), 2, "both traced requests recorded");
        // Newest first: the attention request, with no block structure.
        assert_eq!(recs[0].trace_id, attn_id);
        assert_eq!(recs[0].kind, "attention");
        assert!(recs[0].blocks.is_empty());
        // The model forward carries spans + one profile per block.
        let mf = &recs[1];
        assert_eq!((mf.trace_id, mf.kind, mf.replica), (forward_id, "model_forward", 0));
        assert_eq!(mf.queue_depth, 1, "only request outstanding at reservation");
        assert!(mf.ok);
        assert!(mf.spans.execute_ns > 0);
        let staged = mf.spans.admission_ns
            + mf.spans.route_ns
            + mf.spans.queue_ns
            + mf.spans.batch_ns
            + mf.spans.execute_ns;
        assert!(staged <= mf.spans.total_ns, "stages {staged} ≤ wall {}", mf.spans.total_ns);
        assert_eq!(mf.blocks.len(), mcfg.depth);
        assert!(mf.blocks.iter().all(|b| b.attn_ns > 0 && b.stats.queries > 0));

        // The metrics snapshot now exposes per-block routing series, and
        // their query counts partition the replica's MiTA totals.
        let snap = p.snapshot();
        assert_eq!(snap.replicas[0].blocks.len(), mcfg.depth);
        let block_queries: u64 = snap.replicas[0].blocks.iter().map(|b| b.queries).sum();
        assert!(block_queries > 0);
        assert!(!snap.replicas[0].blocks[0].expert_queries.is_empty());

        // Untraced calls leave the ring untouched.
        p.call(attn_request(2)).unwrap();
        assert_eq!(p.traces().pushed(), 2);
        p.shutdown();
    }

    #[test]
    fn trace_ring_capacity_is_configurable_with_floor() {
        let spec = BackendSpec::Native(NativeAttnConfig::for_shape(16, 8, 2));
        let cfg = ReplicaPoolConfig { trace_capacity: 48, ..Default::default() };
        let p = ReplicaPool::spawn(spec.clone(), vec![], cfg).unwrap();
        assert_eq!(p.traces().capacity(), 48);
        p.shutdown();

        // Below the floor the ring still holds MIN_TRACE_CAPACITY records.
        let cfg = ReplicaPoolConfig { trace_capacity: 3, ..Default::default() };
        let p = ReplicaPool::spawn(spec, vec![], cfg).unwrap();
        assert_eq!(p.traces().capacity(), MIN_TRACE_CAPACITY);
        p.shutdown();
    }

    #[test]
    fn streaming_generate_records_steps_metrics_and_decode_span() {
        use crate::kernels::OP_ATTN_MITA;
        use crate::model::{ModelConfig, OP_MODEL_INIT};
        use crate::service::{BindingId, GenerateParams};

        let mcfg = ModelConfig::new(7, 16, 8, 2, 1, 16, 3, OP_ATTN_MITA);
        let spec =
            BackendSpec::Native(NativeAttnConfig::for_shape(16, 8, 2).with_model(mcfg.clone()));
        let p = ReplicaPool::spawn(spec, vec![], ReplicaPoolConfig::default()).unwrap();
        p.call(ServiceRequest::BindInit {
            binding: BindingId::from("m"),
            init_op: OP_MODEL_INIT.to_string(),
            seed: 7,
            param_count: 0,
        })
        .unwrap();

        let req = ServiceRequest::Generate {
            binding: BindingId::from("m"),
            prompt: Tensor::i32(&[3], vec![1, 2, 3]).unwrap(),
            max_tokens: 5,
            params: GenerateParams::default(),
        };
        let start = TraceStart::begin().admitted();
        let trace_id = start.trace_id;
        let mut streamed = Vec::new();
        let resp = p
            .generate_streaming(req, Some(start), &mut |ev| streamed.push(ev))
            .unwrap();
        let (tokens, prefill) = match resp {
            ServiceResponse::Generate { tokens, prefill_tokens } => (tokens, prefill_tokens),
            other => panic!("unexpected response {other:?}"),
        };
        assert_eq!(prefill, 3);
        assert_eq!(streamed.len(), 5);
        assert_eq!(
            streamed.iter().map(|e| e.token).collect::<Vec<_>>(),
            tokens.as_i32().unwrap().to_vec(),
            "streamed tokens match the terminal response"
        );

        // Counters: five emitted tokens, three prefill tokens, and four
        // decode-step samples (step 0 is the prefill tail, not sampled).
        let snap = p.snapshot();
        assert_eq!(snap.tokens_generated_total, 5);
        assert_eq!(snap.prefill_tokens_total, 3);
        assert_eq!(snap.decode_step_latency_us.count, 4);

        // The trace splits decode out of execute and stays disjoint.
        let recs = p.traces().export(usize::MAX, 0);
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert_eq!((r.trace_id, r.kind), (trace_id, "generate"));
        assert!(r.ok);
        assert!(r.spans.decode_ns > 0, "decode span recorded");
        let staged = r.spans.admission_ns
            + r.spans.route_ns
            + r.spans.queue_ns
            + r.spans.batch_ns
            + r.spans.execute_ns
            + r.spans.decode_ns;
        assert!(staged <= r.spans.total_ns, "stages {staged} ≤ wall {}", r.spans.total_ns);
        p.shutdown();
    }

    #[test]
    fn dead_replica_drains_and_routing_skips_it() {
        use crate::coordinator::health::HEALTH_MIN_SAMPLES;

        let p = pool(2, 8);
        p.kill_replica(0);
        // Every call still succeeds: a failed submission to the dead
        // engine records a fault and retries on the live replica.
        for i in 0..8 {
            p.call(attn_request(i)).unwrap().into_tensor().unwrap();
        }
        let snap = p.snapshot();
        assert_eq!(snap.serve_requests_total, 8);
        assert_eq!(snap.serve_errors_total, 0, "retries hide the dead engine from callers");
        assert_eq!(snap.serve_shed_total, 0);
        assert_eq!(snap.replicas[0].replica_requests_total, 0);
        assert_eq!(snap.replicas[1].replica_requests_total, 8, "all work landed on the live replica");
        assert_eq!(snap.replicas[0].health, "unhealthy");
        assert!(snap.replicas[0].health_faults >= HEALTH_MIN_SAMPLES as u64);
        assert_eq!(snap.replicas[1].health, "healthy");
        assert_eq!(p.replica_health(0), crate::coordinator::HealthState::Unhealthy);
        let (healthy, _degraded, unhealthy) = p.readiness();
        assert_eq!((healthy, unhealthy), (1, 1), "degraded-but-ready pool");
        p.shutdown();
    }

    #[test]
    fn stats_aggregate_across_replicas() {
        let p = pool(2, 4);
        for i in 0..4 {
            p.call(attn_request(i)).unwrap();
        }
        let stats = p.call(ServiceRequest::Stats { reset: false }).unwrap().into_stats().unwrap();
        // Two replicas served two executions each; the aggregate sees all
        // four and the merged MiTA stats cover every query.
        assert_eq!(stats.runtime.executions, 4);
        let mita = stats.mita.expect("native replicas report routing stats");
        assert!(mita.queries > 0);
        p.shutdown();
    }
}
