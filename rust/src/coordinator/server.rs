//! Serving loop: open-loop load generator → bounded admission queue →
//! dynamic batcher → engine thread → per-request latency accounting.
//!
//! This is the L3 system that measures the paper's Fig. 5 inference
//! throughput. The loop itself is backend-agnostic — it only sees an
//! engine op plus a pool of single-request tensors — and has two fronts:
//!
//! - [`serve`]: bundle-driven PJRT path. Requests are single examples; the
//!   compiled `predict` artifact has a fixed batch size B, so the batcher
//!   packs/pads to B.
//! - [`serve_native`]: artifact-free native path. Requests are fused
//!   `[1, 3, n, dim]` QKV bundles executed by the engine's
//!   [`NativeBackend`](crate::runtime::NativeBackend) (`attn.mita` /
//!   `attn.dense`), so the whole pipeline runs on a plain machine.
//! - [`serve_model`]: whole-model native path. Requests are `[1, n]` i32
//!   token sequences drawn from an LRA task and executed by the backend's
//!   `model.forward` op against a bound [`MitaModel`] — end-to-end
//!   classification serving with no artifacts.
//!
//! [`MitaModel`]: crate::model::MitaModel
//!
//! Std threads + channels (no async runtime in the vendored crate set);
//! the generator runs on its own thread, the batching loop on the caller's.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::batcher::{BatchPolicy, Batcher, Flush};
use crate::coordinator::engine::EngineHandle;
use crate::coordinator::metrics::LatencyHistogram;
use crate::data::rng::Rng;
use crate::data::{lra, BatchSource, Split};
use crate::kernels::MitaStats;
use crate::model::OP_MODEL_FORWARD;
use crate::runtime::{BundleSpec, Tensor};

/// Serving workload description (PJRT bundle path).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bundle whose `predict` artifact serves requests.
    pub bundle: String,
    /// Engine parameter-binding key holding the model weights (created via
    /// EngineHandle::bind_init / bind_tensors before serving).
    pub binding: String,
    /// Total requests to issue.
    pub requests: usize,
    /// Open-loop arrival rate (requests/second). 0 = closed loop (as fast
    /// as the pipeline drains).
    pub rate: f64,
    /// Admission queue capacity (backpressure bound; overflow = rejected).
    pub queue_cap: usize,
    pub policy: BatchPolicy,
}

/// Serving workload description (native attention path; no artifacts).
#[derive(Debug, Clone)]
pub struct NativeServeConfig {
    /// Sequence length of each request's QKV bundle.
    pub n: usize,
    /// Model dimension of each request (heads and kernel parameters live
    /// in the engine backend's `NativeAttnConfig`, the single source of
    /// truth for how the op executes).
    pub dim: usize,
    /// Native op to execute: `attn.mita` or `attn.dense`.
    pub op: String,
    pub requests: usize,
    pub rate: f64,
    pub queue_cap: usize,
    pub policy: BatchPolicy,
}

/// Serving workload description (whole-model native path; requests are
/// LRA task token sequences, the op is `model.forward`).
#[derive(Debug, Clone)]
pub struct ModelServeConfig {
    /// LRA task generating the request token sequences
    /// (one of [`lra::TASK_NAMES`]).
    pub task: String,
    /// Sequence length of each request (must match the bound model).
    pub seq_len: usize,
    /// Task vocabulary parameter (must match the bound model's vocab).
    pub vocab: usize,
    /// Engine parameter-binding key holding the model (created via
    /// `bind_tensors` with a checkpoint or `bind_init` with `model.init`).
    pub binding: String,
    pub requests: usize,
    pub rate: f64,
    pub queue_cap: usize,
    pub policy: BatchPolicy,
}

/// Aggregate serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub bundle: String,
    pub completed: usize,
    pub rejected: usize,
    pub elapsed_secs: f64,
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub batches: u64,
    pub pad_fraction: f64,
    /// MiTA routing statistics accumulated over this run (native backend
    /// only; `None` on artifact backends, `queries == 0` when the run
    /// executed no MiTA kernels).
    pub mita: Option<MitaStats>,
}

impl ServeReport {
    pub fn row(&self) -> String {
        let mut row = format!(
            "{:24} reqs={:5} rej={:4} thru={:8.1}/s mean={:7.2}ms p50={:7.2}ms p95={:7.2}ms p99={:7.2}ms batches={:5} pad={:4.1}%",
            self.bundle,
            self.completed,
            self.rejected,
            self.throughput_rps,
            self.mean_ms,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.batches,
            self.pad_fraction * 100.0
        );
        if let Some(m) = &self.mita {
            if m.queries > 0 {
                // ovf: fraction of queries served by the capacity-overflow
                // fallback; imb: peak expert load vs perfect balance.
                let _ = write!(
                    row,
                    " ovf={:4.1}% imb={:4.2}",
                    m.overflow_fraction() * 100.0,
                    m.load_imbalance()
                );
            }
        }
        row
    }
}

struct Request {
    /// Example index into the pre-generated input pool.
    example: u64,
    issued: Instant,
}

/// Extract example `j` of a batched tensor as a batch-1 tensor.
pub(crate) fn slice_example(x: &Tensor, j: usize) -> Result<Tensor> {
    let shape = x.shape();
    let per = shape[1..].iter().product::<usize>();
    let mut sub_shape = vec![1usize];
    sub_shape.extend_from_slice(&shape[1..]);
    match x {
        Tensor::F32 { data, .. } => Tensor::f32(&sub_shape, data[j * per..(j + 1) * per].to_vec()),
        Tensor::I32 { data, .. } => Tensor::i32(&sub_shape, data[j * per..(j + 1) * per].to_vec()),
    }
}

/// Concatenate batch-1 example tensors (+ self-padding) to batch size B.
pub(crate) fn pack_batch(examples: &[Tensor], b: usize) -> Result<Tensor> {
    anyhow::ensure!(!examples.is_empty() && examples.len() <= b);
    let first = &examples[0];
    let mut shape = first.shape().to_vec();
    shape[0] = b;
    match first {
        Tensor::F32 { data: d0, .. } => {
            let per = d0.len();
            let mut data = Vec::with_capacity(per * b);
            for e in examples {
                data.extend_from_slice(e.as_f32()?);
            }
            for _ in examples.len()..b {
                data.extend_from_slice(d0); // pad with a copy of example 0
            }
            Tensor::f32(&shape, data)
        }
        Tensor::I32 { data: d0, .. } => {
            let per = d0.len();
            let mut data = Vec::with_capacity(per * b);
            for e in examples {
                data.extend_from_slice(e.as_i32()?);
            }
            for _ in examples.len()..b {
                data.extend_from_slice(d0);
            }
            Tensor::i32(&shape, data)
        }
    }
}

/// Backend-agnostic parameters of one serving run.
struct LoopSpec<'a> {
    /// Report label.
    label: &'a str,
    /// Engine op (artifact name or native op).
    op: &'a str,
    /// Parameter-binding key, if the op needs bound weights.
    binding: Option<&'a str>,
    /// Append a valid-rows marker tensor to each batch so the backend
    /// short-circuits padding rows (native backend only; compiled PJRT
    /// artifacts take exactly one input and always compute the full
    /// padded batch).
    mark_valid: bool,
    requests: usize,
    rate: f64,
    queue_cap: usize,
    policy: BatchPolicy,
}

/// The serving pipeline shared by both fronts: generator thread → bounded
/// queue → batcher → engine → latency accounting.
fn serve_loop(engine: &EngineHandle, spec: &LoopSpec<'_>, pool: &[Tensor]) -> Result<ServeReport> {
    anyhow::ensure!(!pool.is_empty(), "request pool is empty");
    let b = spec.policy.max_batch;

    // Drain any routing stats a previous run left behind, so the closing
    // take below covers exactly this run (peaks such as the
    // load-imbalance maximum cannot be deltaed from cumulative counters).
    let _ = engine.take_backend_stats();

    // Bounded admission queue: a channel plus an explicit depth counter
    // (std channels have no try_send-with-capacity; the counter enforces
    // the backpressure bound).
    let (tx, rx) = mpsc::channel::<Request>();
    let depth = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));

    let gen_depth = depth.clone();
    let gen_rejected = rejected.clone();
    let gen_requests = spec.requests;
    let rate = spec.rate;
    let queue_cap = spec.queue_cap;
    let generator = std::thread::spawn(move || {
        let t0 = Instant::now();
        for i in 0..gen_requests {
            if rate > 0.0 {
                let due = t0 + Duration::from_secs_f64(i as f64 / rate);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
            }
            if gen_depth.load(Ordering::Acquire) >= queue_cap {
                gen_rejected.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            gen_depth.fetch_add(1, Ordering::AcqRel);
            if tx.send(Request { example: i as u64, issued: Instant::now() }).is_err() {
                break;
            }
        }
        // Dropping tx closes the queue.
    });

    // ---- batching + dispatch loop (caller thread) -------------------------
    let mut batcher: Batcher<Request> = Batcher::new(spec.policy);
    let mut hist = LatencyHistogram::new();
    let mut completed = 0usize;
    let t0 = Instant::now();
    let mut open = true;

    while open || !batcher.is_empty() {
        match batcher.poll(Instant::now()) {
            Flush::Take(n) => {
                let taken = batcher.take(n);
                depth.fetch_sub(taken.len(), Ordering::AcqRel);
                let examples: Vec<Tensor> = taken
                    .iter()
                    .map(|p| pool[p.payload.example as usize % pool.len()].clone())
                    .collect();
                let mut inputs = vec![pack_batch(&examples, b)?];
                if spec.mark_valid {
                    // Padding rows are marked so the backend never
                    // computes them (they also never reach a response:
                    // only `taken` requests are accounted below).
                    inputs.push(Tensor::i32(&[1], vec![examples.len() as i32])?);
                }
                let outs = match spec.binding {
                    Some(key) => engine.run_bound(spec.op, key, inputs)?,
                    None => engine.run(spec.op, inputs)?,
                };
                anyhow::ensure!(!outs.is_empty(), "op {} returned no outputs", spec.op);
                let finish = Instant::now();
                let _responses = outs[0].argmax_last()?; // per-request responses
                for p in taken {
                    hist.record(finish.duration_since(p.payload.issued));
                    completed += 1;
                }
            }
            Flush::Wait(hint) => {
                let timeout = hint.unwrap_or(Duration::from_millis(20));
                match rx.recv_timeout(timeout) {
                    Ok(req) => batcher.push(req, Instant::now()),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            }
        }
        // Opportunistically drain queued arrivals without blocking.
        while let Ok(req) = rx.try_recv() {
            batcher.push(req, Instant::now());
        }
    }

    generator.join().map_err(|_| anyhow::anyhow!("generator thread panicked"))?;
    let elapsed = t0.elapsed().as_secs_f64();
    let mita = engine.take_backend_stats().ok().and_then(|s| s.mita);
    Ok(ServeReport {
        bundle: spec.label.to_string(),
        completed,
        rejected: rejected.load(Ordering::Relaxed),
        elapsed_secs: elapsed,
        throughput_rps: completed as f64 / elapsed,
        mean_ms: hist.mean() * 1e3,
        p50_ms: hist.percentile(50.0) * 1e3,
        p95_ms: hist.percentile(95.0) * 1e3,
        p99_ms: hist.percentile(99.0) * 1e3,
        batches: batcher.batches_emitted,
        pad_fraction: batcher.pad_fraction(),
        mita,
    })
}

/// Run the serving benchmark against a bundle's `predict` artifact.
pub fn serve(
    engine: &EngineHandle,
    bundle: &BundleSpec,
    bundle_name: &str,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let predict = bundle
        .artifacts
        .get("predict")
        .with_context(|| format!("bundle {bundle_name} has no predict artifact"))?
        .clone();
    let source = BatchSource::for_bundle(bundle)?;
    let b = bundle.train.batch_size;
    anyhow::ensure!(
        cfg.policy.max_batch == b,
        "batch policy ({}) must match the compiled batch size ({b})",
        cfg.policy.max_batch
    );

    // Pre-generate the client input pool from the val split.
    let pool_batches = 4usize;
    let mut pool: Vec<Tensor> = Vec::with_capacity(pool_batches * b);
    for i in 0..pool_batches {
        let (x, _) = source.batch(Split::Val, i as u64)?;
        for j in 0..b {
            pool.push(slice_example(&x, j)?);
        }
    }

    let spec = LoopSpec {
        label: bundle_name,
        op: &predict,
        binding: Some(&cfg.binding),
        mark_valid: false, // compiled artifacts take exactly one input
        requests: cfg.requests,
        rate: cfg.rate,
        queue_cap: cfg.queue_cap,
        policy: cfg.policy,
    };
    serve_loop(engine, &spec, &pool)
}

/// Run the serving benchmark against the engine's native attention backend
/// (spawn the engine with [`BackendSpec::Native`]; no artifacts needed).
/// Every dispatched batch carries a valid-rows marker, so the padding the
/// batch policy accounts for (`pad=` in the report row) is never actually
/// computed by the backend, and the report's `mita` stats (`ovf=`/`imb=`
/// in the row) cover exactly this run's real requests.
///
/// [`BackendSpec::Native`]: crate::runtime::BackendSpec::Native
pub fn serve_native(engine: &EngineHandle, cfg: &NativeServeConfig) -> Result<ServeReport> {
    let (n, dim) = (cfg.n, cfg.dim);
    anyhow::ensure!(n > 0 && dim > 0, "native serving needs n > 0 and dim > 0");

    // Pre-generate a pool of fused QKV request bundles.
    let pool_size = 8usize;
    let mut pool: Vec<Tensor> = Vec::with_capacity(pool_size);
    for i in 0..pool_size {
        let mut rng = Rng::derive(0x5E27E, &[i as u64]);
        let data: Vec<f32> = (0..3 * n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        pool.push(Tensor::f32(&[1, 3, n, dim], data)?);
    }

    let label = format!("native/{} n={n}", cfg.op);
    let spec = LoopSpec {
        label: &label,
        op: &cfg.op,
        binding: None,
        mark_valid: true, // native backend skips padded batch rows
        requests: cfg.requests,
        rate: cfg.rate,
        queue_cap: cfg.queue_cap,
        policy: cfg.policy,
    };
    serve_loop(engine, &spec, &pool)
}

/// Run the serving benchmark against a whole model on the engine's native
/// backend: requests are single LRA-task token sequences, each dispatched
/// batch runs `model.forward` against the `cfg.binding` model with a
/// valid-rows marker (padding rows are never computed), and the report's
/// `mita` stats cover exactly this run's routed queries across every
/// MiTA block of the model.
pub fn serve_model(engine: &EngineHandle, cfg: &ModelServeConfig) -> Result<ServeReport> {
    let seed = crate::data::loader::DEFAULT_SEED;
    let task = lra::try_by_name(&cfg.task, cfg.seq_len, cfg.vocab, seed)?;
    let n = task.seq_len();

    // Pre-generate the client request pool from the val split.
    let pool_size = 16usize;
    let mut pool: Vec<Tensor> = Vec::with_capacity(pool_size);
    for i in 0..pool_size {
        let (tokens, _) = task.sample(Split::Val, i as u64);
        pool.push(Tensor::i32(&[1, n], tokens)?);
    }

    let label = format!("model/{} n={n}", cfg.task);
    let spec = LoopSpec {
        label: &label,
        op: OP_MODEL_FORWARD,
        binding: Some(&cfg.binding),
        mark_valid: true, // the model computes only real batch rows
        requests: cfg.requests,
        rate: cfg.rate,
        queue_cap: cfg.queue_cap,
        policy: cfg.policy,
    };
    serve_loop(engine, &spec, &pool)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_batch_pads_with_first_example() {
        let e1 = Tensor::f32(&[1, 2], vec![1.0, 2.0]).unwrap();
        let e2 = Tensor::f32(&[1, 2], vec![3.0, 4.0]).unwrap();
        let packed = pack_batch(&[e1, e2], 4).unwrap();
        assert_eq!(packed.shape(), &[4, 2]);
        assert_eq!(packed.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 1.0, 2.0]);
    }

    #[test]
    fn pack_batch_rejects_oversize() {
        let e = Tensor::f32(&[1, 1], vec![0.0]).unwrap();
        assert!(pack_batch(&[e.clone(), e.clone(), e], 2).is_err());
    }

    #[test]
    fn slice_example_roundtrip() {
        let x = Tensor::i32(&[2, 3], vec![1, 2, 3, 4, 5, 6]).unwrap();
        let s = slice_example(&x, 1).unwrap();
        assert_eq!(s.shape(), &[1, 3]);
        assert_eq!(s.as_i32().unwrap(), &[4, 5, 6]);
        let packed = pack_batch(&[s], 2).unwrap();
        assert_eq!(packed.as_i32().unwrap(), &[4, 5, 6, 4, 5, 6]);
    }
}
