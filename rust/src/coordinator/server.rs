//! Serving loop: open-loop load generator → bounded admission queue →
//! dynamic batcher → **pipelined** engine submission → per-request
//! latency accounting.
//!
//! This is the L3 system that measures the paper's Fig. 5 inference
//! throughput. Since the typed-service redesign there is **one** front:
//! every workload is a generator of per-request tensors plus a
//! [`Workload`] describing how a flushed batch becomes a
//! [`ServiceRequest`] — PJRT bundles ([`Workload::Artifact`]), native
//! attention ([`Workload::Attention`]), and whole-model classification
//! ([`Workload::Model`]) all ride the same loop. The convenience
//! builders [`serve`], [`serve_native`], and [`serve_model`] just
//! assemble the request pool + workload.
//!
//! Batches are dispatched through [`EngineHandle::submit`] tickets, so
//! up to `max_inflight` batches execute/queue engine-side while the
//! batcher keeps packing the next one — the loop never blocks a thread
//! per request, and padding is expressed as the typed `valid_rows` field
//! (never computed by the backend). Per-request latency is split into
//! two histograms: **queue wait** (issue → dispatch) and **execute**
//! (dispatch → completion, including engine-queue residency while
//! pipelined batches drain).
//!
//! Std threads + channels (no async runtime in the vendored crate set);
//! the generator runs on its own thread, the batching loop on the
//! caller's.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::batcher::{BatchPolicy, Batcher, Flush};
use crate::coordinator::engine::{EngineHandle, Ticket};
use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::trace::next_trace_id;
use crate::data::rng::Rng;
use crate::data::{lra, BatchSource, Split};
use crate::kernels::MitaStats;
use crate::runtime::{BundleSpec, Tensor};
use crate::service::{BindingId, KernelId, QkvBatch, ServiceRequest};

/// Default engine-submission pipeline depth of the serve configs.
pub const DEFAULT_MAX_INFLIGHT: usize = 3;

/// Serving workload description (PJRT bundle path).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bundle whose `predict` artifact serves requests.
    pub bundle: String,
    /// Engine parameter-binding key holding the model weights (created via
    /// EngineHandle::bind_init / bind_tensors before serving).
    pub binding: String,
    /// Total requests to issue.
    pub requests: usize,
    /// Open-loop arrival rate (requests/second). 0 = closed loop (as fast
    /// as the pipeline drains).
    pub rate: f64,
    /// Admission queue capacity (backpressure bound; overflow = rejected).
    pub queue_cap: usize,
    /// Batches allowed in flight engine-side before dispatch blocks.
    pub max_inflight: usize,
    pub policy: BatchPolicy,
}

/// Serving workload description (native attention path; no artifacts).
#[derive(Debug, Clone)]
pub struct NativeServeConfig {
    /// Sequence length of each request's QKV bundle.
    pub n: usize,
    /// Model dimension of each request (heads and kernel parameters live
    /// in the engine backend's `NativeAttnConfig`, the single source of
    /// truth for how the op executes).
    pub dim: usize,
    /// Native kernel to execute: `attn.mita` or `attn.dense`.
    pub op: String,
    pub requests: usize,
    pub rate: f64,
    pub queue_cap: usize,
    pub max_inflight: usize,
    pub policy: BatchPolicy,
}

/// Serving workload description (whole-model native path; requests are
/// LRA task token sequences served as typed model-forward requests).
#[derive(Debug, Clone)]
pub struct ModelServeConfig {
    /// LRA task generating the request token sequences
    /// (one of [`lra::TASK_NAMES`]).
    pub task: String,
    /// Sequence length of each request (must match the bound model).
    pub seq_len: usize,
    /// Task vocabulary parameter (must match the bound model's vocab).
    pub vocab: usize,
    /// Engine parameter-binding key holding the model (created via
    /// `bind_tensors` with a checkpoint or `bind_init` with `model.init`).
    pub binding: String,
    pub requests: usize,
    pub rate: f64,
    pub queue_cap: usize,
    pub max_inflight: usize,
    pub policy: BatchPolicy,
}

/// How a flushed batch of per-request tensors becomes one typed
/// [`ServiceRequest`]. This enum is the whole difference between the
/// serving fronts — everything else is the shared loop.
#[derive(Debug, Clone)]
pub enum Workload {
    /// Fused `[B, 3, n, dim]` batches through an attention kernel; short
    /// batches carry `valid_rows` so padding is never computed.
    Attention { op: KernelId },
    /// `[B, n]` token batches through a bound model; short batches carry
    /// `valid_rows`.
    Model { binding: BindingId },
    /// A compiled artifact on the packed batch (PJRT). Compiled bundles
    /// take exactly one input and always compute the full padded batch —
    /// there is no `valid_rows` on this path.
    Artifact { artifact: String, binding: BindingId },
}

impl Workload {
    /// Build the batch request: `examples` are batch-1 tensors from the
    /// request pool, padded up to `b` rows.
    fn build(&self, examples: &[Tensor], b: usize) -> Result<ServiceRequest> {
        let packed = pack_batch(examples, b)?;
        Ok(match self {
            Workload::Attention { op } => ServiceRequest::Attention {
                op: op.clone(),
                qkv: QkvBatch::fused(packed)?,
                valid_rows: Some(examples.len()),
            },
            Workload::Model { binding } => ServiceRequest::ModelForward {
                binding: binding.clone(),
                tokens: packed,
                valid_rows: Some(examples.len()),
            },
            Workload::Artifact { artifact, binding } => ServiceRequest::Artifact {
                artifact: artifact.clone(),
                binding: Some(binding.clone()),
                inputs: vec![packed],
            },
        })
    }
}

/// Aggregate serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub bundle: String,
    pub completed: usize,
    pub rejected: usize,
    pub elapsed_secs: f64,
    pub throughput_rps: f64,
    /// End-to-end latency (issue → completion).
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Queue-wait component (issue → batch dispatch): admission queue +
    /// batcher residency.
    pub queue_mean_ms: f64,
    pub queue_p50_ms: f64,
    pub queue_p95_ms: f64,
    pub queue_p99_ms: f64,
    /// Execute component (dispatch → completion): engine queue + backend
    /// execution of the request's batch.
    pub exec_mean_ms: f64,
    pub exec_p50_ms: f64,
    pub exec_p95_ms: f64,
    pub exec_p99_ms: f64,
    /// Trace id of the slowest completed request (end-to-end latency) in
    /// this run's window — the id to look up under `GET /v1/trace` when
    /// serving through the network edge, or to correlate with logs.
    /// `None` when no request completed.
    pub slowest_trace_id: Option<u64>,
    pub batches: u64,
    pub pad_fraction: f64,
    /// MiTA routing statistics accumulated over this run (native backend
    /// only; `None` on artifact backends, `queries == 0` when the run
    /// executed no MiTA kernels).
    pub mita: Option<MitaStats>,
}

impl ServeReport {
    pub fn row(&self) -> String {
        let mut row = format!(
            "{:24} reqs={:5} rej={:4} thru={:8.1}/s mean={:7.2}ms p50={:7.2}ms p95={:7.2}ms p99={:7.2}ms qwait={:6.2}/{:6.2}/{:6.2}ms exec={:6.2}/{:6.2}/{:6.2}ms batches={:5} pad={:4.1}%",
            self.bundle,
            self.completed,
            self.rejected,
            self.throughput_rps,
            self.mean_ms,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.queue_p50_ms,
            self.queue_p95_ms,
            self.queue_p99_ms,
            self.exec_p50_ms,
            self.exec_p95_ms,
            self.exec_p99_ms,
            self.batches,
            self.pad_fraction * 100.0
        );
        if let Some(id) = self.slowest_trace_id {
            // The slowest end-to-end request of the window, by trace id.
            let _ = write!(row, " slow=#{id}");
        }
        if let Some(m) = &self.mita {
            if m.queries > 0 {
                // ovf: fraction of queries served by the capacity-overflow
                // fallback; imb: peak expert load vs perfect balance.
                let _ = write!(
                    row,
                    " ovf={:4.1}% imb={:4.2}",
                    m.overflow_fraction() * 100.0,
                    m.load_imbalance()
                );
            }
        }
        row
    }
}

struct Request {
    /// Example index into the pre-generated input pool.
    example: u64,
    /// Trace id from the process-wide allocator — the same id space the
    /// network edge uses, so report rows correlate with `/v1/trace`.
    trace_id: u64,
    issued: Instant,
}

/// One dispatched batch awaiting engine completion.
struct InFlightBatch {
    ticket: Ticket,
    dispatched: Instant,
    members: Vec<Request>,
}

/// Extract example `j` of a batched tensor as a batch-1 tensor.
pub(crate) fn slice_example(x: &Tensor, j: usize) -> Result<Tensor> {
    let shape = x.shape();
    let per = shape[1..].iter().product::<usize>();
    let mut sub_shape = vec![1usize];
    sub_shape.extend_from_slice(&shape[1..]);
    match x {
        Tensor::F32 { data, .. } => Tensor::f32(&sub_shape, data[j * per..(j + 1) * per].to_vec()),
        Tensor::I32 { data, .. } => Tensor::i32(&sub_shape, data[j * per..(j + 1) * per].to_vec()),
    }
}

/// Concatenate batch-1 example tensors (+ self-padding) to batch size B.
pub(crate) fn pack_batch(examples: &[Tensor], b: usize) -> Result<Tensor> {
    anyhow::ensure!(!examples.is_empty() && examples.len() <= b);
    let first = &examples[0];
    let mut shape = first.shape().to_vec();
    shape[0] = b;
    match first {
        Tensor::F32 { data: d0, .. } => {
            let per = d0.len();
            let mut data = Vec::with_capacity(per * b);
            for e in examples {
                data.extend_from_slice(e.as_f32()?);
            }
            for _ in examples.len()..b {
                data.extend_from_slice(d0); // pad with a copy of example 0
            }
            Tensor::f32(&shape, data)
        }
        Tensor::I32 { data: d0, .. } => {
            let per = d0.len();
            let mut data = Vec::with_capacity(per * b);
            for e in examples {
                data.extend_from_slice(e.as_i32()?);
            }
            for _ in examples.len()..b {
                data.extend_from_slice(d0);
            }
            Tensor::i32(&shape, data)
        }
    }
}

/// Backend-agnostic parameters of one serving run.
pub struct WorkloadSpec<'a> {
    /// Report label.
    pub label: &'a str,
    /// How a flushed batch becomes a typed request.
    pub workload: Workload,
    pub requests: usize,
    pub rate: f64,
    pub queue_cap: usize,
    /// Batches allowed in flight engine-side (≥ 1) before dispatch blocks
    /// on the oldest one.
    pub max_inflight: usize,
    pub policy: BatchPolicy,
}

/// Latency accounting for one completed batch.
struct Hists {
    total: LatencyHistogram,
    queue: LatencyHistogram,
    exec: LatencyHistogram,
}

fn settle(
    dispatched: Instant,
    members: Vec<Request>,
    result: crate::service::ServiceResult<crate::service::ServiceResponse>,
    label: &str,
    hists: &mut Hists,
    completed: &mut usize,
    slowest: &mut Option<(Duration, u64)>,
) -> Result<()> {
    let resp = result.with_context(|| format!("serving {label}"))?;
    let outs = resp.into_tensors();
    anyhow::ensure!(!outs.is_empty(), "{label}: batch returned no outputs");
    // Producing per-request responses is part of the served work: extract
    // them before the completion timestamp (this also validates that the
    // batch output is a well-formed f32 tensor, and keeps latency numbers
    // comparable with the pre-pipelining serve loop, which did the same).
    let _responses = outs[0].argmax_last().with_context(|| format!("{label}: batch output"))?;
    let finish = Instant::now();
    let exec = finish.duration_since(dispatched);
    for r in &members {
        let total = finish.duration_since(r.issued);
        hists.queue.record(dispatched.duration_since(r.issued));
        hists.exec.record(exec);
        hists.total.record(total);
        if slowest.map_or(true, |(worst, _)| total > worst) {
            *slowest = Some((total, r.trace_id));
        }
    }
    *completed += members.len();
    Ok(())
}

/// The serving pipeline shared by every front: generator thread → bounded
/// queue → batcher → pipelined engine tickets → latency accounting.
pub fn serve_workload(
    engine: &EngineHandle,
    spec: &WorkloadSpec<'_>,
    pool: &[Tensor],
) -> Result<ServeReport> {
    anyhow::ensure!(!pool.is_empty(), "request pool is empty");
    anyhow::ensure!(spec.max_inflight >= 1, "max_inflight must be >= 1");
    let b = spec.policy.max_batch;

    // Drain any routing stats a previous run left behind, so the closing
    // take below covers exactly this run (peaks such as the
    // load-imbalance maximum cannot be deltaed from cumulative counters).
    let _ = engine.take_backend_stats();

    // Bounded admission queue: a channel plus an explicit depth counter
    // (std channels have no try_send-with-capacity; the counter enforces
    // the backpressure bound).
    let (tx, rx) = mpsc::channel::<Request>();
    let depth = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));

    let gen_depth = depth.clone();
    let gen_rejected = rejected.clone();
    let gen_requests = spec.requests;
    let rate = spec.rate;
    let queue_cap = spec.queue_cap;
    let generator = std::thread::spawn(move || {
        let t0 = Instant::now();
        for i in 0..gen_requests {
            if rate > 0.0 {
                let due = t0 + Duration::from_secs_f64(i as f64 / rate);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
            }
            if gen_depth.load(Ordering::Acquire) >= queue_cap {
                gen_rejected.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            gen_depth.fetch_add(1, Ordering::AcqRel);
            let req =
                Request { example: i as u64, trace_id: next_trace_id(), issued: Instant::now() };
            if tx.send(req).is_err() {
                break;
            }
        }
        // Dropping tx closes the queue.
    });

    // ---- batching + pipelined dispatch loop (caller thread) ---------------
    let mut batcher: Batcher<Request> = Batcher::new(spec.policy);
    let mut hists = Hists {
        total: LatencyHistogram::new(),
        queue: LatencyHistogram::new(),
        exec: LatencyHistogram::new(),
    };
    let mut inflight: VecDeque<InFlightBatch> = VecDeque::new();
    let mut completed = 0usize;
    let mut slowest: Option<(Duration, u64)> = None;
    let t0 = Instant::now();
    let mut open = true;

    while open || !batcher.is_empty() || !inflight.is_empty() {
        // Collect finished batches without blocking (the engine completes
        // them in submission order, but tickets make that an
        // implementation detail — each is redeemed independently).
        while let Some(front) = inflight.front_mut() {
            match front.ticket.try_wait() {
                Some(result) => {
                    let InFlightBatch { dispatched, members, .. } =
                        inflight.pop_front().expect("front exists");
                    settle(
                        dispatched,
                        members,
                        result,
                        spec.label,
                        &mut hists,
                        &mut completed,
                        &mut slowest,
                    )?;
                }
                None => break,
            }
        }
        // Pipeline full: block on the oldest batch before dispatching more.
        if inflight.len() >= spec.max_inflight {
            let InFlightBatch { ticket, dispatched, members } =
                inflight.pop_front().expect("non-empty");
            settle(
                dispatched,
                members,
                ticket.wait(),
                spec.label,
                &mut hists,
                &mut completed,
                &mut slowest,
            )?;
            continue;
        }
        match batcher.poll(Instant::now()) {
            Flush::Take(n) => {
                let taken = batcher.take(n);
                depth.fetch_sub(taken.len(), Ordering::AcqRel);
                let examples: Vec<Tensor> = taken
                    .iter()
                    .map(|p| pool[p.payload.example as usize % pool.len()].clone())
                    .collect();
                let req = spec.workload.build(&examples, b)?;
                let dispatched = Instant::now();
                let ticket = engine
                    .submit(req)
                    .with_context(|| format!("submitting {} batch", spec.label))?;
                inflight.push_back(InFlightBatch {
                    ticket,
                    dispatched,
                    members: taken.into_iter().map(|p| p.payload).collect(),
                });
            }
            Flush::Wait(hint) => {
                if !open && batcher.is_empty() {
                    // No more arrivals and nothing to batch: drain the
                    // pipeline.
                    if let Some(InFlightBatch { ticket, dispatched, members }) =
                        inflight.pop_front()
                    {
                        settle(
                            dispatched,
                            members,
                            ticket.wait(),
                            spec.label,
                            &mut hists,
                            &mut completed,
                            &mut slowest,
                        )?;
                    }
                    continue;
                }
                // With batches in flight, poll completions promptly even
                // if no new request arrives.
                let cap = if inflight.is_empty() {
                    Duration::from_millis(20)
                } else {
                    Duration::from_millis(2)
                };
                let timeout = hint.unwrap_or(cap).min(cap);
                match rx.recv_timeout(timeout) {
                    Ok(req) => batcher.push(req, Instant::now()),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            }
        }
        // Opportunistically drain queued arrivals without blocking.
        while let Ok(req) = rx.try_recv() {
            batcher.push(req, Instant::now());
        }
    }

    generator.join().map_err(|_| anyhow::anyhow!("generator thread panicked"))?;
    let elapsed = t0.elapsed().as_secs_f64();
    let mita = engine.take_backend_stats().ok().and_then(|s| s.mita);
    Ok(ServeReport {
        bundle: spec.label.to_string(),
        completed,
        rejected: rejected.load(Ordering::Relaxed),
        elapsed_secs: elapsed,
        throughput_rps: completed as f64 / elapsed,
        mean_ms: hists.total.mean() * 1e3,
        p50_ms: hists.total.percentile(50.0) * 1e3,
        p95_ms: hists.total.percentile(95.0) * 1e3,
        p99_ms: hists.total.percentile(99.0) * 1e3,
        queue_mean_ms: hists.queue.mean() * 1e3,
        queue_p50_ms: hists.queue.percentile(50.0) * 1e3,
        queue_p95_ms: hists.queue.percentile(95.0) * 1e3,
        queue_p99_ms: hists.queue.percentile(99.0) * 1e3,
        exec_mean_ms: hists.exec.mean() * 1e3,
        exec_p50_ms: hists.exec.percentile(50.0) * 1e3,
        exec_p95_ms: hists.exec.percentile(95.0) * 1e3,
        exec_p99_ms: hists.exec.percentile(99.0) * 1e3,
        slowest_trace_id: slowest.map(|(_, id)| id),
        batches: batcher.batches_emitted,
        pad_fraction: batcher.pad_fraction(),
        mita,
    })
}

/// Run the serving benchmark against a bundle's `predict` artifact.
pub fn serve(
    engine: &EngineHandle,
    bundle: &BundleSpec,
    bundle_name: &str,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let predict = bundle
        .artifacts
        .get("predict")
        .with_context(|| format!("bundle {bundle_name} has no predict artifact"))?
        .clone();
    let source = BatchSource::for_bundle(bundle)?;
    let b = bundle.train.batch_size;
    anyhow::ensure!(
        cfg.policy.max_batch == b,
        "batch policy ({}) must match the compiled batch size ({b})",
        cfg.policy.max_batch
    );

    // Pre-generate the client input pool from the val split.
    let pool_batches = 4usize;
    let mut pool: Vec<Tensor> = Vec::with_capacity(pool_batches * b);
    for i in 0..pool_batches {
        let (x, _) = source.batch(Split::Val, i as u64)?;
        for j in 0..b {
            pool.push(slice_example(&x, j)?);
        }
    }

    let spec = WorkloadSpec {
        label: bundle_name,
        workload: Workload::Artifact {
            artifact: predict,
            binding: BindingId::from(cfg.binding.as_str()),
        },
        requests: cfg.requests,
        rate: cfg.rate,
        queue_cap: cfg.queue_cap,
        max_inflight: cfg.max_inflight,
        policy: cfg.policy,
    };
    serve_workload(engine, &spec, &pool)
}

/// Run the serving benchmark against the engine's native attention backend
/// (spawn the engine with [`BackendSpec::Native`]; no artifacts needed).
/// Every dispatched batch carries a typed `valid_rows`, so the padding the
/// batch policy accounts for (`pad=` in the report row) is never actually
/// computed by the backend, and the report's `mita` stats (`ovf=`/`imb=`
/// in the row) cover exactly this run's real requests.
///
/// [`BackendSpec::Native`]: crate::runtime::BackendSpec::Native
pub fn serve_native(engine: &EngineHandle, cfg: &NativeServeConfig) -> Result<ServeReport> {
    let (n, dim) = (cfg.n, cfg.dim);
    anyhow::ensure!(n > 0 && dim > 0, "native serving needs n > 0 and dim > 0");
    let op = KernelId::parse(&cfg.op)?;

    // Pre-generate a pool of fused QKV request bundles.
    let pool_size = 8usize;
    let mut pool: Vec<Tensor> = Vec::with_capacity(pool_size);
    for i in 0..pool_size {
        let mut rng = Rng::derive(0x5E27E, &[i as u64]);
        let data: Vec<f32> = (0..3 * n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        pool.push(Tensor::f32(&[1, 3, n, dim], data)?);
    }

    let label = format!("native/{} n={n}", cfg.op);
    let spec = WorkloadSpec {
        label: &label,
        workload: Workload::Attention { op },
        requests: cfg.requests,
        rate: cfg.rate,
        queue_cap: cfg.queue_cap,
        max_inflight: cfg.max_inflight,
        policy: cfg.policy,
    };
    serve_workload(engine, &spec, &pool)
}

/// Run the serving benchmark against a whole model on the engine's native
/// backend: requests are single LRA-task token sequences, each dispatched
/// batch is a typed model-forward request against the `cfg.binding` model
/// with `valid_rows` (padding rows are never computed), and the report's
/// `mita` stats cover exactly this run's routed queries across every
/// MiTA block of the model.
pub fn serve_model(engine: &EngineHandle, cfg: &ModelServeConfig) -> Result<ServeReport> {
    let seed = crate::data::loader::DEFAULT_SEED;
    let task = lra::try_by_name(&cfg.task, cfg.seq_len, cfg.vocab, seed)?;
    let n = task.seq_len();

    // Pre-generate the client request pool from the val split.
    let pool_size = 16usize;
    let mut pool: Vec<Tensor> = Vec::with_capacity(pool_size);
    for i in 0..pool_size {
        let (tokens, _) = task.sample(Split::Val, i as u64);
        pool.push(Tensor::i32(&[1, n], tokens)?);
    }

    let label = format!("model/{} n={n}", cfg.task);
    let spec = WorkloadSpec {
        label: &label,
        workload: Workload::Model { binding: BindingId::from(cfg.binding.as_str()) },
        requests: cfg.requests,
        rate: cfg.rate,
        queue_cap: cfg.queue_cap,
        max_inflight: cfg.max_inflight,
        policy: cfg.policy,
    };
    serve_workload(engine, &spec, &pool)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_row_prints_p99_and_slowest_trace_id() {
        let report = ServeReport {
            bundle: "native/attn.mita n=64".into(),
            completed: 100,
            rejected: 2,
            elapsed_secs: 1.0,
            throughput_rps: 100.0,
            mean_ms: 4.0,
            p50_ms: 3.0,
            p95_ms: 8.0,
            p99_ms: 12.5,
            queue_mean_ms: 1.0,
            queue_p50_ms: 0.5,
            queue_p95_ms: 2.0,
            queue_p99_ms: 3.5,
            exec_mean_ms: 3.0,
            exec_p50_ms: 2.5,
            exec_p95_ms: 6.0,
            exec_p99_ms: 9.0,
            slowest_trace_id: Some(41),
            batches: 13,
            pad_fraction: 0.04,
            mita: None,
        };
        let row = report.row();
        assert!(row.contains("p99=  12.50ms"), "total p99 missing: {row}");
        assert!(row.contains("qwait=  0.50/  2.00/  3.50ms"), "queue p50/p95/p99 missing: {row}");
        assert!(row.contains("exec=  2.50/  6.00/  9.00ms"), "exec p50/p95/p99 missing: {row}");
        assert!(row.contains("slow=#41"), "slowest trace id missing: {row}");

        let anonymous = ServeReport { slowest_trace_id: None, ..report };
        assert!(!anonymous.row().contains("slow="), "no trace id when nothing completed");
    }

    #[test]
    fn pack_batch_pads_with_first_example() {
        let e1 = Tensor::f32(&[1, 2], vec![1.0, 2.0]).unwrap();
        let e2 = Tensor::f32(&[1, 2], vec![3.0, 4.0]).unwrap();
        let packed = pack_batch(&[e1, e2], 4).unwrap();
        assert_eq!(packed.shape(), &[4, 2]);
        assert_eq!(packed.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 1.0, 2.0]);
    }

    #[test]
    fn pack_batch_rejects_oversize() {
        let e = Tensor::f32(&[1, 1], vec![0.0]).unwrap();
        assert!(pack_batch(&[e.clone(), e.clone(), e], 2).is_err());
    }

    #[test]
    fn slice_example_roundtrip() {
        let x = Tensor::i32(&[2, 3], vec![1, 2, 3, 4, 5, 6]).unwrap();
        let s = slice_example(&x, 1).unwrap();
        assert_eq!(s.shape(), &[1, 3]);
        assert_eq!(s.as_i32().unwrap(), &[4, 5, 6]);
        let packed = pack_batch(&[s], 2).unwrap();
        assert_eq!(packed.as_i32().unwrap(), &[4, 5, 6, 4, 5, 6]);
    }

    #[test]
    fn workload_builds_typed_requests_with_valid_rows() {
        let e = Tensor::f32(&[1, 3, 4, 2], vec![0.5; 24]).unwrap();
        let w = Workload::Attention { op: KernelId::Mita };
        match w.build(&[e.clone(), e.clone()], 4).unwrap() {
            ServiceRequest::Attention { op, qkv, valid_rows } => {
                assert_eq!(op, KernelId::Mita);
                assert_eq!(qkv.batch(), 4);
                assert_eq!(valid_rows, Some(2), "short batches mark real rows");
            }
            other => panic!("wrong request class {:?}", other.kind()),
        }

        let t = Tensor::i32(&[1, 4], vec![1, 2, 3, 4]).unwrap();
        let w = Workload::Model { binding: BindingId::from("m") };
        match w.build(&[t], 3).unwrap() {
            ServiceRequest::ModelForward { binding, tokens, valid_rows } => {
                assert_eq!(binding.as_str(), "m");
                assert_eq!(tokens.shape(), &[3, 4]);
                assert_eq!(valid_rows, Some(1));
            }
            other => panic!("wrong request class {:?}", other.kind()),
        }

        let x = Tensor::f32(&[1, 2], vec![1.0, 2.0]).unwrap();
        let w = Workload::Artifact { artifact: "predict".into(), binding: BindingId::from("w") };
        match w.build(&[x], 2).unwrap() {
            ServiceRequest::Artifact { artifact, binding, inputs } => {
                assert_eq!(artifact, "predict");
                assert_eq!(binding.unwrap().as_str(), "w");
                assert_eq!(inputs[0].shape(), &[2, 2], "artifacts compute the padded batch");
            }
            other => panic!("wrong request class {:?}", other.kind()),
        }
    }
}
