//! Checkpointing: save/load flattened parameter lists.
//!
//! Pure binary format (no serde in the vendored crate set):
//!   magic "MITACKPT" | u32 version | u32 tensor count |
//!   per tensor: u8 dtype (0=f32, 1=i32) | u32 ndim | u64 dims... | raw LE data
//!
//! Used for Tab. 7 warm starts (pretrain standard → finetune MiTA) and for
//! the analysis figures that re-load trained models.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::Tensor;

const MAGIC: &[u8; 8] = b"MITACKPT";
const VERSION: u32 = 1;

/// Save tensors to `path` (atomic via rename).
pub fn save(path: &Path, tensors: &[Tensor]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(tensors.len() as u32).to_le_bytes())?;
        for t in tensors {
            let (tag, shape): (u8, &[usize]) = match t {
                Tensor::F32 { shape, .. } => (0, shape),
                Tensor::I32 { shape, .. } => (1, shape),
            };
            w.write_all(&[tag])?;
            w.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            match t {
                Tensor::F32 { data, .. } => {
                    for &x in data {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
                Tensor::I32 { data, .. } => {
                    for &x in data {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming to {}", path.display()))?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Load tensors from `path`.
pub fn load(path: &Path) -> Result<Vec<Tensor>> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("reading checkpoint magic")?;
    anyhow::ensure!(&magic == MAGIC, "bad checkpoint magic in {}", path.display());
    let version = read_u32(&mut r)?;
    anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
    let count = read_u32(&mut r)? as usize;
    anyhow::ensure!(count < 1_000_000, "implausible tensor count {count}");

    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag).with_context(|| format!("tensor {i} tag"))?;
        let ndim = read_u32(&mut r)? as usize;
        anyhow::ensure!(ndim <= 16, "implausible rank {ndim}");
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u64(&mut r)? as usize);
        }
        let n: usize = shape.iter().product();
        let mut raw = vec![0u8; n * 4];
        r.read_exact(&mut raw).with_context(|| format!("tensor {i} data"))?;
        let t = match tag[0] {
            0 => Tensor::f32(
                &shape,
                raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            )?,
            1 => Tensor::i32(
                &shape,
                raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            )?,
            other => bail!("unknown dtype tag {other}"),
        };
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("mita_ckpt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let tensors = vec![
            Tensor::f32(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-8, -7.25]).unwrap(),
            Tensor::i32(&[4], vec![1, -2, 3, 4]).unwrap(),
            Tensor::scalar_i32(99),
        ];
        save(&path, &tensors).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(tensors, loaded);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("mita_ckpt_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join(format!("mita_ckpt_tr_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        save(&path, &[Tensor::f32(&[8], vec![0.5; 8]).unwrap()]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
