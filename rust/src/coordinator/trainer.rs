//! **PJRT-artifact training driver**: runs a bundle's AOT `train_step`
//! artifact in a loop, feeding batches from the bundle's synthetic data
//! source, tracking the loss curve, and evaluating with the bundle's
//! `eval_step`. The gradients and the optimizer live *inside* the
//! compiled artifact; this driver only threads state between executions.
//!
//! This is **not** the native training path: for pure-Rust training with
//! hand-derived exact backward passes, AdamW, and LRA task loops — no
//! artifacts, no PJRT closure — see [`crate::train::NativeTrainer`].
//! The two share [`StepRecord`] / [`EvalResult`] so reporting code works
//! on either.
//!
//! The training state (params + AdamW moments + step counter) lives as a
//! `Vec<xla::Literal>` threaded between executions — no Python, no pytrees;
//! the manifest's `param_layout` defines the flat order.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::checkpoint;
use crate::coordinator::metrics::{miou_from_confusion, pixel_acc_from_confusion, Streaming};
use crate::data::{BatchSource, Split};
use crate::runtime::{Runtime, Tensor};

/// One recorded training step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    pub batch_acc: f64,
    pub secs: f64,
}

/// Aggregate evaluation result.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub loss: f64,
    /// Classification accuracy (cls/lra) or pixel accuracy (seg).
    pub accuracy: f64,
    /// mIoU for segmentation bundles, None otherwise.
    pub miou: Option<f64>,
    pub examples: usize,
}

/// Training/eval driver bound to one bundle.
pub struct Trainer<'rt> {
    runtime: &'rt Runtime,
    bundle_name: String,
    /// Flat state: P params, P mu, P nu, step (P = param_count).
    state: Vec<xla::Literal>,
    p_count: usize,
    batch_size: usize,
    is_seg: bool,
    num_classes: usize,
    pub history: Vec<StepRecord>,
}

impl<'rt> Trainer<'rt> {
    /// Initialize from the bundle's `init` artifact with the given seed.
    pub fn new(runtime: &'rt Runtime, bundle_name: &str, seed: i32) -> Result<Self> {
        let bundle = runtime.manifest().bundle(bundle_name)?.clone();
        let init_art = runtime.manifest().bundle_artifact(bundle_name, "init")?.to_string();
        let state = runtime
            .run_literals(&init_art, &[Tensor::scalar_i32(seed).to_literal()?])
            .with_context(|| format!("init {bundle_name}"))?;
        let p_count = bundle.param_count();
        anyhow::ensure!(
            state.len() == 3 * p_count + 1,
            "init returned {} literals, expected {}",
            state.len(),
            3 * p_count + 1
        );
        Ok(Trainer {
            runtime,
            bundle_name: bundle_name.to_string(),
            state,
            p_count,
            batch_size: bundle.train.batch_size,
            is_seg: bundle.model.task == "seg_image",
            num_classes: bundle.model.num_classes,
            history: Vec::new(),
        })
    }

    /// Initialize like [`Trainer::new`] but overwrite the parameters with a
    /// checkpoint (optimizer moments stay zero) — the Tab. 7 warm start.
    pub fn with_warm_start(
        runtime: &'rt Runtime,
        bundle_name: &str,
        seed: i32,
        params: &[Tensor],
    ) -> Result<Self> {
        let mut t = Self::new(runtime, bundle_name, seed)?;
        anyhow::ensure!(
            params.len() == t.p_count,
            "warm start has {} tensors, bundle wants {}",
            params.len(),
            t.p_count
        );
        for (i, p) in params.iter().enumerate() {
            t.state[i] = p.to_literal()?;
        }
        Ok(t)
    }

    pub fn bundle_name(&self) -> &str {
        &self.bundle_name
    }

    pub fn param_count(&self) -> usize {
        self.p_count
    }

    /// Current parameters as host tensors (for checkpointing / swaps).
    pub fn params(&self) -> Result<Vec<Tensor>> {
        self.state[..self.p_count].iter().map(Tensor::from_literal).collect()
    }

    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        checkpoint::save(path, &self.params()?)
    }

    /// Run one training step on batch (x, y); returns (loss, batch accuracy).
    pub fn step(&mut self, x: Tensor, y: Tensor) -> Result<(f64, f64)> {
        let art = self.runtime.manifest().bundle_artifact(&self.bundle_name, "train_step")?;
        let t0 = Instant::now();
        let denom = if self.is_seg {
            // per-token accuracy
            y.len() as f64
        } else {
            self.batch_size as f64
        };
        let out = self.runtime.run_hybrid(art, &self.state, &[x, y])?;
        anyhow::ensure!(
            out.len() == 3 * self.p_count + 3,
            "train_step returned {} outputs",
            out.len()
        );
        let mut out = out;
        let correct = Tensor::from_literal(&out.pop().unwrap())?.scalar()?;
        let loss = Tensor::from_literal(&out.pop().unwrap())?.scalar()?;
        self.state = out; // params' + mu' + nu' + step'
        let rec = StepRecord {
            step: self.history.len(),
            loss,
            batch_acc: correct / denom,
            secs: t0.elapsed().as_secs_f64(),
        };
        self.history.push(rec);
        Ok((loss, correct / denom))
    }

    /// Train for `steps` batches from the source's train split.
    pub fn train(&mut self, source: &BatchSource, steps: usize, log_every: usize) -> Result<()> {
        for i in 0..steps {
            let (x, y) = source.batch(Split::Train, i as u64)?;
            let (loss, acc) = self.step(x, y)?;
            if log_every > 0 && (i + 1) % log_every == 0 {
                eprintln!(
                    "[{}] step {:4}/{} loss={:.4} batch_acc={:.3}",
                    self.bundle_name,
                    i + 1,
                    steps,
                    loss,
                    acc
                );
            }
        }
        Ok(())
    }

    /// Evaluate on `batches` val batches using this bundle's eval artifact.
    pub fn eval(&self, source: &BatchSource, batches: usize) -> Result<EvalResult> {
        self.eval_with(source, batches, &self.bundle_name)
    }

    /// Evaluate the *current parameters* under a different bundle's
    /// eval_step (attention-swap experiments: Fig. 9 / Tab. 4 ▽ / Fig. 10).
    /// The other bundle must share this bundle's param layout.
    pub fn eval_with(
        &self,
        source: &BatchSource,
        batches: usize,
        eval_bundle: &str,
    ) -> Result<EvalResult> {
        let art = self.runtime.manifest().bundle_artifact(eval_bundle, "eval_step")?;
        eval_params(
            self.runtime,
            art,
            &self.state[..self.p_count],
            source,
            batches,
            self.is_seg,
            self.num_classes,
        )
    }

    /// Mean training-step wall time (excluding the first, which compiles).
    pub fn mean_step_secs(&self) -> f64 {
        let mut s = Streaming::default();
        for r in self.history.iter().skip(1) {
            s.push(r.secs);
        }
        s.mean()
    }

    /// Final-quarter mean loss (robust "converged loss" summary).
    pub fn tail_loss(&self) -> f64 {
        let n = self.history.len();
        if n == 0 {
            return f64::NAN;
        }
        let tail = &self.history[n - (n / 4).max(1)..];
        tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64
    }
}

/// Evaluate a parameter list under an eval artifact (shared by Trainer and
/// checkpoint-based flows).
#[allow(clippy::too_many_arguments)]
pub fn eval_params(
    runtime: &Runtime,
    eval_artifact: &str,
    params: &[xla::Literal],
    source: &BatchSource,
    batches: usize,
    is_seg: bool,
    num_classes: usize,
) -> Result<EvalResult> {
    let mut total_loss = 0.0;
    let mut total_correct = 0.0;
    let mut examples = 0usize;
    let mut confusion = vec![0f32; num_classes * num_classes];

    for i in 0..batches {
        let (x, y) = source.batch(Split::Val, i as u64)?;
        let bsz = x.shape()[0];
        let tokens = if is_seg { y.len() } else { bsz };
        let out = runtime.run_hybrid(eval_artifact, params, &[x, y])?;
        anyhow::ensure!(out.len() == 2, "eval_step returned {} outputs", out.len());
        let loss = Tensor::from_literal(&out[0])?.scalar()?;
        if is_seg {
            let conf = Tensor::from_literal(&out[1])?;
            let cd = conf.as_f32()?;
            for (a, &b) in confusion.iter_mut().zip(cd) {
                *a += b;
            }
            total_loss += loss * tokens as f64; // seg eval loss is a mean
        } else {
            let correct = Tensor::from_literal(&out[1])?.scalar()?;
            total_correct += correct;
            total_loss += loss; // cls eval loss is a sum
        }
        examples += tokens;
    }

    if is_seg {
        Ok(EvalResult {
            loss: total_loss / examples.max(1) as f64,
            accuracy: pixel_acc_from_confusion(&confusion, num_classes),
            miou: Some(miou_from_confusion(&confusion, num_classes)),
            examples,
        })
    } else {
        Ok(EvalResult {
            loss: total_loss / examples.max(1) as f64,
            accuracy: total_correct / examples.max(1) as f64,
            miou: None,
            examples,
        })
    }
}

/// Evaluate a checkpoint's params under any bundle's eval artifact.
pub fn eval_checkpoint(
    runtime: &Runtime,
    ckpt_path: &std::path::Path,
    eval_bundle: &str,
    batches: usize,
) -> Result<EvalResult> {
    let bundle = runtime.manifest().bundle(eval_bundle)?.clone();
    let params = checkpoint::load(ckpt_path)?;
    anyhow::ensure!(
        params.len() == bundle.param_count(),
        "checkpoint has {} tensors, bundle wants {}",
        params.len(),
        bundle.param_count()
    );
    let lits: Vec<xla::Literal> =
        params.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
    let art = runtime.manifest().bundle_artifact(eval_bundle, "eval_step")?;
    let source = BatchSource::for_bundle(&bundle)?;
    eval_params(
        runtime,
        art,
        &lits,
        &source,
        batches,
        bundle.model.task == "seg_image",
        bundle.model.num_classes,
    )
}
