//! End-to-end request tracing: stage spans + per-block model profiles.
//!
//! Every service request admitted over the network edge gets a unique
//! `trace_id` (client-supplied or allocated at parse time). As the
//! request moves through the fixed stages of the serving path —
//! netserver admission, replica routing, engine queue wait, backend
//! execute — each stage's wall time is recorded into a [`TraceSpans`].
//! Model-forward requests additionally carry one
//! [`BlockProfile`](crate::kernels::api::BlockProfile) per transformer
//! block (attention vs MLP time, per-block MiTA routing stats).
//!
//! Completed traces land in a [`TraceRing`]: a fixed-capacity,
//! oldest-first-evicting buffer owned by the replica pool and exported
//! via `GET /v1/trace?limit=N&min_us=T`. Tracing is observation-only:
//! it never changes routing, batching, or response payloads beyond the
//! echoed `trace_id`.
//!
//! Design notes:
//! - Slot allocation is lock-free (`fetch_add` on a cursor; slot =
//!   seq % capacity), so concurrent completions never contend on a
//!   global lock — only on the (distinct) slot they were assigned.
//! - Spans are stored in nanoseconds and exported as microsecond
//!   floats, matching the `*_us` convention of `/v1/metrics`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::kernels::api::BlockProfile;
use crate::util::json::Value;

/// Default number of completed traces retained by a [`TraceRing`].
pub const DEFAULT_TRACE_CAPACITY: usize = 256;

/// Process-wide trace-id allocator. Starts at 1 so 0 can mean "no
/// trace" in contexts that need a sentinel.
static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);

/// Allocate the next unique trace id (process-wide, monotone).
pub fn next_trace_id() -> u64 {
    NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed)
}

/// The identity and admission timing the network edge captures before
/// handing a request to the replica pool
/// ([`ReplicaPool::call_traced`](crate::coordinator::replica::ReplicaPool::call_traced)).
#[derive(Debug, Clone, Copy)]
pub struct TraceStart {
    /// Client-supplied or freshly allocated id, echoed in the response.
    pub trace_id: u64,
    /// When the HTTP head finished parsing — the origin of `total_ns`.
    pub t0: Instant,
    /// Body read + JSON decode time up to the pool hand-off.
    pub admission_ns: u64,
}

impl TraceStart {
    /// Begin a trace window now with a fresh id; the admission span is
    /// filled in by [`TraceStart::admitted`] once decode finishes.
    pub fn begin() -> Self {
        TraceStart { trace_id: next_trace_id(), t0: Instant::now(), admission_ns: 0 }
    }

    /// Close the admission span (head parse → typed request in hand).
    pub fn admitted(mut self) -> Self {
        self.admission_ns = self.t0.elapsed().as_nanos() as u64;
        self
    }

    /// Adopt a client-supplied trace id (it still must be echoed).
    pub fn with_id(mut self, trace_id: u64) -> Self {
        self.trace_id = trace_id;
        self
    }
}

/// Wall time spent in each fixed stage of the serving path, in
/// nanoseconds. Stages are disjoint, so their sum is ≤ `total_ns`
/// (the remainder is unattributed glue: reply-channel hops, JSON
/// encoding started after the span window closed, etc.).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceSpans {
    /// Netserver: HTTP head+body read and JSON decode, up to the
    /// moment the request is handed to the replica pool.
    pub admission_ns: u64,
    /// Replica pool: replica selection + admission-slot reservation.
    pub route_ns: u64,
    /// Engine: time the job sat in the engine's queue before the
    /// backend picked it up (wait wall time minus execute time).
    pub queue_ns: u64,
    /// Batcher: time spent waiting for a batch to fill. Zero on the
    /// TCP path, where requests are submitted individually.
    pub batch_ns: u64,
    /// Backend: the execute call itself, bracketed on the engine
    /// thread. For generate requests this is the *prefill + glue*
    /// remainder — the decode loop is split out into `decode_ns` so the
    /// stages stay disjoint.
    pub execute_ns: u64,
    /// Backend: wall time of the token-by-token decode loop of a
    /// generate request (0 for every other request kind).
    pub decode_ns: u64,
    /// End-to-end wall time over the span window (head parsed →
    /// response settled).
    pub total_ns: u64,
}

/// One completed, traced request.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TraceRecord {
    /// Unique id, echoed to the client in the response body.
    pub trace_id: u64,
    /// Request kind (`ServiceRequest::kind()`: "attention",
    /// "model_forward", ...).
    pub kind: &'static str,
    /// Replica index the request was routed to.
    pub replica: usize,
    /// That replica's outstanding-request depth at reservation time
    /// (includes this request).
    pub queue_depth: usize,
    /// Whether the backend returned a success response.
    pub ok: bool,
    /// Per-stage wall times.
    pub spans: TraceSpans,
    /// Per-block attention/MLP timings + MiTA routing stats; empty
    /// for non-model requests.
    pub blocks: Vec<BlockProfile>,
}

impl TraceRecord {
    /// Render as a JSON object with deterministic key order (the
    /// renderer sorts keys). Spans come out as `*_us` floats.
    pub fn to_json(&self) -> Value {
        let us = |ns: u64| Value::Num(ns as f64 / 1000.0);
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for (bi, b) in self.blocks.iter().enumerate() {
            let experts: Vec<Value> =
                b.stats.expert_counts.iter().map(|&c| Value::Num(c as f64)).collect();
            blocks.push(Value::obj(vec![
                ("block", Value::Num(bi as f64)),
                ("attn_us", us(b.attn_ns)),
                ("mlp_us", us(b.mlp_ns)),
                ("queries", Value::Num(b.stats.queries as f64)),
                ("overflow_fraction", Value::Num(b.stats.overflow_fraction())),
                ("expert_queries", Value::Arr(experts)),
            ]));
        }
        Value::obj(vec![
            ("trace_id", Value::Num(self.trace_id as f64)),
            ("kind", Value::str(self.kind)),
            ("replica", Value::Num(self.replica as f64)),
            ("queue_depth", Value::Num(self.queue_depth as f64)),
            ("ok", Value::Bool(self.ok)),
            (
                "spans",
                Value::obj(vec![
                    ("admission_us", us(self.spans.admission_ns)),
                    ("route_us", us(self.spans.route_ns)),
                    ("queue_us", us(self.spans.queue_ns)),
                    ("batch_us", us(self.spans.batch_ns)),
                    ("execute_us", us(self.spans.execute_ns)),
                    ("decode_us", us(self.spans.decode_ns)),
                    ("total_us", us(self.spans.total_ns)),
                ]),
            ),
            ("blocks", Value::Arr(blocks)),
        ])
    }
}

/// Fixed-capacity ring of completed traces. Pushes allocate a slot
/// with a single atomic `fetch_add`; once the cursor wraps, new
/// records overwrite the oldest (eviction is oldest-first by
/// construction). Export walks the slots, sorts by sequence number
/// descending (newest first), and applies `min_us` / `limit` filters.
#[derive(Debug)]
pub struct TraceRing {
    /// `(seq, record)` per slot; `seq` disambiguates wrap-around so
    /// export can order records globally.
    slots: Vec<Mutex<Option<(u64, TraceRecord)>>>,
    cursor: AtomicU64,
}

impl TraceRing {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
            cursor: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total records ever pushed (not the retained count).
    pub fn pushed(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Record a completed trace, evicting the oldest record once the
    /// ring is full.
    pub fn push(&self, record: TraceRecord) {
        let seq = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = (seq % self.slots.len() as u64) as usize;
        *self.slots[slot].lock().unwrap() = Some((seq, record));
    }

    /// Snapshot retained traces, newest first. `min_us` drops records
    /// whose total wall time is below the threshold; `limit` caps the
    /// result length after filtering.
    pub fn export(&self, limit: usize, min_us: u64) -> Vec<TraceRecord> {
        let mut records: Vec<(u64, TraceRecord)> = self
            .slots
            .iter()
            .filter_map(|slot| slot.lock().unwrap().clone())
            .filter(|(_, r)| r.spans.total_ns / 1000 >= min_us)
            .collect();
        records.sort_by(|a, b| b.0.cmp(&a.0));
        records.truncate(limit);
        records.into_iter().map(|(_, r)| r).collect()
    }

    /// Render an export as the `GET /v1/trace` response body.
    pub fn export_json(&self, limit: usize, min_us: u64) -> Value {
        let traces: Vec<Value> = self.export(limit, min_us).iter().map(TraceRecord::to_json).collect();
        Value::obj(vec![
            ("traces", Value::Arr(traces)),
            ("capacity", Value::Num(self.capacity() as f64)),
            ("pushed", Value::Num(self.pushed() as f64)),
        ])
    }
}

impl Default for TraceRing {
    fn default() -> Self {
        Self::new(DEFAULT_TRACE_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(trace_id: u64, total_us: u64) -> TraceRecord {
        TraceRecord {
            trace_id,
            kind: "attention",
            replica: 0,
            queue_depth: 1,
            ok: true,
            spans: TraceSpans { total_ns: total_us * 1000, ..TraceSpans::default() },
            blocks: Vec::new(),
        }
    }

    #[test]
    fn trace_ids_are_unique_and_monotone() {
        let a = next_trace_id();
        let b = next_trace_id();
        let c = next_trace_id();
        assert!(a < b && b < c);
    }

    #[test]
    fn ring_exports_newest_first_and_evicts_oldest() {
        let ring = TraceRing::new(3);
        for id in 1..=5 {
            ring.push(record(id, 10));
        }
        // Capacity 3, pushed 5 → ids 1 and 2 were evicted (oldest
        // first); export is newest-first.
        let ids: Vec<u64> = ring.export(usize::MAX, 0).iter().map(|r| r.trace_id).collect();
        assert_eq!(ids, vec![5, 4, 3]);
        assert_eq!(ring.pushed(), 5);

        // `limit` caps after ordering: the newest records win.
        let ids: Vec<u64> = ring.export(2, 0).iter().map(|r| r.trace_id).collect();
        assert_eq!(ids, vec![5, 4]);
    }

    #[test]
    fn min_us_filters_on_total_wall_time() {
        let ring = TraceRing::new(8);
        ring.push(record(1, 5));
        ring.push(record(2, 50));
        ring.push(record(3, 500));
        let ids: Vec<u64> = ring.export(usize::MAX, 50).iter().map(|r| r.trace_id).collect();
        assert_eq!(ids, vec![3, 2]);
        assert!(ring.export(usize::MAX, 1_000_000).is_empty());
    }

    #[test]
    fn record_renders_spans_as_microseconds() {
        let mut rec = record(7, 0);
        rec.spans = TraceSpans {
            admission_ns: 1_500,
            route_ns: 250,
            queue_ns: 3_000,
            batch_ns: 0,
            execute_ns: 40_000,
            decode_ns: 32_000,
            total_ns: 50_000,
        };
        let text = rec.to_json().render();
        assert!(text.contains("\"trace_id\":7"), "{text}");
        assert!(text.contains("\"admission_us\":1.5"), "{text}");
        assert!(text.contains("\"execute_us\":40"), "{text}");
        assert!(text.contains("\"decode_us\":32"), "{text}");
        assert!(text.contains("\"kind\":\"attention\""), "{text}");
    }

    #[test]
    fn export_json_carries_ring_accounting() {
        let ring = TraceRing::new(2);
        ring.push(record(1, 10));
        ring.push(record(2, 10));
        ring.push(record(3, 10));
        let text = ring.export_json(10, 0).render();
        assert!(text.contains("\"capacity\":2"), "{text}");
        assert!(text.contains("\"pushed\":3"), "{text}");
        assert!(!text.contains("\"trace_id\":1"), "evicted record must not render: {text}");
    }
}
