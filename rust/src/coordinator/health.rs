//! Replica health state machine + rolling SLO windows.
//!
//! **Health.** Each replica owns a [`ReplicaHealth`]: a sliding window
//! of the last [`HEALTH_WINDOW`] settled outcomes, where an outcome is
//! a *fault* when the replica itself failed (engine thread dead,
//! backend panic → `internal` / `unavailable` errors) and *ok* when it
//! produced any response at all — client-class errors (bad shapes,
//! unbound bindings) are evidence of a live replica, not a sick one.
//! The window derives a three-state machine:
//!
//! - `healthy` — fault rate below [`DEGRADED_FAULT_RATE`] (or too few
//!   samples to judge: replicas start optimistic);
//! - `degraded` — fault rate in `[DEGRADED_FAULT_RATE, UNHEALTHY_FAULT_RATE)`;
//! - `unhealthy` — fault rate at or above [`UNHEALTHY_FAULT_RATE`].
//!
//! `ReplicaPool` routing consults the state: unhealthy replicas are
//! skipped while any non-unhealthy candidate remains, which both drains
//! traffic away from a dead engine and — because a fully-unhealthy pool
//! still routes — keeps samples flowing so a recovered replica can climb
//! back out. Eviction/respawn is a future PR; this provides its signal.
//!
//! **SLO windows.** [`SloWindows`] tracks request outcomes in
//! [`SLO_SLICE_SECS`]-second slices over a short (1 min) and long
//! (5 min) horizon and derives *burn rates*: the observed error rate
//! (or fraction of requests slower than the latency target — the
//! p99-vs-target proxy) divided by the budgeted rate. A burn rate of 1
//! means the error budget is being consumed exactly as provisioned;
//! sustained short-window burn ≫ long-window burn is the classic page
//! signal. Slices are atomics stamped with their epoch, so recording is
//! lock-free and stale slices are lazily recycled in place.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Settled outcomes considered when deriving a replica's health state.
pub const HEALTH_WINDOW: usize = 16;
/// Outcomes required before the state machine leaves `healthy` — fresh
/// replicas are not judged on one bad request.
pub const HEALTH_MIN_SAMPLES: usize = 4;
/// Fault rate at which a replica is `degraded`.
pub const DEGRADED_FAULT_RATE: f64 = 0.25;
/// Fault rate at which a replica is `unhealthy` (skipped by routing).
pub const UNHEALTHY_FAULT_RATE: f64 = 0.5;

/// Three-state replica health.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(usize)]
pub enum HealthState {
    Healthy = 0,
    Degraded = 1,
    Unhealthy = 2,
}

impl HealthState {
    /// Lowercase name, as exported in JSON and Prometheus labels.
    pub fn as_str(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Unhealthy => "unhealthy",
        }
    }

    fn from_usize(v: usize) -> HealthState {
        match v {
            0 => HealthState::Healthy,
            1 => HealthState::Degraded,
            _ => HealthState::Unhealthy,
        }
    }
}

/// The sliding outcome window: newest outcome in bit 0, fault = 1.
#[derive(Debug, Default)]
struct OutcomeWindow {
    bits: u64,
    len: usize,
}

/// Per-replica rolling health accumulator. Shared (`Arc`) between the
/// pool's routing loop and the in-flight tickets that settle outcomes.
#[derive(Debug, Default)]
pub struct ReplicaHealth {
    window: Mutex<OutcomeWindow>,
    /// Derived state, readable lock-free on the routing hot path.
    state: AtomicUsize,
    /// Lifetime fault count (monotone, for the metrics surface).
    faults_total: AtomicU64,
    /// Lifetime settled-outcome count (monotone).
    results_total: AtomicU64,
}

impl ReplicaHealth {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current state (relaxed read; exact enough for routing).
    pub fn state(&self) -> HealthState {
        HealthState::from_usize(self.state.load(Ordering::Relaxed))
    }

    pub fn faults_total(&self) -> u64 {
        self.faults_total.load(Ordering::Relaxed)
    }

    pub fn results_total(&self) -> u64 {
        self.results_total.load(Ordering::Relaxed)
    }

    /// Record one settled outcome and re-derive the state. Returns the
    /// `(old, new)` pair when the state changed, so the caller can log
    /// the transition.
    pub fn record(&self, fault: bool) -> Option<(HealthState, HealthState)> {
        let mut w = self.window.lock().unwrap();
        w.bits = (w.bits << 1) | fault as u64;
        w.len = (w.len + 1).min(HEALTH_WINDOW);
        self.results_total.fetch_add(1, Ordering::Relaxed);
        if fault {
            self.faults_total.fetch_add(1, Ordering::Relaxed);
        }
        let faults = (w.bits & ((1u64 << w.len) - 1)).count_ones() as f64;
        let rate = faults / w.len as f64;
        let new = if w.len < HEALTH_MIN_SAMPLES || rate < DEGRADED_FAULT_RATE {
            HealthState::Healthy
        } else if rate < UNHEALTHY_FAULT_RATE {
            HealthState::Degraded
        } else {
            HealthState::Unhealthy
        };
        // Derive + publish under the window lock so transitions are
        // reported exactly once even with concurrent settles.
        let old = HealthState::from_usize(self.state.swap(new as usize, Ordering::Relaxed));
        (old != new).then_some((old, new))
    }
}

/// Width of one SLO accounting slice.
pub const SLO_SLICE_SECS: u64 = 10;
/// Slices retained — the long window (5 minutes).
pub const SLO_SLICES: usize = 30;
/// Slices in the short window (1 minute).
pub const SLO_SHORT_SLICES: usize = 6;
/// Budgeted error rate: 1% of requests may fail.
pub const SLO_ERROR_BUDGET: f64 = 0.01;
/// Budgeted slow rate: 1% of requests may exceed the latency target
/// (i.e. the target is provisioned as a p99).
pub const SLO_LATENCY_BUDGET: f64 = 0.01;
/// Default latency target (the p99 objective), milliseconds.
pub const DEFAULT_SLO_TARGET_MS: f64 = 250.0;

#[derive(Debug)]
struct SloSlice {
    /// Which `SLO_SLICE_SECS` epoch this slice currently counts.
    epoch: AtomicU64,
    requests: AtomicU64,
    errors: AtomicU64,
    slow: AtomicU64,
}

impl SloSlice {
    fn new() -> Self {
        SloSlice {
            epoch: AtomicU64::new(u64::MAX),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            slow: AtomicU64::new(0),
        }
    }
}

/// One exported SLO window.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SloWindowSnapshot {
    /// Window name: `"1m"` or `"5m"`.
    pub window: String,
    pub requests: u64,
    pub errors: u64,
    /// Requests slower than the latency target.
    pub slow: u64,
    /// `(errors / requests) / SLO_ERROR_BUDGET`; 0 when idle.
    pub error_burn_rate: f64,
    /// `(slow / requests) / SLO_LATENCY_BUDGET`; 0 when idle.
    pub latency_burn_rate: f64,
}

/// The exported SLO block of `/v1/metrics`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SloSnapshot {
    /// Latency target (p99 objective), milliseconds.
    pub target_ms: f64,
    /// Short then long window.
    pub windows: Vec<SloWindowSnapshot>,
}

/// Rolling short/long SLO accounting. One per pool, fed from the same
/// settle path as the serve counters.
#[derive(Debug)]
pub struct SloWindows {
    start: Instant,
    target_us: u64,
    slices: Vec<SloSlice>,
}

impl Default for SloWindows {
    fn default() -> Self {
        SloWindows::new(DEFAULT_SLO_TARGET_MS)
    }
}

impl SloWindows {
    pub fn new(target_ms: f64) -> Self {
        SloWindows {
            start: Instant::now(),
            target_us: (target_ms.max(0.0) * 1000.0) as u64,
            slices: (0..SLO_SLICES).map(|_| SloSlice::new()).collect(),
        }
    }

    pub fn target_ms(&self) -> f64 {
        self.target_us as f64 / 1000.0
    }

    fn epoch_now(&self) -> u64 {
        self.start.elapsed().as_secs() / SLO_SLICE_SECS
    }

    fn slice_at(&self, epoch: u64) -> &SloSlice {
        let s = &self.slices[(epoch % SLO_SLICES as u64) as usize];
        // Lazily recycle a slice left over from a previous lap. The
        // reset races concurrent recorders in the same new epoch by at
        // most a handful of samples — acceptable for telemetry, and the
        // stale lap's counts never leak into the new epoch's window
        // because the epoch stamp flips first.
        if s.epoch.swap(epoch, Ordering::Relaxed) != epoch {
            s.requests.store(0, Ordering::Relaxed);
            s.errors.store(0, Ordering::Relaxed);
            s.slow.store(0, Ordering::Relaxed);
        }
        s
    }

    /// Record one finished request. `latency_us` of `None` means the
    /// request failed before a latency was measured (it still burns the
    /// error budget, not the latency budget).
    pub fn record(&self, error: bool, latency_us: Option<u64>) {
        let s = self.slice_at(self.epoch_now());
        s.requests.fetch_add(1, Ordering::Relaxed);
        if error {
            s.errors.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(us) = latency_us {
            if us > self.target_us {
                s.slow.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn window(&self, name: &str, slices_back: usize) -> SloWindowSnapshot {
        let now = self.epoch_now();
        let oldest = now.saturating_sub(slices_back as u64 - 1);
        let (mut requests, mut errors, mut slow) = (0u64, 0u64, 0u64);
        for s in &self.slices {
            let e = s.epoch.load(Ordering::Relaxed);
            if e >= oldest && e <= now {
                requests += s.requests.load(Ordering::Relaxed);
                errors += s.errors.load(Ordering::Relaxed);
                slow += s.slow.load(Ordering::Relaxed);
            }
        }
        let rate = |n: u64, budget: f64| {
            if requests == 0 {
                0.0
            } else {
                (n as f64 / requests as f64) / budget
            }
        };
        SloWindowSnapshot {
            window: name.to_string(),
            requests,
            errors,
            slow,
            error_burn_rate: rate(errors, SLO_ERROR_BUDGET),
            latency_burn_rate: rate(slow, SLO_LATENCY_BUDGET),
        }
    }

    pub fn snapshot(&self) -> SloSnapshot {
        SloSnapshot {
            target_ms: self.target_ms(),
            windows: vec![self.window("1m", SLO_SHORT_SLICES), self.window("5m", SLO_SLICES)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_replicas_are_healthy_and_tolerant() {
        let h = ReplicaHealth::new();
        assert_eq!(h.state(), HealthState::Healthy);
        // One early fault: below MIN_SAMPLES, still healthy.
        assert_eq!(h.record(true), None);
        assert_eq!(h.state(), HealthState::Healthy);
        for _ in 0..8 {
            h.record(false);
        }
        assert_eq!(h.state(), HealthState::Healthy);
        assert_eq!(h.faults_total(), 1);
        assert_eq!(h.results_total(), 9);
    }

    #[test]
    fn fault_rate_drives_the_state_machine() {
        let h = ReplicaHealth::new();
        // All faults: unhealthy as soon as MIN_SAMPLES is reached, with
        // exactly one reported transition.
        let mut transitions = Vec::new();
        for _ in 0..HEALTH_MIN_SAMPLES {
            if let Some(t) = h.record(true) {
                transitions.push(t);
            }
        }
        assert_eq!(h.state(), HealthState::Unhealthy);
        assert_eq!(transitions, vec![(HealthState::Healthy, HealthState::Unhealthy)]);
        // Recovery: successes wash the faults out of the window.
        let mut saw_healthy = false;
        for _ in 0..HEALTH_WINDOW {
            if let Some((_, new)) = h.record(false) {
                saw_healthy |= new == HealthState::Healthy;
            }
        }
        assert!(saw_healthy);
        assert_eq!(h.state(), HealthState::Healthy);
    }

    #[test]
    fn degraded_sits_between_thresholds() {
        let h = ReplicaHealth::new();
        // 16-outcome window with 5 faults → rate 0.3125 ∈ [0.25, 0.5).
        for i in 0..HEALTH_WINDOW {
            h.record(i % 3 == 0 && i < 15);
        }
        let w_faults = 5.0 / HEALTH_WINDOW as f64;
        assert!((DEGRADED_FAULT_RATE..UNHEALTHY_FAULT_RATE).contains(&w_faults));
        assert_eq!(h.state(), HealthState::Degraded);
    }

    #[test]
    fn slo_windows_accumulate_and_burn() {
        let slo = SloWindows::new(1.0); // 1 ms target
        for i in 0..100 {
            // 2 errors, 4 slow among 100 requests.
            let err = i < 2;
            let lat = if err { None } else { Some(if i < 6 { 5_000 } else { 10 }) };
            slo.record(err, lat);
        }
        let snap = slo.snapshot();
        assert_eq!(snap.target_ms, 1.0);
        assert_eq!(snap.windows.len(), 2);
        for w in &snap.windows {
            assert_eq!(w.requests, 100, "{}", w.window);
            assert_eq!(w.errors, 2);
            assert_eq!(w.slow, 4);
            // 2% error rate against a 1% budget → burn rate 2.
            assert!((w.error_burn_rate - 2.0).abs() < 1e-9);
            assert!((w.latency_burn_rate - 4.0).abs() < 1e-9);
        }
        assert_eq!(snap.windows[0].window, "1m");
        assert_eq!(snap.windows[1].window, "5m");
    }

    #[test]
    fn idle_windows_report_zero_burn() {
        let snap = SloWindows::default().snapshot();
        for w in &snap.windows {
            assert_eq!(w.requests, 0);
            assert_eq!(w.error_burn_rate, 0.0);
            assert_eq!(w.latency_burn_rate, 0.0);
        }
        assert_eq!(snap.target_ms, DEFAULT_SLO_TARGET_MS);
    }

    #[test]
    fn state_names_are_stable() {
        assert_eq!(HealthState::Healthy.as_str(), "healthy");
        assert_eq!(HealthState::Degraded.as_str(), "degraded");
        assert_eq!(HealthState::Unhealthy.as_str(), "unhealthy");
    }
}
