//! Serving/training metrics: streaming statistics, latency histograms,
//! throughput meters, the mIoU derivation used by Tab. 4 — and the
//! serving-layer telemetry registry behind `GET /v1/metrics`.
//!
//! The registry half of this module is the **single source of truth** for
//! serving observability: [`ServeMetrics`] holds the stable-named
//! counters and the request-latency histogram, and [`MetricsSnapshot`] is
//! the typed, wire-encodable snapshot the replica pool assembles from it
//! (plus per-replica gauges). Every name in the snapshot is registered in
//! `docs/SERVING.md`; tests, ops dashboards, and the load harness all
//! read this one surface.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::health::{SloSnapshot, SloWindows};
use crate::kernels::profile::OpSeries;

/// Crate version baked into `serve_build_info{version=...}`.
pub const BUILD_VERSION: &str = env!("CARGO_PKG_VERSION");
/// Git revision baked into `serve_build_info{git=...}`: set
/// `MITA_BUILD_GIT` at compile time (CI does), `unknown` otherwise.
pub const BUILD_GIT: &str = match option_env!("MITA_BUILD_GIT") {
    Some(rev) => rev,
    None => "unknown",
};

/// Welford streaming mean/variance.
#[derive(Debug, Clone, Default)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Streaming {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Log-bucketed latency histogram (1us .. ~100s), exact count-based
/// percentile queries over bucket midpoints.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [1us * GROWTH^i, 1us * GROWTH^(i+1))
    buckets: Vec<u64>,
    total: u64,
    sum_secs: f64,
    max_secs: f64,
}

const NBUCKETS: usize = 160;
const GROWTH: f64 = 1.122_018_456_459_045; // 10^(1/20): 20 buckets per decade

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: vec![0; NBUCKETS], total: 0, sum_secs: 0.0, max_secs: 0.0 }
    }

    fn bucket_index(secs: f64) -> usize {
        let micros = (secs * 1e6).max(1.0);
        let idx = micros.log(GROWTH).floor() as isize;
        idx.clamp(0, NBUCKETS as isize - 1) as usize
    }

    fn bucket_value(idx: usize) -> f64 {
        // Geometric midpoint of the bucket, in seconds.
        GROWTH.powf(idx as f64 + 0.5) * 1e-6
    }

    pub fn record(&mut self, d: Duration) {
        let secs = d.as_secs_f64();
        self.buckets[Self::bucket_index(secs)] += 1;
        self.total += 1;
        self.sum_secs += secs;
        self.max_secs = self.max_secs.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_secs / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max_secs
    }

    /// Percentile in seconds (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_value(i);
            }
        }
        self.max_secs
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.total,
            self.mean() * 1e3,
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.percentile(99.0) * 1e3,
            self.max_secs * 1e3,
        )
    }

    /// Total recorded time in seconds.
    pub fn sum(&self) -> f64 {
        self.sum_secs
    }

    /// Upper bound of bucket `idx` in microseconds. The bucket grid is
    /// **fixed** (`GROWTH`^(idx+1) µs, 20 buckets per decade from 1 µs),
    /// so exports from different replicas/processes are mergeable
    /// bucket-for-bucket.
    pub fn bucket_le_us(idx: usize) -> f64 {
        GROWTH.powf(idx as f64 + 1.0)
    }

    /// Wire-ready snapshot: counters, percentiles, and the sparse list of
    /// non-empty `(le_us, count)` buckets.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.total,
            sum_us: self.sum_secs * 1e6,
            max_us: self.max_secs * 1e6,
            p50_us: self.percentile(50.0) * 1e6,
            p95_us: self.percentile(95.0) * 1e6,
            p99_us: self.percentile(99.0) * 1e6,
            buckets: self
                .buckets
                .iter()
                .enumerate()
                .filter(|&(_, &c)| c > 0)
                .map(|(i, &c)| (Self::bucket_le_us(i), c))
                .collect(),
        }
    }
}

// ---------------------------------------------------------------------------
// Serving telemetry registry (the data behind `GET /v1/metrics`)
// ---------------------------------------------------------------------------

/// Canonical registry of series names exported by `/v1/metrics` — the
/// contract documented in `docs/SERVING.md`. `mita client metrics` (the
/// CI probe) asserts every name appears in the raw payload, so renaming
/// a series without updating the docs fails loudly.
pub const METRIC_NAMES: &[&str] = &[
    "serve_requests_total",
    "serve_shed_total",
    "serve_errors_total",
    "request_latency_us",
    "replica_requests_total",
    "replica_queue_depth",
    "overflow_fraction",
    "load_imbalance",
    "tokens_generated_total",
    "prefill_tokens_total",
    "decode_step_latency_us",
    "replica_health",
    "op_time_us_total",
    "op_calls_total",
    "slo_error_burn_rate",
    "slo_latency_burn_rate",
    "serve_build_info",
    "uptime_seconds",
    "simd_lane",
];

/// Per-layer MiTA routing series (Prometheus + JSON `blocks` arrays).
/// Kept **out** of [`METRIC_NAMES`]: those names are asserted present in
/// every `/v1/metrics` payload, while per-block series only exist once a
/// model has served traffic.
pub const METRIC_BLOCK_OVERFLOW: &str = "mita_block_overflow_fraction";
/// Per-layer, per-expert routed-query counter (see
/// [`METRIC_BLOCK_OVERFLOW`] for why it is not in `METRIC_NAMES`).
pub const METRIC_EXPERT_QUERIES: &str = "mita_expert_queries_total";

/// Pool-wide serving counters and the request-latency histogram. Shared
/// (`Arc`) between the replica pool's routing path and the snapshot
/// path; counters are lock-free, the histogram takes a short mutex only
/// on settle and snapshot.
///
/// Counting contract (registered in `docs/SERVING.md`):
/// - `serve_requests_total` — every compute request the pool routed
///   **or shed** (attention / model-forward / artifact). Binds, stats,
///   and metrics requests are control-plane and do not count.
/// - `serve_shed_total` — the subset rejected at admission with
///   `overloaded` (so `shed / requests` is the shed fraction).
/// - `serve_errors_total` — settled requests whose backend execution
///   returned an error (sheds are not double-counted here).
/// - `request_latency_us` — submit→settle latency of successfully
///   executed requests, on the fixed log-spaced bucket grid.
/// - `tokens_generated_total` — tokens emitted by successful generate
///   requests; `prefill_tokens_total` — prompt tokens those requests
///   prefilled (so generated/prefill ratios fall out of two counters).
/// - `decode_step_latency_us` — per-token decode-step latency of
///   streamed generate steps (step 0, the prefill tail, is not
///   recorded), on the same fixed bucket grid.
/// - `slo_error_burn_rate` / `slo_latency_burn_rate` — rolling 1m/5m
///   burn rates fed from the same settle path (`record_latency` /
///   `record_error`; sheds never reach the SLO accounting).
/// - `uptime_seconds` — seconds since these metrics (the pool) started.
#[derive(Debug)]
pub struct ServeMetrics {
    requests_total: AtomicU64,
    shed_total: AtomicU64,
    errors_total: AtomicU64,
    latency: Mutex<LatencyHistogram>,
    tokens_generated_total: AtomicU64,
    prefill_tokens_total: AtomicU64,
    decode_latency: Mutex<LatencyHistogram>,
    /// Rolling short/long SLO windows (error + latency burn).
    slo: SloWindows,
    /// Pool start, the origin of `uptime_seconds`.
    started: Instant,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            requests_total: AtomicU64::new(0),
            shed_total: AtomicU64::new(0),
            errors_total: AtomicU64::new(0),
            latency: Mutex::new(LatencyHistogram::new()),
            tokens_generated_total: AtomicU64::new(0),
            prefill_tokens_total: AtomicU64::new(0),
            decode_latency: Mutex::new(LatencyHistogram::new()),
            slo: SloWindows::default(),
            started: Instant::now(),
        }
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors_total.fetch_add(1, Ordering::Relaxed);
        self.slo.record(true, None);
    }

    pub fn record_latency(&self, d: Duration) {
        self.latency.lock().expect("latency lock").record(d);
        self.slo.record(false, Some(d.as_micros() as u64));
    }

    /// Count one settled generate request: its emitted tokens and the
    /// prompt tokens it prefilled.
    pub fn record_generate(&self, tokens: u64, prefill_tokens: u64) {
        self.tokens_generated_total.fetch_add(tokens, Ordering::Relaxed);
        self.prefill_tokens_total.fetch_add(prefill_tokens, Ordering::Relaxed);
    }

    /// Record one decode step's latency (callers skip step 0 — its
    /// compute is the prefill tail, not a decode step).
    pub fn record_decode_step(&self, d: Duration) {
        self.decode_latency.lock().expect("decode latency lock").record(d);
    }

    pub fn requests_total(&self) -> u64 {
        self.requests_total.load(Ordering::Relaxed)
    }

    pub fn shed_total(&self) -> u64 {
        self.shed_total.load(Ordering::Relaxed)
    }

    pub fn errors_total(&self) -> u64 {
        self.errors_total.load(Ordering::Relaxed)
    }

    /// Mean settled latency in milliseconds (0 before any settle) — the
    /// pool's `retry_after_ms` hint is derived from this.
    pub fn mean_latency_ms(&self) -> f64 {
        self.latency.lock().expect("latency lock").mean() * 1e3
    }

    pub fn latency_snapshot(&self) -> HistogramSnapshot {
        self.latency.lock().expect("latency lock").snapshot()
    }

    pub fn tokens_generated_total(&self) -> u64 {
        self.tokens_generated_total.load(Ordering::Relaxed)
    }

    pub fn prefill_tokens_total(&self) -> u64 {
        self.prefill_tokens_total.load(Ordering::Relaxed)
    }

    pub fn decode_latency_snapshot(&self) -> HistogramSnapshot {
        self.decode_latency.lock().expect("decode latency lock").snapshot()
    }

    /// Rolling-window SLO burn-rate export (1m + 5m windows).
    pub fn slo_snapshot(&self) -> SloSnapshot {
        self.slo.snapshot()
    }

    /// Seconds since these metrics (the pool) were created.
    pub fn uptime_seconds(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

/// Wire-encodable histogram export: summary statistics plus the sparse
/// non-empty buckets of the fixed log-spaced grid. All times are in
/// microseconds (percentiles are bucket-midpoint estimates, `sum`/`max`
/// are exact).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum_us: f64,
    pub max_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Non-empty `(le_us, count)` pairs; `le_us` is the bucket's upper
    /// bound on the fixed grid (`LatencyHistogram::bucket_le_us`).
    pub buckets: Vec<(f64, u64)>,
}

/// Per-transformer-block MiTA routing series for one replica, derived
/// from the backend's cumulative [`BlockProfile`](crate::kernels::api::BlockProfile)
/// accumulators. Empty until the replica has served model-forward
/// traffic (attention-only service has no block structure).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BlockSeries {
    /// Block index (0-based, bottom of the stack first).
    pub block: u64,
    /// Overflow fraction for this block's MiTA routing.
    pub overflow_fraction: f64,
    /// Queries routed through this block since startup (or last reset).
    pub queries: u64,
    /// Queries landing on each expert of this block — the expert
    /// occupancy histogram behind `mita_expert_queries_total`.
    pub expert_queries: Vec<u64>,
}

/// Per-replica gauges sampled at snapshot time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicaSnapshot {
    /// Replica index (0-based, stable for the life of the pool).
    pub replica: u64,
    /// Compute requests routed to this replica since startup.
    pub replica_requests_total: u64,
    /// Tickets currently outstanding on this replica (gauge).
    pub replica_queue_depth: u64,
    /// This replica's admission cap.
    pub max_inflight: u64,
    /// MiTA routing overflow fraction from the replica's kernel stats
    /// (queries exceeding an expert's capacity; 0 when unavailable).
    pub overflow_fraction: f64,
    /// Worst observed expert load imbalance (max/mean; 0 when
    /// unavailable).
    pub load_imbalance: f64,
    /// Health state name (`healthy` | `degraded` | `unhealthy`) from the
    /// replica's rolling fault window.
    pub health: String,
    /// Lifetime replica-fault count behind the health window.
    pub health_faults: u64,
    /// Lifetime settled-outcome count behind the health window.
    pub health_results: u64,
    /// Per-block MiTA routing series (empty until model traffic ran).
    pub blocks: Vec<BlockSeries>,
}

/// The full `/v1/metrics` payload: pool counters, the latency histogram,
/// and one [`ReplicaSnapshot`] per replica.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub serve_requests_total: u64,
    pub serve_shed_total: u64,
    pub serve_errors_total: u64,
    pub request_latency_us: HistogramSnapshot,
    /// Tokens emitted by successful generate requests (pool-wide).
    pub tokens_generated_total: u64,
    /// Prompt tokens prefilled by those requests.
    pub prefill_tokens_total: u64,
    /// Per-token decode-step latency histogram (streamed generate steps
    /// past step 0).
    pub decode_step_latency_us: HistogramSnapshot,
    pub replicas: Vec<ReplicaSnapshot>,
    /// Op-level profiler series (`kernels::profile::snapshot()`): every
    /// profiled kernel phase / decode stage, zeros when idle.
    pub ops: Vec<OpSeries>,
    /// Rolling-window SLO burn rates (1m + 5m).
    pub slo: SloSnapshot,
    /// Seconds since the pool started.
    pub uptime_seconds: f64,
    /// Crate version ([`BUILD_VERSION`]), for `serve_build_info`.
    pub build_version: String,
    /// Build git revision ([`BUILD_GIT`]), for `serve_build_info`.
    pub build_git: String,
    /// SIMD lane the serving process dispatched its kernels to at
    /// startup (`scalar` | `portable` | `avx2` | `neon`; see
    /// `docs/PERF.md`). A process-wide fact, so it lives at the pool
    /// level, not per replica.
    pub simd_lane: String,
}

impl MetricsSnapshot {
    /// Shed fraction over the lifetime of the pool (0 with no traffic).
    pub fn shed_fraction(&self) -> f64 {
        if self.serve_requests_total == 0 {
            0.0
        } else {
            self.serve_shed_total as f64 / self.serve_requests_total as f64
        }
    }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition (`GET /v1/metrics?format=prometheus`)
// ---------------------------------------------------------------------------

/// Format a sample value the Prometheus way: integers render without a
/// fractional part, everything else as a plain float.
fn prom_value(v: f64) -> String {
    if v.is_finite() && v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render a [`MetricsSnapshot`] as Prometheus text exposition format
/// (version 0.0.4). Series names match the JSON payload's
/// [`METRIC_NAMES`] contract; the latency histogram becomes cumulative
/// `_bucket{le="..."}` samples plus `_sum`/`_count`; per-replica gauges
/// carry a `replica` label; per-block MiTA series add `block` (and
/// `expert`) labels.
pub fn render_prometheus(snap: &MetricsSnapshot) -> String {
    let mut out = String::with_capacity(4096);
    let mut line = |s: String| {
        out.push_str(&s);
        out.push('\n');
    };

    line("# TYPE serve_requests_total counter".into());
    line(format!("serve_requests_total {}", snap.serve_requests_total));
    line("# TYPE serve_shed_total counter".into());
    line(format!("serve_shed_total {}", snap.serve_shed_total));
    line("# TYPE serve_errors_total counter".into());
    line(format!("serve_errors_total {}", snap.serve_errors_total));

    // Histogram: the snapshot's sparse (le_us, count) pairs carry
    // per-bucket counts; Prometheus buckets are cumulative, ending in
    // the mandatory `+Inf` = total count.
    line("# TYPE request_latency_us histogram".into());
    let h = &snap.request_latency_us;
    let mut cumulative = 0u64;
    for &(le_us, count) in &h.buckets {
        cumulative += count;
        line(format!("request_latency_us_bucket{{le=\"{}\"}} {cumulative}", prom_value(le_us)));
    }
    line(format!("request_latency_us_bucket{{le=\"+Inf\"}} {}", h.count));
    line(format!("request_latency_us_sum {}", prom_value(h.sum_us)));
    line(format!("request_latency_us_count {}", h.count));

    line("# TYPE tokens_generated_total counter".into());
    line(format!("tokens_generated_total {}", snap.tokens_generated_total));
    line("# TYPE prefill_tokens_total counter".into());
    line(format!("prefill_tokens_total {}", snap.prefill_tokens_total));
    // Always emitted, even before any generate traffic (the registry
    // contract asserts every documented series is present).
    line("# TYPE decode_step_latency_us histogram".into());
    let h = &snap.decode_step_latency_us;
    let mut cumulative = 0u64;
    for &(le_us, count) in &h.buckets {
        cumulative += count;
        line(format!(
            "decode_step_latency_us_bucket{{le=\"{}\"}} {cumulative}",
            prom_value(le_us)
        ));
    }
    line(format!("decode_step_latency_us_bucket{{le=\"+Inf\"}} {}", h.count));
    line(format!("decode_step_latency_us_sum {}", prom_value(h.sum_us)));
    line(format!("decode_step_latency_us_count {}", h.count));

    line("# TYPE replica_requests_total counter".into());
    for r in &snap.replicas {
        line(format!(
            "replica_requests_total{{replica=\"{}\"}} {}",
            r.replica, r.replica_requests_total
        ));
    }
    line("# TYPE replica_queue_depth gauge".into());
    for r in &snap.replicas {
        line(format!("replica_queue_depth{{replica=\"{}\"}} {}", r.replica, r.replica_queue_depth));
    }
    line("# TYPE overflow_fraction gauge".into());
    for r in &snap.replicas {
        line(format!(
            "overflow_fraction{{replica=\"{}\"}} {}",
            r.replica,
            prom_value(r.overflow_fraction)
        ));
    }
    line("# TYPE load_imbalance gauge".into());
    for r in &snap.replicas {
        line(format!(
            "load_imbalance{{replica=\"{}\"}} {}",
            r.replica,
            prom_value(r.load_imbalance)
        ));
    }
    // Health is categorical per replica: a 1-valued gauge with the state
    // as a label (the `simd_lane` idiom), so dashboards can group by
    // state without a numeric encoding.
    line("# TYPE replica_health gauge".into());
    for r in &snap.replicas {
        line(format!("replica_health{{replica=\"{}\",state=\"{}\"}} 1", r.replica, r.health));
    }

    // Per-layer MiTA routing introspection (absent until model traffic
    // has run; scrapers must treat the series as optional).
    if snap.replicas.iter().any(|r| !r.blocks.is_empty()) {
        line(format!("# TYPE {METRIC_BLOCK_OVERFLOW} gauge"));
        for r in &snap.replicas {
            for b in &r.blocks {
                line(format!(
                    "{METRIC_BLOCK_OVERFLOW}{{replica=\"{}\",block=\"{}\"}} {}",
                    r.replica,
                    b.block,
                    prom_value(b.overflow_fraction)
                ));
            }
        }
        line(format!("# TYPE {METRIC_EXPERT_QUERIES} counter"));
        for r in &snap.replicas {
            for b in &r.blocks {
                for (e, &q) in b.expert_queries.iter().enumerate() {
                    line(format!(
                        "{METRIC_EXPERT_QUERIES}{{replica=\"{}\",block=\"{}\",expert=\"{e}\"}} {q}",
                        r.replica, b.block
                    ));
                }
            }
        }
    }

    // Op-level profiler: one time + one call series per profiled kernel
    // phase / decode stage. Always present (zeros when idle) so the
    // series set is stable across scrapes.
    line("# TYPE op_time_us_total counter".into());
    for o in &snap.ops {
        line(format!("op_time_us_total{{op=\"{}\"}} {}", o.op, prom_value(o.time_us)));
    }
    line("# TYPE op_calls_total counter".into());
    for o in &snap.ops {
        line(format!("op_calls_total{{op=\"{}\"}} {}", o.op, o.calls));
    }

    // Rolling SLO burn rates over the short/long windows.
    line("# TYPE slo_error_burn_rate gauge".into());
    for w in &snap.slo.windows {
        line(format!(
            "slo_error_burn_rate{{window=\"{}\"}} {}",
            w.window,
            prom_value(w.error_burn_rate)
        ));
    }
    line("# TYPE slo_latency_burn_rate gauge".into());
    for w in &snap.slo.windows {
        line(format!(
            "slo_latency_burn_rate{{window=\"{}\"}} {}",
            w.window,
            prom_value(w.latency_burn_rate)
        ));
    }

    // Build identity as an info-style series + process uptime.
    line("# TYPE serve_build_info gauge".into());
    line(format!(
        "serve_build_info{{version=\"{}\",git=\"{}\",simd_lane=\"{}\"}} 1",
        snap.build_version, snap.build_git, snap.simd_lane
    ));
    line("# TYPE uptime_seconds gauge".into());
    line(format!("uptime_seconds {}", prom_value(snap.uptime_seconds)));

    // The lane is categorical; expose it the Prometheus way — a 1-valued
    // gauge with the category as a label.
    line("# TYPE simd_lane gauge".into());
    line(format!("simd_lane{{lane=\"{}\"}} 1", snap.simd_lane));
    out
}

/// Validate a Prometheus text payload: every non-comment line must match
/// the `name{labels} value` grammar, and every series in
/// [`METRIC_NAMES`] must be present (as the exact sample name or as a
/// `name_` prefix, covering `_bucket`/`_sum`/`_count` expansions).
/// Returns the number of sample lines on success. This is the checker
/// behind `mita client check-prometheus` and the CI loopback smoke.
pub fn check_prometheus_text(text: &str) -> Result<usize, String> {
    fn valid_name(s: &str) -> bool {
        !s.is_empty()
            && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
            && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }
    fn valid_labels(s: &str) -> bool {
        // `key="value",key="value"` — values may contain anything but an
        // unescaped quote (we never emit escapes, so reject them too).
        s.split(',').all(|pair| match pair.split_once('=') {
            Some((k, v)) => {
                valid_name(k)
                    && v.len() >= 2
                    && v.starts_with('"')
                    && v.ends_with('"')
                    && !v[1..v.len() - 1].contains('"')
            }
            None => false,
        })
    }

    let mut samples = 0usize;
    let mut seen: Vec<&str> = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value separator: {raw:?}", ln + 1))?;
        if value.parse::<f64>().is_err() && value != "+Inf" && value != "-Inf" {
            return Err(format!("line {}: unparsable value {value:?}", ln + 1));
        }
        let name = match series.split_once('{') {
            Some((name, rest)) => {
                let labels = rest
                    .strip_suffix('}')
                    .ok_or_else(|| format!("line {}: unterminated labels: {raw:?}", ln + 1))?;
                if !valid_labels(labels) {
                    return Err(format!("line {}: malformed labels {labels:?}", ln + 1));
                }
                name
            }
            None => series,
        };
        if !valid_name(name) {
            return Err(format!("line {}: malformed metric name {name:?}", ln + 1));
        }
        samples += 1;
        seen.push(name);
    }
    for want in METRIC_NAMES {
        let prefix = format!("{want}_");
        if !seen.iter().any(|n| n == want || n.starts_with(&prefix)) {
            return Err(format!("documented series {want:?} missing from exposition"));
        }
    }
    Ok(samples)
}

/// Items-per-second throughput meter.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { start: Instant::now(), items: 0 }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn items(&self) -> u64 {
        self.items
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn per_sec(&self) -> f64 {
        let e = self.elapsed();
        if e <= 0.0 {
            0.0
        } else {
            self.items as f64 / e
        }
    }
}

/// Mean IoU from an accumulated confusion matrix (rows = ground truth).
pub fn miou_from_confusion(confusion: &[f32], classes: usize) -> f64 {
    assert_eq!(confusion.len(), classes * classes);
    let mut ious = Vec::with_capacity(classes);
    for c in 0..classes {
        let tp = confusion[c * classes + c] as f64;
        let row: f64 = (0..classes).map(|j| confusion[c * classes + j] as f64).sum();
        let col: f64 = (0..classes).map(|i| confusion[i * classes + c] as f64).sum();
        let union = row + col - tp;
        if union > 0.0 {
            ious.push(tp / union);
        }
    }
    if ious.is_empty() {
        0.0
    } else {
        ious.iter().sum::<f64>() / ious.len() as f64
    }
}

/// Pixel accuracy from a confusion matrix.
pub fn pixel_acc_from_confusion(confusion: &[f32], classes: usize) -> f64 {
    let total: f64 = confusion.iter().map(|&x| x as f64).sum();
    if total == 0.0 {
        return 0.0;
    }
    let correct: f64 = (0..classes).map(|c| confusion[c * classes + c] as f64).sum();
    correct / total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_moments() {
        let mut s = Streaming::default();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of uniform 1..1000us should be around 500us (bucketed).
        assert!(p50 > 300e-6 && p50 < 800e-6, "p50={p50}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn histogram_extreme_values_clamped() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1));
        h.record(Duration::from_secs(1000));
        assert_eq!(h.count(), 2);
        assert!(h.percentile(100.0) > 0.0);
    }

    #[test]
    fn histogram_snapshot_exports_fixed_buckets() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_micros(12));
        h.record(Duration::from_micros(12));
        h.record(Duration::from_millis(5));
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert!((s.sum_us - 5024.0).abs() < 1.0, "sum_us={}", s.sum_us);
        assert!((s.max_us - 5000.0).abs() < 1.0);
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us);
        // Sparse export: two non-empty buckets, ascending fixed bounds,
        // counts adding back up to the total.
        assert_eq!(s.buckets.len(), 2);
        assert!(s.buckets[0].0 < s.buckets[1].0);
        assert_eq!(s.buckets.iter().map(|&(_, c)| c).sum::<u64>(), 3);
        // Each sample sits within its bucket's bound: 12us ≤ le of the
        // first, 5000us ≤ le of the second.
        assert!(s.buckets[0].0 >= 12.0 && s.buckets[0].1 == 2);
        assert!(s.buckets[1].0 >= 5000.0 && s.buckets[1].1 == 1);
        // The grid itself is fixed and growing.
        assert!(LatencyHistogram::bucket_le_us(0) > 1.0);
        assert!(LatencyHistogram::bucket_le_us(20) > LatencyHistogram::bucket_le_us(19));
    }

    #[test]
    fn serve_metrics_counters_and_latency() {
        let m = ServeMetrics::new();
        assert_eq!(m.requests_total(), 0);
        m.record_request();
        m.record_request();
        m.record_shed();
        m.record_error();
        m.record_latency(Duration::from_millis(2));
        m.record_generate(6, 4);
        m.record_generate(2, 1);
        m.record_decode_step(Duration::from_micros(80));
        assert_eq!(m.requests_total(), 2);
        assert_eq!(m.shed_total(), 1);
        assert_eq!(m.errors_total(), 1);
        assert!((m.mean_latency_ms() - 2.0).abs() < 1e-9);
        assert_eq!(m.latency_snapshot().count, 1);
        assert_eq!(m.tokens_generated_total(), 8);
        assert_eq!(m.prefill_tokens_total(), 5);
        assert_eq!(m.decode_latency_snapshot().count, 1);
        // Settles feed the rolling SLO windows too: 1 error + 1 ok.
        let slo = m.slo_snapshot();
        assert_eq!(slo.windows.len(), 2);
        assert_eq!(slo.windows[0].requests, 2);
        assert_eq!(slo.windows[0].errors, 1);
        assert!(m.uptime_seconds() >= 0.0);
        let snap = MetricsSnapshot {
            serve_requests_total: m.requests_total(),
            serve_shed_total: m.shed_total(),
            serve_errors_total: m.errors_total(),
            request_latency_us: m.latency_snapshot(),
            tokens_generated_total: m.tokens_generated_total(),
            prefill_tokens_total: m.prefill_tokens_total(),
            decode_step_latency_us: m.decode_latency_snapshot(),
            replicas: vec![],
            ops: crate::kernels::profile::snapshot(),
            slo,
            uptime_seconds: m.uptime_seconds(),
            build_version: BUILD_VERSION.into(),
            build_git: BUILD_GIT.into(),
            simd_lane: "scalar".into(),
        };
        assert!((snap.shed_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(MetricsSnapshot::default().shed_fraction(), 0.0);
    }

    #[test]
    fn prometheus_rendering_roundtrips_the_checker() {
        let m = ServeMetrics::new();
        for us in [40u64, 90, 90, 4000] {
            m.record_request();
            m.record_latency(Duration::from_micros(us));
        }
        m.record_generate(3, 2);
        m.record_decode_step(Duration::from_micros(120));
        let snap = MetricsSnapshot {
            serve_requests_total: m.requests_total(),
            serve_shed_total: 0,
            serve_errors_total: 0,
            request_latency_us: m.latency_snapshot(),
            tokens_generated_total: m.tokens_generated_total(),
            prefill_tokens_total: m.prefill_tokens_total(),
            decode_step_latency_us: m.decode_latency_snapshot(),
            replicas: vec![ReplicaSnapshot {
                replica: 0,
                replica_requests_total: 4,
                replica_queue_depth: 0,
                max_inflight: 8,
                overflow_fraction: 0.25,
                load_imbalance: 1.5,
                health: "degraded".into(),
                health_faults: 3,
                health_results: 9,
                blocks: vec![BlockSeries {
                    block: 0,
                    overflow_fraction: 0.125,
                    queries: 64,
                    expert_queries: vec![40, 24],
                }],
            }],
            ops: crate::kernels::profile::snapshot(),
            slo: m.slo_snapshot(),
            uptime_seconds: 12.0,
            build_version: BUILD_VERSION.into(),
            build_git: BUILD_GIT.into(),
            simd_lane: "scalar".into(),
        };
        let text = render_prometheus(&snap);

        // Histogram: buckets are cumulative, +Inf equals the count.
        assert!(text.contains("request_latency_us_bucket{le=\"+Inf\"} 4"), "{text}");
        assert!(text.contains("request_latency_us_count 4"), "{text}");
        let cum: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("request_latency_us_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(cum.windows(2).all(|w| w[0] <= w[1]), "cumulative buckets: {cum:?}");

        // Per-replica and per-block series carry their labels.
        assert!(text.contains("replica_requests_total{replica=\"0\"} 4"), "{text}");
        assert!(text.contains("mita_block_overflow_fraction{replica=\"0\",block=\"0\"} 0.125"));
        assert!(text.contains("mita_expert_queries_total{replica=\"0\",block=\"0\",expert=\"1\"} 24"));
        assert!(text.contains("simd_lane{lane=\"scalar\"} 1"), "{text}");

        // Decode telemetry renders with its own counters + histogram.
        assert!(text.contains("tokens_generated_total 3"), "{text}");
        assert!(text.contains("prefill_tokens_total 2"), "{text}");
        assert!(text.contains("decode_step_latency_us_count 1"), "{text}");
        assert!(text.contains("decode_step_latency_us_bucket{le=\"+Inf\"} 1"), "{text}");

        // Health, profiler, SLO, and build-info series added by the
        // observability layer all render with their labels.
        assert!(text.contains("replica_health{replica=\"0\",state=\"degraded\"} 1"), "{text}");
        for phase in crate::kernels::profile::OP_NAMES {
            assert!(text.contains(&format!("op_time_us_total{{op=\"{phase}\"}}")), "{text}");
            assert!(text.contains(&format!("op_calls_total{{op=\"{phase}\"}}")), "{text}");
        }
        for window in ["1m", "5m"] {
            assert!(text.contains(&format!("slo_error_burn_rate{{window=\"{window}\"}}")), "{text}");
            assert!(
                text.contains(&format!("slo_latency_burn_rate{{window=\"{window}\"}}")),
                "{text}"
            );
        }
        assert!(text.contains("serve_build_info{version=\""), "{text}");
        assert!(text.contains(&format!("git=\"{BUILD_GIT}\"")), "{text}");
        assert!(text.contains("uptime_seconds 12"), "{text}");

        // The whole payload passes the grammar + coverage checker.
        let samples = check_prometheus_text(&text).unwrap();
        assert!(samples >= 12, "sample lines: {samples}");
    }

    #[test]
    fn prometheus_checker_rejects_malformed_and_missing() {
        assert!(check_prometheus_text("serve_requests_total").is_err(), "no value");
        assert!(check_prometheus_text("1bad_name 3").is_err(), "bad name");
        assert!(check_prometheus_text("x{le=\"0.1} 3").is_err(), "unterminated label");
        assert!(check_prometheus_text("x{le} 3").is_err(), "label without value");
        assert!(check_prometheus_text("x{} y").is_err(), "unparsable value");
        // Grammar-clean but missing documented series.
        let err = check_prometheus_text("serve_requests_total 1\n").unwrap_err();
        assert!(err.contains("missing"), "{err}");
    }

    #[test]
    fn checker_coverage_includes_decode_and_observability_series() {
        // The registry contract: decode (PR 9) and the health / profiler
        // / SLO / build-info series are all *required* in every payload.
        for name in [
            "tokens_generated_total",
            "prefill_tokens_total",
            "decode_step_latency_us",
            "replica_health",
            "op_time_us_total",
            "op_calls_total",
            "slo_error_burn_rate",
            "slo_latency_burn_rate",
            "serve_build_info",
            "uptime_seconds",
        ] {
            assert!(METRIC_NAMES.contains(&name), "{name} missing from METRIC_NAMES");
        }
        // A payload carrying everything *except* one of them fails
        // coverage with the missing name in the error.
        let mut full = String::new();
        for name in METRIC_NAMES {
            if *name != "op_time_us_total" {
                full.push_str(&format!("{name} 1\n"));
            }
        }
        let err = check_prometheus_text(&full).unwrap_err();
        assert!(err.contains("op_time_us_total"), "{err}");
    }

    #[test]
    fn miou_perfect_and_degenerate() {
        // Perfect 2-class confusion.
        let conf = [5.0, 0.0, 0.0, 7.0];
        assert!((miou_from_confusion(&conf, 2) - 1.0).abs() < 1e-12);
        assert!((pixel_acc_from_confusion(&conf, 2) - 1.0).abs() < 1e-12);
        // All wrong.
        let conf = [0.0, 5.0, 7.0, 0.0];
        assert_eq!(miou_from_confusion(&conf, 2), 0.0);
        // Absent class ignored.
        let conf = [4.0, 0.0, 0.0, 0.0];
        assert!((miou_from_confusion(&conf, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add(10);
        t.add(5);
        assert_eq!(t.items(), 15);
        assert!(t.per_sec() > 0.0);
    }
}
