//! Serving/training metrics: streaming statistics, latency histograms,
//! throughput meters, and the mIoU derivation used by Tab. 4.

use std::time::{Duration, Instant};

/// Welford streaming mean/variance.
#[derive(Debug, Clone, Default)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Streaming {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Log-bucketed latency histogram (1us .. ~100s), exact count-based
/// percentile queries over bucket midpoints.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [1us * GROWTH^i, 1us * GROWTH^(i+1))
    buckets: Vec<u64>,
    total: u64,
    sum_secs: f64,
    max_secs: f64,
}

const NBUCKETS: usize = 160;
const GROWTH: f64 = 1.122_018_456_459_045; // 10^(1/20): 20 buckets per decade

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: vec![0; NBUCKETS], total: 0, sum_secs: 0.0, max_secs: 0.0 }
    }

    fn bucket_index(secs: f64) -> usize {
        let micros = (secs * 1e6).max(1.0);
        let idx = micros.log(GROWTH).floor() as isize;
        idx.clamp(0, NBUCKETS as isize - 1) as usize
    }

    fn bucket_value(idx: usize) -> f64 {
        // Geometric midpoint of the bucket, in seconds.
        GROWTH.powf(idx as f64 + 0.5) * 1e-6
    }

    pub fn record(&mut self, d: Duration) {
        let secs = d.as_secs_f64();
        self.buckets[Self::bucket_index(secs)] += 1;
        self.total += 1;
        self.sum_secs += secs;
        self.max_secs = self.max_secs.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_secs / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max_secs
    }

    /// Percentile in seconds (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_value(i);
            }
        }
        self.max_secs
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.total,
            self.mean() * 1e3,
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.percentile(99.0) * 1e3,
            self.max_secs * 1e3,
        )
    }
}

/// Items-per-second throughput meter.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { start: Instant::now(), items: 0 }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn items(&self) -> u64 {
        self.items
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn per_sec(&self) -> f64 {
        let e = self.elapsed();
        if e <= 0.0 {
            0.0
        } else {
            self.items as f64 / e
        }
    }
}

/// Mean IoU from an accumulated confusion matrix (rows = ground truth).
pub fn miou_from_confusion(confusion: &[f32], classes: usize) -> f64 {
    assert_eq!(confusion.len(), classes * classes);
    let mut ious = Vec::with_capacity(classes);
    for c in 0..classes {
        let tp = confusion[c * classes + c] as f64;
        let row: f64 = (0..classes).map(|j| confusion[c * classes + j] as f64).sum();
        let col: f64 = (0..classes).map(|i| confusion[i * classes + c] as f64).sum();
        let union = row + col - tp;
        if union > 0.0 {
            ious.push(tp / union);
        }
    }
    if ious.is_empty() {
        0.0
    } else {
        ious.iter().sum::<f64>() / ious.len() as f64
    }
}

/// Pixel accuracy from a confusion matrix.
pub fn pixel_acc_from_confusion(confusion: &[f32], classes: usize) -> f64 {
    let total: f64 = confusion.iter().map(|&x| x as f64).sum();
    if total == 0.0 {
        return 0.0;
    }
    let correct: f64 = (0..classes).map(|c| confusion[c * classes + c] as f64).sum();
    correct / total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_moments() {
        let mut s = Streaming::default();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of uniform 1..1000us should be around 500us (bucketed).
        assert!(p50 > 300e-6 && p50 < 800e-6, "p50={p50}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn histogram_extreme_values_clamped() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1));
        h.record(Duration::from_secs(1000));
        assert_eq!(h.count(), 2);
        assert!(h.percentile(100.0) > 0.0);
    }

    #[test]
    fn miou_perfect_and_degenerate() {
        // Perfect 2-class confusion.
        let conf = [5.0, 0.0, 0.0, 7.0];
        assert!((miou_from_confusion(&conf, 2) - 1.0).abs() < 1e-12);
        assert!((pixel_acc_from_confusion(&conf, 2) - 1.0).abs() < 1e-12);
        // All wrong.
        let conf = [0.0, 5.0, 7.0, 0.0];
        assert_eq!(miou_from_confusion(&conf, 2), 0.0);
        // Absent class ignored.
        let conf = [4.0, 0.0, 0.0, 0.0];
        assert!((miou_from_confusion(&conf, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add(10);
        t.add(5);
        assert_eq!(t.items(), 15);
        assert!(t.per_sec() > 0.0);
    }
}
