//! Dynamic batching policy — the serving-side heart of the coordinator.
//!
//! Requests arrive one at a time; the model artifact is compiled for a fixed
//! batch size B. The batcher groups requests with a max-batch / max-wait
//! policy (vLLM-style): flush when B requests are queued, or when the oldest
//! queued request has waited `max_wait`, whichever comes first. Short
//! batches are padded up to B (the pad fraction is tracked — it is the
//! efficiency cost of latency-bounded batching).
//!
//! The policy is pure (no I/O, no clocks injected) so it is unit- and
//! property-testable; `server.rs` drives it with real time.

use std::time::{Duration, Instant};

/// Flush policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard batch size of the compiled artifact.
    pub max_batch: usize,
    /// Max time the oldest request may wait before a forced flush.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// One queued item (generic payload + enqueue time).
#[derive(Debug)]
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// Decision returned by [`Batcher::poll`].
#[derive(Debug, PartialEq, Eq)]
pub enum Flush {
    /// Not enough demand yet; check again in this duration (None = only on
    /// new arrivals).
    Wait(Option<Duration>),
    /// Take this many items now.
    Take(usize),
}

/// Accumulates pending requests and decides when to flush.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queue: Vec<Pending<T>>,
    pub batches_emitted: u64,
    pub items_emitted: u64,
    pub padded_slots: u64,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0);
        Batcher { policy, queue: Vec::new(), batches_emitted: 0, items_emitted: 0, padded_slots: 0 }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn push(&mut self, payload: T, now: Instant) {
        self.queue.push(Pending { payload, enqueued: now });
    }

    /// Decide whether to flush at time `now`.
    pub fn poll(&self, now: Instant) -> Flush {
        if self.queue.is_empty() {
            return Flush::Wait(None);
        }
        if self.queue.len() >= self.policy.max_batch {
            return Flush::Take(self.policy.max_batch);
        }
        let oldest_age = now.duration_since(self.queue[0].enqueued);
        if oldest_age >= self.policy.max_wait {
            return Flush::Take(self.queue.len());
        }
        Flush::Wait(Some(self.policy.max_wait - oldest_age))
    }

    /// Remove and return the first `n` items (FIFO). Updates pad accounting
    /// as if the batch were padded to `max_batch`.
    pub fn take(&mut self, n: usize) -> Vec<Pending<T>> {
        let n = n.min(self.queue.len());
        let taken: Vec<Pending<T>> = self.queue.drain(..n).collect();
        self.batches_emitted += 1;
        self.items_emitted += taken.len() as u64;
        self.padded_slots += (self.policy.max_batch - taken.len()) as u64;
        taken
    }

    /// Fraction of executed slots wasted on padding so far.
    pub fn pad_fraction(&self) -> f64 {
        let total = self.items_emitted + self.padded_slots;
        if total == 0 {
            0.0
        } else {
            self.padded_slots as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(b: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch: b, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn empty_queue_waits_forever() {
        let b: Batcher<u32> = Batcher::new(policy(4, 10));
        assert_eq!(b.poll(Instant::now()), Flush::Wait(None));
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut b = Batcher::new(policy(3, 1000));
        let now = Instant::now();
        for i in 0..3 {
            b.push(i, now);
        }
        assert_eq!(b.poll(now), Flush::Take(3));
    }

    #[test]
    fn timeout_flushes_partial() {
        let mut b = Batcher::new(policy(8, 5));
        let t0 = Instant::now();
        b.push(1u32, t0);
        b.push(2u32, t0);
        // Before the deadline: wait with a bounded hint.
        match b.poll(t0 + Duration::from_millis(1)) {
            Flush::Wait(Some(d)) => assert!(d <= Duration::from_millis(4)),
            other => panic!("expected bounded wait, got {other:?}"),
        }
        // Past the deadline: flush what we have.
        assert_eq!(b.poll(t0 + Duration::from_millis(6)), Flush::Take(2));
    }

    #[test]
    fn take_is_fifo_and_tracks_padding() {
        let mut b = Batcher::new(policy(4, 5));
        let now = Instant::now();
        for i in 0..2 {
            b.push(i, now);
        }
        let taken = b.take(2);
        assert_eq!(taken.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.batches_emitted, 1);
        assert_eq!(b.items_emitted, 2);
        assert_eq!(b.padded_slots, 2);
        assert!((b.pad_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn prop_timeout_flush_edge_cases() {
        use crate::util::prop::run_prop;
        run_prop(150, |g| {
            let max_batch = g.usize_in(1, 6);
            let wait_ms = g.usize_in(0, 10) as u64;
            let max_wait = Duration::from_millis(wait_ms);
            let mut b: Batcher<usize> = Batcher::new(BatchPolicy { max_batch, max_wait });
            let t0 = Instant::now();

            // Empty queue: wait with no deadline hint, at any time.
            assert_eq!(b.poll(t0), Flush::Wait(None));
            assert_eq!(b.poll(t0 + Duration::from_secs(60)), Flush::Wait(None));

            // Enqueue with monotone arrival times.
            let qlen = g.usize_in(1, 12);
            let mut now = t0;
            let mut first_enq = None;
            for i in 0..qlen {
                now += Duration::from_millis(g.usize_in(0, 3) as u64);
                b.push(i, now);
                first_enq.get_or_insert(now);
            }
            let first_enq = first_enq.unwrap();

            if qlen >= max_batch {
                // Demand flush wins regardless of time.
                assert_eq!(b.poll(first_enq), Flush::Take(max_batch));
            } else {
                // Exactly at the deadline: flush whatever is queued.
                assert_eq!(b.poll(first_enq + max_wait), Flush::Take(qlen));
                // Past the deadline too.
                let late = first_enq + max_wait + Duration::from_millis(1);
                assert_eq!(b.poll(late), Flush::Take(qlen));
                if wait_ms > 0 {
                    // Just before: bounded wait hint, never a flush.
                    let just_before = first_enq + max_wait - Duration::from_millis(1);
                    match b.poll(just_before) {
                        Flush::Wait(Some(hint)) => assert!(hint <= Duration::from_millis(1)),
                        other => panic!("expected bounded wait before deadline, got {other:?}"),
                    }
                }
            }
        });
    }

    #[test]
    fn prop_bounded_admission_conserves_requests() {
        use crate::util::prop::run_prop;
        // Simulates serve_workload's queue_cap backpressure: at most queue_cap
        // requests may sit in the batcher; everything admitted must be
        // emitted exactly once, in FIFO order, with pad slots accounted.
        run_prop(150, |g| {
            let max_batch = g.usize_in(1, 5);
            let max_wait = Duration::from_millis(g.usize_in(0, 4) as u64);
            let queue_cap = g.usize_in(1, 8);
            let total = g.usize_in(1, 40);
            let mut b: Batcher<usize> = Batcher::new(BatchPolicy { max_batch, max_wait });
            let mut now = Instant::now();
            let (mut admitted, mut rejected) = (0usize, 0usize);
            let mut emitted: Vec<usize> = Vec::new();

            for i in 0..total {
                now += Duration::from_millis(g.usize_in(0, 2) as u64);
                if b.len() >= queue_cap {
                    rejected += 1;
                } else {
                    b.push(i, now);
                    admitted += 1;
                }
                assert!(b.len() <= queue_cap, "backpressure bound violated");
                if let Flush::Take(k) = b.poll(now) {
                    assert!(k >= 1 && k == b.len().min(max_batch), "bad take size {k}");
                    emitted.extend(b.take(k).into_iter().map(|p| p.payload));
                }
            }
            // Drain: once time passes the deadline a non-empty queue must
            // always flush (never deadlock on Wait).
            while !b.is_empty() {
                now += max_wait + Duration::from_millis(1);
                match b.poll(now) {
                    Flush::Take(k) => emitted.extend(b.take(k).into_iter().map(|p| p.payload)),
                    Flush::Wait(_) => panic!("non-empty batcher refused to flush past deadline"),
                }
            }

            assert_eq!(admitted + rejected, total);
            assert_eq!(emitted.len(), admitted, "requests lost or duplicated");
            assert!(emitted.windows(2).all(|w| w[0] < w[1]), "FIFO order violated");
            assert_eq!(b.items_emitted as usize, admitted);
            let frac = b.pad_fraction();
            assert!((0.0..1.0).contains(&frac) || b.batches_emitted == 0);
            assert_eq!(
                b.items_emitted + b.padded_slots,
                b.batches_emitted * max_batch as u64,
                "pad accounting must cover every executed slot"
            );
        });
    }

    #[test]
    fn overfull_queue_emits_max_batch_only() {
        let mut b = Batcher::new(policy(2, 5));
        let now = Instant::now();
        for i in 0..5 {
            b.push(i, now);
        }
        assert_eq!(b.poll(now), Flush::Take(2));
        let taken = b.take(2);
        assert_eq!(taken.len(), 2);
        assert_eq!(b.len(), 3);
        // Still flushable right away.
        assert_eq!(b.poll(now), Flush::Take(2));
    }
}
