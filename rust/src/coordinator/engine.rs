//! Engine thread: single-threaded owner of the PJRT [`Runtime`].
//!
//! PJRT handles are not `Send`, so the runtime lives on one dedicated OS
//! thread; the frontend talks to it over an mpsc channel (std threads —
//! the vendored crate set has no tokio). This is the same frontend/engine
//! split as vLLM's router → engine core.
//!
//! Model parameters are *bound* once inside the engine (from an init
//! artifact or a checkpoint) and referenced by key on each request, so the
//! hot path converts only the batch tensor — never the weights.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::runtime::{Runtime, Tensor};

/// Requests served by the engine thread.
pub enum EngineRequest {
    /// Execute `artifact` on `inputs`, optionally prefixed by a parameter
    /// binding created earlier.
    Run {
        artifact: String,
        binding: Option<String>,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    /// Create a binding by running a bundle's `init` artifact and keeping
    /// its first `param_count` outputs (the parameters).
    BindInit {
        key: String,
        init_artifact: String,
        seed: i32,
        param_count: usize,
        reply: mpsc::Sender<Result<()>>,
    },
    /// Create a binding from host tensors (e.g. a loaded checkpoint).
    BindTensors { key: String, params: Vec<Tensor>, reply: mpsc::Sender<Result<()>> },
    /// Stop the engine loop (makes `shutdown` safe even while other
    /// EngineHandle clones are still alive).
    Shutdown,
}

/// Handle for submitting jobs; cloneable across threads.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<EngineRequest>,
}

impl EngineHandle {
    fn submit<T>(&self, req: EngineRequest, rx: mpsc::Receiver<Result<T>>) -> Result<T> {
        self.tx.send(req).map_err(|_| anyhow::anyhow!("engine thread terminated"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))?
    }

    /// Execute an artifact and block for the result.
    pub fn run(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.submit(
            EngineRequest::Run { artifact: artifact.into(), binding: None, inputs, reply },
            rx,
        )
    }

    /// Execute an artifact with a parameter binding prefix.
    pub fn run_bound(
        &self,
        artifact: &str,
        binding: &str,
        inputs: Vec<Tensor>,
    ) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.submit(
            EngineRequest::Run {
                artifact: artifact.into(),
                binding: Some(binding.into()),
                inputs,
                reply,
            },
            rx,
        )
    }

    /// Bind parameters by running an init artifact inside the engine.
    pub fn bind_init(
        &self,
        key: &str,
        init_artifact: &str,
        seed: i32,
        param_count: usize,
    ) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.submit(
            EngineRequest::BindInit {
                key: key.into(),
                init_artifact: init_artifact.into(),
                seed,
                param_count,
                reply,
            },
            rx,
        )
    }

    /// Bind parameters from host tensors (checkpoint weights).
    pub fn bind_tensors(&self, key: &str, params: Vec<Tensor>) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.submit(EngineRequest::BindTensors { key: key.into(), params, reply }, rx)
    }
}

/// The running engine (join handle + submission side).
pub struct Engine {
    handle: EngineHandle,
    join: Option<JoinHandle<()>>,
}

impl Engine {
    /// Spawn the engine thread. `warmup` artifacts are compiled before any
    /// job is served (keeps compiles off the latency path).
    pub fn spawn(artifacts_dir: std::path::PathBuf, warmup: Vec<String>) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<EngineRequest>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let join = std::thread::Builder::new()
            .name("mita-engine".into())
            .spawn(move || {
                let runtime = match Runtime::load(&artifacts_dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for art in &warmup {
                    if let Err(e) = runtime.warmup(art) {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));

                let mut bindings: HashMap<String, Vec<xla::Literal>> = HashMap::new();
                while let Ok(req) = rx.recv() {
                    match req {
                        EngineRequest::Shutdown => break,
                        EngineRequest::Run { artifact, binding, inputs, reply } => {
                            let result = (|| -> Result<Vec<Tensor>> {
                                let outs = match binding {
                                    None => {
                                        return runtime.run(&artifact, &inputs);
                                    }
                                    Some(key) => {
                                        let params = bindings
                                            .get(&key)
                                            .with_context(|| format!("no binding {key:?}"))?;
                                        runtime.run_hybrid(&artifact, params, &inputs)?
                                    }
                                };
                                outs.iter().map(Tensor::from_literal).collect()
                            })();
                            let _ = reply.send(result);
                        }
                        EngineRequest::BindInit { key, init_artifact, seed, param_count, reply } => {
                            let result = (|| -> Result<()> {
                                let seed_lit = Tensor::scalar_i32(seed).to_literal()?;
                                let mut state =
                                    runtime.run_literals(&init_artifact, &[seed_lit])?;
                                anyhow::ensure!(
                                    state.len() >= param_count,
                                    "init returned {} < {param_count} outputs",
                                    state.len()
                                );
                                state.truncate(param_count);
                                bindings.insert(key, state);
                                Ok(())
                            })();
                            let _ = reply.send(result);
                        }
                        EngineRequest::BindTensors { key, params, reply } => {
                            let result = (|| -> Result<()> {
                                let lits: Vec<xla::Literal> = params
                                    .iter()
                                    .map(Tensor::to_literal)
                                    .collect::<Result<_>>()?;
                                bindings.insert(key, lits);
                                Ok(())
                            })();
                            let _ = reply.send(result);
                        }
                    }
                }
            })?;

        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        Ok(Engine { handle: EngineHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Shut down: signal the loop to stop and join the thread. Safe even
    /// while other EngineHandle clones are alive (their later submissions
    /// fail with "engine thread terminated").
    pub fn shutdown(mut self) {
        let _ = self.handle.tx.send(EngineRequest::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if let Some(j) = self.join.take() {
            let _ = self.handle.tx.send(EngineRequest::Shutdown);
            let _ = j.join();
        }
    }
}
