//! Engine thread: single-threaded owner of an execution [`Backend`],
//! driven by **submit/poll tickets** instead of blocking request/reply.
//!
//! PJRT handles are not `Send`, so the backend is *constructed inside*
//! one dedicated OS thread from a [`BackendSpec`]; frontends talk to it
//! over an mpsc channel (std threads — the vendored crate set has no
//! tokio). This is the same frontend/engine split as vLLM's router →
//! engine core, now with a typed, pipelined submission surface:
//!
//! - [`EngineHandle::submit`] enqueues a [`ServiceRequest`] and returns a
//!   [`Ticket`] immediately — the caller keeps batching, generating, or
//!   serving other clients while the engine executes.
//! - [`Ticket::wait`] / [`Ticket::try_wait`] collect that request's
//!   result. Each ticket carries a correlation id and its own completion
//!   channel, so any number of requests can be in flight per handle and
//!   results can be collected **out of submission order** — no caller
//!   thread is parked per request.
//!
//! Parameter bindings live inside the backend (bound once, referenced by
//! [`BindingId`] on each request), so the hot path converts only the
//! batch tensors — never the weights.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::kernels::api::BlockProfile;
use crate::runtime::{BackendSpec, Tensor};
use crate::service::{
    BindingId, KernelId, QkvBatch, ServiceError, ServiceRequest, ServiceResponse, ServiceResult,
    StepEvent,
};

/// Combined backend counters returned by [`EngineHandle::backend_stats`]
/// (the engine-side name of [`crate::service::ServiceStats`]).
pub type EngineStats = crate::service::ServiceStats;

/// Execution-side profile of one job, measured by the engine thread —
/// the only place that brackets `Backend::execute` — and carried back on
/// the ticket's reply channel alongside the result. Observation-only:
/// nothing about scheduling or execution reads it.
#[derive(Debug, Clone, Default)]
pub struct ExecProfile {
    /// Wall time spent inside `Backend::execute`, nanoseconds.
    pub execute_ns: u64,
    /// Of `execute_ns`, the wall time spent inside the decode loop (0 for
    /// anything but a generate request). Lets traces split prefill from
    /// token-by-token decoding.
    pub decode_ns: u64,
    /// Per-transformer-block profile of a model forward (empty for other
    /// request classes and for backends without per-block recording).
    pub blocks: Vec<BlockProfile>,
}

/// What travels back over a ticket's reply channel.
type Reply = (ServiceResult<ServiceResponse>, ExecProfile);

enum EngineMsg {
    /// Execute one typed request; the result travels back over the
    /// ticket's dedicated channel (the correlation id stays caller-side,
    /// on the [`Ticket`] — the engine has no use for it). When `steps` is
    /// present, per-token [`StepEvent`]s of a generate request stream
    /// over it while the job runs (the channel closes with the job).
    Job {
        req: ServiceRequest,
        reply: mpsc::Sender<Reply>,
        steps: Option<mpsc::Sender<StepEvent>>,
    },
    /// Stop the engine loop (makes `shutdown` safe even while other
    /// EngineHandle clones are still alive).
    Shutdown,
}

/// An in-flight engine request: a correlation id plus the completion
/// channel. Obtained from [`EngineHandle::submit`]; redeem with
/// [`Ticket::wait`] (blocking) or [`Ticket::try_wait`] (polling).
pub struct Ticket {
    id: u64,
    rx: mpsc::Receiver<Reply>,
}

impl Ticket {
    /// The correlation id (unique per engine handle family; useful for
    /// logs and for matching completions to submissions).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until this request completes.
    pub fn wait(self) -> ServiceResult<ServiceResponse> {
        self.wait_profiled().0
    }

    /// Block until this request completes, returning the engine-side
    /// [`ExecProfile`] alongside the result (the trace path's entry
    /// point; [`Ticket::wait`] discards the profile).
    pub fn wait_profiled(self) -> (ServiceResult<ServiceResponse>, ExecProfile) {
        match self.rx.recv() {
            Ok(reply) => reply,
            Err(_) => (
                Err(ServiceError::Internal(format!(
                    "engine dropped reply for ticket {}",
                    self.id
                ))),
                ExecProfile::default(),
            ),
        }
    }

    /// Non-blocking completion check. Returns `None` while the request is
    /// still executing; once it returns `Some`, the result has been taken
    /// and later calls report an internal error.
    pub fn try_wait(&mut self) -> Option<ServiceResult<ServiceResponse>> {
        self.try_wait_profiled().map(|(result, _)| result)
    }

    /// Polling variant of [`Ticket::wait_profiled`].
    pub fn try_wait_profiled(&mut self) -> Option<(ServiceResult<ServiceResponse>, ExecProfile)> {
        match self.rx.try_recv() {
            Ok(reply) => Some(reply),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some((
                Err(ServiceError::Internal(format!(
                    "engine dropped reply for ticket {}",
                    self.id
                ))),
                ExecProfile::default(),
            )),
        }
    }
}

/// Handle for submitting jobs; cloneable across threads. Clones share one
/// correlation-id sequence.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<EngineMsg>,
    next_id: Arc<AtomicU64>,
}

impl EngineHandle {
    /// Enqueue a request and return its [`Ticket`] without blocking on
    /// execution. Fails only if the engine thread is gone.
    pub fn submit(&self, req: ServiceRequest) -> ServiceResult<Ticket> {
        self.submit_with_steps(req, None)
    }

    /// Like [`EngineHandle::submit`], but generate requests stream one
    /// [`StepEvent`] per decoded token over `steps` while executing. The
    /// sender is dropped when the job finishes, so a receiver loop ends
    /// cleanly before [`Ticket::wait`] returns.
    pub fn submit_streaming(
        &self,
        req: ServiceRequest,
        steps: mpsc::Sender<StepEvent>,
    ) -> ServiceResult<Ticket> {
        self.submit_with_steps(req, Some(steps))
    }

    fn submit_with_steps(
        &self,
        req: ServiceRequest,
        steps: Option<mpsc::Sender<StepEvent>>,
    ) -> ServiceResult<Ticket> {
        self.submit_recoverable(req, steps).map_err(|(e, _, _)| e)
    }

    /// Like [`EngineHandle::submit_streaming`], but a failed submission
    /// hands the request (and step channel) back to the caller alongside
    /// the typed error — an mpsc send failure returns the unsent message,
    /// so a routing layer can retry the same request on another replica
    /// instead of failing it.
    pub fn submit_recoverable(
        &self,
        req: ServiceRequest,
        steps: Option<mpsc::Sender<StepEvent>>,
    ) -> Result<Ticket, (ServiceError, ServiceRequest, Option<mpsc::Sender<StepEvent>>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        match self.tx.send(EngineMsg::Job { req, reply, steps }) {
            Ok(()) => Ok(Ticket { id, rx }),
            Err(mpsc::SendError(msg)) => {
                let err = ServiceError::Unavailable("engine thread terminated".into());
                match msg {
                    EngineMsg::Job { req, steps, .. } => Err((err, req, steps)),
                    // The send error wraps exactly the Job sent above.
                    EngineMsg::Shutdown => unreachable!("submit sends only Job messages"),
                }
            }
        }
    }

    /// Submit and block for the result (the one-shot convenience).
    pub fn call(&self, req: ServiceRequest) -> ServiceResult<ServiceResponse> {
        self.submit(req)?.wait()
    }

    /// Stop the engine loop **without** joining its thread — the
    /// fault-injection twin of [`Engine::shutdown`]. Later submissions
    /// through any handle clone fail with `unavailable`, which is
    /// exactly the replica-fault signal the pool's health machinery
    /// classifies. Spins until the loop drops its receiver (a queued
    /// shutdown alone would let a racing submit enqueue behind it and
    /// die with a dropped reply instead of failing recoverably), so on
    /// return every subsequent submit fails immediately; jobs queued
    /// before the first shutdown message still complete.
    pub fn terminate(&self) {
        while self.tx.send(EngineMsg::Shutdown).is_ok() {
            std::thread::yield_now();
        }
    }

    /// Typed attention round-trip: `[b, n, dim]` output.
    pub fn attention(
        &self,
        op: KernelId,
        qkv: QkvBatch,
        valid_rows: Option<usize>,
    ) -> ServiceResult<Tensor> {
        self.call(ServiceRequest::Attention { op, qkv, valid_rows })?.into_tensor()
    }

    /// Typed model-forward round-trip: `[b, classes]` logits.
    pub fn model_forward(
        &self,
        binding: &str,
        tokens: Tensor,
        valid_rows: Option<usize>,
    ) -> ServiceResult<Tensor> {
        self.call(ServiceRequest::ModelForward {
            binding: BindingId::from(binding),
            tokens,
            valid_rows,
        })?
        .into_tensor()
    }

    /// Execute a compiled artifact (PJRT backend), optionally against a
    /// parameter binding.
    pub fn run_artifact(
        &self,
        artifact: &str,
        binding: Option<&str>,
        inputs: Vec<Tensor>,
    ) -> ServiceResult<Vec<Tensor>> {
        let resp = self.call(ServiceRequest::Artifact {
            artifact: artifact.to_string(),
            binding: binding.map(BindingId::from),
            inputs,
        })?;
        Ok(resp.into_tensors())
    }

    /// Bind parameters by seeded init inside the engine (`init_op` is
    /// `model.init` natively, an init artifact name on PJRT).
    pub fn bind_init(
        &self,
        key: &str,
        init_op: &str,
        seed: i32,
        param_count: usize,
    ) -> ServiceResult<()> {
        self.call(ServiceRequest::BindInit {
            binding: BindingId::from(key),
            init_op: init_op.to_string(),
            seed,
            param_count,
        })?;
        Ok(())
    }

    /// Bind parameters from host tensors (checkpoint weights).
    pub fn bind_tensors(&self, key: &str, params: Vec<Tensor>) -> ServiceResult<()> {
        self.call(ServiceRequest::BindCheckpoint { binding: BindingId::from(key), params })?;
        Ok(())
    }

    /// Snapshot the backend's execution counters and (for the native
    /// backend) accumulated MiTA routing statistics.
    pub fn backend_stats(&self) -> ServiceResult<EngineStats> {
        self.call(ServiceRequest::Stats { reset: false })?.into_stats()
    }

    /// Like [`EngineHandle::backend_stats`], but clears the routing
    /// accumulator after the snapshot — the serving loop brackets a run
    /// with two of these so its report covers exactly that run (peaks
    /// like the load-imbalance maximum cannot be deltaed out of a
    /// cumulative snapshot).
    pub fn take_backend_stats(&self) -> ServiceResult<EngineStats> {
        self.call(ServiceRequest::Stats { reset: true })?.into_stats()
    }
}

/// The running engine (join handle + submission side).
pub struct Engine {
    handle: EngineHandle,
    join: Option<JoinHandle<()>>,
}

impl Engine {
    /// Spawn an engine over the PJRT artifact backend (back-compat entry
    /// point; equivalent to `spawn_backend(BackendSpec::Pjrt { .. }, ..)`).
    pub fn spawn(artifacts_dir: std::path::PathBuf, warmup: Vec<String>) -> Result<Self> {
        Self::spawn_backend(BackendSpec::Pjrt { artifacts_dir }, warmup)
    }

    /// Spawn the engine thread over any backend. `warmup` ops are prepared
    /// before any job is served (keeps compiles off the latency path).
    pub fn spawn_backend(spec: BackendSpec, warmup: Vec<String>) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<EngineMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let join = std::thread::Builder::new()
            .name("mita-engine".into())
            .spawn(move || {
                let mut backend = match spec.create() {
                    Ok(b) => b,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for op in &warmup {
                    if let Err(e) = backend.warmup(op) {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));

                while let Ok(msg) = rx.recv() {
                    match msg {
                        EngineMsg::Shutdown => break,
                        EngineMsg::Job { req, reply, steps } => {
                            // Panic isolation: the engine serves untrusted
                            // network input through the netserver front; a
                            // panicking backend must surface as a typed
                            // internal error on that one ticket, not kill
                            // the singleton engine thread for every future
                            // request. (Backend scratch is RefCell-based
                            // with no poisoning; borrows release on
                            // unwind, so the backend stays usable.)
                            let t0 = Instant::now();
                            let result = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| match &steps {
                                    // A dropped step receiver means the
                                    // caller stopped listening; decoding
                                    // still completes for the ticket.
                                    Some(tx) => backend.execute_streaming(req, &mut |ev| {
                                        let _ = tx.send(ev);
                                    }),
                                    None => backend.execute(req),
                                }),
                            )
                            .unwrap_or_else(|panic| {
                                let msg = panic
                                    .downcast_ref::<&str>()
                                    .map(|s| (*s).to_string())
                                    .or_else(|| panic.downcast_ref::<String>().cloned())
                                    .unwrap_or_else(|| "non-string panic payload".into());
                                crate::coordinator::log::emit(
                                    crate::coordinator::log::Level::Error,
                                    "engine.panic",
                                    None,
                                    format!("backend panicked: {msg}"),
                                );
                                Err(ServiceError::Internal(format!("backend panicked: {msg}")))
                            });
                            // Drain the per-block profile after every job
                            // (a failed execute may leave a partial one
                            // behind — draining keeps it from leaking into
                            // the next request's trace) but attach it only
                            // to the job that produced it successfully.
                            let blocks = backend.take_block_profiles();
                            let decode_ns = backend.take_decode_ns();
                            let profile = ExecProfile {
                                execute_ns: t0.elapsed().as_nanos() as u64,
                                decode_ns: if result.is_ok() { decode_ns } else { 0 },
                                blocks: if result.is_ok() { blocks } else { Vec::new() },
                            };
                            // Close the step channel before the reply so a
                            // streaming caller's receive loop always ends
                            // ahead of the ticket completing.
                            drop(steps);
                            // A dropped reply receiver just means the
                            // caller stopped caring about this ticket.
                            let _ = reply.send((result, profile));
                        }
                    }
                }
            })?;

        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        Ok(Engine {
            handle: EngineHandle { tx, next_id: Arc::new(AtomicU64::new(0)) },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Shut down: signal the loop to stop and join the thread. Safe even
    /// while other EngineHandle clones are alive (their later submissions
    /// fail with "engine thread terminated").
    pub fn shutdown(mut self) {
        let _ = self.handle.tx.send(EngineMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if let Some(j) = self.join.take() {
            let _ = self.handle.tx.send(EngineMsg::Shutdown);
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::runtime::NativeAttnConfig;

    fn fused_batch(n: usize, dim: usize, seed: u64) -> QkvBatch {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..3 * n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        QkvBatch::fused(Tensor::f32(&[1, 3, n, dim], data).unwrap()).unwrap()
    }

    #[test]
    fn tickets_complete_out_of_submission_order() {
        let attn = NativeAttnConfig::for_shape(16, 8, 2);
        let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![]).unwrap();
        let handle = engine.handle();

        // Submit a pipeline of requests without waiting on any of them.
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| {
                handle
                    .submit(ServiceRequest::Attention {
                        op: KernelId::Mita,
                        qkv: fused_batch(16, 8, i),
                        valid_rows: None,
                    })
                    .unwrap()
            })
            .collect();
        let ids: Vec<u64> = tickets.iter().map(Ticket::id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "correlation ids are sequential");

        // Redeem in reverse order — completions are per-ticket, so the
        // collection order is the caller's choice.
        for t in tickets.into_iter().rev() {
            let out = t.wait().unwrap().into_tensor().unwrap();
            assert_eq!(out.shape(), &[1, 16, 8]);
        }

        // try_wait polls without blocking.
        let mut t = handle
            .submit(ServiceRequest::Attention {
                op: KernelId::Dense,
                qkv: fused_batch(16, 8, 9),
                valid_rows: None,
            })
            .unwrap();
        let result = loop {
            match t.try_wait() {
                Some(r) => break r,
                None => std::thread::yield_now(),
            }
        };
        assert_eq!(result.unwrap().into_tensor().unwrap().shape(), &[1, 16, 8]);
        engine.shutdown();
    }

    #[test]
    fn profiled_wait_carries_execute_time_and_model_blocks() {
        use crate::kernels::OP_ATTN_MITA;
        use crate::model::{ModelConfig, OP_MODEL_INIT};

        let mcfg = ModelConfig::new(7, 12, 8, 2, 2, 16, 3, OP_ATTN_MITA);
        let attn = NativeAttnConfig::for_shape(12, 8, 2).with_model(mcfg.clone());
        let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![]).unwrap();
        let handle = engine.handle();

        // Attention: non-zero execute time, no per-block profile.
        let t = handle
            .submit(ServiceRequest::Attention {
                op: KernelId::Mita,
                qkv: fused_batch(12, 8, 1),
                valid_rows: None,
            })
            .unwrap();
        let (result, prof) = t.wait_profiled();
        result.unwrap();
        assert!(prof.execute_ns > 0, "engine brackets every execute");
        assert!(prof.blocks.is_empty(), "attention requests carry no block profile");

        // Model forward: one BlockProfile per block rides the reply.
        handle.bind_init("m", OP_MODEL_INIT, 3, 0).unwrap();
        let mut rng = Rng::new(5);
        let toks: Vec<i32> = (0..12).map(|_| rng.below(7) as i32).collect();
        let t = handle
            .submit(ServiceRequest::ModelForward {
                binding: BindingId::from("m"),
                tokens: Tensor::i32(&[1, 12], toks).unwrap(),
                valid_rows: None,
            })
            .unwrap();
        let (result, prof) = t.wait_profiled();
        result.unwrap();
        assert_eq!(prof.blocks.len(), mcfg.depth);
        assert!(prof.blocks.iter().all(|b| b.stats.queries > 0 && b.attn_ns > 0));
        assert!(
            prof.execute_ns >= prof.blocks.iter().map(|b| b.attn_ns + b.mlp_ns).sum::<u64>(),
            "execute wall time bounds the per-block spans"
        );
        engine.shutdown();
    }

    #[test]
    fn streaming_submission_delivers_steps_before_completion() {
        use crate::kernels::OP_ATTN_MITA;
        use crate::model::{ModelConfig, OP_MODEL_INIT};
        use crate::service::GenerateParams;

        let mcfg = ModelConfig::new(7, 16, 8, 2, 1, 16, 3, OP_ATTN_MITA);
        let attn = NativeAttnConfig::for_shape(16, 8, 2).with_model(mcfg);
        let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![]).unwrap();
        let handle = engine.handle();
        handle.bind_init("m", OP_MODEL_INIT, 3, 0).unwrap();

        let (tx, rx) = mpsc::channel();
        let t = handle
            .submit_streaming(
                ServiceRequest::Generate {
                    binding: BindingId::from("m"),
                    prompt: Tensor::i32(&[3], vec![1, 2, 3]).unwrap(),
                    max_tokens: 5,
                    params: GenerateParams::default(),
                },
                tx,
            )
            .unwrap();
        // The step channel closes before the ticket completes, so this
        // drain never deadlocks against wait_profiled below.
        let events: Vec<StepEvent> = rx.iter().collect();
        let (result, prof) = t.wait_profiled();
        let tokens = match result.unwrap() {
            ServiceResponse::Generate { tokens, prefill_tokens } => {
                assert_eq!(prefill_tokens, 3);
                tokens
            }
            other => panic!("wrong class {:?}", other.kind()),
        };
        assert_eq!(events.len(), 5, "one event per emitted token");
        let streamed: Vec<i32> = events.iter().map(|e| e.token).collect();
        assert_eq!(streamed, tokens.as_i32().unwrap());
        assert!(
            prof.decode_ns > 0 && prof.decode_ns <= prof.execute_ns,
            "decode time is a sub-span of execute time"
        );

        // Non-generate jobs down the streaming path emit nothing, close
        // the channel, and report zero decode time.
        let (tx, rx) = mpsc::channel();
        let t = handle.submit_streaming(ServiceRequest::Stats { reset: false }, tx).unwrap();
        assert!(rx.iter().next().is_none(), "stats jobs stream no steps");
        let (result, prof) = t.wait_profiled();
        result.unwrap();
        assert_eq!(prof.decode_ns, 0);
        engine.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_unavailable() {
        let attn = NativeAttnConfig::for_shape(8, 4, 1);
        let engine = Engine::spawn_backend(BackendSpec::Native(attn), vec![]).unwrap();
        let handle = engine.handle();
        engine.shutdown();
        let err = handle
            .submit(ServiceRequest::Stats { reset: false })
            .map(|_| ())
            .unwrap_err();
        assert_eq!(err.code(), "unavailable");
    }
}
