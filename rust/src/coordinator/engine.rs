//! Engine thread: single-threaded owner of an execution [`Backend`].
//!
//! PJRT handles are not `Send`, so the backend is *constructed inside* one
//! dedicated OS thread from a [`BackendSpec`]; the frontend talks to it
//! over an mpsc channel (std threads — the vendored crate set has no
//! tokio). This is the same frontend/engine split as vLLM's router →
//! engine core, now backend-agnostic: the same loop drives PJRT artifacts
//! (`Engine::spawn`) or the native CPU attention kernels
//! (`Engine::spawn_backend` with [`BackendSpec::Native`]).
//!
//! Parameter bindings live inside the backend (bound once, referenced by
//! key on each request), so the hot path converts only the batch tensor —
//! never the weights.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::kernels::MitaStats;
use crate::runtime::{BackendSpec, RuntimeStats, Tensor};

/// Combined backend counters returned by [`EngineHandle::backend_stats`].
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Compile/execute counters.
    pub runtime: RuntimeStats,
    /// Native MiTA routing statistics, when the backend runs those
    /// kernels (None on artifact backends).
    pub mita: Option<MitaStats>,
}

/// Requests served by the engine thread.
pub enum EngineRequest {
    /// Execute `artifact` (op name) on `inputs`, optionally prefixed by a
    /// parameter binding created earlier.
    Run {
        artifact: String,
        binding: Option<String>,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    /// Create a binding by running a bundle's `init` artifact and keeping
    /// its first `param_count` outputs (the parameters).
    BindInit {
        key: String,
        init_artifact: String,
        seed: i32,
        param_count: usize,
        reply: mpsc::Sender<Result<()>>,
    },
    /// Create a binding from host tensors (e.g. a loaded checkpoint).
    BindTensors { key: String, params: Vec<Tensor>, reply: mpsc::Sender<Result<()>> },
    /// Snapshot the backend's execution + routing counters. With `reset`,
    /// the routing accumulator is cleared after the snapshot, so
    /// successive resetting reads partition the stats into disjoint
    /// per-interval reports.
    Stats { reset: bool, reply: mpsc::Sender<Result<EngineStats>> },
    /// Stop the engine loop (makes `shutdown` safe even while other
    /// EngineHandle clones are still alive).
    Shutdown,
}

/// Handle for submitting jobs; cloneable across threads.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<EngineRequest>,
}

impl EngineHandle {
    fn submit<T>(&self, req: EngineRequest, rx: mpsc::Receiver<Result<T>>) -> Result<T> {
        self.tx.send(req).map_err(|_| anyhow::anyhow!("engine thread terminated"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine dropped reply"))?
    }

    /// Execute an op and block for the result.
    pub fn run(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.submit(
            EngineRequest::Run { artifact: artifact.into(), binding: None, inputs, reply },
            rx,
        )
    }

    /// Execute an op with a parameter binding prefix.
    pub fn run_bound(
        &self,
        artifact: &str,
        binding: &str,
        inputs: Vec<Tensor>,
    ) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.submit(
            EngineRequest::Run {
                artifact: artifact.into(),
                binding: Some(binding.into()),
                inputs,
                reply,
            },
            rx,
        )
    }

    /// Bind parameters by running an init artifact inside the engine.
    pub fn bind_init(
        &self,
        key: &str,
        init_artifact: &str,
        seed: i32,
        param_count: usize,
    ) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.submit(
            EngineRequest::BindInit {
                key: key.into(),
                init_artifact: init_artifact.into(),
                seed,
                param_count,
                reply,
            },
            rx,
        )
    }

    /// Bind parameters from host tensors (checkpoint weights).
    pub fn bind_tensors(&self, key: &str, params: Vec<Tensor>) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.submit(EngineRequest::BindTensors { key: key.into(), params, reply }, rx)
    }

    /// Snapshot the backend's execution counters and (for the native
    /// backend) accumulated MiTA routing statistics.
    pub fn backend_stats(&self) -> Result<EngineStats> {
        let (reply, rx) = mpsc::channel();
        self.submit(EngineRequest::Stats { reset: false, reply }, rx)
    }

    /// Like [`EngineHandle::backend_stats`], but clears the routing
    /// accumulator after the snapshot — the serving loop brackets a run
    /// with two of these so its report covers exactly that run (peaks
    /// like the load-imbalance maximum cannot be deltaed out of a
    /// cumulative snapshot).
    pub fn take_backend_stats(&self) -> Result<EngineStats> {
        let (reply, rx) = mpsc::channel();
        self.submit(EngineRequest::Stats { reset: true, reply }, rx)
    }
}

/// The running engine (join handle + submission side).
pub struct Engine {
    handle: EngineHandle,
    join: Option<JoinHandle<()>>,
}

impl Engine {
    /// Spawn an engine over the PJRT artifact backend (back-compat entry
    /// point; equivalent to `spawn_backend(BackendSpec::Pjrt { .. }, ..)`).
    pub fn spawn(artifacts_dir: std::path::PathBuf, warmup: Vec<String>) -> Result<Self> {
        Self::spawn_backend(BackendSpec::Pjrt { artifacts_dir }, warmup)
    }

    /// Spawn the engine thread over any backend. `warmup` ops are prepared
    /// before any job is served (keeps compiles off the latency path).
    pub fn spawn_backend(spec: BackendSpec, warmup: Vec<String>) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<EngineRequest>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let join = std::thread::Builder::new()
            .name("mita-engine".into())
            .spawn(move || {
                let mut backend = match spec.create() {
                    Ok(b) => b,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for op in &warmup {
                    if let Err(e) = backend.warmup(op) {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));

                while let Ok(req) = rx.recv() {
                    match req {
                        EngineRequest::Shutdown => break,
                        EngineRequest::Run { artifact, binding, inputs, reply } => {
                            let result = backend.run(&artifact, binding.as_deref(), &inputs);
                            let _ = reply.send(result);
                        }
                        EngineRequest::BindInit {
                            key,
                            init_artifact,
                            seed,
                            param_count,
                            reply,
                        } => {
                            let result =
                                backend.bind_init(&key, &init_artifact, seed, param_count);
                            let _ = reply.send(result);
                        }
                        EngineRequest::BindTensors { key, params, reply } => {
                            let _ = reply.send(backend.bind_tensors(&key, params));
                        }
                        EngineRequest::Stats { reset, reply } => {
                            let mita = if reset {
                                backend.take_mita_stats()
                            } else {
                                backend.mita_stats()
                            };
                            let stats = EngineStats { runtime: backend.stats(), mita };
                            let _ = reply.send(Ok(stats));
                        }
                    }
                }
            })?;

        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        Ok(Engine { handle: EngineHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Shut down: signal the loop to stop and join the thread. Safe even
    /// while other EngineHandle clones are alive (their later submissions
    /// fail with "engine thread terminated").
    pub fn shutdown(mut self) {
        let _ = self.handle.tx.send(EngineRequest::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if let Some(j) = self.join.take() {
            let _ = self.handle.tx.send(EngineRequest::Shutdown);
            let _ = j.join();
        }
    }
}
