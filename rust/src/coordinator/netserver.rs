//! Network serving front: a std::net TCP loop speaking minimal HTTP/1.1
//! + JSON over the typed service API.
//!
//! Wire requests parse **once** at this boundary into
//! [`ServiceRequest`]s (see [`crate::service::wire`] and
//! `docs/PROTOCOL.md`) and route through a [`ReplicaPool`] — N engine
//! replicas behind least-outstanding routing; every failure is a
//! [`ServiceError`] whose stable code becomes the HTTP status + JSON
//! error body. Admission control is layered: a transport in-flight cap
//! acquired **after the headers but before the body** (past
//! [`NetServerConfig::max_inflight`] concurrent requests, new work is
//! rejected with `503 overloaded` before its body is even buffered, so
//! the cap bounds request memory), and the pool's per-replica caps
//! behind it. Both shed with a `retry_after_ms` hint derived from
//! observed latency. `GET /v1/metrics` bypasses admission so telemetry
//! stays readable under load.
//!
//! One OS thread per **connection** (not per request), with a hard
//! connection cap: connections are keep-alive, so a client pipelining
//! many requests costs one thread, and the engine round-trip itself
//! never parks more than that thread. [`NetClient`] is the matching
//! loopback client used by the CLI, the tests, and the CI smoke step;
//! [`NetClient::with_retries`] adds bounded jittered retries that honor
//! the server's `retry_after_ms` hint.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::log::{self, Level};
use crate::coordinator::metrics::{render_prometheus, MetricsSnapshot};
use crate::coordinator::replica::ReplicaPool;
use crate::coordinator::trace::{next_trace_id, TraceStart};
use crate::data::rng::splitmix64;
use crate::service::wire::{
    self, EP_GENERATE, EP_HEALTH, EP_LOGS, EP_METRICS, EP_PROFILE, EP_READYZ, EP_SHUTDOWN,
    EP_TRACE,
};
use crate::service::{ServiceError, ServiceRequest, ServiceResponse, ServiceResult, StepEvent};
use crate::util::json::Value;

/// Largest accepted request body (tensors are JSON, so generous). The
/// body is streamed, never allocated upfront from the declared length.
const MAX_BODY_BYTES: usize = 64 << 20;
/// Cap on the request line + headers of one request.
const MAX_HEADER_BYTES: u64 = 64 * 1024;
/// Body cap for server-local endpoints (health/shutdown/unknown) — they
/// never need one, so a large declared body there is a smuggling attempt.
const MAX_LOCAL_BODY_BYTES: usize = 4 * 1024;
/// Hard cap on concurrent connections (each costs one handler thread).
const MAX_CONNECTIONS: usize = 256;
/// Over-capacity connections get a short-lived drain thread so the 503
/// isn't RST away with unread bytes pending; past this many concurrent
/// rejections the connection is dropped outright.
const MAX_REJECT_DRAINS: usize = 32;
/// Default `limit` for `GET /v1/trace` when the query omits it.
const DEFAULT_TRACE_LIMIT: usize = 32;
/// Default `limit` for `GET /v1/logs` when the query omits it.
const DEFAULT_LOG_LIMIT: usize = 50;

/// JSON content type (every endpoint except the Prometheus exposition).
const CT_JSON: &str = "application/json";
/// Prometheus text exposition format version 0.0.4.
const CT_PROMETHEUS: &str = "text/plain; version=0.0.4";

/// Network front configuration.
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Listen address, e.g. `127.0.0.1:7433` (`:0` picks a free port).
    pub addr: String,
    /// Admission cap: requests allowed to execute concurrently before
    /// new ones are rejected with `overloaded`. 0 rejects everything
    /// (useful to test admission control).
    pub max_inflight: usize,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig { addr: "127.0.0.1:0".into(), max_inflight: 64 }
    }
}

/// The bound network server. [`NetServer::run`] serves until a client
/// posts the shutdown endpoint, then returns cleanly.
pub struct NetServer {
    listener: TcpListener,
    pool: Arc<ReplicaPool>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
    max_inflight: usize,
}

impl NetServer {
    /// Bind the listen socket (fails fast on a bad address). The pool is
    /// shared: connection handlers route through it concurrently, and
    /// the caller keeps its own `Arc` for direct access (binds, tests).
    pub fn bind(pool: Arc<ReplicaPool>, cfg: &NetServerConfig) -> Result<Self> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        if let Ok(addr) = listener.local_addr() {
            log::emit(Level::Info, "server.bind", None, format!("listening on {addr}"));
        }
        Ok(NetServer {
            listener,
            pool,
            inflight: Arc::new(AtomicUsize::new(0)),
            shutdown: Arc::new(AtomicBool::new(false)),
            max_inflight: cfg.max_inflight,
        })
    }

    /// The actual bound address (resolves `:0` to the chosen port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("local_addr")
    }

    /// Accept loop: one handler thread per connection, until shutdown.
    pub fn run(self) -> Result<()> {
        let addr = self.local_addr()?;
        let mut handlers = Vec::new();
        let rejecting = Arc::new(AtomicUsize::new(0));
        for conn in self.listener.incoming() {
            if self.shutdown.load(Ordering::Acquire) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            // Reap finished handler threads, then enforce the connection
            // cap (each live connection holds one thread + its buffers).
            handlers.retain(|h| !h.is_finished());
            if handlers.len() >= MAX_CONNECTIONS {
                // Reject off-thread: the accept loop must never block on
                // a slow peer, and writing the 503 without consuming the
                // request would let close() RST it away (the same reason
                // serve_connection's refuse path drains to a sink).
                if rejecting.load(Ordering::Acquire) < MAX_REJECT_DRAINS {
                    rejecting.fetch_add(1, Ordering::AcqRel);
                    let rejecting = rejecting.clone();
                    let hint = self.pool.retry_hint_ms();
                    std::thread::spawn(move || {
                        let _ = reject_over_capacity(stream, hint);
                        rejecting.fetch_sub(1, Ordering::AcqRel);
                    });
                }
                continue;
            }
            let pool = self.pool.clone();
            let inflight = self.inflight.clone();
            let shutdown = self.shutdown.clone();
            let max_inflight = self.max_inflight;
            handlers.push(std::thread::spawn(move || {
                let _ = serve_connection(stream, &pool, &inflight, &shutdown, max_inflight, addr);
            }));
        }
        for h in handlers {
            // Join only handlers that already returned; an idle keep-alive
            // connection parks its handler in a (60s-capped) read, and
            // joining it would stall shutdown for that long — detach those
            // instead (they exit on their next read timeout/EOF).
            if h.is_finished() {
                let _ = h.join();
            }
        }
        Ok(())
    }
}

/// Answer one over-capacity connection with `503 overloaded`: read the
/// request head (bounded), write the typed error, and drain the declared
/// body to a sink so closing the socket doesn't RST the response. Runs
/// on its own short-lived thread under a tight read timeout.
fn reject_over_capacity(stream: TcpStream, retry_hint_ms: u64) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let head = read_http_head(&mut reader)?;
    let err = ServiceError::overloaded(format!("connection capacity reached ({MAX_CONNECTIONS})"))
        .with_retry_after(retry_hint_ms);
    let body = wire::encode_error(&err).render();
    let _ = write_http_response(&mut writer, err.http_status(), &body, CT_JSON, false);
    if let Some(head) = head {
        let _ = std::io::copy(
            &mut (&mut reader).take(head.content_length as u64),
            &mut std::io::sink(),
        );
    }
    Ok(())
}

/// RAII in-flight slot (decrements on drop, even on error paths).
struct InflightSlot<'a>(&'a AtomicUsize);

impl Drop for InflightSlot<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

fn serve_connection(
    stream: TcpStream,
    pool: &ReplicaPool,
    inflight: &AtomicUsize,
    shutdown: &AtomicBool,
    max_inflight: usize,
    addr: SocketAddr,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    // Even transport-level failures (garbled request line, oversized
    // headers/body) answer with the protocol's typed error body before
    // the connection closes — best-effort, since the peer may be gone.
    let reject = |writer: &mut TcpStream, e: &anyhow::Error| {
        let err = ServiceError::BadRequest(format!("malformed HTTP request: {e}"));
        let body = wire::encode_error(&err).render();
        let _ = write_http_response(writer, err.http_status(), &body, CT_JSON, false);
    };
    loop {
        let head = match read_http_head(&mut reader) {
            Ok(Some(head)) => head,
            Ok(None) => return Ok(()), // clean EOF between requests
            Err(e) => {
                reject(&mut writer, &e);
                return Err(e);
            }
        };
        // The trace window opens the moment the head is parsed; body
        // read + JSON decode land in the admission span.
        let t0 = Instant::now();
        let (path, query) = split_query(&head.path);
        let (path, query) = (path.to_string(), query.to_string());
        // Admission before the body: a rejected request's (possibly
        // large) body is never buffered — answer 503 and close. Engine
        // service requests are POSTs to *known* non-admin endpoints;
        // everything else (server-local endpoints, the metrics surface —
        // which must stay readable while the pool sheds — and unknown
        // paths, which are guaranteed to fail routing anyway) bypasses
        // admission but gets a tiny body cap, so nothing smuggles a
        // large upload past the in-flight accounting.
        let is_service = head.method == "POST"
            && path != EP_SHUTDOWN
            && path != EP_METRICS
            && wire::known_endpoints().contains(&path.as_str());
        // Reject without buffering: write the typed error, then *discard*
        // the declared body to a sink (O(1) memory) so closing the socket
        // doesn't RST the response out from under the client.
        let refuse = |writer: &mut TcpStream, reader: &mut BufReader<TcpStream>, e: ServiceError| {
            let body = wire::encode_error(&e).render();
            let _ = write_http_response(writer, e.http_status(), &body, CT_JSON, false);
            let _ = std::io::copy(
                &mut reader.take(head.content_length as u64),
                &mut std::io::sink(),
            );
        };
        let slot = if is_service {
            if inflight.fetch_add(1, Ordering::AcqRel) >= max_inflight {
                inflight.fetch_sub(1, Ordering::AcqRel);
                pool.record_transport_shed();
                let hint = pool.retry_hint_ms();
                log::emit(
                    Level::Warn,
                    "admission.shed",
                    None,
                    format!("transport cap {max_inflight} reached, retry_after_ms={hint}"),
                );
                let err = ServiceError::overloaded(format!(
                    "admission cap reached ({max_inflight} requests in flight)"
                ))
                .with_retry_after(hint);
                refuse(&mut writer, &mut reader, err);
                return Ok(());
            }
            Some(InflightSlot(inflight))
        } else {
            if head.content_length > MAX_LOCAL_BODY_BYTES {
                let err = ServiceError::BadRequest(format!(
                    "endpoint {path} takes no request body of {} bytes",
                    head.content_length
                ));
                refuse(&mut writer, &mut reader, err);
                return Ok(());
            }
            None
        };
        let body = match read_http_body(&mut reader, head.content_length) {
            Ok(body) => body,
            Err(e) => {
                reject(&mut writer, &e);
                return Err(e);
            }
        };
        if head.method == "POST" && path == EP_GENERATE {
            // Streaming endpoint: the response goes out as chunked
            // transfer encoding — one JSON line per decode step, then the
            // terminal typed response — and the connection closes after
            // the stream (no chunked re-framing across keep-alive
            // requests on this endpoint).
            let r = serve_generate(pool, &mut writer, &body, t0);
            drop(slot);
            return r;
        }
        let (status, resp, content_type) =
            route(pool, shutdown, &head.method, &path, &query, &body, t0);
        drop(slot); // request fully served engine-side; release admission
        write_http_response(&mut writer, status, &resp, content_type, head.keep_alive)?;
        if shutdown.load(Ordering::Acquire) {
            // Wake the accept loop so `run` can return. An unspecified
            // listen address (0.0.0.0/[::]) is not connectable on every
            // platform, so aim the wake at the same family's loopback.
            let wake = if addr.ip().is_unspecified() {
                let loopback: std::net::IpAddr = match addr.ip() {
                    std::net::IpAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                    std::net::IpAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
                };
                SocketAddr::new(loopback, addr.port())
            } else {
                addr
            };
            let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
            return Ok(());
        }
        if !head.keep_alive {
            return Ok(());
        }
    }
}

/// Split the query string off an HTTP request target.
fn split_query(target: &str) -> (&str, &str) {
    match target.split_once('?') {
        Some((path, query)) => (path, query),
        None => (target, ""),
    }
}

/// Look up one `key=value` pair in a query string (no percent-decoding —
/// the protocol's query values are plain integers and idents).
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=')?;
        (k == key).then_some(v)
    })
}

/// Parse an optional non-negative integer query parameter; a present but
/// malformed value is a typed `bad_request`, not a silent default.
fn query_usize(query: &str, key: &str) -> ServiceResult<Option<usize>> {
    query_param(query, key)
        .map(|v| {
            v.parse::<usize>().map_err(|_| {
                ServiceError::BadRequest(format!("query param {key}={v:?} is not a non-negative integer"))
            })
        })
        .transpose()
}

/// Map one wire request onto the typed service API (admission already
/// handled by the caller, which holds the in-flight slot). Returns the
/// status, the rendered body, and its content type — everything is JSON
/// except the Prometheus exposition of the metrics surface.
fn route(
    pool: &ReplicaPool,
    shutdown: &AtomicBool,
    method: &str,
    path: &str,
    query: &str,
    body: &str,
    t0: Instant,
) -> (u16, String, &'static str) {
    let json = |status: u16, v: Value| (status, v.render(), CT_JSON);
    match (method, path) {
        ("GET", EP_HEALTH) => json(200, ok_body(&[("status", Value::str("ok"))])),
        // Telemetry answers plain GET (curl-friendly, body-less) as well
        // as the typed POST below; `?format=prometheus` switches to text
        // exposition for scrapers.
        ("GET", EP_METRICS) => match query_param(query, "format") {
            Some("prometheus") => (200, render_prometheus(&pool.snapshot()), CT_PROMETHEUS),
            Some(other) => {
                let e = ServiceError::BadRequest(format!(
                    "unknown metrics format {other:?} (want \"prometheus\" or no format param)"
                ));
                json(e.http_status(), wire::encode_error(&e))
            }
            None => json(200, wire::encode_response(&ServiceResponse::Metrics(pool.snapshot()))),
        },
        ("GET", EP_TRACE) => match trace_body(pool, query) {
            Ok(v) => json(200, v),
            Err(e) => json(e.http_status(), wire::encode_error(&e)),
        },
        // Readiness is about the fleet, not the process: 200 while any
        // replica can still take traffic (possibly degraded), 503 once
        // every replica is unhealthy. Liveness (EP_HEALTH) stays 200
        // either way.
        ("GET", EP_READYZ) => {
            let (healthy, degraded, unhealthy) = pool.readiness();
            let ready = healthy + degraded > 0;
            let status = if !ready {
                "unready"
            } else if degraded + unhealthy > 0 {
                "degraded"
            } else {
                "ready"
            };
            let body = Value::obj(vec![
                ("proto", Value::num(crate::service::PROTOCOL_VERSION as f64)),
                ("ok", Value::Bool(ready)),
                ("status", Value::str(status)),
                ("replicas_healthy", Value::num(healthy as f64)),
                ("replicas_degraded", Value::num(degraded as f64)),
                ("replicas_unhealthy", Value::num(unhealthy as f64)),
            ]);
            json(if ready { 200 } else { 503 }, body)
        }
        ("GET", EP_PROFILE) => json(
            200,
            ok_body(&[
                ("profile", crate::kernels::profile::profile_tree()),
                ("uptime_seconds", Value::num(pool.uptime_seconds())),
            ]),
        ),
        ("GET", EP_LOGS) => match logs_body(query) {
            Ok(v) => json(200, v),
            Err(e) => json(e.http_status(), wire::encode_error(&e)),
        },
        ("POST", EP_SHUTDOWN) => {
            shutdown.store(true, Ordering::Release);
            log::emit(Level::Info, "server.shutdown", None, "shutdown requested".to_string());
            json(200, ok_body(&[("status", Value::str("shutting down"))]))
        }
        ("POST", _) => match handle_service(pool, path, body, t0) {
            Ok((resp, trace_id)) => {
                json(200, wire::with_trace_id(wire::encode_response(&resp), trace_id))
            }
            Err(e) => json(e.http_status(), wire::encode_error(&e)),
        },
        (m, p) => {
            let e = ServiceError::BadRequest(format!(
                "no route {m} {p} (endpoints: {})",
                wire::known_endpoints().join(", ")
            ));
            json(e.http_status(), wire::encode_error(&e))
        }
    }
}

/// Assemble the `GET /v1/logs` payload: newest-first events from the
/// process journal, filtered by the `limit` / `level` query params
/// (`level` drops events below the named severity; default exports
/// everything retained).
fn logs_body(query: &str) -> ServiceResult<Value> {
    let limit = query_usize(query, "limit")?.unwrap_or(DEFAULT_LOG_LIMIT);
    let min_level = match query_param(query, "level") {
        None => Level::Debug,
        Some(name) => Level::parse(name).ok_or_else(|| {
            ServiceError::BadRequest(format!(
                "query param level={name:?} wants debug, info, warn, or error"
            ))
        })?,
    };
    Ok(log::global().export_json(limit, min_level))
}

/// Assemble the `GET /v1/trace` payload: newest-first records from the
/// pool's ring, filtered by the `limit` / `min_us` query params.
fn trace_body(pool: &ReplicaPool, query: &str) -> ServiceResult<Value> {
    let limit = query_usize(query, "limit")?.unwrap_or(DEFAULT_TRACE_LIMIT);
    let min_us = query_usize(query, "min_us")?.unwrap_or(0) as u64;
    let ring = pool.traces();
    let traces: Vec<Value> = ring.export(limit, min_us).iter().map(|r| r.to_json()).collect();
    Ok(ok_body(&[
        ("traces", Value::Arr(traces)),
        ("capacity", Value::num(ring.capacity() as f64)),
        ("pushed", Value::num(ring.pushed() as f64)),
    ]))
}

/// Parse + execute one service request. The trace id — client-supplied
/// `trace_id` in the body, or freshly allocated — is returned so the
/// caller can echo it; the [`TraceStart`] hands the id plus the
/// admission span (head parse → typed request) to the pool, which
/// records the full stage breakdown on settlement.
fn handle_service(
    pool: &ReplicaPool,
    path: &str,
    body: &str,
    t0: Instant,
) -> ServiceResult<(ServiceResponse, u64)> {
    let parsed = Value::parse(body)
        .map_err(|e| ServiceError::BadRequest(format!("malformed JSON body: {e}")))?;
    let req = wire::parse_request(path, &parsed)?;
    let trace_id = wire::request_trace_id(&parsed).unwrap_or_else(next_trace_id);
    let start =
        TraceStart { trace_id, t0, admission_ns: t0.elapsed().as_nanos() as u64 };
    let resp = pool.call_traced(req, Some(start))?;
    wire::check_encodable(&resp)?;
    Ok((resp, trace_id))
}

/// Serve one `POST /v1/generate` request as a chunked stream. Bad
/// requests (malformed JSON, unparseable body) answer as plain HTTP
/// errors before any streaming starts. Once the first step event
/// arrives, the 200 chunked header is already on the wire, so any
/// later failure is reported as a typed error body in the terminal
/// chunk instead of an HTTP status. If the request settles without
/// streaming a single step (validation inside the engine, unbound
/// binding, `max_tokens` 0), the response degrades to a plain HTTP
/// response with the error's own status.
fn serve_generate(
    pool: &ReplicaPool,
    writer: &mut TcpStream,
    body: &str,
    t0: Instant,
) -> Result<()> {
    let plain_error = |writer: &mut TcpStream, e: &ServiceError| {
        let b = wire::encode_error(e).render();
        write_http_response(writer, e.http_status(), &b, CT_JSON, false)
    };
    let parsed = match Value::parse(body) {
        Ok(v) => v,
        Err(e) => {
            let err = ServiceError::BadRequest(format!("malformed JSON body: {e}"));
            return plain_error(writer, &err);
        }
    };
    let req = match wire::parse_request(EP_GENERATE, &parsed) {
        Ok(r) => r,
        Err(e) => return plain_error(writer, &e),
    };
    let trace_id = wire::request_trace_id(&parsed).unwrap_or_else(next_trace_id);
    let start = TraceStart { trace_id, t0, admission_ns: t0.elapsed().as_nanos() as u64 };

    // Lazily write the chunked header at the first step so pre-stream
    // failures keep their HTTP status. A write failure mid-stream means
    // the peer is gone: stop writing but keep draining step events so
    // the request settles normally (and is traced/metered).
    let mut started = false;
    let mut peer_gone = false;
    let result = pool.generate_streaming(req, Some(start), &mut |ev: StepEvent| {
        if peer_gone {
            return;
        }
        if !started {
            if write_chunked_head(writer).is_err() {
                peer_gone = true;
                return;
            }
            started = true;
        }
        let line = format!("{}\n", wire::step_event_to_json(&ev).render());
        if write_chunk(writer, &line).is_err() {
            peer_gone = true;
        }
    });
    let terminal = match &result {
        Ok(resp) => match wire::check_encodable(resp) {
            Ok(()) => wire::with_trace_id(wire::encode_response(resp), trace_id),
            Err(e) => wire::encode_error(&e),
        },
        Err(e) => wire::encode_error(e),
    };
    if !started {
        let status = match &result {
            Ok(_) => 200,
            Err(e) => e.http_status(),
        };
        return write_http_response(writer, status, &terminal.render(), CT_JSON, false);
    }
    if peer_gone {
        return Ok(());
    }
    write_chunk(writer, &format!("{}\n", terminal.render()))?;
    writer.write_all(b"0\r\n\r\n")?;
    writer.flush()?;
    Ok(())
}

/// Response head for the `/v1/generate` chunked stream.
fn write_chunked_head(w: &mut impl Write) -> Result<()> {
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: {CT_JSON}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    w.flush()?;
    Ok(())
}

/// One chunk of a chunked response (hex size line, payload, CRLF),
/// flushed immediately so steps reach the client as they happen.
fn write_chunk(w: &mut impl Write, payload: &str) -> Result<()> {
    write!(w, "{:x}\r\n{payload}\r\n", payload.len())?;
    w.flush()?;
    Ok(())
}

fn ok_body(extra: &[(&str, Value)]) -> Value {
    let mut pairs: Vec<(String, Value)> = vec![
        ("proto".into(), Value::num(crate::service::PROTOCOL_VERSION as f64)),
        ("ok".into(), Value::Bool(true)),
    ];
    for (k, v) in extra {
        pairs.push(((*k).to_string(), v.clone()));
    }
    Value::obj(pairs)
}

// ---------------------------------------------------------------------------
// Minimal HTTP/1.1
// ---------------------------------------------------------------------------

/// Parsed request line + headers of one HTTP request.
struct HttpHead {
    method: String,
    path: String,
    content_length: usize,
    keep_alive: bool,
}

/// Read one request's line + headers. Returns `None` on clean EOF before
/// a request line; errors on torn/oversized heads. Hard-capped at
/// [`MAX_HEADER_BYTES`] so a missing line terminator cannot grow a
/// buffer without bound; the body is read separately (after admission)
/// by [`read_http_body`].
fn read_http_head<R: BufRead>(reader: &mut R) -> Result<Option<HttpHead>> {
    // Bounded view for the request line + headers: once the cap is
    // consumed, read_line reports EOF and the request is rejected below.
    let mut head = (&mut *reader).take(MAX_HEADER_BYTES);
    let mut line = String::new();
    // Between requests, any read failure (EOF, idle-timeout, reset) just
    // means the connection is over — close silently rather than
    // answering a 400 the peer never solicited.
    match head.read_line(&mut line) {
        Ok(0) | Err(_) => return Ok(None),
        Ok(_) => {}
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    anyhow::ensure!(!method.is_empty() && path.starts_with('/'), "malformed request line {line:?}");

    let mut content_length = 0usize;
    let mut keep_alive = true; // HTTP/1.1 default
    loop {
        let mut header = String::new();
        anyhow::ensure!(head.read_line(&mut header)? > 0, "EOF or header cap inside headers");
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            let value = value.trim();
            match name.to_ascii_lowercase().as_str() {
                "content-length" => {
                    content_length = value.parse().context("content-length")?;
                    anyhow::ensure!(content_length <= MAX_BODY_BYTES, "body too large");
                }
                "connection" => keep_alive = !value.eq_ignore_ascii_case("close"),
                _ => {}
            }
        }
    }
    Ok(Some(HttpHead { method, path, content_length, keep_alive }))
}

/// Stream a request body of the declared length: capacity grows with
/// bytes actually received (capped hint), so a hostile `Content-Length`
/// never causes an upfront allocation.
fn read_http_body<R: BufRead>(reader: &mut R, content_length: usize) -> Result<String> {
    let mut body = Vec::with_capacity(content_length.min(1 << 20));
    let got = (&mut *reader)
        .take(content_length as u64)
        .read_to_end(&mut body)
        .context("read body")?;
    anyhow::ensure!(got == content_length, "truncated body ({got} of {content_length} bytes)");
    String::from_utf8(body).context("body utf-8")
}

fn write_http_response(
    w: &mut impl Write,
    status: u16,
    body: &str,
    content_type: &str,
    keep_alive: bool,
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    };
    write!(
        w,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{body}",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    )?;
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Loopback client
// ---------------------------------------------------------------------------

/// Minimal HTTP/1.1 client for the wire protocol: one connection per
/// call, typed requests in, typed responses (or typed errors) out. Used
/// by `mita client`, the tests, and the CI loopback smoke step.
///
/// Retries are off by default. [`NetClient::with_retries`] enables a
/// bounded retry budget that fires only on `overloaded` sheds, sleeping
/// the server's `retry_after_ms` hint (plus deterministic jitter) between
/// attempts; once the budget is spent the last typed error is returned.
pub struct NetClient {
    addr: String,
    retries: usize,
}

/// Process-wide sequence feeding the retry jitter so backoff is
/// deterministic under test yet de-synchronized across client instances.
static CLIENT_SEQ: AtomicU64 = AtomicU64::new(0x9e37_79b9_7f4a_7c15);

impl NetClient {
    pub fn new(addr: impl Into<String>) -> Self {
        NetClient { addr: addr.into(), retries: 0 }
    }

    /// Allow up to `retries` extra attempts after an `overloaded` shed.
    pub fn with_retries(mut self, retries: usize) -> Self {
        self.retries = retries;
        self
    }

    /// Send one typed request and parse the typed result. Server-side
    /// failures come back as the original [`ServiceError`] (same code).
    /// With a retry budget, `overloaded` sheds are retried after the
    /// server's `retry_after_ms` hint; all other errors return at once.
    pub fn call(&self, req: &ServiceRequest) -> ServiceResult<ServiceResponse> {
        wire::check_request_encodable(req)?;
        let (path, body) = wire::encode_request(req);
        let rendered = body.render();
        let mut attempt = 0usize;
        loop {
            let result = self.call_once(path, &rendered);
            match result {
                Err(ref e) if e.code() == "overloaded" && attempt < self.retries => {
                    attempt += 1;
                    std::thread::sleep(Self::backoff(e.retry_after_ms(), attempt));
                }
                other => return other,
            }
        }
    }

    fn call_once(&self, path: &str, rendered: &str) -> ServiceResult<ServiceResponse> {
        let (_status, text) = self.http("POST", path, rendered)?;
        let parsed = Value::parse(&text)
            .map_err(|e| ServiceError::Internal(format!("malformed response JSON: {e}")))?;
        wire::parse_response(&parsed)
    }

    /// Sleep budget for retry `attempt` (1-based): the server's hint —
    /// default 10ms when absent — scaled linearly per attempt, plus up to
    /// 25% deterministic jitter, capped at 2s so a bad hint can't park
    /// the client.
    fn backoff(hint_ms: Option<u64>, attempt: usize) -> Duration {
        let base = hint_ms.unwrap_or(10).max(1).saturating_mul(attempt as u64);
        let mut seed = CLIENT_SEQ.fetch_add(1, Ordering::Relaxed);
        let jitter = splitmix64(&mut seed) % (base / 4 + 1);
        Duration::from_millis(base.saturating_add(jitter).min(2_000))
    }

    /// POST `/v1/generate` and stream the response: `on_step` fires for
    /// each decode-step chunk line as the server emits it. Returns the
    /// terminal typed response plus the echoed `trace_id` when present.
    /// Pre-stream failures (bad request, unbound binding) arrive as
    /// plain JSON bodies and surface as their original typed error.
    pub fn generate(
        &self,
        req: &ServiceRequest,
        on_step: &mut dyn FnMut(StepEvent),
    ) -> ServiceResult<(ServiceResponse, Option<u64>)> {
        wire::check_request_encodable(req)?;
        let (path, body) = wire::encode_request(req);
        let rendered = body.render();
        let io = |e: std::io::Error| {
            ServiceError::Unavailable(format!("POST {}{path}: {e}", self.addr))
        };
        let mut stream = TcpStream::connect(&self.addr).map_err(io)?;
        stream.set_read_timeout(Some(Duration::from_secs(120))).map_err(io)?;
        write!(
            stream,
            "POST {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{rendered}",
            self.addr,
            rendered.len(),
        )
        .map_err(io)?;
        stream.flush().map_err(io)?;

        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line).map_err(io)?;
        let mut content_length = None;
        let mut chunked = false;
        loop {
            let mut header = String::new();
            if reader.read_line(&mut header).map_err(io)? == 0 {
                break;
            }
            let header = header.trim_end();
            if header.is_empty() {
                break;
            }
            if let Some((name, value)) = header.split_once(':') {
                let value = value.trim();
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value.parse::<usize>().ok();
                } else if name.eq_ignore_ascii_case("transfer-encoding") {
                    chunked = value.eq_ignore_ascii_case("chunked");
                }
            }
        }
        let parse_body = |text: &str| -> ServiceResult<Value> {
            Value::parse(text)
                .map_err(|e| ServiceError::Internal(format!("malformed response JSON: {e}")))
        };
        if !chunked {
            // Pre-stream failure (or a stream that never started): one
            // plain JSON body carrying the typed response or error.
            let mut body = Vec::new();
            match content_length {
                Some(len) => {
                    body.resize(len, 0);
                    reader.read_exact(&mut body).map_err(io)?;
                }
                None => {
                    reader.read_to_end(&mut body).map_err(io)?;
                }
            }
            let text = String::from_utf8(body)
                .map_err(|e| ServiceError::Internal(format!("response utf-8: {e}")))?;
            let parsed = parse_body(&text)?;
            let trace_id = wire::request_trace_id(&parsed);
            return wire::parse_response(&parsed).map(|r| (r, trace_id));
        }
        // Chunked stream: each chunk is one JSON line — step events until
        // the terminal typed response (which also ends the stream).
        loop {
            let chunk = match read_chunk(&mut reader).map_err(io)? {
                Some(c) => c,
                None => {
                    return Err(ServiceError::Internal(
                        "generate stream ended without a terminal response".into(),
                    ))
                }
            };
            let text = String::from_utf8(chunk)
                .map_err(|e| ServiceError::Internal(format!("chunk utf-8: {e}")))?;
            let parsed = parse_body(text.trim())?;
            if wire::is_step_event(&parsed) {
                on_step(wire::step_event_from_json(&parsed)?);
                continue;
            }
            // The trace id rides response bodies under the same key the
            // request helper reads, so reuse it for extraction.
            let trace_id = wire::request_trace_id(&parsed);
            return wire::parse_response(&parsed).map(|r| (r, trace_id));
        }
    }

    /// Fetch and parse the `/v1/metrics` telemetry snapshot.
    pub fn metrics(&self) -> ServiceResult<MetricsSnapshot> {
        self.call(&ServiceRequest::Metrics)?.into_metrics()
    }

    /// Fetch `/v1/metrics` as raw wire text (the CI probe greps this for
    /// the documented metric names without trusting the typed decoder).
    pub fn metrics_raw(&self) -> ServiceResult<String> {
        let (status, text) = self.http("GET", EP_METRICS, "")?;
        if status != 200 {
            if let Ok(parsed) = Value::parse(&text) {
                wire::parse_response(&parsed)?;
            }
            let msg = format!("{}: HTTP {status}: {text}", self.addr);
            return Err(ServiceError::Unavailable(msg));
        }
        Ok(text)
    }

    /// Fetch `/v1/metrics?format=prometheus` as text exposition (status
    /// checked; the caller validates the grammar if it cares).
    pub fn metrics_prometheus(&self) -> ServiceResult<String> {
        let (status, text) = self.http("GET", &format!("{EP_METRICS}?format=prometheus"), "")?;
        if status != 200 {
            if let Ok(parsed) = Value::parse(&text) {
                wire::parse_response(&parsed)?;
            }
            return Err(ServiceError::Unavailable(format!("{}: HTTP {status}: {text}", self.addr)));
        }
        Ok(text)
    }

    /// Fetch `GET /v1/trace` as raw wire text. `limit`/`min_us` map to
    /// the query params; `None` leaves the server defaults in place.
    pub fn trace_raw(&self, limit: Option<usize>, min_us: Option<u64>) -> ServiceResult<String> {
        let mut path = format!("{EP_TRACE}?");
        if let Some(l) = limit {
            path.push_str(&format!("limit={l}&"));
        }
        if let Some(t) = min_us {
            path.push_str(&format!("min_us={t}&"));
        }
        let path = path.trim_end_matches(|c| c == '&' || c == '?');
        let (status, text) = self.http("GET", path, "")?;
        if status != 200 {
            if let Ok(parsed) = Value::parse(&text) {
                wire::parse_response(&parsed)?;
            }
            return Err(ServiceError::Unavailable(format!("{}: HTTP {status}: {text}", self.addr)));
        }
        Ok(text)
    }

    /// Fetch `GET /v1/logs` as raw wire text. `limit`/`level` map to the
    /// query params; `None` leaves the server defaults in place.
    pub fn logs_raw(&self, limit: Option<usize>, level: Option<&str>) -> ServiceResult<String> {
        let mut path = format!("{EP_LOGS}?");
        if let Some(l) = limit {
            path.push_str(&format!("limit={l}&"));
        }
        if let Some(lv) = level {
            path.push_str(&format!("level={lv}&"));
        }
        let path = path.trim_end_matches(|c| c == '&' || c == '?');
        let (status, text) = self.http("GET", path, "")?;
        if status != 200 {
            if let Ok(parsed) = Value::parse(&text) {
                wire::parse_response(&parsed)?;
            }
            return Err(ServiceError::Unavailable(format!("{}: HTTP {status}: {text}", self.addr)));
        }
        Ok(text)
    }

    /// Fetch `GET /v1/profile` (the op-level timing tree) as raw wire text.
    pub fn profile_raw(&self) -> ServiceResult<String> {
        let (status, text) = self.http("GET", EP_PROFILE, "")?;
        if status != 200 {
            if let Ok(parsed) = Value::parse(&text) {
                wire::parse_response(&parsed)?;
            }
            return Err(ServiceError::Unavailable(format!("{}: HTTP {status}: {text}", self.addr)));
        }
        Ok(text)
    }

    /// Readiness probe: returns the HTTP status (200 ready / 503 unready)
    /// plus the JSON body with the per-state replica counts — unlike
    /// [`NetClient::healthz`], a 503 here is a *valid answer*, not a
    /// transport failure, so the caller gets both.
    pub fn readyz_raw(&self) -> ServiceResult<(u16, String)> {
        self.http("GET", EP_READYZ, "")
    }

    /// Raw HTTP access for tests and probes that need the unparsed body
    /// (e.g. reading the echoed `trace_id`, which the typed decoder
    /// deliberately ignores).
    pub fn http_raw(&self, method: &str, path: &str, body: &str) -> ServiceResult<(u16, String)> {
        self.http(method, path, body)
    }

    /// Liveness probe.
    pub fn healthz(&self) -> ServiceResult<()> {
        self.expect_ok(self.http("GET", EP_HEALTH, "")?)
    }

    /// Ask the server to shut down cleanly.
    pub fn shutdown(&self) -> ServiceResult<()> {
        self.expect_ok(self.http("POST", EP_SHUTDOWN, "")?)
    }

    /// Server-local endpoints answer plain ok bodies; any non-200 must
    /// surface its typed error code, never silently read as success.
    fn expect_ok(&self, (status, text): (u16, String)) -> ServiceResult<()> {
        if status == 200 {
            return Ok(());
        }
        if let Ok(parsed) = Value::parse(&text) {
            // Error bodies carry the stable code; bubble it up typed.
            wire::parse_response(&parsed)?;
        }
        Err(ServiceError::Unavailable(format!("{}: HTTP {status}: {text}", self.addr)))
    }

    fn http(&self, method: &str, path: &str, body: &str) -> ServiceResult<(u16, String)> {
        let io = |e: std::io::Error| {
            ServiceError::Unavailable(format!("{method} {}{path}: {e}", self.addr))
        };
        let mut stream = TcpStream::connect(&self.addr).map_err(io)?;
        stream.set_read_timeout(Some(Duration::from_secs(120))).map_err(io)?;
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            self.addr,
            body.len(),
        )
        .map_err(io)?;
        stream.flush().map_err(io)?;

        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line).map_err(io)?;
        let mut content_length = None;
        loop {
            let mut header = String::new();
            if reader.read_line(&mut header).map_err(io)? == 0 {
                break;
            }
            let header = header.trim_end();
            if header.is_empty() {
                break;
            }
            if let Some((name, value)) = header.split_once(':') {
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse::<usize>().ok();
                }
            }
        }
        let mut body = Vec::new();
        match content_length {
            Some(len) => {
                body.resize(len, 0);
                reader.read_exact(&mut body).map_err(io)?;
            }
            None => {
                reader.read_to_end(&mut body).map_err(io)?;
            }
        }
        let text = String::from_utf8(body)
            .map_err(|e| ServiceError::Internal(format!("response utf-8: {e}")))?;
        // Non-JSON error pages (shouldn't happen from our server) still
        // need a typed failure.
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        if text.is_empty() && status != 200 {
            return Err(ServiceError::Internal(format!("HTTP {status} with empty body")));
        }
        Ok((status, text))
    }
}

/// Read one chunk of a chunked response body. `None` is the 0-size
/// terminator (its trailing CRLF consumed). The server frames one JSON
/// line per chunk, so each returned buffer parses standalone.
fn read_chunk<R: BufRead>(r: &mut R) -> std::io::Result<Option<Vec<u8>>> {
    use std::io::{Error, ErrorKind};
    let mut size_line = String::new();
    r.read_line(&mut size_line)?;
    let size = usize::from_str_radix(size_line.trim(), 16).map_err(|_| {
        Error::new(ErrorKind::InvalidData, format!("bad chunk size line {size_line:?}"))
    })?;
    if size > MAX_BODY_BYTES {
        return Err(Error::new(ErrorKind::InvalidData, format!("chunk of {size} bytes")));
    }
    if size == 0 {
        let mut end = String::new();
        let _ = r.read_line(&mut end);
        return Ok(None);
    }
    let mut buf = vec![0u8; size];
    r.read_exact(&mut buf)?;
    let mut crlf = [0u8; 2];
    r.read_exact(&mut crlf)?;
    Ok(Some(buf))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_request_parse_roundtrip() {
        let raw = "POST /v1/stats HTTP/1.1\r\nHost: x\r\nContent-Length: 14\r\n\r\n{\"version\": 1}";
        let mut r = BufReader::new(raw.as_bytes());
        let head = read_http_head(&mut r).unwrap().unwrap();
        assert_eq!((head.method.as_str(), head.path.as_str()), ("POST", "/v1/stats"));
        assert!(head.keep_alive);
        let body = read_http_body(&mut r, head.content_length).unwrap();
        assert_eq!(body, "{\"version\": 1}");

        let raw = "GET /v1/healthz HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut r = BufReader::new(raw.as_bytes());
        let head = read_http_head(&mut r).unwrap().unwrap();
        assert_eq!(head.method, "GET");
        assert_eq!(head.content_length, 0);
        assert!(!head.keep_alive);
        assert!(read_http_body(&mut r, 0).unwrap().is_empty());

        // Clean EOF → None; torn bodies and garbled heads → error.
        let mut r = BufReader::new(&b""[..]);
        assert!(read_http_head(&mut r).unwrap().is_none());
        let raw = &b"POST /x HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort"[..];
        let mut r = BufReader::new(raw);
        let head = read_http_head(&mut r).unwrap().unwrap();
        assert!(read_http_body(&mut r, head.content_length).is_err());
        let mut r = BufReader::new(&b"garbage\r\n\r\n"[..]);
        assert!(read_http_head(&mut r).is_err());
    }

    #[test]
    fn http_response_format() {
        let mut buf = Vec::new();
        write_http_response(&mut buf, 503, "{\"x\":1}", CT_JSON, false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Content-Length: 7\r\n"));
        assert!(text.contains("Connection: close"));
        assert!(text.ends_with("{\"x\":1}"));

        // The Prometheus exposition goes out as versioned text/plain.
        let mut buf = Vec::new();
        write_http_response(&mut buf, 200, "up 1\n", CT_PROMETHEUS, true).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("Content-Type: text/plain; version=0.0.4\r\n"));
        assert!(text.contains("Connection: keep-alive"));
    }

    #[test]
    fn chunked_framing_roundtrips() {
        let mut buf = Vec::new();
        write_chunked_head(&mut buf).unwrap();
        write_chunk(&mut buf, "{\"step\":0}\n").unwrap();
        write_chunk(&mut buf, "{\"ok\":true}\n").unwrap();
        buf.extend_from_slice(b"0\r\n\r\n");
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Transfer-Encoding: chunked\r\n"));
        assert!(text.contains("Connection: close\r\n"));

        // Past the head, each chunk reads back as its exact payload and
        // the zero-size terminator closes the stream.
        let body_at = text.find("\r\n\r\n").unwrap() + 4;
        let mut r = BufReader::new(&buf[body_at..]);
        assert_eq!(read_chunk(&mut r).unwrap().unwrap(), b"{\"step\":0}\n");
        assert_eq!(read_chunk(&mut r).unwrap().unwrap(), b"{\"ok\":true}\n");
        assert!(read_chunk(&mut r).unwrap().is_none());

        // Garbled size lines are data errors, not silent EOF.
        let mut r = BufReader::new(&b"zz\r\n"[..]);
        assert!(read_chunk(&mut r).is_err());
    }

    #[test]
    fn query_split_and_params() {
        assert_eq!(split_query("/v1/trace?limit=5&min_us=100"), ("/v1/trace", "limit=5&min_us=100"));
        assert_eq!(split_query("/v1/metrics"), ("/v1/metrics", ""));
        let q = "limit=5&min_us=100&format=prometheus";
        assert_eq!(query_param(q, "limit"), Some("5"));
        assert_eq!(query_param(q, "format"), Some("prometheus"));
        assert_eq!(query_param(q, "absent"), None);
        assert_eq!(query_usize(q, "min_us").unwrap(), Some(100));
        assert_eq!(query_usize("", "limit").unwrap(), None);
        assert_eq!(query_usize("limit=-3", "limit").unwrap_err().code(), "bad_request");
        assert_eq!(query_usize("limit=x", "limit").unwrap_err().code(), "bad_request");
    }
}
