//! Wire protocol: [`ServiceRequest`]/[`ServiceResponse`] ⇄ HTTP+JSON.
//!
//! One encode/parse pair per direction, shared by the network server
//! (`coordinator::netserver`) and its loopback client, so the two can
//! never drift. The JSON schemas, endpoints, status mapping, and error
//! codes are specified in `docs/PROTOCOL.md`; every body carries a
//! `proto` revision field ([`crate::service::PROTOCOL_VERSION`]), and
//! parsers accept the supported range
//! [`crate::service::PROTOCOL_VERSION_MIN`]`..=`[`crate::service::PROTOCOL_VERSION`]
//! (v1 bodies spelled the field `version`; that spelling still parses).
//! A revision outside the range is the stable `unsupported_proto` error.
//!
//! Tensors cross the wire as `{"dtype": "f32"|"i32", "shape": [..],
//! "data": [..]}` with row-major data. f32 payloads round-trip exactly
//! (JSON numbers are f64 and every f32 is representable).

use crate::coordinator::metrics::{HistogramSnapshot, MetricsSnapshot, ReplicaSnapshot};
use crate::runtime::tensor::Tensor;
use crate::service::{
    BindingId, GenerateParams, KernelId, QkvBatch, ServiceError, ServiceRequest, ServiceResponse,
    ServiceResult, ServiceStats, StepEvent, PROTOCOL_VERSION, PROTOCOL_VERSION_MIN,
};
use crate::util::json::Value;

/// Endpoint of [`ServiceRequest::Attention`].
pub const EP_ATTENTION: &str = "/v1/attention";
/// Endpoint of [`ServiceRequest::ModelForward`].
pub const EP_MODEL_FORWARD: &str = "/v1/model/forward";
/// Endpoint of [`ServiceRequest::Generate`]. The response streams over
/// chunked transfer encoding: one [`StepEvent`] JSON line per generated
/// token, then the standard response body as the final chunk
/// (`docs/DECODE.md`).
pub const EP_GENERATE: &str = "/v1/generate";
/// Endpoint of [`ServiceRequest::BindCheckpoint`] / [`ServiceRequest::BindInit`].
pub const EP_BIND: &str = "/v1/bind";
/// Endpoint of [`ServiceRequest::Artifact`].
pub const EP_ARTIFACT: &str = "/v1/artifact";
/// Endpoint of [`ServiceRequest::Stats`].
pub const EP_STATS: &str = "/v1/stats";
/// Endpoint of [`ServiceRequest::Metrics`] (also answers plain `GET`, and
/// bypasses admission so telemetry stays readable under load).
pub const EP_METRICS: &str = "/v1/metrics";
/// Recent request traces (`GET`-only, answered from the pool's trace
/// ring; query params `limit` and `min_us`). Deliberately **not** in
/// [`known_endpoints`]: that list gates POST service routing, and the
/// trace export never reaches the engine.
pub const EP_TRACE: &str = "/v1/trace";
/// Liveness probe (handled by the server, no engine round-trip).
pub const EP_HEALTH: &str = "/v1/healthz";
/// Readiness probe (`GET`-only): distinct from [`EP_HEALTH`] — liveness
/// says the process answers, readiness says the replica fleet can serve
/// (503 once every replica is unhealthy). Like [`EP_TRACE`], not in
/// [`known_endpoints`]: it never reaches the engine.
pub const EP_READYZ: &str = "/v1/readyz";
/// Continuous op-level profile (`GET`-only): the hierarchical kernel
/// timing tree from [`crate::kernels::profile`]. Not in [`known_endpoints`].
pub const EP_PROFILE: &str = "/v1/profile";
/// Structured event journal (`GET`-only; query params `limit` and
/// `level`), answered from the process [`crate::coordinator::log`]
/// ring. Not in [`known_endpoints`].
pub const EP_LOGS: &str = "/v1/logs";
/// Clean-shutdown endpoint (handled by the server).
pub const EP_SHUTDOWN: &str = "/v1/admin/shutdown";

// ---------------------------------------------------------------------------
// Tensors
// ---------------------------------------------------------------------------

/// Emit a tensor as its wire JSON object.
pub fn tensor_to_json(t: &Tensor) -> Value {
    let shape = Value::Arr(t.shape().iter().map(|&d| Value::num(d as f64)).collect());
    let (dtype, data) = match t {
        Tensor::F32 { data, .. } => {
            ("f32", Value::Arr(data.iter().map(|&x| Value::num(x as f64)).collect()))
        }
        Tensor::I32 { data, .. } => {
            ("i32", Value::Arr(data.iter().map(|&x| Value::num(x as f64)).collect()))
        }
    };
    Value::obj([("dtype", Value::str(dtype)), ("shape", shape), ("data", data)])
}

/// Parse a wire JSON object into a tensor (shape × dtype × data checked).
pub fn tensor_from_json(v: &Value) -> ServiceResult<Tensor> {
    let bad = ServiceError::BadShape;
    let obj = v.as_obj().map_err(|e| bad(format!("tensor: {e}")))?;
    let dtype = obj
        .get("dtype")
        .map(|d| d.as_str().map_err(|e| bad(format!("tensor dtype: {e}"))))
        .transpose()?
        .unwrap_or("f32");
    let shape: Vec<usize> = v
        .get("shape")
        .and_then(|s| s.as_arr())
        .map_err(|e| bad(format!("tensor shape: {e}")))?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<_, _>>()
        .map_err(|e| bad(format!("tensor shape: {e}")))?;
    // Borrowed, not cloned: data arrays are the bulk of a request body.
    let data = v
        .get("data")
        .and_then(|d| d.as_arr())
        .map_err(|e| bad(format!("tensor data: {e}")))?;
    // Checked element count: a crafted shape whose product wraps usize
    // could otherwise "match" a short data array and smuggle impossible
    // dims past every later size check (Tensor::f32 multiplies unchecked).
    let elements = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| bad(format!("tensor shape {shape:?} overflows the element count")))?;
    if elements != data.len() {
        return Err(bad(format!(
            "tensor shape {shape:?} wants {elements} values, got {}",
            data.len()
        )));
    }
    match dtype {
        "f32" => {
            let vals: Vec<f32> = data
                .iter()
                .map(|x| {
                    let f = x.as_f64().map_err(|e| bad(format!("tensor data: {e}")))?;
                    let v = f as f32;
                    // JSON numbers are finite f64; a finite value that
                    // overflows to ±inf in f32 is out of range, not data.
                    if !v.is_finite() {
                        return Err(bad(format!("tensor data: {f} is out of f32 range")));
                    }
                    Ok(v)
                })
                .collect::<Result<_, _>>()?;
            Tensor::f32(&shape, vals).map_err(|e| bad(e.to_string()))
        }
        "i32" => {
            let vals: Vec<i32> = data
                .iter()
                .map(|x| {
                    let f = x.as_f64().map_err(|e| bad(format!("tensor data: {e}")))?;
                    if f.fract() != 0.0 || f < i32::MIN as f64 || f > i32::MAX as f64 {
                        return Err(bad(format!("tensor data: {f} is not an i32")));
                    }
                    Ok(f as i32)
                })
                .collect::<Result<_, _>>()?;
            Tensor::i32(&shape, vals).map_err(|e| bad(e.to_string()))
        }
        other => Err(bad(format!("unsupported tensor dtype {other:?} (want f32 or i32)"))),
    }
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Encode a request as its `(endpoint, body)` wire pair.
pub fn encode_request(req: &ServiceRequest) -> (&'static str, Value) {
    let mut body: Vec<(String, Value)> =
        vec![("proto".into(), Value::num(PROTOCOL_VERSION as f64))];
    let path = match req {
        ServiceRequest::Attention { op, qkv, valid_rows } => {
            body.push(("op".into(), Value::str(op.as_str())));
            let tensors = qkv.tensors();
            if tensors.len() == 1 {
                body.push(("qkv".into(), tensor_to_json(tensors[0])));
            } else {
                body.push(("q".into(), tensor_to_json(tensors[0])));
                body.push(("k".into(), tensor_to_json(tensors[1])));
                body.push(("v".into(), tensor_to_json(tensors[2])));
            }
            if let Some(v) = valid_rows {
                body.push(("valid_rows".into(), Value::num(*v as f64)));
            }
            EP_ATTENTION
        }
        ServiceRequest::ModelForward { binding, tokens, valid_rows } => {
            body.push(("binding".into(), Value::str(binding.as_str())));
            body.push(("tokens".into(), tensor_to_json(tokens)));
            if let Some(v) = valid_rows {
                body.push(("valid_rows".into(), Value::num(*v as f64)));
            }
            EP_MODEL_FORWARD
        }
        ServiceRequest::Generate { binding, prompt, max_tokens, params } => {
            body.push(("binding".into(), Value::str(binding.as_str())));
            body.push(("prompt".into(), tensor_to_json(prompt)));
            body.push(("max_tokens".into(), Value::num(*max_tokens as f64)));
            if let Some(k) = &params.kernel {
                body.push(("kernel".into(), Value::str(k.as_str())));
            }
            EP_GENERATE
        }
        ServiceRequest::BindCheckpoint { binding, params } => {
            body.push(("binding".into(), Value::str(binding.as_str())));
            body.push(("params".into(), Value::Arr(params.iter().map(tensor_to_json).collect())));
            EP_BIND
        }
        ServiceRequest::BindInit { binding, init_op, seed, param_count } => {
            body.push(("binding".into(), Value::str(binding.as_str())));
            body.push((
                "init".into(),
                Value::obj([
                    ("op", Value::str(init_op.clone())),
                    ("seed", Value::num(*seed as f64)),
                    ("param_count", Value::num(*param_count as f64)),
                ]),
            ));
            EP_BIND
        }
        ServiceRequest::Artifact { artifact, binding, inputs } => {
            body.push(("artifact".into(), Value::str(artifact.clone())));
            if let Some(b) = binding {
                body.push(("binding".into(), Value::str(b.as_str())));
            }
            body.push(("inputs".into(), Value::Arr(inputs.iter().map(tensor_to_json).collect())));
            EP_ARTIFACT
        }
        ServiceRequest::Stats { reset } => {
            body.push(("reset".into(), Value::Bool(*reset)));
            EP_STATS
        }
        ServiceRequest::Metrics => EP_METRICS,
    };
    (path, Value::obj(body))
}

/// Validate the protocol revision of a body: `proto` (or the legacy v1
/// spelling `version`) must fall in the supported range. A missing field
/// is a malformed body (`bad_request`); a revision outside the range is
/// the dedicated `unsupported_proto` code, so clients can distinguish
/// "fix your request" from "negotiate a protocol".
fn check_proto(body: &Value) -> ServiceResult<()> {
    let (name, field) = match body.opt("proto") {
        Some(v) => ("proto", v),
        None => match body.opt("version") {
            Some(v) => ("version", v),
            None => {
                return Err(ServiceError::BadRequest(format!(
                    "missing proto field (this server speaks \
                     {PROTOCOL_VERSION_MIN}..={PROTOCOL_VERSION})"
                )))
            }
        },
    };
    let v = field
        .as_usize()
        .map_err(|e| ServiceError::BadRequest(format!("{name}: {e}")))? as u64;
    if (PROTOCOL_VERSION_MIN..=PROTOCOL_VERSION).contains(&v) {
        Ok(())
    } else {
        Err(ServiceError::UnsupportedProto(format!(
            "protocol revision {v} not supported (this server speaks \
             {PROTOCOL_VERSION_MIN}..={PROTOCOL_VERSION})"
        )))
    }
}

fn req_str(body: &Value, key: &str) -> ServiceResult<String> {
    body.get(key)
        .and_then(|v| v.as_str().map(str::to_string))
        .map_err(|e| ServiceError::BadRequest(e.to_string()))
}

fn opt_valid_rows(body: &Value) -> ServiceResult<Option<usize>> {
    body.opt("valid_rows")
        .map(|v| v.as_usize().map_err(|e| ServiceError::BadRequest(format!("valid_rows: {e}"))))
        .transpose()
}

/// Parse an `(endpoint, body)` pair back into a typed request. This is
/// the service boundary of the network front: past this point there are
/// no raw op strings or marker tensors, only validated typed requests.
pub fn parse_request(path: &str, body: &Value) -> ServiceResult<ServiceRequest> {
    check_proto(body)?;
    match path {
        EP_ATTENTION => {
            let op = KernelId::parse(&req_str(body, "op")?)?;
            let qkv = match body.opt("qkv") {
                Some(fused) => QkvBatch::fused(tensor_from_json(fused)?)?,
                None => {
                    let get = |k: &str| -> ServiceResult<Tensor> {
                        tensor_from_json(body.opt(k).ok_or_else(|| {
                            ServiceError::BadRequest(format!(
                                "attention wants \"qkv\" or \"q\"/\"k\"/\"v\" (missing {k:?})"
                            ))
                        })?)
                    };
                    QkvBatch::separate(get("q")?, get("k")?, get("v")?)?
                }
            };
            Ok(ServiceRequest::Attention { op, qkv, valid_rows: opt_valid_rows(body)? })
        }
        EP_MODEL_FORWARD => {
            let binding = BindingId::new(req_str(body, "binding")?);
            let tokens = tensor_from_json(body.get("tokens").map_err(|e| {
                ServiceError::BadRequest(e.to_string())
            })?)?;
            Ok(ServiceRequest::ModelForward { binding, tokens, valid_rows: opt_valid_rows(body)? })
        }
        EP_GENERATE => {
            let binding = BindingId::new(req_str(body, "binding")?);
            let prompt = tensor_from_json(
                body.get("prompt").map_err(|e| ServiceError::BadRequest(e.to_string()))?,
            )?;
            let max_tokens = body
                .get("max_tokens")
                .and_then(|v| v.as_usize())
                .map_err(|e| ServiceError::BadRequest(format!("max_tokens: {e}")))?;
            let kernel = body
                .opt("kernel")
                .map(|k| {
                    k.as_str()
                        .map_err(|e| ServiceError::BadRequest(format!("kernel: {e}")))
                        .and_then(KernelId::parse)
                })
                .transpose()?;
            Ok(ServiceRequest::Generate {
                binding,
                prompt,
                max_tokens,
                params: GenerateParams { kernel },
            })
        }
        EP_BIND => {
            let binding = BindingId::new(req_str(body, "binding")?);
            match (body.opt("init"), body.opt("params")) {
                (Some(init), None) => Ok(ServiceRequest::BindInit {
                    binding,
                    init_op: req_str(init, "op")?,
                    seed: {
                        let s = init
                            .get("seed")
                            .and_then(|v| v.as_f64())
                            .map_err(|e| ServiceError::BadRequest(format!("init seed: {e}")))?;
                        if s.fract() != 0.0 || s < i32::MIN as f64 || s > i32::MAX as f64 {
                            return Err(ServiceError::BadRequest(format!(
                                "init seed {s} is not an i32"
                            )));
                        }
                        s as i32
                    },
                    param_count: init
                        .opt("param_count")
                        .map(|v| v.as_usize())
                        .transpose()
                        .map_err(|e| ServiceError::BadRequest(format!("param_count: {e}")))?
                        .unwrap_or(0),
                }),
                (None, Some(params)) => {
                    let tensors = params
                        .as_arr()
                        .map_err(|e| ServiceError::BadRequest(e.to_string()))?
                        .iter()
                        .map(tensor_from_json)
                        .collect::<ServiceResult<Vec<_>>>()?;
                    Ok(ServiceRequest::BindCheckpoint { binding, params: tensors })
                }
                _ => Err(ServiceError::BadRequest(
                    "bind wants exactly one of \"init\" or \"params\"".into(),
                )),
            }
        }
        EP_ARTIFACT => {
            let artifact = req_str(body, "artifact")?;
            let binding = body
                .opt("binding")
                .map(|b| b.as_str().map(BindingId::from))
                .transpose()
                .map_err(|e| ServiceError::BadRequest(e.to_string()))?;
            let inputs = body
                .get("inputs")
                .and_then(|v| v.as_arr())
                .map_err(|e| ServiceError::BadRequest(e.to_string()))?
                .iter()
                .map(tensor_from_json)
                .collect::<ServiceResult<Vec<_>>>()?;
            Ok(ServiceRequest::Artifact { artifact, binding, inputs })
        }
        EP_STATS => {
            let reset = body
                .opt("reset")
                .map(|v| v.as_bool())
                .transpose()
                .map_err(|e| ServiceError::BadRequest(format!("reset: {e}")))?
                .unwrap_or(false);
            Ok(ServiceRequest::Stats { reset })
        }
        EP_METRICS => Ok(ServiceRequest::Metrics),
        other => Err(ServiceError::BadRequest(format!("unknown endpoint {other:?}"))),
    }
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// Optional client-supplied trace id in a protocol-v2 request body.
/// Unknown to v1 servers (which ignore extra keys), so sending it is
/// always safe.
pub fn request_trace_id(body: &Value) -> Option<u64> {
    body.opt("trace_id").and_then(|v| v.as_usize().ok()).map(|v| v as u64)
}

/// Attach the echoed `trace_id` to an encoded response body (the
/// network front calls this on every service response; clients that
/// predate tracing ignore the extra key).
pub fn with_trace_id(body: Value, trace_id: u64) -> Value {
    match body {
        Value::Obj(mut map) => {
            map.insert("trace_id".into(), Value::num(trace_id as f64));
            Value::Obj(map)
        }
        other => other,
    }
}

// ---------------------------------------------------------------------------
// Streaming step events (/v1/generate chunk lines)
// ---------------------------------------------------------------------------

/// Encode one generation step as a `/v1/generate` chunk line. Step
/// latency rides the wire at microsecond granularity (sub-microsecond
/// remainders are dropped); step 0 always reports 0 — its compute is the
/// prefill tail (`docs/DECODE.md`).
pub fn step_event_to_json(ev: &StepEvent) -> Value {
    Value::obj([
        ("proto", Value::num(PROTOCOL_VERSION as f64)),
        ("step", Value::num(ev.index as f64)),
        ("token", Value::num(ev.token as f64)),
        ("latency_us", Value::num((ev.latency_ns / 1_000) as f64)),
    ])
}

/// Whether a `/v1/generate` chunk line is a streamed step event (`step`
/// key, no `ok`) rather than the terminal response/error body (which
/// always carries `ok`).
pub fn is_step_event(body: &Value) -> bool {
    body.opt("step").is_some() && body.opt("ok").is_none()
}

/// Parse a streamed step-event chunk line back into a [`StepEvent`]
/// (latency at the microsecond granularity the wire carries).
pub fn step_event_from_json(body: &Value) -> ServiceResult<StepEvent> {
    let bad = |e: anyhow::Error| ServiceError::BadRequest(format!("step event: {e}"));
    let index = body.get("step").and_then(|v| v.as_usize()).map_err(bad)?;
    let token = body.get("token").and_then(|v| v.as_f64()).map_err(bad)?;
    if token.fract() != 0.0 || token < i32::MIN as f64 || token > i32::MAX as f64 {
        return Err(ServiceError::BadRequest(format!("step token {token} is not an i32")));
    }
    let latency_us = body
        .opt("latency_us")
        .map(|v| v.as_usize())
        .transpose()
        .map_err(|e| ServiceError::BadRequest(format!("latency_us: {e}")))?
        .unwrap_or(0);
    Ok(StepEvent { index, token: token as i32, latency_ns: latency_us as u64 * 1_000 })
}

fn mita_stats_to_json(m: &crate::kernels::MitaStats) -> Value {
    Value::obj([
        ("calls", Value::num(m.calls as f64)),
        ("queries", Value::num(m.queries as f64)),
        ("overflow", Value::num(m.overflow as f64)),
        ("cap", Value::num(m.cap as f64)),
        ("peak_imbalance_milli", Value::num(m.peak_imbalance_milli as f64)),
        (
            "expert_counts",
            Value::Arr(m.expert_counts.iter().map(|&c| Value::num(c as f64)).collect()),
        ),
    ])
}

fn mita_stats_from_json(m: &Value) -> ServiceResult<crate::kernels::MitaStats> {
    let bad = |e: anyhow::Error| ServiceError::BadRequest(format!("stats: {e}"));
    Ok(crate::kernels::MitaStats {
        calls: m.get("calls").and_then(|x| x.as_usize()).map_err(bad)?,
        queries: m.get("queries").and_then(|x| x.as_usize()).map_err(bad)?,
        overflow: m.get("overflow").and_then(|x| x.as_usize()).map_err(bad)?,
        cap: m.get("cap").and_then(|x| x.as_usize()).map_err(bad)?,
        peak_imbalance_milli: m
            .get("peak_imbalance_milli")
            .and_then(|x| x.as_usize())
            .map_err(bad)?,
        expert_counts: m
            .get("expert_counts")
            .and_then(|x| x.as_arr())
            .map_err(bad)?
            .iter()
            .map(|c| c.as_usize())
            .collect::<Result<_, _>>()
            .map_err(bad)?,
    })
}

fn stats_to_json(s: &ServiceStats) -> Value {
    let runtime = Value::obj([
        ("compiles", Value::num(s.runtime.compiles as f64)),
        ("compile_secs", Value::num(s.runtime.compile_secs)),
        ("executions", Value::num(s.runtime.executions as f64)),
        ("execute_secs", Value::num(s.runtime.execute_secs)),
    ]);
    let mita = match &s.mita {
        None => Value::Null,
        Some(m) => mita_stats_to_json(m),
    };
    let blocks = Value::Arr(
        s.blocks
            .iter()
            .map(|b| {
                Value::obj([
                    ("attn_ns", Value::num(b.attn_ns as f64)),
                    ("mlp_ns", Value::num(b.mlp_ns as f64)),
                    ("stats", mita_stats_to_json(&b.stats)),
                ])
            })
            .collect(),
    );
    Value::obj([("runtime", runtime), ("mita", mita), ("blocks", blocks)])
}

fn stats_from_json(v: &Value) -> ServiceResult<ServiceStats> {
    let bad = |e: anyhow::Error| ServiceError::BadRequest(format!("stats: {e}"));
    let rt = v.get("runtime").map_err(bad)?;
    let runtime = crate::runtime::client::RuntimeStats {
        compiles: rt.get("compiles").and_then(|x| x.as_usize()).map_err(bad)?,
        compile_secs: rt.get("compile_secs").and_then(|x| x.as_f64()).map_err(bad)?,
        executions: rt.get("executions").and_then(|x| x.as_usize()).map_err(bad)?,
        execute_secs: rt.get("execute_secs").and_then(|x| x.as_f64()).map_err(bad)?,
    };
    let mita = v.opt("mita").map(mita_stats_from_json).transpose()?;
    // v1 bodies have no `blocks`; absent parses as empty.
    let blocks = v
        .opt("blocks")
        .map(|b| -> ServiceResult<Vec<crate::kernels::BlockProfile>> {
            b.as_arr()
                .map_err(bad)?
                .iter()
                .map(|p| {
                    Ok(crate::kernels::BlockProfile {
                        attn_ns: p.get("attn_ns").and_then(|x| x.as_usize()).map_err(bad)? as u64,
                        mlp_ns: p.get("mlp_ns").and_then(|x| x.as_usize()).map_err(bad)? as u64,
                        stats: mita_stats_from_json(p.get("stats").map_err(bad)?)?,
                    })
                })
                .collect()
        })
        .transpose()?
        .unwrap_or_default();
    Ok(ServiceStats { runtime, mita, blocks })
}

fn histogram_to_json(h: &HistogramSnapshot) -> Value {
    Value::obj([
        ("count", Value::num(h.count as f64)),
        ("sum_us", Value::num(h.sum_us)),
        ("max_us", Value::num(h.max_us)),
        ("p50_us", Value::num(h.p50_us)),
        ("p95_us", Value::num(h.p95_us)),
        ("p99_us", Value::num(h.p99_us)),
        (
            "buckets",
            Value::Arr(
                h.buckets
                    .iter()
                    .map(|&(le, c)| Value::Arr(vec![Value::num(le), Value::num(c as f64)]))
                    .collect(),
            ),
        ),
    ])
}

fn histogram_from_json(v: &Value) -> ServiceResult<HistogramSnapshot> {
    let bad = |e: anyhow::Error| ServiceError::BadRequest(format!("histogram: {e}"));
    let buckets = v
        .get("buckets")
        .and_then(|b| b.as_arr())
        .map_err(bad)?
        .iter()
        .map(|pair| -> ServiceResult<(f64, u64)> {
            let pair = pair.as_arr().map_err(bad)?;
            if pair.len() != 2 {
                return Err(ServiceError::BadRequest(
                    "histogram bucket wants [le_us, count]".into(),
                ));
            }
            let le = pair[0].as_f64().map_err(bad)?;
            let count = pair[1].as_usize().map_err(bad)? as u64;
            Ok((le, count))
        })
        .collect::<ServiceResult<Vec<_>>>()?;
    Ok(HistogramSnapshot {
        count: v.get("count").and_then(|x| x.as_usize()).map_err(bad)? as u64,
        sum_us: v.get("sum_us").and_then(|x| x.as_f64()).map_err(bad)?,
        max_us: v.get("max_us").and_then(|x| x.as_f64()).map_err(bad)?,
        p50_us: v.get("p50_us").and_then(|x| x.as_f64()).map_err(bad)?,
        p95_us: v.get("p95_us").and_then(|x| x.as_f64()).map_err(bad)?,
        p99_us: v.get("p99_us").and_then(|x| x.as_f64()).map_err(bad)?,
        buckets,
    })
}

fn metrics_to_json(m: &MetricsSnapshot) -> Value {
    let replicas = m
        .replicas
        .iter()
        .map(|r| {
            let blocks = Value::Arr(
                r.blocks
                    .iter()
                    .map(|b| {
                        Value::obj([
                            ("block", Value::num(b.block as f64)),
                            ("overflow_fraction", Value::num(b.overflow_fraction)),
                            ("queries", Value::num(b.queries as f64)),
                            (
                                "expert_queries",
                                Value::Arr(
                                    b.expert_queries
                                        .iter()
                                        .map(|&q| Value::num(q as f64))
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            );
            Value::obj([
                ("replica", Value::num(r.replica as f64)),
                ("replica_requests_total", Value::num(r.replica_requests_total as f64)),
                ("replica_queue_depth", Value::num(r.replica_queue_depth as f64)),
                ("max_inflight", Value::num(r.max_inflight as f64)),
                ("overflow_fraction", Value::num(r.overflow_fraction)),
                ("load_imbalance", Value::num(r.load_imbalance)),
                ("replica_health", Value::str(r.health.as_str())),
                ("health_faults", Value::num(r.health_faults as f64)),
                ("health_results", Value::num(r.health_results as f64)),
                ("blocks", blocks),
            ])
        })
        .collect();
    // Op-series entries reuse the Prometheus series names as JSON keys,
    // so the wire payload and the text exposition name every counter
    // identically (the METRIC_NAMES contract).
    let ops = Value::Arr(
        m.ops
            .iter()
            .map(|o| {
                Value::obj([
                    ("op", Value::str(o.op.as_str())),
                    ("op_time_us_total", Value::num(o.time_us)),
                    ("op_calls_total", Value::num(o.calls as f64)),
                ])
            })
            .collect(),
    );
    let slo_windows = Value::Arr(
        m.slo
            .windows
            .iter()
            .map(|w| {
                Value::obj([
                    ("window", Value::str(w.window.as_str())),
                    ("requests", Value::num(w.requests as f64)),
                    ("errors", Value::num(w.errors as f64)),
                    ("slow", Value::num(w.slow as f64)),
                    ("slo_error_burn_rate", Value::num(w.error_burn_rate)),
                    ("slo_latency_burn_rate", Value::num(w.latency_burn_rate)),
                ])
            })
            .collect(),
    );
    Value::obj([
        ("serve_requests_total", Value::num(m.serve_requests_total as f64)),
        ("serve_shed_total", Value::num(m.serve_shed_total as f64)),
        ("serve_errors_total", Value::num(m.serve_errors_total as f64)),
        ("request_latency_us", histogram_to_json(&m.request_latency_us)),
        ("tokens_generated_total", Value::num(m.tokens_generated_total as f64)),
        ("prefill_tokens_total", Value::num(m.prefill_tokens_total as f64)),
        ("decode_step_latency_us", histogram_to_json(&m.decode_step_latency_us)),
        ("replicas", Value::Arr(replicas)),
        ("ops", ops),
        (
            "slo",
            Value::obj([
                ("target_ms", Value::num(m.slo.target_ms)),
                ("windows", slo_windows),
            ]),
        ),
        ("uptime_seconds", Value::num(m.uptime_seconds)),
        (
            "serve_build_info",
            Value::obj([
                ("version", Value::str(m.build_version.as_str())),
                ("git", Value::str(m.build_git.as_str())),
            ]),
        ),
        ("simd_lane", Value::str(m.simd_lane.as_str())),
    ])
}

fn metrics_from_json(v: &Value) -> ServiceResult<MetricsSnapshot> {
    let bad = |e: anyhow::Error| ServiceError::BadRequest(format!("metrics: {e}"));
    let replicas = v
        .get("replicas")
        .and_then(|r| r.as_arr())
        .map_err(bad)?
        .iter()
        .map(|r| -> ServiceResult<ReplicaSnapshot> {
            Ok(ReplicaSnapshot {
                replica: r.get("replica").and_then(|x| x.as_usize()).map_err(bad)? as u64,
                replica_requests_total: r
                    .get("replica_requests_total")
                    .and_then(|x| x.as_usize())
                    .map_err(bad)? as u64,
                replica_queue_depth: r
                    .get("replica_queue_depth")
                    .and_then(|x| x.as_usize())
                    .map_err(bad)? as u64,
                max_inflight: r.get("max_inflight").and_then(|x| x.as_usize()).map_err(bad)?
                    as u64,
                overflow_fraction: r
                    .get("overflow_fraction")
                    .and_then(|x| x.as_f64())
                    .map_err(bad)?,
                load_imbalance: r.get("load_imbalance").and_then(|x| x.as_f64()).map_err(bad)?,
                // Health fields are absent in pre-observability payloads;
                // an old replica parses as an unjudged (healthy) one.
                health: r
                    .opt("replica_health")
                    .map(|x| x.as_str().map(str::to_string))
                    .transpose()
                    .map_err(bad)?
                    .unwrap_or_else(|| "healthy".to_string()),
                health_faults: r
                    .opt("health_faults")
                    .map(|x| x.as_usize())
                    .transpose()
                    .map_err(bad)?
                    .unwrap_or(0) as u64,
                health_results: r
                    .opt("health_results")
                    .map(|x| x.as_usize())
                    .transpose()
                    .map_err(bad)?
                    .unwrap_or(0) as u64,
                // Absent in pre-tracing payloads; parses as empty.
                blocks: r
                    .opt("blocks")
                    .map(|bs| -> ServiceResult<Vec<crate::coordinator::metrics::BlockSeries>> {
                        bs.as_arr()
                            .map_err(bad)?
                            .iter()
                            .map(|b| {
                                Ok(crate::coordinator::metrics::BlockSeries {
                                    block: b.get("block").and_then(|x| x.as_usize()).map_err(bad)?
                                        as u64,
                                    overflow_fraction: b
                                        .get("overflow_fraction")
                                        .and_then(|x| x.as_f64())
                                        .map_err(bad)?,
                                    queries: b
                                        .get("queries")
                                        .and_then(|x| x.as_usize())
                                        .map_err(bad)? as u64,
                                    expert_queries: b
                                        .get("expert_queries")
                                        .and_then(|x| x.as_arr())
                                        .map_err(bad)?
                                        .iter()
                                        .map(|q| q.as_usize().map(|q| q as u64))
                                        .collect::<Result<_, _>>()
                                        .map_err(bad)?,
                                })
                            })
                            .collect()
                    })
                    .transpose()?
                    .unwrap_or_default(),
            })
        })
        .collect::<ServiceResult<Vec<_>>>()?;
    Ok(MetricsSnapshot {
        serve_requests_total: v
            .get("serve_requests_total")
            .and_then(|x| x.as_usize())
            .map_err(bad)? as u64,
        serve_shed_total: v.get("serve_shed_total").and_then(|x| x.as_usize()).map_err(bad)?
            as u64,
        serve_errors_total: v
            .get("serve_errors_total")
            .and_then(|x| x.as_usize())
            .map_err(bad)? as u64,
        request_latency_us: histogram_from_json(
            v.get("request_latency_us").map_err(bad)?,
        )?,
        // Absent in pre-decode payloads; parse as zeroed telemetry.
        tokens_generated_total: v
            .opt("tokens_generated_total")
            .map(|x| x.as_usize())
            .transpose()
            .map_err(bad)?
            .unwrap_or(0) as u64,
        prefill_tokens_total: v
            .opt("prefill_tokens_total")
            .map(|x| x.as_usize())
            .transpose()
            .map_err(bad)?
            .unwrap_or(0) as u64,
        decode_step_latency_us: v
            .opt("decode_step_latency_us")
            .map(histogram_from_json)
            .transpose()?
            .unwrap_or_default(),
        replicas,
        // Everything below is absent in pre-observability payloads and
        // parses as zeroed/empty telemetry.
        ops: v
            .opt("ops")
            .map(|os| -> ServiceResult<Vec<crate::kernels::profile::OpSeries>> {
                os.as_arr()
                    .map_err(bad)?
                    .iter()
                    .map(|o| {
                        Ok(crate::kernels::profile::OpSeries {
                            op: o.get("op").and_then(|x| x.as_str()).map_err(bad)?.to_string(),
                            time_us: o
                                .get("op_time_us_total")
                                .and_then(|x| x.as_f64())
                                .map_err(bad)?,
                            calls: o
                                .get("op_calls_total")
                                .and_then(|x| x.as_usize())
                                .map_err(bad)? as u64,
                        })
                    })
                    .collect()
            })
            .transpose()?
            .unwrap_or_default(),
        slo: v
            .opt("slo")
            .map(|s| -> ServiceResult<crate::coordinator::health::SloSnapshot> {
                Ok(crate::coordinator::health::SloSnapshot {
                    target_ms: s.get("target_ms").and_then(|x| x.as_f64()).map_err(bad)?,
                    windows: s
                        .get("windows")
                        .and_then(|w| w.as_arr())
                        .map_err(bad)?
                        .iter()
                        .map(|w| {
                            Ok(crate::coordinator::health::SloWindowSnapshot {
                                window: w
                                    .get("window")
                                    .and_then(|x| x.as_str())
                                    .map_err(bad)?
                                    .to_string(),
                                requests: w
                                    .get("requests")
                                    .and_then(|x| x.as_usize())
                                    .map_err(bad)? as u64,
                                errors: w.get("errors").and_then(|x| x.as_usize()).map_err(bad)?
                                    as u64,
                                slow: w.get("slow").and_then(|x| x.as_usize()).map_err(bad)?
                                    as u64,
                                error_burn_rate: w
                                    .get("slo_error_burn_rate")
                                    .and_then(|x| x.as_f64())
                                    .map_err(bad)?,
                                latency_burn_rate: w
                                    .get("slo_latency_burn_rate")
                                    .and_then(|x| x.as_f64())
                                    .map_err(bad)?,
                            })
                        })
                        .collect::<ServiceResult<Vec<_>>>()?,
                })
            })
            .transpose()?
            .unwrap_or_default(),
        uptime_seconds: v
            .opt("uptime_seconds")
            .map(|x| x.as_f64())
            .transpose()
            .map_err(bad)?
            .unwrap_or(0.0),
        build_version: v
            .opt("serve_build_info")
            .and_then(|b| b.opt("version"))
            .and_then(|x| x.as_str().ok().map(str::to_string))
            .unwrap_or_default(),
        build_git: v
            .opt("serve_build_info")
            .and_then(|b| b.opt("git"))
            .and_then(|x| x.as_str().ok().map(str::to_string))
            .unwrap_or_default(),
        simd_lane: v.get("simd_lane").and_then(|x| x.as_str()).map_err(bad)?.to_string(),
    })
}

/// Encode a successful response body.
pub fn encode_response(resp: &ServiceResponse) -> Value {
    let mut body: Vec<(String, Value)> = vec![
        ("proto".into(), Value::num(PROTOCOL_VERSION as f64)),
        ("ok".into(), Value::Bool(true)),
        ("kind".into(), Value::str(resp.kind())),
    ];
    match resp {
        ServiceResponse::Attention { out } => body.push(("out".into(), tensor_to_json(out))),
        ServiceResponse::ModelForward { logits } => {
            body.push(("logits".into(), tensor_to_json(logits)))
        }
        ServiceResponse::Generate { tokens, prefill_tokens } => {
            body.push(("tokens".into(), tensor_to_json(tokens)));
            body.push(("prefill_tokens".into(), Value::num(*prefill_tokens as f64)));
        }
        ServiceResponse::Bound { binding } => {
            body.push(("binding".into(), Value::str(binding.as_str())))
        }
        ServiceResponse::Artifact { outputs } => {
            body.push(("outputs".into(), Value::Arr(outputs.iter().map(tensor_to_json).collect())))
        }
        ServiceResponse::Stats(s) => body.push(("stats".into(), stats_to_json(s))),
        ServiceResponse::Metrics(m) => body.push(("metrics".into(), metrics_to_json(m))),
    }
    Value::obj(body)
}

/// Encode an error response body (the HTTP status comes from
/// [`ServiceError::http_status`]; the body repeats the stable code, and
/// `overloaded` errors carry their `retry_after_ms` backoff hint).
pub fn encode_error(err: &ServiceError) -> Value {
    let mut error = vec![
        ("code".to_string(), Value::str(err.code())),
        ("message".to_string(), Value::str(err.message())),
    ];
    if let Some(ms) = err.retry_after_ms() {
        error.push(("retry_after_ms".to_string(), Value::num(ms as f64)));
    }
    Value::obj([
        ("proto".into(), Value::num(PROTOCOL_VERSION as f64)),
        ("ok".into(), Value::Bool(false)),
        ("error".into(), Value::obj(error)),
    ])
}

/// Parse a response body back into the typed result — errors come back as
/// the same [`ServiceError`] the server produced.
pub fn parse_response(body: &Value) -> ServiceResult<ServiceResponse> {
    check_proto(body)?;
    let ok = body
        .get("ok")
        .and_then(|v| v.as_bool())
        .map_err(|e| ServiceError::BadRequest(format!("response: {e}")))?;
    if !ok {
        let err = body
            .get("error")
            .map_err(|e| ServiceError::BadRequest(format!("response: {e}")))?;
        let code = err
            .get("code")
            .and_then(|c| c.as_str().map(str::to_string))
            .map_err(|e| ServiceError::BadRequest(format!("error code: {e}")))?;
        let message = err
            .opt("message")
            .and_then(|m| m.as_str().ok())
            .unwrap_or("")
            .to_string();
        let mut typed = ServiceError::from_code(&code, message);
        if let Some(ms) = err.opt("retry_after_ms").and_then(|m| m.as_usize().ok()) {
            typed = typed.with_retry_after(ms as u64);
        }
        return Err(typed);
    }
    let kind = body
        .get("kind")
        .and_then(|k| k.as_str().map(str::to_string))
        .map_err(|e| ServiceError::BadRequest(format!("response kind: {e}")))?;
    let get_tensor = |key: &str| -> ServiceResult<Tensor> {
        tensor_from_json(
            body.get(key).map_err(|e| ServiceError::BadRequest(format!("response: {e}")))?,
        )
    };
    match kind.as_str() {
        "attention" => Ok(ServiceResponse::Attention { out: get_tensor("out")? }),
        "model_forward" => Ok(ServiceResponse::ModelForward { logits: get_tensor("logits")? }),
        "generate" => Ok(ServiceResponse::Generate {
            tokens: get_tensor("tokens")?,
            prefill_tokens: body
                .get("prefill_tokens")
                .and_then(|v| v.as_usize())
                .map_err(|e| ServiceError::BadRequest(format!("prefill_tokens: {e}")))?,
        }),
        "bound" => Ok(ServiceResponse::Bound {
            binding: BindingId::new(req_str(body, "binding")?),
        }),
        "artifact" => {
            let outputs = body
                .get("outputs")
                .and_then(|v| v.as_arr())
                .map_err(|e| ServiceError::BadRequest(format!("response: {e}")))?
                .iter()
                .map(tensor_from_json)
                .collect::<ServiceResult<Vec<_>>>()?;
            Ok(ServiceResponse::Artifact { outputs })
        }
        "stats" => {
            let s = body
                .get("stats")
                .map_err(|e| ServiceError::BadRequest(format!("response: {e}")))?;
            Ok(ServiceResponse::Stats(stats_from_json(s)?))
        }
        "metrics" => {
            let m = body
                .get("metrics")
                .map_err(|e| ServiceError::BadRequest(format!("response: {e}")))?;
            Ok(ServiceResponse::Metrics(metrics_from_json(m)?))
        }
        other => Err(ServiceError::BadRequest(format!("unknown response kind {other:?}"))),
    }
}

/// Which endpoints exist (the network server 404s everything else before
/// engine submission).
pub fn known_endpoints() -> &'static [&'static str] {
    &[
        EP_ATTENTION,
        EP_MODEL_FORWARD,
        EP_GENERATE,
        EP_BIND,
        EP_ARTIFACT,
        EP_STATS,
        EP_METRICS,
        EP_HEALTH,
        EP_SHUTDOWN,
    ]
}

fn tensor_is_finite(t: &Tensor) -> bool {
    match t.as_f32() {
        Ok(data) => data.iter().all(|x| x.is_finite()),
        Err(_) => true, // i32 tensors are always representable
    }
}

/// Non-finite floats are not representable in JSON (they would render as
/// `null` and corrupt the payload client-side), so a response carrying
/// them must be surfaced as a typed internal error instead of a 200 —
/// the network front runs this check before encoding.
pub fn check_encodable(resp: &ServiceResponse) -> ServiceResult<()> {
    if resp.tensors().into_iter().all(tensor_is_finite) {
        Ok(())
    } else {
        Err(ServiceError::Internal(
            "response tensor contains non-finite values (not representable in JSON)".into(),
        ))
    }
}

/// Request-side twin of [`check_encodable`]: an outbound request whose
/// tensors carry non-finite floats would corrupt on the wire (rendered
/// as `null`), so the client rejects it locally with a `bad_shape`
/// naming the actual problem, instead of letting the server bounce an
/// opaque parse error.
pub fn check_request_encodable(req: &ServiceRequest) -> ServiceResult<()> {
    let tensors: Vec<&Tensor> = match req {
        ServiceRequest::Attention { qkv, .. } => qkv.tensors(),
        ServiceRequest::ModelForward { tokens, .. } => vec![tokens],
        ServiceRequest::Generate { prompt, .. } => vec![prompt],
        ServiceRequest::BindCheckpoint { params, .. } => params.iter().collect(),
        ServiceRequest::Artifact { inputs, .. } => inputs.iter().collect(),
        ServiceRequest::BindInit { .. }
        | ServiceRequest::Stats { .. }
        | ServiceRequest::Metrics => Vec::new(),
    };
    if tensors.into_iter().all(tensor_is_finite) {
        Ok(())
    } else {
        Err(ServiceError::BadShape(
            "request tensor contains non-finite values (not representable in JSON)".into(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: ServiceRequest) -> ServiceRequest {
        let (path, body) = encode_request(&req);
        let text = body.render();
        let parsed = Value::parse(&text).unwrap();
        parse_request(path, &parsed).unwrap()
    }

    #[test]
    fn tensor_roundtrip_exact() {
        let t = Tensor::f32(&[2, 3], vec![0.1, -1.5, 3.25, 1.0 / 3.0, 0.0, -0.0]).unwrap();
        let back = tensor_from_json(&Value::parse(&tensor_to_json(&t).render()).unwrap()).unwrap();
        assert_eq!(back, t);
        let t = Tensor::i32(&[3], vec![-1, 0, i32::MAX]).unwrap();
        let back = tensor_from_json(&Value::parse(&tensor_to_json(&t).render()).unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn tensor_parse_rejects_bad_payloads() {
        for text in [
            r#"{"shape": [2], "data": [1]}"#,                          // len mismatch
            r#"{"dtype": "i32", "shape": [1], "data": [1.5]}"#,        // non-integer i32
            r#"{"dtype": "f64", "shape": [1], "data": [1]}"#,          // unknown dtype
            r#"{"shape": "x", "data": []}"#,                           // shape not array
            r#"[1, 2]"#,                                               // not an object
            r#"{"shape": [1], "data": [1e39]}"#,                       // overflows f32
            // Shape whose element product wraps usize to 0, "matching"
            // the empty data array.
            r#"{"shape": [9223372036854775807, 4], "data": []}"#,
        ] {
            let v = Value::parse(text).unwrap();
            assert_eq!(tensor_from_json(&v).unwrap_err().code(), "bad_shape", "{text}");
        }
    }

    #[test]
    fn request_roundtrips() {
        let fused = Tensor::f32(&[2, 3, 4, 2], vec![0.5; 48]).unwrap();
        let req = ServiceRequest::Attention {
            op: KernelId::Mita,
            qkv: QkvBatch::fused(fused).unwrap(),
            valid_rows: Some(1),
        };
        match roundtrip_req(req) {
            ServiceRequest::Attention { op, qkv, valid_rows } => {
                assert_eq!(op, KernelId::Mita);
                assert_eq!((qkv.batch(), qkv.seq_len(), qkv.dim()), (2, 4, 2));
                assert_eq!(valid_rows, Some(1));
            }
            other => panic!("wrong class {:?}", other.kind()),
        }

        let tokens = Tensor::i32(&[1, 4], vec![1, 2, 3, 0]).unwrap();
        let req = ServiceRequest::ModelForward {
            binding: BindingId::from("model"),
            tokens: tokens.clone(),
            valid_rows: None,
        };
        match roundtrip_req(req) {
            ServiceRequest::ModelForward { binding, tokens: t, valid_rows } => {
                assert_eq!(binding.as_str(), "model");
                assert_eq!(t, tokens);
                assert_eq!(valid_rows, None);
            }
            other => panic!("wrong class {:?}", other.kind()),
        }

        let req = ServiceRequest::BindInit {
            binding: BindingId::from("m"),
            init_op: "model.init".into(),
            seed: -3,
            param_count: 7,
        };
        match roundtrip_req(req) {
            ServiceRequest::BindInit { binding, init_op, seed, param_count } => {
                assert_eq!((binding.as_str(), init_op.as_str()), ("m", "model.init"));
                assert_eq!((seed, param_count), (-3, 7));
            }
            other => panic!("wrong class {:?}", other.kind()),
        }

        let req = ServiceRequest::Artifact {
            artifact: "predict".into(),
            binding: Some(BindingId::from("w")),
            inputs: vec![Tensor::scalar_i32(5)],
        };
        match roundtrip_req(req) {
            ServiceRequest::Artifact { artifact, binding, inputs } => {
                assert_eq!(artifact, "predict");
                assert_eq!(binding.unwrap().as_str(), "w");
                assert_eq!(inputs.len(), 1);
            }
            other => panic!("wrong class {:?}", other.kind()),
        }

        let prompt = Tensor::i32(&[4], vec![3, 1, 4, 1]).unwrap();
        let req = ServiceRequest::Generate {
            binding: BindingId::from("model"),
            prompt: prompt.clone(),
            max_tokens: 12,
            params: GenerateParams { kernel: Some(KernelId::Mita) },
        };
        let (path, _) = encode_request(&req);
        assert_eq!(path, EP_GENERATE);
        match roundtrip_req(req) {
            ServiceRequest::Generate { binding, prompt: p, max_tokens, params } => {
                assert_eq!(binding.as_str(), "model");
                assert_eq!(p, prompt);
                assert_eq!(max_tokens, 12);
                assert_eq!(params.kernel, Some(KernelId::Mita));
            }
            other => panic!("wrong class {:?}", other.kind()),
        }
        // Absent kernel parses back as the binding's own per-block choice.
        let req = ServiceRequest::Generate {
            binding: BindingId::from("model"),
            prompt,
            max_tokens: 1,
            params: GenerateParams::default(),
        };
        match roundtrip_req(req) {
            ServiceRequest::Generate { params, .. } => assert_eq!(params.kernel, None),
            other => panic!("wrong class {:?}", other.kind()),
        }

        match roundtrip_req(ServiceRequest::Stats { reset: true }) {
            ServiceRequest::Stats { reset } => assert!(reset),
            other => panic!("wrong class {:?}", other.kind()),
        }

        let (path, body) = encode_request(&ServiceRequest::Metrics);
        assert_eq!(path, EP_METRICS);
        assert!(body.render().contains("\"proto\":2"));
        match roundtrip_req(ServiceRequest::Metrics) {
            ServiceRequest::Metrics => {}
            other => panic!("wrong class {:?}", other.kind()),
        }
    }

    #[test]
    fn request_parse_taxonomy() {
        // Unknown endpoint.
        let body = Value::parse(r#"{"version": 1}"#).unwrap();
        assert_eq!(parse_request("/v1/nope", &body).unwrap_err().code(), "bad_request");
        // Missing protocol revision is a malformed body...
        let body = Value::parse(r#"{"op": "attn.mita"}"#).unwrap();
        assert_eq!(parse_request(EP_ATTENTION, &body).unwrap_err().code(), "bad_request");
        // ...but an out-of-range revision is the dedicated code, under
        // either field spelling.
        for text in
            [r#"{"proto": 99, "op": "attn.mita"}"#, r#"{"version": 99, "op": "attn.mita"}"#]
        {
            let body = Value::parse(text).unwrap();
            assert_eq!(
                parse_request(EP_ATTENTION, &body).unwrap_err().code(),
                "unsupported_proto",
                "{text}"
            );
        }
        // Both supported revisions parse (v1 bodies spell the field
        // `version`; v2 spells it `proto`).
        for text in [r#"{"version": 1}"#, r#"{"proto": 1}"#, r#"{"proto": 2}"#] {
            let body = Value::parse(text).unwrap();
            assert!(matches!(
                parse_request(EP_METRICS, &body).unwrap(),
                ServiceRequest::Metrics
            ));
        }
        // Wrong-rank qkv surfaces as bad_shape through the typed layer.
        let body = Value::parse(
            r#"{"version": 1, "op": "attn.mita",
                "qkv": {"dtype": "f32", "shape": [2, 2], "data": [0, 0, 0, 0]}}"#,
        )
        .unwrap();
        assert_eq!(parse_request(EP_ATTENTION, &body).unwrap_err().code(), "bad_shape");
        // Bind with both init and params is ambiguous.
        let body = Value::parse(
            r#"{"version": 1, "binding": "m",
                "init": {"op": "model.init", "seed": 0}, "params": []}"#,
        )
        .unwrap();
        assert_eq!(parse_request(EP_BIND, &body).unwrap_err().code(), "bad_request");
        // Non-integer / out-of-range init seeds are rejected, not cast.
        for seed in ["7.9", "1e12", "-2147483649"] {
            let body = Value::parse(&format!(
                r#"{{"version": 1, "binding": "m", "init": {{"op": "model.init", "seed": {seed}}}}}"#
            ))
            .unwrap();
            assert_eq!(parse_request(EP_BIND, &body).unwrap_err().code(), "bad_request", "{seed}");
        }
    }

    #[test]
    fn v1_request_bodies_still_parse() {
        // Satellite of the v2 Generate addition: a protocol-v1 peer —
        // legacy `"version"` proto spelling, no `trace_id`, none of the
        // Generate fields — must keep parsing and round-tripping, so the
        // decode surface stays strictly additive.
        let body = Value::parse(
            r#"{"version": 1, "binding": "m",
                "tokens": {"dtype": "i32", "shape": [1, 3], "data": [5, 2, 7]}}"#,
        )
        .unwrap();
        assert_eq!(request_trace_id(&body), None);
        let req = parse_request(EP_MODEL_FORWARD, &body).unwrap();
        match &req {
            ServiceRequest::ModelForward { binding, tokens, valid_rows } => {
                assert_eq!(binding.as_str(), "m");
                assert_eq!(tokens.shape(), &[1, 3]);
                assert_eq!(*valid_rows, None);
            }
            other => panic!("wrong class {:?}", other.kind()),
        }
        // Re-encoding speaks v2 but stays parseable: the fields the v1
        // body carried survive the round trip unchanged.
        let (path, reencoded) = encode_request(&req);
        assert_eq!(path, EP_MODEL_FORWARD);
        let text = reencoded.render();
        assert!(text.contains("\"proto\":2"), "{text}");
        let back = parse_request(path, &Value::parse(&text).unwrap()).unwrap();
        assert_eq!(back.kind(), "model_forward");
        // A v1 attention body (the other v1-era compute endpoint) parses
        // too — Generate's new keys are never required of old bodies.
        let body = Value::parse(
            r#"{"version": 1, "op": "attn.dense",
                "qkv": {"dtype": "f32", "shape": [1, 3, 2, 2], "data":
                        [0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0]}}"#,
        )
        .unwrap();
        assert!(matches!(
            parse_request(EP_ATTENTION, &body).unwrap(),
            ServiceRequest::Attention { op: KernelId::Dense, .. }
        ));
    }

    #[test]
    fn step_events_roundtrip_and_classify() {
        let ev = StepEvent { index: 3, token: -7, latency_ns: 1_234_567 };
        let line = step_event_to_json(&ev).render();
        assert!(line.contains("\"proto\":2"), "{line}");
        let parsed = Value::parse(&line).unwrap();
        assert!(is_step_event(&parsed));
        let back = step_event_from_json(&parsed).unwrap();
        // Microsecond wire granularity: ns floor to us.
        assert_eq!((back.index, back.token, back.latency_ns), (3, -7, 1_234_000));
        // The terminal body is not a step event, even with trace_id.
        let resp = with_trace_id(
            encode_response(&ServiceResponse::Generate {
                tokens: Tensor::i32(&[2], vec![1, 2]).unwrap(),
                prefill_tokens: 4,
            }),
            9,
        );
        assert!(!is_step_event(&Value::parse(&resp.render()).unwrap()));
        // Malformed step lines are typed errors, not panics.
        let bad = Value::parse(r#"{"proto": 2, "step": 1, "token": 0.5}"#).unwrap();
        assert_eq!(step_event_from_json(&bad).unwrap_err().code(), "bad_request");
        let bad = Value::parse(r#"{"proto": 2, "token": 3}"#).unwrap();
        assert_eq!(step_event_from_json(&bad).unwrap_err().code(), "bad_request");
    }

    #[test]
    fn non_finite_tensors_are_not_encodable() {
        let ok = ServiceResponse::Attention { out: Tensor::f32(&[2], vec![1.0, 2.0]).unwrap() };
        assert!(check_encodable(&ok).is_ok());
        let bad = ServiceResponse::ModelForward {
            logits: Tensor::f32(&[2], vec![1.0, f32::NAN]).unwrap(),
        };
        assert_eq!(check_encodable(&bad).unwrap_err().code(), "internal");
        assert!(check_encodable(&ServiceResponse::Stats(ServiceStats::default())).is_ok());

        // Request-side twin: rejected locally as bad_shape.
        let inf = Tensor::f32(&[3, 1, 1], vec![1.0, f32::INFINITY, 0.0]).unwrap();
        let req = ServiceRequest::Attention {
            op: KernelId::Mita,
            qkv: QkvBatch::fused(inf).unwrap(),
            valid_rows: None,
        };
        assert_eq!(check_request_encodable(&req).unwrap_err().code(), "bad_shape");
        assert!(check_request_encodable(&ServiceRequest::Stats { reset: false }).is_ok());
    }

    #[test]
    fn stats_without_mita_roundtrip_as_none() {
        // Artifact backends report `"mita": null`; Value::opt maps JSON
        // null to absent, so the client parses it back to None.
        let body = encode_response(&ServiceResponse::Stats(ServiceStats::default()));
        let text = body.render();
        assert!(text.contains("\"mita\":null"), "{text}");
        match parse_response(&Value::parse(&text).unwrap()).unwrap() {
            ServiceResponse::Stats(got) => assert!(got.mita.is_none()),
            other => panic!("wrong class {:?}", other.kind()),
        }
    }

    #[test]
    fn response_roundtrips_including_errors() {
        let out = Tensor::f32(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let body = encode_response(&ServiceResponse::Attention { out: out.clone() });
        match parse_response(&Value::parse(&body.render()).unwrap()).unwrap() {
            ServiceResponse::Attention { out: got } => assert_eq!(got, out),
            other => panic!("wrong class {:?}", other.kind()),
        }

        let body = encode_response(&ServiceResponse::Generate {
            tokens: Tensor::i32(&[3], vec![4, 4, 9]).unwrap(),
            prefill_tokens: 5,
        });
        match parse_response(&Value::parse(&body.render()).unwrap()).unwrap() {
            ServiceResponse::Generate { tokens, prefill_tokens } => {
                assert_eq!(tokens.as_i32().unwrap(), &[4, 4, 9]);
                assert_eq!(prefill_tokens, 5);
            }
            other => panic!("wrong class {:?}", other.kind()),
        }

        let stats = ServiceStats {
            runtime: crate::runtime::client::RuntimeStats {
                compiles: 1,
                compile_secs: 0.25,
                executions: 9,
                execute_secs: 1.5,
            },
            mita: Some({
                let mut m = crate::kernels::MitaStats::default();
                m.record(8, 2, &[5, 3]);
                m
            }),
            blocks: vec![crate::kernels::BlockProfile {
                attn_ns: 1200,
                mlp_ns: 800,
                stats: {
                    let mut m = crate::kernels::MitaStats::default();
                    m.record(8, 2, &[5, 3]);
                    m
                },
            }],
        };
        let body = encode_response(&ServiceResponse::Stats(stats.clone()));
        match parse_response(&Value::parse(&body.render()).unwrap()).unwrap() {
            ServiceResponse::Stats(got) => {
                assert_eq!(got.runtime.executions, 9);
                assert_eq!(got.mita.unwrap(), stats.mita.unwrap());
                assert_eq!(got.blocks, stats.blocks, "per-block profiles survive the wire");
            }
            other => panic!("wrong class {:?}", other.kind()),
        }

        let err = ServiceError::UnboundParams("no model bound under \"m\"".into());
        let body = encode_error(&err);
        let got = parse_response(&Value::parse(&body.render()).unwrap()).unwrap_err();
        assert_eq!(got, err);
    }

    #[test]
    fn overloaded_retry_hint_survives_the_wire() {
        let err = ServiceError::overloaded("pool saturated").with_retry_after(40);
        let body = encode_error(&err);
        let text = body.render();
        assert!(text.contains("\"retry_after_ms\":40"), "{text}");
        let got = parse_response(&Value::parse(&text).unwrap()).unwrap_err();
        assert_eq!(got, err);
        assert_eq!(got.retry_after_ms(), Some(40));
        // Hint-less overloaded omits the field and parses back to None.
        let body = encode_error(&ServiceError::overloaded("x"));
        let text = body.render();
        assert!(!text.contains("retry_after_ms"), "{text}");
        let got = parse_response(&Value::parse(&text).unwrap()).unwrap_err();
        assert_eq!(got.retry_after_ms(), None);
    }

    #[test]
    fn trace_id_reads_from_requests_and_attaches_to_responses() {
        // A client-supplied id is visible to the server...
        let body = Value::parse(r#"{"proto": 2, "trace_id": 41}"#).unwrap();
        assert_eq!(request_trace_id(&body), Some(41));
        // ...absent or malformed ids read as None (never an error)...
        assert_eq!(request_trace_id(&Value::parse(r#"{"proto": 2}"#).unwrap()), None);
        assert_eq!(
            request_trace_id(&Value::parse(r#"{"proto": 2, "trace_id": "x"}"#).unwrap()),
            None
        );
        // ...and the echo rides any response body without disturbing the
        // typed parse (unknown keys are ignored by parse_response).
        let resp = ServiceResponse::Stats(ServiceStats::default());
        let body = with_trace_id(encode_response(&resp), 41);
        let text = body.render();
        assert!(text.contains("\"trace_id\":41"), "{text}");
        parse_response(&Value::parse(&text).unwrap()).unwrap();
    }

    #[test]
    fn metrics_snapshot_roundtrips() {
        use crate::coordinator::metrics::{HistogramSnapshot, MetricsSnapshot, ReplicaSnapshot};
        let snap = MetricsSnapshot {
            serve_requests_total: 12,
            serve_shed_total: 3,
            serve_errors_total: 1,
            request_latency_us: HistogramSnapshot {
                count: 9,
                sum_us: 4250.5,
                max_us: 900.0,
                p50_us: 420.0,
                p95_us: 800.0,
                p99_us: 890.0,
                buckets: vec![(11.22, 2), (5011.87, 7)],
            },
            tokens_generated_total: 16,
            prefill_tokens_total: 7,
            decode_step_latency_us: HistogramSnapshot {
                count: 15,
                sum_us: 1800.0,
                max_us: 240.0,
                p50_us: 110.0,
                p95_us: 220.0,
                p99_us: 235.0,
                buckets: vec![(125.89, 15)],
            },
            replicas: vec![
                ReplicaSnapshot {
                    replica: 0,
                    replica_requests_total: 5,
                    replica_queue_depth: 1,
                    max_inflight: 8,
                    overflow_fraction: 0.25,
                    load_imbalance: 1.5,
                    health: "degraded".into(),
                    health_faults: 3,
                    health_results: 9,
                    blocks: vec![crate::coordinator::metrics::BlockSeries {
                        block: 0,
                        overflow_fraction: 0.125,
                        queries: 64,
                        expert_queries: vec![40, 24],
                    }],
                },
                ReplicaSnapshot {
                    replica: 1,
                    replica_requests_total: 4,
                    replica_queue_depth: 0,
                    max_inflight: 8,
                    overflow_fraction: 0.0,
                    load_imbalance: 1.0,
                    health: "healthy".into(),
                    health_faults: 0,
                    health_results: 5,
                    blocks: vec![],
                },
            ],
            ops: vec![
                crate::kernels::profile::OpSeries {
                    op: "mita.landmarks".into(),
                    time_us: 42.5,
                    calls: 7,
                },
                crate::kernels::profile::OpSeries {
                    op: "dense.attend".into(),
                    time_us: 11.0,
                    calls: 2,
                },
            ],
            slo: crate::coordinator::health::SloSnapshot {
                target_ms: 250.0,
                windows: vec![
                    crate::coordinator::health::SloWindowSnapshot {
                        window: "1m".into(),
                        requests: 10,
                        errors: 1,
                        slow: 0,
                        error_burn_rate: 10.0,
                        latency_burn_rate: 0.0,
                    },
                    crate::coordinator::health::SloWindowSnapshot {
                        window: "5m".into(),
                        requests: 40,
                        errors: 1,
                        slow: 2,
                        error_burn_rate: 2.5,
                        latency_burn_rate: 5.0,
                    },
                ],
            },
            uptime_seconds: 33.5,
            build_version: "0.1.0".into(),
            build_git: "abc123".into(),
            simd_lane: "avx2".into(),
        };
        let body = encode_response(&ServiceResponse::Metrics(snap.clone()));
        let text = body.render();
        // Every name in the canonical registry is literally on the wire.
        for name in crate::coordinator::metrics::METRIC_NAMES {
            assert!(text.contains(name), "{name} missing from {text}");
        }
        match parse_response(&Value::parse(&text).unwrap()).unwrap() {
            ServiceResponse::Metrics(got) => assert_eq!(got, snap),
            other => panic!("wrong class {:?}", other.kind()),
        }

        // A pre-observability payload — no health, ops, slo, uptime, or
        // build-info keys — still parses, with the new telemetry zeroed.
        let old = r#"{"serve_requests_total": 1, "serve_shed_total": 0,
            "serve_errors_total": 0,
            "request_latency_us": {"count": 0, "sum_us": 0, "max_us": 0,
                "p50_us": 0, "p95_us": 0, "p99_us": 0, "buckets": []},
            "replicas": [{"replica": 0, "replica_requests_total": 1,
                "replica_queue_depth": 0, "max_inflight": 4,
                "overflow_fraction": 0, "load_imbalance": 1}],
            "simd_lane": "scalar"}"#;
        let got = metrics_from_json(&Value::parse(old).unwrap()).unwrap();
        assert_eq!(got.replicas[0].health, "healthy");
        assert_eq!((got.replicas[0].health_faults, got.replicas[0].health_results), (0, 0));
        assert!(got.ops.is_empty());
        assert!(got.slo.windows.is_empty());
        assert_eq!(got.uptime_seconds, 0.0);
        assert_eq!((got.build_version.as_str(), got.build_git.as_str()), ("", ""));
    }
}
