//! Typed service API: the single request surface of the execution stack.
//!
//! Everything that used to be stringly-typed plumbing — bare op strings,
//! a magic one-element i32 "valid-rows marker" tensor appended to the
//! input list, per-op ad-hoc shape checks — is parsed **once** here, at
//! the service boundary, into a [`ServiceRequest`]. Backends execute
//! validated requests; the engine, the serving loop, the network front,
//! benches, and examples all speak this one vocabulary:
//!
//! - [`ServiceRequest::Attention`] — a batched QKV problem
//!   ([`QkvBatch`]) routed to a kernel by [`KernelId`], with padding
//!   expressed as a typed `valid_rows: Option<usize>` field.
//! - [`ServiceRequest::ModelForward`] — token classification against a
//!   model bound under a [`BindingId`].
//! - [`ServiceRequest::Generate`] — autoregressive greedy decoding
//!   against a bound model; per-token [`StepEvent`]s stream over the
//!   netserver via chunked transfer encoding (`docs/DECODE.md`).
//! - [`ServiceRequest::BindCheckpoint`] / [`ServiceRequest::BindInit`] —
//!   parameter binding (checkpoint tensors or seeded init).
//! - [`ServiceRequest::Artifact`] — compiled-artifact execution on the
//!   PJRT backend (artifact names come from the build manifest, so they
//!   stay strings by construction — but validated and routed here).
//! - [`ServiceRequest::Stats`] — execution + routing counters.
//! - [`ServiceRequest::Metrics`] — the serving-layer telemetry snapshot
//!   (counters/histograms; answered by the replica pool, not a backend).
//!
//! Failures are a [`ServiceError`] with a stable code ([`error`]);
//! [`wire`] maps requests/responses onto the HTTP+JSON protocol served by
//! `coordinator::netserver` and documented in `docs/PROTOCOL.md`.

pub mod error;
pub mod wire;

pub use error::{ServiceError, ServiceResult};

use crate::coordinator::metrics::MetricsSnapshot;
use crate::kernels::api::{BlockProfile, QkvData, QkvLayout};
use crate::kernels::{MitaStats, OP_ATTN_DENSE, OP_ATTN_MITA};
use crate::runtime::client::RuntimeStats;
use crate::runtime::tensor::Tensor;

/// Protocol revision stamped as `proto` on every wire request/response
/// (and the version of the error-code taxonomy). Servers accept
/// [`PROTOCOL_VERSION_MIN`]`..=`[`PROTOCOL_VERSION`] and reject anything
/// else with the stable `unsupported_proto` code; see `docs/PROTOCOL.md`
/// for the evolution contract.
pub const PROTOCOL_VERSION: u64 = 2;

/// Oldest protocol revision this build still parses (v1 bodies carry the
/// field under its old name, `version`).
pub const PROTOCOL_VERSION_MIN: u64 = 1;

// ---------------------------------------------------------------------------
// Identifiers
// ---------------------------------------------------------------------------

/// A validated attention-kernel selector. The two paper kernels are
/// first-class; anything else must still look like a registry name and
/// resolves (or fails with `unknown_op`) at execution time, so custom
/// kernels registered on the backend stay reachable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KernelId {
    /// The MiTA mixture-of-top-k kernel (`attn.mita`).
    Mita,
    /// The dense O(N²) baseline (`attn.dense`).
    Dense,
    /// A custom registry entry (validated name, resolved at execution).
    Custom(String),
}

impl KernelId {
    /// Parse a registry name. Unknown-but-well-formed names become
    /// [`KernelId::Custom`]; malformed names are rejected here so they
    /// never reach a backend.
    pub fn parse(name: &str) -> ServiceResult<Self> {
        match name {
            OP_ATTN_MITA => Ok(KernelId::Mita),
            OP_ATTN_DENSE => Ok(KernelId::Dense),
            _ => {
                let name_byte_ok =
                    |b: u8| b.is_ascii_lowercase() || b.is_ascii_digit() || b"._-".contains(&b);
                let well_formed =
                    !name.is_empty() && name.len() <= 64 && name.bytes().all(name_byte_ok);
                if well_formed {
                    Ok(KernelId::Custom(name.to_string()))
                } else {
                    Err(ServiceError::BadRequest(format!(
                        "malformed kernel name {name:?} (want lowercase [a-z0-9._-], ≤64 chars)"
                    )))
                }
            }
        }
    }

    /// The registry name this id resolves through.
    pub fn as_str(&self) -> &str {
        match self {
            KernelId::Mita => OP_ATTN_MITA,
            KernelId::Dense => OP_ATTN_DENSE,
            KernelId::Custom(s) => s,
        }
    }
}

impl std::fmt::Display for KernelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Key of a parameter binding held backend-side between requests.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BindingId(String);

impl BindingId {
    pub fn new(key: impl Into<String>) -> Self {
        BindingId(key.into())
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl From<&str> for BindingId {
    fn from(s: &str) -> Self {
        BindingId(s.to_string())
    }
}

impl std::fmt::Display for BindingId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

// ---------------------------------------------------------------------------
// QKV batch
// ---------------------------------------------------------------------------

/// A shape-validated batched QKV input. Construction is the only place
/// attention input shapes are checked — backends consume the already
/// validated batch and read its dims, never re-deriving them from raw
/// tensor lists.
#[derive(Debug, Clone, PartialEq)]
pub struct QkvBatch {
    storage: QkvStorage,
    batch: usize,
    n: usize,
    dim: usize,
}

#[derive(Debug, Clone, PartialEq)]
enum QkvStorage {
    /// `[b, 3, n, dim]` (or `[3, n, dim]` for b = 1), Q/K/V on axis 1.
    Fused(Tensor),
    /// Three equal-shape `[b, n, dim]` (or `[n, dim]`) tensors.
    Separate { q: Tensor, k: Tensor, v: Tensor },
}

impl QkvBatch {
    /// Validate a fused `[b, 3, n, dim]` / `[3, n, dim]` f32 tensor.
    pub fn fused(t: Tensor) -> ServiceResult<Self> {
        if t.as_f32().is_err() {
            return Err(ServiceError::BadShape("fused qkv tensor must be f32".into()));
        }
        let (batch, n, dim) = match *t.shape() {
            [three, n, dim] if three == 3 => (1, n, dim),
            [b, three, n, dim] if three == 3 => (b, n, dim),
            ref s => {
                return Err(ServiceError::BadShape(format!(
                    "fused qkv must be [b, 3, n, dim] or [3, n, dim], got {s:?}"
                )))
            }
        };
        if batch == 0 || n == 0 || dim == 0 {
            return Err(ServiceError::BadShape(format!(
                "qkv dims must be non-zero (b={batch}, n={n}, dim={dim})"
            )));
        }
        Ok(QkvBatch { storage: QkvStorage::Fused(t), batch, n, dim })
    }

    /// Validate three equal-shape `[b, n, dim]` / `[n, dim]` f32 tensors.
    pub fn separate(q: Tensor, k: Tensor, v: Tensor) -> ServiceResult<Self> {
        for (name, t) in [("q", &q), ("k", &k), ("v", &v)] {
            if t.as_f32().is_err() {
                return Err(ServiceError::BadShape(format!("{name} tensor must be f32")));
            }
        }
        if q.shape() != k.shape() || q.shape() != v.shape() {
            return Err(ServiceError::BadShape(format!(
                "q/k/v shapes differ: {:?} vs {:?} vs {:?}",
                q.shape(),
                k.shape(),
                v.shape()
            )));
        }
        let (batch, n, dim) = match *q.shape() {
            [n, dim] => (1, n, dim),
            [b, n, dim] => (b, n, dim),
            ref s => {
                return Err(ServiceError::BadShape(format!(
                    "q/k/v must be [b, n, dim] or [n, dim], got {s:?}"
                )))
            }
        };
        if batch == 0 || n == 0 || dim == 0 {
            return Err(ServiceError::BadShape(format!(
                "qkv dims must be non-zero (b={batch}, n={n}, dim={dim})"
            )));
        }
        Ok(QkvBatch { storage: QkvStorage::Separate { q, k, v }, batch, n, dim })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn seq_len(&self) -> usize {
        self.n
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn layout(&self) -> QkvLayout {
        match self.storage {
            QkvStorage::Fused(_) => QkvLayout::Fused,
            QkvStorage::Separate { .. } => QkvLayout::Separate,
        }
    }

    /// Borrowed kernel-level view (shapes already validated, so the f32
    /// accessors cannot fail).
    pub fn view(&self) -> QkvData<'_> {
        match &self.storage {
            QkvStorage::Fused(t) => QkvData::Fused(t.as_f32().expect("validated f32")),
            QkvStorage::Separate { q, k, v } => QkvData::Separate {
                q: q.as_f32().expect("validated f32"),
                k: k.as_f32().expect("validated f32"),
                v: v.as_f32().expect("validated f32"),
            },
        }
    }

    /// The wire/storage tensors, in protocol order.
    pub fn tensors(&self) -> Vec<&Tensor> {
        match &self.storage {
            QkvStorage::Fused(t) => vec![t],
            QkvStorage::Separate { q, k, v } => vec![q, k, v],
        }
    }
}

/// Resolve a typed `valid_rows` field against a batch size: `None` means
/// every row is real; `Some(v)` marks the trailing `batch - v` rows as
/// padding (never computed, zero-filled in the output).
pub fn resolve_valid_rows(valid_rows: Option<usize>, batch: usize) -> ServiceResult<usize> {
    match valid_rows {
        None => Ok(batch),
        Some(v) if (1..=batch).contains(&v) => Ok(v),
        Some(v) => Err(ServiceError::BadShape(format!(
            "valid_rows {v} out of range 1..={batch}"
        ))),
    }
}

// ---------------------------------------------------------------------------
// Requests / responses
// ---------------------------------------------------------------------------

/// One typed request against an execution backend.
#[derive(Debug, Clone)]
pub enum ServiceRequest {
    /// Batched attention: `qkv` through the kernel named by `op`.
    /// Output is `[b, n, dim]`; rows past `valid_rows` stay zero.
    Attention { op: KernelId, qkv: QkvBatch, valid_rows: Option<usize> },
    /// Whole-model classification: `[b, n]` (or `[n]`) i32 `tokens`
    /// against the model bound under `binding`. Output is
    /// `[b, classes]` logits; rows past `valid_rows` stay zero.
    ModelForward { binding: BindingId, tokens: Tensor, valid_rows: Option<usize> },
    /// Autoregressive greedy generation: decode `max_tokens` tokens from
    /// the `[p]` i32 `prompt` against the model bound under `binding`,
    /// emitting one [`StepEvent`] per token. All fields are v2-additive.
    Generate { binding: BindingId, prompt: Tensor, max_tokens: usize, params: GenerateParams },
    /// Bind parameters from host tensors (a loaded checkpoint).
    BindCheckpoint { binding: BindingId, params: Vec<Tensor> },
    /// Bind parameters by seeded init (`init_op` is backend-specific:
    /// `model.init` natively, an init artifact name on PJRT;
    /// `param_count` is how many leading init outputs are parameters —
    /// 0 (the wire default) keeps every output, and the value is
    /// advisory on backends whose init materializes exactly the
    /// parameter set).
    BindInit { binding: BindingId, init_op: String, seed: i32, param_count: usize },
    /// Execute a compiled artifact (PJRT backend), optionally prefixed by
    /// a binding's parameters.
    Artifact { artifact: String, binding: Option<BindingId>, inputs: Vec<Tensor> },
    /// Snapshot execution + routing counters; with `reset`, clear the
    /// routing accumulator after the snapshot.
    Stats { reset: bool },
    /// Snapshot the serving-layer telemetry registry (request counters,
    /// shed counters, latency histogram, per-replica gauges). Answered by
    /// the replica pool; a bare backend returns `unavailable`.
    Metrics,
}

impl ServiceRequest {
    /// Short request-class tag for logs and metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            ServiceRequest::Attention { .. } => "attention",
            ServiceRequest::ModelForward { .. } => "model_forward",
            ServiceRequest::Generate { .. } => "generate",
            ServiceRequest::BindCheckpoint { .. } => "bind_checkpoint",
            ServiceRequest::BindInit { .. } => "bind_init",
            ServiceRequest::Artifact { .. } => "artifact",
            ServiceRequest::Stats { .. } => "stats",
            ServiceRequest::Metrics => "metrics",
        }
    }
}

/// Decode-time options of a [`ServiceRequest::Generate`]. Every field
/// has a wire default, so absent fields keep v1/v2 bodies parseable.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GenerateParams {
    /// Override the causal attention path for every block (`attn.mita` /
    /// `mita.causal` route causal MiTA, `attn.dense` / `dense.causal`
    /// causal dense). `None` derives the path per block from the bound
    /// model's kernel tags.
    pub kernel: Option<KernelId>,
}

/// One generated token of a streaming [`ServiceRequest::Generate`]:
/// emitted in `index` order over the chunked `/v1/generate` response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepEvent {
    /// Zero-based position in the generated suffix.
    pub index: usize,
    /// The generated token id.
    pub token: i32,
    /// Wall time of the forward pass that produced this token (step 0
    /// reports 0 — its compute is the tail of the prefill pass).
    pub latency_ns: u64,
}

/// Combined backend counters returned by [`ServiceRequest::Stats`].
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    /// Compile/execute counters.
    pub runtime: RuntimeStats,
    /// Native MiTA routing statistics, when the backend runs those
    /// kernels (None on artifact backends).
    pub mita: Option<MitaStats>,
    /// Cumulative per-transformer-block profile of model forwards
    /// (index = block; empty when no model ran or the backend does not
    /// record per-block stats). The element-wise sum of `blocks[i].stats`
    /// partitions the model-forward share of `mita`.
    pub blocks: Vec<BlockProfile>,
}

/// The typed result of a [`ServiceRequest`].
#[derive(Debug, Clone)]
pub enum ServiceResponse {
    /// `[b, n, dim]` attention output.
    Attention { out: Tensor },
    /// `[b, classes]` classification logits.
    ModelForward { logits: Tensor },
    /// `[generated]` i32 token ids — the suffix after the prompt (one
    /// per step event), plus how many prompt tokens were prefilled.
    Generate { tokens: Tensor, prefill_tokens: usize },
    /// The binding now exists backend-side.
    Bound { binding: BindingId },
    /// Raw artifact outputs, in artifact order.
    Artifact { outputs: Vec<Tensor> },
    /// Counter snapshot.
    Stats(ServiceStats),
    /// Serving-layer telemetry snapshot.
    Metrics(MetricsSnapshot),
}

impl ServiceResponse {
    /// Response-class tag (mirrors [`ServiceRequest::kind`]).
    pub fn kind(&self) -> &'static str {
        match self {
            ServiceResponse::Attention { .. } => "attention",
            ServiceResponse::ModelForward { .. } => "model_forward",
            ServiceResponse::Generate { .. } => "generate",
            ServiceResponse::Bound { .. } => "bound",
            ServiceResponse::Artifact { .. } => "artifact",
            ServiceResponse::Stats(_) => "stats",
            ServiceResponse::Metrics(_) => "metrics",
        }
    }

    /// Borrowed payload tensors (the by-value form is
    /// [`ServiceResponse::into_tensors`]).
    pub fn tensors(&self) -> Vec<&Tensor> {
        match self {
            ServiceResponse::Attention { out } => vec![out],
            ServiceResponse::ModelForward { logits } => vec![logits],
            ServiceResponse::Generate { tokens, .. } => vec![tokens],
            ServiceResponse::Artifact { outputs } => outputs.iter().collect(),
            ServiceResponse::Bound { .. }
            | ServiceResponse::Stats(_)
            | ServiceResponse::Metrics(_) => Vec::new(),
        }
    }

    /// The payload tensors, if this response class carries any.
    pub fn into_tensors(self) -> Vec<Tensor> {
        match self {
            ServiceResponse::Attention { out } => vec![out],
            ServiceResponse::ModelForward { logits } => vec![logits],
            ServiceResponse::Generate { tokens, .. } => vec![tokens],
            ServiceResponse::Artifact { outputs } => outputs,
            ServiceResponse::Bound { .. }
            | ServiceResponse::Stats(_)
            | ServiceResponse::Metrics(_) => Vec::new(),
        }
    }

    /// The single payload tensor of an attention / model-forward
    /// response (errors on other classes — a protocol mix-up).
    pub fn into_tensor(self) -> ServiceResult<Tensor> {
        match self {
            ServiceResponse::Attention { out } => Ok(out),
            ServiceResponse::ModelForward { logits } => Ok(logits),
            other => Err(ServiceError::Internal(format!(
                "expected a tensor-bearing response, got {:?} class",
                other.kind()
            ))),
        }
    }

    /// The stats payload (errors on other classes).
    pub fn into_stats(self) -> ServiceResult<ServiceStats> {
        match self {
            ServiceResponse::Stats(s) => Ok(s),
            other => Err(ServiceError::Internal(format!(
                "expected a stats response, got {:?} class",
                other.kind()
            ))),
        }
    }

    /// The telemetry payload (errors on other classes).
    pub fn into_metrics(self) -> ServiceResult<MetricsSnapshot> {
        match self {
            ServiceResponse::Metrics(m) => Ok(m),
            other => Err(ServiceError::Internal(format!(
                "expected a metrics response, got {:?} class",
                other.kind()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_id_parse_and_roundtrip() {
        assert_eq!(KernelId::parse("attn.mita").unwrap(), KernelId::Mita);
        assert_eq!(KernelId::parse("attn.dense").unwrap(), KernelId::Dense);
        assert_eq!(
            KernelId::parse("attn.flash2").unwrap(),
            KernelId::Custom("attn.flash2".into())
        );
        for bad in ["", "Attn.Mita", "a b", "x\n"] {
            let e = KernelId::parse(bad).unwrap_err();
            assert_eq!(e.code(), "bad_request", "{bad:?}");
        }
        assert_eq!(KernelId::Mita.as_str(), "attn.mita");
    }

    #[test]
    fn qkv_batch_validates_shapes() {
        let fused = Tensor::f32(&[2, 3, 4, 8], vec![0.0; 2 * 3 * 4 * 8]).unwrap();
        let b = QkvBatch::fused(fused).unwrap();
        assert_eq!((b.batch(), b.seq_len(), b.dim()), (2, 4, 8));
        assert_eq!(b.layout(), QkvLayout::Fused);
        assert_eq!(b.tensors().len(), 1);

        // Rank-3 single example.
        let one = Tensor::f32(&[3, 4, 8], vec![0.0; 3 * 4 * 8]).unwrap();
        assert_eq!(QkvBatch::fused(one).unwrap().batch(), 1);

        // Wrong rank / wrong axis-1 / wrong dtype are all bad_shape.
        let bad = Tensor::f32(&[2, 2], vec![0.0; 4]).unwrap();
        assert_eq!(QkvBatch::fused(bad).unwrap_err().code(), "bad_shape");
        let bad = Tensor::f32(&[2, 4, 4, 8], vec![0.0; 2 * 4 * 4 * 8]).unwrap();
        assert_eq!(QkvBatch::fused(bad).unwrap_err().code(), "bad_shape");
        let bad = Tensor::i32(&[3, 4, 8], vec![0; 3 * 4 * 8]).unwrap();
        assert_eq!(QkvBatch::fused(bad).unwrap_err().code(), "bad_shape");

        // Separate tensors must agree on shape.
        let q = Tensor::f32(&[4, 8], vec![0.0; 32]).unwrap();
        let k = Tensor::f32(&[4, 8], vec![1.0; 32]).unwrap();
        let v = Tensor::f32(&[5, 8], vec![2.0; 40]).unwrap();
        assert_eq!(
            QkvBatch::separate(q.clone(), k.clone(), v).unwrap_err().code(),
            "bad_shape"
        );
        let v = Tensor::f32(&[4, 8], vec![2.0; 32]).unwrap();
        let s = QkvBatch::separate(q, k, v).unwrap();
        assert_eq!((s.batch(), s.seq_len(), s.dim()), (1, 4, 8));
        assert_eq!(s.tensors().len(), 3);
    }

    #[test]
    fn valid_rows_resolution() {
        assert_eq!(resolve_valid_rows(None, 4).unwrap(), 4);
        assert_eq!(resolve_valid_rows(Some(2), 4).unwrap(), 2);
        assert_eq!(resolve_valid_rows(Some(4), 4).unwrap(), 4);
        assert_eq!(resolve_valid_rows(Some(0), 4).unwrap_err().code(), "bad_shape");
        assert_eq!(resolve_valid_rows(Some(5), 4).unwrap_err().code(), "bad_shape");
    }

    #[test]
    fn response_accessors() {
        let t = Tensor::f32(&[1, 2], vec![1.0, 2.0]).unwrap();
        let r = ServiceResponse::Attention { out: t.clone() };
        assert_eq!(r.clone().into_tensor().unwrap(), t);
        assert_eq!(r.into_tensors().len(), 1);
        let r = ServiceResponse::Bound { binding: BindingId::from("m") };
        assert!(r.clone().into_tensor().is_err());
        assert!(r.into_tensors().is_empty());
        let s = ServiceResponse::Stats(ServiceStats::default());
        assert!(s.into_stats().is_ok());
    }
}
