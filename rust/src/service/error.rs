//! The versioned error taxonomy of the typed service API.
//!
//! Every failure that can cross the service boundary — engine submission,
//! backend execution, or the network front — is a [`ServiceError`] with a
//! **stable string code**. Codes are part of the wire protocol (see
//! `docs/PROTOCOL.md`): clients branch on `code`, never on the free-text
//! `message`, so messages can improve without breaking anyone. The
//! taxonomy itself is versioned through the protocol's `version` field;
//! adding a code is backward-compatible, renaming one is not.

use std::fmt;

/// Typed service failure with a stable wire code.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The request itself is malformed: unparseable JSON, missing fields,
    /// wrong protocol version, unknown endpoint.
    BadRequest(String),
    /// Tensors have the wrong rank/shape/dtype, or `valid_rows` is out of
    /// range for the batch.
    BadShape(String),
    /// The named op/kernel/artifact does not exist.
    UnknownOp(String),
    /// The request references a parameter binding that was never bound.
    UnboundParams(String),
    /// Admission control rejected the request (queue/inflight capacity).
    Overloaded(String),
    /// The backend cannot serve this request class at all (e.g. artifact
    /// execution on the native backend, or a stubbed PJRT closure).
    Unavailable(String),
    /// Anything else: an execution failure behind a well-formed request.
    Internal(String),
}

/// `Result` alias used across the service boundary.
pub type ServiceResult<T> = Result<T, ServiceError>;

impl ServiceError {
    /// The stable wire code (what clients branch on).
    pub fn code(&self) -> &'static str {
        match self {
            ServiceError::BadRequest(_) => "bad_request",
            ServiceError::BadShape(_) => "bad_shape",
            ServiceError::UnknownOp(_) => "unknown_op",
            ServiceError::UnboundParams(_) => "unbound_params",
            ServiceError::Overloaded(_) => "overloaded",
            ServiceError::Unavailable(_) => "unavailable",
            ServiceError::Internal(_) => "internal",
        }
    }

    /// The human-readable detail (free text; never branch on this).
    pub fn message(&self) -> &str {
        match self {
            ServiceError::BadRequest(m)
            | ServiceError::BadShape(m)
            | ServiceError::UnknownOp(m)
            | ServiceError::UnboundParams(m)
            | ServiceError::Overloaded(m)
            | ServiceError::Unavailable(m)
            | ServiceError::Internal(m) => m,
        }
    }

    /// HTTP status the network front maps this error to.
    pub fn http_status(&self) -> u16 {
        match self {
            ServiceError::BadRequest(_) | ServiceError::BadShape(_) => 400,
            ServiceError::UnknownOp(_) | ServiceError::UnboundParams(_) => 404,
            ServiceError::Overloaded(_) => 503,
            ServiceError::Unavailable(_) => 501,
            ServiceError::Internal(_) => 500,
        }
    }

    /// Rebuild a typed error from its wire `(code, message)` pair — the
    /// loopback client uses this so errors stay typed end to end. Unknown
    /// codes (a newer server) degrade to [`ServiceError::Internal`].
    pub fn from_code(code: &str, message: impl Into<String>) -> Self {
        let m = message.into();
        match code {
            "bad_request" => ServiceError::BadRequest(m),
            "bad_shape" => ServiceError::BadShape(m),
            "unknown_op" => ServiceError::UnknownOp(m),
            "unbound_params" => ServiceError::UnboundParams(m),
            "overloaded" => ServiceError::Overloaded(m),
            "unavailable" => ServiceError::Unavailable(m),
            _ => ServiceError::Internal(format!("[{code}] {m}")),
        }
    }

    /// Wrap an arbitrary failure as [`ServiceError::Internal`].
    pub fn internal(e: impl fmt::Display) -> Self {
        ServiceError::Internal(e.to_string())
    }
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.code(), self.message())
    }
}

// `?` from a ServiceResult inside an anyhow::Result works through anyhow's
// blanket `From<E: std::error::Error>` impl; the code survives inside the
// message as the `[code]` prefix.
impl std::error::Error for ServiceError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_roundtrip() {
        let all = [
            ServiceError::BadRequest("a".into()),
            ServiceError::BadShape("b".into()),
            ServiceError::UnknownOp("c".into()),
            ServiceError::UnboundParams("d".into()),
            ServiceError::Overloaded("e".into()),
            ServiceError::Unavailable("f".into()),
            ServiceError::Internal("g".into()),
        ];
        let codes: Vec<&str> = all.iter().map(|e| e.code()).collect();
        assert_eq!(
            codes,
            [
                "bad_request",
                "bad_shape",
                "unknown_op",
                "unbound_params",
                "overloaded",
                "unavailable",
                "internal"
            ]
        );
        for e in &all {
            assert_eq!(&ServiceError::from_code(e.code(), e.message()), e);
        }
        // Unknown codes degrade without losing information.
        let e = ServiceError::from_code("brand_new", "future failure");
        assert_eq!(e.code(), "internal");
        assert!(e.message().contains("brand_new"));
    }

    #[test]
    fn display_carries_code_and_message() {
        let e = ServiceError::BadShape("rank 2 != 4".into());
        assert_eq!(e.to_string(), "[bad_shape] rank 2 != 4");
        // And the anyhow bridge keeps both.
        let a: anyhow::Error = e.into();
        assert!(a.to_string().contains("[bad_shape]"));
    }

    #[test]
    fn http_statuses() {
        assert_eq!(ServiceError::BadShape(String::new()).http_status(), 400);
        assert_eq!(ServiceError::UnknownOp(String::new()).http_status(), 404);
        assert_eq!(ServiceError::Overloaded(String::new()).http_status(), 503);
        assert_eq!(ServiceError::Internal(String::new()).http_status(), 500);
    }
}
