//! The versioned error taxonomy of the typed service API.
//!
//! Every failure that can cross the service boundary — engine submission,
//! backend execution, replica-pool admission, or the network front — is a
//! [`ServiceError`] with a **stable string code**. Codes are part of the
//! wire protocol (see `docs/PROTOCOL.md`): clients branch on `code`,
//! never on the free-text `message`, so messages can improve without
//! breaking anyone. The taxonomy itself is versioned through the
//! protocol's `proto` field; adding a code is backward-compatible,
//! renaming one is not.
//!
//! [`ServiceError::Overloaded`] carries a structured `retry_after_ms`
//! hint alongside the message: the serving layer fills it from observed
//! latency so a shed client knows *when* to retry, and the wire layer
//! round-trips it (`error.retry_after_ms`) so the hint survives typed
//! end to end.

use std::fmt;

/// Typed service failure with a stable wire code.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The request itself is malformed: unparseable JSON, missing fields,
    /// unknown endpoint.
    BadRequest(String),
    /// The request speaks a protocol revision this server does not
    /// support (`proto` outside the accepted range).
    UnsupportedProto(String),
    /// Tensors have the wrong rank/shape/dtype, or `valid_rows` is out of
    /// range for the batch.
    BadShape(String),
    /// The named op/kernel/artifact does not exist.
    UnknownOp(String),
    /// The request references a parameter binding that was never bound.
    UnboundParams(String),
    /// Admission control rejected the request (queue/inflight capacity).
    /// `retry_after_ms`, when present, is the server's backoff hint.
    Overloaded { message: String, retry_after_ms: Option<u64> },
    /// The backend cannot serve this request class at all (e.g. artifact
    /// execution on the native backend, or a stubbed PJRT closure).
    Unavailable(String),
    /// Anything else: an execution failure behind a well-formed request.
    Internal(String),
}

/// `Result` alias used across the service boundary.
pub type ServiceResult<T> = Result<T, ServiceError>;

impl ServiceError {
    /// The stable wire code (what clients branch on).
    pub fn code(&self) -> &'static str {
        match self {
            ServiceError::BadRequest(_) => "bad_request",
            ServiceError::UnsupportedProto(_) => "unsupported_proto",
            ServiceError::BadShape(_) => "bad_shape",
            ServiceError::UnknownOp(_) => "unknown_op",
            ServiceError::UnboundParams(_) => "unbound_params",
            ServiceError::Overloaded { .. } => "overloaded",
            ServiceError::Unavailable(_) => "unavailable",
            ServiceError::Internal(_) => "internal",
        }
    }

    /// The human-readable detail (free text; never branch on this).
    pub fn message(&self) -> &str {
        match self {
            ServiceError::BadRequest(m)
            | ServiceError::UnsupportedProto(m)
            | ServiceError::BadShape(m)
            | ServiceError::UnknownOp(m)
            | ServiceError::UnboundParams(m)
            | ServiceError::Overloaded { message: m, .. }
            | ServiceError::Unavailable(m)
            | ServiceError::Internal(m) => m,
        }
    }

    /// HTTP status the network front maps this error to.
    pub fn http_status(&self) -> u16 {
        match self {
            ServiceError::BadRequest(_)
            | ServiceError::UnsupportedProto(_)
            | ServiceError::BadShape(_) => 400,
            ServiceError::UnknownOp(_) | ServiceError::UnboundParams(_) => 404,
            ServiceError::Overloaded { .. } => 503,
            ServiceError::Unavailable(_) => 501,
            ServiceError::Internal(_) => 500,
        }
    }

    /// An [`ServiceError::Overloaded`] without a backoff hint (the
    /// serving layer adds one via [`ServiceError::with_retry_after`]).
    pub fn overloaded(message: impl Into<String>) -> Self {
        ServiceError::Overloaded { message: message.into(), retry_after_ms: None }
    }

    /// Attach a backoff hint (no-op on non-`overloaded` errors, which
    /// carry none on the wire).
    pub fn with_retry_after(self, ms: u64) -> Self {
        match self {
            ServiceError::Overloaded { message, .. } => {
                ServiceError::Overloaded { message, retry_after_ms: Some(ms) }
            }
            other => other,
        }
    }

    /// The backoff hint, if this is an `overloaded` error carrying one.
    pub fn retry_after_ms(&self) -> Option<u64> {
        match self {
            ServiceError::Overloaded { retry_after_ms, .. } => *retry_after_ms,
            _ => None,
        }
    }

    /// Rebuild a typed error from its wire `(code, message)` pair — the
    /// loopback client uses this so errors stay typed end to end (the
    /// wire layer re-attaches `retry_after_ms` separately). Unknown
    /// codes (a newer server) degrade to [`ServiceError::Internal`].
    pub fn from_code(code: &str, message: impl Into<String>) -> Self {
        let m = message.into();
        match code {
            "bad_request" => ServiceError::BadRequest(m),
            "unsupported_proto" => ServiceError::UnsupportedProto(m),
            "bad_shape" => ServiceError::BadShape(m),
            "unknown_op" => ServiceError::UnknownOp(m),
            "unbound_params" => ServiceError::UnboundParams(m),
            "overloaded" => ServiceError::overloaded(m),
            "unavailable" => ServiceError::Unavailable(m),
            _ => ServiceError::Internal(format!("[{code}] {m}")),
        }
    }

    /// Wrap an arbitrary failure as [`ServiceError::Internal`].
    pub fn internal(e: impl fmt::Display) -> Self {
        ServiceError::Internal(e.to_string())
    }
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.code(), self.message())
    }
}

// `?` from a ServiceResult inside an anyhow::Result works through anyhow's
// blanket `From<E: std::error::Error>` impl; the code survives inside the
// message as the `[code]` prefix.
impl std::error::Error for ServiceError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_roundtrip() {
        let all = [
            ServiceError::BadRequest("a".into()),
            ServiceError::UnsupportedProto("p".into()),
            ServiceError::BadShape("b".into()),
            ServiceError::UnknownOp("c".into()),
            ServiceError::UnboundParams("d".into()),
            ServiceError::overloaded("e"),
            ServiceError::Unavailable("f".into()),
            ServiceError::Internal("g".into()),
        ];
        let codes: Vec<&str> = all.iter().map(|e| e.code()).collect();
        assert_eq!(
            codes,
            [
                "bad_request",
                "unsupported_proto",
                "bad_shape",
                "unknown_op",
                "unbound_params",
                "overloaded",
                "unavailable",
                "internal"
            ]
        );
        for e in &all {
            assert_eq!(&ServiceError::from_code(e.code(), e.message()), e);
        }
        // Unknown codes degrade without losing information.
        let e = ServiceError::from_code("brand_new", "future failure");
        assert_eq!(e.code(), "internal");
        assert!(e.message().contains("brand_new"));
    }

    #[test]
    fn overloaded_retry_hint() {
        let e = ServiceError::overloaded("queue full");
        assert_eq!(e.retry_after_ms(), None);
        let e = e.with_retry_after(25);
        assert_eq!(e.retry_after_ms(), Some(25));
        assert_eq!(e.code(), "overloaded");
        assert_eq!(e.message(), "queue full");
        // Only overloaded carries a hint; other errors ignore it.
        let e = ServiceError::BadRequest("x".into()).with_retry_after(25);
        assert_eq!(e.retry_after_ms(), None);
    }

    #[test]
    fn display_carries_code_and_message() {
        let e = ServiceError::BadShape("rank 2 != 4".into());
        assert_eq!(e.to_string(), "[bad_shape] rank 2 != 4");
        // And the anyhow bridge keeps both.
        let a: anyhow::Error = e.into();
        assert!(a.to_string().contains("[bad_shape]"));
    }

    #[test]
    fn http_statuses() {
        assert_eq!(ServiceError::BadShape(String::new()).http_status(), 400);
        assert_eq!(ServiceError::UnsupportedProto(String::new()).http_status(), 400);
        assert_eq!(ServiceError::UnknownOp(String::new()).http_status(), 404);
        assert_eq!(ServiceError::overloaded("").http_status(), 503);
        assert_eq!(ServiceError::Internal(String::new()).http_status(), 500);
    }
}
