//! Experiment harness: drivers that regenerate every table and figure of
//! the paper (DESIGN.md §5 experiment index). Each `table*` / `figure*`
//! function trains/evaluates the relevant bundles and prints rows in the
//! paper's format; EXPERIMENTS.md records paper-vs-measured.

pub mod figures;
pub mod tables;

use anyhow::Result;

use crate::coordinator::trainer::{EvalResult, Trainer};
use crate::data::BatchSource;
use crate::runtime::Runtime;

/// Outcome of training one bundle end-to-end.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub bundle: String,
    pub eval: EvalResult,
    pub tail_loss: f64,
    pub mean_step_secs: f64,
    pub train_secs: f64,
    pub steps: usize,
    /// (step, loss) curve, subsampled for reports.
    pub loss_curve: Vec<(f64, f64)>,
}

/// Train a bundle (steps from its meta unless overridden) and evaluate.
pub fn train_bundle<'rt>(
    rt: &'rt Runtime,
    bundle_name: &str,
    seed: i32,
    steps_override: Option<usize>,
    warm_start: Option<&[crate::runtime::Tensor]>,
) -> Result<(Trainer<'rt>, TrainOutcome)> {
    let spec = rt.manifest().bundle(bundle_name)?.clone();
    let steps = steps_override
        .or_else(|| spec.meta_u64("steps").map(|s| s as usize))
        .unwrap_or(spec.train.total_steps);
    let eval_batches = spec.meta_u64("eval_batches").unwrap_or(16) as usize;
    let source = BatchSource::for_bundle(&spec)?;

    let mut trainer = match warm_start {
        Some(params) => Trainer::with_warm_start(rt, bundle_name, seed, params)?,
        None => Trainer::new(rt, bundle_name, seed)?,
    };
    let t0 = std::time::Instant::now();
    trainer.train(&source, steps, 0)?;
    let train_secs = t0.elapsed().as_secs_f64();
    let eval = trainer.eval(&source, eval_batches)?;

    let curve: Vec<(f64, f64)> = trainer
        .history
        .iter()
        .step_by((steps / 50).max(1))
        .map(|r| (r.step as f64, r.loss))
        .collect();

    let outcome = TrainOutcome {
        bundle: bundle_name.to_string(),
        eval,
        tail_loss: trainer.tail_loss(),
        mean_step_secs: trainer.mean_step_secs(),
        train_secs,
        steps,
        loss_curve: curve,
    };
    Ok((trainer, outcome))
}

/// Checkpoint directory used by multi-stage experiments (t2 → t7/f9/f10).
pub fn checkpoint_dir() -> std::path::PathBuf {
    let dir = std::path::PathBuf::from("checkpoints");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

pub fn checkpoint_path(bundle: &str) -> std::path::PathBuf {
    checkpoint_dir().join(format!("{bundle}.ckpt"))
}

/// Metrics sidecar path for a cached training outcome.
pub fn metrics_path(bundle: &str) -> std::path::PathBuf {
    checkpoint_dir().join(format!("{bundle}.metrics"))
}

fn save_outcome(bundle: &str, oc: &TrainOutcome) -> Result<()> {
    let mut s = String::new();
    use std::fmt::Write as _;
    let _ = writeln!(s, "steps {}", oc.steps);
    let _ = writeln!(s, "eval_loss {}", oc.eval.loss);
    let _ = writeln!(s, "eval_acc {}", oc.eval.accuracy);
    if let Some(m) = oc.eval.miou {
        let _ = writeln!(s, "miou {m}");
    }
    let _ = writeln!(s, "examples {}", oc.eval.examples);
    let _ = writeln!(s, "tail_loss {}", oc.tail_loss);
    let _ = writeln!(s, "mean_step_secs {}", oc.mean_step_secs);
    let _ = writeln!(s, "train_secs {}", oc.train_secs);
    std::fs::write(metrics_path(bundle), s)?;
    Ok(())
}

fn load_outcome(bundle: &str) -> Option<TrainOutcome> {
    let text = std::fs::read_to_string(metrics_path(bundle)).ok()?;
    let mut kv = std::collections::HashMap::new();
    for line in text.lines() {
        let (k, v) = line.split_once(' ')?;
        kv.insert(k.to_string(), v.parse::<f64>().ok()?);
    }
    Some(TrainOutcome {
        bundle: bundle.to_string(),
        eval: EvalResult {
            loss: *kv.get("eval_loss")?,
            accuracy: *kv.get("eval_acc")?,
            miou: kv.get("miou").copied(),
            examples: *kv.get("examples")? as usize,
        },
        tail_loss: *kv.get("tail_loss")?,
        mean_step_secs: *kv.get("mean_step_secs")?,
        train_secs: *kv.get("train_secs")?,
        steps: *kv.get("steps")? as usize,
        loss_curve: Vec::new(),
    })
}

/// Cached variant of [`train_bundle`]: if a checkpoint + metrics sidecar
/// exist on disk (a previous run of the harness), reuse them instead of
/// retraining — this makes `mita all` resumable after an interruption.
pub fn train_bundle_cached(
    rt: &Runtime,
    bundle_name: &str,
    seed: i32,
    steps_override: Option<usize>,
    warm_start: Option<&[crate::runtime::Tensor]>,
) -> Result<TrainOutcome> {
    let ckpt = checkpoint_path(bundle_name);
    if ckpt.exists() {
        if let Some(oc) = load_outcome(bundle_name) {
            let want = rt.manifest().bundle(bundle_name)?.param_count();
            let params = crate::coordinator::checkpoint::load(&ckpt)?;
            if params.len() == want {
                eprintln!("[harness] cached {bundle_name}: acc={:.3}", oc.eval.accuracy);
                return Ok(oc);
            }
        }
    }
    let (trainer, outcome) = train_bundle(rt, bundle_name, seed, steps_override, warm_start)?;
    trainer.save_checkpoint(&ckpt)?;
    save_outcome(bundle_name, &outcome)?;
    Ok(outcome)
}

/// Train a bundle once and cache its checkpoint on disk; reuse if present.
pub fn train_or_load_checkpoint(
    rt: &Runtime,
    bundle_name: &str,
    seed: i32,
) -> Result<Vec<crate::runtime::Tensor>> {
    let path = checkpoint_path(bundle_name);
    if path.exists() {
        let params = crate::coordinator::checkpoint::load(&path)?;
        let want = rt.manifest().bundle(bundle_name)?.param_count();
        if params.len() == want {
            eprintln!("[harness] reusing checkpoint {}", path.display());
            return Ok(params);
        }
        eprintln!("[harness] stale checkpoint {} (layout changed), retraining", path.display());
    }
    let (trainer, outcome) = train_bundle(rt, bundle_name, seed, None, None)?;
    eprintln!(
        "[harness] trained {bundle_name}: acc={:.3} loss={:.3} ({:.1}s)",
        outcome.eval.accuracy, outcome.eval.loss, outcome.train_secs
    );
    trainer.save_checkpoint(&path)?;
    save_outcome(bundle_name, &outcome)?;
    trainer.params()
}
