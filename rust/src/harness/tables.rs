//! Table drivers (Tabs. 2–7). Every function prints the regenerated table
//! in the paper's row format and returns the rendered string so binaries
//! and tests can capture it.

use anyhow::Result;

use crate::coordinator::trainer::eval_params;
use crate::data::BatchSource;
use crate::flops;
use crate::harness::{train_bundle_cached, train_or_load_checkpoint};
use crate::report::{pct, speedup, Table};
use crate::runtime::{Runtime, Tensor};

/// Common options for table drivers.
#[derive(Debug, Clone, Default)]
pub struct Opts {
    /// Override per-bundle training steps (None = bundle meta).
    pub steps: Option<usize>,
    pub seed: i32,
}

fn acc_delta(acc: f64, baseline: Option<f64>) -> String {
    match baseline {
        Some(b) => format!("{} ({:+.1})", pct(acc), (acc - b) * 100.0),
        None => pct(acc),
    }
}

/// Tab. 2 — from-scratch image classification, attention varied only.
pub fn table2(rt: &Runtime, opts: &Opts) -> Result<String> {
    let rows = ["std", "linear", "agent", "mita", "mita_dwc", "mita_dwc_gate"];
    let mut out = Table::new(&["Method", "#Params", "attn FLOPs/ex", "Acc. (%)", "tail loss"]);
    let mut std_acc = None;

    for row in rows {
        let bundle = format!("t2_{row}");
        let spec = rt.manifest().bundle(&bundle)?.clone();
        // Checkpoint-cached: t7/f9/f10/figures reuse the weights, and an
        // interrupted `mita all` resumes here without retraining.
        let oc = train_bundle_cached(rt, &bundle, opts.seed, opts.steps, None)?;
        if row == "std" {
            std_acc = Some(oc.eval.accuracy);
        }
        out.row(&[
            row.to_string(),
            flops::param_count(&spec.model).to_string(),
            flops::gflops(flops::attention_flops(&spec.model)),
            acc_delta(oc.eval.accuracy, if row == "std" { None } else { std_acc }),
            format!("{:.3}", oc.tail_loss),
        ]);
        eprintln!(
            "[t2] {row}: acc={:.3} ({} steps, {:.2}s/step)",
            oc.eval.accuracy, oc.steps, oc.mean_step_secs
        );
    }
    let rendered =
        format!("## Table 2 — synthetic-image classification from scratch\n{}", out.render());
    println!("{rendered}");
    Ok(rendered)
}

/// Tab. 3 — comparison table of efficient models (FLOPs/params/acc).
///
/// The paper's Tab. 3 compares against SOTA ViT variants we cannot
/// reproduce (ViT-5 etc.); the substitution keeps its *shape*: best MiTA
/// variants vs the standard/linear/agent baselines at equal parameter
/// count, with the FLOPs column from the analytical model. Reuses the
/// checkpoints produced by table2.
pub fn table3(rt: &Runtime, opts: &Opts) -> Result<String> {
    let rows =
        [("std", "DeiT-equiv (standard)"), ("agent", "Agent-equiv"), ("linear", "Linear-equiv"),
         ("mita", "MiTA"), ("mita_dwc", "MiTA^DWC"), ("mita_dwc_gate", "MiTA^DWC,Gate")];
    let mut out = Table::new(&["Model", "#Params", "model FLOPs/ex", "Acc. (%)"]);
    for (row, label) in rows {
        let bundle = format!("t2_{row}");
        let spec = rt.manifest().bundle(&bundle)?.clone();
        let ckpt = crate::harness::checkpoint_path(&bundle);
        let params = if ckpt.exists() {
            crate::coordinator::checkpoint::load(&ckpt)?
        } else {
            train_or_load_checkpoint(rt, &bundle, opts.seed)?
        };
        let lits: Vec<xla::Literal> =
            params.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let source = BatchSource::for_bundle(&spec)?;
        let art = rt.manifest().bundle_artifact(&bundle, "eval_step")?;
        let ev = eval_params(rt, art, &lits, &source, 16, false, spec.model.num_classes)?;
        out.row(&[
            label.to_string(),
            flops::param_count(&spec.model).to_string(),
            flops::gflops(flops::model_flops(&spec.model)),
            pct(ev.accuracy),
        ]);
    }
    let rendered =
        format!("## Table 3 — model-level comparison (substituted scope)\n{}", out.render());
    println!("{rendered}");
    Ok(rendered)
}

/// Tab. 4 — dense prediction (segmentation): mIoU + FLOPs reduction.
pub fn table4(rt: &Runtime, opts: &Opts) -> Result<String> {
    let mut out = Table::new(&["Backbone", "FLOPs/ex", "mIoU (%)", "pixel acc (%)"]);

    // Native standard backbone (checkpoint-cached).
    let std_spec = rt.manifest().bundle("t4_std")?.clone();
    let std_oc = train_bundle_cached(rt, "t4_std", opts.seed, opts.steps, None)?;
    let std_flops = flops::model_flops(&std_spec.model);
    out.row(&[
        "ViT (standard attn)".into(),
        flops::gflops(std_flops),
        pct(std_oc.eval.miou.unwrap_or(0.0)),
        pct(std_oc.eval.accuracy),
    ]);

    // ▽ row: std-trained params evaluated under MiTA attention.
    let swap_spec = rt.manifest().bundle("t4_mita_swap")?.clone();
    let source = BatchSource::for_bundle(&swap_spec)?;
    let swap_art = rt.manifest().bundle_artifact("t4_mita_swap", "eval_step")?;
    let std_params_host =
        crate::coordinator::checkpoint::load(&crate::harness::checkpoint_path("t4_std"))?;
    let std_params: Vec<xla::Literal> =
        std_params_host.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
    let swap_ev =
        eval_params(rt, swap_art, &std_params, &source, 16, true, swap_spec.model.num_classes)?;
    let mita_flops = flops::model_flops(&swap_spec.model);
    out.row(&[
        "MiTA-ViT ▽ (swapped)".into(),
        format!("{} (↓{:.0}%)", flops::gflops(mita_flops), (1.0 - mita_flops / std_flops) * 100.0),
        pct(swap_ev.miou.unwrap_or(0.0)),
        pct(swap_ev.accuracy),
    ]);

    // Natively-trained MiTA backbone (the paper predicts this closes the gap).
    let mita_oc = train_bundle_cached(rt, "t4_mita", opts.seed, opts.steps, None)?;
    out.row(&[
        "MiTA-ViT (native)".into(),
        format!("{} (↓{:.0}%)", flops::gflops(mita_flops), (1.0 - mita_flops / std_flops) * 100.0),
        pct(mita_oc.eval.miou.unwrap_or(0.0)),
        pct(mita_oc.eval.accuracy),
    ]);

    let rendered =
        format!("## Table 4 — synthetic dense prediction (ADE20K stand-in)\n{}", out.render());
    println!("{rendered}");
    Ok(rendered)
}

/// Tab. 5 — LRA: accuracy + training throughput per task.
pub fn table5(rt: &Runtime, opts: &Opts) -> Result<String> {
    let tasks = ["listops", "text", "retrieval", "image", "pathfinder"];
    let methods = ["standard", "mita", "mita_route", "agent", "linear"];

    let mut out_header = vec!["Method".to_string()];
    for t in tasks {
        out_header.push(format!("{t} acc/steps-s"));
    }
    out_header.push("Avg acc / tot hrs".into());
    let header_refs: Vec<&str> = out_header.iter().map(|s| s.as_str()).collect();
    let mut out = Table::new(&header_refs);

    let mut std_time_total = 0.0f64;
    let mut per_method_time = std::collections::HashMap::new();

    for method in methods {
        let mut cells = vec![method.to_string()];
        let mut accs = Vec::new();
        let mut total_secs = 0.0;
        for task in tasks {
            let bundle = format!("t5_{task}_{method}");
            let oc = train_bundle_cached(rt, &bundle, opts.seed, opts.steps, None)?;
            let steps_per_sec = if oc.mean_step_secs > 0.0 { 1.0 / oc.mean_step_secs } else { 0.0 };
            cells.push(format!("{} / {:.1}", pct(oc.eval.accuracy), steps_per_sec));
            accs.push(oc.eval.accuracy);
            total_secs += oc.train_secs;
            eprintln!(
                "[t5] {task}/{method}: acc={:.3} {:.2}s/step",
                oc.eval.accuracy, oc.mean_step_secs
            );
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        if method == "standard" {
            std_time_total = total_secs;
            cells.push(format!("{} / {:.1}s", pct(avg), total_secs));
        } else {
            let save = 1.0 - total_secs / std_time_total;
            cells.push(format!("{} / {:.1}s (↓{:.0}%)", pct(avg), total_secs, save * 100.0));
        }
        per_method_time.insert(method, total_secs);
        out.row(&cells);
    }

    let rendered = format!("## Table 5 — synthetic LRA benchmark\n{}", out.render());
    println!("{rendered}");
    Ok(rendered)
}

/// Tab. 6 — ablations: landmark extraction, (m,k), compression/routing.
pub fn table6(rt: &Runtime, opts: &Opts) -> Result<String> {
    let groups: &[(&str, &[&str])] = &[
        ("Landmark extraction", &["lm_random", "lm_learned", "lm_pool1d", "lm_pool2d"]),
        (
            "m x k",
            &[
                "mk_8x8", "mk_8x16", "mk_8x32", "mk_16x8", "mk_16x16", "mk_16x32", "mk_32x8",
                "mk_32x16", "mk_32x32",
            ],
        ),
        ("Compression & routing", &["mk_16x16", "route_only", "compress_only"]),
    ];
    let mut out = Table::new(&["Group", "Setting", "Acc. (%)", "Δ vs default"]);
    let mut results: std::collections::HashMap<String, f64> = Default::default();

    // Train the default configuration first so every row's Δ is defined.
    {
        let oc = train_bundle_cached(rt, "t6_mk_16x16", opts.seed, opts.steps, None)?;
        results.insert("t6_mk_16x16".to_string(), oc.eval.accuracy);
    }

    for (group, rows) in groups {
        for row in rows.iter() {
            let bundle = format!("t6_{row}");
            let acc = if let Some(&a) = results.get(&bundle) {
                a
            } else {
                let oc = train_bundle_cached(rt, &bundle, opts.seed, opts.steps, None)?;
                eprintln!("[t6] {row}: acc={:.3}", oc.eval.accuracy);
                results.insert(bundle.clone(), oc.eval.accuracy);
                oc.eval.accuracy
            };
            let default = *results.get("t6_mk_16x16").unwrap_or(&acc);
            out.row(&[
                group.to_string(),
                row.to_string(),
                pct(acc),
                if *row == "mk_16x16" || *row == "lm_pool2d" {
                    "default".into()
                } else {
                    format!("{:+.1}", (acc - default) * 100.0)
                },
            ]);
        }
    }
    let rendered = format!("## Table 6 — ablation study\n{}", out.render());
    println!("{rendered}");
    Ok(rendered)
}

/// Tab. 7 — finetuning a standard-attention-pretrained model with each
/// attention mechanism.
pub fn table7(rt: &Runtime, opts: &Opts) -> Result<String> {
    let pretrained = train_or_load_checkpoint(rt, "t2_std", opts.seed)?;
    let rows = ["std", "linear", "agent", "mita"];
    let mut out = Table::new(&["Finetune attention", "Acc. (%)", "Δ vs standard"]);
    let mut std_acc = None;
    for row in rows {
        let bundle = format!("t7_{row}");
        let oc = train_bundle_cached(rt, &bundle, opts.seed, opts.steps, Some(&pretrained))?;
        if row == "std" {
            std_acc = Some(oc.eval.accuracy);
        }
        let delta = match (row, std_acc) {
            ("std", _) | (_, None) => "baseline".to_string(),
            (_, Some(b)) => format!("{:+.1}", (oc.eval.accuracy - b) * 100.0),
        };
        out.row(&[row.to_string(), pct(oc.eval.accuracy), delta]);
        eprintln!("[t7] {row}: acc={:.3}", oc.eval.accuracy);
    }
    let rendered =
        format!("## Table 7 — finetuning pretrained standard-attn params\n{}", out.render());
    println!("{rendered}");
    Ok(rendered)
}

/// Complexity sanity table (Sec. 3.2): attention FLOPs scaling with N.
pub fn complexity_table(rt: &Runtime) -> Result<String> {
    let mut out = Table::new(&["N", "standard", "mita", "ratio"]);
    for name in rt.manifest().bundles_with_prefix("f5_standard_n") {
        let n = rt.manifest().bundle(name)?.model.num_tokens();
        let mita_name = format!("f5_mita_n{n}");
        if rt.manifest().bundle(&mita_name).is_err() {
            continue;
        }
        let std_f = flops::attention_flops(&rt.manifest().bundle(name)?.model);
        let mita_f = flops::attention_flops(&rt.manifest().bundle(&mita_name)?.model);
        out.row(&[
            n.to_string(),
            flops::gflops(std_f),
            flops::gflops(mita_f),
            speedup(std_f / mita_f),
        ]);
    }
    let rendered = format!("## Complexity (attention FLOPs vs N)\n{}", out.render());
    println!("{rendered}");
    Ok(rendered)
}
