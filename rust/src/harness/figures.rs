//! Figure drivers (Figs. 3/4/5/8/9/10).

use anyhow::Result;

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::server::{serve, ServeConfig};
use crate::coordinator::Engine;
use crate::data::{BatchSource, ImageCorpus, Split};
use crate::harness::train_or_load_checkpoint;
use crate::mita::analysis;
use crate::report::{ascii_chart, pct, speedup, Table};
use crate::runtime::{Runtime, Tensor};

/// Fig. 5 — inference throughput vs sequence length, standard vs MiTA,
/// measured end-to-end through the dynamic-batching server.
pub fn figure5(artifacts_dir: &std::path::Path, rt: &Runtime, requests: usize) -> Result<String> {
    let lens: Vec<usize> = rt
        .manifest()
        .bundles_with_prefix("f5_standard_n")
        .iter()
        .map(|b| rt.manifest().bundle(b).unwrap().model.num_tokens())
        .collect();

    let mut out = Table::new(&["N", "standard req/s", "MiTA req/s", "speedup", "MiTA p95 ms"]);
    let mut series_std = Vec::new();
    let mut series_mita = Vec::new();

    for &n in &lens {
        let mut rps = std::collections::HashMap::new();
        let mut p95 = 0.0;
        for method in ["standard", "mita"] {
            let bundle_name = format!("f5_{method}_n{n}");
            let spec = rt.manifest().bundle(&bundle_name)?.clone();
            let predict = rt.manifest().bundle_artifact(&bundle_name, "predict")?.to_string();
            let init = rt.manifest().bundle_artifact(&bundle_name, "init")?.to_string();
            let engine = Engine::spawn(artifacts_dir.to_path_buf(), vec![predict])?;
            engine.handle().bind_init(&bundle_name, &init, 0, spec.param_count())?;
            let cfg = ServeConfig {
                bundle: bundle_name.clone(),
                binding: bundle_name.clone(),
                requests,
                rate: 0.0, // closed loop: measures peak throughput
                queue_cap: requests,
                max_inflight: crate::coordinator::DEFAULT_MAX_INFLIGHT,
                policy: BatchPolicy {
                    max_batch: spec.train.batch_size,
                    max_wait: std::time::Duration::from_millis(2),
                },
            };
            let report = serve(&engine.handle(), &spec, &bundle_name, &cfg)?;
            eprintln!("[f5] {}", report.row());
            rps.insert(method, report.throughput_rps);
            if method == "mita" {
                p95 = report.p95_ms;
            }
            engine.shutdown();
        }
        let s = rps["standard"];
        let m = rps["mita"];
        out.row(&[
            n.to_string(),
            format!("{s:.2}"),
            format!("{m:.2}"),
            speedup(m / s),
            format!("{p95:.1}"),
        ]);
        series_std.push((n as f64, s));
        series_mita.push((n as f64, m));
    }

    let chart = ascii_chart(&[("standard", series_std), ("mita", series_mita)], 60, 12);
    let rendered = format!("## Figure 5 — inference throughput vs N\n{}\n{}", out.render(), chart);
    println!("{rendered}");
    Ok(rendered)
}

/// Run the analysis artifact on one image with a trained t2_mita model.
fn run_analysis(
    rt: &Runtime,
    seed: i32,
) -> Result<(Vec<Tensor>, usize, usize, usize, usize, usize)> {
    let params = train_or_load_checkpoint(rt, "t2_mita", seed)?;
    let bundle = rt.manifest().bundle("fig_analysis_mita")?.clone();
    anyhow::ensure!(
        bundle.param_count() == params.len(),
        "analysis bundle layout mismatch"
    );
    let m = bundle.model.attention.m;
    let kk = bundle.model.attention.k;
    let depth = bundle.model.depth;
    let heads = bundle.model.heads;
    let n = bundle.model.num_tokens();

    let corpus = ImageCorpus::new(
        bundle.model.image_hw.0,
        bundle.model.image_hw.1,
        bundle.model.channels,
        bundle.model.num_classes,
        8,
        crate::data::loader::DEFAULT_SEED,
    );
    let (pixels, _, _) = corpus.render(Split::Val, 0);
    let x = Tensor::f32(
        &[bundle.model.image_hw.0, bundle.model.image_hw.1, bundle.model.channels],
        pixels,
    )?;

    let mut inputs = params;
    inputs.push(x);
    let art = rt.manifest().bundle_artifact("fig_analysis_mita", "analysis")?;
    let outs = rt.run(art, &inputs)?;
    Ok((outs, depth, heads, m, kk, n))
}

/// Figs. 3/4 — expert key-value heatmaps + the token-pruning effect.
pub fn figures34(rt: &Runtime, seed: i32) -> Result<String> {
    let (outs, depth, heads, m, kk, n) = run_analysis(rt, seed)?;
    let idx = outs[1].as_i32()?; // [depth, heads, m, kk]
    let (gh, gw) = {
        let b = rt.manifest().bundle("fig_analysis_mita")?;
        b.model.grid_hw()
    };

    let mut rendered = String::from("## Figures 3/4 — expert selections + token pruning\n");
    let mut fractions = Vec::new();
    for layer in 0..depth {
        // Aggregate selected tokens over heads (as the paper does).
        let mut all: Vec<usize> = Vec::with_capacity(heads * m * kk);
        for h in 0..heads {
            let base = ((layer * heads) + h) * m * kk;
            all.extend(idx[base..base + m * kk].iter().map(|&v| v as usize));
        }
        let frac = analysis::selected_token_fraction(&all, n);
        fractions.push(frac);
        let counts = analysis::selection_counts(&all, n);
        rendered.push_str(&format!(
            "\nlayer {layer}: {:.1}% of tokens selected by >=1 expert\n{}",
            frac * 100.0,
            analysis::ascii_heatmap(&counts, gh, gw)
        ));
    }
    rendered.push_str("\nToken-pruning trend (selected fraction per layer): ");
    rendered.push_str(
        &fractions.iter().map(|f| format!("{:.2}", f)).collect::<Vec<_>>().join(" → "),
    );
    rendered.push('\n');
    println!("{rendered}");
    Ok(rendered)
}

/// Fig. 8 — layer-wise positional overlap (expert KV vs routed queries).
pub fn figure8(rt: &Runtime, seed: i32) -> Result<String> {
    let (outs, depth, heads, m, kk, n) = run_analysis(rt, seed)?;
    let idx = outs[1].as_i32()?; // [depth, heads, m, kk]
    let assign = outs[2].as_i32()?; // [depth, heads, n]

    let mut out = Table::new(&["layer", "overlap mIoU"]);
    let mut series = Vec::new();
    for layer in 0..depth {
        let mut per_head = Vec::new();
        for h in 0..heads {
            let ib = ((layer * heads) + h) * m * kk;
            let ab = ((layer * heads) + h) * n;
            let topk: Vec<usize> = idx[ib..ib + m * kk].iter().map(|&v| v as usize).collect();
            let asg: Vec<usize> = assign[ab..ab + n].iter().map(|&v| v as usize).collect();
            per_head.push(analysis::expert_query_overlap(&topk, &asg, m, kk));
        }
        let mean = per_head.iter().sum::<f64>() / per_head.len() as f64;
        out.row(&[layer.to_string(), format!("{mean:.3}")]);
        series.push((layer as f64, mean));
    }
    let chart = ascii_chart(&[("overlap", series)], 40, 8);
    let rendered = format!(
        "## Figure 8 — expert/query positional overlap (routing ≠ clustering)\n{}\n{}",
        out.render(),
        chart
    );
    println!("{rendered}");
    Ok(rendered)
}

/// Fig. 9 — train-with-X / infer-with-Y attention swap matrix.
pub fn figure9(rt: &Runtime, seed: i32) -> Result<String> {
    let kinds = ["std", "agent", "mita"];
    // Checkpoints come from the t2 bundles (same param layout across kinds).
    let mut out = Table::new(&["train \\ infer", "std", "agent", "mita"]);
    for train_kind in kinds {
        let params = train_or_load_checkpoint(rt, &format!("t2_{train_kind}"), seed)?;
        let lits: Vec<xla::Literal> =
            params.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
        let mut cells = vec![train_kind.to_string()];
        for infer_kind in kinds {
            let eval_bundle = format!("f9_eval_{infer_kind}");
            let spec = rt.manifest().bundle(&eval_bundle)?.clone();
            let art = rt.manifest().bundle_artifact(&eval_bundle, "eval_step")?;
            let source = BatchSource::for_bundle(&spec)?;
            let ev = crate::coordinator::trainer::eval_params(
                rt, art, &lits, &source, 16, false, spec.model.num_classes,
            )?;
            cells.push(pct(ev.accuracy));
            eprintln!("[f9] train={train_kind} infer={infer_kind}: acc={:.3}", ev.accuracy);
        }
        out.row(&cells);
    }
    let rendered = format!("## Figure 9 — algorithmic generalization matrix\n{}", out.render());
    println!("{rendered}");
    Ok(rendered)
}

/// Fig. 10 — inference (m, k) generalization grid for a trained MiTA model.
pub fn figure10(rt: &Runtime, seed: i32) -> Result<String> {
    let params = train_or_load_checkpoint(rt, "t2_mita", seed)?;
    let lits: Vec<xla::Literal> = params.iter().map(Tensor::to_literal).collect::<Result<_>>()?;

    // Discover the grid from the manifest.
    let mut ms = std::collections::BTreeSet::new();
    let mut ks = std::collections::BTreeSet::new();
    for name in rt.manifest().bundles_with_prefix("f10_eval_") {
        let b = rt.manifest().bundle(name)?;
        ms.insert(b.model.attention.m);
        ks.insert(b.model.attention.k);
    }

    let mut header = vec!["m \\ k".to_string()];
    header.extend(ks.iter().map(|k| k.to_string()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut out = Table::new(&header_refs);

    for &m in &ms {
        let mut cells = vec![m.to_string()];
        for &k in &ks {
            let bundle_name = format!("f10_eval_m{m}k{k}");
            let spec = rt.manifest().bundle(&bundle_name)?.clone();
            let art = rt.manifest().bundle_artifact(&bundle_name, "eval_step")?;
            let source = BatchSource::for_bundle(&spec)?;
            let ev = crate::coordinator::trainer::eval_params(
                rt, art, &lits, &source, 16, false, spec.model.num_classes,
            )?;
            cells.push(pct(ev.accuracy));
        }
        out.row(&cells);
        eprintln!("[f10] m={m} done");
    }
    let rendered = format!(
        "## Figure 10 — (m, k) generalization of a model trained at m=k=16\n{}",
        out.render()
    );
    println!("{rendered}");
    Ok(rendered)
}

/// Loss-curve chart for a freshly trained bundle (E2E driver visual).
pub fn loss_curve_chart(curve: &[(f64, f64)], name: &str) -> String {
    ascii_chart(&[(name, curve.to_vec())], 60, 12)
}
