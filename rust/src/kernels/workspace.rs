//! Reusable scratch-memory arena for the native attention kernels.
//!
//! [`Workspace`] owns named scratch buffers that kernels check out by name
//! and hand back when done. Buffers keep their capacity across calls, so a
//! kernel running repeatedly at one problem shape performs **zero heap
//! allocations** after the first (warm-up) call — the steady state the
//! serving hot path cares about. The take/give protocol moves buffers out
//! of the arena as owned `Vec`s and returns them afterwards, which
//! sidesteps the aliasing limits of handing out several `&mut` slices from
//! one arena at once.
//!
//! [`WorkspacePool`] is the thread-safe extension: the batched executor
//! ([`crate::kernels::api::run_batched`]) checks one workspace out per
//! (example × head) work item (two brief pool-mutex operations per item),
//! so every worker thread reuses warm buffers instead of allocating. Each
//! pooled entry also carries a [`MitaStats`] accumulator, so kernels
//! record routing statistics lock-free into the workspace they already
//! hold — no separate shared stats mutex, no per-item stats allocation;
//! [`WorkspacePool::collect_stats`] drains them once the parallel region
//! has joined.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::kernels::api::MitaStats;

/// Named scratch buffers with stable (high-water-mark) capacity.
#[derive(Debug, Default)]
pub struct Workspace {
    f32s: Vec<(&'static str, Vec<f32>)>,
    usizes: Vec<(&'static str, Vec<usize>)>,
}

impl Workspace {
    /// An empty arena; buffers materialize on first take (warm-up).
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Check out the f32 buffer `name`, sized to exactly `len`. Contents
    /// are **unspecified** (zero on first growth, stale data from the
    /// previous checkout otherwise) — callers must write every element
    /// they later read, which is what lets the steady state skip both the
    /// allocator and a redundant memset. Allocates only if the buffer has
    /// never been this large before.
    pub fn take_f32(&mut self, name: &'static str, len: usize) -> Vec<f32> {
        let mut buf = match self.f32s.iter().position(|(n, _)| *n == name) {
            Some(i) => self.f32s.swap_remove(i).1,
            None => Vec::new(),
        };
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer checked out with [`Workspace::take_f32`], parking
    /// its capacity for the next call.
    pub fn give_f32(&mut self, name: &'static str, buf: Vec<f32>) {
        debug_assert!(
            self.f32s.iter().all(|(n, _)| *n != name),
            "workspace buffer {name} given back twice"
        );
        self.f32s.push((name, buf));
    }

    /// Check out the usize buffer `name`, sized to exactly `len`. Same
    /// contract as [`Workspace::take_f32`]: contents are unspecified,
    /// callers must write every element they later read.
    pub fn take_usize(&mut self, name: &'static str, len: usize) -> Vec<usize> {
        let mut buf = match self.usizes.iter().position(|(n, _)| *n == name) {
            Some(i) => self.usizes.swap_remove(i).1,
            None => Vec::new(),
        };
        buf.resize(len, 0);
        buf
    }

    /// Return a buffer checked out with [`Workspace::take_usize`].
    pub fn give_usize(&mut self, name: &'static str, buf: Vec<usize>) {
        debug_assert!(
            self.usizes.iter().all(|(n, _)| *n != name),
            "workspace buffer {name} given back twice"
        );
        self.usizes.push((name, buf));
    }

    /// Total f32 capacity parked in the arena — the allocation high-water
    /// mark. Stable across steady-state kernel calls.
    pub fn f32_capacity(&self) -> usize {
        self.f32s.iter().map(|(_, b)| b.capacity()).sum()
    }

    /// Total usize capacity parked in the arena.
    pub fn usize_capacity(&self) -> usize {
        self.usizes.iter().map(|(_, b)| b.capacity()).sum()
    }

    /// Number of parked buffers (every take must have been given back).
    pub fn buffer_count(&self) -> usize {
        self.f32s.len() + self.usizes.len()
    }
}

/// Thread-safe pool of [`Workspace`]s (plus per-workspace [`MitaStats`]
/// accumulators) for parallel work-item execution.
#[derive(Debug, Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<(Workspace, MitaStats)>>,
    created: AtomicUsize,
}

impl WorkspacePool {
    /// An empty pool; workspaces are created on demand, bounded by the
    /// number of threads that hold one concurrently.
    pub fn new() -> Self {
        WorkspacePool::default()
    }

    /// Check a workspace out (reusing an idle one when available). The
    /// guard returns it on drop.
    pub fn acquire(&self) -> PooledWorkspace<'_> {
        let entry = self.free.lock().unwrap().pop().unwrap_or_else(|| {
            self.created.fetch_add(1, Ordering::Relaxed);
            (Workspace::new(), MitaStats::default())
        });
        PooledWorkspace { pool: self, entry: Some(entry) }
    }

    /// Workspaces ever created — stable once the pool is warm (steady
    /// state reuses instead of allocating).
    pub fn created(&self) -> usize {
        self.created.load(Ordering::Relaxed)
    }

    /// Workspaces currently parked in the pool.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Merge (and reset) the stats accumulated by every idle workspace
    /// into `into`. Call after the parallel region has joined — while
    /// workspaces are checked out their stats are not visible here.
    pub fn collect_stats(&self, into: &mut MitaStats) {
        for (_, stats) in self.free.lock().unwrap().iter_mut() {
            into.merge(stats);
            stats.reset();
        }
    }
}

/// RAII guard over a pooled workspace; returns it to the pool on drop.
#[derive(Debug)]
pub struct PooledWorkspace<'a> {
    pool: &'a WorkspacePool,
    entry: Option<(Workspace, MitaStats)>,
}

impl PooledWorkspace<'_> {
    /// Split borrows of the workspace and its stats accumulator (kernels
    /// take them as two separate `&mut` arguments).
    pub fn parts(&mut self) -> (&mut Workspace, &mut MitaStats) {
        let entry = self.entry.as_mut().expect("pooled workspace already returned");
        (&mut entry.0, &mut entry.1)
    }
}

impl Drop for PooledWorkspace<'_> {
    fn drop(&mut self) {
        if let Some(entry) = self.entry.take() {
            self.pool.free.lock().unwrap().push(entry);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_capacity_without_rezeroing() {
        let mut ws = Workspace::new();
        let mut buf = ws.take_f32("a", 64);
        assert_eq!(buf.len(), 64);
        assert!(buf.iter().all(|&x| x == 0.0), "first growth is zero-filled");
        buf.iter_mut().for_each(|x| *x = 7.0);
        ws.give_f32("a", buf);
        let cap = ws.f32_capacity();

        // Same size: reuse with NO memset (contents are unspecified by
        // contract — here the previous checkout's data), capacity stable.
        let buf = ws.take_f32("a", 64);
        assert_eq!(buf.len(), 64);
        assert!(buf.iter().all(|&x| x == 7.0), "steady-state take must not re-zero");
        ws.give_f32("a", buf);
        assert_eq!(ws.f32_capacity(), cap);

        // Smaller: shorter view, capacity keeps the high-water mark.
        let buf = ws.take_f32("a", 8);
        assert_eq!(buf.len(), 8);
        ws.give_f32("a", buf);
        assert_eq!(ws.f32_capacity(), cap);
        assert_eq!(ws.buffer_count(), 1);

        // Growing again re-fills only the growth.
        let buf = ws.take_f32("a", 64);
        assert_eq!(buf.len(), 64);
        ws.give_f32("a", buf);
        assert_eq!(ws.f32_capacity(), cap);
    }

    #[test]
    fn distinct_names_are_distinct_buffers() {
        let mut ws = Workspace::new();
        let a = ws.take_usize("a", 4);
        let b = ws.take_usize("b", 6);
        assert_eq!((a.len(), b.len()), (4, 6));
        ws.give_usize("a", a);
        ws.give_usize("b", b);
        assert_eq!(ws.buffer_count(), 2);
        assert!(ws.usize_capacity() >= 10);
    }

    #[test]
    fn pool_reuses_workspaces_and_collects_stats() {
        let pool = WorkspacePool::new();
        {
            let mut g = pool.acquire();
            let (ws, stats) = g.parts();
            let buf = ws.take_f32("x", 16);
            ws.give_f32("x", buf);
            stats.record(4, 1, &[2, 3]);
        }
        assert_eq!(pool.created(), 1);
        assert_eq!(pool.idle(), 1);

        // Re-acquire: same workspace comes back, nothing new created.
        {
            let mut g = pool.acquire();
            let (ws, _) = g.parts();
            assert_eq!(ws.buffer_count(), 1);
        }
        assert_eq!(pool.created(), 1);

        let mut total = MitaStats::default();
        pool.collect_stats(&mut total);
        assert_eq!(total.overflow, 1);
        assert_eq!(total.queries, 5);
        // Stats were reset at collection: a second drain adds nothing.
        pool.collect_stats(&mut total);
        assert_eq!(total.queries, 5);
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        let pool = WorkspacePool::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..8 {
                        let mut g = pool.acquire();
                        let (ws, _) = g.parts();
                        let buf = ws.take_f32("t", 32);
                        ws.give_f32("t", buf);
                    }
                });
            }
        });
        assert!(pool.created() >= 1 && pool.created() <= 4);
        assert_eq!(pool.idle(), pool.created());
    }
}
