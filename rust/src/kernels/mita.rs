//! Native MiTA attention forward pass (Alg. 1 of the paper, CPU edition).
//!
//! The N-width fast-weight MLP is compressed by `m` landmark queries
//! (adaptive average pooling over Q), each landmark gathers its top-`k`
//! activated key-value pairs into a deformable expert, and every real query
//! is argmax-routed to exactly one expert. Routing semantics are *reused*
//! from [`crate::mita::routing`] — the same functions the property tests
//! pin against kernels/ref.py — so the native path and the Pallas kernel
//! share one definition of the math.
//!
//! The kernel is deliberately **serial and allocation-free**: every scratch
//! buffer comes from a [`Workspace`], so repeated calls at one problem
//! shape never touch the allocator, and parallelism lives one level up —
//! the batched executor in [`crate::kernels::api`] schedules whole
//! (example × head) problems across threads with pooled workspaces.
//! Queries grouped by expert execute together (the expert's gathered KV
//! stays hot), and queries that overflow an expert's capacity are not
//! dropped (unlike the static-shape Pallas kernel): they fall back to an
//! unpacked per-query pass over the same expert KV, so the native output
//! is exact for every query.

use std::time::Instant;

use crate::kernels::api::MitaStats;
use crate::kernels::linalg::{
    axpy, dot, gather_head, matmul_nt, scale_in_place, scatter_head, softmax_in_place,
};
use crate::kernels::profile::{self, Op};
use crate::kernels::workspace::Workspace;
use crate::mita::routing;

/// Shape-independent MiTA kernel parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MitaKernelConfig {
    /// Landmark / expert count (m in the paper).
    pub m: usize,
    /// KV pairs gathered per expert (k in the paper).
    pub k: usize,
    /// Query capacity factor over the mean per-expert load.
    pub cap_factor: usize,
    /// Capacity rounding granularity (the kernel's query block).
    pub block_q: usize,
}

impl Default for MitaKernelConfig {
    fn default() -> Self {
        MitaKernelConfig { m: 16, k: 64, cap_factor: 2, block_q: 16 }
    }
}

impl MitaKernelConfig {
    /// Paper-flavored defaults for a sequence length: m ≈ √n landmarks
    /// (clamped to [4, 64]), k = 4·(n/m) gathered KV per expert.
    pub fn for_seq(n: usize) -> Self {
        let m = (n as f64).sqrt().round() as usize;
        let m = m.clamp(4, 64).min(n.max(1));
        let k = (4 * n.div_ceil(m)).min(n.max(1));
        MitaKernelConfig { m, k, cap_factor: 2, block_q: 16 }
    }

    /// Clamp to a concrete sequence length (m, k ≤ n; everything ≥ 1).
    /// `pub(crate)` so the training backward clamps identically.
    pub(crate) fn clamped(self, n: usize) -> Self {
        MitaKernelConfig {
            m: self.m.clamp(1, n.max(1)),
            k: self.k.clamp(1, n.max(1)),
            cap_factor: self.cap_factor.max(1),
            block_q: self.block_q.max(1),
        }
    }
}

/// Steps 1–4 of Alg. 1 — the kernel's *selection structure*: landmark
/// pooling over Q, blocked landmark scores S = K Q̃ᵀ/√d, top-k KV picks
/// per landmark, and argmax routing of every query (blocked logits
/// Q Q̃ᵀ; dot products run in the same order as
/// `routing::route_argmax`'s scalar loop and ties keep the lower expert
/// id, so the assignment is bit-identical to it). All outputs land in
/// caller-provided buffers.
///
/// This helper is shared **verbatim** by the forward kernel and the
/// straight-through training backward
/// ([`crate::train::backward::mita_attention_backward`]): the backward
/// treats these selections as constants, which is only exact if it
/// recomputes precisely the indices the forward used — one function, no
/// drift. `cfg` must already be clamped to `n`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn select_experts(
    q: &[f32],
    kmat: &[f32],
    n: usize,
    d: usize,
    cfg: &MitaKernelConfig,
    landmarks: &mut [f32],
    s: &mut [f32],
    col: &mut [f32],
    order: &mut [usize],
    topk: &mut [usize],
    route_logits: &mut [f32],
    assign: &mut [usize],
) {
    let (m, kk) = (cfg.m, cfg.k);
    debug_assert_eq!(landmarks.len(), m * d);
    debug_assert_eq!(s.len(), n * m);
    debug_assert_eq!(route_logits.len(), n * m);
    debug_assert_eq!(topk.len(), m * kk);
    let scale = 1.0 / (d as f32).sqrt();
    // Profiler brackets time each phase without touching its arithmetic
    // (the bit-parity contract with the training backward is on the
    // computed values, which the clock reads cannot observe).
    let t = Instant::now();
    routing::landmarks_pool1d_into(q, n, d, m, landmarks);
    profile::record_since(Op::MitaLandmarks, t);
    let t = Instant::now();
    matmul_nt(kmat, landmarks, n, m, d, s);
    // The positive scale is applied *before* top-k on purpose: dropping
    // it would be mathematically order-preserving but could collapse
    // near-equal scores differently after rounding and flip a tie-break.
    scale_in_place(s, scale);
    profile::record_since(Op::MitaScores, t);
    let t = Instant::now();
    routing::topk_indices_into(s, n, m, kk, col, order, topk);
    profile::record_since(Op::MitaTopk, t);
    let t = Instant::now();
    matmul_nt(q, landmarks, n, m, d, route_logits);
    for (a, row) in assign.iter_mut().zip(route_logits.chunks_exact(m)) {
        let mut best = 0usize;
        for (i, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = i;
            }
        }
        *a = best;
    }
    profile::record_since(Op::MitaRoute, t);
}

/// One query row attending over an expert's gathered KV (indices into the
/// original K/V, no copies). `orow` is overwritten. `pub(crate)` so the
/// causal decode path (`crate::decode`) runs the identical expert-row
/// attention arithmetic instead of re-deriving it.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_one(
    qrow: &[f32],
    picks: &[usize],
    kmat: &[f32],
    v: &[f32],
    d: usize,
    scale: f32,
    logits: &mut [f32],
    orow: &mut [f32],
) {
    debug_assert_eq!(logits.len(), picks.len());
    for (l, &ki) in logits.iter_mut().zip(picks) {
        *l = dot(qrow, &kmat[ki * d..(ki + 1) * d]) * scale;
    }
    softmax_in_place(logits);
    orow.fill(0.0);
    for (&w, &ki) in logits.iter().zip(picks) {
        axpy(w, &v[ki * d..(ki + 1) * d], orow);
    }
}

/// Single-head MiTA forward over row-major `[n, d]` Q/K/V, scratch from
/// `ws`. Writes `[n, d]` into `out` and records routing statistics into
/// `stats` (a fresh `MitaStats::default()` captures exactly this call).
/// Zero heap allocations once `ws` has served this problem size.
#[allow(clippy::too_many_arguments)]
pub fn mita_attention(
    q: &[f32],
    kmat: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    cfg: &MitaKernelConfig,
    ws: &mut Workspace,
    out: &mut [f32],
    stats: &mut MitaStats,
) {
    assert_eq!(q.len(), n * d, "q must be [n, d]");
    assert_eq!(kmat.len(), n * d, "k must be [n, d]");
    assert_eq!(v.len(), n * d, "v must be [n, d]");
    assert_eq!(out.len(), n * d, "out must be [n, d]");
    if n == 0 || d == 0 {
        return;
    }
    let cfg = cfg.clamped(n);
    let (m, kk) = (cfg.m, cfg.k);
    let scale = 1.0 / (d as f32).sqrt();

    // 1–4. Selection structure (landmarks → scores → top-k experts →
    //    argmax routing), via the helper shared with the training
    //    backward — see `select_experts` — then capacity packing
    //    (DESIGN.md §6 semantics).
    let mut landmarks = ws.take_f32("mita.landmarks", m * d);
    let mut s = ws.take_f32("mita.scores", n * m);
    let mut col = ws.take_f32("mita.topk_col", n);
    let mut order = ws.take_usize("mita.order", n);
    let mut topk = ws.take_usize("mita.topk", m * kk);
    let mut route_logits = ws.take_f32("mita.route", n * m);
    let mut assign = ws.take_usize("mita.assign", n);
    select_experts(
        q,
        kmat,
        n,
        d,
        &cfg,
        &mut landmarks,
        &mut s,
        &mut col,
        &mut order,
        &mut topk,
        &mut route_logits,
        &mut assign,
    );
    let t_pack = Instant::now();
    let cap = routing::capacity(n, m, cfg.cap_factor, cfg.block_q);
    let mut counts = ws.take_usize("mita.counts", m);
    let mut slot = ws.take_usize("mita.slot", n);
    let overflow = routing::pack_into(&assign, m, cap, &mut counts, &mut slot);

    // 5. Expert-grouped attention straight into `out`: queries execute in
    //    (expert, arrival-rank) order so each expert's gathered KV stays
    //    hot, but every row lands at its own query position — no packed
    //    intermediate or scatter pass needed in the serial kernel.
    let mut packed_qi = ws.take_usize("mita.packed_qi", m * cap);
    for (qi, &sl) in slot.iter().enumerate() {
        if sl != routing::OVERFLOW {
            packed_qi[sl] = qi;
        }
    }
    profile::record_since(Op::MitaPack, t_pack);
    let t_attend = Instant::now();
    let mut logits = ws.take_f32("mita.logits", kk);
    for e in 0..m {
        let picks = &topk[e * kk..(e + 1) * kk];
        let filled = counts[e].min(cap);
        for &qi in &packed_qi[e * cap..e * cap + filled] {
            attend_one(
                &q[qi * d..(qi + 1) * d],
                picks,
                kmat,
                v,
                d,
                scale,
                &mut logits,
                &mut out[qi * d..(qi + 1) * d],
            );
        }
    }

    profile::record_since(Op::MitaAttend, t_attend);

    // 6. Overflowed queries: unpacked fallback over the same expert KV, so
    //    the native output stays exact under skewed routing. The phase is
    //    profiled only when it actually runs, so `op_calls_total` for
    //    `mita.overflow` counts calls that overflowed.
    if overflow > 0 {
        let t_overflow = Instant::now();
        for (qi, &sl) in slot.iter().enumerate() {
            if sl == routing::OVERFLOW {
                let e = assign[qi];
                let picks = &topk[e * kk..(e + 1) * kk];
                attend_one(
                    &q[qi * d..(qi + 1) * d],
                    picks,
                    kmat,
                    v,
                    d,
                    scale,
                    &mut logits,
                    &mut out[qi * d..(qi + 1) * d],
                );
            }
        }
        profile::record_since(Op::MitaOverflow, t_overflow);
    }

    stats.record(cap, overflow, &counts);

    ws.give_f32("mita.landmarks", landmarks);
    ws.give_f32("mita.scores", s);
    ws.give_f32("mita.topk_col", col);
    ws.give_f32("mita.route", route_logits);
    ws.give_f32("mita.logits", logits);
    ws.give_usize("mita.order", order);
    ws.give_usize("mita.topk", topk);
    ws.give_usize("mita.assign", assign);
    ws.give_usize("mita.counts", counts);
    ws.give_usize("mita.slot", slot);
    ws.give_usize("mita.packed_qi", packed_qi);
}

/// Multi-head MiTA over model-dim layout `[n, dim]` (`dim = heads · dh`),
/// with independent routing per head. Head results accumulate into `stats`
/// (total overflow across heads is `stats.overflow`).
#[allow(clippy::too_many_arguments)]
pub fn mita_attention_mh(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    heads: usize,
    dim: usize,
    cfg: &MitaKernelConfig,
    ws: &mut Workspace,
    out: &mut [f32],
    stats: &mut MitaStats,
) {
    assert!(heads >= 1 && dim % heads == 0, "dim {dim} must divide into {heads} heads");
    assert_eq!(out.len(), n * dim, "out must be [n, dim]");
    if n == 0 || dim == 0 {
        return;
    }
    let dh = dim / heads;
    let mut qh = ws.take_f32("mh.q", n * dh);
    let mut kh = ws.take_f32("mh.k", n * dh);
    let mut vh = ws.take_f32("mh.v", n * dh);
    let mut oh = ws.take_f32("mh.out", n * dh);
    for h in 0..heads {
        gather_head(q, n, dim, dh, h, &mut qh);
        gather_head(k, n, dim, dh, h, &mut kh);
        gather_head(v, n, dim, dh, h, &mut vh);
        mita_attention(&qh, &kh, &vh, n, dh, cfg, ws, &mut oh, stats);
        scatter_head(&oh, n, dim, dh, h, out);
    }
    ws.give_f32("mh.q", qh);
    ws.give_f32("mh.k", kh);
    ws.give_f32("mh.v", vh);
    ws.give_f32("mh.out", oh);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::kernels::dense::dense_attention;

    fn rand_qkv(rng: &mut Rng, n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut gen = |len: usize| (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect::<Vec<_>>();
        (gen(n * d), gen(n * d), gen(n * d))
    }

    #[test]
    fn degenerate_full_attention_matches_dense() {
        // m = n, k = n: every landmark is one query, every expert gathers
        // the full KV set, so MiTA must reduce to dense attention.
        let mut rng = Rng::new(21);
        let mut ws = Workspace::new();
        for (n, d) in [(8, 4), (33, 8), (64, 16)] {
            let (q, k, v) = rand_qkv(&mut rng, n, d);
            let cfg = MitaKernelConfig { m: n, k: n, cap_factor: 2, block_q: 8 };
            let mut got = vec![0.0f32; n * d];
            let mut stats = MitaStats::default();
            mita_attention(&q, &k, &v, n, d, &cfg, &mut ws, &mut got, &mut stats);
            let mut want = vec![0.0f32; n * d];
            dense_attention(&q, &k, &v, n, d, &mut ws, &mut want);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!((g - w).abs() < 1e-4, "n={n} d={d} elem {i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn identical_queries_survive_overflow() {
        // All queries identical ⇒ all route to one expert ⇒ massive
        // overflow; every output row must still be identical because the
        // fallback pass computes the same expert attention.
        let (n, d) = (24, 4);
        let q = vec![0.7f32; n * d];
        let mut rng = Rng::new(9);
        let k: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let v: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let cfg = MitaKernelConfig { m: 4, k: 8, cap_factor: 1, block_q: 1 };
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; n * d];
        let mut stats = MitaStats::default();
        mita_attention(&q, &k, &v, n, d, &cfg, &mut ws, &mut out, &mut stats);
        assert!(stats.overflow > 0, "test must exercise the overflow path");
        let first = &out[..d];
        for r in 1..n {
            for c in 0..d {
                assert!(
                    (out[r * d + c] - first[c]).abs() < 1e-5,
                    "row {r} diverged despite identical queries"
                );
            }
        }
    }

    #[test]
    fn stats_are_consistent() {
        let mut rng = Rng::new(33);
        let (n, d) = (50, 8);
        let (q, k, v) = rand_qkv(&mut rng, n, d);
        let cfg = MitaKernelConfig { m: 5, k: 12, cap_factor: 2, block_q: 4 };
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; n * d];
        let mut stats = MitaStats::default();
        mita_attention(&q, &k, &v, n, d, &cfg, &mut ws, &mut out, &mut stats);
        assert_eq!(stats.calls, 1);
        assert_eq!(stats.queries, n);
        assert_eq!(stats.expert_counts.len(), 5);
        assert_eq!(stats.expert_counts.iter().sum::<usize>(), n);
        assert_eq!(stats.cap % 4, 0);
        let expect_overflow: usize =
            stats.expert_counts.iter().map(|&c| c.saturating_sub(stats.cap)).sum();
        assert_eq!(stats.overflow, expect_overflow);
    }

    #[test]
    fn config_clamps_to_sequence() {
        let cfg = MitaKernelConfig { m: 100, k: 100, cap_factor: 0, block_q: 0 };
        let (n, d) = (6, 3);
        let mut rng = Rng::new(2);
        let (q, k, v) = rand_qkv(&mut rng, n, d);
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; n * d];
        let mut stats = MitaStats::default();
        mita_attention(&q, &k, &v, n, d, &cfg, &mut ws, &mut out, &mut stats);
        assert_eq!(stats.expert_counts.len(), n); // m clamped to n
        assert!(out.iter().all(|x| x.is_finite()));
        let auto = MitaKernelConfig::for_seq(1024);
        assert!(auto.m >= 4 && auto.m <= 64 && auto.k <= 1024);
    }

    #[test]
    fn multihead_equals_per_head_calls() {
        let mut rng = Rng::new(8);
        let (n, heads, dh) = (40, 2, 8);
        let dim = heads * dh;
        let gen = |rng: &mut Rng, len: usize| {
            (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect::<Vec<f32>>()
        };
        let q = gen(&mut rng, n * dim);
        let k = gen(&mut rng, n * dim);
        let v = gen(&mut rng, n * dim);
        let cfg = MitaKernelConfig { m: 8, k: 16, cap_factor: 2, block_q: 8 };
        let mut ws = Workspace::new();
        let mut got = vec![0.0f32; n * dim];
        let mut stats = MitaStats::default();
        mita_attention_mh(&q, &k, &v, n, heads, dim, &cfg, &mut ws, &mut got, &mut stats);
        assert_eq!(stats.calls, heads);
        assert_eq!(stats.queries, heads * n);

        let mut want = vec![0.0f32; n * dim];
        let mut qh = vec![0.0f32; n * dh];
        let mut kh = vec![0.0f32; n * dh];
        let mut vh = vec![0.0f32; n * dh];
        let mut oh = vec![0.0f32; n * dh];
        for h in 0..heads {
            gather_head(&q, n, dim, dh, h, &mut qh);
            gather_head(&k, n, dim, dh, h, &mut kh);
            gather_head(&v, n, dim, dh, h, &mut vh);
            let mut st = MitaStats::default();
            mita_attention(&qh, &kh, &vh, n, dh, &cfg, &mut ws, &mut oh, &mut st);
            scatter_head(&oh, n, dim, dh, h, &mut want);
        }
        assert_eq!(got, want);
    }

    #[test]
    fn workspace_capacity_is_stable_after_warmup() {
        // The acceptance gate for the zero-alloc refactor: one workspace
        // serving repeated kernel calls must stop growing after the first
        // (warm-up) call — steady-state calls take and give back the same
        // buffers without touching the allocator.
        let mut rng = Rng::new(55);
        let (n, heads, dim) = (96, 4, 32);
        let (q, k, v) = rand_qkv(&mut rng, n, dim);
        let cfg = MitaKernelConfig::for_seq(n);
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; n * dim];
        let mut stats = MitaStats::default();

        fn snapshot(ws: &Workspace, stats: &MitaStats) -> (usize, usize, usize, usize) {
            let counts_cap = stats.expert_counts.capacity();
            (ws.f32_capacity(), ws.usize_capacity(), ws.buffer_count(), counts_cap)
        }

        mita_attention_mh(&q, &k, &v, n, heads, dim, &cfg, &mut ws, &mut out, &mut stats);
        dense_attention(&q, &k, &v, n, dim, &mut ws, &mut out);
        let warm = snapshot(&ws, &stats);

        let first_out = out.clone();
        for _ in 0..4 {
            mita_attention_mh(&q, &k, &v, n, heads, dim, &cfg, &mut ws, &mut out, &mut stats);
            dense_attention(&q, &k, &v, n, dim, &mut ws, &mut out);
            assert_eq!(snapshot(&ws, &stats), warm, "workspace must not grow in steady state");
        }
        // Same inputs through a warm workspace still give the same answer.
        dense_attention(&q, &k, &v, n, dim, &mut ws, &mut out);
        assert_eq!(out, first_out);
    }
}
