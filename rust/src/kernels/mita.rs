//! Native MiTA attention forward pass (Alg. 1 of the paper, CPU edition).
//!
//! The N-width fast-weight MLP is compressed by `m` landmark queries
//! (adaptive average pooling over Q), each landmark gathers its top-`k`
//! activated key-value pairs into a deformable expert, and every real query
//! is argmax-routed to exactly one expert. Routing semantics are *reused*
//! from [`crate::mita::routing`] — the same functions the property tests
//! pin against kernels/ref.py — so the native path and the Pallas kernel
//! share one definition of the math.
//!
//! Execution layout mirrors the Pallas host wrapper: queries are packed
//! into `[m, cap, d]` slots ([`routing::pack_by_expert`]), experts compute
//! in parallel over disjoint packed regions, and results scatter back to
//! `[n, d]`. Queries that overflow an expert's capacity are not dropped
//! (unlike the static-shape kernel): they fall back to an unpacked
//! per-query pass over the same expert KV, so the native output is exact
//! for every query.

use crate::kernels::linalg::{
    axpy, dot, gather_head, matmul_nt, scale_in_place, scatter_head, softmax_in_place,
};
use crate::kernels::par::par_chunks_mut;
use crate::mita::routing;

/// Shape-independent MiTA kernel parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MitaKernelConfig {
    /// Landmark / expert count (m in the paper).
    pub m: usize,
    /// KV pairs gathered per expert (k in the paper).
    pub k: usize,
    /// Query capacity factor over the mean per-expert load.
    pub cap_factor: usize,
    /// Capacity rounding granularity (the kernel's query block).
    pub block_q: usize,
}

impl Default for MitaKernelConfig {
    fn default() -> Self {
        MitaKernelConfig { m: 16, k: 64, cap_factor: 2, block_q: 16 }
    }
}

impl MitaKernelConfig {
    /// Paper-flavored defaults for a sequence length: m ≈ √n landmarks
    /// (clamped to [4, 64]), k = 4·(n/m) gathered KV per expert.
    pub fn for_seq(n: usize) -> Self {
        let m = (n as f64).sqrt().round() as usize;
        let m = m.clamp(4, 64).min(n.max(1));
        let k = (4 * n.div_ceil(m)).min(n.max(1));
        MitaKernelConfig { m, k, cap_factor: 2, block_q: 16 }
    }

    /// Clamp to a concrete sequence length (m, k ≤ n; everything ≥ 1).
    fn clamped(self, n: usize) -> Self {
        MitaKernelConfig {
            m: self.m.clamp(1, n.max(1)),
            k: self.k.clamp(1, n.max(1)),
            cap_factor: self.cap_factor.max(1),
            block_q: self.block_q.max(1),
        }
    }
}

/// Routing/packing statistics of one forward call.
#[derive(Debug, Clone)]
pub struct MitaStats {
    /// Query slots per expert after rounding.
    pub cap: usize,
    /// Queries that exceeded their expert's capacity (served by the
    /// unpacked fallback pass).
    pub overflow: usize,
    /// Queries routed to each expert (before capacity truncation).
    pub expert_counts: Vec<usize>,
}

/// One query row attending over an expert's gathered KV (indices into the
/// original K/V, no copies). `orow` is overwritten.
#[allow(clippy::too_many_arguments)]
fn attend_one(
    qrow: &[f32],
    picks: &[usize],
    kmat: &[f32],
    v: &[f32],
    d: usize,
    scale: f32,
    logits: &mut [f32],
    orow: &mut [f32],
) {
    debug_assert_eq!(logits.len(), picks.len());
    for (l, &ki) in logits.iter_mut().zip(picks) {
        *l = dot(qrow, &kmat[ki * d..(ki + 1) * d]) * scale;
    }
    softmax_in_place(logits);
    orow.fill(0.0);
    for (&w, &ki) in logits.iter().zip(picks) {
        axpy(w, &v[ki * d..(ki + 1) * d], orow);
    }
}

/// Single-head MiTA forward over row-major `[n, d]` Q/K/V. Writes `[n, d]`
/// into `out` and returns routing statistics.
pub fn mita_attention(
    q: &[f32],
    kmat: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    cfg: &MitaKernelConfig,
    out: &mut [f32],
) -> MitaStats {
    assert_eq!(q.len(), n * d, "q must be [n, d]");
    assert_eq!(kmat.len(), n * d, "k must be [n, d]");
    assert_eq!(v.len(), n * d, "v must be [n, d]");
    assert_eq!(out.len(), n * d, "out must be [n, d]");
    if n == 0 || d == 0 {
        return MitaStats { cap: 0, overflow: 0, expert_counts: Vec::new() };
    }
    let cfg = cfg.clamped(n);
    let (m, kk) = (cfg.m, cfg.k);
    let scale = 1.0 / (d as f32).sqrt();

    // 1. Landmarks: adaptive average pooling over Q (Alg. 1 line 3).
    let landmarks = routing::landmarks_pool1d(q, n, d, m);

    // 2. Landmark scores S = K Q̃ᵀ / √d as a blocked matmul ([n, m], same
    //    layout as routing::scores).
    let mut s = vec![0.0f32; n * m];
    matmul_nt(kmat, &landmarks, n, m, d, &mut s);
    scale_in_place(&mut s, scale);

    // 3. Deformable experts: top-k activated KV rows per landmark (Eq. 7).
    let topk = routing::topk_indices(&s, n, m, kk);

    // 4. Argmax routing via blocked logits Q Q̃ᵀ — the dot products run in
    //    the same order as routing::route_argmax's scalar loop (and ties
    //    keep the lower expert id), so the assignment is bit-identical to
    //    it — then capacity packing (DESIGN.md §6 semantics).
    let mut route_logits = vec![0.0f32; n * m];
    matmul_nt(q, &landmarks, n, m, d, &mut route_logits);
    let assign: Vec<usize> = route_logits
        .chunks_exact(m)
        .map(|row| {
            let mut best = 0usize;
            for (i, &x) in row.iter().enumerate() {
                if x > row[best] {
                    best = i;
                }
            }
            best
        })
        .collect();
    let cap = routing::capacity(n, m, cfg.cap_factor, cfg.block_q);
    let pack = routing::pack_by_expert(&assign, m, cap);
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); m];
    for (qi, slot) in pack.slot.iter().enumerate() {
        if let Some(si) = slot {
            members[si / cap].push(qi); // rank order == arrival order
        }
    }

    // 5. Per-expert attention into the packed [m, cap, d] buffer; experts
    //    own disjoint regions, so they run in parallel.
    let mut packed = vec![0.0f32; m * cap * d];
    par_chunks_mut(&mut packed, cap * d, |e, chunk| {
        let picks = &topk[e * kk..(e + 1) * kk];
        let mut logits = vec![0.0f32; kk];
        for (rank, &qi) in members[e].iter().enumerate() {
            let qrow = &q[qi * d..(qi + 1) * d];
            let orow = &mut chunk[rank * d..(rank + 1) * d];
            attend_one(qrow, picks, kmat, v, d, scale, &mut logits, orow);
        }
    });

    // 6. Scatter packed results back to query order.
    for (e, mem) in members.iter().enumerate() {
        for (rank, &qi) in mem.iter().enumerate() {
            let src = &packed[(e * cap + rank) * d..(e * cap + rank + 1) * d];
            out[qi * d..(qi + 1) * d].copy_from_slice(src);
        }
    }

    // 7. Overflowed queries: unpacked fallback over the same expert KV, so
    //    the native output stays exact under skewed routing.
    if pack.overflow > 0 {
        let mut logits = vec![0.0f32; kk];
        for (qi, slot) in pack.slot.iter().enumerate() {
            if slot.is_none() {
                let e = assign[qi];
                let picks = &topk[e * kk..(e + 1) * kk];
                let qrow = &q[qi * d..(qi + 1) * d];
                let orow = &mut out[qi * d..(qi + 1) * d];
                attend_one(qrow, picks, kmat, v, d, scale, &mut logits, orow);
            }
        }
    }

    MitaStats { cap, overflow: pack.overflow, expert_counts: pack.counts }
}

/// Multi-head MiTA over model-dim layout `[n, dim]` (`dim = heads · dh`),
/// with independent routing per head. Returns the total overflow across
/// heads (each head's overflow queries were served by the fallback pass).
#[allow(clippy::too_many_arguments)]
pub fn mita_attention_mh(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    heads: usize,
    dim: usize,
    cfg: &MitaKernelConfig,
    out: &mut [f32],
) -> usize {
    assert!(heads >= 1 && dim % heads == 0, "dim {dim} must divide into {heads} heads");
    if n == 0 || dim == 0 {
        return 0;
    }
    let dh = dim / heads;
    let mut qh = vec![0.0f32; n * dh];
    let mut kh = vec![0.0f32; n * dh];
    let mut vh = vec![0.0f32; n * dh];
    let mut oh = vec![0.0f32; n * dh];
    let mut overflow = 0usize;
    for h in 0..heads {
        gather_head(q, n, dim, dh, h, &mut qh);
        gather_head(k, n, dim, dh, h, &mut kh);
        gather_head(v, n, dim, dh, h, &mut vh);
        overflow += mita_attention(&qh, &kh, &vh, n, dh, cfg, &mut oh).overflow;
        scatter_head(&oh, n, dim, dh, h, out);
    }
    overflow
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::kernels::dense::dense_attention;

    fn rand_qkv(rng: &mut Rng, n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut gen = |len: usize| (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect::<Vec<_>>();
        (gen(n * d), gen(n * d), gen(n * d))
    }

    #[test]
    fn degenerate_full_attention_matches_dense() {
        // m = n, k = n: every landmark is one query, every expert gathers
        // the full KV set, so MiTA must reduce to dense attention.
        let mut rng = Rng::new(21);
        for (n, d) in [(8, 4), (33, 8), (64, 16)] {
            let (q, k, v) = rand_qkv(&mut rng, n, d);
            let cfg = MitaKernelConfig { m: n, k: n, cap_factor: 2, block_q: 8 };
            let mut got = vec![0.0f32; n * d];
            mita_attention(&q, &k, &v, n, d, &cfg, &mut got);
            let mut want = vec![0.0f32; n * d];
            dense_attention(&q, &k, &v, n, d, &mut want);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!((g - w).abs() < 1e-4, "n={n} d={d} elem {i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn identical_queries_survive_overflow() {
        // All queries identical ⇒ all route to one expert ⇒ massive
        // overflow; every output row must still be identical because the
        // fallback pass computes the same expert attention.
        let (n, d) = (24, 4);
        let q = vec![0.7f32; n * d];
        let mut rng = Rng::new(9);
        let k: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let v: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let cfg = MitaKernelConfig { m: 4, k: 8, cap_factor: 1, block_q: 1 };
        let mut out = vec![0.0f32; n * d];
        let stats = mita_attention(&q, &k, &v, n, d, &cfg, &mut out);
        assert!(stats.overflow > 0, "test must exercise the overflow path");
        let first = &out[..d];
        for r in 1..n {
            for c in 0..d {
                assert!(
                    (out[r * d + c] - first[c]).abs() < 1e-5,
                    "row {r} diverged despite identical queries"
                );
            }
        }
    }

    #[test]
    fn stats_are_consistent() {
        let mut rng = Rng::new(33);
        let (n, d) = (50, 8);
        let (q, k, v) = rand_qkv(&mut rng, n, d);
        let cfg = MitaKernelConfig { m: 5, k: 12, cap_factor: 2, block_q: 4 };
        let mut out = vec![0.0f32; n * d];
        let stats = mita_attention(&q, &k, &v, n, d, &cfg, &mut out);
        assert_eq!(stats.expert_counts.len(), 5);
        assert_eq!(stats.expert_counts.iter().sum::<usize>(), n);
        assert_eq!(stats.cap % 4, 0);
        let expect_overflow: usize =
            stats.expert_counts.iter().map(|&c| c.saturating_sub(stats.cap)).sum();
        assert_eq!(stats.overflow, expect_overflow);
    }

    #[test]
    fn config_clamps_to_sequence() {
        let cfg = MitaKernelConfig { m: 100, k: 100, cap_factor: 0, block_q: 0 };
        let (n, d) = (6, 3);
        let mut rng = Rng::new(2);
        let (q, k, v) = rand_qkv(&mut rng, n, d);
        let mut out = vec![0.0f32; n * d];
        let stats = mita_attention(&q, &k, &v, n, d, &cfg, &mut out);
        assert_eq!(stats.expert_counts.len(), n); // m clamped to n
        assert!(out.iter().all(|x| x.is_finite()));
        let auto = MitaKernelConfig::for_seq(1024);
        assert!(auto.m >= 4 && auto.m <= 64 && auto.k <= 1024);
    }

    #[test]
    fn multihead_equals_per_head_calls() {
        let mut rng = Rng::new(8);
        let (n, heads, dh) = (40, 2, 8);
        let dim = heads * dh;
        let gen = |rng: &mut Rng, len: usize| {
            (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect::<Vec<f32>>()
        };
        let q = gen(&mut rng, n * dim);
        let k = gen(&mut rng, n * dim);
        let v = gen(&mut rng, n * dim);
        let cfg = MitaKernelConfig { m: 8, k: 16, cap_factor: 2, block_q: 8 };
        let mut got = vec![0.0f32; n * dim];
        mita_attention_mh(&q, &k, &v, n, heads, dim, &cfg, &mut got);

        let mut want = vec![0.0f32; n * dim];
        let mut qh = vec![0.0f32; n * dh];
        let mut kh = vec![0.0f32; n * dh];
        let mut vh = vec![0.0f32; n * dh];
        let mut oh = vec![0.0f32; n * dh];
        for h in 0..heads {
            gather_head(&q, n, dim, dh, h, &mut qh);
            gather_head(&k, n, dim, dh, h, &mut kh);
            gather_head(&v, n, dim, dh, h, &mut vh);
            mita_attention(&qh, &kh, &vh, n, dh, &cfg, &mut oh);
            scatter_head(&oh, n, dim, dh, h, &mut want);
        }
        assert_eq!(got, want);
    }
}
