//! Scoped-thread parallel helpers — the std-only substitute for rayon in
//! this offline build (the vendored crate set has no rayon).
//!
//! The only primitive the execution stack needs is "run a closure over
//! disjoint mutable chunks of a buffer, spread across threads". The
//! batched executor ([`crate::kernels::api::run_batched`]) is the main
//! user: every (example × head) work item owns one disjoint chunk of the
//! staging/output buffer, and each worker draws scratch from the
//! [`crate::kernels::workspace::WorkspacePool`]. Chunks are dealt
//! round-robin so ragged workloads still balance.

use std::num::NonZeroUsize;

/// Worker count: `MITA_NUM_THREADS` if set to a positive integer (useful
/// for deterministic benchmarking), else the machine's available
/// parallelism. An unparseable or zero value falls back to the latter
/// rather than silently degrading to one thread.
pub fn num_threads() -> usize {
    let fallback = std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1);
    match std::env::var("MITA_NUM_THREADS") {
        Ok(v) => v.trim().parse::<usize>().ok().filter(|&n| n > 0).unwrap_or(fallback),
        Err(_) => fallback,
    }
}

/// Invoke `f(chunk_index, chunk)` for every `chunk_len`-sized chunk of
/// `buf` (last chunk may be short), distributing chunks across threads.
/// Falls back to a plain loop when one thread suffices.
pub fn par_chunks_mut<T, F>(buf: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    if buf.is_empty() {
        return;
    }
    let nchunks = buf.len().div_ceil(chunk_len);
    let threads = num_threads().min(nchunks);
    if threads <= 1 {
        for (i, chunk) in buf.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let f = &f;
    std::thread::scope(|scope| {
        let mut groups: Vec<_> = (0..threads).map(|_| Vec::new()).collect();
        for (i, chunk) in buf.chunks_mut(chunk_len).enumerate() {
            groups[i % threads].push((i, chunk));
        }
        for group in groups {
            scope.spawn(move || {
                for (i, chunk) in group {
                    f(i, chunk);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_chunk_visited_exactly_once() {
        let mut buf = vec![0usize; 103]; // ragged tail
        par_chunks_mut(&mut buf, 10, |i, chunk| {
            for x in chunk.iter_mut() {
                *x += i + 1;
            }
        });
        for (j, &x) in buf.iter().enumerate() {
            assert_eq!(x, j / 10 + 1, "element {j}");
        }
    }

    #[test]
    fn single_chunk_and_empty_buffers() {
        let mut buf = vec![1.0f32; 4];
        par_chunks_mut(&mut buf, 64, |i, chunk| {
            assert_eq!(i, 0);
            assert_eq!(chunk.len(), 4);
        });
        let mut empty: Vec<f32> = Vec::new();
        par_chunks_mut(&mut empty, 8, |_, _| panic!("no chunks expected"));
    }

    #[test]
    fn num_threads_is_positive() {
        assert!(num_threads() >= 1);
    }
}
