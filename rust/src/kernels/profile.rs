//! Continuous op-level profiler: always-on per-phase timing accumulators.
//!
//! Every native hot path (the MiTA kernel phases, the dense baseline,
//! the decode prefill/step loop) brackets its work with an
//! [`Instant`] pair and folds the elapsed nanoseconds into one of a
//! fixed set of process-wide atomic accumulators — one `(ns, calls)`
//! pair per [`Op`]. Recording is two relaxed `fetch_add`s plus a
//! monotonic clock read, so the profiler can stay on in production;
//! when nothing executes it costs nothing at all.
//!
//! The accumulators are process-global rather than per-replica by
//! design: kernel work items run on the shared scoped-thread pool
//! (`kernels::par`), where a worker has no replica identity — replica
//! attribution lives one level up in `/v1/trace` and the per-replica
//! series of `/v1/metrics`. The profile is exported two ways:
//!
//! - `GET /v1/profile` — a hierarchical timing tree (`mita.*`,
//!   `dense.*`, `decode.*` groups) built by [`profile_tree`];
//! - `op_time_us_total{op}` / `op_calls_total{op}` Prometheus series in
//!   `GET /v1/metrics?format=prometheus`, fed from [`snapshot`].
//!
//! Timing only ever *brackets* phase calls — it never reorders or
//! conditions the arithmetic, so bit-parity guarantees (shared
//! `select_experts`, SIMD lane equivalence) are untouched.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Value;

/// One profiled operation (a kernel phase or decode stage). The
/// discriminant indexes the accumulator table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Op {
    /// MiTA: adaptive-average landmark pooling over Q.
    MitaLandmarks = 0,
    /// MiTA: blocked landmark scores S = K·Q̃ᵀ/√d.
    MitaScores = 1,
    /// MiTA: top-k KV gather per landmark.
    MitaTopk = 2,
    /// MiTA: routing logits + argmax assignment per query.
    MitaRoute = 3,
    /// MiTA: capacity computation + expert packing.
    MitaPack = 4,
    /// MiTA: packed expert-grouped attention.
    MitaAttend = 5,
    /// MiTA: unpacked overflow fallback (recorded only when it runs).
    MitaOverflow = 6,
    /// Dense baseline: the full O(N²) attention body.
    DenseAttend = 7,
    /// Decode: prefill pass (prompt forwards + first argmax).
    DecodePrefill = 8,
    /// Decode: one steady-state token step.
    DecodeStep = 9,
}

/// Number of profiled ops (length of [`OP_NAMES`] and the slot table).
pub const OP_COUNT: usize = 10;

/// Exported op names, indexed by `Op as usize`. Dotted so the profile
/// tree can group them (`mita.*` / `dense.*` / `decode.*`).
pub const OP_NAMES: [&str; OP_COUNT] = [
    "mita.landmarks",
    "mita.scores",
    "mita.topk",
    "mita.route",
    "mita.pack",
    "mita.attend",
    "mita.overflow",
    "dense.attend",
    "decode.prefill",
    "decode.step",
];

/// The MiTA phase names, in execution order — the set the profile
/// acceptance probe asserts nonzero after a forward with overflow.
pub const MITA_PHASES: [&str; 7] = [
    "mita.landmarks",
    "mita.scores",
    "mita.topk",
    "mita.route",
    "mita.pack",
    "mita.attend",
    "mita.overflow",
];

struct OpSlot {
    ns: AtomicU64,
    calls: AtomicU64,
}

impl OpSlot {
    const fn new() -> Self {
        OpSlot { ns: AtomicU64::new(0), calls: AtomicU64::new(0) }
    }
}

#[allow(clippy::declare_interior_mutable_const)]
static SLOTS: [OpSlot; OP_COUNT] = [
    OpSlot::new(),
    OpSlot::new(),
    OpSlot::new(),
    OpSlot::new(),
    OpSlot::new(),
    OpSlot::new(),
    OpSlot::new(),
    OpSlot::new(),
    OpSlot::new(),
    OpSlot::new(),
];

/// Fold `ns` nanoseconds (one call) into `op`'s accumulator.
#[inline]
pub fn record(op: Op, ns: u64) {
    let slot = &SLOTS[op as usize];
    slot.ns.fetch_add(ns, Ordering::Relaxed);
    slot.calls.fetch_add(1, Ordering::Relaxed);
}

/// Fold the wall time since `t0` (one call) into `op`'s accumulator.
#[inline]
pub fn record_since(op: Op, t0: Instant) {
    record(op, t0.elapsed().as_nanos() as u64);
}

/// One exported op series: cumulative microseconds + call count.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OpSeries {
    /// Dotted op name (see [`OP_NAMES`]).
    pub op: String,
    /// Cumulative wall time, microseconds (float: sub-µs ops still show).
    pub time_us: f64,
    /// Cumulative call count.
    pub calls: u64,
}

/// Snapshot every op accumulator, in [`OP_NAMES`] order. Every op is
/// always present (zeros when idle), so the exported series set is
/// stable across scrapes.
pub fn snapshot() -> Vec<OpSeries> {
    OP_NAMES
        .iter()
        .enumerate()
        .map(|(i, name)| OpSeries {
            op: (*name).to_string(),
            time_us: SLOTS[i].ns.load(Ordering::Relaxed) as f64 / 1000.0,
            calls: SLOTS[i].calls.load(Ordering::Relaxed),
        })
        .collect()
}

/// Render the profile as a hierarchical timing tree: ops grouped by
/// their dotted prefix, each leaf carrying `{time_us, calls, mean_us}`,
/// each group carrying a `total_us` rollup. The `GET /v1/profile` body.
pub fn profile_tree() -> Value {
    let snap = snapshot();
    let mut groups: Vec<(&str, Vec<(&str, &OpSeries)>)> = Vec::new();
    for (i, s) in snap.iter().enumerate() {
        let (group, leaf) = OP_NAMES[i].split_once('.').expect("op names are dotted");
        match groups.iter_mut().find(|(g, _)| *g == group) {
            Some((_, leaves)) => leaves.push((leaf, s)),
            None => groups.push((group, vec![(leaf, s)])),
        }
    }
    let mut out = Vec::with_capacity(groups.len());
    for (group, leaves) in groups {
        let total_us: f64 = leaves.iter().map(|(_, s)| s.time_us).sum();
        let mut obj: Vec<(&str, Value)> = vec![("total_us", Value::Num(total_us))];
        for (leaf, s) in leaves {
            let mean = if s.calls > 0 { s.time_us / s.calls as f64 } else { 0.0 };
            obj.push((
                leaf,
                Value::obj(vec![
                    ("time_us", Value::Num(s.time_us)),
                    ("calls", Value::Num(s.calls as f64)),
                    ("mean_us", Value::Num(mean)),
                ]),
            ));
        }
        out.push((group, Value::obj(obj)));
    }
    Value::obj(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series(snap: &[OpSeries], op: &str) -> OpSeries {
        snap.iter().find(|s| s.op == op).cloned().expect("op present")
    }

    #[test]
    fn snapshot_lists_every_op_exactly_once() {
        let snap = snapshot();
        assert_eq!(snap.len(), OP_COUNT);
        for name in OP_NAMES {
            assert_eq!(snap.iter().filter(|s| s.op == name).count(), 1, "{name}");
        }
    }

    #[test]
    fn record_accumulates_time_and_calls() {
        // The table is process-global and tests run in parallel, so
        // assert on deltas rather than absolute values.
        let before = series(&snapshot(), "dense.attend");
        record(Op::DenseAttend, 2_500);
        record(Op::DenseAttend, 500);
        let after = series(&snapshot(), "dense.attend");
        assert!(after.calls >= before.calls + 2);
        assert!(after.time_us >= before.time_us + 3.0 - 1e-9);
    }

    #[test]
    fn record_since_uses_wall_time() {
        let before = series(&snapshot(), "decode.prefill");
        let t0 = Instant::now();
        std::hint::black_box(0u64);
        record_since(Op::DecodePrefill, t0);
        let after = series(&snapshot(), "decode.prefill");
        assert_eq!(after.calls, before.calls.max(after.calls));
        assert!(after.calls > before.calls);
    }

    #[test]
    fn profile_tree_groups_by_prefix_with_rollups() {
        record(Op::MitaLandmarks, 1_000);
        let text = profile_tree().render();
        for group in ["mita", "dense", "decode"] {
            assert!(text.contains(&format!("\"{group}\":")), "{text}");
        }
        for leaf in ["landmarks", "scores", "topk", "route", "pack", "attend", "overflow"] {
            assert!(text.contains(&format!("\"{leaf}\":")), "{text}");
        }
        assert!(text.contains("\"total_us\":"), "{text}");
        assert!(text.contains("\"mean_us\":"), "{text}");
    }

    #[test]
    fn mita_phase_registry_matches_op_names() {
        for phase in MITA_PHASES {
            assert!(OP_NAMES.contains(&phase), "{phase} missing from OP_NAMES");
        }
        assert_eq!(MITA_PHASES.len(), OP_NAMES.iter().filter(|n| n.starts_with("mita.")).count());
    }
}
