//! Blocked row-major matrix primitives shared by the native attention
//! kernels. Everything is f32, row-major, allocation-free (callers own the
//! buffers), and routed through the runtime-dispatched SIMD ops of
//! [`crate::kernels::simd`] — all lanes return bit-identical results
//! (fixed canonical reduction order), so callers never observe which
//! lane ran.

use crate::kernels::simd;

/// `out[i, j] = Σ_c a[i, c] · b[j, c]` — A·Bᵀ for row-major A `[p, d]` and
/// B `[q, d]`. This dot-product form is every attention score computation.
/// Tiled over (i, j) so a block of B rows stays hot in L1; the dispatched
/// dot is hoisted out of the loops once.
pub fn matmul_nt(a: &[f32], b: &[f32], p: usize, q: usize, d: usize, out: &mut [f32]) {
    assert_eq!(a.len(), p * d, "a must be [p, d]");
    assert_eq!(b.len(), q * d, "b must be [q, d]");
    assert_eq!(out.len(), p * q, "out must be [p, q]");
    const IB: usize = 16;
    const JB: usize = 32;
    let dot_op = simd::ops().dot;
    for i0 in (0..p).step_by(IB) {
        let i1 = (i0 + IB).min(p);
        for j0 in (0..q).step_by(JB) {
            let j1 = (j0 + JB).min(q);
            for i in i0..i1 {
                let arow = &a[i * d..(i + 1) * d];
                let orow = &mut out[i * q..(i + 1) * q];
                for j in j0..j1 {
                    let brow = &b[j * d..(j + 1) * d];
                    orow[j] = dot_op(arow, brow);
                }
            }
        }
    }
}

/// Dot product of two equal-length slices (dispatched; canonical
/// tree-reduction order on every lane).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    (simd::ops().dot)(x, y)
}

/// `y += alpha · x` (the attention value-accumulation step; dispatched).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    (simd::ops().axpy)(alpha, x, y)
}

/// Multiply every element by `s` (dispatched).
#[inline]
pub fn scale_in_place(x: &mut [f32], s: f32) {
    (simd::ops().scale)(x, s)
}

/// Numerically-stable softmax over one row, in place. No-op on empty rows.
pub fn softmax_in_place(x: &mut [f32]) {
    // `1.0 · (v − mx)` is exact in IEEE f32, so delegating keeps the
    // unscaled softmax bit-identical to the scaled one at scale = 1.
    softmax_in_place_scaled(x, 1.0);
}

/// Softmax of `scale · x` over one row, in place, for `scale > 0` — the
/// attention-logit pre-scale folded into the exp pass. `max(scale·x) =
/// scale·max(x)` for positive scale, so `exp(scale·(v − max))` needs no
/// separate scaling traversal over the row (one fewer full-row pass on
/// the dense serving hot path). The max and the final normalization are
/// dispatched; the exp loop (libm) and its running denominator stay
/// sequential scalar code shared by every lane.
pub fn softmax_in_place_scaled(x: &mut [f32], scale: f32) {
    debug_assert!(scale > 0.0, "softmax pre-scale must be positive, got {scale}");
    if x.is_empty() {
        return;
    }
    let ops = simd::ops();
    let mx = (ops.max)(x);
    let mut den = 0.0f32;
    for v in x.iter_mut() {
        *v = (scale * (*v - mx)).exp();
        den += *v;
    }
    (ops.scale)(x, 1.0 / den);
}

/// Softmax over each row of a `[rows, cols]` buffer, in place.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols);
    for row in x.chunks_exact_mut(cols) {
        softmax_in_place(row);
    }
}

/// Row-wise [`softmax_in_place_scaled`] over a `[rows, cols]` buffer.
pub fn softmax_rows_scaled(x: &mut [f32], rows: usize, cols: usize, scale: f32) {
    assert_eq!(x.len(), rows * cols);
    for row in x.chunks_exact_mut(cols) {
        softmax_in_place_scaled(row, scale);
    }
}

/// `out[c] = Σ_i weights[i] · rows[i, c]` for row-major `rows` `[k, d]` —
/// the probability-weighted value combine.
pub fn weighted_row_sum(weights: &[f32], rows: &[f32], d: usize, out: &mut [f32]) {
    assert_eq!(rows.len(), weights.len() * d, "rows must be [len(weights), d]");
    assert_eq!(out.len(), d);
    out.fill(0.0);
    for (w, row) in weights.iter().zip(rows.chunks_exact(d)) {
        axpy(*w, row, out);
    }
}

/// Copy head `h`'s column block out of a `[n, dim]` matrix into a
/// contiguous `[n, dh]` buffer (`dim = heads · dh`).
pub fn gather_head(x: &[f32], n: usize, dim: usize, dh: usize, h: usize, out: &mut [f32]) {
    assert_eq!(x.len(), n * dim);
    assert_eq!(out.len(), n * dh);
    let off = h * dh;
    for (orow, xrow) in out.chunks_exact_mut(dh).zip(x.chunks_exact(dim)) {
        orow.copy_from_slice(&xrow[off..off + dh]);
    }
}

/// Inverse of [`gather_head`]: write a contiguous `[n, dh]` head result
/// back into its column block of the `[n, dim]` output.
pub fn scatter_head(xh: &[f32], n: usize, dim: usize, dh: usize, h: usize, out: &mut [f32]) {
    assert_eq!(xh.len(), n * dh);
    assert_eq!(out.len(), n * dim);
    let off = h * dh;
    for (orow, xrow) in out.chunks_exact_mut(dim).zip(xh.chunks_exact(dh)) {
        orow[off..off + dh].copy_from_slice(xrow);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn naive_nt(a: &[f32], b: &[f32], p: usize, q: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0.0f64; p * q];
        for i in 0..p {
            for j in 0..q {
                for c in 0..d {
                    out[i * q + j] += a[i * d + c] as f64 * b[j * d + c] as f64;
                }
            }
        }
        out.into_iter().map(|x| x as f32).collect()
    }

    #[test]
    fn matmul_nt_matches_naive_on_awkward_shapes() {
        let mut rng = Rng::new(11);
        for (p, q, d) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (40, 19, 64), (16, 32, 16)] {
            let a: Vec<f32> = (0..p * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..q * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let mut got = vec![0.0f32; p * q];
            matmul_nt(&a, &b, p, q, d, &mut got);
            let want = naive_nt(&a, &b, p, q, d);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "p={p} q={q} d={d}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn softmax_rows_are_distributions() {
        let mut x = vec![0.0f32, 1.0, 2.0, -50.0, 100.0, 100.0];
        softmax_rows(&mut x, 2, 3);
        for row in x.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row sums to {s}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        // Large equal logits split evenly without overflow.
        assert!((x[4] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn scaled_softmax_folds_the_prescale() {
        // softmax_rows_scaled(x, s) must agree with the two-pass spelling
        // scale_in_place(x, s); softmax_rows(x) it replaced.
        let mut rng = Rng::new(17);
        for (rows, cols) in [(1, 1), (3, 9), (5, 33)] {
            let base: Vec<f32> = (0..rows * cols).map(|_| rng.range_f32(-3.0, 3.0)).collect();
            let scale = 0.37f32;
            let mut folded = base.clone();
            softmax_rows_scaled(&mut folded, rows, cols, scale);
            let mut two_pass = base;
            scale_in_place(&mut two_pass, scale);
            softmax_rows(&mut two_pass, rows, cols);
            for (f, t) in folded.iter().zip(&two_pass) {
                assert!((f - t).abs() < 1e-5, "folded {f} vs two-pass {t}");
            }
        }
    }

    #[test]
    fn weighted_row_sum_and_axpy() {
        let rows = [1.0f32, 0.0, 0.0, 1.0]; // identity [2, 2]
        let mut out = vec![9.0f32; 2];
        weighted_row_sum(&[0.25, 0.75], &rows, 2, &mut out);
        assert_eq!(out, vec![0.25, 0.75]);
        axpy(2.0, &[1.0, 2.0], &mut out);
        assert_eq!(out, vec![2.25, 4.75]);
    }

    #[test]
    fn head_gather_scatter_roundtrip() {
        let (n, heads, dh) = (3, 2, 2);
        let dim = heads * dh;
        let x: Vec<f32> = (0..n * dim).map(|i| i as f32).collect();
        let mut back = vec![0.0f32; n * dim];
        let mut xh = vec![0.0f32; n * dh];
        for h in 0..heads {
            gather_head(&x, n, dim, dh, h, &mut xh);
            scatter_head(&xh, n, dim, dh, h, &mut back);
        }
        assert_eq!(back, x);
    }
}
